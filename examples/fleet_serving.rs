//! Fleet serving demo: the L4 tier scales one PYNQ-Z1 serving stack
//! to N modeled boards behind a gossip-fed, cost-model router.
//!
//! Two demonstrations:
//!
//! * **Scaling** — a mixed burst (small conv net + FC head, offered
//!   far beyond one board's capacity) served by 1/2/4-board fleets.
//!   The router spreads the burst by gossiped backlog, so aggregate
//!   modeled req/s scales near-linearly with the board count.
//! * **Portfolio** — two boards start mis-provisioned on the VM
//!   bitstream while the traffic is deep-K convolution, the one shape
//!   the VM cannot hold on fabric (K exceeds its local buffers). The
//!   fleet-wide planner sees the aggregate profile, splits it per
//!   board, and pays one modeled bitstream reload per board to move
//!   the portfolio onto the SA design — the SECDA co-design loop run
//!   at serving time, across a fleet.
//!
//! Run: `cargo run --release --example fleet_serving`
//!
//! Observability: `--trace-out trace.json` writes the portfolio run's
//! fleet Chrome trace — one process per board, Perfetto-loadable, with
//! the per-board request/batch/GEMM tracks side by side.
//! `--metrics-out metrics.json` writes the fleet metrics snapshot
//! (`fleet.*` aggregates plus `board{N}.*` breakdowns).
//! `--series-out series.json` enables fleet telemetry on the portfolio
//! run and writes the merged fleet-level time-series document
//! (validated by `secda trace-validate`); `--alerts` prints every
//! fleet-level alert the burn-rate/change-point engine fired.

use std::sync::Arc;

use secda::coordinator::CoordinatorConfig;
use secda::elastic::ElasticConfig;
use secda::fleet::{Fleet, FleetConfig, GossipConfig, IngressModel};
use secda::framework::graph::{Graph, GraphBuilder};
use secda::framework::ops::{Activation, Conv2d, FullyConnected, GlobalAvgPool, Op, SoftmaxOp};
use secda::framework::quant::QParams;
use secda::framework::tensor::Tensor;
use secda::obs::export::{metrics_json, timeseries_json};
use secda::obs::TelemetryConfig;
use secda::sysc::SimTime;

fn xorshift(st: &mut u64) -> u64 {
    *st ^= *st << 13;
    *st ^= *st >> 7;
    *st ^= *st << 17;
    *st
}

/// Small conv net for the scaling burst (both convs offload).
fn cam() -> Graph {
    let mut st = 0xf1u64;
    let (cin, cout) = (3usize, 24usize);
    let mut b = GraphBuilder::new("fleet_cam", vec![1, 12, 12, cin], QParams::new(0.05, 0));
    let conv = Conv2d {
        name: "c1".into(),
        cout,
        kh: 3,
        kw: 3,
        cin,
        stride: 1,
        pad: 1,
        weights: (0..cout * 9 * cin)
            .map(|_| (xorshift(&mut st) & 0xff) as u8 as i8)
            .collect(),
        bias: vec![5; cout],
        w_scales: vec![0.02; cout],
        out_qp: QParams::new(0.05, 0),
        act: Activation::Relu,
        weights_resident: false,
    };
    let c = b.push(Op::Conv(conv), vec![b.input()]);
    let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
    let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
    b.finish(s)
}

/// FC head for the mixed half of the burst.
fn head() -> Graph {
    let mut st = 0x4eadu64;
    let feat = 512;
    let mut b = GraphBuilder::new("fleet_head", vec![1, feat], QParams::new(0.05, 0));
    let fc = FullyConnected {
        name: "fc0".into(),
        in_features: feat,
        out_features: feat,
        weights: (0..feat * feat)
            .map(|_| (xorshift(&mut st) & 0xff) as u8 as i8)
            .collect(),
        bias: vec![3; feat],
        w_scale: 0.02,
        out_qp: QParams::new(0.05, 0),
        act: Activation::Relu,
    };
    let f = b.push(Op::Fc(fc), vec![b.input()]);
    let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![f]);
    b.finish(s)
}

/// Deep-K conv model for the portfolio demo: the conv GEMM K (4608)
/// exceeds the paper VM's local buffers, so a VM board serves it at
/// CPU-fallback speed while an SA board runs it on fabric.
fn deep_cam() -> Graph {
    let mut st = 0xdeu64;
    let cin = 512;
    let cout = 48;
    let mut b = GraphBuilder::new("deep_cam", vec![1, 14, 14, cin], QParams::new(0.05, 0));
    let conv = Conv2d {
        name: "c1".into(),
        cout,
        kh: 3,
        kw: 3,
        cin,
        stride: 1,
        pad: 1,
        weights: (0..cout * 9 * cin)
            .map(|_| (xorshift(&mut st) & 0xff) as u8 as i8)
            .collect(),
        bias: vec![5; cout],
        w_scales: vec![0.02; cout],
        out_qp: QParams::new(0.05, 0),
        act: Activation::Relu,
        weights_resident: false,
    };
    let c = b.push(Op::Conv(conv), vec![b.input()]);
    let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
    let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
    b.finish(s)
}

fn image(g: &Graph, st: &mut u64) -> Tensor {
    let n: usize = g.input_shape.iter().product();
    let data = (0..n).map(|_| (xorshift(st) & 0xff) as u8 as i8).collect();
    Tensor::new(g.input_shape.clone(), data, g.input_qp)
}

/// Serve one mixed burst through an N-board fleet and report the
/// aggregate view.
fn serve_burst(gs: &[Arc<Graph>; 2], boards: usize, n_requests: usize) -> (f64, f64) {
    let fcfg = FleetConfig::default()
        .with_boards(boards)
        .with_board(CoordinatorConfig {
            queue_depth: n_requests,
            ..CoordinatorConfig::default()
        })
        .with_gossip(GossipConfig {
            staleness: SimTime::ZERO,
        });
    let mut fleet = Fleet::new(fcfg);
    let mut st = 0x5eedu64;
    for i in 0..n_requests {
        let g = &gs[i % 2];
        let input = image(g, &mut st);
        fleet.submit(g.clone(), input).expect("queue sized for the burst");
    }
    let done = fleet.run_until_idle();
    assert_eq!(done.len(), n_requests, "the fleet must serve the whole burst");
    let m = fleet.metrics();
    let util =
        m.boards.iter().map(|b| b.utilization).sum::<f64>() / m.boards.len() as f64;
    (m.throughput_rps(), util)
}

/// Strip a `--flag <value>` pair from the arg vector.
fn take_flag(args: &mut Vec<String>, flag: &str) -> Option<String> {
    let i = args.iter().position(|a| a == flag)?;
    assert!(i + 1 < args.len(), "{flag} needs a path argument");
    let v = args.remove(i + 1);
    args.remove(i);
    Some(v)
}

/// Strip a bare `--flag` switch from the arg vector.
fn take_switch(args: &mut Vec<String>, flag: &str) -> bool {
    match args.iter().position(|a| a == flag) {
        Some(i) => {
            args.remove(i);
            true
        }
        None => false,
    }
}

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let trace_out = take_flag(&mut args, "--trace-out");
    let metrics_out = take_flag(&mut args, "--metrics-out");
    let series_out = take_flag(&mut args, "--series-out");
    let show_alerts = take_switch(&mut args, "--alerts");
    println!("=== fleet serving: one serving stack, N modeled boards ===\n");

    // --- scaling: mixed burst across 1/2/4 boards -------------------
    let gs = [Arc::new(cam()), Arc::new(head())];
    let n_requests = 96;
    println!("mixed burst ({n_requests} requests, 2SA+1VM+1CPU per board):");
    println!("{:<8} {:>12} {:>9} {:>9}", "boards", "req/s", "speedup", "util");
    let mut base = None;
    let mut ratio_at_4 = 0.0;
    for boards in [1usize, 2, 4] {
        let (tp, util) = serve_burst(&gs, boards, n_requests);
        let base_tp = *base.get_or_insert(tp);
        let speedup = tp / base_tp;
        if boards == 4 {
            ratio_at_4 = speedup;
        }
        println!(
            "{:<8} {:>12.2} {:>8.2}x {:>8.1}%",
            boards,
            tp,
            speedup,
            100.0 * util
        );
    }
    assert!(
        ratio_at_4 >= 3.0,
        "4-board fleet must scale near-linearly, got {ratio_at_4:.2}x"
    );
    println!();

    // --- portfolio: fleet-wide bitstream re-planning ----------------
    println!("portfolio (2 boards start on the VM bitstream, deep-K conv traffic):");
    let mut fcfg = FleetConfig::default()
        .with_boards(2)
        .with_board(CoordinatorConfig {
            sa_workers: 0,
            vm_workers: 1,
            cpu_workers: 0,
            queue_depth: 64,
            ..CoordinatorConfig::default()
        })
        .with_ingress(IngressModel::default())
        .with_portfolio(ElasticConfig {
            eval_interval: SimTime::ms(100),
            window: SimTime::ms(2_500),
            min_samples: 4,
            hysteresis: SimTime::ms(10),
            max_swaps: 1,
            cpu_max: 0,
            ..ElasticConfig::default()
        });
    if trace_out.is_some() || metrics_out.is_some() {
        fcfg = fcfg.with_tracing(1 << 16);
    }
    if series_out.is_some() || show_alerts {
        fcfg = fcfg.with_telemetry(TelemetryConfig::default());
    }
    let deep = Arc::new(deep_cam());
    let mut fleet = Fleet::new(fcfg);
    let mut st = 0x90ddu64;
    let mut served = 0usize;
    for (bi, burst) in [4usize, 8, 8].into_iter().enumerate() {
        for _ in 0..burst {
            let input = image(&deep, &mut st);
            fleet
                .submit(deep.clone(), input)
                .expect("queue sized for the stream");
            fleet.advance(SimTime::ms(25));
        }
        let before = fleet.compositions();
        served += fleet.run_until_idle().len();
        let after = fleet.compositions();
        for b in 0..2 {
            if before[b] != after[b] {
                println!(
                    "  burst {bi}: board{b} reconfigured {} -> {}",
                    before[b], after[b]
                );
            }
        }
    }
    let m = fleet.metrics();
    println!(
        "  served {served} requests; {} portfolio swap(s), {} bitstream time",
        m.reconfigs, m.reconfig_time
    );
    println!("  {}", m.summary());

    // the demonstration this example exists for: the fleet planner
    // moved every board off the mis-provisioned VM bitstream onto the
    // SA design, paying the modeled reconfiguration cost per board
    assert!(
        !fleet.portfolio_history().is_empty(),
        "the portfolio planner never reconfigured any board"
    );
    for rec in fleet.portfolio_history() {
        assert!(
            rec.record.to.sa >= 1,
            "board {} swapped to {} instead of the SA design",
            rec.board,
            rec.record.to
        );
    }
    assert!(
        fleet.compositions().iter().any(|c| c.sa >= 1),
        "no board ended on the SA bitstream"
    );

    if let Some(path) = &trace_out {
        let trace = fleet.chrome_trace();
        std::fs::write(path, &trace).expect("write trace");
        println!("\nfleet chrome trace -> {path} (load in https://ui.perfetto.dev)");
    }
    if let Some(path) = &metrics_out {
        let json = metrics_json(&m.registry());
        std::fs::write(path, &json).expect("write metrics");
        println!("fleet metrics snapshot -> {path}");
    }
    if show_alerts {
        println!("\nfleet-level alerts:");
        let alerts = fleet.fleet_alerts();
        if alerts.is_empty() {
            println!("  (none fired — the fleet stayed inside its error budget)");
        }
        for a in alerts {
            println!(
                "  t={} {} on `{}`: value {:.3} vs threshold {:.3} (window {})",
                a.at,
                a.kind.name(),
                a.series,
                a.value,
                a.threshold,
                a.window
            );
        }
    }
    if let Some(path) = &series_out {
        let bank = fleet.fleet_series().expect("telemetry enabled for --series-out");
        let doc = timeseries_json(bank, fleet.fleet_alerts());
        std::fs::write(path, doc).expect("write series");
        println!("fleet time-series document -> {path} (validate: secda trace-validate {path})");
    }
}
