//! End-to-end serving driver: route a stream of inference requests
//! through the L3 coordinator — a pool of simulated accelerator
//! instances with bucket-aware batching, per-layer HW/SW partitioning,
//! work stealing and backpressure — while cross-checking every GEMM's
//! functional bits per request.
//!
//! This is the repo's end-to-end validation (ARCHITECTURE.md): it proves all
//! layers compose — Pallas kernel (L1) → jax lowering (L2) → rust
//! runtime + coordinator (L3) — by checking, for every request, that
//! the pool's outputs are bit-identical to an independent functional
//! path, and reports serving latency/throughput for the stream.
//!
//! With the `pjrt` feature and `make artifacts` done, the independent
//! path is the AOT-compiled PJRT executables (the "real hardware"
//! numerics); otherwise the gemmlowp CPU reference stands in, so the
//! example runs out of the box on a plain `cargo run`.
//!
//! The 4th argument picks the exec mode: `modeled` (default) drains
//! the pool as the deterministic discrete-event model; `threaded` runs
//! one OS thread per pool worker and reports real wall-clock
//! throughput next to the modeled numbers. The per-GEMM bit-identity
//! cross-check runs identically in both modes (the hook is `Send` and
//! serialized by its mutex).
//!
//! The 5th argument picks the scheduling policy: `fifo` (default),
//! `edf` (deadline-ordered queues) or `admission` (EDF plus
//! predictive load shedding). Under `edf`/`admission` every request
//! carries a 400 ms modeled SLO, and the run reports SLO attainment
//! and predicted-miss sheds.
//!
//! Run: `cargo run --release --example edge_serving \
//!     [n_requests] [model] [sa_workers] [modeled|threaded] [fifo|edf|admission]`
//!
//! Observability: `--trace-out trace.json` turns the span recorder on
//! and writes a Chrome trace-event file at the end — load it in
//! <https://ui.perfetto.dev> to see one track per pool worker, async
//! queue-wait arrows and per-GEMM accelerator events.
//! `--metrics-out metrics.json` writes the flat metrics snapshot
//! (`secda-metrics-v1`). Tracing is inert: the served outputs are
//! bit-identical with or without the flags (pinned by
//! `prop_tracing_is_inert`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use secda::coordinator::{
    AdmissionPolicy, Coordinator, CoordinatorConfig, DeadlinePolicy, ExecMode, FifoPolicy,
    SchedulePolicy, SubmitError,
};
use secda::framework::models;
use secda::framework::tensor::Tensor;
use secda::gemm;
use secda::obs::export::{chrome_trace, metrics_json};
use secda::runtime::default_dir;
use secda::sysc::SimTime;

/// Strip a `--flag <value>` pair from the arg vector, so the
/// positional arguments keep their historical indices.
fn take_flag(args: &mut Vec<String>, flag: &str) -> Option<String> {
    let i = args.iter().position(|a| a == flag)?;
    assert!(i + 1 < args.len(), "{flag} needs a path argument");
    let v = args.remove(i + 1);
    args.remove(i);
    Some(v)
}

/// Install the per-GEMM bit-identity assertion; returns the name of
/// the reference path it checks the pool against.
fn install_cross_check(coord: &mut Coordinator, checks: Arc<AtomicU64>) -> &'static str {
    #[cfg(feature = "pjrt")]
    {
        use secda::runtime::ArtifactRuntime;
        let dir = default_dir();
        if ArtifactRuntime::available(&dir) {
            // NOTE: CrossCheckFn is `Send` (worker threads invoke the
            // hook under ExecMode::Threaded), so this closure requires
            // the vendored xla PJRT wrappers to be Send. If they are
            // not when the dependency is re-added, route the cross-
            // check through a dedicated PJRT thread + channel instead
            // of capturing the runtime directly (ROADMAP item).
            let mut rt = ArtifactRuntime::new(&dir).expect("artifact runtime");
            coord.set_cross_check(Box::new(move |task, out| {
                let pjrt = rt
                    .qgemm(task.m, task.k, task.n, task.weights, task.inputs, task.params)
                    .unwrap_or_else(|e| panic!("PJRT qgemm failed for {}: {e}", task.layer));
                assert_eq!(
                    pjrt, out,
                    "layer {}: PJRT artifact diverged from the TLM simulator",
                    task.layer
                );
                checks.fetch_add(1, Ordering::Relaxed);
            }));
            return "PJRT artifacts";
        }
        eprintln!("artifacts missing at {dir:?}; cross-checking against CPU gemmlowp instead");
    }
    coord.set_cross_check(Box::new(move |task, out| {
        let reference = gemm::qgemm(
            task.weights,
            task.inputs,
            task.m,
            task.k,
            task.n,
            task.params,
            1,
        );
        assert_eq!(
            reference, out,
            "layer {}: pool output diverged from the gemmlowp reference",
            task.layer
        );
        checks.fetch_add(1, Ordering::Relaxed);
    }));
    "CPU gemmlowp reference"
}

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let trace_out = take_flag(&mut args, "--trace-out");
    let metrics_out = take_flag(&mut args, "--metrics-out");
    let n_requests: usize = args.first().and_then(|v| v.parse().ok()).unwrap_or(8);
    let model = args.get(1).map(String::as_str).unwrap_or("mobilenet_v1");
    let sa_workers: usize = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(2);
    let exec_mode = match args.get(3).map(String::as_str) {
        Some("threaded") => ExecMode::Threaded,
        Some("modeled") | None => ExecMode::Modeled,
        Some(other) => panic!("unknown exec mode {other:?}: use `modeled` or `threaded`"),
    };
    let policy_name = args.get(4).map(String::as_str).unwrap_or("fifo");
    let policy: Arc<dyn SchedulePolicy> = match policy_name {
        "fifo" => Arc::new(FifoPolicy),
        "edf" => Arc::new(DeadlinePolicy),
        "admission" => Arc::new(AdmissionPolicy),
        other => panic!("unknown policy {other:?}: use `fifo`, `edf` or `admission`"),
    };
    // SLO budget attached to every request under the deadline-aware
    // policies; `fifo` submits best-effort (no deadline), exactly the
    // pre-policy behavior.
    let slo = (policy_name != "fifo").then_some(SimTime::ms(400));

    let g = Arc::new(models::by_name(model).expect("model"));
    let mut cfg = CoordinatorConfig {
        sa_workers,
        exec_mode,
        policy,
        ..CoordinatorConfig::default()
    };
    if trace_out.is_some() || metrics_out.is_some() {
        cfg = cfg.with_tracing(1 << 16);
    }
    let mut coord =
        Coordinator::with_artifact_manifest(cfg, &default_dir()).expect("artifact manifest");
    let checks = Arc::new(AtomicU64::new(0));
    let reference = install_cross_check(&mut coord, checks.clone());
    println!(
        "serving {model} through the L3 coordinator [{exec_mode}, {policy_name} policy]: \
         {} SA + {} VM + {} CPU workers (batch window {}, queue depth {}); \
         cross-check vs {reference}",
        coord.cfg.sa_workers,
        coord.cfg.vm_workers,
        coord.cfg.cpu_workers,
        coord.cfg.batch_window,
        coord.cfg.queue_depth,
    );

    // request stream: deterministic pseudo-images, ~20-50 ms modeled
    // inter-arrival
    let mut st = 0xfeedu64;
    let mut rnd = move || {
        st ^= st << 13;
        st ^= st >> 7;
        st ^= st << 17;
        st
    };
    let mut completions = Vec::new();
    let t_serve = Instant::now();
    for _ in 0..n_requests {
        let n: usize = g.input_shape.iter().product();
        let data: Vec<i8> = (0..n).map(|_| (rnd() & 0xff) as u8 as i8).collect();
        let mut model = g.clone();
        let mut input = Tensor::new(g.input_shape.clone(), data, g.input_qp);
        loop {
            let attempt = match slo {
                Some(s) => coord.submit_with_slo(model, input, s),
                None => coord.submit(model, input),
            };
            match attempt {
                Ok(_) => break,
                // closed-loop client: drain the pool, then resubmit
                // the request that was handed back
                Err(SubmitError::Backpressure { request, .. }) => {
                    completions.extend(coord.run_until_idle());
                    model = request.model;
                    input = request.input;
                }
                // admission control says this request cannot make its
                // deadline: drop it (a real client would fail fast);
                // counted by the coordinator as metrics.shed_predicted
                Err(SubmitError::ShedPredicted { .. }) => break,
                Err(e) => panic!("submit failed: {e}"),
            }
        }
        coord.advance(SimTime::ms(20 + rnd() % 31));
    }
    completions.extend(coord.run_until_idle());
    let wall = t_serve.elapsed();

    completions.sort_by_key(|c| c.id);
    for c in &completions {
        let top = c
            .output
            .data
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap();
        println!(
            "  req {:>2}: class {top:>4}  worker {}  batch {}  modeled {:>7.1} ms on PYNQ-Z1 \
             ({:>7.1} ms incl. queueing)",
            c.id,
            c.worker,
            c.batch_size,
            c.report.overall().as_ms_f64(),
            c.latency().as_ms_f64(),
        );
    }

    println!();
    println!("{}", coord.metrics().summary());
    print!("{}", coord.worker_report());
    {
        let b = coord.batcher();
        println!(
            "executable cache: {} buckets compiled once ({} total), {} warm hits",
            b.compiles, b.compile_time, b.hits
        );
    }
    println!(
        "pool output == {reference} on every one of {} GEMMs across {} requests",
        checks.load(Ordering::Relaxed),
        completions.len()
    );
    if let Some(s) = slo {
        let m = coord.metrics();
        println!(
            "SLO ({s}): {}/{} attained ({:.1}%), {} shed by admission control",
            m.slo_attained,
            m.slo_attained + m.slo_missed,
            100.0 * m.slo_attainment(),
            m.shed_predicted,
        );
    }
    if exec_mode == ExecMode::Threaded {
        println!(
            "threaded drains: {:.1} ms wall -> {:.1} req/s real",
            coord.metrics().wall_elapsed.as_secs_f64() * 1e3,
            coord.metrics().wall_throughput_rps(),
        );
    }
    if let Some(path) = &trace_out {
        let spans = coord.spans().snapshot();
        std::fs::write(path, chrome_trace(&spans)).expect("write trace");
        println!(
            "chrome trace: {} spans -> {path} (load in https://ui.perfetto.dev)",
            spans.len()
        );
    }
    if let Some(path) = &metrics_out {
        std::fs::write(path, metrics_json(&coord.metrics().registry())).expect("write metrics");
        println!("metrics snapshot -> {path}");
    }
    println!("host wall: {:.1} s", wall.as_secs_f64());
}
