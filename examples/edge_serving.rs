//! End-to-end driver: serve a stream of inference requests through the
//! FULL three-layer stack, with the AOT-compiled PJRT artifacts doing
//! the functional GEMM math on the request path (the "real hardware"
//! numerics) while the TLM simulators provide the PYNQ-Z1 timing.
//!
//! This is the repo's end-to-end validation (DESIGN.md): it proves all
//! layers compose — Pallas kernel (L1) → jax lowering (L2) → rust
//! runtime + coordinator (L3) — by checking, for every request, that
//! the PJRT outputs are bit-identical to the simulator outputs, and
//! reports serving latency/throughput for the batch.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example edge_serving [n_requests] [model]`

use std::time::Instant;

use secda::accel::SaDesign;
use secda::driver::{AccelBackend, DriverConfig};
use secda::framework::backend::{GemmBackend, GemmTask, GemmTiming};
use secda::framework::interpreter::Session;
use secda::framework::models;
use secda::framework::tensor::Tensor;
use secda::runtime::{default_dir, ArtifactRuntime};
use secda::sysc::SimTime;

/// A GemmBackend that executes numerics through the PJRT artifacts
/// while delegating the timing model to the SA driver — cross-checking
/// the two functional paths bit for bit on every call.
struct PjrtBackend {
    rt: ArtifactRuntime,
    inner: AccelBackend<SaDesign>,
    gemm_calls: u64,
}

impl GemmBackend for PjrtBackend {
    fn name(&self) -> &str {
        "sa+pjrt"
    }

    fn run_gemm(&mut self, task: &GemmTask<'_>) -> (Vec<i8>, GemmTiming) {
        let (sim_out, timing) = self.inner.run_gemm(task);
        let pjrt_out = self
            .rt
            .qgemm(task.m, task.k, task.n, task.weights, task.inputs, task.params)
            .unwrap_or_else(|e| panic!("PJRT qgemm failed for {}: {e:#}", task.layer));
        assert_eq!(
            pjrt_out, sim_out,
            "layer {}: PJRT artifact diverged from the TLM simulator",
            task.layer
        );
        self.gemm_calls += 1;
        (pjrt_out, timing)
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|v| v.parse().ok()).unwrap_or(8);
    let model = args.get(1).map(String::as_str).unwrap_or("mobilenet_v1");

    let dir = default_dir();
    if !ArtifactRuntime::available(&dir) {
        eprintln!("artifacts missing at {dir:?}; run `make artifacts` first");
        std::process::exit(1);
    }
    let rt = ArtifactRuntime::new(&dir).expect("runtime");
    println!(
        "serving {model} with SA accelerator + PJRT functional path ({} AOT buckets)",
        rt.buckets.len()
    );

    let g = models::by_name(model).expect("model");
    let mut backend = PjrtBackend {
        rt,
        inner: AccelBackend::new(SaDesign::paper(), DriverConfig::with_threads(2)),
        gemm_calls: 0,
    };

    // request stream: deterministic pseudo-images
    let mut modeled_latencies: Vec<SimTime> = Vec::new();
    let mut host_latencies = Vec::new();
    let mut st = 0xfeedu64;
    let t_serve = Instant::now();
    for r in 0..n_requests {
        let n: usize = g.input_shape.iter().product();
        let data: Vec<i8> = (0..n)
            .map(|_| {
                st ^= st << 13;
                st ^= st >> 7;
                st ^= st << 17;
                (st & 0xff) as u8 as i8
            })
            .collect();
        let input = Tensor::new(g.input_shape.clone(), data, g.input_qp);
        let t0 = Instant::now();
        let (out, report) = Session::new(&g, &mut backend, 2).run(&input);
        host_latencies.push(t0.elapsed());
        modeled_latencies.push(report.overall());
        // classify: argmax of the head
        let top = out
            .data
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap();
        println!(
            "  req {r:>2}: class {top:>4}  modeled {:>7.1} ms on PYNQ-Z1  ({:>6.0} ms host wall)",
            report.overall().as_ms_f64(),
            host_latencies[r].as_secs_f64() * 1000.0
        );
    }
    let wall = t_serve.elapsed();

    modeled_latencies.sort();
    let pct = |p: f64| modeled_latencies[(p * (n_requests - 1) as f64) as usize];
    println!("\nserved {n_requests} requests in {:.1} s host wall", wall.as_secs_f64());
    println!(
        "modeled PYNQ-Z1 latency: p50 {:.1} ms, p99 {:.1} ms -> {:.2} inf/s on-device",
        pct(0.5).as_ms_f64(),
        pct(0.99).as_ms_f64(),
        1.0 / pct(0.5).as_secs_f64()
    );
    println!(
        "PJRT == simulator on every one of {} GEMM offloads across {} requests",
        backend.gemm_calls, n_requests
    );
    println!(
        "driver: {} offloads, {} fallbacks, {:.1} MB moved",
        backend.inner.stats.offloads,
        backend.inner.stats.cpu_fallbacks,
        (backend.inner.stats.bytes_to_accel + backend.inner.stats.bytes_from_accel) as f64 / 1e6
    );
}
