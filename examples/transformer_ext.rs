//! §VII future-work extension: Transformer-class models through the
//! SECDA stack.
//!
//! The paper closes with "as future work, we plan to ... support other
//! ... DNN classes (e.g., Transformer models)". This example shows the
//! methodology carries over unchanged: a quantized single-head
//! self-attention encoder block routes its Q/K/V/O projections through
//! the SAME gemmlowp seam the convolutions use, so the VM/SA
//! accelerators serve them with zero design changes, while the
//! dynamic-by-dynamic attention matmuls stay on the CPU (like the
//! depthwise convolutions did).
//!
//! Run: `cargo run --release --example transformer_ext`

use secda::accel::{SaDesign, VmDesign};
use secda::driver::{AccelBackend, DriverConfig};
use secda::framework::backend::CpuBackend;
use secda::framework::models::WeightGen;
use secda::framework::ops::{OpCtx, SelfAttention};
use secda::framework::quant::QParams;
use secda::framework::tensor::Tensor;
use secda::perf::CpuModel;

fn block(name: &str, seq: usize, d: usize) -> SelfAttention {
    let mut gen = WeightGen::for_layer("transformer_ext", name);
    SelfAttention {
        name: name.to_string(),
        seq,
        d,
        wq: gen.i8s(d * d),
        wk: gen.i8s(d * d),
        wv: gen.i8s(d * d),
        wo: gen.i8s(d * d),
        w_scale: 0.3 / (d as f32).sqrt() / 25.0,
        out_qp: QParams::new(0.05, -4),
    }
}

fn main() {
    let (seq, d, n_blocks) = (64, 128, 4);
    println!("transformer encoder: {n_blocks} attention blocks, seq={seq}, d={d}\n");

    let mut gen = WeightGen::for_layer("transformer_ext", "tokens");
    let input = Tensor::new(vec![1, seq, d], gen.i8s(seq * d), QParams::new(0.05, -4));
    let cpu = CpuModel::pynq_a9();

    let mut results = Vec::new();
    for backend_name in ["cpu", "vm", "sa"] {
        let mut cpu_b;
        let mut vm_b;
        let mut sa_b;
        let backend: &mut dyn secda::framework::backend::GemmBackend = match backend_name {
            "cpu" => {
                cpu_b = CpuBackend::new(1);
                &mut cpu_b
            }
            "vm" => {
                vm_b = AccelBackend::new(VmDesign::paper(), DriverConfig::with_threads(1));
                &mut vm_b
            }
            _ => {
                sa_b = AccelBackend::new(SaDesign::paper(), DriverConfig::with_threads(1));
                &mut sa_b
            }
        };
        let mut ctx = OpCtx::new(backend, &cpu, 1);
        let mut x = input.clone();
        for b in 0..n_blocks {
            x = block(&format!("blk{b}"), seq, d).eval(&x, &mut ctx);
        }
        println!(
            "{backend_name:<4} backend: projections(GEMM seam) {:>7.2} ms | attention(CPU) {:>7.2} ms | total {:>7.2} ms",
            ctx.conv_time.as_ms_f64(),
            ctx.nonconv_time.as_ms_f64(),
            (ctx.conv_time + ctx.nonconv_time).as_ms_f64()
        );
        results.push(x.data);
    }
    assert_eq!(results[0], results[1], "VM must be bit-exact");
    assert_eq!(results[0], results[2], "SA must be bit-exact");
    println!("\nall three backends produced bit-identical encodings —");
    println!("the SECDA designs serve Transformer projections with zero changes.");
}
