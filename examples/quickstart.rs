//! Quickstart: run one quantized inference through the SECDA stack.
//!
//! Walks the paper's Fig. 2 runtime flow: the TFLite-like framework
//! executes MobileNetV1; its conv layers are intercepted at the GEMM
//! seam and offloaded to the SA accelerator via the co-designed
//! driver; everything else runs on the (modeled) CPU. Prints the
//! resulting Table-II-style row and the per-layer breakdown.
//!
//! Run: `cargo run --release --example quickstart`

use secda::accel::SaDesign;
use secda::driver::{AccelBackend, DriverConfig};
use secda::framework::backend::CpuBackend;
use secda::framework::interpreter::Session;
use secda::framework::models;
use secda::framework::ops::TimeBucket;
use secda::framework::tensor::Tensor;

fn main() {
    let model = "mobilenet_v1";
    let g = models::by_name(model).expect("model");
    println!(
        "{}: {} nodes, {} conv layers, {:.1} MB of int8 weights",
        model,
        g.nodes.len(),
        g.conv_layer_count(),
        g.weight_bytes() as f64 / 1e6
    );

    // a synthetic 224x224 image
    let input = Tensor::zeros(g.input_shape.clone(), g.input_qp);

    // 1) CPU-only baseline (1 thread)
    let mut cpu = CpuBackend::new(1);
    let (out_cpu, rep_cpu) = Session::new(&g, &mut cpu, 1).run(&input);
    println!("\n{}", rep_cpu.row());

    // 2) CPU + SA accelerator (the paper's best design)
    let mut sa = AccelBackend::new(SaDesign::paper(), DriverConfig::with_threads(1));
    let (out_sa, rep_sa) = Session::new(&g, &mut sa, 1).run(&input);
    println!("{}", rep_sa.row());

    // functional equivalence: the accelerator is bit-exact
    assert_eq!(out_cpu.data, out_sa.data, "accelerator must be bit-exact");
    println!(
        "\noutputs bit-identical; speedup {:.2}x, energy {:.2}x lower",
        rep_cpu.overall().as_secs_f64() / rep_sa.overall().as_secs_f64(),
        rep_cpu.energy_j / rep_sa.energy_j
    );
    println!(
        "driver: {} offloads, {} CPU fallbacks, {:.1} MB to accel, {:.1} MB back",
        sa.stats.offloads,
        sa.stats.cpu_fallbacks,
        sa.stats.bytes_to_accel as f64 / 1e6,
        sa.stats.bytes_from_accel as f64 / 1e6
    );

    // per-layer breakdown (top 8 by time)
    println!("\nslowest layers (accelerated run):");
    let mut layers = rep_sa.layers.clone();
    layers.sort_by_key(|(_, _, t)| std::cmp::Reverse(t.as_ps()));
    for (name, bucket, t) in layers.iter().take(8) {
        println!(
            "  {:<18} {:>9.2} ms  [{}]",
            name,
            t.as_ms_f64(),
            match bucket {
                TimeBucket::Conv => "CONV",
                TimeBucket::NonConv => "non-conv",
            }
        );
    }
}
