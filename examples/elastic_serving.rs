//! Elastic serving demo: a diurnal traffic shift replayed against the
//! L3 coordinator with traffic-aware FPGA reprovisioning enabled, and
//! against two static pools for comparison.
//!
//! The stream has two phases:
//!
//! * **day — conv-heavy**: a camera model whose conv GEMM is
//!   (96, 4608, 196). K = 4608 exceeds the paper VM's local buffers
//!   (`max_k` 4096, §IV-E4), so a VM pool can only serve it at
//!   CPU-fallback speed while the SA runs it on fabric.
//! * **night — FC-heavy**: an embedding/classifier model that is all
//!   fully-connected layers. The paper accelerates only convolutions,
//!   so this traffic is *fabric-neutral*: no composition beats any
//!   other, and the rational elastic response is to hold position
//!   rather than pay a bitstream load for nothing.
//!
//! The elastic pool starts deliberately mis-provisioned on the VM
//! bitstream ("yesterday's configuration"). Watch the composition
//! timeline: after the first observed burst the planner swaps VM→SA —
//! one modeled bitstream reload — and then stays put through the phase
//! shift, hysteresis holding against the fabric-neutral night traffic.
//! This mirrors the repo's reproduction of §V-B: the SA paper design
//! is the stronger conv engine, and the VM's distinctive trait is its
//! K cliff; "VM-favoring" traffic is traffic where the VM's deficit
//! does not matter, which is exactly when a reconfiguration is not
//! worth its cost.
//!
//! The second act pits **reactive** against **predictive**
//! reprovisioning on a regime-shift stream: the reactive controller
//! only evaluates on its interval cadence, while the predictive run
//! feeds the telemetry change-point trend into the controller
//! ([`TelemetryConfig::feed_trend`]) so the shift's onset itself
//! triggers the evaluation — the swap lands at least one full eval
//! interval earlier, and the main conv wave runs on the right
//! bitstream.
//!
//! Run: `cargo run --release --example elastic_serving`
//!
//! Observability: `--trace-out trace.json` records the elastic pool's
//! run as a Chrome trace (Perfetto-loadable) — the estimator windows,
//! plan decisions and the VM→SA bitstream reload show up as events on
//! the elastic track. `--metrics-out metrics.json` writes the elastic
//! pool's flat metrics snapshot. `--series-out series.json` writes the
//! predictive run's time-series document (validated by
//! `secda trace-validate`), and `--alerts` prints every fired alert.

use std::sync::Arc;

use secda::coordinator::{Coordinator, CoordinatorConfig};
use secda::elastic::{Composition, ElasticConfig};
use secda::framework::graph::{Graph, GraphBuilder};
use secda::framework::ops::{Activation, Conv2d, FullyConnected, GlobalAvgPool, Op, SoftmaxOp};
use secda::framework::quant::QParams;
use secda::framework::tensor::Tensor;
use secda::obs::export::{chrome_trace, metrics_json, timeseries_json};
use secda::obs::TelemetryConfig;
use secda::sysc::SimTime;

fn xorshift(st: &mut u64) -> u64 {
    *st ^= *st << 13;
    *st ^= *st >> 7;
    *st ^= *st << 17;
    *st
}

/// Day traffic: one deep-K conv, (cout, kh*kw*cin, oh*ow) = (96, 4608, 196).
fn day_cam() -> Graph {
    let mut st = 0xdau64;
    let cin = 512;
    let cout = 96;
    let mut b = GraphBuilder::new("day_cam", vec![1, 14, 14, cin], QParams::new(0.05, 0));
    let conv = Conv2d {
        name: "c1".into(),
        cout,
        kh: 3,
        kw: 3,
        cin,
        stride: 1,
        pad: 1,
        weights: (0..cout * 9 * cin)
            .map(|_| (xorshift(&mut st) & 0xff) as u8 as i8)
            .collect(),
        bias: vec![5; cout],
        w_scales: vec![0.02; cout],
        out_qp: QParams::new(0.05, 0),
        act: Activation::Relu,
        weights_resident: false,
    };
    let c = b.push(Op::Conv(conv), vec![b.input()]);
    let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
    let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
    b.finish(s)
}

/// Night traffic: a 3-layer MLP head — all FC, nothing the fabric
/// accelerates.
fn night_mlp() -> Graph {
    let mut st = 0x917u64;
    let feat = 2048;
    let mut b = GraphBuilder::new("night_mlp", vec![1, feat], QParams::new(0.05, 0));
    let mut prev = b.input();
    for i in 0..3 {
        let fc = FullyConnected {
            name: format!("fc{i}"),
            in_features: feat,
            out_features: feat,
            weights: (0..feat * feat)
                .map(|_| (xorshift(&mut st) & 0xff) as u8 as i8)
                .collect(),
            bias: vec![3; feat],
            w_scale: 0.02,
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
        };
        prev = b.push(Op::Fc(fc), vec![prev]);
    }
    let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![prev]);
    b.finish(s)
}

fn image(g: &Graph, st: &mut u64) -> Tensor {
    let n: usize = g.input_shape.iter().product();
    let data = (0..n).map(|_| (xorshift(st) & 0xff) as u8 as i8).collect();
    Tensor::new(g.input_shape.clone(), data, g.input_qp)
}

struct RunResult {
    label: String,
    p50: SimTime,
    p99: SimTime,
    throughput: f64,
    swaps: usize,
    final_comp: Composition,
    /// Chrome trace / metrics JSON, exported when the run's
    /// coordinator had tracing enabled.
    trace: Option<String>,
    metrics: Option<String>,
}

/// Replay the two-phase stream: day bursts of the conv model, then
/// night bursts of the MLP. Each burst drains to idle, which is where
/// the elastic controller (if configured) evaluates.
fn serve_stream(label: &str, cfg: CoordinatorConfig, verbose: bool) -> RunResult {
    let day = Arc::new(day_cam());
    let night = Arc::new(night_mlp());
    let mut coord = Coordinator::new(cfg);
    let mut st = 0x5eedu64;
    let phases: [(&str, &Arc<Graph>, &[usize]); 2] = [
        ("day/conv", &day, &[4, 10, 10]),
        ("night/fc", &night, &[10, 10]),
    ];
    for (phase, model, bursts) in phases {
        for (bi, &burst) in bursts.iter().enumerate() {
            for _ in 0..burst {
                let input = image(model, &mut st);
                coord
                    .submit((*model).clone(), input)
                    .expect("queue sized for the stream");
                coord.advance(SimTime::ms(25));
            }
            let before = coord.composition();
            let done = coord.run_until_idle();
            let after = coord.composition();
            if verbose {
                let note = if before != after {
                    format!("  <-- reconfigured {before} -> {after}")
                } else {
                    String::new()
                };
                println!(
                    "  [{label}] {phase} burst {bi}: {:>2} served on {before}{note}",
                    done.len(),
                );
            }
        }
        coord.advance(SimTime::ms(50));
    }
    let (trace, metrics) = if coord.spans().is_enabled() {
        let trace = chrome_trace(&coord.spans().snapshot());
        let metrics = metrics_json(&coord.metrics().registry());
        (Some(trace), Some(metrics))
    } else {
        (None, None)
    };
    let m = coord.metrics();
    RunResult {
        label: label.to_string(),
        p50: m.latency_pct(0.5),
        p99: m.latency_pct(0.99),
        throughput: m.throughput_rps(),
        swaps: coord.elastic_history().len(),
        final_comp: coord.composition(),
        trace,
        metrics,
    }
}

/// One run of the regime-shift stream: fabric-neutral night FC bursts
/// establish the baseline, a trigger burst of deep-K convs shifts the
/// regime, a lull of one eval interval passes, then the main conv
/// wave lands. The reactive controller cannot evaluate at the trigger
/// drain (its interval has not elapsed since the night evaluation);
/// the predictive one can, because the telemetry change-point trend
/// arms a one-shot bypass of the rate limit.
fn serve_shift(cfg: CoordinatorConfig, eval_interval: SimTime) -> Coordinator {
    let day = Arc::new(day_cam());
    let night = Arc::new(night_mlp());
    let mut coord = Coordinator::new(cfg);
    let mut st = 0xf00du64;
    // night: five FC bursts; the first drain runs (and stamps) the
    // reactive evaluation, the rest are rate-limited
    for _ in 0..5 {
        for _ in 0..5 {
            coord
                .submit(night.clone(), image(&night, &mut st))
                .expect("queue sized");
            coord.advance(SimTime::ms(20));
        }
        coord.run_until_idle();
    }
    // the regime shifts: deep-K convs the VM only serves at
    // CPU-fallback speed
    for _ in 0..12 {
        coord
            .submit(day.clone(), image(&day, &mut st))
            .expect("queue sized");
        coord.advance(SimTime::ms(20));
    }
    coord.run_until_idle();
    // a lull long enough for the reactive interval to elapse, then
    // the main conv wave
    coord.advance(eval_interval);
    for _ in 0..12 {
        coord
            .submit(day.clone(), image(&day, &mut st))
            .expect("queue sized");
        coord.advance(SimTime::ms(20));
    }
    coord.run_until_idle();
    coord
}

/// Strip a `--flag <value>` pair from the arg vector.
fn take_flag(args: &mut Vec<String>, flag: &str) -> Option<String> {
    let i = args.iter().position(|a| a == flag)?;
    assert!(i + 1 < args.len(), "{flag} needs a path argument");
    let v = args.remove(i + 1);
    args.remove(i);
    Some(v)
}

/// Strip a bare `--flag` switch from the arg vector.
fn take_switch(args: &mut Vec<String>, flag: &str) -> bool {
    match args.iter().position(|a| a == flag) {
        Some(i) => {
            args.remove(i);
            true
        }
        None => false,
    }
}

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let trace_out = take_flag(&mut args, "--trace-out");
    let metrics_out = take_flag(&mut args, "--metrics-out");
    let series_out = take_flag(&mut args, "--series-out");
    let show_alerts = take_switch(&mut args, "--alerts");
    println!("=== elastic serving: diurnal conv->fc shift on one Zynq-7020 ===\n");

    let elastic_cfg = ElasticConfig {
        eval_interval: SimTime::ms(100),
        window: SimTime::ms(2_500),
        min_samples: 4,
        hysteresis: SimTime::ms(10),
        max_swaps: 1,
        // pure which-bitstream decision: the two A9 cores already run
        // the driver's own prep threads
        cpu_max: 0,
        ..ElasticConfig::default()
    };
    let base = CoordinatorConfig {
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };

    println!("elastic pool (starts mis-provisioned on the VM bitstream):");
    let mut elastic_pool_cfg = CoordinatorConfig {
        sa_workers: 0,
        vm_workers: 1,
        cpu_workers: 0,
        elastic: Some(elastic_cfg),
        ..base.clone()
    };
    if trace_out.is_some() || metrics_out.is_some() {
        elastic_pool_cfg = elastic_pool_cfg.with_tracing(1 << 16);
    }
    let elastic = serve_stream("elastic", elastic_pool_cfg, true);
    println!();

    let static_sa = serve_stream(
        "static 1xSA",
        CoordinatorConfig {
            sa_workers: 1,
            vm_workers: 0,
            cpu_workers: 0,
            ..base.clone()
        },
        false,
    );
    let static_vm = serve_stream(
        "static 1xVM",
        CoordinatorConfig {
            sa_workers: 0,
            vm_workers: 1,
            cpu_workers: 0,
            ..base
        },
        false,
    );

    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>7} {:>18}",
        "pool", "req/s", "p50", "p99", "swaps", "final composition"
    );
    for r in [&elastic, &static_sa, &static_vm] {
        println!(
            "{:<14} {:>10.2} {:>10} {:>10} {:>7} {:>18}",
            r.label,
            r.throughput,
            format!("{}", r.p50),
            format!("{}", r.p99),
            r.swaps,
            format!("{}", r.final_comp),
        );
    }
    println!();

    // the demonstration this example exists for: the planner swapped
    // the bitstream at least once, the swap was SA<->VM, and the
    // elastic pool beat the worst static provisioning on tail latency
    // while never exceeding the device budget (the planner only emits
    // budget-feasible compositions; pinned by proptest).
    assert!(elastic.swaps >= 1, "the planner never reconfigured the pool");
    assert_eq!(
        elastic.final_comp,
        Composition::new(1, 0, 0),
        "day traffic must end on the SA bitstream"
    );
    let worst = if static_sa.p99 > static_vm.p99 {
        &static_sa
    } else {
        &static_vm
    };
    assert!(
        elastic.p99 < worst.p99,
        "elastic p99 {} not better than static-worst ({}) p99 {}",
        elastic.p99,
        worst.label,
        worst.p99
    );
    println!(
        "elastic pool: {} swap(s), p99 {} vs static-worst ({}) p99 {} -- \
         the bitstream followed the traffic",
        elastic.swaps, elastic.p99, worst.label, worst.p99
    );
    if let Some(path) = &trace_out {
        let trace = elastic.trace.as_ref().expect("tracing was enabled");
        std::fs::write(path, trace).expect("write trace");
        println!("chrome trace -> {path} (load in https://ui.perfetto.dev)");
    }
    if let Some(path) = &metrics_out {
        let metrics = elastic.metrics.as_ref().expect("tracing was enabled");
        std::fs::write(path, metrics).expect("write metrics");
        println!("metrics snapshot -> {path}");
    }

    // --- act two: reactive vs predictive reprovisioning -------------
    println!("\n=== predictive reprovisioning: telemetry trend vs interval cadence ===\n");
    let shift_elastic = ElasticConfig {
        eval_interval: SimTime::ms(5_000),
        window: SimTime::ms(2_500),
        min_samples: 4,
        hysteresis: SimTime::ms(10),
        max_swaps: 1,
        cpu_max: 0,
        ..ElasticConfig::default()
    };
    let eval_interval = shift_elastic.eval_interval;
    let shift_base = CoordinatorConfig {
        queue_depth: 64,
        sa_workers: 0,
        vm_workers: 1,
        cpu_workers: 0,
        elastic: Some(shift_elastic),
        ..CoordinatorConfig::default()
    };
    let reactive = serve_shift(shift_base.clone(), eval_interval);
    let predictive = serve_shift(
        shift_base.with_telemetry(TelemetryConfig {
            feed_trend: true,
            ..TelemetryConfig::default()
        }),
        eval_interval,
    );
    let react_at = reactive
        .elastic_history()
        .first()
        .expect("reactive controller must swap once the interval elapses")
        .at;
    let pred_at = predictive
        .elastic_history()
        .first()
        .expect("predictive controller must swap at the regime shift")
        .at;
    let lead = react_at.saturating_sub(pred_at);
    let (p99_react, p99_pred) = (
        reactive.metrics().latency_pct(0.99),
        predictive.metrics().latency_pct(0.99),
    );
    println!(
        "reactive swap at   {react_at} (interval cadence)\n\
         predictive swap at {pred_at} (change-point trend)\n\
         lead: {lead} (eval interval {eval_interval}); p99 {p99_pred} vs {p99_react}"
    );
    assert!(
        lead >= eval_interval,
        "predictive swap must lead the reactive one by >= one eval \
         interval (lead {lead}, interval {eval_interval})"
    );
    assert!(
        p99_pred <= p99_react,
        "predictive p99 {p99_pred} must not regress reactive p99 {p99_react}"
    );
    if show_alerts {
        println!("\nfired alerts (predictive run):");
        for a in predictive.alerts() {
            println!(
                "  t={} {} on `{}`: value {:.3} vs threshold {:.3} (window {})",
                a.at,
                a.kind.name(),
                a.series,
                a.value,
                a.threshold,
                a.window
            );
        }
    }
    if let Some(path) = &series_out {
        let bank = predictive.telemetry_series().expect("predictive run has telemetry");
        let doc = timeseries_json(bank, predictive.alerts());
        std::fs::write(path, doc).expect("write series");
        println!("time-series document -> {path} (validate: secda trace-validate {path})");
    }
}
