//! Design-space exploration, end to end: sweep every feasible SA/VM
//! candidate against real model workloads on the cycle-modeled
//! simulators, memoize each `(design, GEMM shape)` result, print the
//! per-workload Pareto frontiers, then serve requests with the design
//! the campaign picked.
//!
//! This is the paper's §IV design loop run as a batch job instead of
//! by hand: the simulate-evaluate-compare iterations that SECDA makes
//! cheap are exactly what the campaign parallelizes across a
//! work-stealing thread pool, and the memo cache makes reruns free.
//!
//! Run: `cargo run --release --example dse_campaign [model] [budget]`
//! (defaults: mobilenet_v1, 6 distinct GEMM shapes per profile).

use std::sync::Arc;
use std::time::Instant;

use secda::coordinator::{Coordinator, CoordinatorConfig};
use secda::dse::{design_space, run_campaign, CampaignConfig, MemoCache, WorkloadProfile};
use secda::framework::models;
use secda::framework::tensor::Tensor;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("mobilenet_v1");
    let budget: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let profile = WorkloadProfile::from_model(model).expect("bundled model");
    let space = design_space();
    println!(
        "campaign: {} candidate designs x {} ({} GEMM shapes, budget {budget}), {threads} threads\n",
        space.len(),
        profile.name,
        profile.demand.len(),
    );

    // --- cold campaign -------------------------------------------------
    let cache = MemoCache::new();
    let cfg = CampaignConfig {
        threads,
        budget: Some(budget),
        ..CampaignConfig::default()
    };
    let profiles = [profile];
    let t0 = Instant::now();
    let report = run_campaign(&cfg, &profiles, &space, &cache);
    let cold = t0.elapsed();
    println!(
        "cold: {} (design, shape) pairs, {} fresh sims in {:.2}s",
        report.pairs,
        report.fresh_sims,
        cold.as_secs_f64()
    );

    // --- warm rerun: the memo cache answers everything ------------------
    let t0 = Instant::now();
    let warm_report = run_campaign(&cfg, &profiles, &space, &cache);
    assert_eq!(warm_report.fresh_sims, 0, "warm rerun must be sim-free");
    assert_eq!(warm_report.pareto_json(), report.pareto_json());
    println!(
        "warm: 0 fresh sims, {} cache hits in {:.3}s\n",
        warm_report.cache_hits,
        t0.elapsed().as_secs_f64()
    );

    // --- the frontier ----------------------------------------------------
    let p = &report.profiles[0];
    println!(
        "{:<8} {:>14} {:>12} {:>6} {:>6} {:>5} {:>7}",
        "design", "latency", "energy (J)", "util", "LUTs", "DSPs", "BRAM36"
    );
    for e in &p.frontier {
        println!(
            "{:<8} {:>14} {:>12.4} {:>6.2} {:>6} {:>5} {:>7}",
            e.design.key(),
            e.latency.to_string(),
            e.energy_j,
            e.utilization,
            e.resources.luts,
            e.resources.dsps,
            e.resources.bram36,
        );
    }

    // --- serve with the winner -------------------------------------------
    let sa = p.best_sa().expect("an SA design on the frontier");
    println!(
        "\nserving {model} with the campaign's SA pick ({0}x{0} array):",
        sa.array.dim
    );
    let coord_cfg = CoordinatorConfig {
        sa_design: sa,
        ..CoordinatorConfig::sa_pool(2)
    };
    let mut coord = Coordinator::new(coord_cfg);
    let g = Arc::new(models::by_name(model).expect("model"));
    let input = Tensor::zeros(g.input_shape.clone(), g.input_qp);
    for _ in 0..4 {
        coord.submit(Arc::clone(&g), input.clone()).expect("submit");
    }
    let done = coord.run_until_idle();
    let makespan = done.iter().map(|c| c.finished).max().unwrap();
    println!(
        "  {} requests served, modeled makespan {}",
        done.len(),
        makespan
    );
}
