//! The SECDA design loop (paper Fig. 1 + §IV-E), replayed end to end.
//!
//! Starts from a naive VM candidate and walks the paper's actual
//! design-improvement history, using the cheap SystemC-simulation loop
//! for most iterations and "synthesis + hardware evaluation" only at
//! the checkpoints — then totals the development time both ways
//! (Equations 1 and 2) to show the methodology's payoff.
//!
//! Run: `cargo run --release --example design_loop`

use secda::accel::components::PpuModel;
use secda::accel::{ExecMode, GemmAccel, GemmRequest, VmConfig, VmDesign};
use secda::framework::quant::quantize_multiplier;
use secda::gemm::QGemmParams;
use secda::perf::devtime::{self, DevTimeParams};
use secda::synth;
use secda::sysc::SimTime;

fn workload() -> GemmRequest {
    // an InceptionV1-like conv: 192 filters over 3x3x96, 14x14 output
    let (m, k, n) = (192, 864, 196);
    let mut st = 5u64;
    let mut rnd = || {
        st ^= st << 13;
        st ^= st >> 7;
        st ^= st << 17;
        st
    };
    let w: Vec<i8> = (0..m * k).map(|_| (rnd() & 0xff) as u8 as i8).collect();
    let x: Vec<i8> = (0..k * n).map(|_| (rnd() & 0xff) as u8 as i8).collect();
    let (mult, shift) = quantize_multiplier(0.015);
    GemmRequest::new(m, k, n, w, x, QGemmParams::uniform(m, 0, mult, shift))
}

fn main() {
    let req = workload();
    let mut n_sim = 0u64;
    let mut n_synth = 0u64;

    println!("SECDA design loop: VM accelerator, InceptionV1-like GEMM\n");

    // --- iteration 1: first candidate — unbanked buffers, no
    //     scheduler broadcast, CPU-side post-processing -------------
    let mut cfg = VmConfig::unbanked();
    cfg.scheduler_broadcast = false;
    cfg.ppu = None;
    let r1 = VmDesign::new(cfg.clone()).run(&req, ExecMode::Simulation);
    n_sim += 1;
    println!(
        "[sim {n_sim}] naive VM:            {:>9} cycles ({} global reads)",
        r1.report.total_cycles, r1.report.global_buffer_reads
    );

    // --- §IV-E1: simulation shows low BRAM bandwidth -> bank the
    //     input buffer across 8 BRAMs ------------------------------
    cfg.global_input_buf = VmConfig::paper().global_input_buf;
    let r2 = VmDesign::new(cfg.clone()).run(&req, ExecMode::Simulation);
    n_sim += 1;
    println!(
        "[sim {n_sim}] + BRAM banking:      {:>9} cycles ({:.2}x)",
        r2.report.total_cycles,
        r1.report.total_cycles as f64 / r2.report.total_cycles as f64
    );

    // --- §IV-E2: simulation shows redundant weight reads -> add the
    //     broadcasting Scheduler ------------------------------------
    cfg.scheduler_broadcast = true;
    let r3 = VmDesign::new(cfg.clone()).run(&req, ExecMode::Simulation);
    n_sim += 1;
    println!(
        "[sim {n_sim}] + scheduler:         {:>9} cycles ({} global reads, 4x fewer)",
        r3.report.total_cycles, r3.report.global_buffer_reads
    );

    // --- checkpoint: synthesize and evaluate on "hardware" ---------
    let synth_rep = synth::synthesize_vm(&cfg);
    n_synth += 1;
    println!(
        "\n[synth {n_synth}] {} LUT / {} DSP / {} BRAM36 -> fits={} ({:.0} min)",
        synth_rep.resources.luts,
        synth_rep.resources.dsps,
        synth_rep.resources.bram36,
        synth_rep.fits,
        synth_rep.synth_time.as_secs_f64() / 60.0
    );
    let single_link = VmConfig {
        axi: secda::accel::components::AxiBus::pynq_single_link(),
        ..cfg.clone()
    };
    let hw1 = VmDesign::new(single_link).run(&req, ExecMode::HardwareEval);
    println!(
        "[hw-eval] single AXI link:    {:>9} cycles — transfer bottleneck EXPOSED",
        hw1.report.total_cycles
    );
    println!(
        "          (simulation had predicted {} cycles; off-chip DMA was unmodeled)",
        r3.report.total_cycles
    );

    // --- §IV-E1: leverage all four AXI HP ports --------------------
    let r4 = VmDesign::new(cfg.clone()).run(&req, ExecMode::HardwareEval);
    n_sim += 1;
    println!(
        "[sim {n_sim}] + 4 AXI links:       {:>9} cycles ({:.2}x vs 1 link)",
        r4.report.total_cycles,
        hw1.report.total_cycles as f64 / r4.report.total_cycles as f64
    );

    // --- §IV-E2: hardware breakdown shows CPU post-processing is the
    //     new bottleneck -> move it on-fabric (the PPU) --------------
    cfg.ppu = Some(PpuModel::vm_small());
    let r5 = VmDesign::new(cfg.clone()).run(&req, ExecMode::HardwareEval);
    n_sim += 1;
    n_synth += 1;
    println!(
        "[sim {n_sim}] + PPU:               {:>9} cycles, output bytes {} -> {} (4x less)",
        r5.report.total_cycles, r4.report.bytes_out, r5.report.bytes_out
    );

    // --- final design == the paper's VM ----------------------------
    let paper = VmDesign::paper().run(&req, ExecMode::HardwareEval);
    assert_eq!(paper.output, r5.output, "every iteration stayed bit-exact");
    println!(
        "\nfinal VM == paper config: {} cycles, compute util {:.0}%",
        paper.report.total_cycles,
        paper.report.compute_utilization() * 100.0
    );

    // --- development-time accounting (Eq. 1 vs Eq. 2) --------------
    let params = DevTimeParams::measured(
        SimTime::ms(96_000),                 // sim build (C_t)
        SimTime::ms(45_000),                 // e2e sim (IS_t)
        synth_rep.synth_time,                // modeled S_t
    );
    let secda_t = devtime::eq1_secda(&params, n_sim, n_synth);
    let synth_only = devtime::eq2_synth_only(&params, n_sim, n_synth);
    println!(
        "\ndev time for this loop ({} sims, {} synths):",
        n_sim, n_synth
    );
    println!("  SECDA (Eq.1):      {:>7.1} min", secda_t.as_secs_f64() / 60.0);
    println!("  synth-only (Eq.2): {:>7.1} min", synth_only.as_secs_f64() / 60.0);
    println!(
        "  -> {:.1}x less time waiting on evaluations",
        synth_only.as_secs_f64() / secda_t.as_secs_f64()
    );
}
