//! End-to-end forced-scalar run, in its own test binary so the
//! `SECDA_FORCE_SCALAR` environment variable is set before this
//! process first dispatches a kernel (the variable is sampled once, at
//! first use). CI additionally exports the variable around the whole
//! test suite; this binary makes the env-var path self-contained so a
//! plain `cargo test` covers it too.

use std::sync::Arc;

use secda::coordinator::{Coordinator, CoordinatorConfig};
use secda::framework::backend::CpuBackend;
use secda::framework::graph::{Graph, GraphBuilder};
use secda::framework::interpreter::Session;
use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
use secda::framework::quant::QParams;
use secda::framework::tensor::Tensor;
use secda::gemm::simd::{self, KernelTier};

fn rnd(st: &mut u64) -> u64 {
    *st ^= *st << 13;
    *st ^= *st >> 7;
    *st ^= *st << 17;
    *st
}

fn convnet(name: &str, cout: usize, seed: u64) -> Graph {
    let mut st = seed.max(1);
    let cin = 3;
    let mut b = GraphBuilder::new(name, vec![1, 16, 16, cin], QParams::new(0.05, 0));
    let conv = Conv2d {
        name: format!("{name}.c1"),
        cout,
        kh: 3,
        kw: 3,
        cin,
        stride: 1,
        pad: 1,
        weights: (0..cout * 9 * cin)
            .map(|_| (rnd(&mut st) & 0xff) as u8 as i8)
            .collect(),
        bias: vec![7; cout],
        w_scales: vec![0.02; cout],
        out_qp: QParams::new(0.05, 0),
        act: Activation::Relu,
        weights_resident: false,
    };
    let c = b.push(Op::Conv(conv), vec![b.input()]);
    let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
    let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
    b.finish(s)
}

fn image(g: &Graph, seed: u64) -> Tensor {
    let mut st = seed.max(1);
    let n: usize = g.input_shape.iter().product();
    let data = (0..n).map(|_| (rnd(&mut st) & 0xff) as u8 as i8).collect();
    Tensor::new(g.input_shape.clone(), data, g.input_qp)
}

#[test]
fn env_var_forces_the_scalar_tier_end_to_end() {
    // set before any kernel dispatch happens in this process
    std::env::set_var("SECDA_FORCE_SCALAR", "1");
    assert_eq!(simd::tier(), KernelTier::Scalar);

    // a small serving round under the forced tier stays bit-exact to
    // the independent single-threaded CPU reference
    let g = Arc::new(convnet("net", 16, 3));
    let mut coord = Coordinator::new(CoordinatorConfig::default());
    let mut inputs = Vec::new();
    for i in 0..3u64 {
        let input = image(&g, 100 + i);
        let id = coord.submit(g.clone(), input.clone()).unwrap();
        inputs.push((id, input));
    }
    let done = coord.run_until_idle();
    assert_eq!(done.len(), 3);
    for (id, input) in inputs {
        let c = done.iter().find(|c| c.id == id).expect("completed");
        let mut cb = CpuBackend::new(1);
        let reference = Session::new(&g, &mut cb, 1).run(&input).0;
        assert_eq!(c.output.data, reference.data, "request {id} diverged");
    }

    // the runtime toggle overrides the environment in both directions
    simd::set_force_scalar(false);
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    assert_ne!(simd::tier(), KernelTier::Scalar);
    simd::set_force_scalar(true);
    assert_eq!(simd::tier(), KernelTier::Scalar);
}
