//! Golden tests for the observability layer: serve a small stream
//! with tracing on, export the Chrome trace and the metrics snapshot,
//! and validate both with the same checkers `secda trace-validate`
//! uses — events must parse, carry their mandatory fields, sort by
//! timestamp, and nest correctly (GEMMs inside requests inside
//! batches).

use std::sync::Arc;

use secda::coordinator::{Completion, Coordinator, CoordinatorConfig, ExecMode};
use secda::elastic::ElasticConfig;
use secda::framework::graph::{Graph, GraphBuilder};
use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
use secda::framework::quant::QParams;
use secda::framework::tensor::Tensor;
use secda::obs::export::{
    chrome_trace, metrics_json, validate_chrome_trace, validate_metrics_json,
};
use secda::obs::{Span, Stage};
use secda::sysc::trace::TraceEntry;
use secda::sysc::{SimTime, Trace};

fn convnet(name: &str) -> Graph {
    let mut st = 0xab5u64;
    let mut rnd = move || {
        st ^= st << 13;
        st ^= st >> 7;
        st ^= st << 17;
        st
    };
    let (cin, cout) = (3usize, 16usize);
    let mut b = GraphBuilder::new(name, vec![1, 10, 10, cin], QParams::new(0.05, 0));
    let conv = Conv2d {
        name: format!("{name}.c1"),
        cout,
        kh: 3,
        kw: 3,
        cin,
        stride: 1,
        pad: 1,
        weights: (0..cout * 9 * cin).map(|_| (rnd() & 0xff) as u8 as i8).collect(),
        bias: vec![7; cout],
        w_scales: vec![0.02; cout],
        out_qp: QParams::new(0.05, 0),
        act: Activation::Relu,
        weights_resident: false,
    };
    let c = b.push(Op::Conv(conv), vec![b.input()]);
    let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
    let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
    b.finish(s)
}

/// Serve a deterministic little stream with tracing on and return the
/// coordinator (for its spans and metrics) plus the completions.
fn traced_serve(mut cfg: CoordinatorConfig) -> (Coordinator, Vec<Completion>) {
    cfg.queue_depth = 64;
    cfg = cfg.with_tracing(1 << 14);
    let g = Arc::new(convnet("golden_net"));
    let mut coord = Coordinator::new(cfg);
    let mut seed = 0x901du64;
    let mut rnd = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        seed
    };
    for _ in 0..6 {
        let n: usize = g.input_shape.iter().product();
        let data: Vec<i8> = (0..n).map(|_| (rnd() & 0xff) as u8 as i8).collect();
        let input = Tensor::new(g.input_shape.clone(), data, g.input_qp);
        coord
            .submit_with_slo(g.clone(), input, SimTime::ms(5_000))
            .expect("queue sized");
        coord.advance(SimTime::us(300 + rnd() % 2000));
    }
    let done = coord.run_until_idle();
    (coord, done)
}

/// The full lifecycle is present and the exported trace survives the
/// validator: parseable, mandatory fields, sorted timestamps, paired
/// async arrows and flows.
#[test]
fn golden_chrome_trace_validates() {
    let (coord, done) = traced_serve(CoordinatorConfig::default());
    assert_eq!(done.len(), 6);
    let spans = coord.spans().snapshot();
    assert!(!spans.is_empty());
    let json = chrome_trace(&spans);
    let check = validate_chrome_trace(&json).expect("exported trace must validate");
    assert!(check.slices > 0, "no complete slices exported");
    assert!(check.instants > 0, "no instant events exported");
    assert!(check.tracks >= 2, "expected coordinator + worker tracks");
    assert_eq!(check.flows, 6, "one submit->execution arrow per request");
}

/// Spans nest: every GEMM span sits inside its request's span, every
/// request span inside some batch span on the same worker, and every
/// bridged simulator instant inside its GEMM.
#[test]
fn golden_spans_nest() {
    let (coord, _) = traced_serve(CoordinatorConfig::default());
    let spans = coord.spans().snapshot();
    let by_stage = |stage: Stage| -> Vec<&Span> {
        spans.iter().filter(|s| s.stage == stage).collect()
    };
    let requests = by_stage(Stage::Request);
    let batches = by_stage(Stage::Batch);
    let gemms = by_stage(Stage::Gemm);
    let sim_events = by_stage(Stage::SimEvent);
    assert_eq!(requests.len(), 6);
    assert!(!batches.is_empty());
    assert!(!gemms.is_empty(), "the conv layer must produce a GEMM span");
    assert!(
        !sim_events.is_empty(),
        "accelerator runs must bridge simulator trace entries"
    );
    for g in &gemms {
        let id = g.request_id.expect("gemm spans carry their request");
        let r = requests
            .iter()
            .find(|r| r.request_id == Some(id))
            .expect("request span exists");
        assert!(
            g.t_start >= r.t_start && g.t_end <= r.t_end,
            "gemm [{}, {}] outside request [{}, {}]",
            g.t_start,
            g.t_end,
            r.t_start,
            r.t_end
        );
    }
    for r in &requests {
        let w = r.worker.expect("request spans carry their worker");
        assert!(
            batches.iter().any(|b| b.worker == Some(w)
                && b.t_start <= r.t_start
                && r.t_end <= b.t_end),
            "request {:?} not inside any batch on worker {w}",
            r.request_id
        );
    }
    for e in &sim_events {
        let id = e.request_id.expect("sim events carry their request");
        assert!(
            gemms.iter().any(|g| g.request_id == Some(id)
                && g.t_start <= e.t_start
                && e.t_start <= g.t_end),
            "sim event at {} outside every gemm of request {id}",
            e.t_start
        );
    }
    // queue-wait ends where execution starts
    for q in by_stage(Stage::QueueWait) {
        let id = q.request_id.expect("queue-wait spans carry their request");
        let r = requests.iter().find(|r| r.request_id == Some(id)).unwrap();
        assert_eq!(q.t_end, r.t_start, "queue wait must end at execution start");
    }
}

/// An elastic coordinator records estimator-window spans at drain
/// boundaries even when the planner holds position.
#[test]
fn golden_elastic_estimator_spans() {
    let cfg = CoordinatorConfig {
        elastic: Some(ElasticConfig {
            eval_interval: SimTime::ZERO,
            min_samples: 1,
            max_swaps: 0, // observe, never swap
            cpu_max: 0,
            ..ElasticConfig::default()
        }),
        ..CoordinatorConfig::default()
    };
    let (coord, _) = traced_serve(cfg);
    let spans = coord.spans().snapshot();
    let windows: Vec<&Span> = spans
        .iter()
        .filter(|s| s.stage == Stage::EstimatorWindow)
        .collect();
    assert!(
        !windows.is_empty(),
        "elastic evaluation must record an estimator-window span"
    );
    for w in windows {
        assert!(w.attrs.iter().any(|(k, _)| *k == "requests"));
        assert!(w.attrs.iter().any(|(k, _)| *k == "rate_rps"));
    }
    // and the whole trace still validates
    validate_chrome_trace(&chrome_trace(&spans)).expect("elastic trace must validate");
}

/// Threaded mode records the same modeled spans, doubled with host
/// wall-clock stamps on batch spans, and the export still validates.
#[test]
fn golden_threaded_trace_validates() {
    let cfg = CoordinatorConfig {
        exec_mode: ExecMode::Threaded,
        ..CoordinatorConfig::default()
    };
    let (coord, done) = traced_serve(cfg);
    assert_eq!(done.len(), 6);
    let spans = coord.spans().snapshot();
    let batches: Vec<&Span> = spans.iter().filter(|s| s.stage == Stage::Batch).collect();
    assert!(!batches.is_empty());
    for b in &batches {
        let (w0, w1) = b.wall_ns.expect("threaded batches carry wall-clock stamps");
        assert!(w1 >= w0, "wall clock must not run backwards");
    }
    validate_chrome_trace(&chrome_trace(&spans)).expect("threaded trace must validate");
}

/// The metrics snapshot round-trips through its validator and carries
/// the serving histograms.
#[test]
fn golden_metrics_snapshot_validates() {
    let (coord, _) = traced_serve(CoordinatorConfig::default());
    let json = metrics_json(&coord.metrics().registry());
    let n = validate_metrics_json(&json).expect("metrics snapshot must validate");
    assert!(n > 0, "snapshot exported no metrics");
    assert!(json.contains("latency_ps"), "latency histogram missing");
}

/// Golden burn-rate run: a phase of SLO-attaining traffic followed by
/// an all-miss regime. The multi-window burn-rate rule must fire while
/// the *cumulative* SLO attainment still sits above the objective —
/// the early warning the rule exists for — the alert must land in the
/// span stream, and every telemetry export must validate.
#[test]
fn golden_burn_rate_fires_before_attainment_drops() {
    use secda::obs::export::{timeseries_json, validate_timeseries_json};
    use secda::obs::timeseries::names;
    use secda::obs::{AlertKind, TelemetryConfig};

    let objective = 0.7;
    let tel = TelemetryConfig {
        slo_objective: objective,
        burn_fast: SimTime::ms(50),
        burn_slow: SimTime::ms(200),
        burn_factor: 2.0,
        ..TelemetryConfig::default()
    };
    let cfg = CoordinatorConfig {
        queue_depth: 64,
        ..CoordinatorConfig::default()
    }
    .with_tracing(1 << 14)
    .with_telemetry(tel);
    let g = Arc::new(convnet("alert_net"));
    let mut coord = Coordinator::new(cfg);
    let n: usize = g.input_shape.iter().product();
    let input = Tensor::new(g.input_shape.clone(), vec![3i8; n], g.input_qp);
    // phase 1: 50 requests with a generous SLO, all attained
    for _ in 0..25 {
        for _ in 0..2 {
            coord
                .submit_with_slo(g.clone(), input.clone(), SimTime::ms(5_000))
                .expect("queue sized");
        }
        coord.advance(SimTime::ms(20));
        coord.run_until_idle();
    }
    assert_eq!(coord.metrics().slo_attained, 50, "phase 1 must attain");
    // phase 2: the regime shifts — every request misses its (already
    // elapsed) deadline
    for _ in 0..15 {
        for _ in 0..2 {
            coord
                .submit_with_slo(g.clone(), input.clone(), SimTime::ns(1))
                .expect("fifo never sheds");
        }
        coord.advance(SimTime::ms(20));
        coord.run_until_idle();
    }
    let burn = coord
        .alerts()
        .iter()
        .find(|a| a.kind == AlertKind::BurnRate)
        .cloned()
        .expect("burn-rate alert must fire");
    // the firing instant precedes the cumulative attainment gauge
    // first dipping under the objective
    let bank = coord.telemetry_series().expect("telemetry configured");
    let attainment = bank.get(names::SLO_ATTAINMENT).expect("gauge sampled");
    let t_drop = attainment
        .points()
        .find(|(_, v)| *v < objective)
        .map(|(t, _)| t)
        .expect("the all-miss regime must eventually sink the average");
    assert!(
        burn.at < t_drop,
        "burn rate fired at {} but attainment only dropped at {t_drop}",
        burn.at
    );
    // the alert is in the span stream, and the merged trace (counter
    // tracks included) still validates
    let spans = coord.spans().snapshot();
    assert!(
        spans.iter().any(|s| s.stage == Stage::Alert),
        "alert span missing from the stream"
    );
    let check = validate_chrome_trace(&coord.chrome_trace()).expect("merged trace validates");
    assert!(check.counters > 0, "counter tracks missing");
    // and the time-series document round-trips its validator
    let doc = timeseries_json(bank, coord.alerts());
    let (series, alerts) = validate_timeseries_json(&doc).expect("timeseries validates");
    assert!(series >= 12, "canonical series missing (got {series})");
    assert!(alerts >= 1, "fired alerts missing from the export");
}

/// Golden profile run: folding the span stream of a known graph yields
/// a well-formed collapsed-stack profile — every line parses, stacks
/// are rooted at a worker frame, and the graph's layers appear as
/// gemm/op frames under their request.
#[test]
fn golden_collapsed_stack_profile() {
    use secda::obs::AttributionProfile;

    let (coord, _) = traced_serve(CoordinatorConfig::default());
    let spans = coord.spans().snapshot();
    let prof = AttributionProfile::from_spans(&spans);
    assert!(!prof.is_empty(), "profile folded nothing");
    assert!(prof.total_ns() > 0);
    for line in prof.collapsed().lines() {
        let (path, ns) = line.rsplit_once(' ').expect("`path self_ns` line shape");
        assert!(
            path.starts_with("worker:"),
            "stack not rooted at a worker frame: {path}"
        );
        ns.parse::<u64>().expect("integer self-time ns");
    }
    let has = |needle: &str| prof.iter().any(|(k, _)| k.contains(needle));
    assert!(has("batch:golden_net"), "batch frame missing");
    assert!(has("request:golden_net"), "request frame missing");
    assert!(has("gemm:golden_net.c1"), "conv GEMM frame missing");
    assert!(has("op:gap"), "pooling op frame missing");
}

/// The simulator-level `Trace::to_chrome_json` reuses the same
/// exporter shape and passes the same validator.
#[test]
fn golden_sim_trace_chrome_json_validates() {
    let mut t = Trace::enabled(16);
    t.entries.push(TraceEntry {
        time: SimTime::ns(10),
        module: "dma".into(),
        label: "burst start".into(),
    });
    t.entries.push(TraceEntry {
        time: SimTime::ns(25),
        module: "sa16".into(),
        label: "tile 0".into(),
    });
    let check = validate_chrome_trace(&t.to_chrome_json()).expect("sim trace must validate");
    assert_eq!(check.instants, 2);
}
