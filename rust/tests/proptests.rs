//! Property-based tests (hand-rolled generator; the offline vendor set
//! has no proptest). Each property runs over many randomized cases with
//! a deterministic xorshift seed, printing the failing seed on panic.
//!
//! Focus: coordinator invariants — simulator/CPU functional agreement
//! over arbitrary shapes, FIFO/batching conservation laws, tiling
//! partitions, and sysc event-ordering determinism.

use secda::accel::{ExecMode, GemmAccel, GemmRequest, SaDesign, VmDesign};
use secda::driver::tiling;
use secda::framework::quant::{self, quantize_multiplier};
use secda::gemm::{self, QGemmParams};
use secda::sysc::{Fifo, SimTime};

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo + 1)
    }
    fn i8s(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| (self.next() & 0xff) as u8 as i8).collect()
    }
}

fn random_request(rng: &mut Rng) -> GemmRequest {
    let m = rng.range(1, 48);
    let k = rng.range(1, 64);
    let n = rng.range(1, 48);
    let w = rng.i8s(m * k);
    let x = rng.i8s(k * n);
    let (mult, shift) = quantize_multiplier(0.001 + (rng.next() % 1000) as f64 / 1500.0);
    let mut p = QGemmParams::uniform(m, 0, mult, shift);
    for i in 0..m {
        p.bias[i] = (rng.next() % 4000) as i32 - 2000;
    }
    p.out_zp = (rng.next() % 21) as i32 - 10;
    GemmRequest::new(m, k, n, w, x, p)
}

/// Property: for ANY shape and data, both accelerator simulators
/// produce bit-identical results to the CPU gemm (TLM bit-accuracy).
#[test]
fn prop_simulators_match_cpu_gemm() {
    for seed in 1..=60u64 {
        let mut rng = Rng::new(seed * 0x9e3779b9);
        let req = random_request(&mut rng);
        let cpu = gemm::qgemm(
            &req.weights, &req.inputs, req.m, req.k, req.n, &req.params, 1,
        );
        let mode = if seed % 2 == 0 {
            ExecMode::Simulation
        } else {
            ExecMode::HardwareEval
        };
        let sa = SaDesign::paper().run(&req, mode);
        assert_eq!(sa.output, cpu, "SA seed {seed} shape ({},{},{})", req.m, req.k, req.n);
        let vm = VmDesign::paper().run(&req, mode);
        assert_eq!(vm.output, cpu, "VM seed {seed} shape ({},{},{})", req.m, req.k, req.n);
    }
}

/// Property: the arch-dispatched SIMD GEMM and PPU kernels are
/// bit-identical to the scalar reference for ANY shape, per-channel
/// requant parameters, zero points and clamps, and both packed
/// layouts (full rows and column-window blocks) — the core contract
/// of [`secda::gemm::simd`].
#[test]
fn prop_simd_matches_scalar() {
    for seed in 1..=50u64 {
        let mut rng = Rng::new(seed * 0x51d7);
        let m = rng.range(1, 40);
        let k = rng.range(1, 80);
        let n = rng.range(1, 64);
        let w = rng.i8s(m * k);
        let x = rng.i8s(k * n);
        let mut p = QGemmParams::uniform(m, 0, 0, 0);
        p.out_zp = (rng.next() % 21) as i32 - 10;
        p.act_min = -128 + (rng.next() % 8) as i32;
        p.act_max = 127 - (rng.next() % 8) as i32;
        for i in 0..m {
            let real = 0.0005 + (rng.next() % 2000) as f64 / 1200.0;
            let (mult, shift) = quantize_multiplier(real);
            p.mult[i] = mult;
            p.shift[i] = shift;
            p.bias[i] = (rng.next() % 65536) as i32 - 32768;
        }

        // accumulate + PPU through the dispatched wrappers...
        let mut acc = vec![0i32; m * n];
        gemm::accumulate_rows(&w, &x, 0, m, k, n, &mut acc);
        let mut out = vec![0i8; m * n];
        gemm::ppu_rows(&acc, &p, 0, m, n, &mut out);
        // ...pinned to the scalar reference
        let mut acc_s = vec![0i32; m * n];
        gemm::accumulate_rows_scalar(&w, &x, 0, m, k, n, &mut acc_s);
        assert_eq!(acc, acc_s, "seed {seed}: accumulators diverged ({m},{k},{n})");
        let mut out_s = vec![0i8; m * n];
        gemm::ppu_rows_scalar(&acc_s, &p, 0, m, n, &mut out_s);
        assert_eq!(out, out_s, "seed {seed}: outputs diverged ({m},{k},{n})");

        // the threaded qgemm entry point agrees as well
        let threads = 1 + (rng.next() % 2) as usize;
        assert_eq!(
            gemm::qgemm(&w, &x, m, k, n, &p, threads),
            out_s,
            "seed {seed}: qgemm diverged ({m},{k},{n}) x{threads}"
        );

        // column-window (block-packed) layout
        let n0 = rng.range(0, n - 1);
        let n1 = rng.range(n0 + 1, n);
        let bn = n1 - n0;
        let mut bacc = vec![0i32; m * bn];
        gemm::accumulate_block(&w, &x, 0, m, k, n, n0, n1, &mut bacc);
        let mut bacc_s = vec![0i32; m * bn];
        gemm::accumulate_block_scalar(&w, &x, 0, m, k, n, n0, n1, &mut bacc_s);
        assert_eq!(
            bacc, bacc_s,
            "seed {seed}: block diverged ({m},{k},{n}) cols [{n0},{n1})"
        );
    }
}

/// Property: simulated time and cycle reports are deterministic —
/// running the same request twice gives identical reports.
#[test]
fn prop_simulation_deterministic() {
    for seed in 1..=20u64 {
        let mut rng = Rng::new(seed * 31);
        let req = random_request(&mut rng);
        let a = SaDesign::paper().run(&req, ExecMode::HardwareEval).report;
        let b = SaDesign::paper().run(&req, ExecMode::HardwareEval).report;
        assert_eq!(a.total_cycles, b.total_cycles, "seed {seed}");
        assert_eq!(a.compute_cycles, b.compute_cycles, "seed {seed}");
        assert_eq!(a.bytes_in, b.bytes_in, "seed {seed}");
    }
}

/// Property: accelerator byte accounting is conserved — output bytes
/// equal exactly m*n (int8 PPU path) regardless of tiling/shape.
#[test]
fn prop_output_byte_conservation() {
    for seed in 1..=30u64 {
        let mut rng = Rng::new(seed * 77);
        let req = random_request(&mut rng);
        let res = SaDesign::paper().run(&req, ExecMode::HardwareEval);
        assert_eq!(
            res.report.bytes_out,
            (req.m * req.n) as u64,
            "seed {seed}"
        );
        assert_eq!(res.output.len(), req.m * req.n);
    }
}

/// Property: FIFO conservation — len == pushes - pops, never exceeds
/// capacity, FIFO order preserved.
#[test]
fn prop_fifo_conservation() {
    for seed in 1..=40u64 {
        let mut rng = Rng::new(seed * 131);
        let cap = rng.range(1, 16);
        let mut f: Fifo<u64> = Fifo::new(cap);
        let mut model: std::collections::VecDeque<u64> = Default::default();
        for step in 0..200 {
            if rng.next() % 2 == 0 {
                let v = rng.next();
                let ok = f.push(v, SimTime::ns(step));
                assert_eq!(ok, model.len() < cap, "push acceptance");
                if ok {
                    model.push_back(v);
                }
            } else {
                let got = f.pop(SimTime::ns(step));
                assert_eq!(got, model.pop_front(), "fifo order");
            }
            assert_eq!(f.len(), model.len());
            assert!(f.len() <= cap);
            assert_eq!(
                f.stats().pushes - f.stats().pops,
                model.len() as u64,
                "conservation"
            );
        }
    }
}

/// Property: tiling chunks partition [0, m) exactly, without overlap,
/// and every chunk's weights fit the buffer (except the 16-row floor).
#[test]
fn prop_tiling_partitions() {
    for seed in 1..=50u64 {
        let mut rng = Rng::new(seed * 523);
        let m = rng.range(1, 2048);
        let k = rng.range(1, 8192);
        let buf = rng.range(1024, 512 * 1024);
        let chunks = tiling::plan_chunks(m, k, buf);
        assert_eq!(chunks[0].m0, 0, "seed {seed}");
        assert_eq!(chunks.last().unwrap().m1, m, "seed {seed}");
        for w in chunks.windows(2) {
            assert_eq!(w[0].m1, w[1].m0, "contiguous, seed {seed}");
            assert!(w[0].m1 > w[0].m0, "non-empty, seed {seed}");
        }
        if chunks.len() > 1 {
            for c in &chunks {
                let rows = c.m1 - c.m0;
                assert!(rows * k <= buf.max(16 * k), "cap, seed {seed}");
            }
        }
    }
}

/// Property: requantization stays within i8 after the PPU clamp for
/// any accumulator/multiplier/shift, and is monotone in acc for fixed
/// positive multiplier.
#[test]
fn prop_requant_bounded_and_monotone() {
    for seed in 1..=50u64 {
        let mut rng = Rng::new(seed * 7);
        let mult = (1 << 30) + (rng.next() % (1 << 30)) as i32;
        let shift = -((rng.next() % 20) as i32);
        let mut prev = i32::MIN;
        for step in 0..60 {
            let acc = -30_000_000 + step * 1_000_000;
            let v = quant::ppu_requant(acc, mult, shift, 0, -128, 127);
            assert!((-128..=127).contains(&(v as i32)));
            let raw = quant::multiply_by_quantized_multiplier(acc, mult, shift);
            assert!(raw >= prev, "monotonicity, seed {seed}");
            prev = raw;
        }
    }
}

/// Property: the quantize->requantize roundtrip approximates the real
/// multiplication within 1 output step for moderate accumulators.
#[test]
fn prop_requant_approximates_real() {
    for seed in 1..=50u64 {
        let mut rng = Rng::new(seed * 911);
        let real = 0.0001 + (rng.next() % 10_000) as f64 / 10_500.0;
        let (mult, shift) = quantize_multiplier(real);
        for _ in 0..20 {
            let acc = (rng.next() % (1 << 24)) as i32 - (1 << 23);
            let got = quant::multiply_by_quantized_multiplier(acc, mult, shift) as f64;
            let want = acc as f64 * real;
            assert!(
                (got - want).abs() <= 1.0 + want.abs() * 1e-6,
                "seed {seed}: acc {acc} real {real} got {got} want {want}"
            );
        }
    }
}

/// Property: zero-padding K or M never changes the valid output region
/// (the AOT bucket-padding contract).
#[test]
fn prop_padding_inert() {
    for seed in 1..=25u64 {
        let mut rng = Rng::new(seed * 1337);
        let req = random_request(&mut rng);
        let base = gemm::qgemm(
            &req.weights, &req.inputs, req.m, req.k, req.n, &req.params, 1,
        );
        // pad K by up to 16 with zero weights / garbage inputs
        let pad = rng.range(1, 16);
        let kp = req.k + pad;
        let mut wp = vec![0i8; req.m * kp];
        for i in 0..req.m {
            wp[i * kp..i * kp + req.k]
                .copy_from_slice(&req.weights[i * req.k..(i + 1) * req.k]);
        }
        let mut xp = rng.i8s(kp * req.n);
        for r in 0..req.k {
            let row = &req.inputs[r * req.n..(r + 1) * req.n];
            xp[r * req.n..(r + 1) * req.n].copy_from_slice(row);
        }
        let padded = gemm::qgemm(&wp, &xp, req.m, kp, req.n, &req.params, 1);
        assert_eq!(padded, base, "seed {seed}");
    }
}

/// Property: the coordinator pool (heterogeneous workers, batching,
/// work stealing, per-layer partitioning) is functionally invisible —
/// for ANY request stream its outputs are bit-identical to the
/// single-driver path (one `AccelBackend<SaDesign>` session per
/// request), which is itself bit-identical to the CPU path.
#[test]
fn prop_coordinator_matches_single_driver_path() {
    use std::sync::Arc;

    use secda::accel::SaDesign;
    use secda::coordinator::{Coordinator, CoordinatorConfig};
    use secda::driver::{AccelBackend, DriverConfig};
    use secda::framework::graph::{Graph, GraphBuilder};
    use secda::framework::interpreter::Session;
    use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
    use secda::framework::quant::QParams;
    use secda::framework::tensor::Tensor;

    fn random_convnet(rng: &mut Rng, name: &str) -> Graph {
        let cin = rng.range(1, 6);
        let cout = rng.range(4, 24);
        let hw = rng.range(6, 14);
        let (kh, pad) = if rng.next() % 2 == 0 { (3, 1) } else { (1, 0) };
        let mut b = GraphBuilder::new(name, vec![1, hw, hw, cin], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: format!("{name}.c1"),
            cout,
            kh,
            kw: kh,
            cin,
            stride: 1,
            pad,
            weights: rng.i8s(cout * kh * kh * cin),
            bias: (0..cout).map(|_| (rng.next() % 200) as i32 - 100).collect(),
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    for seed in 1..=8u64 {
        let mut rng = Rng::new(seed * 0x51ed);
        let nets = [
            Arc::new(random_convnet(&mut rng, "net_a")),
            Arc::new(random_convnet(&mut rng, "net_b")),
        ];
        let cfg = CoordinatorConfig {
            queue_depth: 64,
            ..CoordinatorConfig::default() // 2 SA + 1 VM + 1 CPU
        };
        let mut coord = Coordinator::new(cfg);
        let mut inputs = Vec::new();
        for i in 0..5usize {
            let g = &nets[i % 2];
            let n: usize = g.input_shape.iter().product();
            let input = Tensor::new(g.input_shape.clone(), rng.i8s(n), g.input_qp);
            let id = coord.submit(g.clone(), input.clone()).expect("queue sized");
            inputs.push((id, g.clone(), input));
            coord.advance(secda::sysc::SimTime::us(rng.range(50, 5000) as u64));
        }
        let done = coord.run_until_idle();
        assert_eq!(done.len(), 5, "seed {seed}");
        for (id, g, input) in inputs {
            let c = done.iter().find(|c| c.id == id).expect("completed");
            let mut single = AccelBackend::new(SaDesign::paper(), DriverConfig::default());
            let (reference, _) = Session::new(&g, &mut single, 1).run(&input);
            assert_eq!(
                c.output.data, reference.data,
                "seed {seed} request {id}: coordinator diverged from single driver"
            );
        }
    }
}

/// Property: scheduling policy is functionally invisible — for ANY
/// request stream, all three shipped policies (FIFO, deadline-EDF,
/// EDF + admission control) produce bit-identical outputs in BOTH
/// exec modes (modeled discrete-event and OS threads), and the
/// modeled-mode EDF service order is deterministic across reruns.
#[test]
fn prop_policies_agree_across_exec_modes() {
    use std::sync::Arc;

    use secda::coordinator::{
        AdmissionPolicy, Coordinator, CoordinatorConfig, DeadlinePolicy, ExecMode, FifoPolicy,
        SchedulePolicy,
    };
    use secda::framework::graph::{Graph, GraphBuilder};
    use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
    use secda::framework::quant::QParams;
    use secda::framework::tensor::Tensor;
    use secda::sysc::SimTime;

    fn random_convnet(rng: &mut Rng, name: &str) -> Graph {
        let cin = rng.range(1, 4);
        let cout = rng.range(8, 24);
        let hw = rng.range(8, 14);
        let mut b = GraphBuilder::new(name, vec![1, hw, hw, cin], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: format!("{name}.c1"),
            cout,
            kh: 3,
            kw: 3,
            cin,
            stride: 1,
            pad: 1,
            weights: rng.i8s(cout * 9 * cin),
            bias: (0..cout).map(|_| (rng.next() % 200) as i32 - 100).collect(),
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    // one deterministic stream: (model index, input, slo)
    struct Stream {
        nets: [Arc<Graph>; 2],
        items: Vec<(usize, Tensor, Option<SimTime>)>,
        gaps: Vec<u64>,
    }

    fn build_stream(seed: u64) -> Stream {
        let mut rng = Rng::new(seed * 0xed5);
        let nets = [
            Arc::new(random_convnet(&mut rng, "net_a")),
            Arc::new(random_convnet(&mut rng, "net_b")),
        ];
        let mut items = Vec::new();
        let mut gaps = Vec::new();
        for i in 0..6usize {
            let which = (rng.next() % 2) as usize;
            let g = &nets[which];
            let n: usize = g.input_shape.iter().product();
            let input = Tensor::new(g.input_shape.clone(), rng.i8s(n), g.input_qp);
            // generous SLOs (seconds of modeled time): EDF gets real
            // deadline diversity, admission control sheds nothing, so
            // the accepted set is identical across policies
            let slo = if i % 3 == 2 {
                None
            } else {
                Some(SimTime::ms(2_000 + (rng.next() % 8) * 500))
            };
            items.push((which, input, slo));
            gaps.push(50 + rng.next() % 3000);
        }
        Stream { nets, items, gaps }
    }

    fn serve(
        stream: &Stream,
        policy: Arc<dyn SchedulePolicy>,
        mode: ExecMode,
    ) -> Vec<(u64, Vec<i8>)> {
        let cfg = CoordinatorConfig {
            queue_depth: 64,
            exec_mode: mode,
            policy,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg);
        for ((which, input, slo), gap) in stream.items.iter().zip(&stream.gaps) {
            let g = stream.nets[*which].clone();
            match slo {
                Some(s) => coord.submit_with_slo(g, input.clone(), *s).expect("admitted"),
                None => coord.submit(g, input.clone()).expect("admitted"),
            };
            coord.advance(SimTime::us(*gap));
        }
        let mut done = coord.run_until_idle();
        assert_eq!(done.len(), stream.items.len());
        done.sort_by_key(|c| c.id);
        done.into_iter().map(|c| (c.id, c.output.data)).collect()
    }

    for seed in 1..=4u64 {
        let stream = build_stream(seed);
        let reference = serve(&stream, Arc::new(FifoPolicy), ExecMode::Modeled);
        let policies: [Arc<dyn SchedulePolicy>; 3] = [
            Arc::new(FifoPolicy),
            Arc::new(DeadlinePolicy),
            Arc::new(AdmissionPolicy),
        ];
        for policy in &policies {
            for mode in [ExecMode::Modeled, ExecMode::Threaded] {
                let got = serve(&stream, policy.clone(), mode);
                assert_eq!(
                    got, reference,
                    "seed {seed}: outputs diverged under {policy:?} / {mode}"
                );
            }
        }
        // modeled-mode EDF service order is deterministic: identical
        // (id, worker, started) sequences on a rerun
        let order = || {
            let cfg = CoordinatorConfig {
                queue_depth: 64,
                policy: Arc::new(DeadlinePolicy),
                ..CoordinatorConfig::default()
            };
            let mut coord = Coordinator::new(cfg);
            for ((which, input, slo), gap) in stream.items.iter().zip(&stream.gaps) {
                let g = stream.nets[*which].clone();
                match slo {
                    Some(s) => coord.submit_with_slo(g, input.clone(), *s).expect("admitted"),
                    None => coord.submit(g, input.clone()).expect("admitted"),
                };
                coord.advance(SimTime::us(*gap));
            }
            coord
                .run_until_idle()
                .iter()
                .map(|c| (c.id, c.worker, c.started))
                .collect::<Vec<_>>()
        };
        assert_eq!(order(), order(), "seed {seed}: modeled EDF order not deterministic");
    }

    // one more pass with the kernels forced to the scalar tier:
    // dispatch must be functionally invisible to policy agreement.
    // (CI additionally runs the whole suite under SECDA_FORCE_SCALAR=1,
    // which exercises the env-var path in a fresh process.)
    secda::gemm::simd::set_force_scalar(true);
    let stream = build_stream(1);
    let reference = serve(&stream, Arc::new(FifoPolicy), ExecMode::Modeled);
    for mode in [ExecMode::Modeled, ExecMode::Threaded] {
        let got = serve(&stream, Arc::new(AdmissionPolicy), mode);
        assert_eq!(got, reference, "forced-scalar outputs diverged under {mode}");
    }
    secda::gemm::simd::set_force_scalar(false);
}

/// Property: the coordinator-as-GemmBackend seam ([`Coordinator::backend`])
/// produces bit-identical GEMM outputs to the plain CPU gemm for ANY
/// shape and data, regardless of which pool instance each call lands on.
#[test]
fn prop_coordinator_backend_gemm_bit_exact() {
    use secda::coordinator::{Coordinator, CoordinatorConfig};
    use secda::framework::backend::{GemmBackend, GemmTask};

    for seed in 1..=20u64 {
        let mut rng = Rng::new(seed * 0xc0de);
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut cb = coord.backend();
        for _ in 0..4 {
            let req = random_request(&mut rng);
            let task = GemmTask {
                m: req.m,
                k: req.k,
                n: req.n,
                weights: &req.weights,
                inputs: &req.inputs,
                params: &req.params,
                layer: "prop",
                weights_resident: false,
            };
            let (out, timing) = cb.run_gemm(&task);
            let cpu = gemm::qgemm(
                &req.weights, &req.inputs, req.m, req.k, req.n, &req.params, 1,
            );
            assert_eq!(
                out, cpu,
                "seed {seed} shape ({},{},{})",
                req.m, req.k, req.n
            );
            assert!(timing.total > SimTime::ZERO);
        }
    }
}

/// Property: the elastic composition planner only ever emits
/// resource-feasible plans — for ANY traffic profile, observation set,
/// current composition and knob setting, every enumerated composition
/// and every planned target fits the Zynq-7020 budget, stays within
/// `max_swaps`, and clears the cost-plus-hysteresis bar.
#[test]
fn prop_elastic_planner_emits_only_feasible_compositions() {
    use secda::coordinator::{GemmShape, WorkerKind};
    use secda::elastic::{
        Composition, CompositionPlanner, DesignCosts, ElasticConfig, TrafficProfile,
    };
    use secda::synth::Resources;

    let budget = Resources::zynq7020();
    let planner = CompositionPlanner::new(budget);
    for seed in 1..=40u64 {
        let mut rng = Rng::new(seed * 0xe1a);
        let n_shapes = rng.range(1, 4);
        let mut demand = Vec::new();
        for _ in 0..n_shapes {
            let shape = GemmShape {
                m: rng.range(1, 512),
                k: rng.range(1, 8192),
                n: rng.range(1, 4096),
            };
            demand.push((shape, rng.range(1, 64) as u64));
        }
        let requests = rng.range(1, 64);
        let profile = TrafficProfile {
            requests,
            span: SimTime::ms(rng.range(50, 5000) as u64),
            arrival_rate_rps: requests as f64,
            demand,
            slo_carrying: 0,
            slo_missed: 0,
            trend: 0.0,
        };
        let mut costs = DesignCosts::new(rng.range(1, 2), SimTime::us(150));
        for _ in 0..rng.range(0, 6) {
            let kind = match rng.next() % 3 {
                0 => WorkerKind::Sa,
                1 => WorkerKind::Vm,
                _ => WorkerKind::Cpu,
            };
            let shape = GemmShape {
                m: rng.range(1, 256),
                k: rng.range(1, 4096),
                n: rng.range(1, 256),
            };
            costs.model_mut(kind).observe(
                shape,
                rng.next() % 2 == 0,
                SimTime::us(rng.range(10, 100_000) as u64),
            );
        }
        let cfg = ElasticConfig {
            max_swaps: rng.range(0, 3),
            cpu_max: rng.range(0, 3),
            hysteresis: SimTime::us(rng.range(0, 50_000) as u64),
            ..ElasticConfig::default()
        };
        for comp in planner.enumerate(cfg.cpu_max) {
            assert!(comp.fits(&budget), "seed {seed}: enumerated {comp} infeasible");
            assert!(comp.total() >= 1, "seed {seed}");
            assert!(comp.cpu <= cfg.cpu_max, "seed {seed}");
        }
        let current =
            Composition::new(rng.range(0, 2), rng.range(0, 2), rng.range(0, 2));
        if let Some(plan) = planner.plan(current, &profile, &costs, &cfg) {
            assert!(
                plan.to.fits(&budget),
                "seed {seed}: planned target {} infeasible",
                plan.to
            );
            assert!(plan.to.total() >= 1, "seed {seed}");
            assert!(plan.to != current, "seed {seed}: no-op plan emitted");
            assert!(
                plan.swaps <= cfg.max_swaps,
                "seed {seed}: {} swaps over the {} cap",
                plan.swaps,
                cfg.max_swaps
            );
            assert!(
                plan.projected_win() > plan.reconfig_cost + cfg.hysteresis,
                "seed {seed}: win {} does not clear cost {} + hysteresis {}",
                plan.projected_win(),
                plan.reconfig_cost,
                cfg.hysteresis
            );
        }
    }
}

/// Property: an elastic controller with `max_swaps = 0` is
/// bit-identical to today's static pool in BOTH exec modes — same
/// outputs, and in the deterministic modeled mode the same workers and
/// the same timeline — and it never records a reconfiguration.
#[test]
fn prop_elastic_max_swaps_zero_is_static() {
    use std::sync::Arc;

    use secda::coordinator::{Completion, Coordinator, CoordinatorConfig, ExecMode};
    use secda::elastic::ElasticConfig;
    use secda::framework::graph::{Graph, GraphBuilder};
    use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
    use secda::framework::quant::QParams;
    use secda::framework::tensor::Tensor;

    fn random_convnet(rng: &mut Rng, name: &str) -> Graph {
        let cin = rng.range(1, 4);
        let cout = rng.range(8, 24);
        let hw = rng.range(8, 14);
        let mut b = GraphBuilder::new(name, vec![1, hw, hw, cin], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: format!("{name}.c1"),
            cout,
            kh: 3,
            kw: 3,
            cin,
            stride: 1,
            pad: 1,
            weights: rng.i8s(cout * 9 * cin),
            bias: (0..cout).map(|_| (rng.next() % 200) as i32 - 100).collect(),
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    fn serve(
        nets: &[Arc<Graph>; 2],
        inputs: &[(usize, Tensor)],
        mode: ExecMode,
        elastic: bool,
    ) -> (Vec<Completion>, usize, u64) {
        let cfg = CoordinatorConfig {
            queue_depth: 64,
            exec_mode: mode,
            elastic: elastic.then(|| ElasticConfig {
                eval_interval: SimTime::ZERO,
                min_samples: 1,
                hysteresis: SimTime::ZERO,
                max_swaps: 0, // observe everything, touch nothing
                cpu_max: 2,
                ..ElasticConfig::default()
            }),
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg);
        let mut done = Vec::new();
        for (i, (which, input)) in inputs.iter().enumerate() {
            coord
                .submit(nets[*which].clone(), input.clone())
                .expect("queue sized");
            coord.advance(SimTime::us(400));
            if i % 3 == 2 {
                // drain mid-stream so the controller gets evaluation
                // opportunities it must decline
                done.extend(coord.run_until_idle());
            }
        }
        done.extend(coord.run_until_idle());
        done.sort_by_key(|c| c.id);
        (
            done,
            coord.elastic_history().len(),
            coord.metrics().reconfigs,
        )
    }

    for seed in 1..=4u64 {
        let mut rng = Rng::new(seed * 0x51a);
        let nets = [
            Arc::new(random_convnet(&mut rng, "net_a")),
            Arc::new(random_convnet(&mut rng, "net_b")),
        ];
        let inputs: Vec<(usize, Tensor)> = (0..6)
            .map(|_| {
                let which = (rng.next() % 2) as usize;
                let g = &nets[which];
                let n: usize = g.input_shape.iter().product();
                (which, Tensor::new(g.input_shape.clone(), rng.i8s(n), g.input_qp))
            })
            .collect();
        for mode in [ExecMode::Modeled, ExecMode::Threaded] {
            let (stat, _, _) = serve(&nets, &inputs, mode, false);
            let (elas, history, reconfigs) = serve(&nets, &inputs, mode, true);
            assert_eq!(history, 0, "seed {seed}: pinned pool recorded a swap");
            assert_eq!(reconfigs, 0, "seed {seed}");
            assert_eq!(stat.len(), elas.len());
            for (s, e) in stat.iter().zip(&elas) {
                assert_eq!(s.id, e.id, "seed {seed}");
                assert_eq!(
                    s.output.data, e.output.data,
                    "seed {seed}: request {} bits diverged under {mode}",
                    s.id
                );
                if mode == ExecMode::Modeled {
                    // deterministic mode: the whole timeline must match
                    assert_eq!(
                        (s.worker, s.started, s.finished),
                        (e.worker, e.started, e.finished),
                        "seed {seed}: request {} timeline diverged",
                        s.id
                    );
                }
            }
        }
    }
}

/// Property: observability is inert — serving ANY stream with span
/// tracing enabled produces bit-identical outputs to the untraced run,
/// and in the deterministic modeled mode the exact same timeline
/// (worker placement, start, finish per request), across both exec
/// modes and multiple scheduling policies. The traced run must also
/// actually record spans (the property is not vacuous).
#[test]
fn prop_tracing_is_inert() {
    use std::sync::Arc;

    use secda::coordinator::{
        Completion, Coordinator, CoordinatorConfig, DeadlinePolicy, ExecMode, FifoPolicy,
        SchedulePolicy,
    };
    use secda::framework::graph::{Graph, GraphBuilder};
    use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
    use secda::framework::quant::QParams;
    use secda::framework::tensor::Tensor;
    use secda::sysc::SimTime;

    fn random_convnet(rng: &mut Rng, name: &str) -> Graph {
        let cin = rng.range(1, 4);
        let cout = rng.range(8, 24);
        let hw = rng.range(8, 14);
        let mut b = GraphBuilder::new(name, vec![1, hw, hw, cin], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: format!("{name}.c1"),
            cout,
            kh: 3,
            kw: 3,
            cin,
            stride: 1,
            pad: 1,
            weights: rng.i8s(cout * 9 * cin),
            bias: (0..cout).map(|_| (rng.next() % 200) as i32 - 100).collect(),
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    fn serve(
        nets: &[Arc<Graph>; 2],
        inputs: &[(usize, Tensor, u64)],
        mode: ExecMode,
        policy: Arc<dyn SchedulePolicy>,
        traced: bool,
    ) -> (Vec<Completion>, usize) {
        let mut cfg = CoordinatorConfig {
            queue_depth: 64,
            exec_mode: mode,
            policy,
            ..CoordinatorConfig::default()
        };
        if traced {
            cfg = cfg.with_tracing(1 << 14);
        }
        let mut coord = Coordinator::new(cfg);
        for (which, input, gap) in inputs {
            coord
                .submit_with_slo(nets[*which].clone(), input.clone(), SimTime::ms(5_000))
                .expect("queue sized, SLO generous");
            coord.advance(SimTime::us(*gap));
        }
        let mut done = coord.run_until_idle();
        done.sort_by_key(|c| c.id);
        let spans = coord.spans().len();
        (done, spans)
    }

    for seed in 1..=4u64 {
        let mut rng = Rng::new(seed * 0x0b5);
        let nets = [
            Arc::new(random_convnet(&mut rng, "net_a")),
            Arc::new(random_convnet(&mut rng, "net_b")),
        ];
        let inputs: Vec<(usize, Tensor, u64)> = (0..6)
            .map(|_| {
                let which = (rng.next() % 2) as usize;
                let g = &nets[which];
                let n: usize = g.input_shape.iter().product();
                let input = Tensor::new(g.input_shape.clone(), rng.i8s(n), g.input_qp);
                (which, input, 50 + rng.next() % 3000)
            })
            .collect();
        let policies: [Arc<dyn SchedulePolicy>; 2] =
            [Arc::new(FifoPolicy), Arc::new(DeadlinePolicy)];
        for policy in &policies {
            for mode in [ExecMode::Modeled, ExecMode::Threaded] {
                let (plain, plain_spans) =
                    serve(&nets, &inputs, mode, policy.clone(), false);
                let (traced, traced_spans) =
                    serve(&nets, &inputs, mode, policy.clone(), true);
                assert_eq!(plain_spans, 0, "seed {seed}: untraced run recorded spans");
                assert!(
                    traced_spans > 0,
                    "seed {seed}: traced run recorded nothing under {mode}"
                );
                assert_eq!(plain.len(), traced.len(), "seed {seed}");
                for (p, t) in plain.iter().zip(&traced) {
                    assert_eq!(p.id, t.id, "seed {seed}");
                    assert_eq!(
                        p.output.data, t.output.data,
                        "seed {seed}: request {} bits diverged with tracing on ({mode})",
                        p.id
                    );
                    if mode == ExecMode::Modeled {
                        assert_eq!(
                            (p.worker, p.started, p.finished),
                            (t.worker, t.started, t.finished),
                            "seed {seed}: request {} modeled timeline diverged \
                             with tracing on ({policy:?})",
                            p.id
                        );
                    }
                }
            }
        }
    }
}

/// Property: streaming telemetry is inert — serving ANY stream with
/// the telemetry engine enabled (series sampling + alert evaluation at
/// every drain boundary) produces bit-identical outputs to the
/// untelemetered run, and in the deterministic modeled mode the exact
/// same timeline, across both exec modes and two scheduling policies.
/// The telemetry run must actually sample (the property is not
/// vacuous).
#[test]
fn prop_telemetry_is_inert() {
    use std::sync::Arc;

    use secda::coordinator::{
        Completion, Coordinator, CoordinatorConfig, DeadlinePolicy, ExecMode, FifoPolicy,
        SchedulePolicy,
    };
    use secda::framework::graph::{Graph, GraphBuilder};
    use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
    use secda::framework::quant::QParams;
    use secda::framework::tensor::Tensor;
    use secda::obs::TelemetryConfig;
    use secda::sysc::SimTime;

    fn random_convnet(rng: &mut Rng, name: &str) -> Graph {
        let cin = rng.range(1, 4);
        let cout = rng.range(8, 24);
        let hw = rng.range(8, 14);
        let mut b = GraphBuilder::new(name, vec![1, hw, hw, cin], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: format!("{name}.c1"),
            cout,
            kh: 3,
            kw: 3,
            cin,
            stride: 1,
            pad: 1,
            weights: rng.i8s(cout * 9 * cin),
            bias: (0..cout).map(|_| (rng.next() % 200) as i32 - 100).collect(),
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    fn serve(
        nets: &[Arc<Graph>; 2],
        inputs: &[(usize, Tensor, u64)],
        mode: ExecMode,
        policy: Arc<dyn SchedulePolicy>,
        telemetry: bool,
    ) -> (Vec<Completion>, usize) {
        let mut cfg = CoordinatorConfig {
            queue_depth: 64,
            exec_mode: mode,
            policy,
            ..CoordinatorConfig::default()
        };
        if telemetry {
            cfg = cfg.with_telemetry(TelemetryConfig::default());
        }
        let mut coord = Coordinator::new(cfg);
        let mut all: Vec<Completion> = Vec::new();
        // drain every few submits so the sampler sees several drain
        // boundaries, and keep every drain's completions
        for (i, (which, input, gap)) in inputs.iter().enumerate() {
            coord
                .submit_with_slo(nets[*which].clone(), input.clone(), SimTime::ms(5_000))
                .expect("queue sized, SLO generous");
            coord.advance(SimTime::us(*gap));
            if i % 3 == 2 {
                all.extend(coord.run_until_idle());
            }
        }
        all.extend(coord.run_until_idle());
        let samples = coord
            .telemetry_series()
            .map(|bank| bank.iter().map(|s| s.len()).sum())
            .unwrap_or(0);
        (all, samples)
    }

    for seed in 1..=4u64 {
        let mut rng = Rng::new(seed * 0x7e1);
        let nets = [
            Arc::new(random_convnet(&mut rng, "net_a")),
            Arc::new(random_convnet(&mut rng, "net_b")),
        ];
        let inputs: Vec<(usize, Tensor, u64)> = (0..6)
            .map(|_| {
                let which = (rng.next() % 2) as usize;
                let g = &nets[which];
                let n: usize = g.input_shape.iter().product();
                let input = Tensor::new(g.input_shape.clone(), rng.i8s(n), g.input_qp);
                (which, input, 50 + rng.next() % 3000)
            })
            .collect();
        let policies: [Arc<dyn SchedulePolicy>; 2] =
            [Arc::new(FifoPolicy), Arc::new(DeadlinePolicy)];
        for policy in &policies {
            for mode in [ExecMode::Modeled, ExecMode::Threaded] {
                let run = |telemetry: bool| {
                    let (mut done, samples) =
                        serve(&nets, &inputs, mode, policy.clone(), telemetry);
                    done.sort_by_key(|c| c.id);
                    (done, samples)
                };
                let (plain, plain_samples) = run(false);
                let (tele, tele_samples) = run(true);
                assert_eq!(plain_samples, 0, "seed {seed}: plain run sampled");
                assert!(
                    tele_samples > 0,
                    "seed {seed}: telemetry run sampled nothing under {mode}"
                );
                assert_eq!(plain.len(), tele.len(), "seed {seed}");
                for (p, t) in plain.iter().zip(&tele) {
                    assert_eq!(p.id, t.id, "seed {seed}");
                    assert_eq!(
                        p.output.data, t.output.data,
                        "seed {seed}: request {} bits diverged with telemetry on ({mode})",
                        p.id
                    );
                    if mode == ExecMode::Modeled {
                        assert_eq!(
                            (p.worker, p.started, p.finished),
                            (t.worker, t.started, t.finished),
                            "seed {seed}: request {} modeled timeline diverged \
                             with telemetry on ({policy:?})",
                            p.id
                        );
                    }
                }
            }
        }
    }
}

/// Property: the telemetry series themselves are deterministic across
/// exec modes — a 1×SA pool (the cross-mode-deterministic
/// configuration the threaded pinning tests use) served under Modeled
/// and Threaded produces byte-identical series banks: same series
/// names in the same order, same kinds, and bit-identical (timestamp,
/// value) points.
#[test]
fn prop_timeseries_deterministic_across_exec_modes() {
    use std::sync::Arc;

    use secda::coordinator::{Coordinator, CoordinatorConfig, ExecMode};
    use secda::framework::graph::{Graph, GraphBuilder};
    use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
    use secda::framework::quant::QParams;
    use secda::framework::tensor::Tensor;
    use secda::obs::TelemetryConfig;
    use secda::sysc::SimTime;

    fn random_convnet(rng: &mut Rng, name: &str) -> Graph {
        let cin = rng.range(1, 4);
        let cout = rng.range(8, 24);
        let hw = rng.range(8, 14);
        let mut b = GraphBuilder::new(name, vec![1, hw, hw, cin], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: format!("{name}.c1"),
            cout,
            kh: 3,
            kw: 3,
            cin,
            stride: 1,
            pad: 1,
            weights: rng.i8s(cout * 9 * cin),
            bias: (0..cout).map(|_| (rng.next() % 200) as i32 - 100).collect(),
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    fn series_dump(coord: &Coordinator) -> Vec<(String, String, Vec<(u64, u64)>)> {
        coord
            .telemetry_series()
            .expect("telemetry configured")
            .iter()
            .map(|s| {
                (
                    s.name().to_string(),
                    s.kind().name().to_string(),
                    // compare values by bit pattern: "identical" here
                    // means bit-identical, not approximately equal
                    s.points()
                        .map(|(t, v)| (t.as_ps(), v.to_bits()))
                        .collect(),
                )
            })
            .collect()
    }

    for seed in 1..=4u64 {
        let mut rng = Rng::new(seed * 0x5e1);
        let g = Arc::new(random_convnet(&mut rng, "net"));
        let inputs: Vec<(Tensor, u64)> = (0..8)
            .map(|_| {
                let n: usize = g.input_shape.iter().product();
                let input = Tensor::new(g.input_shape.clone(), rng.i8s(n), g.input_qp);
                (input, 100 + rng.next() % 2000)
            })
            .collect();
        let run = |mode: ExecMode| {
            let cfg = CoordinatorConfig::sa_pool(1)
                .with_exec_mode(mode)
                .with_telemetry(TelemetryConfig::default());
            let mut coord = Coordinator::new(cfg);
            // several drains so the series hold multiple points each
            for chunk in inputs.chunks(2) {
                for (input, gap) in chunk {
                    coord
                        .submit_with_slo(g.clone(), input.clone(), SimTime::ms(5_000))
                        .expect("queue sized");
                    coord.advance(SimTime::us(*gap));
                }
                coord.run_until_idle();
            }
            series_dump(&coord)
        };
        let modeled = run(ExecMode::Modeled);
        let threaded = run(ExecMode::Threaded);
        assert!(
            modeled.iter().any(|(_, _, pts)| pts.len() >= 2),
            "seed {seed}: expected multi-point series"
        );
        assert_eq!(
            modeled, threaded,
            "seed {seed}: telemetry series diverged across exec modes"
        );
    }
}

/// Property: a 1-board fleet with free ingress is bit-identical to a
/// bare coordinator — same outputs for every request, and in the
/// deterministic modeled mode the same (worker, started, finished)
/// timeline — across two scheduling policies and both exec modes. The
/// fleet front-end (gossip tick, router ranking, admission probe,
/// board clock management) must be functionally invisible.
#[test]
fn prop_fleet_matches_single_board() {
    use std::sync::Arc;

    use secda::coordinator::{
        AdmissionPolicy, Coordinator, CoordinatorConfig, ExecMode, FifoPolicy, SchedulePolicy,
    };
    use secda::fleet::{Fleet, FleetConfig, IngressModel};
    use secda::framework::graph::{Graph, GraphBuilder};
    use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
    use secda::framework::quant::QParams;
    use secda::framework::tensor::Tensor;
    use secda::sysc::SimTime;

    fn random_convnet(rng: &mut Rng, name: &str) -> Graph {
        let cin = rng.range(1, 4);
        let cout = rng.range(8, 24);
        let hw = rng.range(8, 14);
        let mut b = GraphBuilder::new(name, vec![1, hw, hw, cin], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: format!("{name}.c1"),
            cout,
            kh: 3,
            kw: 3,
            cin,
            stride: 1,
            pad: 1,
            weights: rng.i8s(cout * 9 * cin),
            bias: (0..cout).map(|_| (rng.next() % 200) as i32 - 100).collect(),
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    fn board_cfg(policy: Arc<dyn SchedulePolicy>, mode: ExecMode) -> CoordinatorConfig {
        CoordinatorConfig {
            queue_depth: 64,
            exec_mode: mode,
            policy,
            ..CoordinatorConfig::default()
        }
    }

    type Timeline = Vec<(u64, Vec<i8>, usize, SimTime, SimTime)>;

    fn key(c: &secda::coordinator::Completion) -> (u64, Vec<i8>, usize, SimTime, SimTime) {
        (c.id, c.output.data.clone(), c.worker, c.started, c.finished)
    }

    for seed in 1..=3u64 {
        let mut rng = Rng::new(seed * 0xf1ee);
        let nets = [
            Arc::new(random_convnet(&mut rng, "net_a")),
            Arc::new(random_convnet(&mut rng, "net_b")),
        ];
        let inputs: Vec<(usize, Tensor, u64)> = (0..6)
            .map(|_| {
                let which = (rng.next() % 2) as usize;
                let g = &nets[which];
                let n: usize = g.input_shape.iter().product();
                let input = Tensor::new(g.input_shape.clone(), rng.i8s(n), g.input_qp);
                (which, input, 50 + rng.next() % 3000)
            })
            .collect();
        let policies: [Arc<dyn SchedulePolicy>; 2] =
            [Arc::new(FifoPolicy), Arc::new(AdmissionPolicy)];
        for policy in &policies {
            for mode in [ExecMode::Modeled, ExecMode::Threaded] {
                // bare coordinator
                let mut coord = Coordinator::new(board_cfg(policy.clone(), mode));
                for (which, input, gap) in &inputs {
                    coord
                        .submit_with_slo(
                            nets[*which].clone(),
                            input.clone(),
                            SimTime::ms(5_000),
                        )
                        .expect("generous SLO admits");
                    coord.advance(SimTime::us(*gap));
                }
                let mut bare = coord.run_until_idle();
                bare.sort_by_key(|c| c.id);
                let bare: Timeline = bare.iter().map(key).collect();

                // 1-board fleet, free ingress
                let fcfg = FleetConfig::default()
                    .with_boards(1)
                    .with_board(board_cfg(policy.clone(), mode))
                    .with_ingress(IngressModel::none());
                let mut fleet = Fleet::new(fcfg);
                for (which, input, gap) in &inputs {
                    let p = fleet
                        .submit_with_slo(
                            nets[*which].clone(),
                            input.clone(),
                            SimTime::ms(5_000),
                        )
                        .expect("generous SLO admits");
                    assert_eq!(p.board, 0, "seed {seed}: only one board exists");
                    fleet.advance(SimTime::us(*gap));
                }
                let mut fled = fleet.run_until_idle();
                fled.sort_by_key(|bc| bc.completion.id);
                let fled: Timeline = fled.iter().map(|bc| key(&bc.completion)).collect();

                assert_eq!(bare.len(), fled.len(), "seed {seed} ({mode})");
                for (b, f) in bare.iter().zip(&fled) {
                    assert_eq!(b.0, f.0, "seed {seed}: ids diverged ({mode})");
                    assert_eq!(
                        b.1, f.1,
                        "seed {seed}: request {} bits diverged ({mode})",
                        b.0
                    );
                    if mode == ExecMode::Modeled {
                        assert_eq!(
                            (b.2, b.3, b.4),
                            (f.2, f.3, f.4),
                            "seed {seed}: request {} modeled timeline diverged \
                             behind the fleet front-end",
                            b.0
                        );
                    }
                }
            }
        }
    }
}

/// Property: an N-board modeled fleet is bit-identical to the threaded
/// fleet — same placement sequence, same per-board request ids, same
/// output bits — for ANY request stream. The exec-mode split carries
/// through the whole fleet tier.
#[test]
fn prop_fleet_modeled_threaded_agree() {
    use std::sync::Arc;

    use secda::coordinator::{CoordinatorConfig, ExecMode};
    use secda::fleet::{Fleet, FleetConfig, Placement};
    use secda::framework::graph::{Graph, GraphBuilder};
    use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
    use secda::framework::quant::QParams;
    use secda::framework::tensor::Tensor;
    use secda::sysc::SimTime;

    fn random_convnet(rng: &mut Rng, name: &str) -> Graph {
        let cin = rng.range(1, 4);
        let cout = rng.range(8, 24);
        let hw = rng.range(8, 14);
        let mut b = GraphBuilder::new(name, vec![1, hw, hw, cin], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: format!("{name}.c1"),
            cout,
            kh: 3,
            kw: 3,
            cin,
            stride: 1,
            pad: 1,
            weights: rng.i8s(cout * 9 * cin),
            bias: (0..cout).map(|_| (rng.next() % 200) as i32 - 100).collect(),
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    fn serve(
        nets: &[Arc<Graph>; 2],
        inputs: &[(usize, Tensor, u64)],
        boards: usize,
        mode: ExecMode,
    ) -> (Vec<Placement>, Vec<(usize, u64, Vec<i8>)>) {
        let fcfg = FleetConfig::default()
            .with_boards(boards)
            .with_board(CoordinatorConfig {
                queue_depth: 64,
                ..CoordinatorConfig::default()
            })
            .with_exec_mode(mode);
        let mut fleet = Fleet::new(fcfg);
        for (which, input, gap) in inputs {
            fleet
                .submit(nets[*which].clone(), input.clone())
                .expect("queue sized");
            fleet.advance(SimTime::us(*gap));
        }
        let mut done: Vec<(usize, u64, Vec<i8>)> = fleet
            .run_until_idle()
            .into_iter()
            .map(|bc| (bc.board, bc.completion.id, bc.completion.output.data))
            .collect();
        done.sort();
        (fleet.placements().to_vec(), done)
    }

    for seed in 1..=3u64 {
        let mut rng = Rng::new(seed * 0xf2ee);
        let nets = [
            Arc::new(random_convnet(&mut rng, "net_a")),
            Arc::new(random_convnet(&mut rng, "net_b")),
        ];
        let inputs: Vec<(usize, Tensor, u64)> = (0..7)
            .map(|_| {
                let which = (rng.next() % 2) as usize;
                let g = &nets[which];
                let n: usize = g.input_shape.iter().product();
                let input = Tensor::new(g.input_shape.clone(), rng.i8s(n), g.input_qp);
                (which, input, 50 + rng.next() % 2000)
            })
            .collect();
        let boards = 2 + (seed as usize % 2);
        let (mp, md) = serve(&nets, &inputs, boards, ExecMode::Modeled);
        let (tp, td) = serve(&nets, &inputs, boards, ExecMode::Threaded);
        assert_eq!(
            mp, tp,
            "seed {seed}: placement sequence diverged across exec modes"
        );
        assert_eq!(md, td, "seed {seed}: outputs diverged across exec modes");
        assert_eq!(md.len(), inputs.len(), "seed {seed}: lost completions");
        // and modeled reruns are self-identical (fleet determinism)
        let (mp2, md2) = serve(&nets, &inputs, boards, ExecMode::Modeled);
        assert_eq!((mp, md), (mp2, md2), "seed {seed}: modeled rerun diverged");
    }
}

/// Property: the fleet router is deterministic under stale gossip —
/// the same stream against the same staleness bound produces the same
/// placement sequence, accept/shed pattern and outputs on a rerun —
/// and it never places a request onto a board whose admission control
/// would shed it while another board would accept it.
#[test]
fn prop_router_is_deterministic_under_stale_gossip() {
    use std::sync::Arc;

    use secda::coordinator::{AdmissionPolicy, CoordinatorConfig, SubmitError};
    use secda::fleet::{Fleet, FleetConfig, GossipConfig, IngressModel, Placement};
    use secda::framework::graph::{Graph, GraphBuilder};
    use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
    use secda::framework::quant::QParams;
    use secda::framework::tensor::Tensor;
    use secda::sysc::SimTime;

    fn random_convnet(rng: &mut Rng, name: &str) -> Graph {
        let cin = rng.range(1, 4);
        let cout = rng.range(8, 24);
        let hw = rng.range(8, 14);
        let mut b = GraphBuilder::new(name, vec![1, hw, hw, cin], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: format!("{name}.c1"),
            cout,
            kh: 3,
            kw: 3,
            cin,
            stride: 1,
            pad: 1,
            weights: rng.i8s(cout * 9 * cin),
            bias: (0..cout).map(|_| (rng.next() % 200) as i32 - 100).collect(),
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    // Outcome of one submit: where it landed, or that it was shed.
    #[derive(Debug, PartialEq, Eq)]
    enum Outcome {
        Placed(Placement),
        Shed,
    }

    fn serve(
        nets: &[Arc<Graph>; 2],
        inputs: &[(usize, Tensor, u64, u64)],
        boards: usize,
        staleness: SimTime,
        check_invariant: bool,
    ) -> (Vec<Outcome>, Vec<(usize, u64, Vec<i8>)>, u64) {
        let ingress = IngressModel::default();
        let fcfg = FleetConfig::default()
            .with_boards(boards)
            .with_board(CoordinatorConfig {
                queue_depth: 64,
                policy: Arc::new(AdmissionPolicy),
                ..CoordinatorConfig::default()
            })
            .with_ingress(ingress)
            .with_gossip(GossipConfig { staleness });
        let mut fleet = Fleet::new(fcfg);
        let mut outcomes = Vec::new();
        for (which, input, gap, slo) in inputs {
            let g = nets[*which].clone();
            let slo = SimTime::us(*slo);
            // the accept set, probed exactly the way the fleet will
            let deadline = fleet.now() + slo;
            let cost = ingress.cost(input.bytes() as u64);
            let acceptors: Vec<usize> = (0..boards)
                .filter(|b| {
                    let board = &fleet.boards()[*b];
                    let arrive = (fleet.now() + cost).max(board.now());
                    board
                        .would_shed(&g, input, Some(deadline), arrive)
                        .is_none()
                })
                .collect();
            match fleet.submit_with_slo(g, input.clone(), slo) {
                Ok(p) => {
                    if check_invariant {
                        assert!(
                            acceptors.contains(&p.board),
                            "placed on board {} but the accept set was {acceptors:?}",
                            p.board
                        );
                    }
                    outcomes.push(Outcome::Placed(p));
                }
                Err(SubmitError::ShedPredicted { .. }) => {
                    if check_invariant {
                        assert!(
                            acceptors.is_empty(),
                            "shed although boards {acceptors:?} would accept"
                        );
                    }
                    outcomes.push(Outcome::Shed);
                }
                Err(e) => panic!("unexpected submit error: {e:?}"),
            }
            fleet.advance(SimTime::us(*gap));
        }
        let mut done: Vec<(usize, u64, Vec<i8>)> = fleet
            .run_until_idle()
            .into_iter()
            .map(|bc| (bc.board, bc.completion.id, bc.completion.output.data))
            .collect();
        done.sort();
        let refreshes = fleet.gossip().refreshes();
        (outcomes, done, refreshes)
    }

    for seed in 1..=3u64 {
        let mut rng = Rng::new(seed * 0xf3ee);
        let nets = [
            Arc::new(random_convnet(&mut rng, "net_a")),
            Arc::new(random_convnet(&mut rng, "net_b")),
        ];
        // tight-ish SLOs (hundreds of us to a few ms) against bursty
        // gaps: some requests genuinely shed, most are served
        let inputs: Vec<(usize, Tensor, u64, u64)> = (0..8)
            .map(|_| {
                let which = (rng.next() % 2) as usize;
                let g = &nets[which];
                let n: usize = g.input_shape.iter().product();
                let input = Tensor::new(g.input_shape.clone(), rng.i8s(n), g.input_qp);
                let gap = 20 + rng.next() % 800;
                let slo = 300 + rng.next() % 20_000;
                (which, input, gap, slo)
            })
            .collect();
        let boards = 2 + (seed as usize % 3);
        let staleness = SimTime::us([0u64, 200, 5_000][seed as usize % 3]);
        let a = serve(&nets, &inputs, boards, staleness, true);
        let b = serve(&nets, &inputs, boards, staleness, false);
        assert_eq!(
            a.0, b.0,
            "seed {seed}: outcome sequence diverged on rerun \
             ({boards} boards, staleness {staleness})"
        );
        assert_eq!(a.1, b.1, "seed {seed}: outputs diverged on rerun");
        assert_eq!(a.2, b.2, "seed {seed}: gossip refresh count diverged");
        let placed = a.0.iter().filter(|o| matches!(o, Outcome::Placed(_))).count();
        assert_eq!(a.1.len(), placed, "seed {seed}: completions != placements");
    }
}

/// Failure injection: a livelocked module graph (self-rescheduling
/// forever) must be contained by the kernel's event budget instead of
/// hanging the design loop.
#[test]
fn prop_event_budget_contains_livelock() {
    use secda::sysc::{Ctx, Module, Simulator};

    #[derive(Clone, Debug)]
    struct Spin;
    struct Spinner;
    impl Module<Spin> for Spinner {
        fn name(&self) -> &str {
            "spinner"
        }
        fn handle(&mut self, _p: Spin, ctx: &mut Ctx<'_, Spin>) {
            ctx.schedule_self(SimTime::ns(1), Spin); // never terminates
        }
    }
    let mut sim = Simulator::new();
    let id = sim.add_module(Box::new(Spinner));
    sim.schedule(SimTime::ZERO, id, Spin);
    sim.run_with_limit(10_000);
    assert_eq!(sim.events_dispatched(), 10_000);
}

/// Property: a DSE campaign's full result — the Pareto set AND every
/// per-design modeled number behind it — is invariant to the worker
/// thread count. Threads decide who simulates a `(design, shape)`
/// pair, never what the pair evaluates to or how results reduce.
#[test]
fn prop_dse_is_thread_count_invariant() {
    use secda::coordinator::GemmShape;
    use secda::dse::{design_space, run_campaign, CampaignConfig, MemoCache, WorkloadProfile};

    let space = design_space();
    for seed in 1..=4u64 {
        let mut rng = Rng::new(seed * 0xd5e);
        let profiles: Vec<WorkloadProfile> = (0..rng.range(1, 2))
            .map(|p| {
                let demand = (0..rng.range(1, 3))
                    .map(|_| {
                        let shape = GemmShape {
                            m: rng.range(1, 24),
                            k: rng.range(1, 48),
                            n: rng.range(1, 24),
                        };
                        (shape, rng.range(1, 4) as u64)
                    })
                    .collect();
                WorkloadProfile::new(format!("w{p}"), demand)
            })
            .collect();
        let run = |threads: usize| {
            let cfg = CampaignConfig {
                threads,
                ..CampaignConfig::default()
            };
            run_campaign(&cfg, &profiles, &space, &MemoCache::new())
        };
        let baseline = run(1);
        for threads in [2usize, 8] {
            let other = run(threads);
            assert_eq!(
                baseline.pareto_json(),
                other.pareto_json(),
                "seed {seed}: frontier diverged at {threads} threads"
            );
            assert_eq!(baseline.pairs, other.pairs, "seed {seed}");
            for (a, b) in baseline.profiles.iter().zip(&other.profiles) {
                for (ea, eb) in a.evals.iter().zip(&b.evals) {
                    assert_eq!(ea.design, eb.design, "seed {seed}");
                    assert_eq!(
                        ea.latency, eb.latency,
                        "seed {seed}: {} latency diverged at {threads} threads",
                        ea.design.key()
                    );
                    assert_eq!(
                        ea.energy_j.to_bits(),
                        eb.energy_j.to_bits(),
                        "seed {seed}: {} energy diverged at {threads} threads",
                        ea.design.key()
                    );
                    assert_eq!(
                        ea.utilization.to_bits(),
                        eb.utilization.to_bits(),
                        "seed {seed}: {} utilization diverged",
                        ea.design.key()
                    );
                }
            }
        }
    }
}

/// Property: every design a campaign puts on a frontier fits the
/// Zynq-7020 budget and is dominated by no other frontier member, for
/// ANY random workload profile.
#[test]
fn prop_dse_frontier_is_feasible_and_nondominated() {
    use secda::coordinator::GemmShape;
    use secda::dse::{design_space, run_campaign, CampaignConfig, MemoCache, WorkloadProfile};
    use secda::synth::Resources;

    let space = design_space();
    let budget = Resources::zynq7020();
    for seed in 1..=4u64 {
        let mut rng = Rng::new(seed * 0xace1);
        let demand = (0..rng.range(1, 3))
            .map(|_| {
                let shape = GemmShape {
                    m: rng.range(1, 24),
                    k: rng.range(1, 48),
                    n: rng.range(1, 24),
                };
                (shape, rng.range(1, 5) as u64)
            })
            .collect();
        let profiles = [WorkloadProfile::new("random", demand)];
        let cfg = CampaignConfig {
            threads: 2,
            ..CampaignConfig::default()
        };
        let report = run_campaign(&cfg, &profiles, &space, &MemoCache::new());
        for p in &report.profiles {
            assert!(!p.frontier.is_empty(), "seed {seed}: empty frontier");
            for e in &p.frontier {
                assert!(
                    e.design.fits(&budget),
                    "seed {seed}: frontier design {} does not fit",
                    e.design.key()
                );
                assert!(
                    !p.frontier.iter().any(|o| o.dominates(e)),
                    "seed {seed}: frontier member {} is dominated",
                    e.design.key()
                );
            }
        }
    }
}
