//! PJRT runtime numerics: the AOT-compiled Pallas artifacts must be
//! bit-exact against the rust CPU gemm (and therefore against the
//! accelerator simulators, which share the same functional core).
//!
//! This is the three-layer integration proof: L1 Pallas kernel ==
//! L2 jax lowering == L3 rust, across shape buckets including padding.
//!
//! Requires the `pjrt` feature (PJRT execution of the artifacts).
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use secda::framework::quant::quantize_multiplier;
use secda::gemm::{self, QGemmParams};
use secda::runtime::ArtifactRuntime;

fn artifacts_dir() -> PathBuf {
    std::env::var_os("SECDA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn xorshift(st: &mut u64) -> u64 {
    *st ^= *st << 13;
    *st ^= *st >> 7;
    *st ^= *st << 17;
    *st
}

fn rand_i8(st: &mut u64, n: usize) -> Vec<i8> {
    (0..n).map(|_| (xorshift(st) & 0xff) as u8 as i8).collect()
}

fn check_shape(rt: &mut ArtifactRuntime, m: usize, k: usize, n: usize, seed: u64) {
    let mut st = seed.max(1);
    let w = rand_i8(&mut st, m * k);
    let x = rand_i8(&mut st, k * n);
    let (mult, shift) = quantize_multiplier(0.5 / (k as f64).sqrt());
    let mut p = QGemmParams::uniform(m, 0, mult, shift);
    for i in 0..m {
        p.bias[i] = (xorshift(&mut st) % 2000) as i32 - 1000;
        p.shift[i] = shift - (xorshift(&mut st) % 3) as i32;
    }
    p.out_zp = (xorshift(&mut st) % 17) as i32 - 8;
    let pjrt = rt
        .qgemm(m, k, n, &w, &x, &p)
        .unwrap_or_else(|e| panic!("pjrt qgemm ({m},{k},{n}): {e:#}"));
    let cpu = gemm::qgemm(&w, &x, m, k, n, &p, 1);
    assert_eq!(pjrt, cpu, "PJRT vs CPU mismatch at ({m},{k},{n})");
}

#[test]
fn pjrt_matches_cpu_gemm_across_buckets() {
    let dir = artifacts_dir();
    assert!(
        ArtifactRuntime::available(&dir),
        "artifacts missing at {dir:?}; run `make artifacts`"
    );
    let mut rt = ArtifactRuntime::new(&dir).expect("runtime init");
    assert!(rt.buckets.len() >= 50, "expected many buckets");
    // exact-bucket shapes and padded (off-bucket) shapes
    for (i, &(m, k, n)) in [
        (32, 27, 12544), // MobileNetV1 conv0 (logical, padded into bucket)
        (64, 32, 12544), // exact bucket
        (512, 4608, 49), // ResNet18 stage-4 (largest K)
        (100, 100, 100), // arbitrary padding in all dims
        (1, 1, 1),       // degenerate
        (130, 33, 140),  // just past bucket boundaries
    ]
    .iter()
    .enumerate()
    {
        check_shape(&mut rt, m, k, n, (i as u64 + 1) * 7919);
    }
}

#[test]
fn pjrt_matches_accelerator_simulators() {
    use secda::accel::{ExecMode, GemmAccel, GemmRequest, SaDesign, VmDesign};
    let dir = artifacts_dir();
    let mut rt = ArtifactRuntime::new(&dir).expect("runtime init");
    let (m, k, n) = (64, 96, 160);
    let mut st = 31u64;
    let w = rand_i8(&mut st, m * k);
    let x = rand_i8(&mut st, k * n);
    let (mult, shift) = quantize_multiplier(0.01);
    let p = QGemmParams::uniform(m, 7, mult, shift);
    let pjrt = rt.qgemm(m, k, n, &w, &x, &p).expect("pjrt");
    let req = GemmRequest::new(m, k, n, w, x, p);
    let sa = SaDesign::paper().run(&req, ExecMode::Simulation);
    let vm = VmDesign::paper().run(&req, ExecMode::HardwareEval);
    assert_eq!(pjrt, sa.output, "PJRT vs SA simulator");
    assert_eq!(pjrt, vm.output, "PJRT vs VM simulator");
}

#[test]
fn bucket_coverage_for_all_models() {
    // every GEMM in the rust model zoo must have an AOT bucket — this
    // cross-checks the rust shape tables against python/compile/model.py
    let dir = artifacts_dir();
    let rt = ArtifactRuntime::new(&dir).expect("runtime init");
    for name in secda::framework::models::ALL {
        let g = secda::framework::models::by_name(name).unwrap();
        for (m, k, n) in secda::framework::models::gemm_shapes(&g) {
            assert!(
                rt.pick_bucket(m, k, n).is_some(),
                "{name}: GEMM ({m},{k},{n}) has no AOT bucket — python \
                 and rust shape tables have diverged"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Failure injection: the runtime must fail loudly and descriptively,
// never silently compute garbage.
// ---------------------------------------------------------------------

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = std::env::temp_dir().join("secda_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.tsv"), "64\tnot_a_number\t64\tx.hlo.txt\n").unwrap();
    let err = match ArtifactRuntime::new(&dir) {
        Err(e) => e,
        Ok(_) => panic!("must reject"),
    };
    assert!(format!("{err:#}").contains("manifest.tsv line 1"), "{err:#}");
}

#[test]
fn empty_manifest_is_rejected() {
    let dir = std::env::temp_dir().join("secda_empty_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.tsv"), "").unwrap();
    let err = match ArtifactRuntime::new(&dir) {
        Err(e) => e,
        Ok(_) => panic!("must reject"),
    };
    assert!(format!("{err:#}").contains("empty manifest"), "{err:#}");
}

#[test]
fn missing_artifact_file_fails_at_compile() {
    let dir = std::env::temp_dir().join("secda_missing_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.tsv"), "32\t32\t32\tdoes_not_exist.hlo.txt\n").unwrap();
    let mut rt = ArtifactRuntime::new(&dir).expect("manifest parses");
    let w = vec![0i8; 32 * 32];
    let x = vec![0i8; 32 * 32];
    let p = QGemmParams::uniform(32, 0, 1 << 30, 0);
    let err = rt.qgemm(32, 32, 32, &w, &x, &p).expect_err("must fail");
    assert!(format!("{err:#}").contains("does_not_exist"), "{err:#}");
}

#[test]
fn uncovered_shape_reports_bucket_miss() {
    let dir = artifacts_dir();
    let mut rt = ArtifactRuntime::new(&dir).expect("runtime init");
    // absurdly large GEMM: no bucket can cover it
    let (m, k, n) = (100_000, 8, 8);
    let w = vec![0i8; m * k];
    let x = vec![0i8; k * n];
    let p = QGemmParams::uniform(m, 0, 1 << 30, 0);
    let err = rt.qgemm(m, k, n, &w, &x, &p).expect_err("must fail");
    assert!(format!("{err:#}").contains("no AOT bucket"), "{err:#}");
}

#[test]
fn runtime_missing_dir_reports_helpfully() {
    let dir = std::path::Path::new("/nonexistent/secda_artifacts");
    assert!(!ArtifactRuntime::available(dir));
    let err = match ArtifactRuntime::new(dir) {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
}
