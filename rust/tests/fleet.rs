//! Golden tests for the L4 fleet tier: metrics aggregation must be
//! exactly the sum/merge of the per-board parts (utilization numerators
//! recomputed from worker busy time, merged latency quantiles checked
//! against a brute-force sort of every completion), and the fleet
//! Chrome trace must pass the same validator `secda trace-validate`
//! uses, with one process of tracks per board.

use std::sync::Arc;

use secda::elastic::ElasticConfig;
use secda::fleet::{Fleet, FleetConfig, GossipConfig, IngressModel};
use secda::framework::graph::{Graph, GraphBuilder};
use secda::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
use secda::framework::quant::QParams;
use secda::framework::tensor::Tensor;
use secda::obs::export::{metrics_json, validate_chrome_trace, validate_metrics_json};
use secda::sysc::SimTime;

fn convnet(name: &str) -> Graph {
    let mut st = 0xf1ee7u64;
    let mut rnd = move || {
        st ^= st << 13;
        st ^= st >> 7;
        st ^= st << 17;
        st
    };
    let (cin, cout) = (3usize, 16usize);
    let mut b = GraphBuilder::new(name, vec![1, 10, 10, cin], QParams::new(0.05, 0));
    let conv = Conv2d {
        name: format!("{name}.c1"),
        cout,
        kh: 3,
        kw: 3,
        cin,
        stride: 1,
        pad: 1,
        weights: (0..cout * 9 * cin).map(|_| (rnd() & 0xff) as u8 as i8).collect(),
        bias: vec![7; cout],
        w_scales: vec![0.02; cout],
        out_qp: QParams::new(0.05, 0),
        act: Activation::Relu,
        weights_resident: false,
    };
    let c = b.push(Op::Conv(conv), vec![b.input()]);
    let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
    let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
    b.finish(s)
}

/// Serve a deterministic stream through a fleet and return it drained,
/// with the completions.
fn served_fleet(
    mut cfg: FleetConfig,
    requests: usize,
) -> (Fleet, Vec<secda::fleet::BoardCompletion>) {
    cfg = cfg.with_gossip(GossipConfig {
        // always-fresh gossip: backlog steering spreads the stream
        // across boards instead of piling onto board 0
        staleness: SimTime::ZERO,
    });
    let g = Arc::new(convnet("fleet_net"));
    let mut fleet = Fleet::new(cfg);
    let mut seed = 0x5eedu64;
    let mut rnd = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        seed
    };
    for _ in 0..requests {
        let n: usize = g.input_shape.iter().product();
        let data: Vec<i8> = (0..n).map(|_| (rnd() & 0xff) as u8 as i8).collect();
        let input = Tensor::new(g.input_shape.clone(), data, g.input_qp);
        fleet
            .submit_with_slo(g.clone(), input, SimTime::ms(5_000))
            .expect("queue sized, SLO generous");
        fleet.advance(SimTime::us(300 + rnd() % 2000));
    }
    let done = fleet.run_until_idle();
    (fleet, done)
}

/// Fleet counters are exactly the per-board sums, per-board
/// utilization is exactly worker busy time over workers x makespan,
/// and every board served part of the stream.
#[test]
fn golden_fleet_metrics_aggregate_per_board() {
    let (fleet, done) = served_fleet(FleetConfig::default().with_boards(3), 9);
    assert_eq!(done.len(), 9);
    let m = fleet.metrics();
    assert_eq!(m.boards.len(), 3);
    assert_eq!(m.completed, 9);
    assert_eq!(m.submitted, 9);
    assert_eq!(m.rejected, 0);
    assert_eq!(m.shed_predicted, 0);

    let mut sum_submitted = 0u64;
    let mut sum_completed = 0u64;
    for (i, bs) in m.boards.iter().enumerate() {
        let board = &fleet.boards()[i];
        assert_eq!(bs.board, i);
        assert_eq!(bs.submitted, board.metrics().submitted);
        assert_eq!(bs.completed, board.metrics().completed);
        assert!(bs.completed >= 1, "board {i} served nothing");
        sum_submitted += bs.submitted;
        sum_completed += bs.completed;
        // utilization numerator: recomputed straight from the pool
        let busy = board
            .pool()
            .workers
            .iter()
            .fold(SimTime::ZERO, |acc, w| acc + w.busy);
        assert_eq!(bs.busy, busy, "board {i} busy time");
        assert_eq!(bs.workers, board.pool().workers.len());
        let want = busy.as_secs_f64() / (bs.workers as f64 * m.makespan.as_secs_f64());
        assert!(
            (bs.utilization - want).abs() < 1e-12,
            "board {i} utilization {} != {want}",
            bs.utilization
        );
        assert!(bs.utilization > 0.0 && bs.utilization <= 1.0);
    }
    assert_eq!(m.submitted, sum_submitted);
    assert_eq!(m.completed, sum_completed);
    assert!(m.throughput_rps() > 0.0);
    assert!(m.makespan > SimTime::ZERO);
    assert_eq!(m.makespan, fleet.makespan());

    // the summary and registry exports carry the per-board breakdown
    let s = m.summary();
    assert!(s.contains("board0:") && s.contains("board2:"), "{s}");
    let json = metrics_json(&m.registry());
    let n = validate_metrics_json(&json).expect("fleet metrics snapshot must validate");
    assert!(n > 0);
    assert!(json.contains("fleet.latency_ps"), "{json}");
    assert!(json.contains("board1.utilization"), "{json}");
}

/// The merged fleet latency histogram agrees with a brute-force sort
/// of every completion's latency: extremes exact, interior quantiles
/// within the histogram's ~1.6% bucket width.
#[test]
fn golden_fleet_latency_quantiles_match_brute_force() {
    let (fleet, done) = served_fleet(FleetConfig::default().with_boards(2), 10);
    let m = fleet.metrics();
    let mut lat: Vec<u64> = done
        .iter()
        .map(|bc| bc.completion.finished.saturating_sub(bc.completion.arrival).as_ps())
        .collect();
    lat.sort_unstable();
    assert_eq!(lat.len(), 10);

    // extremes are tracked exactly
    assert_eq!(m.latency_pct(0.0).as_ps(), lat[0], "min must be exact");
    assert_eq!(
        m.latency_pct(1.0).as_ps(),
        lat[lat.len() - 1],
        "max must be exact"
    );
    // interior: nearest-rank brute force vs log-bucket resolution
    for p in [0.25, 0.5, 0.9] {
        let rank = (p * (lat.len() - 1) as f64).round() as usize;
        let want = lat[rank] as f64;
        let got = m.latency_pct(p).as_ps() as f64;
        assert!(
            (got - want).abs() <= want * 0.02,
            "p{p}: merged histogram {got} vs brute force {want}"
        );
    }
    // waits obey the same merge (started >= arrival on every board)
    assert!(m.wait_pct(1.0) >= m.wait_pct(0.0));
}

/// The fleet Chrome trace validates and carries one process of tracks
/// per board, with per-request flows intact across the merge.
#[test]
fn golden_fleet_chrome_trace_one_process_per_board() {
    let (fleet, done) = served_fleet(
        FleetConfig::default().with_boards(2).with_tracing(1 << 14),
        6,
    );
    assert_eq!(done.len(), 6);
    let json = fleet.chrome_trace();
    let check = validate_chrome_trace(&json).expect("fleet trace must validate");
    assert!(check.slices > 0, "no complete slices exported");
    assert_eq!(check.flows, 6, "one submit->execution arrow per request");
    assert!(
        check.tracks >= 4,
        "expected coordinator + worker tracks on both boards, got {}",
        check.tracks
    );
    assert!(json.contains("board0"), "board 0 process label missing");
    assert!(json.contains("board1"), "board 1 process label missing");
}

/// A fleet with portfolio planning enabled stays consistent: every
/// committed swap shows up in exactly one board's reconfig counters,
/// and the deployed compositions match what the boards report.
#[test]
fn golden_fleet_portfolio_accounting() {
    let cfg = FleetConfig::default()
        .with_boards(2)
        .with_ingress(IngressModel::none())
        .with_portfolio(ElasticConfig {
            eval_interval: SimTime::ZERO,
            min_samples: 1,
            hysteresis: SimTime::ZERO,
            ..ElasticConfig::default()
        });
    let g = Arc::new(convnet("portfolio_net"));
    let mut fleet = Fleet::new(cfg);
    let mut seed = 0xab1eu64;
    let mut rnd = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        seed
    };
    let mut served = 0usize;
    for round in 0..3 {
        for _ in 0..4 {
            let n: usize = g.input_shape.iter().product();
            let data: Vec<i8> = (0..n).map(|_| (rnd() & 0xff) as u8 as i8).collect();
            let input = Tensor::new(g.input_shape.clone(), data, g.input_qp);
            fleet.submit(g.clone(), input).expect("queue sized");
            fleet.advance(SimTime::us(500 + rnd() % 1500));
        }
        served += fleet.run_until_idle().len();
        assert_eq!(served, (round + 1) * 4, "round {round} lost completions");
    }
    let m = fleet.metrics();
    assert_eq!(m.completed, 12);
    // without board-local elastic, every reconfig is a portfolio swap
    assert_eq!(
        m.reconfigs,
        fleet.portfolio_history().len() as u64,
        "portfolio history and board reconfig counters disagree"
    );
    for rec in fleet.portfolio_history() {
        assert!(rec.board < 2);
        assert!(rec.record.projected_win > rec.record.reconfig_cost);
    }
    // deployed portfolio == what each board reports
    let comps = fleet.compositions();
    for (i, b) in fleet.boards().iter().enumerate() {
        assert_eq!(comps[i], b.composition());
    }
}
