//! Cross-language bit-exactness: the rust requantization pipeline must
//! reproduce the golden vectors emitted by the python reference
//! (`python/compile/kernels/ref.py`, written by `make artifacts`).
//!
//! This pins the integer semantics shared by three implementations:
//! the Pallas kernel epilogue (L1), the jnp oracle, and
//! `framework::quant` (L3 / the accelerator PPU models).

use std::path::PathBuf;

use secda::framework::quant::multiply_by_quantized_multiplier;

fn golden_path() -> PathBuf {
    std::env::var_os("SECDA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
        .join("requant_golden.tsv")
}

#[test]
fn requant_matches_python_golden_vectors() {
    let path = golden_path();
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read {path:?} ({e}); run `make artifacts` first"));
    let mut n = 0;
    for (i, line) in text.lines().enumerate() {
        let f: Vec<i64> = line
            .split('\t')
            .map(|v| v.parse().unwrap_or_else(|e| panic!("line {}: {e}", i + 1)))
            .collect();
        assert_eq!(f.len(), 4, "line {}", i + 1);
        let (acc, mult, shift, want) = (f[0] as i32, f[1] as i32, f[2] as i32, f[3] as i32);
        let got = multiply_by_quantized_multiplier(acc, mult, shift);
        assert_eq!(got, want, "case {i}: acc={acc} mult={mult} shift={shift}");
        n += 1;
    }
    assert!(n >= 64, "expected at least 64 golden cases, got {n}");
}
