//! PJRT runtime: load and execute the AOT-compiled qGEMM artifacts.
//!
//! This is the "bitstream" of the reproduction: `make artifacts`
//! lowers the Layer-1 Pallas kernel (via the Layer-2 JAX entry) to HLO
//! text once per shape bucket; this module compiles each bucket on the
//! PJRT CPU client at first use and executes it from the request path.
//! Python is never involved at runtime.
//!
//! Interchange is HLO *text* — xla_extension 0.5.1 rejects jax >= 0.5
//! serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md and aot_recipe).
//!
//! i8/i32 literals are built through
//! `Literal::create_from_shape_and_untyped_data` (the crate's typed
//! constructors only cover i32/i64/u32/u64/f32/f64).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::gemm::QGemmParams;

/// One AOT shape bucket from the manifest.
#[derive(Debug, Clone)]
pub struct Bucket {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub file: String,
}

impl Bucket {
    pub fn covers(&self, m: usize, k: usize, n: usize) -> bool {
        self.m >= m && self.k >= k && self.n >= n
    }

    pub fn volume(&self) -> u128 {
        self.m as u128 * self.k as u128 * self.n as u128
    }
}

/// The artifact runtime: manifest + lazily compiled executables.
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub buckets: Vec<Bucket>,
    cache: HashMap<(usize, usize, usize), xla::PjRtLoadedExecutable>,
}

/// Default artifacts directory (repo-relative, overridable via env).
pub fn default_dir() -> PathBuf {
    std::env::var_os("SECDA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

impl ArtifactRuntime {
    /// Load the manifest and create the PJRT CPU client.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {manifest:?}; run `make artifacts` first"))?;
        let mut buckets = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let mut it = line.split('\t');
            let parse = |s: Option<&str>| -> Result<usize> {
                s.ok_or_else(|| anyhow!("manifest.tsv line {}: missing field", lineno + 1))?
                    .parse::<usize>()
                    .with_context(|| format!("manifest.tsv line {}", lineno + 1))
            };
            let m = parse(it.next())?;
            let k = parse(it.next())?;
            let n = parse(it.next())?;
            let file = it
                .next()
                .ok_or_else(|| anyhow!("manifest.tsv line {}: missing file", lineno + 1))?
                .to_string();
            buckets.push(Bucket { m, k, n, file });
        }
        if buckets.is_empty() {
            bail!("empty manifest at {manifest:?}");
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(ArtifactRuntime {
            client,
            dir: dir.to_path_buf(),
            buckets,
            cache: HashMap::new(),
        })
    }

    /// True when the artifacts directory looks usable.
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.tsv").is_file()
    }

    /// Smallest bucket covering a logical GEMM shape.
    pub fn pick_bucket(&self, m: usize, k: usize, n: usize) -> Option<&Bucket> {
        self.buckets
            .iter()
            .filter(|b| b.covers(m, k, n))
            .min_by_key(|b| b.volume())
    }

    fn executable(
        &mut self,
        key: (usize, usize, usize),
        file: &str,
    ) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&key) {
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {file}: {e:?}"))?;
            self.cache.insert(key, exe);
        }
        Ok(&self.cache[&key])
    }

    /// Execute a quantized GEMM through the AOT artifact: pads into the
    /// bucket, runs on PJRT, and returns the valid `m x n` region.
    /// Bit-exact vs [`crate::gemm::qgemm`] (see tests/runtime_numerics).
    pub fn qgemm(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        w: &[i8],
        x: &[i8],
        params: &QGemmParams,
    ) -> Result<Vec<i8>> {
        assert_eq!(w.len(), m * k);
        assert_eq!(x.len(), k * n);
        let b = self
            .pick_bucket(m, k, n)
            .ok_or_else(|| anyhow!("no AOT bucket covers GEMM ({m},{k},{n})"))?
            .clone();
        let (mb, kb, nb) = (b.m, b.k, b.n);

        // pad W rows with zeros (inert), X with anything (zero)
        let mut wp = vec![0i8; mb * kb];
        for i in 0..m {
            wp[i * kb..i * kb + k].copy_from_slice(&w[i * k..(i + 1) * k]);
        }
        let mut xp = vec![0i8; kb * nb];
        for r in 0..k {
            xp[r * nb..r * nb + n].copy_from_slice(&x[r * n..(r + 1) * n]);
        }
        let mut bias = vec![0i32; mb];
        bias[..m].copy_from_slice(&params.bias);
        let mut mult = vec![1 << 30; mb];
        mult[..m].copy_from_slice(&params.mult);
        let mut shift = vec![0i32; mb];
        shift[..m].copy_from_slice(&params.shift);
        let qp = [params.out_zp, params.act_min, params.act_max, 0i32];

        let lit_i8 = |data: &[i8], dims: &[usize]| -> Result<xla::Literal> {
            let bytes =
                unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S8, dims, bytes)
                .map_err(|e| anyhow!("i8 literal: {e:?}"))
        };
        let lit_i32 = |data: &[i32], dims: &[usize]| -> Result<xla::Literal> {
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
                .map_err(|e| anyhow!("i32 literal: {e:?}"))
        };

        let args = [
            lit_i8(&wp, &[mb, kb])?,
            lit_i8(&xp, &[kb, nb])?,
            lit_i32(&bias, &[mb])?,
            lit_i32(&mult, &[mb])?,
            lit_i32(&shift, &[mb])?,
            lit_i32(&qp, &[4])?,
        ];
        let exe = self.executable((mb, kb, nb), &b.file)?;
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("executing bucket {:?}: {e:?}", (mb, kb, nb)))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // lowered with return_tuple=True -> 1-tuple
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        let flat: Vec<i8> = out.to_vec().map_err(|e| anyhow!("to_vec i8: {e:?}"))?;
        if flat.len() != mb * nb {
            bail!("unexpected output size {} != {}", flat.len(), mb * nb);
        }
        // crop the valid region
        let mut cropped = vec![0i8; m * n];
        for i in 0..m {
            cropped[i * n..(i + 1) * n].copy_from_slice(&flat[i * nb..i * nb + n]);
        }
        Ok(cropped)
    }

    /// Number of compiled executables (cache telemetry).
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_picking_prefers_smallest() {
        let buckets = vec![
            Bucket { m: 128, k: 64, n: 128, file: "a".into() },
            Bucket { m: 64, k: 64, n: 128, file: "b".into() },
            Bucket { m: 64, k: 32, n: 64, file: "c".into() },
        ];
        let rt_pick = |m: usize, k: usize, n: usize| -> Option<String> {
            buckets
                .iter()
                .filter(|b| b.covers(m, k, n))
                .min_by_key(|b| b.volume())
                .map(|b| b.file.clone())
        };
        assert_eq!(rt_pick(60, 30, 60), Some("c".into()));
        assert_eq!(rt_pick(60, 60, 100), Some("b".into()));
        assert_eq!(rt_pick(100, 60, 100), Some("a".into()));
        assert_eq!(rt_pick(200, 10, 10), None);
    }

    #[test]
    fn covers_semantics() {
        let b = Bucket { m: 64, k: 32, n: 128, file: "x".into() };
        assert!(b.covers(64, 32, 128));
        assert!(b.covers(1, 1, 1));
        assert!(!b.covers(65, 32, 128));
    }
}
