//! PJRT runtime: load and execute the AOT-compiled qGEMM artifacts.
//!
//! This is the "bitstream" of the reproduction: `make artifacts`
//! lowers the Layer-1 Pallas kernel (via the Layer-2 JAX entry) to HLO
//! text once per shape bucket; this module compiles each bucket on the
//! PJRT CPU client at first use and executes it from the request path.
//! Python is never involved at runtime.
//!
//! Interchange is HLO *text* — xla_extension 0.5.1 rejects jax >= 0.5
//! serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md and aot_recipe).
//!
//! i8/i32 literals are built through
//! `Literal::create_from_shape_and_untyped_data` (the crate's typed
//! constructors only cover i32/i64/u32/u64/f32/f64).
//!
//! ## Layering
//!
//! The *bucket book-keeping* half of this module (manifest parsing,
//! [`Bucket`], [`smallest_covering`], the [`bucket_shape`] rounding
//! grid) is dependency-free and always compiled: the L3 serving
//! coordinator ([`crate::coordinator`]) shares it to group queued GEMM
//! tasks by AOT bucket so executable reuse amortizes across requests.
//! The *execution* half (`ArtifactRuntime`, not linked here because it
//! is compiled out of the default build) needs the vendored `xla`
//! crate (PJRT C API bindings over xla_extension 0.5.1) and is gated
//! behind the `pjrt` cargo feature; enable it only after re-adding
//! that dependency to `Cargo.toml` (see the manifest's comment).

use std::fmt;
use std::path::{Path, PathBuf};

/// Runtime error (std-only; the default build carries no anyhow).
#[derive(Debug)]
pub struct RuntimeError(
    /// Human-readable error message.
    pub String,
);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used throughout the artifact runtime.
pub type Result<T, E = RuntimeError> = std::result::Result<T, E>;

fn err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

/// One AOT shape bucket from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bucket {
    /// Padded M (weight rows) the executable was compiled for.
    pub m: usize,
    /// Padded K (reduction depth).
    pub k: usize,
    /// Padded N (im2col columns).
    pub n: usize,
    /// HLO text file of this bucket, relative to the artifacts dir.
    pub file: String,
}

impl Bucket {
    /// True when a logical GEMM `(m, k, n)` fits in this bucket (every
    /// axis no larger than the compiled shape).
    pub fn covers(&self, m: usize, k: usize, n: usize) -> bool {
        self.m >= m && self.k >= k && self.n >= n
    }

    /// Padded element count — the tie-breaker for bucket selection.
    pub fn volume(&self) -> u128 {
        self.m as u128 * self.k as u128 * self.n as u128
    }

    /// The bucket's identity as a key (what the coordinator's batcher
    /// groups on).
    pub fn key(&self) -> (usize, usize, usize) {
        (self.m, self.k, self.n)
    }
}

/// Round a logical GEMM `(m, k, n)` up to its AOT bucket shape — the
/// rust mirror of `python/compile/model.py::bucket_shape`: M and N
/// round to the Pallas/MXU tile grid (multiples of 32 below 128,
/// multiples of 128 above); K (the reduction) rounds to 32. Used as
/// the batching key when no artifact manifest is on disk.
///
/// # Examples
///
/// ```
/// use secda::runtime::bucket_shape;
///
/// // MobileNetV1's first conv GEMM rounds to the 32-grid
/// assert_eq!(bucket_shape(32, 27, 12544), (32, 32, 12544));
/// // at/above 128, M and N round to the 128-grid instead
/// assert_eq!(bucket_shape(129, 64, 200), (256, 64, 256));
/// // K always rounds to 32, independent of magnitude
/// assert_eq!(bucket_shape(1, 1, 1), (32, 32, 32));
/// ```
pub fn bucket_shape(m: usize, k: usize, n: usize) -> (usize, usize, usize) {
    fn round_up(v: usize, to: usize) -> usize {
        v.div_ceil(to) * to
    }
    let mb = if m < 128 { round_up(m, 32) } else { round_up(m, 128) };
    let nb = if n < 128 { round_up(n, 32) } else { round_up(n, 128) };
    let kb = round_up(k, 32);
    (mb, kb, nb)
}

/// Smallest bucket (by [`Bucket::volume`]) covering a logical GEMM
/// shape. Shared by `ArtifactRuntime::pick_bucket` (the `pjrt` execution
/// half) and the serving coordinator's batcher so both agree on
/// executable identity.
///
/// # Examples
///
/// ```
/// use secda::runtime::{smallest_covering, Bucket};
///
/// let buckets = vec![
///     Bucket { m: 128, k: 64, n: 128, file: "big.hlo".into() },
///     Bucket { m: 64, k: 32, n: 64, file: "small.hlo".into() },
/// ];
/// // both buckets cover (60, 30, 60); the smaller volume wins
/// let b = smallest_covering(&buckets, 60, 30, 60).unwrap();
/// assert_eq!(b.file, "small.hlo");
/// // nothing covers an oversized shape
/// assert!(smallest_covering(&buckets, 256, 32, 32).is_none());
/// ```
pub fn smallest_covering(buckets: &[Bucket], m: usize, k: usize, n: usize) -> Option<&Bucket> {
    buckets
        .iter()
        .filter(|b| b.covers(m, k, n))
        .min_by_key(|b| b.volume())
}

/// Error for a GEMM shape no AOT bucket covers — names the requested
/// shape so serving logs identify the offending layer immediately.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NoBucketError {
    /// Requested M (weight rows).
    pub m: usize,
    /// Requested K (reduction depth).
    pub k: usize,
    /// Requested N (im2col columns).
    pub n: usize,
}

impl fmt::Display for NoBucketError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "no AOT bucket covers GEMM ({},{},{})",
            self.m, self.k, self.n
        )
    }
}

impl std::error::Error for NoBucketError {}

/// [`smallest_covering`], or a [`NoBucketError`] naming the shape.
///
/// # Examples
///
/// ```
/// use secda::runtime::{require_covering, Bucket, NoBucketError};
///
/// let buckets = vec![Bucket { m: 64, k: 32, n: 64, file: "a.hlo".into() }];
/// assert_eq!(require_covering(&buckets, 60, 30, 60).unwrap().file, "a.hlo");
///
/// // the error names the uncovered shape for serving logs
/// let err = require_covering(&buckets, 4096, 27, 12544).unwrap_err();
/// assert_eq!(err, NoBucketError { m: 4096, k: 27, n: 12544 });
/// assert_eq!(err.to_string(), "no AOT bucket covers GEMM (4096,27,12544)");
/// ```
pub fn require_covering(
    buckets: &[Bucket],
    m: usize,
    k: usize,
    n: usize,
) -> Result<&Bucket, NoBucketError> {
    smallest_covering(buckets, m, k, n).ok_or(NoBucketError { m, k, n })
}

/// Default artifacts directory (repo-relative, overridable via env).
pub fn default_dir() -> PathBuf {
    std::env::var_os("SECDA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True when the artifacts directory looks usable.
pub fn available(dir: &Path) -> bool {
    dir.join("manifest.tsv").is_file()
}

/// Parse `manifest.tsv` (one bucket per line, `m\tk\tn\tfile`) into
/// the bucket table. Dependency-free so the coordinator can use the
/// bucket grid without a PJRT client.
pub fn load_manifest(dir: &Path) -> Result<Vec<Bucket>> {
    let manifest = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&manifest)
        .map_err(|e| err(format!("reading {manifest:?}; run `make artifacts` first: {e}")))?;
    let mut buckets = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let mut it = line.split('\t');
        let parse = |s: Option<&str>| -> Result<usize> {
            s.ok_or_else(|| err(format!("manifest.tsv line {}: missing field", lineno + 1)))?
                .parse::<usize>()
                .map_err(|e| err(format!("manifest.tsv line {}: {e}", lineno + 1)))
        };
        let m = parse(it.next())?;
        let k = parse(it.next())?;
        let n = parse(it.next())?;
        let file = it
            .next()
            .ok_or_else(|| err(format!("manifest.tsv line {}: missing file", lineno + 1)))?
            .to_string();
        buckets.push(Bucket { m, k, n, file });
    }
    if buckets.is_empty() {
        return Err(err(format!("empty manifest at {manifest:?}")));
    }
    Ok(buckets)
}

#[cfg(feature = "pjrt")]
mod artifact {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use super::{err, load_manifest, require_covering, smallest_covering, Bucket, Result};
    use crate::gemm::QGemmParams;

    /// The artifact runtime: manifest + lazily compiled executables.
    pub struct ArtifactRuntime {
        client: xla::PjRtClient,
        dir: PathBuf,
        pub buckets: Vec<Bucket>,
        cache: HashMap<(usize, usize, usize), xla::PjRtLoadedExecutable>,
    }

    impl ArtifactRuntime {
        /// Load the manifest and create the PJRT CPU client.
        pub fn new(dir: &Path) -> Result<Self> {
            let buckets = load_manifest(dir)?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| err(format!("PJRT cpu client: {e:?}")))?;
            Ok(ArtifactRuntime {
                client,
                dir: dir.to_path_buf(),
                buckets,
                cache: HashMap::new(),
            })
        }

        /// True when the artifacts directory looks usable.
        pub fn available(dir: &Path) -> bool {
            super::available(dir)
        }

        /// Smallest bucket covering a logical GEMM shape.
        pub fn pick_bucket(&self, m: usize, k: usize, n: usize) -> Option<&Bucket> {
            smallest_covering(&self.buckets, m, k, n)
        }

        fn executable(
            &mut self,
            key: (usize, usize, usize),
            file: &str,
        ) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.cache.contains_key(&key) {
                let path = self.dir.join(file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| err("non-utf8 path"))?,
                )
                .map_err(|e| err(format!("parsing {path:?}: {e:?}")))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| err(format!("compiling {file}: {e:?}")))?;
                self.cache.insert(key, exe);
            }
            Ok(&self.cache[&key])
        }

        /// Execute a quantized GEMM through the AOT artifact: pads into the
        /// bucket, runs on PJRT, and returns the valid `m x n` region.
        /// Bit-exact vs [`crate::gemm::qgemm`] (see tests/runtime_numerics).
        pub fn qgemm(
            &mut self,
            m: usize,
            k: usize,
            n: usize,
            w: &[i8],
            x: &[i8],
            params: &QGemmParams,
        ) -> Result<Vec<i8>> {
            assert_eq!(w.len(), m * k);
            assert_eq!(x.len(), k * n);
            let b = require_covering(&self.buckets, m, k, n)
                .map_err(|e| err(e.to_string()))?
                .clone();
            let (mb, kb, nb) = (b.m, b.k, b.n);

            // pad W rows with zeros (inert), X with anything (zero)
            let mut wp = vec![0i8; mb * kb];
            for i in 0..m {
                wp[i * kb..i * kb + k].copy_from_slice(&w[i * k..(i + 1) * k]);
            }
            let mut xp = vec![0i8; kb * nb];
            for r in 0..k {
                xp[r * nb..r * nb + n].copy_from_slice(&x[r * n..(r + 1) * n]);
            }
            let mut bias = vec![0i32; mb];
            bias[..m].copy_from_slice(&params.bias);
            let mut mult = vec![1 << 30; mb];
            mult[..m].copy_from_slice(&params.mult);
            let mut shift = vec![0i32; mb];
            shift[..m].copy_from_slice(&params.shift);
            let qp = [params.out_zp, params.act_min, params.act_max, 0i32];

            let lit_i8 = |data: &[i8], dims: &[usize]| -> Result<xla::Literal> {
                let bytes =
                    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
                xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S8, dims, bytes)
                    .map_err(|e| err(format!("i8 literal: {e:?}")))
            };
            let lit_i32 = |data: &[i32], dims: &[usize]| -> Result<xla::Literal> {
                let bytes = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
                    .map_err(|e| err(format!("i32 literal: {e:?}")))
            };

            let args = [
                lit_i8(&wp, &[mb, kb])?,
                lit_i8(&xp, &[kb, nb])?,
                lit_i32(&bias, &[mb])?,
                lit_i32(&mult, &[mb])?,
                lit_i32(&shift, &[mb])?,
                lit_i32(&qp, &[4])?,
            ];
            let exe = self.executable((mb, kb, nb), &b.file)?;
            let result = exe
                .execute::<xla::Literal>(&args)
                .map_err(|e| err(format!("executing bucket {:?}: {e:?}", (mb, kb, nb))))?[0][0]
                .to_literal_sync()
                .map_err(|e| err(format!("fetch result: {e:?}")))?;
            // lowered with return_tuple=True -> 1-tuple
            let out = result
                .to_tuple1()
                .map_err(|e| err(format!("untuple: {e:?}")))?;
            let flat: Vec<i8> = out
                .to_vec()
                .map_err(|e| err(format!("to_vec i8: {e:?}")))?;
            if flat.len() != mb * nb {
                return Err(err(format!(
                    "unexpected output size {} != {}",
                    flat.len(),
                    mb * nb
                )));
            }
            // crop the valid region
            let mut cropped = vec![0i8; m * n];
            for i in 0..m {
                cropped[i * n..(i + 1) * n].copy_from_slice(&flat[i * nb..i * nb + n]);
            }
            Ok(cropped)
        }

        /// Number of compiled executables (cache telemetry).
        pub fn compiled_count(&self) -> usize {
            self.cache.len()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use artifact::ArtifactRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Vec<Bucket> {
        vec![
            Bucket { m: 128, k: 64, n: 128, file: "a".into() },
            Bucket { m: 64, k: 64, n: 128, file: "b".into() },
            Bucket { m: 64, k: 32, n: 64, file: "c".into() },
        ]
    }

    #[test]
    fn bucket_picking_prefers_smallest() {
        // Pin the selection policy: among all covering buckets, the
        // one with the smallest volume() wins (not first-found, not
        // tightest-per-axis).
        let buckets = table();
        let pick = |m, k, n| smallest_covering(&buckets, m, k, n).map(|b| b.file.as_str());
        assert_eq!(pick(60, 30, 60), Some("c"));
        assert_eq!(pick(60, 60, 100), Some("b"));
        assert_eq!(pick(100, 60, 100), Some("a"));
        assert_eq!(pick(200, 10, 10), None);
        // exact-fit bucket beats any strictly larger cover
        assert_eq!(pick(64, 32, 64), Some("c"));
        // "b" covers this too, but c's volume (131072) < b's (524288)
        assert!(table()[2].volume() < table()[1].volume());
    }

    #[test]
    fn covers_semantics() {
        let b = Bucket { m: 64, k: 32, n: 128, file: "x".into() };
        assert!(b.covers(64, 32, 128));
        assert!(b.covers(1, 1, 1));
        assert!(!b.covers(65, 32, 128));
    }

    #[test]
    fn missing_bucket_error_names_shape() {
        let e = require_covering(&table(), 4096, 27, 12544).unwrap_err();
        assert_eq!(e, NoBucketError { m: 4096, k: 27, n: 12544 });
        assert_eq!(e.to_string(), "no AOT bucket covers GEMM (4096,27,12544)");
    }

    #[test]
    fn bucket_shape_mirrors_python_grid() {
        // below 128: multiples of 32; at/above 128: multiples of 128;
        // K always multiples of 32 (python/compile/model.py).
        assert_eq!(bucket_shape(1, 1, 1), (32, 32, 32));
        assert_eq!(bucket_shape(32, 27, 12544), (32, 32, 12544));
        assert_eq!(bucket_shape(100, 33, 100), (128, 64, 128));
        assert_eq!(bucket_shape(128, 64, 49), (128, 64, 64));
        assert_eq!(bucket_shape(129, 64, 200), (256, 64, 256));
        assert_eq!(bucket_shape(512, 4608, 49), (512, 4608, 64));
    }

    #[test]
    fn load_manifest_missing_dir_errors() {
        let e = load_manifest(Path::new("/nonexistent-secda-artifacts")).unwrap_err();
        assert!(e.to_string().contains("make artifacts"), "{e}");
    }
}
