//! CPU quantized GEMM — the gemmlowp analogue and the paper's CPU-only
//! baseline.
//!
//! In the paper, TFLite's convolutions execute through gemmlowp on the
//! two Cortex-A9 cores; SECDA's driver *intercepts* those GEMM calls
//! (Fig. 2) and offloads them. Here this module provides:
//!
//! * the functional int8 GEMM + PPU used by the CPU execution path and
//!   by the accelerator simulators' functional tile computation (so
//!   simulation stays bit-exact, as TLM promises), and
//! * a cache-blocked, multi-threaded implementation whose structure
//!   mirrors gemmlowp (pack → kernel → unpack/PPU), with the hot loop
//!   arch-dispatched through [`simd`] (AVX2/SSE2/NEON) and the scalar
//!   code kept as the pinned reference every tier is bit-equal to.
//!
//! The public entry points ([`accumulate_rows`], [`accumulate_block`],
//! [`ppu_rows`], [`qgemm`]) keep their scalar-era signatures and
//! semantics exactly; which kernel tier executes underneath is a pure
//! wall-clock concern (see the [`simd`] module doc for why the bits
//! cannot differ). The `*_scalar` variants are the frozen reference
//! implementations — property tests pin the dispatched paths against
//! them.
//!
//! Wall-clock on this x86 host is *not* the Table II number — the
//! Cortex-A9 timing model lives in [`crate::perf`]; this code is the
//! functional substrate (and its MAC counts feed the timing model).

pub mod simd;

use crate::framework::quant::ppu_requant;

/// Per-call quantized GEMM parameters (PPU inputs).
///
/// `bias` must already contain the activation zero-point fold
/// `bias[i] - x_zp * sum_k(w[i,k])` — the same driver contract the AOT
/// artifacts use (see python/compile/model.py).
#[derive(Debug, Clone)]
pub struct QGemmParams {
    /// Per-output-channel int32 bias (zero-point fold included).
    pub bias: Vec<i32>,
    /// Per-channel fixed-point requant multiplier (Q31).
    pub mult: Vec<i32>,
    /// Per-channel requant shift (negative = right shift).
    pub shift: Vec<i32>,
    /// Output zero point added after requantization.
    pub out_zp: i32,
    /// Activation clamp floor (e.g. 0 for ReLU) in output quanta.
    pub act_min: i32,
    /// Activation clamp ceiling (e.g. 6/scale for ReLU6).
    pub act_max: i32,
}

impl QGemmParams {
    /// Uniform per-tensor params broadcast over `m` output channels.
    pub fn uniform(m: usize, bias: i32, mult: i32, shift: i32) -> Self {
        QGemmParams {
            bias: vec![bias; m],
            mult: vec![mult; m],
            shift: vec![shift; m],
            out_zp: 0,
            act_min: -128,
            act_max: 127,
        }
    }
}

/// Fold the activation zero-point into the bias vector (driver step).
pub fn fold_bias(bias: &[i32], w: &[i8], m: usize, k: usize, x_zp: i32) -> Vec<i32> {
    assert_eq!(bias.len(), m);
    assert_eq!(w.len(), m * k);
    (0..m)
        .map(|i| {
            let rowsum: i64 = w[i * k..(i + 1) * k].iter().map(|&v| v as i64).sum();
            (bias[i] as i64 - x_zp as i64 * rowsum) as i32
        })
        .collect()
}

/// Below this MAC count packing overhead dominates the kernel win, so
/// dispatch stays on the scalar path. Any threshold is bit-safe (the
/// tiers agree bitwise); this only tunes where the crossover sits.
const SIMD_MIN_MACS: u64 = 2048;

/// True when a GEMM is degenerate or too small to be worth packing.
fn simd_too_small(rows: usize, k: usize, n: usize) -> bool {
    rows == 0 || k == 0 || n == 0 || mac_count(rows, k, n) < SIMD_MIN_MACS
}

/// Run the packed kernel and land logical columns `[0, n)` in `acc`
/// (the kernels write NR-padded rows; ragged N goes via a scratch).
fn accumulate_packed(
    t: simd::KernelTier,
    pa: &[i32],
    pb: &simd::PackedB,
    rows: usize,
    acc: &mut [i32],
) {
    let n = pb.n;
    let padded = pb.padded_n();
    if padded == n {
        acc.fill(0);
        simd::gemm_rows(t, pa, pb, rows, acc);
        return;
    }
    let mut tmp = vec![0i32; rows * padded];
    simd::gemm_rows(t, pa, pb, rows, &mut tmp);
    for r in 0..rows {
        acc[r * n..(r + 1) * n].copy_from_slice(&tmp[r * padded..r * padded + n]);
    }
}

/// Raw int32 accumulation for a row range `[m0, m1)`:
/// `acc[(i-m0)*n + j] = sum_k w[i*k + kk] * x[kk*n + j]`.
///
/// This is the shared functional core: CPU baseline, VM/SA simulators
/// and the VTA model all call it so every path produces identical bits.
/// Dispatches to the arch kernel tier when profitable; bit-equal to
/// [`accumulate_rows_scalar`] always.
pub fn accumulate_rows(
    w: &[i8],
    x: &[i8],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
    acc: &mut [i32],
) {
    assert!(m1 >= m0);
    assert_eq!(acc.len(), (m1 - m0) * n);
    assert!(w.len() >= m1 * k);
    assert_eq!(x.len(), k * n);
    let rows = m1 - m0;
    let t = simd::tier();
    if t == simd::KernelTier::Scalar || simd_too_small(rows, k, n) {
        return accumulate_rows_scalar(w, x, m0, m1, k, n, acc);
    }
    let pb = simd::pack_b(x, k, n, 0, n);
    let pa = simd::pack_a(w, m0, m1, k);
    accumulate_packed(t, &pa, &pb, rows, acc);
}

/// The scalar reference for [`accumulate_rows`] — frozen; the SIMD
/// tiers are property-tested bit-equal to this.
pub fn accumulate_rows_scalar(
    w: &[i8],
    x: &[i8],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
    acc: &mut [i32],
) {
    assert!(m1 >= m0);
    assert_eq!(acc.len(), (m1 - m0) * n);
    assert!(w.len() >= m1 * k);
    assert_eq!(x.len(), k * n);
    acc.fill(0);
    // i-k-j loop order: stream x rows sequentially (row-major K x N),
    // accumulate into the acc row — cache-friendly on both arrays.
    // §Perf note: 4-wide k-unrolling (two variants) was tried and
    // measured <5% (slightly negative) vs this form, which LLVM
    // already vectorizes — this is the practical roofline on one core
    // without explicit intrinsics (see EXPERIMENTS.md §Perf).
    for i in m0..m1 {
        let wrow = &w[i * k..(i + 1) * k];
        let arow = &mut acc[(i - m0) * n..(i - m0 + 1) * n];
        for (kk, &wv) in wrow.iter().enumerate() {
            if wv == 0 {
                continue; // zero weights (incl. bucket padding) are free
            }
            let wv = wv as i32;
            let xrow = &x[kk * n..(kk + 1) * n];
            for (a, &xv) in arow.iter_mut().zip(xrow) {
                *a += wv * xv as i32;
            }
        }
    }
}

/// Like [`accumulate_rows`] but over a column block `[n0, n1)` too:
/// `acc[(i-m0)*(n1-n0) + (j-n0)]`. Used by the VM simulator, whose
/// scheduler splits the N dimension across the four GEMM units.
/// Dispatches like [`accumulate_rows`]; bit-equal to
/// [`accumulate_block_scalar`] always.
// the argument list IS the tile coordinate system; a params struct
// would just rename the same nine values
#[allow(clippy::too_many_arguments)]
pub fn accumulate_block(
    w: &[i8],
    x: &[i8],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
    n0: usize,
    n1: usize,
    acc: &mut [i32],
) {
    assert!(m1 >= m0 && n1 >= n0 && n1 <= n);
    let bn = n1 - n0;
    assert_eq!(acc.len(), (m1 - m0) * bn);
    let rows = m1 - m0;
    let t = simd::tier();
    if t == simd::KernelTier::Scalar || simd_too_small(rows, k, bn) {
        return accumulate_block_scalar(w, x, m0, m1, k, n, n0, n1, acc);
    }
    let pb = simd::pack_b(x, k, n, n0, n1);
    let pa = simd::pack_a(w, m0, m1, k);
    accumulate_packed(t, &pa, &pb, rows, acc);
}

/// The scalar reference for [`accumulate_block`] — frozen.
#[allow(clippy::too_many_arguments)]
pub fn accumulate_block_scalar(
    w: &[i8],
    x: &[i8],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
    n0: usize,
    n1: usize,
    acc: &mut [i32],
) {
    assert!(m1 >= m0 && n1 >= n0 && n1 <= n);
    let bn = n1 - n0;
    assert_eq!(acc.len(), (m1 - m0) * bn);
    acc.fill(0);
    for i in m0..m1 {
        let wrow = &w[i * k..(i + 1) * k];
        let arow = &mut acc[(i - m0) * bn..(i - m0 + 1) * bn];
        for (kk, &wv) in wrow.iter().enumerate() {
            if wv == 0 {
                continue;
            }
            let wv = wv as i32;
            let xrow = &x[kk * n + n0..kk * n + n1];
            for (a, &xv) in arow.iter_mut().zip(xrow) {
                *a += wv * xv as i32;
            }
        }
    }
}

/// PPU over a row range of accumulators -> int8 outputs. Vectorized
/// per row when the tier supports it; bit-equal to [`ppu_rows_scalar`]
/// always.
pub fn ppu_rows(acc: &[i32], params: &QGemmParams, m0: usize, m1: usize, n: usize, out: &mut [i8]) {
    assert_eq!(acc.len(), (m1 - m0) * n);
    assert_eq!(out.len(), (m1 - m0) * n);
    let t = simd::tier();
    for i in m0..m1 {
        let arow = &acc[(i - m0) * n..(i - m0 + 1) * n];
        let orow = &mut out[(i - m0) * n..(i - m0 + 1) * n];
        simd::requant_row(
            t,
            arow,
            params.bias[i],
            params.mult[i],
            params.shift[i],
            params.out_zp,
            params.act_min,
            params.act_max,
            orow,
        );
    }
}

/// The scalar reference for [`ppu_rows`] — frozen.
pub fn ppu_rows_scalar(
    acc: &[i32],
    params: &QGemmParams,
    m0: usize,
    m1: usize,
    n: usize,
    out: &mut [i8],
) {
    assert_eq!(acc.len(), (m1 - m0) * n);
    assert_eq!(out.len(), (m1 - m0) * n);
    for i in m0..m1 {
        let (mult, shift, bias) = (params.mult[i], params.shift[i], params.bias[i]);
        let arow = &acc[(i - m0) * n..(i - m0 + 1) * n];
        let orow = &mut out[(i - m0) * n..(i - m0 + 1) * n];
        for (o, &a) in orow.iter_mut().zip(arow) {
            *o = ppu_requant(
                a.wrapping_add(bias),
                mult,
                shift,
                params.out_zp,
                params.act_min,
                params.act_max,
            );
        }
    }
}

/// One M-chunk of the SIMD qgemm path: pack the chunk's A rows, run
/// the kernel into an NR-padded scratch accumulator, requantize the
/// logical columns straight into the output slice.
#[allow(clippy::too_many_arguments)]
fn qgemm_simd_rows(
    t: simd::KernelTier,
    w: &[i8],
    pb: &simd::PackedB,
    m0: usize,
    m1: usize,
    k: usize,
    params: &QGemmParams,
    out: &mut [i8],
) {
    let rows = m1 - m0;
    let n = pb.n;
    let padded = pb.padded_n();
    let pa = simd::pack_a(w, m0, m1, k);
    let mut acc = vec![0i32; rows * padded];
    simd::gemm_rows(t, &pa, pb, rows, &mut acc);
    for r in 0..rows {
        let i = m0 + r;
        simd::requant_row(
            t,
            &acc[r * padded..r * padded + n],
            params.bias[i],
            params.mult[i],
            params.shift[i],
            params.out_zp,
            params.act_min,
            params.act_max,
            &mut out[r * n..(r + 1) * n],
        );
    }
}

/// Full quantized GEMM + PPU: `out[i8; m*n] = PPU(W[m,k] @ X[k,n])`.
///
/// `threads` models the paper's 1- or 2-thread CPU configurations; the
/// M dimension is split across threads exactly like gemmlowp's
/// workers-pool partitioning. On the SIMD path B is packed *once* and
/// shared read-only across the worker threads (the gemmlowp pack-once
/// structure); each chunk packs its own A rows. Results are bit-equal
/// to the scalar path for every tier and thread count.
pub fn qgemm(
    w: &[i8],
    x: &[i8],
    m: usize,
    k: usize,
    n: usize,
    params: &QGemmParams,
    threads: usize,
) -> Vec<i8> {
    assert_eq!(w.len(), m * k, "weight shape");
    assert_eq!(x.len(), k * n, "input shape");
    assert_eq!(params.bias.len(), m);
    assert_eq!(params.mult.len(), m);
    assert_eq!(params.shift.len(), m);
    let threads = threads.clamp(1, m.max(1));
    let t = simd::tier();
    if t == simd::KernelTier::Scalar || simd_too_small(m, k, n) {
        return qgemm_scalar(w, x, m, k, n, params, threads);
    }
    let pb = simd::pack_b(x, k, n, 0, n);
    let mut out = vec![0i8; m * n];
    if threads <= 1 || m < 2 {
        qgemm_simd_rows(t, w, &pb, 0, m, k, params, &mut out);
        return out;
    }
    let chunk = m.div_ceil(threads);
    let mut slices: Vec<&mut [i8]> = Vec::new();
    let mut rest = out.as_mut_slice();
    let mut starts = Vec::new();
    let mut i = 0;
    while i < m {
        let rows = chunk.min(m - i);
        let (head, tail) = rest.split_at_mut(rows * n);
        slices.push(head);
        starts.push((i, i + rows));
        rest = tail;
        i += rows;
    }
    let pbr = &pb;
    std::thread::scope(|s| {
        for (slice, &(m0, m1)) in slices.into_iter().zip(&starts) {
            s.spawn(move || {
                qgemm_simd_rows(t, w, pbr, m0, m1, k, params, slice);
            });
        }
    });
    out
}

/// The scalar qgemm path — frozen reference, also the execution path
/// whenever the scalar tier is forced (`SECDA_FORCE_SCALAR`).
fn qgemm_scalar(
    w: &[i8],
    x: &[i8],
    m: usize,
    k: usize,
    n: usize,
    params: &QGemmParams,
    threads: usize,
) -> Vec<i8> {
    let mut out = vec![0i8; m * n];
    if threads <= 1 || m < 2 {
        let mut acc = vec![0i32; m * n];
        accumulate_rows_scalar(w, x, 0, m, k, n, &mut acc);
        ppu_rows_scalar(&acc, params, 0, m, n, &mut out);
        return out;
    }
    // split M into `threads` contiguous chunks
    let chunk = m.div_ceil(threads);
    let mut slices: Vec<&mut [i8]> = Vec::new();
    let mut rest = out.as_mut_slice();
    let mut starts = Vec::new();
    let mut i = 0;
    while i < m {
        let rows = chunk.min(m - i);
        let (head, tail) = rest.split_at_mut(rows * n);
        slices.push(head);
        starts.push((i, i + rows));
        rest = tail;
        i += rows;
    }
    std::thread::scope(|s| {
        for (slice, &(m0, m1)) in slices.into_iter().zip(&starts) {
            s.spawn(move || {
                let mut acc = vec![0i32; (m1 - m0) * n];
                accumulate_rows_scalar(w, x, m0, m1, k, n, &mut acc);
                ppu_rows_scalar(&acc, params, m0, m1, n, slice);
            });
        }
    });
    out
}

/// MAC count of a logical GEMM (feeds the CPU timing model).
pub fn mac_count(m: usize, k: usize, n: usize) -> u64 {
    m as u64 * k as u64 * n as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::quant::quantize_multiplier;

    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    fn rand_i8(state: &mut u64, len: usize) -> Vec<i8> {
        (0..len).map(|_| (xorshift(state) & 0xff) as u8 as i8).collect()
    }

    fn naive(w: &[i8], x: &[i8], m: usize, k: usize, n: usize, p: &QGemmParams) -> Vec<i8> {
        let mut out = vec![0i8; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc: i64 = 0;
                for kk in 0..k {
                    acc += w[i * k + kk] as i64 * x[kk * n + j] as i64;
                }
                let acc = (acc as i32).wrapping_add(p.bias[i]);
                out[i * n + j] =
                    ppu_requant(acc, p.mult[i], p.shift[i], p.out_zp, p.act_min, p.act_max);
            }
        }
        out
    }

    #[test]
    fn matches_naive_small() {
        let (m, k, n) = (7, 13, 9);
        let mut st = 0x1234_5678_9abc_def0u64;
        let w = rand_i8(&mut st, m * k);
        let x = rand_i8(&mut st, k * n);
        let (mult, shift) = quantize_multiplier(0.37);
        let mut p = QGemmParams::uniform(m, 0, mult, shift);
        for i in 0..m {
            p.bias[i] = (xorshift(&mut st) % 1000) as i32 - 500;
        }
        assert_eq!(qgemm(&w, &x, m, k, n, &p, 1), naive(&w, &x, m, k, n, &p));
    }

    #[test]
    fn threads_do_not_change_result() {
        let (m, k, n) = (33, 21, 17);
        let mut st = 42u64;
        let w = rand_i8(&mut st, m * k);
        let x = rand_i8(&mut st, k * n);
        let (mult, shift) = quantize_multiplier(0.0123);
        let p = QGemmParams::uniform(m, 77, mult, shift);
        let a = qgemm(&w, &x, m, k, n, &p, 1);
        let b = qgemm(&w, &x, m, k, n, &p, 2);
        let c = qgemm(&w, &x, m, k, n, &p, 5);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn accumulate_rows_range() {
        let (m, k, n) = (8, 4, 6);
        let mut st = 7u64;
        let w = rand_i8(&mut st, m * k);
        let x = rand_i8(&mut st, k * n);
        let mut full = vec![0i32; m * n];
        accumulate_rows(&w, &x, 0, m, k, n, &mut full);
        let mut part = vec![0i32; 2 * n];
        accumulate_rows(&w, &x, 3, 5, k, n, &mut part);
        assert_eq!(&full[3 * n..5 * n], &part[..]);
    }

    #[test]
    fn dispatch_matches_scalar_reference() {
        // 15750 macs: above the SIMD gate, odd dims: all tail paths
        let (m, k, n) = (9, 35, 50);
        let mut st = 0xabcdu64;
        let w = rand_i8(&mut st, m * k);
        let x = rand_i8(&mut st, k * n);
        let mut a = vec![0i32; m * n];
        let mut b = vec![0i32; m * n];
        accumulate_rows(&w, &x, 0, m, k, n, &mut a);
        accumulate_rows_scalar(&w, &x, 0, m, k, n, &mut b);
        assert_eq!(a, b);
        let (n0, n1) = (3, 41);
        let mut ba = vec![0i32; m * (n1 - n0)];
        let mut bb = vec![0i32; m * (n1 - n0)];
        accumulate_block(&w, &x, 0, m, k, n, n0, n1, &mut ba);
        accumulate_block_scalar(&w, &x, 0, m, k, n, n0, n1, &mut bb);
        assert_eq!(ba, bb);
        let (mult, shift) = quantize_multiplier(0.37);
        let p = QGemmParams::uniform(m, 5, mult, shift);
        let mut oa = vec![0i8; m * n];
        let mut ob = vec![0i8; m * n];
        ppu_rows(&a, &p, 0, m, n, &mut oa);
        ppu_rows_scalar(&b, &p, 0, m, n, &mut ob);
        assert_eq!(oa, ob);
    }

    #[test]
    fn fold_bias_matches_definition() {
        let w: Vec<i8> = vec![1, 2, 3, -4];
        let folded = fold_bias(&[10, 20], &w, 2, 2, 5);
        assert_eq!(folded, vec![10 - 5 * 3, 20 - 5 * -1]);
    }

    #[test]
    fn zero_weight_shortcut_is_sound() {
        // padding rows of zeros must accumulate exactly zero
        let (m, k, n) = (2, 3, 4);
        let w = vec![0i8; m * k];
        let mut st = 9u64;
        let x = rand_i8(&mut st, k * n);
        let mut acc = vec![123i32; m * n];
        accumulate_rows(&w, &x, 0, m, k, n, &mut acc);
        assert!(acc.iter().all(|&v| v == 0));
    }

    #[test]
    fn relu6_window() {
        let (m, k, n) = (4, 8, 4);
        let mut st = 11u64;
        let w = rand_i8(&mut st, m * k);
        let x = rand_i8(&mut st, k * n);
        let (mult, shift) = quantize_multiplier(0.5);
        let mut p = QGemmParams::uniform(m, 0, mult, shift);
        p.act_min = 0;
        p.act_max = 6;
        let out = qgemm(&w, &x, m, k, n, &p, 1);
        assert!(out.iter().all(|&v| (0..=6).contains(&v)));
        assert_eq!(out, naive(&w, &x, m, k, n, &p));
    }

    #[test]
    fn mac_count_is_product() {
        assert_eq!(mac_count(32, 27, 12544), 32 * 27 * 12544);
    }
}
