//! x86_64 microkernels: the AVX2 register-blocked pair-madd GEMM and
//! the exact vectorized requantization, plus the SSE2 baseline GEMM
//! (SSE2 is part of the x86_64 ABI, so that tier needs no runtime
//! detection).
//!
//! Bit-exactness: `pmaddwd` computes `a[2c]*b[2c] + a[2c+1]*b[2c+1]`
//! in i32 lanes — for i8-ranged inputs each product is at most
//! 127*127, so the pair sum can never hit the instruction's lone
//! saturation case (both products 0x4000_0000), and the surrounding
//! `paddd` accumulation wraps exactly like the scalar reference's
//! wrapping i32 adds. The requant kernel reproduces gemmlowp's
//! `SaturatingRoundingDoublingHighMul` + `RoundingDivideByPOT`
//! including the truncating-division and ties-away rounding corners;
//! the dispatcher routes the rare parameter corners the vector form
//! does not model (`mult == i32::MIN`, `|shift| > 31`) to the scalar
//! path.

use super::pack::{PackedB, NR};
use std::arch::x86_64::*;

/// Rows per AVX2 register block: 6 rows x 2 panels of accumulators
/// (12 ymm) + 2 B panels + 1 broadcast leaves the 16-register file
/// full but not spilling.
const MR_AVX2: usize = 6;

/// One AVX2 row-block over all panels.
///
/// # Safety
/// Caller must ensure AVX2 is available, `pa` holds at least
/// `(r0 + MR) * k_pairs` pairs, and `acc` is `rows * padded_n` long.
#[target_feature(enable = "avx2")]
unsafe fn block_avx2<const MR: usize>(pa: &[i32], pb: &PackedB, r0: usize, acc: &mut [i32]) {
    let kp = pb.k_pairs;
    let padded = pb.padded_n();
    let mut q = 0;
    while q < pb.n_panels {
        let two = q + 1 < pb.n_panels;
        let mut acc0 = [_mm256_setzero_si256(); MR];
        let mut acc1 = [_mm256_setzero_si256(); MR];
        let p0 = pb.data.as_ptr().add(q * kp * 2 * NR);
        let p1 = if two {
            pb.data.as_ptr().add((q + 1) * kp * 2 * NR)
        } else {
            p0
        };
        for p in 0..kp {
            let b0 = _mm256_loadu_si256(p0.add(p * 2 * NR) as *const __m256i);
            let b1 = _mm256_loadu_si256(p1.add(p * 2 * NR) as *const __m256i);
            for rr in 0..MR {
                let a = _mm256_set1_epi32(*pa.get_unchecked((r0 + rr) * kp + p));
                acc0[rr] = _mm256_add_epi32(acc0[rr], _mm256_madd_epi16(a, b0));
                if two {
                    acc1[rr] = _mm256_add_epi32(acc1[rr], _mm256_madd_epi16(a, b1));
                }
            }
        }
        for rr in 0..MR {
            let dst = acc.as_mut_ptr().add((r0 + rr) * padded + q * NR);
            _mm256_storeu_si256(dst as *mut __m256i, acc0[rr]);
            if two {
                _mm256_storeu_si256(dst.add(NR) as *mut __m256i, acc1[rr]);
            }
        }
        q += if two { 2 } else { 1 };
    }
}

/// AVX2 GEMM over packed operands: writes the full padded accumulator
/// rows `[0, rows)`, bit-equal to [`super::pack::kernel_rows_portable`].
///
/// # Safety
/// Caller must ensure AVX2 is available; slice shapes as in the
/// portable kernel.
#[target_feature(enable = "avx2")]
pub unsafe fn gemm_rows_avx2(pa: &[i32], pb: &PackedB, rows: usize, acc: &mut [i32]) {
    assert!(pa.len() >= rows * pb.k_pairs);
    assert_eq!(acc.len(), rows * pb.padded_n());
    let mut r = 0;
    while r + MR_AVX2 <= rows {
        block_avx2::<MR_AVX2>(pa, pb, r, acc);
        r += MR_AVX2;
    }
    while r < rows {
        block_avx2::<1>(pa, pb, r, acc);
        r += 1;
    }
}

/// Rows per SSE2 register block: 4 rows x 2 half-panels (8 xmm) + 2 B
/// halves + 1 broadcast.
const MR_SSE2: usize = 4;

/// One SSE2 row-block over all panels (each panel is two xmm of 4
/// columns).
///
/// # Safety
/// Slice shapes as in [`block_avx2`]; SSE2 is ABI-guaranteed on
/// x86_64.
#[target_feature(enable = "sse2")]
unsafe fn block_sse2<const MR: usize>(pa: &[i32], pb: &PackedB, r0: usize, acc: &mut [i32]) {
    let kp = pb.k_pairs;
    let padded = pb.padded_n();
    for q in 0..pb.n_panels {
        let mut acc_lo = [_mm_setzero_si128(); MR];
        let mut acc_hi = [_mm_setzero_si128(); MR];
        let panel = pb.data.as_ptr().add(q * kp * 2 * NR);
        for p in 0..kp {
            let b_lo = _mm_loadu_si128(panel.add(p * 2 * NR) as *const __m128i);
            let b_hi = _mm_loadu_si128(panel.add(p * 2 * NR + NR) as *const __m128i);
            for rr in 0..MR {
                let a = _mm_set1_epi32(*pa.get_unchecked((r0 + rr) * kp + p));
                acc_lo[rr] = _mm_add_epi32(acc_lo[rr], _mm_madd_epi16(a, b_lo));
                acc_hi[rr] = _mm_add_epi32(acc_hi[rr], _mm_madd_epi16(a, b_hi));
            }
        }
        for rr in 0..MR {
            let dst = acc.as_mut_ptr().add((r0 + rr) * padded + q * NR);
            _mm_storeu_si128(dst as *mut __m128i, acc_lo[rr]);
            _mm_storeu_si128(dst.add(NR / 2) as *mut __m128i, acc_hi[rr]);
        }
    }
}

/// SSE2 GEMM over packed operands, bit-equal to the portable kernel.
///
/// # Safety
/// Slice shapes as in the portable kernel; SSE2 is ABI-guaranteed on
/// x86_64.
#[target_feature(enable = "sse2")]
pub unsafe fn gemm_rows_sse2(pa: &[i32], pb: &PackedB, rows: usize, acc: &mut [i32]) {
    assert!(pa.len() >= rows * pb.k_pairs);
    assert_eq!(acc.len(), rows * pb.padded_n());
    let mut r = 0;
    while r + MR_SSE2 <= rows {
        block_sse2::<MR_SSE2>(pa, pb, r, acc);
        r += MR_SSE2;
    }
    while r < rows {
        block_sse2::<1>(pa, pb, r, acc);
        r += 1;
    }
}

/// Broadcast constants of one requant pipeline invocation (per-row
/// parameters splatted once, reused across vector steps).
struct RequantConsts {
    left: __m128i,
    right: __m128i,
    biasv: __m256i,
    multv: __m256i,
    mult_odd: __m256i,
    rmask: __m256i,
    rthr: __m256i,
    zpv: __m256i,
    minv: __m256i,
    maxv: __m256i,
}

/// One 8-lane step of the whole PPU pipeline (bias add, shift, SRDHM,
/// rounding divide, zero-point, clamp). Kept a standalone
/// `#[target_feature]` fn (not a closure) so the AVX2 codegen feature
/// provably applies on every supported toolchain.
///
/// # Safety
/// Caller must ensure AVX2 is available.
#[target_feature(enable = "avx2")]
unsafe fn requant8_avx2(v: __m256i, c: &RequantConsts) -> __m256i {
    let zero = _mm256_setzero_si256();
    let nudge = _mm256_set1_epi64x(1 << 30);
    let nudge_neg = _mm256_set1_epi64x(1 - (1i64 << 31));
    let trunc_fix = _mm256_set1_epi64x((1i64 << 31) - 1);
    let low32 = _mm256_set1_epi64x(0xFFFF_FFFF);
    let v = _mm256_add_epi32(v, c.biasv);
    let s = _mm256_sll_epi32(v, c.left);
    // SRDHM in 64-bit lanes: even i32 lanes sit in the low halves
    // already; odd lanes are shifted down (pmuldq reads only the low
    // 32 bits of each 64-bit lane, sign-extending).
    let s_odd = _mm256_srli_epi64::<32>(s);
    let pe = _mm256_mul_epi32(s, c.multv);
    let po = _mm256_mul_epi32(s_odd, c.mult_odd);
    let ne = _mm256_add_epi64(
        nudge,
        _mm256_and_si256(_mm256_cmpgt_epi64(zero, pe), nudge_neg),
    );
    let no = _mm256_add_epi64(
        nudge,
        _mm256_and_si256(_mm256_cmpgt_epi64(zero, po), nudge_neg),
    );
    let te = _mm256_add_epi64(pe, ne);
    let to = _mm256_add_epi64(po, no);
    let fe = _mm256_add_epi64(
        te,
        _mm256_and_si256(_mm256_cmpgt_epi64(zero, te), trunc_fix),
    );
    let fo = _mm256_add_epi64(
        to,
        _mm256_and_si256(_mm256_cmpgt_epi64(zero, to), trunc_fix),
    );
    let qe = _mm256_srli_epi64::<31>(fe);
    let qo = _mm256_srli_epi64::<31>(fo);
    let q = _mm256_or_si256(_mm256_and_si256(qe, low32), _mm256_slli_epi64::<32>(qo));
    // RoundingDivideByPOT in 32-bit lanes.
    let rem = _mm256_and_si256(q, c.rmask);
    let thr = _mm256_sub_epi32(c.rthr, _mm256_cmpgt_epi32(zero, q));
    let sh = _mm256_sra_epi32(q, c.right);
    let rd = _mm256_sub_epi32(sh, _mm256_cmpgt_epi32(rem, thr));
    let o = _mm256_add_epi32(rd, c.zpv);
    _mm256_min_epi32(_mm256_max_epi32(o, c.minv), c.maxv)
}

/// Vectorized gemmlowp requant of one accumulator row — bit-exact to
/// `ppu_requant(acc[j].wrapping_add(bias), mult, shift, ...)` per
/// element.
///
/// The Q31 `SaturatingRoundingDoublingHighMul` runs in 64-bit lanes
/// (even/odd split via `pmuldq`), with the two rounding corners the
/// scalar code hides in plain arithmetic made explicit:
/// * the nudge is `2^30` for non-negative products and `1 - 2^30` for
///   negative ones (ties away from zero), selected by a 64-bit mask;
/// * the divide by `2^31` is *truncating* (toward zero), recovered
///   from a logical shift by pre-adding `2^31 - 1` to negative values
///   — only the low 32 bits of each 64-bit quotient are kept, which
///   is exactly the scalar `as i32` narrowing.
///
/// `RoundingDivideByPOT` then runs in 32-bit lanes: remainder mask,
/// threshold bump for negative inputs, arithmetic shift, and a +1
/// where the remainder exceeds the threshold.
///
/// # Safety
/// Caller must ensure AVX2 is available, `out.len() == acc.len()`,
/// `mult != i32::MIN` and `-31 <= shift <= 31` (the dispatcher guards
/// all three; outside them the scalar path is the definition).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub unsafe fn requant_row_avx2(
    acc: &[i32],
    bias: i32,
    mult: i32,
    shift: i32,
    out_zp: i32,
    act_min: i32,
    act_max: i32,
    out: &mut [i8],
) {
    assert_eq!(acc.len(), out.len());
    let right = (-shift).max(0);
    let multv = _mm256_set1_epi32(mult);
    let consts = RequantConsts {
        left: _mm_cvtsi32_si128(shift.max(0)),
        right: _mm_cvtsi32_si128(right),
        biasv: _mm256_set1_epi32(bias),
        multv,
        mult_odd: _mm256_srli_epi64::<32>(multv),
        rmask: _mm256_set1_epi32(((1i64 << right) - 1) as i32),
        rthr: _mm256_set1_epi32((((1i64 << right) - 1) >> 1) as i32),
        zpv: _mm256_set1_epi32(out_zp),
        minv: _mm256_set1_epi32(act_min),
        maxv: _mm256_set1_epi32(act_max),
    };

    let n = acc.len();
    let mut buf = [0i32; 8];
    let mut j = 0;
    while j + 8 <= n {
        let v = _mm256_loadu_si256(acc.as_ptr().add(j) as *const __m256i);
        let r = requant8_avx2(v, &consts);
        _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, r);
        for (c, &b) in buf.iter().enumerate() {
            *out.get_unchecked_mut(j + c) = b as i8;
        }
        j += 8;
    }
    if j < n {
        let mut tin = [0i32; 8];
        tin[..n - j].copy_from_slice(&acc[j..]);
        let r = requant8_avx2(_mm256_loadu_si256(tin.as_ptr() as *const __m256i), &consts);
        _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, r);
        for c in 0..(n - j) {
            out[j + c] = buf[c] as i8;
        }
    }
}
