//! Arch-dispatched int8 GEMM microkernels (pack → register-blocked
//! kernel → unpack), bit-exact to the scalar reference.
//!
//! Dispatch is a runtime decision, not a compile-time one: on x86_64
//! the [`tier`] probe uses `is_x86_feature_detected!` to pick AVX2
//! over the ABI-baseline SSE2, aarch64 always has NEON, and every
//! other target (or a forced override, see [`set_force_scalar`]) runs
//! the portable kernel. All tiers produce identical bits — the
//! kernels only re-block and re-order *wrapping* i32 accumulation,
//! which is associative and commutative — so which tier executed is
//! unobservable in outputs; only wall-clock changes. That invariant
//! is pinned by `prop_simd_matches_scalar` and the scalar-forced
//! exec-mode test, and is what lets the serving pool, the simulators'
//! functional tiles and the per-GEMM cross-check all share one
//! functional substrate regardless of host.
//!
//! `SECDA_FORCE_SCALAR=1` in the environment (read once, first use)
//! forces the scalar tier process-wide — CI runs the whole test suite
//! once under it so both dispatch arms stay green.

mod pack;
#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

pub use pack::{kernel_rows_portable, pack_a, pack_b, PackedB, NR};

use crate::framework::quant::ppu_requant;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

/// Which kernel family executes on this host. Every tier is bit-exact
/// to [`KernelTier::Scalar`]; the tier only changes wall-clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelTier {
    /// Portable scalar/auto-vectorized reference path.
    Scalar,
    /// x86_64 baseline 128-bit `pmaddwd` kernel (ABI-guaranteed).
    Sse2,
    /// x86_64 256-bit kernel + vectorized requant (runtime-detected).
    Avx2,
    /// aarch64 kernel (NEON is mandatory on aarch64).
    Neon,
}

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);
static ENV_READ: Once = Once::new();

fn env_init() {
    ENV_READ.call_once(|| {
        let v = std::env::var_os("SECDA_FORCE_SCALAR");
        if v.is_some_and(|v| !v.is_empty() && v != "0") {
            FORCE_SCALAR.store(true, Ordering::Relaxed);
        }
    });
}

/// Force (or un-force) the scalar tier process-wide. Overrides the
/// `SECDA_FORCE_SCALAR` environment variable; used by benches to
/// measure scalar-vs-SIMD and by tests to pin dispatch-independence.
pub fn set_force_scalar(v: bool) {
    env_init();
    FORCE_SCALAR.store(v, Ordering::Relaxed);
}

/// Whether the scalar tier is currently forced (environment variable
/// or [`set_force_scalar`]).
pub fn force_scalar() -> bool {
    env_init();
    FORCE_SCALAR.load(Ordering::Relaxed)
}

/// The kernel tier dispatch resolves to on this host, right now.
pub fn tier() -> KernelTier {
    if force_scalar() {
        return KernelTier::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return KernelTier::Avx2;
        }
        return KernelTier::Sse2;
    }
    #[cfg(target_arch = "aarch64")]
    {
        return KernelTier::Neon;
    }
    #[allow(unreachable_code)]
    KernelTier::Scalar
}

/// Run the packed GEMM kernel for `tier` over rows `[0, rows)`.
///
/// `acc` must be zero-initialized and exactly `rows * pb.padded_n()`
/// long; logical column `j` of row `r` lands at `r * padded_n() + j`
/// (padded columns hold zero). All tiers produce identical bits.
pub fn gemm_rows(t: KernelTier, pa: &[i32], pb: &PackedB, rows: usize, acc: &mut [i32]) {
    match t {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: tier() only returns Avx2 after runtime detection;
        // SSE2 is part of the x86_64 ABI.
        KernelTier::Avx2 => unsafe { x86::gemm_rows_avx2(pa, pb, rows, acc) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is ABI-guaranteed on x86_64.
        KernelTier::Sse2 => unsafe { x86::gemm_rows_sse2(pa, pb, rows, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is ABI-guaranteed on aarch64.
        KernelTier::Neon => unsafe { neon::gemm_rows_neon(pa, pb, rows, acc) },
        _ => kernel_rows_portable(pa, pb, rows, acc),
    }
}

/// Requantize one accumulator row: for each `j`,
/// `out[j] = ppu_requant(acc[j].wrapping_add(bias), mult, shift,
/// out_zp, act_min, act_max)` — vectorized when the tier supports it
/// and the parameters avoid the scalar definition's corner cases
/// (`mult == i32::MIN`, `|shift| > 31`), scalar otherwise. Bit-exact
/// either way.
// the argument list IS the PPU parameter set, same shape as
// ppu_requant itself
#[allow(clippy::too_many_arguments)]
pub fn requant_row(
    t: KernelTier,
    acc: &[i32],
    bias: i32,
    mult: i32,
    shift: i32,
    out_zp: i32,
    act_min: i32,
    act_max: i32,
    out: &mut [i8],
) {
    match t {
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 if mult != i32::MIN && (-31..=31).contains(&shift) => unsafe {
            // SAFETY: tier() only returns Avx2 after runtime
            // detection; the guard upholds the kernel's parameter
            // contract and slice lengths are asserted inside.
            x86::requant_row_avx2(acc, bias, mult, shift, out_zp, act_min, act_max, out)
        },
        _ => requant_row_scalar(acc, bias, mult, shift, out_zp, act_min, act_max, out),
    }
}

/// The scalar requant row — the pinned definition [`requant_row`]
/// must match bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn requant_row_scalar(
    acc: &[i32],
    bias: i32,
    mult: i32,
    shift: i32,
    out_zp: i32,
    act_min: i32,
    act_max: i32,
    out: &mut [i8],
) {
    assert_eq!(acc.len(), out.len());
    for (o, &a) in out.iter_mut().zip(acc) {
        *o = ppu_requant(a.wrapping_add(bias), mult, shift, out_zp, act_min, act_max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::quant::quantize_multiplier;

    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    #[test]
    fn every_available_tier_matches_portable() {
        let (m, k, n) = (13, 31, 27); // odd everything: all tail paths
        let mut st = 0xc0ffeeu64;
        let w: Vec<i8> = (0..m * k).map(|_| (xorshift(&mut st) & 0xff) as u8 as i8).collect();
        let x: Vec<i8> = (0..k * n).map(|_| (xorshift(&mut st) & 0xff) as u8 as i8).collect();
        let pb = pack_b(&x, k, n, 0, n);
        let pa = pack_a(&w, 0, m, k);
        let mut reference = vec![0i32; m * pb.padded_n()];
        kernel_rows_portable(&pa, &pb, m, &mut reference);
        let mut tiers = vec![KernelTier::Scalar];
        #[cfg(target_arch = "x86_64")]
        {
            tiers.push(KernelTier::Sse2);
            if std::arch::is_x86_feature_detected!("avx2") {
                tiers.push(KernelTier::Avx2);
            }
        }
        #[cfg(target_arch = "aarch64")]
        tiers.push(KernelTier::Neon);
        for t in tiers {
            let mut acc = vec![0i32; m * pb.padded_n()];
            gemm_rows(t, &pa, &pb, m, &mut acc);
            assert_eq!(acc, reference, "tier {t:?}");
        }
    }

    #[test]
    fn requant_dispatch_matches_scalar_including_corners() {
        let mut st = 0x5eedu64;
        let acc: Vec<i32> = (0..261)
            .map(|_| (xorshift(&mut st) & 0xffffff) as i32 - (1 << 23))
            .collect();
        let t = tier();
        // realistic multipliers plus the guarded corner cases
        let mut cases: Vec<(i32, i32)> = [0.75, 0.02, 1.9, 1e-4]
            .iter()
            .map(|&r| quantize_multiplier(r))
            .collect();
        cases.push((i32::MIN, 0)); // must fall back to scalar
        cases.push((1 << 30, 0));
        for (mult, shift) in cases {
            for (zp, lo, hi) in [(0, -128, 127), (3, 0, 6), (-128, -128, 127)] {
                let mut a = vec![0i8; acc.len()];
                let mut b = vec![0i8; acc.len()];
                requant_row(t, &acc, 17, mult, shift, zp, lo, hi, &mut a);
                requant_row_scalar(&acc, 17, mult, shift, zp, lo, hi, &mut b);
                assert_eq!(a, b, "mult={mult} shift={shift} zp={zp}");
            }
        }
    }

    #[test]
    fn forced_scalar_wins_over_detection() {
        set_force_scalar(true);
        assert_eq!(tier(), KernelTier::Scalar);
        set_force_scalar(false);
    }
}
