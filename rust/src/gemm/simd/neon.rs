//! aarch64 NEON microkernel consuming the same pair-interleaved
//! packed layout as the x86 tiers.
//!
//! NEON has no direct `pmaddwd` analogue, so the pair-madd is built
//! from a widening 16-bit multiply (`vmull_s16`) against the
//! broadcast `[w0, w1, w0, w1]` pair followed by a pairwise i32 add
//! (`vpaddq_s32`), which sums each column's two products — the same
//! i32 products and wrapping accumulation as the portable kernel, so
//! bits are identical. NEON is a mandatory aarch64 target feature;
//! this file is kept honest by the `cargo check
//! --target aarch64-unknown-linux-gnu` CI job.

use super::pack::{PackedB, NR};
use std::arch::aarch64::*;

/// NEON GEMM over packed operands: writes the full padded accumulator
/// rows `[0, rows)`, bit-equal to [`super::pack::kernel_rows_portable`].
///
/// # Safety
/// Slice shapes as in the portable kernel (`pa` at least
/// `rows * k_pairs` pairs, `acc` exactly `rows * padded_n()` long).
/// NEON itself is ABI-mandatory on aarch64.
pub unsafe fn gemm_rows_neon(pa: &[i32], pb: &PackedB, rows: usize, acc: &mut [i32]) {
    assert!(pa.len() >= rows * pb.k_pairs);
    assert_eq!(acc.len(), rows * pb.padded_n());
    let kp = pb.k_pairs;
    let padded = pb.padded_n();
    for r in 0..rows {
        for q in 0..pb.n_panels {
            let panel = pb.data.as_ptr().add(q * kp * 2 * NR);
            let mut acc_lo = vdupq_n_s32(0); // panel columns 0..4
            let mut acc_hi = vdupq_n_s32(0); // panel columns 4..8
            for p in 0..kp {
                // [w0, w1, w0, w1] — low/high i16 halves of the fused pair
                let a = vreinterpret_s16_s32(vdup_n_s32(*pa.get_unchecked(r * kp + p)));
                let b_lo = vld1q_s16(panel.add(p * 2 * NR));
                let b_hi = vld1q_s16(panel.add(p * 2 * NR + NR));
                // products per column pair, then pairwise-summed into
                // one i32 per column
                let p0 = vmull_s16(vget_low_s16(b_lo), a);
                let p1 = vmull_s16(vget_high_s16(b_lo), a);
                acc_lo = vaddq_s32(acc_lo, vpaddq_s32(p0, p1));
                let p2 = vmull_s16(vget_low_s16(b_hi), a);
                let p3 = vmull_s16(vget_high_s16(b_hi), a);
                acc_hi = vaddq_s32(acc_hi, vpaddq_s32(p2, p3));
            }
            let dst = acc.as_mut_ptr().add(r * padded + q * NR);
            vst1q_s32(dst, acc_lo);
            vst1q_s32(dst.add(NR / 2), acc_hi);
        }
    }
}
