//! Portable packing for the SIMD microkernels.
//!
//! Every vector tier consumes the same layout, chosen for the one
//! instruction shape they all share: a widening 16-bit pair
//! multiply-accumulate (`pmaddwd` on x86, widening multiply plus
//! pairwise add on NEON). The K dimension is walked two rows at a
//! time, so B is packed as NR-column panels whose entries interleave
//! each column's K-pair `[b[2p][j], b[2p+1][j]]` as adjacent i16
//! lanes, and A rows fuse each weight K-pair into one i32 that the
//! kernels broadcast across lanes.
//!
//! Padding is exact by construction: a missing odd-K row is stored as
//! a zero *input* lane (its product contributes exactly 0 to the
//! wrapping i32 accumulator), and panel columns beyond `n` are zero
//! columns whose results land in padded accumulator space the unpack
//! step never reads.

/// Columns per packed B panel — fixed across tiers so one packed
/// buffer feeds every kernel (AVX2 consumes one panel per 256-bit
/// `pmaddwd`, SSE2 and NEON half a panel per vector op).
pub const NR: usize = 8;

/// The `k x n` im2col matrix packed into K-pair-interleaved column
/// panels (see the module doc for the layout rationale).
pub struct PackedB {
    /// Logical (unpadded) column count of the packed window.
    pub n: usize,
    /// Number of NR-wide column panels (`ceil(n / NR)`).
    pub n_panels: usize,
    /// Number of K pairs (`ceil(k / 2)`); odd K is padded with a zero
    /// row.
    pub k_pairs: usize,
    /// Panel-major data: panel `q`, pair `p` starts at
    /// `(q * k_pairs + p) * 2 * NR` and holds, for each panel column
    /// `c`, the adjacent lanes `[b[2p][c], b[2p+1][c]]` widened to
    /// i16.
    pub data: Vec<i16>,
}

impl PackedB {
    /// Accumulator row length the kernels write: every panel stores
    /// its full NR columns, so rows are padded to `n_panels * NR`.
    pub fn padded_n(&self) -> usize {
        self.n_panels * NR
    }
}

/// Pack the column window `[n0, n1)` of the row-major `k x n_stride`
/// matrix `x` (the im2col activations) for the pair-madd kernels.
pub fn pack_b(x: &[i8], k: usize, n_stride: usize, n0: usize, n1: usize) -> PackedB {
    assert!(n1 >= n0 && n1 <= n_stride);
    assert!(x.len() >= k * n_stride);
    let cols = n1 - n0;
    let n_panels = cols.div_ceil(NR);
    let k_pairs = k.div_ceil(2);
    let mut data = vec![0i16; n_panels * k_pairs * 2 * NR];
    for q in 0..n_panels {
        let c0 = q * NR;
        let width = NR.min(cols - c0);
        for p in 0..k_pairs {
            let base = (q * k_pairs + p) * 2 * NR;
            let r0 = 2 * p;
            let r1 = 2 * p + 1;
            for c in 0..width {
                let j = n0 + c0 + c;
                data[base + 2 * c] = x[r0 * n_stride + j] as i16;
                if r1 < k {
                    data[base + 2 * c + 1] = x[r1 * n_stride + j] as i16;
                }
            }
        }
    }
    PackedB {
        n: cols,
        n_panels,
        k_pairs,
        data,
    }
}

/// Pack W rows `[m0, m1)`: each K-pair of a row is widened to i16 and
/// fused into one i32 (low half = even-K element, matching the lane
/// order [`pack_b`] stores), ready for broadcast. Row `i`'s pairs
/// start at `(i - m0) * ceil(k / 2)`.
pub fn pack_a(w: &[i8], m0: usize, m1: usize, k: usize) -> Vec<i32> {
    assert!(m1 >= m0);
    assert!(w.len() >= m1 * k);
    let k_pairs = k.div_ceil(2);
    let mut out = vec![0i32; (m1 - m0) * k_pairs];
    for i in m0..m1 {
        let row = &w[i * k..(i + 1) * k];
        let dst = &mut out[(i - m0) * k_pairs..(i - m0 + 1) * k_pairs];
        for (p, d) in dst.iter_mut().enumerate() {
            let w0 = row[2 * p] as i16 as u16 as u32;
            let w1 = if 2 * p + 1 < k {
                row[2 * p + 1] as i16 as u16 as u32
            } else {
                0
            };
            *d = (w0 | (w1 << 16)) as i32;
        }
    }
    out
}

/// Portable consumer of the packed layout — the fallback when no
/// vector tier applies, and the executable specification the vector
/// kernels are bit-equal to (wrapping i32 accumulation is associative
/// and commutative, so any walk order over the same products yields
/// identical bits).
///
/// `acc` must be zero-initialized, `rows * padded_n()` long; results
/// for logical column `j` of row `r` land at `r * padded_n() + j`.
pub fn kernel_rows_portable(pa: &[i32], pb: &PackedB, rows: usize, acc: &mut [i32]) {
    let kp = pb.k_pairs;
    let padded = pb.padded_n();
    assert!(pa.len() >= rows * kp);
    assert_eq!(acc.len(), rows * padded);
    for r in 0..rows {
        let arow = &mut acc[r * padded..(r + 1) * padded];
        for q in 0..pb.n_panels {
            let out = &mut arow[q * NR..(q + 1) * NR];
            for p in 0..kp {
                let pair = pa[r * kp + p];
                let w0 = pair as i16 as i32;
                let w1 = (pair >> 16) as i16 as i32;
                if w0 == 0 && w1 == 0 {
                    continue;
                }
                let base = (q * kp + p) * 2 * NR;
                for (c, o) in out.iter_mut().enumerate() {
                    let x0 = pb.data[base + 2 * c] as i32;
                    let x1 = pb.data[base + 2 * c + 1] as i32;
                    *o = o.wrapping_add(w0 * x0).wrapping_add(w1 * x1);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    fn rand_i8(state: &mut u64, len: usize) -> Vec<i8> {
        (0..len).map(|_| (xorshift(state) & 0xff) as u8 as i8).collect()
    }

    #[test]
    fn portable_kernel_matches_direct_accumulation() {
        // odd k (zero-row pad) and ragged n (zero-column pad) at once
        let (m, k, n) = (5, 7, 11);
        let mut st = 0xfeedu64;
        let w = rand_i8(&mut st, m * k);
        let x = rand_i8(&mut st, k * n);
        let pb = pack_b(&x, k, n, 0, n);
        let pa = pack_a(&w, 0, m, k);
        let mut acc = vec![0i32; m * pb.padded_n()];
        kernel_rows_portable(&pa, &pb, m, &mut acc);
        for i in 0..m {
            for j in 0..n {
                let direct: i32 = (0..k)
                    .map(|kk| w[i * k + kk] as i32 * x[kk * n + j] as i32)
                    .sum();
                assert_eq!(acc[i * pb.padded_n() + j], direct, "({i},{j})");
            }
        }
        // padded columns hold exactly zero
        for i in 0..m {
            for j in n..pb.padded_n() {
                assert_eq!(acc[i * pb.padded_n() + j], 0);
            }
        }
    }

    #[test]
    fn column_window_packs_the_block() {
        let (k, n) = (4, 20);
        let mut st = 3u64;
        let x = rand_i8(&mut st, k * n);
        let w = rand_i8(&mut st, 2 * k);
        let (n0, n1) = (5, 17);
        let pb = pack_b(&x, k, n, n0, n1);
        assert_eq!(pb.n, n1 - n0);
        let pa = pack_a(&w, 0, 2, k);
        let mut acc = vec![0i32; 2 * pb.padded_n()];
        kernel_rows_portable(&pa, &pb, 2, &mut acc);
        for i in 0..2 {
            for j in n0..n1 {
                let direct: i32 = (0..k)
                    .map(|kk| w[i * k + kk] as i32 * x[kk * n + j] as i32)
                    .sum();
                assert_eq!(acc[i * pb.padded_n() + (j - n0)], direct);
            }
        }
    }
}
