//! The benchmark model zoo: MobileNetV1, MobileNetV2, InceptionV1
//! (GoogLeNet) and ResNet18 — the four DNNs of the paper's evaluation
//! (§V-A), quantized to 8 bits, ImageNet 224x224 input.
//!
//! Weights are deterministic synthetic (xorshift-generated): layer
//! *shapes* are faithful to the published architectures — which is
//! what inference time and energy depend on — while weight values are
//! irrelevant to the SECDA evaluation (accuracy is out of scope for
//! the paper too). Scales are chosen so activations stay in-range
//! (requant multiplier ~ 1/(25*sqrt(K))), exercising the full
//! quantized pipeline rather than saturating.
//!
//! The conv GEMM shape tables here are cross-checked against
//! `python/compile/model.py` (the AOT bucket source) by
//! `rust/tests/integration.rs`.

pub mod inception_v1;
pub mod mobilenet_v1;
pub mod mobilenet_v2;
pub mod resnet18;

use crate::framework::graph::Graph;
use crate::framework::ops::{Activation, Conv2d, DepthwiseConv2d, FullyConnected, Op};
use crate::framework::quant::QParams;

/// The four benchmark model names (paper §V-A).
pub const ALL: [&str; 4] = ["mobilenet_v1", "mobilenet_v2", "inception_v1", "resnet18"];

/// Build a benchmark model by name.
pub fn by_name(name: &str) -> Option<Graph> {
    match name {
        "mobilenet_v1" => Some(mobilenet_v1::build()),
        "mobilenet_v2" => Some(mobilenet_v2::build()),
        "inception_v1" => Some(inception_v1::build()),
        "resnet18" => Some(resnet18::build()),
        _ => None,
    }
}

/// Standard activation quantization used throughout the zoo.
pub fn act_qp() -> QParams {
    QParams::new(0.05, -4)
}

/// Input image quantization.
pub fn input_qp() -> QParams {
    QParams::new(1.0 / 128.0, 0)
}

/// Recover the conv GEMM dims of a graph by shape propagation (used by
/// the AOT-bucket coverage test and the table2 harness).
pub fn gemm_shapes(g: &Graph) -> Vec<(usize, usize, usize)> {
    let mut shapes: Vec<Option<Vec<usize>>> = vec![None; g.n_slots];
    shapes[g.input_slot] = Some(g.input_shape.clone());
    let mut out = Vec::new();
    for node in &g.nodes {
        let in_shape = shapes[node.inputs[0]].clone().expect("shape ready");
        if let Some(dims) = node.op.gemm_shape(&in_shape) {
            out.push(dims);
        }
        let o = match &node.op {
            Op::Conv(c) => {
                let (oh, ow) = c.out_hw(in_shape[1], in_shape[2]);
                vec![1, oh, ow, c.cout]
            }
            Op::DwConv(d) => {
                let (oh, ow) = d.out_hw(in_shape[1], in_shape[2]);
                vec![1, oh, ow, d.channels]
            }
            Op::Pool(p) => {
                let (oh, ow) = p.out_hw(in_shape[1], in_shape[2]);
                vec![1, oh, ow, in_shape[3]]
            }
            Op::GlobalAvgPool(_) => vec![1, in_shape[3]],
            Op::Fc(f) => vec![1, f.out_features],
            Op::Add(_) => in_shape.clone(),
            Op::Concat(_) => {
                let c: usize = node
                    .inputs
                    .iter()
                    .map(|&s| shapes[s].as_ref().unwrap()[3])
                    .sum();
                vec![1, in_shape[1], in_shape[2], c]
            }
            Op::Softmax(_) => in_shape.clone(),
        };
        shapes[node.output] = Some(o);
    }
    out
}

/// Deterministic weight generator (seeded per layer from its name).
pub struct WeightGen {
    state: u64,
}

impl WeightGen {
    /// A generator seeded from the model and layer names.
    pub fn for_layer(model: &str, layer: &str) -> Self {
        // FNV-1a over the model/layer names
        let mut h: u64 = 0xcbf29ce484222325;
        for b in model.bytes().chain("/".bytes()).chain(layer.bytes()) {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        WeightGen { state: h.max(1) }
    }

    fn next(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x
    }

    /// `n` uniform int8 weights.
    pub fn i8s(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| (self.next() & 0xff) as u8 as i8).collect()
    }

    /// `n` int32 biases in [-200, 200].
    pub fn biases(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| (self.next() % 401) as i32 - 200).collect()
    }
}

/// Per-layer weight scale keeping requantized activations in-range:
/// real multiplier = in_s * w_s / out_s ~= 1 / (25 * sqrt(K)).
fn w_scale_for(k: usize, in_s: f32, out_s: f32) -> f32 {
    out_s / (in_s * 25.0 * (k as f32).sqrt())
}

/// Standard conv builder (square kernel, per-channel scales with a
/// small deterministic jitter).
#[allow(clippy::too_many_arguments)]
pub fn conv(
    model: &str,
    name: &str,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    pad: usize,
    act: Activation,
    in_qp: QParams,
    out_qp: QParams,
) -> Conv2d {
    let mut gen = WeightGen::for_layer(model, name);
    let kk = k * k * cin;
    let base = w_scale_for(kk, in_qp.scale, out_qp.scale);
    let w_scales = (0..cout)
        .map(|_| base * (0.9 + 0.2 * ((gen.next() % 1000) as f32 / 1000.0)))
        .collect();
    Conv2d {
        name: name.to_string(),
        cout,
        kh: k,
        kw: k,
        cin,
        stride,
        pad,
        weights: gen.i8s(cout * kk),
        bias: gen.biases(cout),
        w_scales,
        out_qp,
        act,
        weights_resident: false,
    }
}

/// Depthwise conv builder (3x3).
pub fn dwconv(
    model: &str,
    name: &str,
    channels: usize,
    stride: usize,
    act: Activation,
    in_qp: QParams,
    out_qp: QParams,
) -> DepthwiseConv2d {
    let mut gen = WeightGen::for_layer(model, name);
    let base = w_scale_for(9, in_qp.scale, out_qp.scale);
    DepthwiseConv2d {
        name: name.to_string(),
        channels,
        kh: 3,
        kw: 3,
        stride,
        pad: 1,
        weights: gen.i8s(9 * channels),
        bias: gen.biases(channels),
        w_scales: vec![base; channels],
        out_qp,
        act,
    }
}

/// Fully-connected classifier head builder.
pub fn fc(
    model: &str,
    name: &str,
    in_features: usize,
    out_features: usize,
    in_qp: QParams,
) -> FullyConnected {
    let mut gen = WeightGen::for_layer(model, name);
    let out_qp = QParams::new(0.1, 0);
    FullyConnected {
        name: name.to_string(),
        in_features,
        out_features,
        weights: gen.i8s(in_features * out_features),
        bias: gen.biases(out_features),
        w_scale: w_scale_for(in_features, in_qp.scale, out_qp.scale),
        out_qp,
        act: Activation::None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_build_and_validate() {
        for name in ALL {
            let g = by_name(name).unwrap();
            assert!(g.validate().is_ok(), "{name}");
            assert!(g.conv_layer_count() > 0, "{name}");
        }
    }

    #[test]
    fn gemm_mac_totals_match_paper_architectures() {
        // mirrors python/tests/test_model.py
        let total = |name: &str| -> u64 {
            gemm_shapes(&by_name(name).unwrap())
                .iter()
                .map(|&(m, k, n)| (m * k * n) as u64)
                .sum()
        };
        let mb1 = total("mobilenet_v1");
        assert!((400_000_000..600_000_000).contains(&mb1), "mb1 {mb1}");
        let mb2 = total("mobilenet_v2");
        assert!((250_000_000..400_000_000).contains(&mb2), "mb2 {mb2}");
        let inc = total("inception_v1");
        assert!((1_200_000_000..1_700_000_000).contains(&inc), "inc {inc}");
        let res = total("resnet18");
        assert!((1_600_000_000..2_000_000_000).contains(&res), "res {res}");
    }

    #[test]
    fn gemm_conv_counts_match_python_tables() {
        let count = |name: &str| gemm_shapes(&by_name(name).unwrap()).len();
        assert_eq!(count("mobilenet_v1"), 14);
        assert_eq!(count("mobilenet_v2"), 1 + 17 + 16 + 1);
        assert_eq!(count("inception_v1"), 3 + 9 * 6);
        assert_eq!(count("resnet18"), 1 + 4 + 5 + 5 + 5);
    }

    #[test]
    fn weight_gen_is_deterministic_per_layer() {
        let a = WeightGen::for_layer("m", "l").i8s(16);
        let b = WeightGen::for_layer("m", "l").i8s(16);
        let c = WeightGen::for_layer("m", "l2").i8s(16);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn model_sizes_are_plausible() {
        // int8 model weight sizes within ~2x of the known parameter
        // counts: MbV1 4.2M, MbV2 3.5M, GoogLeNet 7.0M, ResNet18 11.7M
        let size = |n: &str| by_name(n).unwrap().weight_bytes();
        let mb1 = size("mobilenet_v1");
        assert!((3_000_000..6_000_000).contains(&mb1), "mb1 {mb1}");
        let mb2 = size("mobilenet_v2");
        assert!((2_000_000..5_500_000).contains(&mb2), "mb2 {mb2}");
        let inc = size("inception_v1");
        assert!((5_000_000..9_000_000).contains(&inc), "inc {inc}");
        let res = size("resnet18");
        assert!((9_000_000..14_000_000).contains(&res), "res {res}");
    }
}
