//! ResNet18 (He et al., 2016), int8-quantized: 7x7 stem + maxpool,
//! four stages of two basic blocks (3x3+3x3 with residual add; the
//! first block of stages 2-4 downsamples via a strided 1x1 conv on
//! the skip path), GAP, FC-1000, softmax.
//!
//! ResNet18's stage-4 convs have K = 3*3*512 = 4608 — too deep for the
//! standard VM design's local buffers, motivating the §IV-E4 variant.

use crate::framework::graph::{Graph, GraphBuilder, SlotId};
use crate::framework::ops::{
    Activation, AddOp, GlobalAvgPool, Op, Pool2d, PoolKind, SoftmaxOp,
};

use super::{act_qp, conv, fc, input_qp};

const M: &str = "resnet18";

/// (channels, first-block stride, in channels) per stage.
pub const STAGES: [(usize, usize, usize); 4] =
    [(64, 1, 64), (128, 2, 64), (256, 2, 128), (512, 2, 256)];

fn basic_block(
    b: &mut GraphBuilder,
    x: SlotId,
    name: &str,
    cin: usize,
    cout: usize,
    stride: usize,
) -> SlotId {
    let qp = act_qp();
    let c1 = b.push(
        Op::Conv(conv(
            M, &format!("{name}_conv1"), cin, cout, 3, stride, 1, Activation::Relu, qp, qp,
        )),
        vec![x],
    );
    let c2 = b.push(
        Op::Conv(conv(M, &format!("{name}_conv2"), cout, cout, 3, 1, 1, Activation::None, qp, qp)),
        vec![c1],
    );
    let skip = if stride != 1 || cin != cout {
        b.push(
            Op::Conv(conv(
                M, &format!("{name}_down"), cin, cout, 1, stride, 0, Activation::None, qp, qp,
            )),
            vec![x],
        )
    } else {
        x
    };
    // residual add with fused relu
    b.push(
        Op::Add(AddOp {
            name: format!("{name}_add"),
            out_qp: qp,
            act: Activation::Relu,
        }),
        vec![skip, c2],
    )
}

/// Build the ResNet18 graph (4 residual stages).
pub fn build() -> Graph {
    let qp = act_qp();
    let mut b = GraphBuilder::new(M, vec![1, 224, 224, 3], input_qp());
    let mut x = b.input();
    x = b.push(
        Op::Conv(conv(M, "conv1", 3, 64, 7, 2, 3, Activation::Relu, input_qp(), qp)),
        vec![x],
    );
    x = b.push(
        Op::Pool(Pool2d {
            name: "pool1".into(),
            kind: PoolKind::Max,
            k: 3,
            stride: 2,
            pad: 1,
        }),
        vec![x],
    ); // 112 -> 56
    for (si, &(c, s, cin)) in STAGES.iter().enumerate() {
        for blk in 0..2 {
            let (bin, bstride) = if blk == 0 { (cin, s) } else { (c, 1) };
            x = basic_block(&mut b, x, &format!("l{}b{}", si + 1, blk), bin, c, bstride);
        }
    }
    x = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![x]);
    x = b.push(Op::Fc(fc(M, "fc", 512, 1000, qp)), vec![x]);
    x = b.push(Op::Softmax(SoftmaxOp { name: "softmax".into() }), vec![x]);
    b.finish(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::models::gemm_shapes;

    #[test]
    fn structure() {
        let g = build();
        // 1 stem + 8 blocks x 2 convs + 3 downsamples = 20 GEMM convs
        assert_eq!(g.conv_layer_count(), 20);
        // 8 residual adds
        let adds = g.nodes.iter().filter(|n| matches!(n.op, Op::Add(_))).count();
        assert_eq!(adds, 8);
    }

    #[test]
    fn stage4_k_exceeds_vm_local_buffers() {
        // the §IV-E4 motivation: K = 4608 > 4096 (= 16 KiB / 4 rows)
        let shapes = gemm_shapes(&build());
        let kmax = shapes.iter().map(|&(_, k, _)| k).max().unwrap();
        assert_eq!(kmax, 4608);
        assert!(kmax > crate::accel::VmConfig::paper().max_k());
        assert!(kmax <= crate::accel::VmConfig::resnet_variant().max_k());
    }
}
