//! MobileNetV1 1.0/224 (Howard et al., 2017), int8-quantized: a stem
//! conv followed by 13 depthwise-separable blocks, GAP, FC-1001,
//! softmax. The 1x1 pointwise convs go through the GEMM seam; the
//! depthwise convs stay on the CPU (as in TFLite/gemmlowp).

use crate::framework::graph::{Graph, GraphBuilder};
use crate::framework::ops::{Activation, GlobalAvgPool, Op, SoftmaxOp};

use super::{act_qp, conv, dwconv, fc, input_qp};

const M: &str = "mobilenet_v1";

/// (in_ch, out_ch, dw stride) per separable block.
pub const BLOCKS: [(usize, usize, usize); 13] = [
    (32, 64, 1),
    (64, 128, 2),
    (128, 128, 1),
    (128, 256, 2),
    (256, 256, 1),
    (256, 512, 2),
    (512, 512, 1),
    (512, 512, 1),
    (512, 512, 1),
    (512, 512, 1),
    (512, 512, 1),
    (512, 1024, 2),
    (1024, 1024, 1),
];

/// Build the MobileNetV1 graph (14 conv GEMMs).
pub fn build() -> Graph {
    let qp = act_qp();
    let mut b = GraphBuilder::new(M, vec![1, 224, 224, 3], input_qp());
    let mut x = b.input();
    x = b.push(
        Op::Conv(conv(M, "conv0", 3, 32, 3, 2, 1, Activation::Relu6, input_qp(), qp)),
        vec![x],
    );
    for (i, &(cin, cout, s)) in BLOCKS.iter().enumerate() {
        let i = i + 1;
        x = b.push(
            Op::DwConv(dwconv(M, &format!("dw{i}"), cin, s, Activation::Relu6, qp, qp)),
            vec![x],
        );
        x = b.push(
            Op::Conv(conv(M, &format!("pw{i}"), cin, cout, 1, 1, 0, Activation::Relu6, qp, qp)),
            vec![x],
        );
    }
    x = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![x]);
    x = b.push(Op::Fc(fc(M, "fc", 1024, 1001, qp)), vec![x]);
    x = b.push(Op::Softmax(SoftmaxOp { name: "softmax".into() }), vec![x]);
    b.finish(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let g = build();
        // stem + 13 dw + 13 pw convs; GAP + FC + softmax non-conv
        assert_eq!(g.conv_layer_count(), 1 + 26);
        assert_eq!(g.nodes.len(), 27 + 3);
    }
}
