//! MobileNetV2 1.0/224 (Sandler et al., 2018), int8-quantized:
//! inverted-residual bottlenecks with linear projections, residual
//! adds on stride-1 same-width blocks, final 1x1 conv to 1280,
//! GAP, FC-1001, softmax.

use crate::framework::graph::{Graph, GraphBuilder};
use crate::framework::ops::{Activation, AddOp, GlobalAvgPool, Op, SoftmaxOp};

use super::{act_qp, conv, dwconv, fc, input_qp};

const M: &str = "mobilenet_v2";

/// (expansion t, out channels c, repeats n, first stride s).
pub const CFG: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

/// Build the MobileNetV2 graph (inverted residual blocks).
pub fn build() -> Graph {
    let qp = act_qp();
    let mut b = GraphBuilder::new(M, vec![1, 224, 224, 3], input_qp());
    let mut x = b.input();
    x = b.push(
        Op::Conv(conv(M, "conv0", 3, 32, 3, 2, 1, Activation::Relu6, input_qp(), qp)),
        vec![x],
    );
    let mut cin = 32;
    let mut blk = 0;
    for &(t, c, n, s) in &CFG {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            let residual = stride == 1 && cin == c;
            let entry = x;
            let exp = cin * t;
            if t != 1 {
                x = b.push(
                    Op::Conv(conv(
                        M,
                        &format!("b{blk}_expand"),
                        cin,
                        exp,
                        1,
                        1,
                        0,
                        Activation::Relu6,
                        qp,
                        qp,
                    )),
                    vec![x],
                );
            }
            x = b.push(
                Op::DwConv(dwconv(
                    M,
                    &format!("b{blk}_dw"),
                    exp,
                    stride,
                    Activation::Relu6,
                    qp,
                    qp,
                )),
                vec![x],
            );
            // linear projection (no activation)
            x = b.push(
                Op::Conv(conv(
                    M,
                    &format!("b{blk}_project"),
                    exp,
                    c,
                    1,
                    1,
                    0,
                    Activation::None,
                    qp,
                    qp,
                )),
                vec![x],
            );
            if residual {
                x = b.push(
                    Op::Add(AddOp {
                        name: format!("b{blk}_add"),
                        out_qp: qp,
                        act: Activation::None,
                    }),
                    vec![entry, x],
                );
            }
            cin = c;
            blk += 1;
        }
    }
    x = b.push(
        Op::Conv(conv(M, "conv_last", 320, 1280, 1, 1, 0, Activation::Relu6, qp, qp)),
        vec![x],
    );
    x = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![x]);
    x = b.push(Op::Fc(fc(M, "fc", 1280, 1001, qp)), vec![x]);
    x = b.push(Op::Softmax(SoftmaxOp { name: "softmax".into() }), vec![x]);
    b.finish(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::ops::Op;

    #[test]
    fn structure() {
        let g = build();
        // GEMM convs: stem + 16 expands + 17 projects + last = 35
        let convs = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Conv(_)))
            .count();
        assert_eq!(convs, 35);
        // 17 bottleneck blocks, 10 with residual adds
        let adds = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Add(_)))
            .count();
        assert_eq!(adds, 10);
    }
}
