//! InceptionV1 / GoogLeNet (Szegedy et al., 2015), int8-quantized:
//! 7x7 stem, two stacked convs, nine inception modules with channel
//! concat, GAP, FC-1001, softmax. All convs are standard (no
//! depthwise), so nearly every CONV MAC is GEMM-acceleratable — which
//! is why InceptionV1 shows the best speedups in Table II (§V-B).

use crate::framework::graph::{Graph, GraphBuilder, SlotId};
use crate::framework::ops::{
    Activation, ConcatOp, GlobalAvgPool, Op, Pool2d, PoolKind, SoftmaxOp,
};

use super::{act_qp, conv, fc, input_qp};

const M: &str = "inception_v1";

/// (name, in, #1x1, #3x3red, #3x3, #5x5red, #5x5, pool_proj).
pub const MODULES: [(&str, usize, usize, usize, usize, usize, usize, usize); 9] = [
    ("3a", 192, 64, 96, 128, 16, 32, 32),
    ("3b", 256, 128, 128, 192, 32, 96, 64),
    ("4a", 480, 192, 96, 208, 16, 48, 64),
    ("4b", 512, 160, 112, 224, 24, 64, 64),
    ("4c", 512, 128, 128, 256, 24, 64, 64),
    ("4d", 512, 112, 144, 288, 32, 64, 64),
    ("4e", 528, 256, 160, 320, 32, 128, 128),
    ("5a", 832, 256, 160, 320, 32, 128, 128),
    ("5b", 832, 384, 192, 384, 48, 128, 128),
];

#[allow(clippy::too_many_arguments)]
fn inception(
    b: &mut GraphBuilder,
    x: SlotId,
    name: &str,
    cin: usize,
    c1: usize,
    c3r: usize,
    c3: usize,
    c5r: usize,
    c5: usize,
    cp: usize,
) -> SlotId {
    let qp = act_qp();
    let r = Activation::Relu;
    // branch 1: 1x1
    let b1 = b.push(
        Op::Conv(conv(M, &format!("{name}_1x1"), cin, c1, 1, 1, 0, r, qp, qp)),
        vec![x],
    );
    // branch 2: 1x1 reduce -> 3x3
    let b2r = b.push(
        Op::Conv(conv(M, &format!("{name}_3x3r"), cin, c3r, 1, 1, 0, r, qp, qp)),
        vec![x],
    );
    let b2 = b.push(
        Op::Conv(conv(M, &format!("{name}_3x3"), c3r, c3, 3, 1, 1, r, qp, qp)),
        vec![b2r],
    );
    // branch 3: 1x1 reduce -> 5x5
    let b3r = b.push(
        Op::Conv(conv(M, &format!("{name}_5x5r"), cin, c5r, 1, 1, 0, r, qp, qp)),
        vec![x],
    );
    let b3 = b.push(
        Op::Conv(conv(M, &format!("{name}_5x5"), c5r, c5, 5, 1, 2, r, qp, qp)),
        vec![b3r],
    );
    // branch 4: 3x3 maxpool -> 1x1 proj
    let b4p = b.push(
        Op::Pool(Pool2d {
            name: format!("{name}_pool"),
            kind: PoolKind::Max,
            k: 3,
            stride: 1,
            pad: 1,
        }),
        vec![x],
    );
    let b4 = b.push(
        Op::Conv(conv(M, &format!("{name}_pool"), cin, cp, 1, 1, 0, r, qp, qp)),
        vec![b4p],
    );
    b.push(
        Op::Concat(ConcatOp {
            name: format!("{name}_concat"),
            out_qp: qp,
        }),
        vec![b1, b2, b3, b4],
    )
}

fn maxpool(b: &mut GraphBuilder, x: SlotId, name: &str) -> SlotId {
    b.push(
        Op::Pool(Pool2d {
            name: name.into(),
            kind: PoolKind::Max,
            k: 3,
            stride: 2,
            pad: 1,
        }),
        vec![x],
    )
}

/// Build the InceptionV1 (GoogLeNet) graph (9 inception blocks).
pub fn build() -> Graph {
    let qp = act_qp();
    let r = Activation::Relu;
    let mut b = GraphBuilder::new(M, vec![1, 224, 224, 3], input_qp());
    let mut x = b.input();
    x = b.push(
        Op::Conv(conv(M, "conv1", 3, 64, 7, 2, 3, r, input_qp(), qp)),
        vec![x],
    );
    x = maxpool(&mut b, x, "pool1"); // 112 -> 56
    x = b.push(Op::Conv(conv(M, "conv2_red", 64, 64, 1, 1, 0, r, qp, qp)), vec![x]);
    x = b.push(Op::Conv(conv(M, "conv2", 64, 192, 3, 1, 1, r, qp, qp)), vec![x]);
    x = maxpool(&mut b, x, "pool2"); // 56 -> 28
    for (i, &(name, cin, c1, c3r, c3, c5r, c5, cp)) in MODULES.iter().enumerate() {
        x = inception(&mut b, x, name, cin, c1, c3r, c3, c5r, c5, cp);
        // maxpool after 3b (idx 1) and 4e (idx 6)
        if i == 1 || i == 6 {
            x = maxpool(&mut b, x, &format!("pool_{name}"));
        }
    }
    x = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![x]);
    x = b.push(Op::Fc(fc(M, "fc", 1024, 1001, qp)), vec![x]);
    x = b.push(Op::Softmax(SoftmaxOp { name: "softmax".into() }), vec![x]);
    b.finish(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let g = build();
        // convs: 3 stem + 9 modules x 6 = 57, all GEMM-delegatable
        assert_eq!(g.conv_layer_count(), 57);
        // output channel sums: 5b -> 384+384+128+128 = 1024
        let (_, cin, c1, _, c3, _, c5, cp) = MODULES[8];
        assert_eq!(cin, 832);
        assert_eq!(c1 + c3 + c5 + cp, 1024);
    }
}
