//! Quantized tensors (NHWC int8, TFLite-style asymmetric quantization).

use super::quant::QParams;

/// An int8 tensor with quantization parameters. Layout is NHWC for
/// activations, `[Cout, kh, kw, Cin]` for convolution weights.
#[derive(Debug, Clone)]
pub struct Tensor {
    /// Dimensions, outermost first (NHWC for activations).
    pub shape: Vec<usize>,
    /// Quantized values, row-major in `shape` order.
    pub data: Vec<i8>,
    /// Asymmetric quantization parameters of `data`.
    pub qp: QParams,
}

impl Tensor {
    /// A tensor from parts; panics unless `data` fills `shape` exactly.
    pub fn new(shape: Vec<usize>, data: Vec<i8>, qp: QParams) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data, qp }
    }

    /// A tensor holding real value 0.0 everywhere (i.e. filled with
    /// the zero point).
    pub fn zeros(shape: Vec<usize>, qp: QParams) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![qp.zero_point.clamp(-128, 127) as i8; n],
            qp,
        }
    }

    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Storage size in bytes (one byte per int8 element).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// NHWC dims of an activation tensor (requires rank 4, batch 1).
    pub fn nhwc(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.shape.len(), 4, "expected NHWC, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3])
    }

    /// The real values `scale * (q - zero_point)`, element-wise.
    pub fn dequantize(&self) -> Vec<f32> {
        self.data.iter().map(|&v| self.qp.dequantize(v)).collect()
    }

    /// Quantize an f32 image into a tensor (test/example inputs).
    pub fn quantize_from(values: &[f32], shape: Vec<usize>, qp: QParams) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let data = values.iter().map(|&v| qp.quantize(v)).collect();
        Tensor { shape, data, qp }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let qp = QParams::new(0.1, 0);
        let t = Tensor::new(vec![1, 2, 2, 3], vec![1; 12], qp);
        assert_eq!(t.numel(), 12);
        assert_eq!(t.nhwc(), (1, 2, 2, 3));
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![0; 5], QParams::new(1.0, 0));
    }

    #[test]
    fn zeros_takes_zero_point() {
        let t = Tensor::zeros(vec![4], QParams::new(0.5, 3));
        assert!(t.data.iter().all(|&v| v == 3));
        assert!(t.dequantize().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn quantize_round_trip() {
        let qp = QParams::from_range(-1.0, 1.0);
        let vals = [-0.9f32, -0.1, 0.0, 0.4, 0.77];
        let t = Tensor::quantize_from(&vals, vec![5], qp);
        for (a, b) in t.dequantize().iter().zip(&vals) {
            assert!((a - b).abs() <= qp.scale, "{a} vs {b}");
        }
    }
}
