//! Model graphs: a DAG of quantized ops over tensor slots.

use super::ops::Op;
use super::quant::QParams;

/// Index of a tensor slot inside one graph.
pub type SlotId = usize;

/// One graph node: an op reading `inputs` slots and writing `output`.
#[derive(Debug, Clone)]
pub struct Node {
    /// The operator this node runs.
    pub op: Op,
    /// Slots the op reads, in the op's argument order.
    pub inputs: Vec<SlotId>,
    /// The slot the op writes (single writer per slot).
    pub output: SlotId,
}

/// A quantized inference graph (batch-1, NHWC).
#[derive(Debug, Clone)]
pub struct Graph {
    /// Model name (batching groups requests by it).
    pub name: String,
    /// Nodes in execution (topological) order.
    pub nodes: Vec<Node>,
    /// The slot the request input lands in (always 0).
    pub input_slot: SlotId,
    /// The slot holding the final output.
    pub output_slot: SlotId,
    /// Required shape of the input tensor.
    pub input_shape: Vec<usize>,
    /// Required quantization of the input tensor.
    pub input_qp: QParams,
    /// Total slot count (for interpreter slot allocation).
    pub n_slots: usize,
}

impl Graph {
    /// Validate DAG invariants: slots written before read, single
    /// writer per slot, output reachable.
    pub fn validate(&self) -> Result<(), String> {
        let mut written = vec![false; self.n_slots];
        written[self.input_slot] = true;
        for (i, node) in self.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                if !written[inp] {
                    return Err(format!(
                        "node {} ({}) reads slot {} before it is written",
                        i,
                        node.op.name(),
                        inp
                    ));
                }
            }
            if written[node.output] {
                return Err(format!(
                    "node {} ({}) rewrites slot {}",
                    i,
                    node.op.name(),
                    node.output
                ));
            }
            written[node.output] = true;
        }
        if !written[self.output_slot] {
            return Err("output slot never written".into());
        }
        Ok(())
    }

    /// Number of conv layers (Table II CONV bucket members).
    pub fn conv_layer_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.op.is_conv()).count()
    }

    /// Total weight bytes (model size).
    pub fn weight_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| match &n.op {
                Op::Conv(c) => c.weights.len(),
                Op::DwConv(d) => d.weights.len(),
                Op::Fc(f) => f.weights.len(),
                _ => 0,
            })
            .sum()
    }

    /// Last slot each slot is read (or written) — for slot freeing.
    pub fn last_use(&self) -> Vec<usize> {
        let mut last = vec![0usize; self.n_slots];
        for (i, node) in self.nodes.iter().enumerate() {
            for &inp in &node.inputs {
                last[inp] = i;
            }
            last[node.output] = last[node.output].max(i);
        }
        last[self.output_slot] = self.nodes.len();
        last
    }
}

/// Incremental graph builder used by the model zoo.
pub struct GraphBuilder {
    name: String,
    nodes: Vec<Node>,
    input_shape: Vec<usize>,
    input_qp: QParams,
    next_slot: SlotId,
}

impl GraphBuilder {
    /// Start a graph with the given input shape and quantization.
    pub fn new(name: &str, input_shape: Vec<usize>, input_qp: QParams) -> Self {
        GraphBuilder {
            name: name.to_string(),
            nodes: Vec::new(),
            input_shape,
            input_qp,
            next_slot: 1, // slot 0 = graph input
        }
    }

    /// The graph-input slot.
    pub fn input(&self) -> SlotId {
        0
    }

    /// Append an op, returning its output slot.
    pub fn push(&mut self, op: Op, inputs: Vec<SlotId>) -> SlotId {
        let out = self.next_slot;
        self.next_slot += 1;
        self.nodes.push(Node {
            op,
            inputs,
            output: out,
        });
        out
    }

    /// Seal the graph with `output` as its output slot; panics if the
    /// built graph fails [`Graph::validate`].
    pub fn finish(self, output: SlotId) -> Graph {
        let g = Graph {
            name: self.name,
            nodes: self.nodes,
            input_slot: 0,
            output_slot: output,
            input_shape: self.input_shape,
            input_qp: self.input_qp,
            n_slots: self.next_slot,
        };
        g.validate().expect("graph invalid");
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::ops::{GlobalAvgPool, SoftmaxOp};

    fn tiny() -> Graph {
        let mut b = GraphBuilder::new("tiny", vec![1, 4, 4, 2], QParams::new(0.05, 0));
        let gap = b.push(
            Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }),
            vec![b.input()],
        );
        let sm = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![gap]);
        b.finish(sm)
    }

    #[test]
    fn builder_produces_valid_graph() {
        let g = tiny();
        assert_eq!(g.nodes.len(), 2);
        assert!(g.validate().is_ok());
        assert_eq!(g.conv_layer_count(), 0);
    }

    #[test]
    fn validation_catches_read_before_write() {
        let mut g = tiny();
        g.nodes[0].inputs = vec![2]; // slot 2 is written by node 1
        assert!(g.validate().is_err());
    }

    #[test]
    fn last_use_tracks_reads() {
        let g = tiny();
        let last = g.last_use();
        assert_eq!(last[0], 0); // input read by node 0
        assert_eq!(last[1], 1); // gap out read by node 1
        assert_eq!(last[2], g.nodes.len()); // output kept alive
    }
}
