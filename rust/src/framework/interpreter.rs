//! The graph interpreter — the runtime of the Application Framework.
//!
//! Executes a [`Graph`] node by node against a [`GemmBackend`] and
//! produces the functional output plus an [`InferenceReport`] with the
//! Table II quantities: CONV time, Non-CONV time, overall latency and
//! energy, with per-layer breakdowns (§V-B analyses).

use super::backend::GemmBackend;
use super::graph::Graph;
use super::ops::{OpCtx, TimeBucket};
use super::tensor::Tensor;
use crate::perf::{CpuModel, EnergyModel};
use crate::sysc::SimTime;

/// Table II row, plus breakdowns.
#[derive(Debug, Clone)]
pub struct InferenceReport {
    /// Model (graph) name.
    pub model: String,
    /// Setup label, e.g. `CPU(2thr)+SA`.
    pub setup: String,
    /// Modeled time in CONV-bucket layers.
    pub conv_time: SimTime,
    /// Modeled time in Non-CONV layers (+ framework overhead).
    pub nonconv_time: SimTime,
    /// Time the accelerator fabric was active (energy accounting).
    pub accel_active: SimTime,
    /// Modeled energy for the inference, in joules.
    pub energy_j: f64,
    /// CPU threads the session modeled.
    pub threads: usize,
    /// (layer name, bucket, time) per node.
    pub layers: Vec<(String, TimeBucket, SimTime)>,
}

impl InferenceReport {
    /// Overall modeled latency (CONV + Non-CONV).
    pub fn overall(&self) -> SimTime {
        self.conv_time + self.nonconv_time
    }

    /// §V-B: share of inference time in Non-CONV layers.
    pub fn nonconv_share(&self) -> f64 {
        self.nonconv_time.as_secs_f64() / self.overall().as_secs_f64()
    }

    /// One formatted Table II row.
    pub fn row(&self) -> String {
        format!(
            "{:<14} {:<16} {:>8.0} ms {:>8.0} ms {:>8.0} ms {:>7.2} J",
            self.model,
            self.setup,
            self.conv_time.as_ms_f64(),
            self.nonconv_time.as_ms_f64(),
            self.overall().as_ms_f64(),
            self.energy_j
        )
    }
}

/// An inference session: a graph bound to a GEMM backend.
pub struct Session<'a> {
    /// The graph to run.
    pub graph: &'a Graph,
    /// Where conv/FC GEMMs go (the Fig. 2 delegate seam).
    pub backend: &'a mut dyn GemmBackend,
    /// CPU threads to model for CPU-side work.
    pub threads: usize,
    /// CPU timing model pricing the non-offloaded work.
    pub cpu: CpuModel,
    /// Energy model folding active/idle power over the run.
    pub energy: EnergyModel,
    /// Label stamped into reports, e.g. `CPU(2thr)+SA`.
    pub setup_label: String,
}

impl<'a> Session<'a> {
    /// A session on the PYNQ-A9 CPU model (the single-inference
    /// baseline the paper tables use; the serving pool swaps in
    /// [`CpuModel::serving`] via its own backends).
    pub fn new(graph: &'a Graph, backend: &'a mut dyn GemmBackend, threads: usize) -> Self {
        let label = format!("CPU({}thr)+{}", threads, backend.name());
        Session {
            graph,
            backend,
            threads,
            cpu: CpuModel::pynq_a9(),
            energy: EnergyModel::pynq(),
            setup_label: label,
        }
    }

    /// Run one inference.
    pub fn run(&mut self, input: &Tensor) -> (Tensor, InferenceReport) {
        assert_eq!(
            input.shape, self.graph.input_shape,
            "input shape mismatch for {}",
            self.graph.name
        );
        let mut slots: Vec<Option<Tensor>> = vec![None; self.graph.n_slots];
        slots[self.graph.input_slot] = Some(input.clone());
        let last_use = self.graph.last_use();

        let mut ctx = OpCtx::new(self.backend, &self.cpu, self.threads);
        for (i, node) in self.graph.nodes.iter().enumerate() {
            let inputs: Vec<&Tensor> = node
                .inputs
                .iter()
                .map(|&s| slots[s].as_ref().expect("slot not ready"))
                .collect();
            let out = node.op.eval(&inputs, &mut ctx);
            slots[node.output] = Some(out);
            // free tensors whose last use has passed (arena hygiene)
            for &s in &node.inputs {
                if last_use[s] <= i && s != self.graph.output_slot {
                    slots[s] = None;
                }
            }
        }
        let output = slots[self.graph.output_slot]
            .take()
            .expect("output not produced");

        // per-inference framework overhead (interpreter dispatch,
        // input/output (de)quantization — see perf::calib)
        let fw = SimTime::ps(
            (self.cpu.framework_overhead.as_ps() as f64 / self.cpu.eff_threads(self.threads))
                as u64,
        );
        ctx.nonconv_time += fw;
        ctx.layers
            .push(("framework".to_string(), TimeBucket::NonConv, fw));

        let overall = ctx.conv_time + ctx.nonconv_time;
        let energy = self
            .energy
            .energy_j(overall, ctx.accel_active, self.threads);
        let report = InferenceReport {
            model: self.graph.name.clone(),
            setup: self.setup_label.clone(),
            conv_time: ctx.conv_time,
            nonconv_time: ctx.nonconv_time,
            accel_active: ctx.accel_active,
            energy_j: energy,
            threads: self.threads,
            layers: ctx.layers,
        };
        (output, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::backend::CpuBackend;
    use crate::framework::graph::GraphBuilder;
    use crate::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
    use crate::framework::quant::QParams;

    fn tiny_convnet() -> Graph {
        let mut st = 5u64;
        let mut rnd = || {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            st
        };
        let mut b = GraphBuilder::new("tiny_conv", vec![1, 8, 8, 3], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: "c1".into(),
            cout: 8,
            kh: 3,
            kw: 3,
            cin: 3,
            stride: 1,
            pad: 1,
            weights: (0..8 * 27).map(|_| (rnd() & 0xff) as u8 as i8).collect(),
            bias: vec![10; 8],
            w_scales: vec![0.02; 8],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    #[test]
    fn session_runs_and_reports() {
        let g = tiny_convnet();
        let mut backend = CpuBackend::new(1);
        let mut sess = Session::new(&g, &mut backend, 1);
        let input = Tensor::zeros(vec![1, 8, 8, 3], QParams::new(0.05, 0));
        let (out, report) = sess.run(&input);
        assert_eq!(out.shape, vec![1, 8]);
        assert!(report.conv_time > SimTime::ZERO);
        assert!(report.nonconv_time > SimTime::ZERO);
        assert!(report.energy_j > 0.0);
        assert_eq!(report.layers.len(), 4); // 3 ops + framework overhead
    }

    #[test]
    fn deterministic_outputs() {
        let g = tiny_convnet();
        let input = Tensor::zeros(vec![1, 8, 8, 3], QParams::new(0.05, 0));
        let mut b1 = CpuBackend::new(1);
        let o1 = Session::new(&g, &mut b1, 1).run(&input).0;
        let mut b2 = CpuBackend::new(2);
        let o2 = Session::new(&g, &mut b2, 2).run(&input).0;
        assert_eq!(o1.data, o2.data); // thread count never changes bits
    }

    #[test]
    fn accel_session_matches_cpu_session() {
        use crate::accel::SaDesign;
        use crate::driver::{AccelBackend, DriverConfig};
        let g = tiny_convnet();
        let input = Tensor::zeros(vec![1, 8, 8, 3], QParams::new(0.05, 0));
        let mut cb = CpuBackend::new(1);
        let (o_cpu, _) = Session::new(&g, &mut cb, 1).run(&input);
        let mut ab = AccelBackend::new(SaDesign::paper(), DriverConfig::default());
        let (o_acc, rep) = Session::new(&g, &mut ab, 1).run(&input);
        assert_eq!(o_cpu.data, o_acc.data);
        assert!(rep.accel_active > SimTime::ZERO);
    }
}
