//! The Application Framework (paper §III-A) — a TFLite-like quantized
//! inference runtime: int8 tensors, the op set of the four benchmark
//! models, a graph interpreter with per-op cost accounting, and the
//! gemmlowp-style GEMM interception seam ([`backend`]) through which
//! the SECDA driver offloads convolutions (Fig. 2).

pub mod backend;
pub mod graph;
pub mod interpreter;
pub mod models;
pub mod ops;
pub mod quant;
pub mod tensor;
