//! Quantization arithmetic — bit-exact port of gemmlowp/TFLite fixed
//! point requantization, mirrored by `python/compile/kernels/ref.py`
//! (cross-checked by `rust/tests/quant_golden.rs` against the golden
//! vectors emitted at `make artifacts` time).
//!
//! Convention (TFLite int8 spec): weights are symmetric (zero-point 0,
//! per-output-channel scales); activations are asymmetric int8 with a
//! per-tensor zero-point; accumulators are int32; the requantization
//! multiplier is a Q31 mantissa + power-of-two shift.

/// Quantization parameters of an int8 tensor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    /// Real value per quantization step (> 0).
    pub scale: f32,
    /// The int8 value representing real 0.0.
    pub zero_point: i32,
}

impl QParams {
    /// Parameters from parts; `scale` must be positive.
    pub fn new(scale: f32, zero_point: i32) -> Self {
        debug_assert!(scale > 0.0);
        QParams { scale, zero_point }
    }

    /// Parameters covering `[lo, hi]` with the int8 value range.
    pub fn from_range(lo: f32, hi: f32) -> Self {
        let (lo, hi) = (lo.min(0.0), hi.max(0.0));
        let scale = (hi - lo) / 255.0;
        let scale = if scale <= 0.0 { 1.0 / 255.0 } else { scale };
        let zp = (-128.0 - lo / scale).round().clamp(-128.0, 127.0) as i32;
        QParams::new(scale, zp)
    }

    /// Nearest int8 value for real `v` (saturating).
    pub fn quantize(&self, v: f32) -> i8 {
        ((v / self.scale).round() as i32 + self.zero_point).clamp(-128, 127) as i8
    }

    /// The real value `scale * (q - zero_point)`.
    pub fn dequantize(&self, q: i8) -> f32 {
        (q as i32 - self.zero_point) as f32 * self.scale
    }
}

/// gemmlowp `SaturatingRoundingDoublingHighMul`:
/// `round(a * b / 2^31)`, ties away from zero, saturating the single
/// overflow case `a == b == i32::MIN`.
#[inline]
pub fn srdhm(a: i32, b: i32) -> i32 {
    if a == i32::MIN && b == i32::MIN {
        return i32::MAX;
    }
    let ab = a as i64 * b as i64;
    let nudge: i64 = if ab >= 0 { 1 << 30 } else { 1 - (1 << 30) };
    // C++ truncating division by 2^31 (toward zero), not a floor shift.
    ((ab + nudge) / (1i64 << 31)) as i32
}

/// gemmlowp `RoundingDivideByPOT`: `x / 2^exponent`, round to nearest,
/// ties away from zero. `exponent` in [0, 31].
#[inline]
pub fn rounding_divide_by_pot(x: i32, exponent: i32) -> i32 {
    debug_assert!((0..=31).contains(&exponent));
    let mask = (1i64 << exponent) - 1;
    let remainder = (x as i64) & mask;
    let threshold = (mask >> 1) + i64::from(x < 0);
    (x >> exponent) + i32::from(remainder > threshold)
}

/// TFLite `MultiplyByQuantizedMultiplier`: `shift` positive = left.
#[inline]
pub fn multiply_by_quantized_multiplier(acc: i32, mult: i32, shift: i32) -> i32 {
    let left = shift.max(0);
    let right = (-shift).max(0);
    let shifted = acc.wrapping_shl(left as u32);
    rounding_divide_by_pot(srdhm(shifted, mult), right)
}

/// TFLite `QuantizeMultiplier`: real multiplier -> (Q31 mantissa, shift).
pub fn quantize_multiplier(real: f64) -> (i32, i32) {
    if real == 0.0 {
        return (0, 0);
    }
    let (mant, exp) = frexp(real);
    let mut q = (mant * (1i64 << 31) as f64).round() as i64;
    let mut shift = exp;
    if q == 1i64 << 31 {
        q /= 2;
        shift += 1;
    }
    if shift < -31 {
        return (0, 0);
    }
    (q as i32, shift)
}

/// libm `frexp` for f64 (mantissa in [0.5, 1), power-of-two exponent).
fn frexp(v: f64) -> (f64, i32) {
    if v == 0.0 || v.is_nan() || v.is_infinite() {
        return (v, 0);
    }
    let bits = v.to_bits();
    let exp_bits = ((bits >> 52) & 0x7ff) as i32;
    if exp_bits == 0 {
        // subnormal: scale up first
        let (m, e) = frexp(v * (1u64 << 54) as f64);
        return (m, e - 54);
    }
    let exp = exp_bits - 1022;
    let mant_bits = (bits & !(0x7ffu64 << 52)) | (1022u64 << 52);
    (f64::from_bits(mant_bits), exp)
}

/// The full PPU scalar path: bias add happens before, this performs
/// requantize + zero-point add + activation clamp + narrow.
#[inline]
pub fn ppu_requant(acc: i32, mult: i32, shift: i32, out_zp: i32, act_min: i32, act_max: i32) -> i8 {
    let v = multiply_by_quantized_multiplier(acc, mult, shift) + out_zp;
    v.clamp(act_min, act_max) as i8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn srdhm_saturates() {
        assert_eq!(srdhm(i32::MIN, i32::MIN), i32::MAX);
    }

    #[test]
    fn srdhm_half_multiplier_even() {
        // SRDHM(a, 2^30) == a/2 exactly for even a
        for a in [-100, -2, 0, 2, 100, 123456] {
            assert_eq!(srdhm(a, 1 << 30), a / 2, "a={a}");
        }
    }

    #[test]
    fn srdhm_truncating_division_semantics() {
        // Regression for the floor-vs-trunc subtlety: a=-1, b=0.75*2^31.
        let b = (0.75 * (1i64 << 31) as f64) as i32;
        assert_eq!(srdhm(-1, b), -1); // floor would give -2
    }

    #[test]
    fn rdbypot_rounds_to_nearest_away() {
        assert_eq!(rounding_divide_by_pot(5, 1), 3); // 2.5 -> 3
        assert_eq!(rounding_divide_by_pot(-5, 1), -3); // -2.5 -> -3
        assert_eq!(rounding_divide_by_pot(4, 1), 2);
        assert_eq!(rounding_divide_by_pot(7, 2), 2); // 1.75 -> 2
        assert_eq!(rounding_divide_by_pot(-7, 2), -2);
        assert_eq!(rounding_divide_by_pot(123, 0), 123);
    }

    #[test]
    fn quantize_multiplier_range() {
        for real in [0.25, 0.5, 0.75, 0.9999, 0.0001, 1.5] {
            let (m, s) = quantize_multiplier(real);
            let recon = m as f64 / (1i64 << 31) as f64 * 2f64.powi(s);
            assert!((recon - real).abs() / real < 1e-6, "real={real}");
            assert!(m >= 1 << 30, "mantissa normalized: {m}");
        }
        assert_eq!(quantize_multiplier(0.0), (0, 0));
    }

    #[test]
    fn frexp_matches_definition() {
        for v in [1.0, 0.5, 0.75, 3.14159, 1e-12, 123456.789] {
            let (m, e) = frexp(v);
            assert!((0.5..1.0).contains(&m), "v={v} m={m}");
            assert!((m * 2f64.powi(e) - v).abs() < 1e-15 * v.abs().max(1.0));
        }
    }

    #[test]
    fn qparams_round_trip() {
        let q = QParams::from_range(-1.0, 1.0);
        for v in [-1.0f32, -0.5, 0.0, 0.5, 0.9999] {
            let d = q.dequantize(q.quantize(v));
            assert!((d - v).abs() <= q.scale, "v={v} d={d}");
        }
    }

    #[test]
    fn qparams_zero_always_exact() {
        // the real value 0.0 must be exactly representable (TFLite req)
        for (lo, hi) in [(-1.0, 1.0), (0.0, 6.0), (-0.3, 2.7)] {
            let q = QParams::from_range(lo, hi);
            assert_eq!(q.dequantize(q.quantize(0.0)), 0.0);
        }
    }

    #[test]
    fn ppu_requant_clamps() {
        // huge accumulator clamps to act_max
        let (m, s) = quantize_multiplier(0.5);
        assert_eq!(ppu_requant(i32::MAX / 2, m, s, 0, -128, 127), 127);
        assert_eq!(ppu_requant(i32::MIN / 2, m, s, 0, -128, 127), -128);
        assert_eq!(ppu_requant(10, m, s, 3, 0, 6), 6); // relu6 window
    }
}
