//! The GEMM interception point (paper Fig. 2).
//!
//! TFLite convolutions execute through gemmlowp; SECDA modifies that
//! call-site so a co-designed driver can offload the GEMM. Here the
//! same seam is the [`GemmBackend`] trait: the conv/FC ops build the
//! (W, im2col(X)) matrices and call whichever backend the session is
//! configured with — the CPU baseline ([`CpuBackend`]), an
//! accelerator driver ([`crate::driver::AccelBackend`]), or the L3
//! serving pool ([`crate::coordinator::CoordinatorBackend`]), which
//! dispatches each layer to whichever pool instance frees up first
//! and partitions HW/SW per layer by the calibrated perf model.

use crate::gemm::{self, QGemmParams};
use crate::perf::CpuModel;
use crate::sysc::SimTime;

/// One GEMM offload request from a conv/FC layer.
pub struct GemmTask<'a> {
    /// Output rows (the layer's output channels).
    pub m: usize,
    /// Reduction depth (kh*kw*cin for a conv).
    pub k: usize,
    /// Output columns (spatial positions after im2col).
    pub n: usize,
    /// Row-major `m x k` weight matrix.
    pub weights: &'a [i8],
    /// Row-major `k x n` im2col activation matrix.
    pub inputs: &'a [i8],
    /// Requantization parameters (bias already zero-point-folded).
    pub params: &'a QGemmParams,
    /// Layer name (bucket charging and cross-check reporting).
    pub layer: &'a str,
    /// True when the layer's weights are already resident on the
    /// accelerator (preloaded once per session).
    pub weights_resident: bool,
}

impl GemmTask<'_> {
    /// Multiply-accumulate count of this GEMM (`m * k * n`).
    pub fn macs(&self) -> u64 {
        gemm::mac_count(self.m, self.k, self.n)
    }
}

/// Modeled timing of one GEMM execution (PYNQ-Z1 time base).
#[derive(Debug, Clone, Default)]
pub struct GemmTiming {
    /// Contribution to the layer's CONV wall time.
    pub total: SimTime,
    /// CPU-busy portion (prep + unpack + CPU compute).
    pub cpu_time: SimTime,
    /// Fabric-active time (drives the energy model).
    pub accel_active: SimTime,
    /// Named components for breakdown reporting (§V-B's 31%/69%).
    pub breakdown: Vec<(&'static str, SimTime)>,
}

/// Where a conv/FC layer's GEMM runs.
///
/// Implementations that should be poolable under
/// [`crate::coordinator::ExecMode::Threaded`] must also be [`Send`]
/// (see [`crate::driver::DriverHandle`], which boxes backends as
/// `dyn GemmBackend + Send` so worker threads can own them).
pub trait GemmBackend {
    /// Short backend label (`cpu`, `sa`, `vm`, `coordinator`, ...).
    fn name(&self) -> &str;
    /// Execute the GEMM, returning the int8 output (`m*n`) and the
    /// modeled timing.
    fn run_gemm(&mut self, task: &GemmTask<'_>) -> (Vec<i8>, GemmTiming);
    /// Accumulated driver statistics, for backends that wrap an
    /// accelerator driver (lets pool owners report per-instance
    /// offloads/bytes through the trait object).
    fn driver_stats(&self) -> Option<&crate::driver::DriverStats> {
        None
    }
    /// Drain the simulator-kernel events recorded during the most
    /// recent [`GemmBackend::run_gemm`], when the backend bridges a
    /// [`crate::sysc::Trace`] out of its simulated fabric (see
    /// [`crate::driver::DriverConfig::sim_trace`]). Backends without a
    /// simulator (CPU baseline) return nothing.
    fn take_sim_trace(&mut self) -> Vec<crate::sysc::trace::TraceEntry> {
        Vec::new()
    }
}

/// The CPU-only baseline: gemmlowp on 1 or 2 A9 threads.
pub struct CpuBackend {
    /// The timing model charged for each GEMM.
    pub model: CpuModel,
    /// CPU threads the kernels (and the timing model) use.
    pub threads: usize,
}

impl CpuBackend {
    /// The paper-fidelity baseline, timed as the PYNQ-Z1 Cortex-A9
    /// ([`CpuModel::pynq_a9`]).
    pub fn new(threads: usize) -> Self {
        CpuBackend {
            model: CpuModel::pynq_a9(),
            threads,
        }
    }

    /// A CPU backend timed by an explicit model — the serving pool
    /// prices its workers with [`CpuModel::serving`], matching the
    /// arch-dispatched SIMD kernels they actually run
    /// ([`crate::gemm::simd`]).
    pub fn with_model(model: CpuModel, threads: usize) -> Self {
        CpuBackend { model, threads }
    }
}

impl GemmBackend for CpuBackend {
    fn name(&self) -> &str {
        "cpu"
    }

    fn run_gemm(&mut self, task: &GemmTask<'_>) -> (Vec<i8>, GemmTiming) {
        let out = gemm::qgemm(
            task.weights,
            task.inputs,
            task.m,
            task.k,
            task.n,
            task.params,
            self.threads,
        );
        let t = self.model.gemm_time(task.macs(), self.threads);
        let timing = GemmTiming {
            total: t,
            cpu_time: t,
            accel_active: SimTime::ZERO,
            breakdown: vec![("cpu_gemm", t)],
        };
        (out, timing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::quant::quantize_multiplier;

    #[test]
    fn cpu_backend_functional_and_timed() {
        let (m, k, n) = (8, 16, 8);
        let w: Vec<i8> = (0..m * k).map(|i| (i % 7) as i8 - 3).collect();
        let x: Vec<i8> = (0..k * n).map(|i| (i % 11) as i8 - 5).collect();
        let (mult, shift) = quantize_multiplier(0.1);
        let p = QGemmParams::uniform(m, 5, mult, shift);
        let mut b = CpuBackend::new(1);
        let task = GemmTask {
            m,
            k,
            n,
            weights: &w,
            inputs: &x,
            params: &p,
            layer: "t",
            weights_resident: false,
        };
        let (out, timing) = b.run_gemm(&task);
        assert_eq!(out, gemm::qgemm(&w, &x, m, k, n, &p, 1));
        assert!(timing.total > SimTime::ZERO);
        assert_eq!(timing.accel_active, SimTime::ZERO);
    }

    #[test]
    fn two_threads_faster() {
        let p = QGemmParams::uniform(64, 0, 1 << 30, 0);
        let w = vec![1i8; 64 * 64];
        let x = vec![1i8; 64 * 64];
        let task = GemmTask {
            m: 64,
            k: 64,
            n: 64,
            weights: &w,
            inputs: &x,
            params: &p,
            layer: "t",
            weights_resident: false,
        };
        let t1 = CpuBackend::new(1).run_gemm(&task).1.total;
        let t2 = CpuBackend::new(2).run_gemm(&task).1.total;
        assert!(t2 < t1);
    }
}
