//! Quantized element-wise ops: residual Add and channel Concat.

use crate::framework::ops::{Activation, OpCtx, TimeBucket};
use crate::framework::quant::{multiply_by_quantized_multiplier, quantize_multiplier, QParams};
use crate::framework::tensor::Tensor;

/// TFLite-style quantized add: both operands are rescaled into a
/// shared fixed-point domain (left-shift 20), summed, then requantized
/// to the output scale.
#[derive(Debug, Clone)]
pub struct AddOp {
    /// Layer name.
    pub name: String,
    /// Output quantization.
    pub out_qp: QParams,
    /// Fused activation.
    pub act: Activation,
}

const ADD_LEFT_SHIFT: i32 = 20;

impl AddOp {
    /// Element-wise quantized add of two same-shape tensors.
    pub fn eval(&self, a: &Tensor, b: &Tensor, ctx: &mut OpCtx<'_>) -> Tensor {
        assert_eq!(a.shape, b.shape, "{}: shape mismatch", self.name);
        let twice_max = 2.0 * a.qp.scale.max(b.qp.scale) as f64;
        let (m_a, s_a) = quantize_multiplier(a.qp.scale as f64 / twice_max);
        let (m_b, s_b) = quantize_multiplier(b.qp.scale as f64 / twice_max);
        let (m_o, s_o) = quantize_multiplier(
            twice_max / ((1i64 << ADD_LEFT_SHIFT) as f64 * self.out_qp.scale as f64),
        );
        let (act_min, act_max) = self.act.window(&self.out_qp);
        let mut out = vec![0i8; a.numel()];
        for i in 0..a.numel() {
            let av = ((a.data[i] as i32) - a.qp.zero_point) << ADD_LEFT_SHIFT;
            let bv = ((b.data[i] as i32) - b.qp.zero_point) << ADD_LEFT_SHIFT;
            let sa = multiply_by_quantized_multiplier(av, m_a, s_a);
            let sb = multiply_by_quantized_multiplier(bv, m_b, s_b);
            let sum = sa.wrapping_add(sb);
            let v = multiply_by_quantized_multiplier(sum, m_o, s_o) + self.out_qp.zero_point;
            out[i] = v.clamp(act_min, act_max) as i8;
        }
        let t = ctx
            .cpu
            .elementwise_time(2 * a.numel() as u64, ctx.threads);
        ctx.charge(&self.name, TimeBucket::NonConv, t);
        Tensor::new(a.shape.clone(), out, self.out_qp)
    }
}

/// Channel-dimension concat; inputs are requantized to the output
/// scale when their params differ (TFLite semantics).
#[derive(Debug, Clone)]
pub struct ConcatOp {
    /// Layer name.
    pub name: String,
    /// Output quantization.
    pub out_qp: QParams,
}

impl ConcatOp {
    /// Concatenate along the channel dimension.
    pub fn eval(&self, inputs: &[&Tensor], ctx: &mut OpCtx<'_>) -> Tensor {
        assert!(!inputs.is_empty());
        let (_, h, w, _) = inputs[0].nhwc();
        let mut c_total = 0;
        for t in inputs {
            let (_, th, tw, tc) = t.nhwc();
            assert_eq!((th, tw), (h, w), "{}: spatial mismatch", self.name);
            c_total += tc;
        }
        let mut out = vec![0i8; h * w * c_total];
        let mut c_off = 0;
        let mut total_bytes = 0u64;
        for t in inputs {
            let (_, _, _, tc) = t.nhwc();
            let same = t.qp == self.out_qp;
            let (m, s) = if same {
                (0, 0)
            } else {
                quantize_multiplier(t.qp.scale as f64 / self.out_qp.scale as f64)
            };
            for p in 0..h * w {
                for cc in 0..tc {
                    let v = t.data[p * tc + cc];
                    out[p * c_total + c_off + cc] = if same {
                        v
                    } else {
                        let shifted = (v as i32) - t.qp.zero_point;
                        let r = multiply_by_quantized_multiplier(shifted, m, s)
                            + self.out_qp.zero_point;
                        r.clamp(-128, 127) as i8
                    };
                }
            }
            c_off += tc;
            total_bytes += t.numel() as u64;
        }
        let t = ctx.cpu.elementwise_time(total_bytes, ctx.threads);
        ctx.charge(&self.name, TimeBucket::NonConv, t);
        Tensor::new(vec![1, h, w, c_total], out, self.out_qp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::backend::CpuBackend;
    use crate::perf::CpuModel;

    fn ctx_eval<F: FnOnce(&mut OpCtx<'_>) -> Tensor>(f: F) -> Tensor {
        let cpu = CpuModel::pynq_a9();
        let mut b = CpuBackend::new(1);
        let mut ctx = OpCtx::new(&mut b, &cpu, 1);
        f(&mut ctx)
    }

    #[test]
    fn add_same_scale_is_plain_sum() {
        let qp = QParams::new(0.1, 0);
        let a = Tensor::new(vec![1, 1, 1, 4], vec![10, 20, -30, 40], qp);
        let b = Tensor::new(vec![1, 1, 1, 4], vec![1, 2, 3, -4], qp);
        let add = AddOp {
            name: "add".into(),
            out_qp: QParams::new(0.2, 0), // out scale 2x -> sum/2
            act: Activation::None,
        };
        let y = ctx_eval(|c| add.eval(&a, &b, c));
        // (a+b)*0.1/0.2 = (a+b)/2, rounded
        assert_eq!(y.data, vec![6, 11, -14, 18]);
    }

    #[test]
    fn add_dequantized_error_bounded() {
        let qa = QParams::new(0.07, 3);
        let qb = QParams::new(0.11, -5);
        let qo = QParams::new(0.15, 1);
        let a = Tensor::new(vec![1, 1, 1, 3], vec![50, -20, 100], qa);
        let b = Tensor::new(vec![1, 1, 1, 3], vec![-10, 60, 7], qb);
        let add = AddOp {
            name: "add".into(),
            out_qp: qo,
            act: Activation::None,
        };
        let y = ctx_eval(|c| add.eval(&a, &b, c));
        for i in 0..3 {
            let real = qa.dequantize(a.data[i]) + qb.dequantize(b.data[i]);
            let got = qo.dequantize(y.data[i]);
            assert!((real - got).abs() <= qo.scale, "i={i} {real} vs {got}");
        }
    }

    #[test]
    fn concat_same_params_is_interleave() {
        let qp = QParams::new(0.1, 0);
        let a = Tensor::new(vec![1, 1, 2, 2], vec![1, 2, 3, 4], qp);
        let b = Tensor::new(vec![1, 1, 2, 1], vec![9, 8], qp);
        let cat = ConcatOp {
            name: "cat".into(),
            out_qp: qp,
        };
        let y = ctx_eval(|c| cat.eval(&[&a, &b], c));
        assert_eq!(y.shape, vec![1, 1, 2, 3]);
        assert_eq!(y.data, vec![1, 2, 9, 3, 4, 8]);
    }

    #[test]
    fn concat_requantizes_mismatched_scales() {
        let a = Tensor::new(vec![1, 1, 1, 1], vec![100], QParams::new(0.1, 0));
        let b = Tensor::new(vec![1, 1, 1, 1], vec![100], QParams::new(0.2, 0));
        let cat = ConcatOp {
            name: "cat".into(),
            out_qp: QParams::new(0.1, 0),
        };
        let y = ctx_eval(|c| cat.eval(&[&a, &b], c));
        assert_eq!(y.data[0], 100); // same scale: unchanged
        assert_eq!(y.data[1], 127); // 100*0.2/0.1 = 200 -> saturates
    }
}
