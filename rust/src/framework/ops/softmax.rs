//! Softmax head. TFLite fixes the output quantization to
//! (scale 1/256, zero-point -128). The inner computation is
//! fixed-point, like the reference TFLite kernel: a per-call Q26 exp
//! table over the 256 possible `max - v` deltas, an i64 sum, and the
//! shared PPU requant step (which arch-dispatches with the GEMM
//! kernels) to land on the 1/256 output grid. The retired f32 shortcut
//! is kept as [`SoftmaxOp::eval_f32_reference`]; a unit test bounds
//! the fixed-point path within one output quantum of it.

use crate::framework::ops::{OpCtx, TimeBucket};
use crate::framework::quant::{quantize_multiplier, QParams};
use crate::framework::tensor::Tensor;
use crate::gemm::simd;

/// Fixed-point one: the Q26 representation of 1.0 in the exp table.
const ONE_Q26: f64 = (1i64 << 26) as f64;

/// The softmax head op (always last in the benchmark graphs).
#[derive(Debug, Clone)]
pub struct SoftmaxOp {
    /// Layer name used for per-op cost accounting.
    pub name: String,
}

impl SoftmaxOp {
    /// The TFLite-fixed output quantization (scale 1/256, zp -128).
    pub fn out_qp() -> QParams {
        QParams::new(1.0 / 256.0, -128)
    }

    /// Evaluate the head in fixed point and charge its modeled cost.
    pub fn eval(&self, x: &Tensor, ctx: &mut OpCtx<'_>) -> Tensor {
        let out = Self::eval_fixed(&x.data, x.qp.scale);
        let t = ctx.cpu.elementwise_time(x.numel() as u64 * 4, ctx.threads);
        ctx.charge(&self.name, TimeBucket::NonConv, t);
        Tensor::new(x.shape.clone(), out, Self::out_qp())
    }

    /// The fixed-point kernel: `exp((v - max) * scale)` via a 256-entry
    /// Q26 table indexed by `max - v` (an i8 delta, so always in
    /// `[0, 255]`), normalized by the shared requant step with real
    /// multiplier `256 / sum`. Deterministic for a given input within
    /// a process, and bit-identical across kernel tiers. The Q31
    /// multiplier stays in requant range for heads up to 16384
    /// classes — far above the benchmark models' 10..=1001.
    pub fn eval_fixed(data: &[i8], in_scale: f32) -> Vec<i8> {
        let max_q = i32::from(data.iter().copied().max().unwrap_or(0));
        let table: Vec<i32> = (0..256)
            .map(|d| ((-(d as f64) * in_scale as f64).exp() * ONE_Q26).round() as i32)
            .collect();
        let accs: Vec<i32> = data
            .iter()
            .map(|&v| table[(max_q - i32::from(v)) as usize])
            .collect();
        let sum: i64 = accs.iter().map(|&a| i64::from(a)).sum();
        let (mult, shift) = quantize_multiplier(256.0 / sum as f64);
        let mut out = vec![0i8; data.len()];
        let t = simd::tier();
        simd::requant_row(t, &accs, 0, mult, shift, -128, -128, 127, &mut out);
        out
    }

    /// The retired f32 evaluation, kept as the accuracy reference the
    /// fixed-point path is ULP-bounded against (no cost accounting).
    pub fn eval_f32_reference(x: &Tensor) -> Tensor {
        let vals = x.dequantize();
        let max = vals.iter().cloned().fold(f32::MIN, f32::max);
        let exps: Vec<f32> = vals.iter().map(|v| (v - max).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let qp = Self::out_qp();
        let out: Vec<i8> = exps.iter().map(|e| qp.quantize(e / sum)).collect();
        Tensor::new(x.shape.clone(), out, qp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::backend::CpuBackend;
    use crate::perf::CpuModel;

    #[test]
    fn softmax_sums_to_one() {
        let x = Tensor::new(
            vec![1, 5],
            vec![10, 20, 30, -10, 0],
            QParams::new(0.1, 0),
        );
        let sm = SoftmaxOp { name: "sm".into() };
        let cpu = CpuModel::pynq_a9();
        let mut b = CpuBackend::new(1);
        let mut ctx = OpCtx::new(&mut b, &cpu, 1);
        let y = sm.eval(&x, &mut ctx);
        let probs = y.dequantize();
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 0.05, "sum {sum}");
        // argmax preserved
        let argmax = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 2);
    }

    #[test]
    fn fixed_point_softmax_within_one_ulp_of_f32() {
        // deterministic pseudo-random sweep over scales and shapes
        let mut st = 0xdecafu64;
        let mut xorshift = move || {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            st
        };
        for &scale in &[1.0f32 / 256.0, 0.05, 0.1, 0.33] {
            for &len in &[1usize, 2, 10, 100, 1001] {
                let data: Vec<i8> = (0..len).map(|_| (xorshift() & 0xff) as u8 as i8).collect();
                let x = Tensor::new(vec![1, len], data.clone(), QParams::new(scale, 0));
                let fixed = SoftmaxOp::eval_fixed(&data, scale);
                let reference = SoftmaxOp::eval_f32_reference(&x);
                for (i, (&a, &b)) in fixed.iter().zip(&reference.data).enumerate() {
                    let d = (i32::from(a) - i32::from(b)).abs();
                    assert!(d <= 1, "idx {i}: fixed {a} vs f32 {b} (scale {scale})");
                }
            }
        }
    }
}
