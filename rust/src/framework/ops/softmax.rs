//! Softmax head. TFLite fixes the output quantization to
//! (scale 1/256, zero-point -128). The inner computation here uses
//! f32 (the reference TFLite kernel uses a fixed-point exp table; the
//! f32 shortcut changes results by < 1 ulp of the 1/256 output grid
//! and is documented as a substitution in DESIGN.md).

use crate::framework::ops::{OpCtx, TimeBucket};
use crate::framework::quant::QParams;
use crate::framework::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct SoftmaxOp {
    pub name: String,
}

impl SoftmaxOp {
    pub fn out_qp() -> QParams {
        QParams::new(1.0 / 256.0, -128)
    }

    pub fn eval(&self, x: &Tensor, ctx: &mut OpCtx<'_>) -> Tensor {
        let vals = x.dequantize();
        let max = vals.iter().cloned().fold(f32::MIN, f32::max);
        let exps: Vec<f32> = vals.iter().map(|v| (v - max).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let qp = Self::out_qp();
        let out: Vec<i8> = exps.iter().map(|e| qp.quantize(e / sum)).collect();
        let t = ctx.cpu.elementwise_time(x.numel() as u64 * 4, ctx.threads);
        ctx.charge(&self.name, TimeBucket::NonConv, t);
        Tensor::new(x.shape.clone(), out, qp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::backend::CpuBackend;
    use crate::perf::CpuModel;

    #[test]
    fn softmax_sums_to_one() {
        let x = Tensor::new(
            vec![1, 5],
            vec![10, 20, 30, -10, 0],
            QParams::new(0.1, 0),
        );
        let sm = SoftmaxOp { name: "sm".into() };
        let cpu = CpuModel::pynq_a9();
        let mut b = CpuBackend::new(1);
        let mut ctx = OpCtx::new(&mut b, &cpu, 1);
        let y = sm.eval(&x, &mut ctx);
        let probs = y.dequantize();
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 0.05, "sum {sum}");
        // argmax preserved
        let argmax = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 2);
    }
}
