//! Quantized 2-D convolution via GEMM ("GEMM convolution", §IV) — the
//! op the SECDA case study accelerates.
//!
//! `eval` performs im2col (padding with the input zero-point), folds
//! the zero-point into the bias (the driver contract shared with the
//! AOT artifacts), derives the per-channel requantization multipliers,
//! and calls the configured [`GemmBackend`] — the interception point
//! where the accelerator driver takes over (Fig. 2).

use crate::framework::backend::GemmTask;
use crate::framework::ops::{OpCtx, TimeBucket};
use crate::framework::quant::{quantize_multiplier, QParams};
use crate::framework::tensor::Tensor;
use crate::gemm::{self, QGemmParams};

/// Fused activation of a conv/FC layer (TFLite style).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    /// No activation (full int8 range).
    None,
    /// Clamp below at real 0.0.
    Relu,
    /// Clamp to real [0.0, 6.0].
    Relu6,
}

impl Activation {
    /// Quantized clamp window for an output with params `qp`.
    pub fn window(&self, qp: &QParams) -> (i32, i32) {
        match self {
            Activation::None => (-128, 127),
            Activation::Relu => (qp.zero_point.max(-128), 127),
            Activation::Relu6 => {
                let hi = qp.zero_point + (6.0 / qp.scale).round() as i32;
                (qp.zero_point.max(-128), hi.min(127))
            }
        }
    }
}

/// Quantized conv2d. Weights are `[cout, kh, kw, cin]` int8 with
/// per-output-channel scales (TFLite int8 spec: symmetric weights).
#[derive(Debug, Clone)]
pub struct Conv2d {
    /// Layer name.
    pub name: String,
    /// Output channels.
    pub cout: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Input channels.
    pub cin: usize,
    /// Spatial stride (both axes).
    pub stride: usize,
    /// Zero padding (both axes).
    pub pad: usize,
    /// `[cout, kh, kw, cin]` int8 weights.
    pub weights: Vec<i8>,
    /// Per-output-channel int32 bias.
    pub bias: Vec<i32>,
    /// Per-output-channel weight scales.
    pub w_scales: Vec<f32>,
    /// Output quantization.
    pub out_qp: QParams,
    /// Fused activation.
    pub act: Activation,
    /// Weights preloaded on the accelerator across inferences.
    pub weights_resident: bool,
}

impl Conv2d {
    /// Output spatial dims for an `h`×`w` input.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.kh) / self.stride + 1,
            (w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }

    /// im2col: NHWC input -> `[K, N]` matrix, K = kh*kw*cin (kh-major,
    /// then kw, then cin — matching python/compile/model.py), N =
    /// oh*ow. Out-of-bounds positions take the input zero-point so
    /// they vanish after offset folding.
    pub fn im2col(&self, x: &Tensor) -> (Vec<i8>, usize, usize) {
        let (_, h, w, c) = x.nhwc();
        assert_eq!(c, self.cin, "{}: cin mismatch", self.name);
        let (oh, ow) = self.out_hw(h, w);
        let n = oh * ow;
        let k = self.kh * self.kw * c;
        let zp = x.qp.zero_point.clamp(-128, 127) as i8;
        let mut cols = vec![zp; k * n];
        let pad = self.pad as isize;
        for ki in 0..self.kh {
            for kj in 0..self.kw {
                for oy in 0..oh {
                    let iy = oy as isize * self.stride as isize + ki as isize - pad;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for ox in 0..ow {
                        let ix = ox as isize * self.stride as isize + kj as isize - pad;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((iy as usize * w) + ix as usize) * c;
                        let col = oy * ow + ox;
                        let row_base = (ki * self.kw + kj) * c;
                        for cc in 0..c {
                            cols[(row_base + cc) * n + col] = x.data[src + cc];
                        }
                    }
                }
            }
        }
        (cols, k, n)
    }

    /// Build the requantization params for input qp `in_qp`.
    pub fn qgemm_params(&self, in_qp: &QParams) -> QGemmParams {
        let k = self.kh * self.kw * self.cin;
        let folded = gemm::fold_bias(&self.bias, &self.weights, self.cout, k, in_qp.zero_point);
        let mut mult = Vec::with_capacity(self.cout);
        let mut shift = Vec::with_capacity(self.cout);
        for oc in 0..self.cout {
            let real = in_qp.scale as f64 * self.w_scales[oc] as f64 / self.out_qp.scale as f64;
            let (m, s) = quantize_multiplier(real);
            mult.push(m);
            shift.push(s);
        }
        let (act_min, act_max) = self.act.window(&self.out_qp);
        QGemmParams {
            bias: folded,
            mult,
            shift,
            out_zp: self.out_qp.zero_point,
            act_min,
            act_max,
        }
    }

    /// Run the convolution through the GEMM seam.
    pub fn eval(&self, x: &Tensor, ctx: &mut OpCtx<'_>) -> Tensor {
        let (_, h, w, _) = x.nhwc();
        let (oh, ow) = self.out_hw(h, w);
        let (cols, k, n) = self.im2col(x);
        let params = self.qgemm_params(&x.qp);
        let task = GemmTask {
            m: self.cout,
            k,
            n,
            weights: &self.weights,
            inputs: &cols,
            params: &params,
            layer: &self.name,
            weights_resident: self.weights_resident,
        };
        let (out_mn, mut timing) = ctx.backend.run_gemm(&task);
        // The CPU baseline path pays im2col here; accelerator drivers
        // already include data prep in their own timing.
        let cpu_path = timing.breakdown.iter().any(|(n, _)| *n == "cpu_gemm");
        if timing.accel_active.as_ps() == 0 && cpu_path {
            timing.total += ctx.cpu.reshape_time((k * n) as u64, ctx.threads);
        }
        ctx.accel_active += timing.accel_active;
        ctx.charge(&self.name, TimeBucket::Conv, timing.total);

        // out_mn is [cout, oh*ow] (M x N); convert to NHWC
        let mut nhwc = vec![0i8; oh * ow * self.cout];
        for oc in 0..self.cout {
            for p in 0..oh * ow {
                nhwc[p * self.cout + oc] = out_mn[oc * (oh * ow) + p];
            }
        }
        Tensor::new(vec![1, oh, ow, self.cout], nhwc, self.out_qp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::backend::CpuBackend;
    use crate::perf::CpuModel;

    fn mk_conv(cout: usize, kh: usize, cin: usize, stride: usize, pad: usize) -> Conv2d {
        let k = kh * kh * cin;
        let mut st = 0xdeadbeefu64;
        let mut rnd = || {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            st
        };
        Conv2d {
            name: "conv_t".into(),
            cout,
            kh,
            kw: kh,
            cin,
            stride,
            pad,
            weights: (0..cout * k).map(|_| (rnd() & 0xff) as u8 as i8).collect(),
            bias: (0..cout).map(|_| (rnd() % 512) as i32 - 256).collect(),
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, -5),
            act: Activation::None,
            weights_resident: false,
        }
    }

    fn mk_input(h: usize, c: usize) -> Tensor {
        let mut st = 777u64;
        let mut rnd = || {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            st
        };
        Tensor::new(
            vec![1, h, h, c],
            (0..h * h * c).map(|_| (rnd() & 0xff) as u8 as i8).collect(),
            QParams::new(0.05, 3),
        )
    }

    /// Direct O(n^4) reference convolution.
    fn direct(conv: &Conv2d, x: &Tensor) -> Vec<i8> {
        use crate::framework::quant::ppu_requant;
        let (_, h, w, c) = x.nhwc();
        let (oh, ow) = conv.out_hw(h, w);
        let p = conv.qgemm_params(&x.qp);
        let zp_in = x.qp.zero_point;
        let mut out = vec![0i8; oh * ow * conv.cout];
        for oc in 0..conv.cout {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc: i64 = 0;
                    for ki in 0..conv.kh {
                        for kj in 0..conv.kw {
                            let iy = (oy * conv.stride + ki) as isize - conv.pad as isize;
                            let ix = (ox * conv.stride + kj) as isize - conv.pad as isize;
                            for cc in 0..c {
                                let xv = if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize
                                {
                                    0 // (x - zp) of padding is zero
                                } else {
                                    x.data[((iy as usize * w) + ix as usize) * c + cc] as i64
                                        - zp_in as i64
                                };
                                let wv = conv.weights
                                    [((oc * conv.kh + ki) * conv.kw + kj) * c + cc]
                                    as i64;
                                acc += wv * xv;
                            }
                        }
                    }
                    // p.bias has the zp fold; undo it by using raw bias
                    let raw_acc = acc as i32 + conv.bias[oc];
                    out[(oy * ow + ox) * conv.cout + oc] = ppu_requant(
                        raw_acc,
                        p.mult[oc],
                        p.shift[oc],
                        p.out_zp,
                        p.act_min,
                        p.act_max,
                    );
                }
            }
        }
        out
    }

    #[test]
    fn conv_matches_direct_reference() {
        for (cout, kh, cin, stride, pad, h) in [
            (8, 3, 4, 1, 1, 8),
            (8, 3, 4, 2, 1, 9),
            (16, 1, 8, 1, 0, 6),
            (4, 5, 3, 2, 2, 11),
            (6, 7, 3, 2, 3, 14),
        ] {
            let conv = mk_conv(cout, kh, cin, stride, pad);
            let x = mk_input(h, cin);
            let cpu = CpuModel::pynq_a9();
            let mut backend = CpuBackend::new(1);
            let mut ctx = OpCtx::new(&mut backend, &cpu, 1);
            let y = conv.eval(&x, &mut ctx);
            assert_eq!(y.data, direct(&conv, &x), "cfg ({cout},{kh},{cin},{stride},{pad})");
            assert!(ctx.conv_time > crate::sysc::SimTime::ZERO);
            assert_eq!(ctx.nonconv_time, crate::sysc::SimTime::ZERO);
        }
    }

    #[test]
    fn relu6_window_clamps() {
        let mut conv = mk_conv(4, 3, 4, 1, 1);
        conv.act = Activation::Relu6;
        let (lo, hi) = conv.act.window(&conv.out_qp);
        assert_eq!(lo, -5);
        assert_eq!(hi, -5 + 120);
        let x = mk_input(6, 4);
        let cpu = CpuModel::pynq_a9();
        let mut backend = CpuBackend::new(1);
        let mut ctx = OpCtx::new(&mut backend, &cpu, 1);
        let y = conv.eval(&x, &mut ctx);
        assert!(y.data.iter().all(|&v| (lo..=hi).contains(&(v as i32))));
    }

    #[test]
    fn im2col_shapes() {
        let conv = mk_conv(4, 3, 2, 2, 1);
        let x = mk_input(8, 2);
        let (cols, k, n) = conv.im2col(&x);
        assert_eq!(k, 3 * 3 * 2);
        assert_eq!(n, 4 * 4);
        assert_eq!(cols.len(), k * n);
    }

    #[test]
    fn accel_backend_agrees_with_cpu_backend() {
        use crate::accel::SaDesign;
        use crate::driver::{AccelBackend, DriverConfig};
        let conv = mk_conv(16, 3, 8, 1, 1);
        let x = mk_input(10, 8);
        let cpu = CpuModel::pynq_a9();
        let mut cb = CpuBackend::new(1);
        let mut ctx1 = OpCtx::new(&mut cb, &cpu, 1);
        let y_cpu = conv.eval(&x, &mut ctx1);
        let mut ab = AccelBackend::new(SaDesign::paper(), DriverConfig::default());
        let mut ctx2 = OpCtx::new(&mut ab, &cpu, 1);
        let y_acc = conv.eval(&x, &mut ctx2);
        assert_eq!(y_cpu.data, y_acc.data);
        assert!(ctx2.accel_active > crate::sysc::SimTime::ZERO);
    }
}
