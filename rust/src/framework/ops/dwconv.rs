//! Quantized depthwise convolution (MobileNet family).
//!
//! In TFLite depthwise convs do not go through the gemmlowp GEMM, so
//! the paper's accelerators never see them — they run on the CPU and
//! count toward the CONV bucket of Table II (they are conv layers).
//! This is why MobileNets profit less from the accelerators (§V-B).

use crate::framework::ops::{Activation, OpCtx, TimeBucket};
use crate::framework::quant::{ppu_requant, quantize_multiplier, QParams};
use crate::framework::tensor::Tensor;

/// Depthwise conv: one `kh x kw` filter per channel (multiplier 1).
#[derive(Debug, Clone)]
pub struct DepthwiseConv2d {
    /// Layer name.
    pub name: String,
    /// Channel count (input == output).
    pub channels: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Spatial stride (both axes).
    pub stride: usize,
    /// Zero padding (both axes).
    pub pad: usize,
    /// `[kh, kw, channels]` int8 filters.
    pub weights: Vec<i8>,
    /// Per-channel int32 bias.
    pub bias: Vec<i32>,
    /// Per-channel weight scales.
    pub w_scales: Vec<f32>,
    /// Output quantization.
    pub out_qp: QParams,
    /// Fused activation.
    pub act: Activation,
}

impl DepthwiseConv2d {
    /// Output spatial dims for an `h`×`w` input.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.kh) / self.stride + 1,
            (w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }

    /// Multiply-accumulate count for an `h`×`w` input.
    pub fn macs(&self, h: usize, w: usize) -> u64 {
        let (oh, ow) = self.out_hw(h, w);
        (oh * ow * self.channels * self.kh * self.kw) as u64
    }

    /// Run the depthwise convolution on the CPU.
    pub fn eval(&self, x: &Tensor, ctx: &mut OpCtx<'_>) -> Tensor {
        let (_, h, w, c) = x.nhwc();
        assert_eq!(c, self.channels, "{}: channel mismatch", self.name);
        let (oh, ow) = self.out_hw(h, w);
        let zp_in = x.qp.zero_point;
        let (act_min, act_max) = self.act.window(&self.out_qp);

        // per-channel requant params
        let mut mult = vec![0i32; c];
        let mut shift = vec![0i32; c];
        for cc in 0..c {
            let real = x.qp.scale as f64 * self.w_scales[cc] as f64 / self.out_qp.scale as f64;
            let (m, s) = quantize_multiplier(real);
            mult[cc] = m;
            shift[cc] = s;
        }

        let mut out = vec![0i8; oh * ow * c];
        let pad = self.pad as isize;
        for oy in 0..oh {
            for ox in 0..ow {
                for cc in 0..c {
                    let mut acc: i32 = self.bias[cc];
                    for ki in 0..self.kh {
                        let iy = oy as isize * self.stride as isize + ki as isize - pad;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kj in 0..self.kw {
                            let ix = ox as isize * self.stride as isize + kj as isize - pad;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let xv = x.data[((iy as usize) * w + ix as usize) * c + cc] as i32
                                - zp_in;
                            let wv = self.weights[(ki * self.kw + kj) * c + cc] as i32;
                            acc += wv * xv;
                        }
                    }
                    let zp = self.out_qp.zero_point;
                    out[(oy * ow + ox) * c + cc] =
                        ppu_requant(acc, mult[cc], shift[cc], zp, act_min, act_max);
                }
            }
        }
        let t = ctx.cpu.dwconv_time(self.macs(h, w), ctx.threads);
        ctx.charge(&self.name, TimeBucket::Conv, t);
        Tensor::new(vec![1, oh, ow, c], out, self.out_qp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::backend::CpuBackend;
    use crate::perf::CpuModel;

    fn mk(channels: usize, stride: usize) -> DepthwiseConv2d {
        let mut st = 99u64;
        let mut rnd = || {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            st
        };
        DepthwiseConv2d {
            name: "dw_t".into(),
            channels,
            kh: 3,
            kw: 3,
            stride,
            pad: 1,
            weights: (0..9 * channels).map(|_| (rnd() & 0xff) as u8 as i8).collect(),
            bias: (0..channels).map(|_| (rnd() % 200) as i32 - 100).collect(),
            w_scales: vec![0.02; channels],
            out_qp: QParams::new(0.05, 0),
            act: Activation::None,
        }
    }

    #[test]
    fn identity_filter_passes_signal_through() {
        // single channel, center tap = 1/w_scale-quantized identity
        let mut dw = mk(1, 1);
        dw.weights = vec![0, 0, 0, 0, 50, 0, 0, 0, 0]; // center 50
        dw.bias = vec![0];
        // real multiplier: in 0.05 * w 0.02 / out 0.05 = 0.02;
        // out ≈ (x - zp) * 50 * 0.02 = x - zp
        let x = Tensor::new(
            vec![1, 3, 3, 1],
            vec![10, -20, 30, 40, -50, 60, 70, -80, 90],
            QParams::new(0.05, 0),
        );
        let cpu = CpuModel::pynq_a9();
        let mut b = CpuBackend::new(1);
        let mut ctx = OpCtx::new(&mut b, &cpu, 1);
        let y = dw.eval(&x, &mut ctx);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn stride_two_halves_spatial() {
        let dw = mk(4, 2);
        let x = Tensor::zeros(vec![1, 8, 8, 4], QParams::new(0.05, 0));
        let cpu = CpuModel::pynq_a9();
        let mut b = CpuBackend::new(1);
        let mut ctx = OpCtx::new(&mut b, &cpu, 1);
        let y = dw.eval(&x, &mut ctx);
        assert_eq!(y.shape, vec![1, 4, 4, 4]);
    }

    #[test]
    fn charges_conv_bucket() {
        let dw = mk(8, 1);
        let x = Tensor::zeros(vec![1, 6, 6, 8], QParams::new(0.05, 0));
        let cpu = CpuModel::pynq_a9();
        let mut b = CpuBackend::new(1);
        let mut ctx = OpCtx::new(&mut b, &cpu, 1);
        dw.eval(&x, &mut ctx);
        assert!(ctx.conv_time > crate::sysc::SimTime::ZERO);
        assert_eq!(ctx.nonconv_time, crate::sysc::SimTime::ZERO);
    }
}
