//! Quantized fully-connected layer (the classifier heads).
//!
//! FC layers run on the CPU and land in the Non-CONV bucket: the paper
//! accelerates only the convolutional layers (§IV: "We accelerate the
//! convolutional layers"). Functionally this is a GEMM with N = 1.

use crate::framework::ops::{Activation, OpCtx, TimeBucket};
use crate::framework::quant::{quantize_multiplier, QParams};
use crate::framework::tensor::Tensor;
use crate::gemm::{self, QGemmParams};

/// Quantized fully-connected layer over a flattened input.
#[derive(Debug, Clone)]
pub struct FullyConnected {
    /// Layer name.
    pub name: String,
    /// Flattened input size.
    pub in_features: usize,
    /// Output size.
    pub out_features: usize,
    /// `[out_features, in_features]` int8 weights (per-tensor scale).
    pub weights: Vec<i8>,
    /// Per-output int32 bias.
    pub bias: Vec<i32>,
    /// The per-tensor weight scale.
    pub w_scale: f32,
    /// Output quantization.
    pub out_qp: QParams,
    /// Fused activation.
    pub act: Activation,
}

impl FullyConnected {
    /// Run the layer (a GEMM with N = 1) on the CPU.
    pub fn eval(&self, x: &Tensor, ctx: &mut OpCtx<'_>) -> Tensor {
        assert_eq!(
            x.numel(),
            self.in_features,
            "{}: flattened input size mismatch",
            self.name
        );
        let folded = gemm::fold_bias(
            &self.bias,
            &self.weights,
            self.out_features,
            self.in_features,
            x.qp.zero_point,
        );
        let real = x.qp.scale as f64 * self.w_scale as f64 / self.out_qp.scale as f64;
        let (mult, shift) = quantize_multiplier(real);
        let (act_min, act_max) = self.act.window(&self.out_qp);
        let params = QGemmParams {
            bias: folded,
            mult: vec![mult; self.out_features],
            shift: vec![shift; self.out_features],
            out_zp: self.out_qp.zero_point,
            act_min,
            act_max,
        };
        let out = gemm::qgemm(
            &self.weights,
            &x.data,
            self.out_features,
            self.in_features,
            1,
            &params,
            ctx.threads,
        );
        let macs = (self.out_features * self.in_features) as u64;
        let t = ctx.cpu.gemm_time(macs, ctx.threads);
        ctx.charge(&self.name, TimeBucket::NonConv, t);
        Tensor::new(vec![1, self.out_features], out, self.out_qp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::backend::CpuBackend;
    use crate::framework::quant::ppu_requant;
    use crate::perf::CpuModel;

    #[test]
    fn fc_matches_scalar_reference() {
        let (fin, fout) = (12, 5);
        let mut st = 31u64;
        let mut rnd = || {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            st
        };
        let fc = FullyConnected {
            name: "fc_t".into(),
            in_features: fin,
            out_features: fout,
            weights: (0..fin * fout).map(|_| (rnd() & 0xff) as u8 as i8).collect(),
            bias: (0..fout).map(|_| (rnd() % 100) as i32).collect(),
            w_scale: 0.01,
            out_qp: QParams::new(0.1, 4),
            act: Activation::None,
        };
        let x = Tensor::new(
            vec![1, fin],
            (0..fin).map(|_| (rnd() & 0xff) as u8 as i8).collect(),
            QParams::new(0.05, -3),
        );
        let cpu = CpuModel::pynq_a9();
        let mut b = CpuBackend::new(1);
        let mut ctx = OpCtx::new(&mut b, &cpu, 1);
        let y = fc.eval(&x, &mut ctx);

        let real = 0.05f64 * 0.01 / 0.1;
        let (m, s) = quantize_multiplier(real);
        for o in 0..fout {
            let mut acc: i64 = fc.bias[o] as i64;
            for i in 0..fin {
                acc += fc.weights[o * fin + i] as i64 * (x.data[i] as i64 - (-3));
            }
            let want = ppu_requant(acc as i32, m, s, 4, -128, 127);
            assert_eq!(y.data[o], want, "out {o}");
        }
        // FC is Non-CONV time
        assert_eq!(ctx.conv_time, crate::sysc::SimTime::ZERO);
        assert!(ctx.nonconv_time > crate::sysc::SimTime::ZERO);
    }
}
