//! Quantized pooling ops (TFLite semantics: qparams pass through).

use crate::framework::ops::{OpCtx, TimeBucket};
use crate::framework::tensor::Tensor;

/// Pooling reduction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    /// Window maximum.
    Max,
    /// Rounded window average.
    Avg,
}

/// Windowed max/avg pooling.
#[derive(Debug, Clone)]
pub struct Pool2d {
    /// Layer name.
    pub name: String,
    /// Max or average.
    pub kind: PoolKind,
    /// Square window size.
    pub k: usize,
    /// Spatial stride (both axes).
    pub stride: usize,
    /// Zero padding (both axes).
    pub pad: usize,
}

impl Pool2d {
    /// Output spatial dims for an `h`×`w` input.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.k) / self.stride + 1,
            (w + 2 * self.pad - self.k) / self.stride + 1,
        )
    }

    /// Run the pooling on the CPU (qparams pass through).
    pub fn eval(&self, x: &Tensor, ctx: &mut OpCtx<'_>) -> Tensor {
        let (_, h, w, c) = x.nhwc();
        let (oh, ow) = self.out_hw(h, w);
        let mut out = vec![0i8; oh * ow * c];
        let pad = self.pad as isize;
        for oy in 0..oh {
            for ox in 0..ow {
                for cc in 0..c {
                    let mut maxv = i8::MIN;
                    let mut sum: i32 = 0;
                    let mut count: i32 = 0;
                    for ki in 0..self.k {
                        let iy = oy as isize * self.stride as isize + ki as isize - pad;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kj in 0..self.k {
                            let ix = ox as isize * self.stride as isize + kj as isize - pad;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let v = x.data[((iy as usize) * w + ix as usize) * c + cc];
                            maxv = maxv.max(v);
                            sum += v as i32;
                            count += 1;
                        }
                    }
                    out[(oy * ow + ox) * c + cc] = match self.kind {
                        PoolKind::Max => maxv,
                        PoolKind::Avg => {
                            // round-to-nearest integer average
                            let half = count / 2;
                            let r = if sum >= 0 { sum + half } else { sum - half } / count;
                            r.clamp(-128, 127) as i8
                        }
                    };
                }
            }
        }
        let t = ctx
            .cpu
            .elementwise_time((h * w * c) as u64, ctx.threads);
        ctx.charge(&self.name, TimeBucket::NonConv, t);
        Tensor::new(vec![1, oh, ow, c], out, x.qp)
    }
}

/// Global average pooling: NHWC -> [1, C].
#[derive(Debug, Clone)]
pub struct GlobalAvgPool {
    /// Layer name.
    pub name: String,
}

impl GlobalAvgPool {
    /// Average every channel over all spatial positions.
    pub fn eval(&self, x: &Tensor, ctx: &mut OpCtx<'_>) -> Tensor {
        let (_, h, w, c) = x.nhwc();
        let count = (h * w) as i32;
        let mut out = vec![0i8; c];
        for cc in 0..c {
            let mut sum: i32 = 0;
            for p in 0..h * w {
                sum += x.data[p * c + cc] as i32;
            }
            let half = count / 2;
            let r = if sum >= 0 { sum + half } else { sum - half } / count;
            out[cc] = r.clamp(-128, 127) as i8;
        }
        let t = ctx
            .cpu
            .elementwise_time((h * w * c) as u64, ctx.threads);
        ctx.charge(&self.name, TimeBucket::NonConv, t);
        Tensor::new(vec![1, c], out, x.qp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::backend::CpuBackend;
    use crate::framework::quant::QParams;
    use crate::perf::CpuModel;

    fn ctx_eval<F: FnOnce(&mut OpCtx<'_>) -> Tensor>(f: F) -> Tensor {
        let cpu = CpuModel::pynq_a9();
        let mut b = CpuBackend::new(1);
        let mut ctx = OpCtx::new(&mut b, &cpu, 1);
        f(&mut ctx)
    }

    #[test]
    fn maxpool_picks_max() {
        let x = Tensor::new(
            vec![1, 2, 2, 1],
            vec![1, 5, -3, 2],
            QParams::new(0.1, 0),
        );
        let p = Pool2d {
            name: "mp".into(),
            kind: PoolKind::Max,
            k: 2,
            stride: 2,
            pad: 0,
        };
        let y = ctx_eval(|c| p.eval(&x, c));
        assert_eq!(y.data, vec![5]);
        assert_eq!(y.shape, vec![1, 1, 1, 1]);
    }

    #[test]
    fn avgpool_rounds_to_nearest() {
        let x = Tensor::new(
            vec![1, 2, 2, 1],
            vec![1, 2, 2, 2], // mean 1.75 -> 2
            QParams::new(0.1, 0),
        );
        let p = Pool2d {
            name: "ap".into(),
            kind: PoolKind::Avg,
            k: 2,
            stride: 2,
            pad: 0,
        };
        let y = ctx_eval(|c| p.eval(&x, c));
        assert_eq!(y.data, vec![2]);
        // negative mean rounds away from zero
        let xn = Tensor::new(vec![1, 2, 2, 1], vec![-1, -2, -2, -2], QParams::new(0.1, 0));
        let y = ctx_eval(|c| p.eval(&xn, c));
        assert_eq!(y.data, vec![-2]);
    }

    #[test]
    fn global_avg_pool_shape_and_value() {
        let x = Tensor::new(
            vec![1, 2, 2, 2],
            vec![10, 0, 20, 0, 30, 0, 40, 100],
            QParams::new(0.1, 0),
        );
        let g = GlobalAvgPool { name: "gap".into() };
        let y = ctx_eval(|c| g.eval(&x, c));
        assert_eq!(y.shape, vec![1, 2]);
        assert_eq!(y.data, vec![25, 25]);
    }

    #[test]
    fn pool_with_padding_ignores_outside() {
        let x = Tensor::new(vec![1, 2, 2, 1], vec![4, 4, 4, 4], QParams::new(0.1, 0));
        let p = Pool2d {
            name: "mp3".into(),
            kind: PoolKind::Avg,
            k: 3,
            stride: 2,
            pad: 1,
        };
        // window at (0,0) covers 4 valid cells, all 4 -> avg 4
        let y = ctx_eval(|c| p.eval(&x, c));
        assert_eq!(y.data[0], 4);
    }
}
