//! Quantized self-attention — the paper's §VII future-work extension
//! ("support ... DNN classes (e.g., Transformer models)").
//!
//! A single-head int8 self-attention over a `[seq, d]` activation:
//! the Q/K/V/output projections are weight-static GEMMs, so they flow
//! through the same gemmlowp seam the conv layers use and are
//! offloaded to the SECDA accelerators unchanged. The two
//! activation-by-activation matmuls (QK^T and PV) have no static
//! operand, so — like depthwise convs — they stay on the CPU, computed
//! in int32 with a quantized softmax in between.

use crate::framework::backend::GemmTask;
use crate::framework::ops::{OpCtx, TimeBucket};
use crate::framework::quant::{quantize_multiplier, QParams};
use crate::framework::tensor::Tensor;
use crate::gemm::{self, QGemmParams};

/// Single-head quantized self-attention block.
#[derive(Debug, Clone)]
pub struct SelfAttention {
    /// Layer name.
    pub name: String,
    /// Sequence length (token count).
    pub seq: usize,
    /// Embedding width.
    pub d: usize,
    /// Q projection weights, `[d, d]` row-major (as are K/V/O).
    pub wq: Vec<i8>,
    /// K projection weights.
    pub wk: Vec<i8>,
    /// V projection weights.
    pub wv: Vec<i8>,
    /// Output projection weights.
    pub wo: Vec<i8>,
    /// Shared per-tensor projection weight scale.
    pub w_scale: f32,
    /// Output quantization.
    pub out_qp: QParams,
}

impl SelfAttention {
    fn projection(
        &self,
        label: &str,
        w: &[i8],
        x_t: &[i8], // [d, seq] column-major tokens (K x N layout)
        in_qp: &QParams,
        ctx: &mut OpCtx<'_>,
    ) -> Vec<i8> {
        // per-projection requant back into in_qp's domain
        let real = in_qp.scale as f64 * self.w_scale as f64 / in_qp.scale as f64;
        let (mult, shift) = quantize_multiplier(real);
        let mut params = QGemmParams::uniform(self.d, 0, mult, shift);
        params.out_zp = in_qp.zero_point;
        // fold x zero-point
        params.bias = gemm::fold_bias(&vec![0; self.d], w, self.d, self.d, in_qp.zero_point);
        let task = GemmTask {
            m: self.d,
            k: self.d,
            n: self.seq,
            weights: w,
            inputs: x_t,
            params: &params,
            layer: label,
            weights_resident: false,
        };
        let (out, mut timing) = ctx.backend.run_gemm(&task);
        if timing.accel_active.as_ps() == 0
            && timing.breakdown.iter().any(|(n, _)| *n == "cpu_gemm")
        {
            timing.total += ctx
                .cpu
                .reshape_time((self.d * self.seq) as u64, ctx.threads);
        }
        ctx.accel_active += timing.accel_active;
        ctx.charge(label, TimeBucket::Conv, timing.total);
        out // [d, seq]
    }

    /// Evaluate over `x`: `[1, seq, d]` int8 tokens.
    pub fn eval(&self, x: &Tensor, ctx: &mut OpCtx<'_>) -> Tensor {
        assert_eq!(x.shape, vec![1, self.seq, self.d], "{}", self.name);
        let qp = x.qp;
        // transpose tokens to [d, seq] for the (M=d, K=d, N=seq) GEMMs
        let mut x_t = vec![0i8; self.d * self.seq];
        for t in 0..self.seq {
            for c in 0..self.d {
                x_t[c * self.seq + t] = x.data[t * self.d + c];
            }
        }
        let q = self.projection(&format!("{}_q", self.name), &self.wq, &x_t, &qp, ctx);
        let k = self.projection(&format!("{}_k", self.name), &self.wk, &x_t, &qp, ctx);
        let v = self.projection(&format!("{}_v", self.name), &self.wv, &x_t, &qp, ctx);

        // attention scores: S = Q^T K / sqrt(d), int32 accumulation on
        // the CPU (both operands dynamic -> not offloadable)
        let zp = qp.zero_point;
        let mut probs = vec![0f32; self.seq * self.seq]; // row-softmaxed
        let scale2 = qp.scale * qp.scale / (self.d as f32).sqrt();
        for i in 0..self.seq {
            let mut row = vec![0f32; self.seq];
            for (j, r) in row.iter_mut().enumerate() {
                let mut acc: i32 = 0;
                for c in 0..self.d {
                    let qv = q[c * self.seq + i] as i32 - zp;
                    let kv = k[c * self.seq + j] as i32 - zp;
                    acc += qv * kv;
                }
                *r = acc as f32 * scale2;
            }
            let max = row.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = row.iter().map(|s| (s - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            for (j, e) in exps.iter().enumerate() {
                probs[i * self.seq + j] = e / sum;
            }
        }
        // context = P V (P float probs in [0,1], V int8): accumulate in
        // f32 then requantize to qp — an 8.8 fixed-point P would change
        // results by <1 output step
        let mut context_t = vec![0i8; self.d * self.seq]; // [d, seq]
        for i in 0..self.seq {
            for c in 0..self.d {
                let mut acc = 0f32;
                for j in 0..self.seq {
                    acc += probs[i * self.seq + j] * (v[c * self.seq + j] as i32 - zp) as f32;
                }
                let qv = (acc + zp as f32).round().clamp(-128.0, 127.0) as i8;
                context_t[c * self.seq + i] = qv;
            }
        }
        // CPU cost of the two dynamic matmuls + softmax
        let macs = 2 * (self.seq * self.seq * self.d) as u64;
        let t = ctx.cpu.gemm_time(macs, ctx.threads);
        ctx.charge(&format!("{}_attn", self.name), TimeBucket::NonConv, t);

        // output projection back to token-major [1, seq, d]
        let o = self.projection(&format!("{}_o", self.name), &self.wo, &context_t, &qp, ctx);
        let mut out = vec![0i8; self.seq * self.d];
        for t in 0..self.seq {
            for c in 0..self.d {
                out[t * self.d + c] = o[c * self.seq + t];
            }
        }
        Tensor::new(vec![1, self.seq, self.d], out, self.out_qp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::SaDesign;
    use crate::driver::{AccelBackend, DriverConfig};
    use crate::framework::backend::CpuBackend;
    use crate::framework::models::WeightGen;
    use crate::perf::CpuModel;

    fn block(seq: usize, d: usize) -> SelfAttention {
        let mut gen = WeightGen::for_layer("attn_test", "blk");
        SelfAttention {
            name: "attn".into(),
            seq,
            d,
            wq: gen.i8s(d * d),
            wk: gen.i8s(d * d),
            wv: gen.i8s(d * d),
            wo: gen.i8s(d * d),
            w_scale: 0.3 / (d as f32).sqrt() / 25.0,
            out_qp: QParams::new(0.05, -4),
        }
    }

    fn tokens(seq: usize, d: usize) -> Tensor {
        let mut gen = WeightGen::for_layer("attn_test", "tokens");
        Tensor::new(vec![1, seq, d], gen.i8s(seq * d), QParams::new(0.05, -4))
    }

    #[test]
    fn attention_runs_and_shapes() {
        let a = block(16, 32);
        let x = tokens(16, 32);
        let cpu = CpuModel::pynq_a9();
        let mut b = CpuBackend::new(1);
        let mut ctx = OpCtx::new(&mut b, &cpu, 1);
        let y = a.eval(&x, &mut ctx);
        assert_eq!(y.shape, vec![1, 16, 32]);
        // 4 projections land in the (delegatable) CONV bucket, the
        // dynamic attention matmuls in Non-CONV
        assert!(ctx.conv_time > crate::sysc::SimTime::ZERO);
        assert!(ctx.nonconv_time > crate::sysc::SimTime::ZERO);
        assert_eq!(ctx.layers.len(), 5);
    }

    #[test]
    fn projections_offload_to_accelerator_bit_exactly() {
        // the §VII extension works through the SAME seam: outputs on the
        // accelerated path match the CPU path bit for bit
        let a = block(16, 32);
        let x = tokens(16, 32);
        let cpu = CpuModel::pynq_a9();
        let mut cb = CpuBackend::new(1);
        let mut ctx1 = OpCtx::new(&mut cb, &cpu, 1);
        let y_cpu = a.eval(&x, &mut ctx1);
        let mut ab = AccelBackend::new(SaDesign::paper(), DriverConfig::with_threads(1));
        let mut ctx2 = OpCtx::new(&mut ab, &cpu, 1);
        let y_acc = a.eval(&x, &mut ctx2);
        assert_eq!(y_cpu.data, y_acc.data);
        assert!(ctx2.accel_active > crate::sysc::SimTime::ZERO);
        assert_eq!(ab.stats.offloads, 4); // q, k, v, o
    }

    #[test]
    fn attention_attends() {
        // with identity-ish V and a strongly self-similar token, the
        // output should not be constant across tokens
        let a = block(8, 16);
        let x = tokens(8, 16);
        let cpu = CpuModel::pynq_a9();
        let mut b = CpuBackend::new(1);
        let mut ctx = OpCtx::new(&mut b, &cpu, 1);
        let y = a.eval(&x, &mut ctx);
        let first = &y.data[..16];
        assert!(y.data.chunks(16).any(|t| t != first));
    }
}
