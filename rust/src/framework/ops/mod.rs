//! The operator set of the four benchmark models.
//!
//! Convolutions execute through the GEMM seam ([`super::backend`]) so
//! the SECDA driver can intercept them (paper Fig. 2); everything else
//! runs on the CPU with times from the calibrated
//! [`crate::perf::CpuModel`]. Depthwise convolutions are *conv layers*
//! (they land in Table II's CONV bucket) but do not go through
//! gemmlowp, so they stay on the CPU — exactly as in the paper's
//! TFLite case study.

pub mod attention;
pub mod conv;
pub mod dwconv;
pub mod eltwise;
pub mod fc;
pub mod pool;
pub mod softmax;

use super::backend::GemmBackend;
use super::tensor::Tensor;
use crate::perf::CpuModel;
use crate::sysc::SimTime;

pub use attention::SelfAttention;
pub use conv::{Activation, Conv2d};
pub use dwconv::DepthwiseConv2d;
pub use eltwise::{AddOp, ConcatOp};
pub use fc::FullyConnected;
pub use pool::{GlobalAvgPool, Pool2d, PoolKind};
pub use softmax::SoftmaxOp;

/// Time bucket an op's cost lands in (Table II columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeBucket {
    /// Convolution layers (standard and depthwise).
    Conv,
    /// Everything else, incl. framework overhead.
    NonConv,
}

/// Execution context handed to op kernels: the GEMM backend, the CPU
/// timing model, and the time accounting sinks.
pub struct OpCtx<'a> {
    /// GEMM seam convolutions and FCs execute through.
    pub backend: &'a mut dyn GemmBackend,
    /// CPU timing model pricing non-offloaded work.
    pub cpu: &'a CpuModel,
    /// CPU threads modeled for CPU-side work.
    pub threads: usize,
    /// Accumulated CONV-bucket time.
    pub conv_time: SimTime,
    /// Accumulated Non-CONV time.
    pub nonconv_time: SimTime,
    /// Accumulated accelerator-active time (energy accounting).
    pub accel_active: SimTime,
    /// Per-layer records: (name, bucket, time).
    pub layers: Vec<(String, TimeBucket, SimTime)>,
}

impl<'a> OpCtx<'a> {
    /// A fresh context with zeroed accounting.
    pub fn new(backend: &'a mut dyn GemmBackend, cpu: &'a CpuModel, threads: usize) -> Self {
        OpCtx {
            backend,
            cpu,
            threads,
            conv_time: SimTime::ZERO,
            nonconv_time: SimTime::ZERO,
            accel_active: SimTime::ZERO,
            layers: Vec::new(),
        }
    }

    /// Record `t` for layer `name` in `bucket`.
    pub fn charge(&mut self, name: &str, bucket: TimeBucket, t: SimTime) {
        match bucket {
            TimeBucket::Conv => self.conv_time += t,
            TimeBucket::NonConv => self.nonconv_time += t,
        }
        self.layers.push((name.to_string(), bucket, t));
    }
}

/// One graph operator.
#[derive(Debug, Clone)]
pub enum Op {
    /// Standard convolution (GEMM seam).
    Conv(Conv2d),
    /// Depthwise convolution (CPU, CONV bucket).
    DwConv(DepthwiseConv2d),
    /// Fully-connected layer (GEMM seam).
    Fc(FullyConnected),
    /// Windowed max/average pooling.
    Pool(Pool2d),
    /// Global average pooling.
    GlobalAvgPool(GlobalAvgPool),
    /// Element-wise add (residual connections).
    Add(AddOp),
    /// Channel concatenation (inception branches).
    Concat(ConcatOp),
    /// Softmax classifier head.
    Softmax(SoftmaxOp),
}

impl Op {
    /// The layer name.
    pub fn name(&self) -> &str {
        match self {
            Op::Conv(o) => &o.name,
            Op::DwConv(o) => &o.name,
            Op::Fc(o) => &o.name,
            Op::Pool(o) => &o.name,
            Op::GlobalAvgPool(o) => &o.name,
            Op::Add(o) => &o.name,
            Op::Concat(o) => &o.name,
            Op::Softmax(o) => &o.name,
        }
    }

    /// Evaluate the op, charging its time to `ctx`.
    pub fn eval(&self, inputs: &[&Tensor], ctx: &mut OpCtx<'_>) -> Tensor {
        match self {
            Op::Conv(o) => o.eval(inputs[0], ctx),
            Op::DwConv(o) => o.eval(inputs[0], ctx),
            Op::Fc(o) => o.eval(inputs[0], ctx),
            Op::Pool(o) => o.eval(inputs[0], ctx),
            Op::GlobalAvgPool(o) => o.eval(inputs[0], ctx),
            Op::Add(o) => o.eval(inputs[0], inputs[1], ctx),
            Op::Concat(o) => o.eval(inputs, ctx),
            Op::Softmax(o) => o.eval(inputs[0], ctx),
        }
    }

    /// Is this a convolution layer (Table II CONV bucket)?
    pub fn is_conv(&self) -> bool {
        matches!(self, Op::Conv(_) | Op::DwConv(_))
    }

    /// GEMM dims (m, k, n) if this op offloads through the GEMM seam.
    pub fn gemm_shape(&self, input_shape: &[usize]) -> Option<(usize, usize, usize)> {
        match self {
            Op::Conv(o) => {
                let (h, w) = (input_shape[1], input_shape[2]);
                let (oh, ow) = o.out_hw(h, w);
                Some((o.cout, o.kh * o.kw * o.cin, oh * ow))
            }
            _ => None,
        }
    }
}
