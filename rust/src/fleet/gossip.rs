//! Queue-depth gossip: the staleness-bounded board state the router
//! places against.
//!
//! A real fleet front-end never sees live board state — it sees
//! periodic load reports. This module models that: the router reads
//! [`BoardSnapshot`]s out of a [`GossipTable`], and each snapshot may
//! lag the board it describes by up to the configured staleness bound.
//! Two refresh edges exist, both driven **only by modeled time**:
//!
//! * the *tick* — at every submit the table refreshes any snapshot
//!   whose age (fleet modeled now minus `taken_at`) has reached the
//!   staleness bound;
//! * the *drain boundary* — [`crate::fleet::Fleet::run_until_idle`]
//!   refreshes every snapshot once the pool is idle, when board state
//!   is cheap and exact in both exec modes.
//!
//! Because neither edge consults host time, the gossip a submit sees
//! is a pure function of the modeled history — which is what makes
//! the router's placement sequence bit-identical between
//! [`crate::coordinator::ExecMode::Modeled`] and
//! [`crate::coordinator::ExecMode::Threaded`], and across reruns
//! (pinned by `prop_router_is_deterministic_under_stale_gossip`).

use crate::coordinator::Coordinator;
use crate::elastic::Composition;
use crate::sysc::SimTime;

/// Gossip refresh policy.
#[derive(Debug, Clone, Copy)]
pub struct GossipConfig {
    /// Maximum snapshot age before the tick refreshes it. `ZERO`
    /// means every submit sees perfectly fresh board state (the
    /// degenerate "router has an oracle" configuration the
    /// single-board equivalence tests use).
    pub staleness: SimTime,
}

impl Default for GossipConfig {
    fn default() -> Self {
        GossipConfig {
            // a couple of batch windows: stale enough to matter, fresh
            // enough that the router tracks phase shifts
            staleness: SimTime::ms(5),
        }
    }
}

/// What one board last reported about itself.
#[derive(Debug, Clone)]
pub struct BoardSnapshot {
    /// Board index within the fleet.
    pub board: usize,
    /// Requests queued across the board's pool at `taken_at`.
    pub queued: usize,
    /// The board's pool composition at `taken_at` (the elastic layer
    /// may have swapped it since).
    pub composition: Composition,
    /// Modeled time the snapshot was taken.
    pub taken_at: SimTime,
}

/// The per-board snapshot table the router reads.
#[derive(Debug)]
pub struct GossipTable {
    cfg: GossipConfig,
    snaps: Vec<BoardSnapshot>,
    refreshes: u64,
}

impl GossipTable {
    /// A table seeded with fresh snapshots of every board at time
    /// `now`.
    pub fn new(cfg: GossipConfig, boards: &[Coordinator], now: SimTime) -> Self {
        let mut t = GossipTable {
            cfg,
            snaps: Vec::with_capacity(boards.len()),
            refreshes: 0,
        };
        for (i, b) in boards.iter().enumerate() {
            t.snaps.push(Self::take(i, b, now));
        }
        t
    }

    fn take(board: usize, b: &Coordinator, now: SimTime) -> BoardSnapshot {
        BoardSnapshot {
            board,
            queued: b.queued(),
            composition: b.composition(),
            taken_at: now,
        }
    }

    /// The tick: refresh every snapshot whose age has reached the
    /// staleness bound. Called on the submit path; a snapshot younger
    /// than the bound is left as-is, so the router deliberately places
    /// against (boundedly) stale state.
    pub fn tick(&mut self, now: SimTime, boards: &[Coordinator]) {
        for snap in &mut self.snaps {
            if now.saturating_sub(snap.taken_at) >= self.cfg.staleness {
                *snap = Self::take(snap.board, &boards[snap.board], now);
                self.refreshes += 1;
            }
        }
    }

    /// Drain-boundary refresh: retake every snapshot unconditionally.
    pub fn refresh_all(&mut self, now: SimTime, boards: &[Coordinator]) {
        for snap in &mut self.snaps {
            *snap = Self::take(snap.board, &boards[snap.board], now);
            self.refreshes += 1;
        }
    }

    /// The current snapshots, indexed by board.
    pub fn snapshots(&self) -> &[BoardSnapshot] {
        &self.snaps
    }

    /// Total snapshot refreshes performed (tick + drain-boundary).
    pub fn refreshes(&self) -> u64 {
        self.refreshes
    }

    /// The configured staleness bound.
    pub fn staleness(&self) -> SimTime {
        self.cfg.staleness
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;

    fn boards(n: usize) -> Vec<Coordinator> {
        (0..n)
            .map(|_| Coordinator::new(CoordinatorConfig::default()))
            .collect()
    }

    #[test]
    fn tick_respects_staleness_bound() {
        let b = boards(2);
        let cfg = GossipConfig {
            staleness: SimTime::ms(10),
        };
        let mut t = GossipTable::new(cfg, &b, SimTime::ZERO);
        let seeded = t.refreshes(); // seeding does not count
        assert_eq!(seeded, 0);
        t.tick(SimTime::ms(9), &b);
        assert_eq!(t.refreshes(), 0, "younger than the bound: untouched");
        assert_eq!(t.snapshots()[0].taken_at, SimTime::ZERO);
        t.tick(SimTime::ms(10), &b);
        assert_eq!(t.refreshes(), 2, "age == bound refreshes");
        assert_eq!(t.snapshots()[1].taken_at, SimTime::ms(10));
    }

    #[test]
    fn zero_staleness_is_always_fresh() {
        let b = boards(1);
        let mut t = GossipTable::new(
            GossipConfig {
                staleness: SimTime::ZERO,
            },
            &b,
            SimTime::ZERO,
        );
        t.tick(SimTime::ZERO, &b);
        assert_eq!(t.refreshes(), 1, "zero bound refreshes on every tick");
    }

    #[test]
    fn refresh_all_is_unconditional() {
        let b = boards(3);
        let mut t = GossipTable::new(GossipConfig::default(), &b, SimTime::ZERO);
        t.refresh_all(SimTime::us(1), &b);
        assert_eq!(t.refreshes(), 3);
        assert!(t.snapshots().iter().all(|s| s.taken_at == SimTime::us(1)));
    }
}
