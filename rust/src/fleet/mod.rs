//! The L4 fleet tier: many modeled boards behind one front-end.
//!
//! One PYNQ-Z1 cannot serve millions of users; a fleet of them can —
//! and SECDA-style reconfigurability becomes a *fleet-wide* advantage
//! once each board can carry a different bitstream (Hao et al.,
//! FPGA/DNN Co-Design; the per-board design space surveyed by Guo et
//! al.). This module shards the L3 [`Coordinator`] across N board
//! replicas, each a full serving stack with its own pool, batcher and
//! (optionally) elastic controller:
//!
//! * [`router`] — the front-end placement engine: scores every board
//!   with the unified [`CostModel`](crate::coordinator::CostModel)
//!   plus a modeled network/DMA ingress cost
//!   ([`router::IngressModel`]), reading board state through gossip
//!   rather than omnisciently;
//! * [`gossip`] — staleness-bounded per-board queue-depth snapshots,
//!   refreshed at drain boundaries and on a modeled-time tick (never
//!   host time, so both exec modes see identical gossip);
//! * [`metrics`] — [`FleetMetrics`]: per-board
//!   [`ServingMetrics`](crate::coordinator::ServingMetrics) aggregated
//!   into fleet req/s, per-board utilization and merged tail-latency
//!   histograms ([`crate::obs::Histogram::merge`]);
//! * the *bitstream portfolio* — the PR-5 elastic planner
//!   ([`CompositionPlanner`]) run one level up: against the aggregate
//!   traffic profile it proposes per-board compositions (e.g. three
//!   boards SA-heavy, one VM), paying the modeled
//!   [`crate::synth::reconfig_time`] per swapped board through the
//!   public [`Coordinator::reconfigure`].
//!
//! The [`ExecMode`](crate::coordinator::ExecMode) split carries
//! through end-to-end: a modeled fleet is deterministic and
//! bit-identical to the threaded fleet (same functional outputs, same
//! modeled timeline, same placement sequence), which the fleet
//! proptests pin. A 1-board fleet with [`router::IngressModel::none`]
//! degenerates bit-for-bit to a bare [`Coordinator`].
//!
//! ```no_run
//! use std::sync::Arc;
//! use secda::fleet::{Fleet, FleetConfig};
//! use secda::framework::{models, tensor::Tensor};
//!
//! let g = Arc::new(models::by_name("mobilenet_v1").unwrap());
//! let mut fleet = Fleet::new(FleetConfig::default().with_boards(4));
//! for _ in 0..32 {
//!     let input = Tensor::zeros(g.input_shape.clone(), g.input_qp);
//!     fleet.submit(g.clone(), input).unwrap();
//!     fleet.advance(secda::sysc::SimTime::us(500));
//! }
//! let done = fleet.run_until_idle();
//! assert_eq!(done.len(), 32);
//! println!("{}", fleet.metrics().summary());
//! ```

pub mod gossip;
pub mod metrics;
pub mod router;

use std::sync::Arc;

use crate::coordinator::{Completion, Coordinator, CoordinatorConfig, SubmitError};
use crate::elastic::{
    Composition, CompositionPlanner, DesignCosts, ElasticConfig, SwapRecord, TrafficProfile,
    WorkloadEstimator,
};
use crate::framework::graph::Graph;
use crate::framework::tensor::Tensor;
use crate::sysc::SimTime;

pub use gossip::{BoardSnapshot, GossipConfig, GossipTable};
pub use metrics::{BoardStats, FleetMetrics};
pub use router::{Candidate, IngressModel, Router};

/// Fleet-level serving configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of board replicas.
    pub boards: usize,
    /// Per-board configuration template. Cloned per board; when
    /// tracing is enabled ([`FleetConfig::with_tracing`]) each board
    /// gets its *own* span recorder so traces stay per-board.
    pub board: CoordinatorConfig,
    /// Modeled network/DMA ingress cost the router charges per
    /// request.
    pub ingress: IngressModel,
    /// Gossip refresh policy.
    pub gossip: GossipConfig,
    /// Fleet-wide bitstream-portfolio planning: when set, the elastic
    /// planner runs at the fleet level against aggregate traffic,
    /// proposing per-board compositions at drain boundaries. Distinct
    /// from `board.elastic`, which re-plans each board against only
    /// its own traffic; enable one or the other, not both.
    pub portfolio: Option<ElasticConfig>,
    /// Streaming telemetry, fleet-wide: every board gets its own
    /// series bank + alert engine
    /// ([`CoordinatorConfig::with_telemetry`]), and the fleet keeps a
    /// merged fleet-level bank sampled at fleet drain boundaries.
    pub telemetry: Option<crate::obs::TelemetryConfig>,
    /// Per-board span-recorder capacity, when tracing.
    trace_cap: Option<usize>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            boards: 2,
            board: CoordinatorConfig::default(),
            ingress: IngressModel::default(),
            gossip: GossipConfig::default(),
            portfolio: None,
            telemetry: None,
            trace_cap: None,
        }
    }
}

impl FleetConfig {
    /// Set the number of board replicas.
    pub fn with_boards(mut self, n: usize) -> Self {
        self.boards = n;
        self
    }

    /// Replace the per-board configuration template.
    pub fn with_board(mut self, board: CoordinatorConfig) -> Self {
        self.board = board;
        self
    }

    /// Set the ingress cost model.
    pub fn with_ingress(mut self, ingress: IngressModel) -> Self {
        self.ingress = ingress;
        self
    }

    /// Set the gossip refresh policy.
    pub fn with_gossip(mut self, gossip: GossipConfig) -> Self {
        self.gossip = gossip;
        self
    }

    /// Enable fleet-wide portfolio planning.
    pub fn with_portfolio(mut self, cfg: ElasticConfig) -> Self {
        self.portfolio = Some(cfg);
        self
    }

    /// Enable streaming telemetry on every board plus the fleet-level
    /// merged series ([`Fleet::fleet_series`]) and alert engine
    /// ([`Fleet::fleet_alerts`]).
    pub fn with_telemetry(mut self, telemetry: crate::obs::TelemetryConfig) -> Self {
        self.telemetry = Some(telemetry);
        self
    }

    /// Set every board's exec mode (the fleet mirrors the
    /// [`Coordinator`] split: modeled fleets are deterministic,
    /// threaded fleets report wall-clock throughput too).
    pub fn with_exec_mode(mut self, mode: crate::coordinator::ExecMode) -> Self {
        self.board.exec_mode = mode;
        self
    }

    /// Enable span recording on every board (capacity per board).
    /// Export the run with [`Fleet::chrome_trace`].
    pub fn with_tracing(mut self, cap: usize) -> Self {
        self.trace_cap = Some(cap);
        self
    }
}

/// Where a fleet submit landed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// Board the request was placed on.
    pub board: usize,
    /// The board-local request id (boards number independently).
    pub id: u64,
}

/// One completion, tagged with the board that served it.
#[derive(Debug, Clone)]
pub struct BoardCompletion {
    /// Board index.
    pub board: usize,
    /// The board's completion record.
    pub completion: Completion,
}

/// One committed portfolio swap.
#[derive(Debug, Clone)]
pub struct FleetSwapRecord {
    /// Board the swap was applied to.
    pub board: usize,
    /// The swap itself (same record shape as board-local elastic
    /// history).
    pub record: SwapRecord,
}

/// Fleet-level portfolio planning state: the PR-5 planner, one level
/// up. One estimator aggregates every board's completions; at each
/// (rate-limited) drain-boundary evaluation the planner scores each
/// board's composition against its per-board share of the aggregate
/// profile and reconfigures the boards whose projected win amortizes
/// the modeled bitstream-load cost.
struct Portfolio {
    cfg: ElasticConfig,
    estimator: WorkloadEstimator,
    planner: CompositionPlanner,
    costs: DesignCosts,
    last_eval: Option<SimTime>,
    history: Vec<FleetSwapRecord>,
}

impl Portfolio {
    fn new(cfg: ElasticConfig, threads: usize, sync_overhead: SimTime) -> Self {
        Portfolio {
            planner: CompositionPlanner::new(cfg.budget),
            estimator: WorkloadEstimator::new(cfg.window),
            costs: DesignCosts::new(threads, sync_overhead),
            last_eval: None,
            history: Vec::new(),
            cfg,
        }
    }

    fn observe(&mut self, c: &Completion) {
        self.estimator.observe(c);
    }

    /// Each board plans against its share of the aggregate profile:
    /// counts divide (rounding demand up so a minority shape is never
    /// planned away to zero), rates divide exactly.
    fn per_board_share(profile: &TrafficProfile, n: usize) -> TrafficProfile {
        let n = n.max(1);
        TrafficProfile {
            requests: profile.requests.div_ceil(n),
            span: profile.span,
            arrival_rate_rps: profile.arrival_rate_rps / n as f64,
            demand: profile
                .demand
                .iter()
                .map(|(s, c)| (*s, c.div_ceil(n as u64)))
                .collect(),
            slo_carrying: profile.slo_carrying.div_ceil(n),
            // misses round *down*: phantom misses would overstate SLO
            // pressure on every board
            slo_missed: profile.slo_missed / n,
            trend: profile.trend,
        }
    }

    fn evaluate(&mut self, now: SimTime, boards: &mut [Coordinator]) {
        if let Some(last) = self.last_eval {
            if now.saturating_sub(last) < self.cfg.eval_interval {
                return;
            }
        }
        self.last_eval = Some(now);
        // pool every board's observed simulator timings into the
        // per-design cost models, exactly as the board-local
        // controller does
        for board in boards.iter() {
            for w in &board.pool().workers {
                self.costs.absorb(w.kind, &w.backend.planner.cost);
            }
        }
        let Some(profile) = self.estimator.profile(now) else {
            return;
        };
        if profile.requests < self.cfg.min_samples {
            return;
        }
        let share = Self::per_board_share(&profile, boards.len());
        for (b, board) in boards.iter_mut().enumerate() {
            let current = board.composition();
            if let Some(plan) = self.planner.plan(current, &share, &self.costs, &self.cfg) {
                board.reconfigure(&plan);
                self.history.push(FleetSwapRecord {
                    board: b,
                    record: SwapRecord {
                        at: now,
                        from: plan.from,
                        to: plan.to,
                        reconfig_cost: plan.reconfig_cost,
                        projected_win: plan.projected_win(),
                    },
                });
            }
        }
    }
}

/// Fleet-level streaming telemetry: one merged series bank + alert
/// engine over the whole fleet's traffic, sampled at fleet drain
/// boundaries (each board additionally samples its own bank at its
/// own drain boundaries). Sampling only reads already-aggregated
/// state, so the modeled timeline is untouched.
struct FleetTelemetry {
    series: crate::obs::SeriesBank,
    engine: crate::obs::AlertEngine,
}

impl FleetTelemetry {
    fn new(cfg: crate::obs::TelemetryConfig) -> Self {
        FleetTelemetry {
            series: crate::obs::SeriesBank::new(cfg.capacity),
            engine: crate::obs::AlertEngine::new(&cfg),
        }
    }

    /// One fleet drain-boundary sample: counters summed across boards,
    /// gauges from the aggregate fleet view, per-board utilization.
    fn sample(
        &mut self,
        now: SimTime,
        fm: &FleetMetrics,
        boards: &[Coordinator],
        done: &[BoardCompletion],
    ) {
        use crate::obs::timeseries::names;
        let mut submitted = 0u64;
        let mut steals = 0u64;
        let mut slo_attained = 0u64;
        let mut slo_missed = 0u64;
        let mut queue_peak = 0usize;
        for b in boards {
            let sm = b.metrics();
            submitted += sm.submitted;
            steals += sm.steals;
            slo_attained += sm.slo_attained;
            slo_missed += sm.slo_missed;
            queue_peak = queue_peak.max(sm.queue_peak);
        }
        let s = &mut self.series;
        s.counter(names::SUBMITTED).push_counter(now, submitted);
        s.counter(names::COMPLETED).push_counter(now, fm.completed);
        s.counter(names::SHED).push_counter(now, fm.shed_predicted);
        s.counter(names::STEALS).push_counter(now, steals);
        s.counter(names::SLO_ATTAINED).push_counter(now, slo_attained);
        s.counter(names::SLO_MISSED).push_counter(now, slo_missed);
        s.gauge(names::QUEUE_PEAK).push_gauge(now, queue_peak as f64);
        s.gauge(names::REQ_S).push_gauge(now, fm.throughput_rps());
        s.gauge(names::LATENCY_P99_MS).push_gauge(now, fm.latency_pct(0.99).as_ms_f64());
        let attainment = if slo_attained + slo_missed == 0 {
            1.0
        } else {
            slo_attained as f64 / (slo_attained + slo_missed) as f64
        };
        s.gauge(names::SLO_ATTAINMENT).push_gauge(now, attainment);
        s.gauge(names::DRAIN_REQUESTS).push_gauge(now, done.len() as f64);
        // order-independent integer mean, exactly as the per-board
        // sampler computes it (bit-identical across exec modes)
        let mean_ms = if done.is_empty() {
            0.0
        } else {
            let sum_ps: u128 = done
                .iter()
                .map(|bc| bc.completion.latency().as_ps() as u128)
                .sum();
            (sum_ps / done.len() as u128) as f64 / 1e9
        };
        s.gauge(names::DRAIN_LATENCY_MS).push_gauge(now, mean_ms);
        for b in &fm.boards {
            s.gauge(&format!("util.board{}", b.board)).push_gauge(now, b.utilization);
        }
    }
}

/// N board replicas behind a gossip-fed, cost-model router.
///
/// The API mirrors [`Coordinator`]: submit, advance the modeled
/// clock, drain with [`Fleet::run_until_idle`], then read
/// [`Fleet::metrics`]. All boards share the fleet's modeled timeline —
/// [`Fleet::advance`] moves every board's clock, and a drain boundary
/// re-synchronizes them to the fleet-wide frontier.
pub struct Fleet {
    boards: Vec<Coordinator>,
    router: Router,
    gossip: GossipTable,
    portfolio: Option<Portfolio>,
    telemetry: Option<FleetTelemetry>,
    ingress: IngressModel,
    placements: Vec<Placement>,
    now: SimTime,
    first_arrival: Option<SimTime>,
    last_finish: SimTime,
}

impl Fleet {
    /// Build the fleet a [`FleetConfig`] describes. Panics when
    /// `boards` is zero.
    pub fn new(cfg: FleetConfig) -> Self {
        assert!(cfg.boards > 0, "a fleet needs at least one board");
        let boards: Vec<Coordinator> = (0..cfg.boards)
            .map(|_| {
                let mut bc = cfg.board.clone();
                if let Some(cap) = cfg.trace_cap {
                    bc = bc.with_tracing(cap);
                }
                if let Some(tel) = &cfg.telemetry {
                    bc = bc.with_telemetry(tel.clone());
                }
                Coordinator::new(bc)
            })
            .collect();
        let threads = cfg.board.driver.threads;
        let sync = cfg.board.driver.sync_overhead;
        let router = Router::new(cfg.ingress, threads, sync);
        let gossip = GossipTable::new(cfg.gossip, &boards, SimTime::ZERO);
        let portfolio = cfg.portfolio.map(|p| Portfolio::new(p, threads, sync));
        let telemetry = cfg.telemetry.map(FleetTelemetry::new);
        Fleet {
            boards,
            router,
            gossip,
            portfolio,
            telemetry,
            ingress: cfg.ingress,
            placements: Vec::new(),
            now: SimTime::ZERO,
            first_arrival: None,
            last_finish: SimTime::ZERO,
        }
    }

    /// The fleet's modeled clock.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Advance the modeled clock fleet-wide (inter-arrival time of a
    /// load generator). Every board's clock moves in lockstep.
    pub fn advance(&mut self, dt: SimTime) {
        self.now += dt;
        for b in &mut self.boards {
            let behind = self.now.saturating_sub(b.now());
            if behind > SimTime::ZERO {
                b.advance(behind);
            }
        }
    }

    /// Submit a best-effort request through the router.
    pub fn submit(&mut self, model: Arc<Graph>, input: Tensor) -> Result<Placement, SubmitError> {
        self.submit_with_deadline(model, input, None)
    }

    /// Submit with an SLO budget relative to the fleet clock. Network
    /// ingress time eats into the budget: the deadline is fixed at
    /// submit, before the modeled transfer to the board.
    pub fn submit_with_slo(
        &mut self,
        model: Arc<Graph>,
        input: Tensor,
        slo: SimTime,
    ) -> Result<Placement, SubmitError> {
        let deadline = self.now + slo;
        self.submit_with_deadline(model, input, Some(deadline))
    }

    /// Submit with an explicit absolute deadline (or none). The router
    /// ranks boards on gossiped state (ingress + backlog + execution,
    /// see [`Router::rank`]), then places on the best-ranked board
    /// whose admission control would *not* shed the request
    /// ([`Coordinator::would_shed`] — exact, not estimated). When
    /// every board would shed, the request goes to the best-ranked
    /// board anyway so exactly one board records the shed verdict.
    pub fn submit_with_deadline(
        &mut self,
        model: Arc<Graph>,
        input: Tensor,
        deadline: Option<SimTime>,
    ) -> Result<Placement, SubmitError> {
        self.gossip.tick(self.now, &self.boards);
        let ranked = self.router.rank(self.gossip.snapshots(), &model, &input);
        let ingress = self.ingress.cost(input.bytes() as u64);
        for c in &ranked {
            let board = &self.boards[c.board];
            let arrive = (self.now + ingress).max(board.now());
            if board.would_shed(&model, &input, deadline, arrive).is_none() {
                return self.place_on(c.board, model, input, deadline);
            }
        }
        self.place_on(ranked[0].board, model, input, deadline)
    }

    /// Deliver the request to board `b`: charge the modeled ingress
    /// time (the board's clock moves to the delivery instant, so the
    /// arrival stamp includes the transfer), then submit.
    fn place_on(
        &mut self,
        b: usize,
        model: Arc<Graph>,
        input: Tensor,
        deadline: Option<SimTime>,
    ) -> Result<Placement, SubmitError> {
        let ingress = self.ingress.cost(input.bytes() as u64);
        let arrive = self.now + ingress;
        let board = &mut self.boards[b];
        let behind = arrive.saturating_sub(board.now());
        if behind > SimTime::ZERO {
            board.advance(behind);
        }
        let id = board.submit_with_deadline(model, input, deadline)?;
        if self.first_arrival.is_none() {
            self.first_arrival = Some(board.now());
        }
        let p = Placement { board: b, id };
        self.placements.push(p);
        Ok(p)
    }

    /// Drain every board, then run the fleet drain boundary:
    /// re-synchronize board clocks to the fleet-wide frontier, let the
    /// portfolio planner observe the completed traffic (and possibly
    /// reconfigure boards), and refresh every gossip snapshot.
    /// Completions come back board-tagged, boards in index order, each
    /// board's completions in its [`Coordinator::run_until_idle`]
    /// order.
    pub fn run_until_idle(&mut self) -> Vec<BoardCompletion> {
        let mut out = Vec::new();
        for (b, board) in self.boards.iter_mut().enumerate() {
            for completion in board.run_until_idle() {
                self.last_finish = self.last_finish.max(completion.finished);
                out.push(BoardCompletion {
                    board: b,
                    completion,
                });
            }
        }
        // clock re-sync: the fleet timeline is the slowest board's
        let frontier = self
            .boards
            .iter()
            .map(|b| b.now())
            .max()
            .unwrap_or(self.now)
            .max(self.now);
        self.now = frontier;
        for b in &mut self.boards {
            let behind = frontier.saturating_sub(b.now());
            if behind > SimTime::ZERO {
                b.advance(behind);
            }
        }
        // portfolio planning at the drain boundary (pools are idle in
        // both exec modes, same as the board-local elastic contract)
        if let Some(mut p) = self.portfolio.take() {
            for bc in &out {
                p.observe(&bc.completion);
            }
            p.evaluate(self.now, &mut self.boards);
            self.portfolio = Some(p);
        }
        // fleet-level telemetry sample + alert evaluation (after the
        // portfolio block, so a portfolio swap is visible in this
        // drain's composition-dependent gauges)
        if let Some(mut tel) = self.telemetry.take() {
            let fm = self.metrics();
            tel.sample(self.now, &fm, &self.boards, &out);
            tel.engine.evaluate(self.now, &tel.series);
            self.telemetry = Some(tel);
        }
        self.gossip.refresh_all(self.now, &self.boards);
        out
    }

    /// The board replicas (read-only: per-board metrics, spans,
    /// compositions).
    pub fn boards(&self) -> &[Coordinator] {
        &self.boards
    }

    /// The gossip table the router places against.
    pub fn gossip(&self) -> &GossipTable {
        &self.gossip
    }

    /// Every placement the router made, in submit order (the
    /// determinism proptests compare these sequences).
    pub fn placements(&self) -> &[Placement] {
        &self.placements
    }

    /// Every portfolio swap committed, in commit order (empty without
    /// a portfolio config; board-local elastic swaps live in each
    /// board's [`Coordinator::elastic_history`]).
    pub fn portfolio_history(&self) -> &[FleetSwapRecord] {
        self.portfolio.as_ref().map(|p| p.history.as_slice()).unwrap_or(&[])
    }

    /// The current composition of every board (the portfolio, as
    /// deployed).
    pub fn compositions(&self) -> Vec<Composition> {
        self.boards.iter().map(|b| b.composition()).collect()
    }

    /// First arrival to last completion across the whole fleet.
    pub fn makespan(&self) -> SimTime {
        match self.first_arrival {
            Some(t0) => self.last_finish.saturating_sub(t0),
            None => SimTime::ZERO,
        }
    }

    /// Aggregate the boards' serving metrics into a [`FleetMetrics`]
    /// snapshot.
    pub fn metrics(&self) -> FleetMetrics {
        FleetMetrics::aggregate(&self.boards, self.makespan())
    }

    /// The fleet-level telemetry series bank, sampled at every fleet
    /// drain boundary (`None` without [`FleetConfig::with_telemetry`];
    /// per-board banks live on each board,
    /// [`Coordinator::telemetry_series`]).
    pub fn fleet_series(&self) -> Option<&crate::obs::SeriesBank> {
        self.telemetry.as_ref().map(|t| &t.series)
    }

    /// Fleet-level alerts fired so far, in firing order (empty without
    /// a telemetry config; per-board alerts live on each board,
    /// [`Coordinator::alerts`]).
    pub fn fleet_alerts(&self) -> &[crate::obs::Alert] {
        self.telemetry
            .as_ref()
            .map(|t| t.engine.alerts())
            .unwrap_or(&[])
    }

    /// Export the whole fleet run as one Chrome trace: one process per
    /// board, each with the full per-board track layout (requires
    /// [`FleetConfig::with_tracing`]). With telemetry configured, each
    /// board's counter tracks ride under its pid and the fleet-level
    /// bank becomes its own `fleet` process. Validates under
    /// [`crate::obs::export::validate_chrome_trace`].
    pub fn chrome_trace(&self) -> String {
        let per_board: Vec<_> = self.boards.iter().map(|b| b.spans().snapshot()).collect();
        match &self.telemetry {
            Some(tel) => {
                let banks: Vec<_> = self.boards.iter().map(|b| b.telemetry_series()).collect();
                crate::obs::export::fleet_chrome_trace_with_series(
                    &per_board,
                    &banks,
                    Some(&tel.series),
                )
            }
            None => crate::obs::export::fleet_chrome_trace(&per_board),
        }
    }
}
