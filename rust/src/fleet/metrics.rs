//! Fleet-level telemetry: per-board [`ServingMetrics`] aggregated into
//! one view.
//!
//! Counters add, latency/wait distributions merge bucket-wise through
//! [`Histogram::merge`] (so fleet tail latency is exactly what one
//! histogram fed every board's samples would report — no samples are
//! retained anywhere), and throughput is completions over the *fleet*
//! makespan, not the sum of per-board rates (boards overlap in modeled
//! time; summing rates would double-count the overlap).

use std::time::Duration;

use crate::coordinator::{Coordinator, ServingMetrics};
use crate::elastic::Composition;
use crate::obs::{Histogram, MetricsRegistry};
use crate::sysc::SimTime;

/// One board's contribution to the fleet view.
#[derive(Debug, Clone)]
pub struct BoardStats {
    /// Board index within the fleet.
    pub board: usize,
    /// Requests this board accepted.
    pub submitted: u64,
    /// Backpressure rejections on this board.
    pub rejected: u64,
    /// Admission-control sheds on this board.
    pub shed_predicted: u64,
    /// Requests this board completed.
    pub completed: u64,
    /// Pool reconfigurations applied on this board (portfolio swaps
    /// plus any board-local elastic swaps).
    pub reconfigs: u64,
    /// Modeled bitstream-load time charged on this board.
    pub reconfig_time: SimTime,
    /// The board's live pool composition.
    pub composition: Composition,
    /// Mean worker utilization over the fleet makespan: total worker
    /// busy time divided by (workers x makespan), in `[0, 1]`.
    pub utilization: f64,
    /// Total modeled busy time across the board's workers (the
    /// numerator of `utilization`, exposed so aggregation is
    /// checkable).
    pub busy: SimTime,
    /// Workers on the board (the other utilization denominator term).
    pub workers: usize,
}

/// The aggregated fleet view ([`crate::fleet::Fleet::metrics`]).
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    /// Per-board breakdown, indexed by board.
    pub boards: Vec<BoardStats>,
    /// Fleet-total accepted submissions.
    pub submitted: u64,
    /// Fleet-total backpressure rejections.
    pub rejected: u64,
    /// Fleet-total admission-control sheds.
    pub shed_predicted: u64,
    /// Fleet-total completions.
    pub completed: u64,
    /// Fleet-total reconfigurations.
    pub reconfigs: u64,
    /// Fleet-total modeled bitstream-load time.
    pub reconfig_time: SimTime,
    /// First arrival to last completion across the whole fleet.
    pub makespan: SimTime,
    /// Host wall-clock accumulated inside threaded drains, all boards.
    pub wall_elapsed: Duration,
    /// Requests completed inside threaded drains, all boards.
    pub wall_completed: u64,
    latencies: Histogram,
    waits: Histogram,
}

impl FleetMetrics {
    /// Aggregate the boards' [`ServingMetrics`] under the given fleet
    /// makespan (the fleet tracks its own first-arrival/last-finish
    /// envelope; per-board makespans would under-count idle boards).
    pub fn aggregate(boards: &[Coordinator], makespan: SimTime) -> Self {
        let mut m = FleetMetrics {
            boards: Vec::with_capacity(boards.len()),
            submitted: 0,
            rejected: 0,
            shed_predicted: 0,
            completed: 0,
            reconfigs: 0,
            reconfig_time: SimTime::ZERO,
            makespan,
            wall_elapsed: Duration::ZERO,
            wall_completed: 0,
            latencies: Histogram::new(),
            waits: Histogram::new(),
        };
        for (i, b) in boards.iter().enumerate() {
            let sm: &ServingMetrics = b.metrics();
            let busy = b
                .pool()
                .workers
                .iter()
                .fold(SimTime::ZERO, |acc, w| acc + w.busy);
            let workers = b.pool().workers.len();
            let utilization = if makespan == SimTime::ZERO || workers == 0 {
                0.0
            } else {
                busy.as_secs_f64() / (workers as f64 * makespan.as_secs_f64())
            };
            m.boards.push(BoardStats {
                board: i,
                submitted: sm.submitted,
                rejected: sm.rejected,
                shed_predicted: sm.shed_predicted,
                completed: sm.completed,
                reconfigs: sm.reconfigs,
                reconfig_time: sm.reconfig_time,
                composition: b.composition(),
                utilization,
                busy,
                workers,
            });
            m.submitted += sm.submitted;
            m.rejected += sm.rejected;
            m.shed_predicted += sm.shed_predicted;
            m.completed += sm.completed;
            m.reconfigs += sm.reconfigs;
            m.reconfig_time += sm.reconfig_time;
            m.wall_elapsed += sm.wall_elapsed;
            m.wall_completed += sm.wall_completed;
            m.latencies.merge(sm.latency_histogram());
            m.waits.merge(sm.wait_histogram());
        }
        m
    }

    /// Fleet completions per modeled second (aggregate req/s).
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.makespan.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / secs
    }

    /// Fleet completions per host wall-clock second spent in threaded
    /// drains (zero when no board ran threaded).
    pub fn wall_throughput_rps(&self) -> f64 {
        let secs = self.wall_elapsed.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.wall_completed as f64 / secs
    }

    /// Fleet-wide latency percentile (merged across boards; extremes
    /// exact, interior within the histogram's ~1.6% bucket width).
    pub fn latency_pct(&self, p: f64) -> SimTime {
        self.latencies.quantile_time(p)
    }

    /// Fleet-wide queue-wait percentile (same merge).
    pub fn wait_pct(&self, p: f64) -> SimTime {
        self.waits.quantile_time(p)
    }

    /// The merged latency histogram itself.
    pub fn latency_histogram(&self) -> &Histogram {
        &self.latencies
    }

    /// The merged queue-wait histogram itself.
    pub fn wait_histogram(&self) -> &Histogram {
        &self.waits
    }

    /// One-paragraph fleet summary plus a per-board line each.
    pub fn summary(&self) -> String {
        let mut out = format!(
            "fleet[{} boards] served {}/{} requests ({} rejected, {} shed) \
             in {} makespan -> {:.2} req/s; latency p50 {} p99 {}",
            self.boards.len(),
            self.completed,
            self.submitted,
            self.rejected,
            self.shed_predicted,
            self.makespan,
            self.throughput_rps(),
            self.latency_pct(0.5),
            self.latency_pct(0.99),
        );
        if self.reconfigs > 0 {
            out.push_str(&format!(
                "; {} reconfigs ({} bitstream time)",
                self.reconfigs, self.reconfig_time
            ));
        }
        if self.wall_elapsed > Duration::ZERO {
            out.push_str(&format!(
                "; wall {:.1} ms -> {:.1} req/s real",
                self.wall_elapsed.as_secs_f64() * 1e3,
                self.wall_throughput_rps()
            ));
        }
        for b in &self.boards {
            out.push_str(&format!(
                "\n  board{}: {} {} done, util {:.1}%, {} shed, {} reconfigs",
                b.board,
                b.composition,
                b.completed,
                100.0 * b.utilization,
                b.shed_predicted,
                b.reconfigs,
            ));
        }
        out
    }

    /// A flat [`MetricsRegistry`] snapshot — `fleet.*` aggregates plus
    /// `board{N}.*` breakdowns — exportable through
    /// [`crate::obs::export::metrics_json`].
    pub fn registry(&self) -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        r.counter("fleet.boards", self.boards.len() as u64);
        r.counter("fleet.submitted", self.submitted);
        r.counter("fleet.rejected", self.rejected);
        r.counter("fleet.shed_predicted", self.shed_predicted);
        r.counter("fleet.completed", self.completed);
        r.counter("fleet.reconfigs", self.reconfigs);
        r.gauge("fleet.throughput_rps", self.throughput_rps());
        r.gauge("fleet.wall_throughput_rps", self.wall_throughput_rps());
        r.gauge("fleet.makespan_ms", self.makespan.as_ms_f64());
        r.gauge("fleet.reconfig_time_ms", self.reconfig_time.as_ms_f64());
        r.histogram("fleet.latency_ps", &self.latencies);
        r.histogram("fleet.queue_wait_ps", &self.waits);
        for b in &self.boards {
            r.counter(&format!("board{}.completed", b.board), b.completed);
            r.counter(&format!("board{}.shed_predicted", b.board), b.shed_predicted);
            r.counter(&format!("board{}.reconfigs", b.board), b.reconfigs);
            r.gauge(&format!("board{}.utilization", b.board), b.utilization);
        }
        r
    }
}
