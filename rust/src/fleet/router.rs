//! The fleet front-end router: cost-model placement over gossip.
//!
//! Placement is a pure function of three inputs — the request (model +
//! input tensor), the current [`GossipTable`](super::GossipTable)
//! snapshots, and the
//! router's own [`CostModel`] priors — so the placement sequence for a
//! given submit stream is identical across exec modes and reruns. The
//! score of a board is the modeled time for the request to *ingress*
//! (move its input over the fleet network, [`IngressModel`]), wait out
//! the board's gossiped backlog, and execute on the best design the
//! board carries:
//!
//! ```text
//! score(board) = ingress(input bytes)
//!              + backlog(gossiped queue depth x exec / workers)
//!              + exec(min over the board's designs of request_cost)
//! ```
//!
//! Lowest score wins; ties break to the lowest board index. The
//! admission pre-check (never place onto a board whose admission
//! control would shed — [`crate::coordinator::Coordinator::would_shed`])
//! lives in [`crate::fleet::Fleet::submit_with_deadline`], because it
//! consults the board itself rather than gossip.

use std::sync::Arc;

use crate::coordinator::{CostModel, WorkerKind};
use crate::framework::graph::Graph;
use crate::framework::tensor::Tensor;
use crate::sysc::SimTime;

use super::gossip::BoardSnapshot;

/// Modeled network/DMA ingress cost: what it takes to move a request's
/// input tensor from the front-end to a board.
#[derive(Debug, Clone, Copy)]
pub struct IngressModel {
    /// Fixed per-request overhead (connection + DMA descriptor setup).
    pub base: SimTime,
    /// Link bandwidth in bytes per second; `0.0` disables the
    /// per-byte term entirely.
    pub bytes_per_sec: f64,
}

impl Default for IngressModel {
    fn default() -> Self {
        // gigabit Ethernet to the board, plus a fixed hop overhead —
        // deliberately slower than the on-board AXI DMA the driver
        // models, so fleet ingress is a real cost the router weighs
        IngressModel {
            base: SimTime::us(50),
            bytes_per_sec: 125.0e6,
        }
    }
}

impl IngressModel {
    /// A free ingress (zero base, zero per-byte): a 1-board fleet with
    /// this model degenerates bit-for-bit to a bare coordinator, which
    /// the `prop_fleet_matches_single_board` property pins.
    pub fn none() -> Self {
        IngressModel {
            base: SimTime::ZERO,
            bytes_per_sec: 0.0,
        }
    }

    /// Modeled time to move `bytes` to a board.
    pub fn cost(&self, bytes: u64) -> SimTime {
        let per_byte = if self.bytes_per_sec > 0.0 {
            SimTime::ps((bytes as f64 / self.bytes_per_sec * 1e12) as u64)
        } else {
            SimTime::ZERO
        };
        self.base + per_byte
    }
}

/// One scored placement candidate (returned by [`Router::rank`] for
/// telemetry and tests; the fleet places on the first entry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    /// Board index.
    pub board: usize,
    /// Total modeled score (ingress + backlog + exec), in picoseconds.
    pub score_ps: u64,
}

/// The front-end placement engine.
#[derive(Debug)]
pub struct Router {
    ingress: IngressModel,
    cost: CostModel,
    // request_cost walks the whole graph; memoize per (model, kind).
    // The Arc is held so a memoized pointer can never be recycled by a
    // dropped-and-reallocated graph.
    memo: Vec<(Arc<Graph>, [Option<SimTime>; 3])>,
}

const KINDS: [WorkerKind; 3] = [WorkerKind::Sa, WorkerKind::Vm, WorkerKind::Cpu];

impl Router {
    /// A router with the given ingress model and cost-model
    /// calibration (`threads`/`sync_overhead` as in
    /// [`CostModel::new`] — pass the boards' driver settings so the
    /// router prices work the way the boards do).
    pub fn new(ingress: IngressModel, threads: usize, sync_overhead: SimTime) -> Self {
        Router {
            ingress,
            cost: CostModel::new(threads, sync_overhead),
            memo: Vec::new(),
        }
    }

    /// The ingress model in force.
    pub fn ingress(&self) -> &IngressModel {
        &self.ingress
    }

    fn request_cost(&mut self, model: &Arc<Graph>, kind: WorkerKind) -> SimTime {
        let slot = KINDS.iter().position(|k| *k == kind).expect("known kind");
        let entry = match self.memo.iter().position(|(g, _)| Arc::ptr_eq(g, model)) {
            Some(i) => i,
            None => {
                self.memo.push((model.clone(), [None; 3]));
                self.memo.len() - 1
            }
        };
        if let Some(c) = self.memo[entry].1[slot] {
            return c;
        }
        let c = self.cost.request_cost(model, kind);
        self.memo[entry].1[slot] = Some(c);
        c
    }

    /// Modeled execution cost of `model` on the cheapest design in
    /// `comp` (CPU-priced when the composition is empty — it cannot
    /// be, but the router must stay total).
    fn exec_cost(&mut self, model: &Arc<Graph>, comp: &crate::elastic::Composition) -> SimTime {
        let mut best: Option<SimTime> = None;
        for (kind, n) in [
            (WorkerKind::Sa, comp.sa),
            (WorkerKind::Vm, comp.vm),
            (WorkerKind::Cpu, comp.cpu),
        ] {
            if n == 0 {
                continue;
            }
            let c = self.request_cost(model, kind);
            best = Some(match best {
                Some(b) => b.min(c),
                None => c,
            });
        }
        best.unwrap_or_else(|| self.request_cost(model, WorkerKind::Cpu))
    }

    /// Score every board against the gossiped snapshots and return the
    /// candidates sorted best-first (score, then board index). The
    /// fleet submits to the first candidate that passes the admission
    /// pre-check.
    pub fn rank(
        &mut self,
        snaps: &[BoardSnapshot],
        model: &Arc<Graph>,
        input: &Tensor,
    ) -> Vec<Candidate> {
        let ingress = self.ingress.cost(input.bytes() as u64).as_ps();
        let mut out: Vec<Candidate> = snaps
            .iter()
            .map(|s| {
                let exec = self.exec_cost(model, &s.composition).as_ps();
                let workers = s.composition.total().max(1) as u64;
                // gossiped queue depth spread across the board's
                // workers: how long the request waits behind work the
                // snapshot already saw
                let backlog = exec
                    .saturating_mul(s.queued as u64)
                    .checked_div(workers)
                    .unwrap_or(u64::MAX);
                Candidate {
                    board: s.board,
                    score_ps: ingress.saturating_add(exec).saturating_add(backlog),
                }
            })
            .collect();
        out.sort_by_key(|c| (c.score_ps, c.board));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::DriverConfig;
    use crate::elastic::Composition;
    use crate::framework::models;

    fn router(ingress: IngressModel) -> Router {
        let d = DriverConfig::default();
        Router::new(ingress, d.threads, d.sync_overhead)
    }

    fn snap(board: usize, queued: usize, comp: Composition) -> BoardSnapshot {
        BoardSnapshot {
            board,
            queued,
            composition: comp,
            taken_at: SimTime::ZERO,
        }
    }

    #[test]
    fn ingress_none_is_free_and_default_is_not() {
        assert_eq!(IngressModel::none().cost(1 << 20), SimTime::ZERO);
        let lan = IngressModel::default();
        assert!(lan.cost(0) >= SimTime::us(50));
        assert!(lan.cost(1 << 20) > lan.cost(0), "per-byte term exists");
    }

    #[test]
    fn idle_identical_boards_tie_break_to_lowest_index() {
        let g = Arc::new(models::by_name("mobilenet_v1").unwrap());
        let input = Tensor::zeros(g.input_shape.clone(), g.input_qp);
        let mut r = router(IngressModel::none());
        let comp = Composition::new(2, 1, 1);
        let ranked = r.rank(
            &[snap(0, 0, comp), snap(1, 0, comp), snap(2, 0, comp)],
            &g,
            &input,
        );
        assert_eq!(ranked[0].board, 0);
        assert!(ranked.iter().all(|c| c.score_ps == ranked[0].score_ps));
    }

    #[test]
    fn gossiped_backlog_steers_away() {
        let g = Arc::new(models::by_name("mobilenet_v1").unwrap());
        let input = Tensor::zeros(g.input_shape.clone(), g.input_qp);
        let mut r = router(IngressModel::none());
        let comp = Composition::new(2, 1, 1);
        let ranked = r.rank(&[snap(0, 8, comp), snap(1, 0, comp)], &g, &input);
        assert_eq!(ranked[0].board, 1, "idle board beats a backlogged one");
        assert!(ranked[0].score_ps < ranked[1].score_ps);
    }

    #[test]
    fn rank_is_deterministic() {
        let g = Arc::new(models::by_name("mobilenet_v1").unwrap());
        let input = Tensor::zeros(g.input_shape.clone(), g.input_qp);
        let snaps = [
            snap(0, 3, Composition::new(2, 0, 1)),
            snap(1, 1, Composition::new(0, 2, 1)),
            snap(2, 0, Composition::new(1, 1, 1)),
        ];
        let a = router(IngressModel::default()).rank(&snaps, &g, &input);
        let b = router(IngressModel::default()).rank(&snaps, &g, &input);
        assert_eq!(a, b);
    }
}
