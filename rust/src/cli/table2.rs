//! The Table II harness: regenerate every row of the paper's main
//! result table (inference time split CONV / Non-CONV / Overall plus
//! energy, for the four models under each hardware setup).

use crate::accel::{SaDesign, VmConfig, VmDesign};
use crate::driver::{AccelBackend, DriverConfig};
use crate::framework::backend::CpuBackend;
use crate::framework::interpreter::{InferenceReport, Session};
use crate::framework::models;
use crate::framework::tensor::Tensor;
use crate::perf::EnergyModel;
use crate::vta::VtaDesign;

/// A hardware setup column of Table II.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Setup {
    /// CPU-only gemmlowp with the given thread count.
    Cpu(usize),
    /// CPU threads + the VM accelerator (paper Fig. 3).
    CpuVm(usize),
    /// CPU threads + the SA accelerator (paper Fig. 4).
    CpuSa(usize),
    /// CPU (2 threads) + the VTA baseline (§V-C).
    CpuVta,
}

impl Setup {
    /// The column header used in the rendered table (and stored in
    /// [`InferenceReport::setup`]).
    pub fn label(&self) -> String {
        match self {
            Setup::Cpu(t) => format!("CPU ({t} thr)"),
            Setup::CpuVm(t) => format!("CPU ({t} thr) + VM"),
            Setup::CpuSa(t) => format!("CPU ({t} thr) + SA"),
            Setup::CpuVta => "CPU (2 thr) + VTA".to_string(),
        }
    }

    /// CPU threads available to the interpreter under this setup.
    pub fn threads(&self) -> usize {
        match self {
            Setup::Cpu(t) | Setup::CpuVm(t) | Setup::CpuSa(t) => *t,
            Setup::CpuVta => 2,
        }
    }

    /// The six standard setups of Table II.
    pub const STANDARD: [Setup; 6] = [
        Setup::Cpu(1),
        Setup::CpuVm(1),
        Setup::CpuSa(1),
        Setup::Cpu(2),
        Setup::CpuVm(2),
        Setup::CpuSa(2),
    ];
}

/// Deterministic synthetic "image" input for a graph.
pub fn synthetic_input(g: &crate::framework::graph::Graph) -> Tensor {
    let n: usize = g.input_shape.iter().product();
    let mut st = 0x5eedu64;
    let data = (0..n)
        .map(|_| {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            (st & 0xff) as u8 as i8
        })
        .collect();
    Tensor::new(g.input_shape.clone(), data, g.input_qp)
}

/// Run one (model, setup) cell of Table II.
pub fn run_cell(model: &str, setup: Setup) -> InferenceReport {
    let g = models::by_name(model).unwrap_or_else(|| panic!("unknown model {model}"));
    let input = synthetic_input(&g);
    let threads = setup.threads();
    let mut report = match setup {
        Setup::Cpu(t) => {
            let mut backend = CpuBackend::new(t);
            let mut sess = Session::new(&g, &mut backend, t);
            sess.setup_label = setup.label();
            sess.run(&input).1
        }
        Setup::CpuVm(t) => {
            // the paper's final VM flow: ResNet18 uses the §IV-E4
            // variant (bigger local buffers) to avoid CPU fallbacks
            let cfg = if model == "resnet18" {
                VmConfig::resnet_variant()
            } else {
                VmConfig::paper()
            };
            let mut backend =
                AccelBackend::new(VmDesign::new(cfg), DriverConfig::with_threads(t));
            let mut sess = Session::new(&g, &mut backend, t);
            sess.setup_label = setup.label();
            sess.run(&input).1
        }
        Setup::CpuSa(t) => {
            let mut backend =
                AccelBackend::new(SaDesign::paper(), DriverConfig::with_threads(t));
            let mut sess = Session::new(&g, &mut backend, t);
            sess.setup_label = setup.label();
            sess.run(&input).1
        }
        Setup::CpuVta => {
            let mut dcfg = DriverConfig::with_threads(2);
            // TVM keeps tensors resident: far less per-layer CPU prep
            dcfg.sync_overhead = crate::sysc::SimTime::us(60);
            let mut backend = AccelBackend::new(VtaDesign::pynq(), dcfg);
            let mut sess = Session::new(&g, &mut backend, 2);
            sess.setup_label = setup.label();
            sess.run(&input).1
        }
    };
    if setup == Setup::CpuVta {
        // Energy correction for VTA (§V-C): TVM keeps the CPU largely
        // idle while the accelerator runs most layers (fewer off-chip
        // transfers), and VTA's GEMM core is a smaller, lower-power
        // fabric design than the SECDA accelerators — the paper's VTA
        // row draws 2.05 W vs SA's 3.28 W. Model: ~20% CPU duty cycle
        // and ~40% of the SECDA fabric power.
        let e = EnergyModel::pynq();
        let overall = report.overall();
        report.energy_j = overall.as_secs_f64() * (e.p_idle_w + 0.2 * 2.0 * e.p_per_thread_w)
            + report.accel_active.as_secs_f64() * 0.4 * e.p_fpga_active_w;
    }
    let _ = threads;
    report
}

/// All rows of Table II for the given models (plus the VTA row for
/// ResNet18, as in the paper).
pub fn table2(model_names: &[&str]) -> Vec<InferenceReport> {
    let mut rows = Vec::new();
    for model in model_names {
        for setup in Setup::STANDARD {
            rows.push(run_cell(model, setup));
        }
        if *model == "resnet18" {
            rows.push(run_cell(model, Setup::CpuVta));
        }
    }
    rows
}

/// Render rows in the paper's layout.
pub fn render(rows: &[InferenceReport]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<14} {:<18} {:>10} {:>10} {:>10} {:>8}\n",
        "DNN", "Hardware setup", "CONV", "Non-CONV", "Overall", "Energy"
    ));
    let mut last_model = String::new();
    for r in rows {
        let model = if r.model == last_model {
            String::new()
        } else {
            last_model = r.model.clone();
            r.model.clone()
        };
        out.push_str(&format!(
            "{:<14} {:<18} {:>7.0} ms {:>7.0} ms {:>7.0} ms {:>6.2} J\n",
            model,
            r.setup,
            r.conv_time.as_ms_f64(),
            r.nonconv_time.as_ms_f64(),
            r.overall().as_ms_f64(),
            r.energy_j
        ));
    }
    out
}

/// §V-B summary statistics across models for a pair of setups.
pub fn speedup_summary(rows: &[InferenceReport], base: Setup, accel: Setup) -> (f64, f64) {
    let mut speedups = Vec::new();
    let mut energy_ratios = Vec::new();
    let models: Vec<&str> = {
        let mut seen = Vec::new();
        for r in rows {
            if !seen.contains(&r.model.as_str()) {
                seen.push(&r.model);
            }
        }
        seen
    };
    for m in models {
        let find = |s: Setup| {
            rows.iter()
                .find(|r| r.model == m && r.setup == s.label())
        };
        if let (Some(b), Some(a)) = (find(base), find(accel)) {
            speedups.push(b.overall().as_secs_f64() / a.overall().as_secs_f64());
            energy_ratios.push(b.energy_j / a.energy_j);
        }
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    (avg(&speedups), avg(&energy_ratios))
}
