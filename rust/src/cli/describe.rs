//! Textual block diagrams of the case-study designs — the structural
//! realization of the paper's Figures 3 and 4 (`secda describe vm|sa`).

use crate::accel::{SaConfig, VmConfig};
use crate::synth;

/// Render the VM accelerator's block diagram (paper Fig. 3) with the
/// concrete parameters of `cfg` and its synthesized resource estimate.
pub fn describe_vm(cfg: &VmConfig) -> String {
    let r = synth::synthesize_vm(cfg);
    let mut s = String::new();
    s.push_str("VM accelerator (paper Fig. 3)\n");
    s.push_str("=============================\n");
    s.push_str(&format!(
        "  AXI DMA        : {} HP port(s), {} B/beat, burst {}\n",
        cfg.axi.links, cfg.axi.bytes_per_beat, cfg.axi.burst_beats
    ));
    s.push_str("  Input Handler  -> distributes to banked global buffers\n");
    s.push_str(&format!(
        "  Weight buffer  : {} KiB over {} banks\n",
        cfg.global_weight_buf.capacity_bytes / 1024,
        cfg.global_weight_buf.banks
    ));
    s.push_str(&format!(
        "  Input buffer   : {} KiB over {} banks ({} B/cycle)\n",
        cfg.global_input_buf.capacity_bytes / 1024,
        cfg.global_input_buf.banks,
        cfg.global_input_buf.read_bytes_per_cycle()
    ));
    s.push_str(&format!(
        "  Scheduler      : weight-stripe broadcast {}\n",
        if cfg.scheduler_broadcast { "ON (1x reads)" } else { "OFF (4x reads)" }
    ));
    for u in 0..cfg.units {
        s.push_str(&format!(
            "  GEMM unit[{u}]   : {}x{} outputs x {} MACs + adder tree, local buf {} KiB\n",
            cfg.unit.tile_m,
            cfg.unit.tile_n,
            cfg.unit.macs_per_output,
            cfg.local_buf_bytes / 1024
        ));
    }
    match &cfg.ppu {
        Some(p) => s.push_str(&format!(
            "  PPU x{}         : {} lanes each (bias+requant+clamp+narrow)\n",
            cfg.units, p.lanes
        )),
        None => s.push_str("  PPU            : none (int32 results unpacked on CPU)\n"),
    }
    s.push_str("  Output Crossbar-> Output DMA -> main memory\n");
    s.push_str(&format!(
        "  Peak           : {} MAC/cycle @ {} MHz = {:.1} GMAC/s\n",
        cfg.units as u64 * cfg.unit.macs_per_cycle(),
        cfg.clock_mhz,
        cfg.units as f64 * cfg.unit.macs_per_cycle() as f64 * cfg.clock_mhz / 1e3
    ));
    s.push_str(&format!(
        "  Resources      : {} LUT, {} FF, {} DSP, {} BRAM36 ({}), util {:.0}%\n",
        r.resources.luts,
        r.resources.ffs,
        r.resources.dsps,
        r.resources.bram36,
        if r.fits { "fits Zynq-7020" } else { "DOES NOT FIT" },
        r.utilization * 100.0
    ));
    s
}

/// Render the SA accelerator's block diagram (paper Fig. 4) with the
/// concrete parameters of `cfg` and its synthesized resource estimate.
pub fn describe_sa(cfg: &SaConfig) -> String {
    let r = synth::synthesize_sa(cfg);
    let d = cfg.array.dim;
    let mut s = String::new();
    s.push_str("SA accelerator (paper Fig. 4)\n");
    s.push_str("=============================\n");
    s.push_str(&format!(
        "  AXI DMA        : {} HP port(s)\n  Input Handler  -> global buffers\n",
        cfg.axi.links
    ));
    s.push_str(&format!(
        "  Weight buffer  : {} KiB | Input buffer: {} KiB\n",
        cfg.global_weight_buf.capacity_bytes / 1024,
        cfg.global_input_buf.capacity_bytes / 1024
    ));
    s.push_str(&format!(
        "  Scheduler      : fills {} data queues ({} weight cols + {} input rows), {} fill\n",
        cfg.array.queue_count(),
        d,
        d,
        if cfg.array.parallel_fill { "parallel" } else { "serial" }
    ));
    s.push_str(&format!(
        "  Systolic array : {d}x{d} output-stationary MACs (weights move down, inputs right)\n"
    ));
    match &cfg.ppu {
        Some(p) => s.push_str(&format!("  PPU            : single, {} lanes\n", p.lanes)),
        None => s.push_str("  PPU            : none (int32 to CPU)\n"),
    }
    s.push_str("  Output DMA     -> main memory\n");
    s.push_str(&format!(
        "  Peak           : {} MAC/cycle @ {} MHz = {:.1} GMAC/s\n",
        cfg.array.macs_per_cycle(),
        cfg.clock_mhz,
        cfg.array.macs_per_cycle() as f64 * cfg.clock_mhz / 1e3
    ));
    s.push_str(&format!(
        "  Resources      : {} LUT, {} FF, {} DSP, {} BRAM36 ({}), util {:.0}%\n",
        r.resources.luts,
        r.resources.ffs,
        r.resources.dsps,
        r.resources.bram36,
        if r.fits { "fits Zynq-7020" } else { "DOES NOT FIT" },
        r.utilization * 100.0
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptions_mention_key_structure() {
        let vm = describe_vm(&VmConfig::paper());
        assert!(vm.contains("GEMM unit[3]"));
        assert!(vm.contains("Output Crossbar"));
        assert!(vm.contains("fits Zynq-7020"));
        let sa = describe_sa(&SaConfig::paper());
        assert!(sa.contains("16x16 output-stationary"));
        assert!(sa.contains("32 data queues"));
    }
}
