//! CLI plumbing: the Table II harness, design descriptions, and the
//! hand-rolled argument parsing used by `rust/src/main.rs` (the
//! offline environment has no clap; see Cargo.toml).

pub mod describe;
pub mod table2;
