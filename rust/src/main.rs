//! `secda` — the SECDA reproduction CLI (Layer-3 leader entrypoint).
//!
//! Subcommands (hand-rolled parsing; the offline vendor set has no
//! clap):
//!
//! ```text
//! secda table2 [model...]        regenerate Table II rows
//! secda describe <vm|sa> [dim]   print a design block diagram (Figs 3/4)
//! secda synth <vm|sa> [dim]      resource + synthesis-time report
//! secda simulate <vm|sa> M K N   TLM-simulate one GEMM, per-component report
//! secda sa-sizes                 §IV-E3 systolic-array size sweep
//! secda devtime                  Eq. 1-3 development-time model
//! secda dse [flags]              parallel design-space exploration campaign
//! secda runtime-check            PJRT artifact numerics vs CPU gemm
//! secda trace-validate <file...>  check exported observability files
//! secda report <file> [--profile <trace.json>]
//!                                summarize a metrics / time-series export
//! secda bench-diff <old> <new>   perf-regression gate over bench snapshots
//! ```

use std::process::ExitCode;

use secda::accel::{ExecMode, GemmAccel, GemmRequest, SaConfig, SaDesign, VmConfig, VmDesign};
use secda::cli::{describe, table2};
use secda::framework::quant::quantize_multiplier;
use secda::gemm::QGemmParams;
use secda::perf::devtime;
use secda::synth;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "table2" => cmd_table2(&args[1..]),
        "describe" => cmd_describe(&args[1..]),
        "synth" => cmd_synth(&args[1..]),
        "simulate" => cmd_simulate(&args[1..]),
        "sa-sizes" => cmd_sa_sizes(),
        "devtime" => cmd_devtime(),
        "dse" => cmd_dse(&args[1..]),
        "runtime-check" => cmd_runtime_check(),
        "trace-validate" => cmd_trace_validate(&args[1..]),
        "report" => cmd_report(&args[1..]),
        "bench-diff" => cmd_bench_diff(&args[1..]),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            ExitCode::SUCCESS
        }
        other => {
            eprintln!("unknown command `{other}`\n{HELP}");
            ExitCode::FAILURE
        }
    }
}

const HELP: &str = "\
secda — SECDA reproduction (SystemC-enabled co-design of DNN accelerators)

USAGE: secda <command> [args]

COMMANDS:
  table2 [model...]       regenerate Table II (default: all four models)
  describe <vm|sa> [dim]  design block diagram (paper Figs. 3/4)
  synth <vm|sa> [dim]     resource estimate + synthesis-time model
  simulate <vm|sa> M K N  TLM-simulate one GEMM with per-component stats
  sa-sizes                §IV-E3 systolic array size sweep (4/8/16)
  devtime                 Eq. 1-3 development-time comparison
  dse [--budget N] [--threads N] [--cache FILE] [--out FILE] [--assert-warm]
                          run a design-space exploration campaign over the
                          bundled model workloads; --cache persists the memo
                          cache across runs, --out writes the Pareto JSON,
                          --assert-warm fails if any fresh simulation ran
  dse --validate <pareto.json>
                          validate a Pareto document written by --out
  runtime-check           verify PJRT artifacts against the CPU gemm
  trace-validate <file...>
                          validate exported observability JSON (Chrome
                          trace, metrics snapshot or time-series document;
                          the schema is auto-detected per file)
  report <file> [--profile <trace.json>] [--top N] [--collapsed FILE]
                          summarize a metrics snapshot or time-series
                          document: per-series stats, fired alerts, and
                          (with --profile) the top-N self-time frames
                          folded from a Chrome trace; --collapsed writes
                          flamegraph-ready collapsed stacks
  bench-diff <committed.json> <new.json> [--tol FRACTION]
                          diff two serving-bench snapshots with per-metric
                          tolerance (default 0.10): fail on throughput /
                          tail-latency regressions beyond the tolerance
";

fn cmd_table2(args: &[String]) -> ExitCode {
    let models: Vec<&str> = if args.is_empty() {
        secda::framework::models::ALL.to_vec()
    } else {
        args.iter().map(String::as_str).collect()
    };
    for m in &models {
        if secda::framework::models::by_name(m).is_none() {
            eprintln!(
                "unknown model `{m}` (known: {:?})",
                secda::framework::models::ALL
            );
            return ExitCode::FAILURE;
        }
    }
    eprintln!("running Table II for {models:?} (full functional inference per cell)...");
    let rows = table2::table2(&models);
    print!("{}", table2::render(&rows));
    // §V-B summary lines
    use table2::Setup;
    for (base, accel, label) in [
        (Setup::Cpu(1), Setup::CpuVm(1), "VM vs CPU(1thr)"),
        (Setup::Cpu(1), Setup::CpuSa(1), "SA vs CPU(1thr)"),
        (Setup::Cpu(2), Setup::CpuVm(2), "VM vs CPU(2thr)"),
        (Setup::Cpu(2), Setup::CpuSa(2), "SA vs CPU(2thr)"),
    ] {
        let (s, e) = table2::speedup_summary(&rows, base, accel);
        println!("avg {label}: {s:.2}x speedup, {e:.2}x energy reduction");
    }
    ExitCode::SUCCESS
}

fn cmd_describe(args: &[String]) -> ExitCode {
    match args.first().map(String::as_str) {
        Some("vm") => {
            print!("{}", describe::describe_vm(&VmConfig::paper()));
            ExitCode::SUCCESS
        }
        Some("sa") => {
            let dim = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
            print!("{}", describe::describe_sa(&SaConfig::with_dim(dim)));
            ExitCode::SUCCESS
        }
        _ => {
            eprintln!("usage: secda describe <vm|sa> [dim]");
            ExitCode::FAILURE
        }
    }
}

fn cmd_synth(args: &[String]) -> ExitCode {
    let rep = match args.first().map(String::as_str) {
        Some("vm") => synth::synthesize_vm(&VmConfig::paper()),
        Some("sa") => {
            let dim = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
            synth::synthesize_sa(&SaConfig::with_dim(dim))
        }
        _ => {
            eprintln!("usage: secda synth <vm|sa> [dim]");
            return ExitCode::FAILURE;
        }
    };
    println!(
        "resources: {} LUT, {} FF, {} DSP, {} BRAM36",
        rep.resources.luts, rep.resources.ffs, rep.resources.dsps, rep.resources.bram36
    );
    println!(
        "fits Zynq-7020: {} (max utilization {:.0}%)",
        rep.fits,
        rep.utilization * 100.0
    );
    println!(
        "modeled synthesis time: {:.1} min",
        rep.synth_time.as_secs_f64() / 60.0
    );
    ExitCode::SUCCESS
}

fn parse_mkn(args: &[String]) -> Option<(usize, usize, usize)> {
    Some((
        args.first()?.parse().ok()?,
        args.get(1)?.parse().ok()?,
        args.get(2)?.parse().ok()?,
    ))
}

fn cmd_simulate(args: &[String]) -> ExitCode {
    let design = args.first().map(String::as_str).unwrap_or("sa");
    let Some((m, k, n)) = parse_mkn(&args[1..]) else {
        eprintln!("usage: secda simulate <vm|sa> M K N");
        return ExitCode::FAILURE;
    };
    let mut st = 1u64;
    let mut rnd = || {
        st ^= st << 13;
        st ^= st >> 7;
        st ^= st << 17;
        st
    };
    let w: Vec<i8> = (0..m * k).map(|_| (rnd() & 0xff) as u8 as i8).collect();
    let x: Vec<i8> = (0..k * n).map(|_| (rnd() & 0xff) as u8 as i8).collect();
    let (mult, shift) = quantize_multiplier(0.03);
    let req = GemmRequest::new(m, k, n, w, x, QGemmParams::uniform(m, 0, mult, shift));
    let run = |label: &str, mode: ExecMode| {
        let report = match design {
            "vm" => VmDesign::paper().run(&req, mode).report,
            _ => SaDesign::paper().run(&req, mode).report,
        };
        println!("--- {design} {label} ---");
        println!(
            "total: {} ({} cycles) | compute {} cyc | weight-load {} cyc | dma in/out {}/{} cyc",
            report.total_time,
            report.total_cycles,
            report.compute_cycles,
            report.weight_load_cycles,
            report.dma_in_cycles,
            report.dma_out_cycles
        );
        println!(
            "bytes in/out: {}/{} | global buffer reads: {}",
            report.bytes_in, report.bytes_out, report.global_buffer_reads
        );
        for (name, s) in &report.modules {
            println!(
                "  {:<18} busy {:>12} util {:>5.1}% txns {:>6}",
                name,
                format!("{}", s.busy),
                s.utilization() * 100.0,
                s.transactions
            );
        }
    };
    run("simulation (SystemC loop)", ExecMode::Simulation);
    run("hardware-eval loop", ExecMode::HardwareEval);
    ExitCode::SUCCESS
}

fn cmd_sa_sizes() -> ExitCode {
    println!("SA size sweep (§IV-E3): GEMM 512x512x784 per size");
    let mut st = 3u64;
    let mut rnd = || {
        st ^= st << 13;
        st ^= st >> 7;
        st ^= st << 17;
        st
    };
    let (m, k, n) = (512, 512, 784);
    let w: Vec<i8> = (0..m * k).map(|_| (rnd() & 0xff) as u8 as i8).collect();
    let x: Vec<i8> = (0..k * n).map(|_| (rnd() & 0xff) as u8 as i8).collect();
    let (mult, shift) = quantize_multiplier(0.02);
    let req = GemmRequest::new(m, k, n, w, x, QGemmParams::uniform(m, 0, mult, shift));
    let mut prev: Option<u64> = None;
    for dim in [4usize, 8, 16] {
        let res = SaDesign::with_dim(dim).run(&req, ExecMode::HardwareEval);
        let rep = synth::synthesize_sa(&SaConfig::with_dim(dim));
        let speedup = prev
            .map(|p| format!("{:.2}x vs previous", p as f64 / res.report.total_cycles as f64))
            .unwrap_or_default();
        println!(
            "  {dim:>2}x{dim:<2}: {:>10} cycles, {:>3} DSP, util {:>4.0}%  {}",
            res.report.total_cycles,
            rep.resources.dsps,
            rep.utilization * 100.0,
            speedup
        );
        prev = Some(res.report.total_cycles);
    }
    ExitCode::SUCCESS
}

fn cmd_devtime() -> ExitCode {
    let p = devtime::DevTimeParams::paper_like();
    println!("development-time model (Eqs. 1-3), paper-like parameters:");
    println!(
        "  C_t={:.1} min  IS_t={:.1} min  S_t={:.1} min (S_t/C_t = {:.0}x)",
        p.compile.as_secs_f64() / 60.0,
        p.sim_inference.as_secs_f64() / 60.0,
        p.synthesis.as_secs_f64() / 60.0,
        p.synthesis.as_secs_f64() / p.compile.as_secs_f64()
    );
    for (n_sim, n_synth) in [(20u64, 2u64), (50, 3), (100, 5)] {
        let e1 = devtime::eq1_secda(&p, n_sim, n_synth);
        let e2 = devtime::eq2_synth_only(&p, n_sim, n_synth);
        let e3 = devtime::eq3_full_sim(&p, n_sim, n_synth, 100.0);
        println!(
            "  {n_sim} sims + {n_synth} synths: SECDA {:.1} h | synth-only {:.1} h ({:.1}x) | full-sys sim {:.1} h",
            e1.as_secs_f64() / 3600.0,
            e2.as_secs_f64() / 3600.0,
            e2.as_secs_f64() / e1.as_secs_f64(),
            e3.as_secs_f64() / 3600.0
        );
    }
    ExitCode::SUCCESS
}

fn cmd_dse(args: &[String]) -> ExitCode {
    use secda::dse::{
        design_space, run_campaign, validate_pareto_json, CampaignConfig, MemoCache,
        WorkloadProfile,
    };

    if args.first().map(String::as_str) == Some("--validate") {
        let Some(path) = args.get(1) else {
            eprintln!("usage: secda dse --validate <pareto.json>");
            return ExitCode::FAILURE;
        };
        let doc = match std::fs::read_to_string(path) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("cannot read {path}: {e}");
                return ExitCode::FAILURE;
            }
        };
        return match validate_pareto_json(&doc) {
            Ok(()) => {
                println!("{path}: OK");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("{path}: INVALID — {e}");
                ExitCode::FAILURE
            }
        };
    }

    let mut budget: Option<usize> = None;
    let mut threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut cache_path: Option<String> = None;
    let mut out_path: Option<String> = None;
    let mut assert_warm = false;
    fn value<'a>(args: &'a [String], i: usize, name: &str) -> Option<&'a String> {
        let v = args.get(i + 1);
        if v.is_none() {
            eprintln!("flag {name} needs a value");
        }
        v
    }
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--budget" => match value(args, i, "--budget").and_then(|s| s.parse().ok()) {
                Some(b) => {
                    budget = Some(b);
                    i += 2;
                }
                None => return ExitCode::FAILURE,
            },
            "--threads" => match value(args, i, "--threads").and_then(|s| s.parse().ok()) {
                Some(t) => {
                    threads = t;
                    i += 2;
                }
                None => return ExitCode::FAILURE,
            },
            "--cache" => match value(args, i, "--cache") {
                Some(p) => {
                    cache_path = Some(p.clone());
                    i += 2;
                }
                None => return ExitCode::FAILURE,
            },
            "--out" => match value(args, i, "--out") {
                Some(p) => {
                    out_path = Some(p.clone());
                    i += 2;
                }
                None => return ExitCode::FAILURE,
            },
            "--assert-warm" => {
                assert_warm = true;
                i += 1;
            }
            other => {
                eprintln!("unknown dse flag `{other}` (see `secda help`)");
                return ExitCode::FAILURE;
            }
        }
    }

    let cache = match cache_path.as_deref().map(std::fs::read_to_string) {
        Some(Ok(doc)) => match MemoCache::from_json(&doc) {
            Ok(c) => {
                println!("loaded {} cached simulations", c.len());
                c
            }
            Err(e) => {
                eprintln!("corrupt cache file: {e}");
                return ExitCode::FAILURE;
            }
        },
        // a missing cache file is a cold start, not an error
        Some(Err(_)) | None => MemoCache::new(),
    };

    let profiles = WorkloadProfile::all_models();
    let space = design_space();
    let cfg = CampaignConfig {
        threads,
        budget,
        ..CampaignConfig::default()
    };
    let start = std::time::Instant::now();
    let report = run_campaign(&cfg, &profiles, &space, &cache);
    let secs = start.elapsed().as_secs_f64();
    println!(
        "campaign: {} designs x {} profiles -> {} (design, shape) pairs",
        space.len(),
        profiles.len(),
        report.pairs
    );
    println!(
        "  fresh simulations {} | cache hits {} | {secs:.2}s wall on {threads} thread(s)",
        report.fresh_sims, report.cache_hits
    );
    for p in &report.profiles {
        println!("  {} frontier:", p.workload);
        for e in &p.frontier {
            println!(
                "    {:<8} latency {:>14} energy {:>10.4} J  util {:>3.0}%",
                e.design.key(),
                e.latency.to_string(),
                e.energy_j,
                e.utilization * 100.0
            );
        }
    }
    if let Some(p) = &cache_path {
        if let Err(e) = std::fs::write(p, cache.to_json()) {
            eprintln!("cannot write {p}: {e}");
            return ExitCode::FAILURE;
        }
    }
    if let Some(p) = &out_path {
        if let Err(e) = std::fs::write(p, report.pareto_json()) {
            eprintln!("cannot write {p}: {e}");
            return ExitCode::FAILURE;
        }
    }
    if assert_warm && report.fresh_sims > 0 {
        eprintln!(
            "--assert-warm: expected a fully warm cache, but {} fresh simulation(s) ran",
            report.fresh_sims
        );
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}

fn cmd_trace_validate(args: &[String]) -> ExitCode {
    use secda::obs::export::{
        validate_chrome_trace, validate_metrics_json, validate_timeseries_json,
        METRICS_SCHEMA, TIMESERIES_SCHEMA,
    };
    if args.is_empty() {
        eprintln!("usage: secda trace-validate <file...>");
        return ExitCode::FAILURE;
    }
    for path in args {
        let doc = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("cannot read {path}: {e}");
                return ExitCode::FAILURE;
            }
        };
        // schema sniff: exported documents carry their tag inline; a
        // Chrome trace has no tag, so it is the fallback
        let result = if doc.contains(METRICS_SCHEMA) {
            validate_metrics_json(&doc).map(|n| format!("{n} metrics"))
        } else if doc.contains(TIMESERIES_SCHEMA) {
            validate_timeseries_json(&doc).map(|(s, a)| format!("{s} series, {a} alerts"))
        } else {
            validate_chrome_trace(&doc).map(|c| {
                format!(
                    "{} events ({} slices, {} instants, {} tracks, {} flows, {} counters)",
                    c.events, c.slices, c.instants, c.tracks, c.flows, c.counters
                )
            })
        };
        match result {
            Ok(what) => println!("{path}: OK — {what}"),
            Err(e) => {
                eprintln!("{path}: INVALID — {e}");
                return ExitCode::FAILURE;
            }
        }
    }
    ExitCode::SUCCESS
}

fn cmd_report(args: &[String]) -> ExitCode {
    use secda::obs::export::{METRICS_SCHEMA, TIMESERIES_SCHEMA};
    let Some(path) = args.first() else {
        eprintln!(
            "usage: secda report <file> [--profile <trace.json>] [--top N] [--collapsed FILE]"
        );
        return ExitCode::FAILURE;
    };
    let mut profile_path: Option<String> = None;
    let mut top = 10usize;
    let mut collapsed_out: Option<String> = None;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--profile" => match args.get(i + 1) {
                Some(p) => {
                    profile_path = Some(p.clone());
                    i += 2;
                }
                None => {
                    eprintln!("flag --profile needs a value");
                    return ExitCode::FAILURE;
                }
            },
            "--top" => match args.get(i + 1).and_then(|s| s.parse().ok()) {
                Some(n) => {
                    top = n;
                    i += 2;
                }
                None => {
                    eprintln!("flag --top needs a number");
                    return ExitCode::FAILURE;
                }
            },
            "--collapsed" => match args.get(i + 1) {
                Some(p) => {
                    collapsed_out = Some(p.clone());
                    i += 2;
                }
                None => {
                    eprintln!("flag --collapsed needs a value");
                    return ExitCode::FAILURE;
                }
            },
            other => {
                eprintln!("unknown report flag `{other}` (see `secda help`)");
                return ExitCode::FAILURE;
            }
        }
    }
    let doc = match std::fs::read_to_string(path) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("cannot read {path}: {e}");
            return ExitCode::FAILURE;
        }
    };
    let summarized = if doc.contains(TIMESERIES_SCHEMA) {
        report_timeseries(path, &doc)
    } else if doc.contains(METRICS_SCHEMA) {
        report_metrics(path, &doc)
    } else {
        Err("not a secda metrics or time-series document (no schema tag)".into())
    };
    if let Err(e) = summarized {
        eprintln!("{path}: {e}");
        return ExitCode::FAILURE;
    }
    if let Some(tp) = profile_path {
        if let Err(e) = report_profile(&tp, top, collapsed_out.as_deref()) {
            eprintln!("{tp}: {e}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

/// Per-series summary + fired alerts of a `secda-timeseries-v1` file.
fn report_timeseries(path: &str, doc: &str) -> Result<(), String> {
    use secda::obs::export::validate_timeseries_json;
    use secda::obs::json::Json;
    let (ns, na) = validate_timeseries_json(doc)?;
    let j = Json::parse(doc)?;
    println!("{path}: time-series document ({ns} series, {na} alerts)");
    println!(
        "  {:<22} {:>7} {:>7} {:>7} {:>12} {:>12} {:>12}",
        "series", "kind", "samples", "dropped", "last", "min", "max"
    );
    for s in j.get("series").and_then(Json::as_arr).unwrap_or(&[]) {
        let name = s.get("name").and_then(Json::as_str).unwrap_or("?");
        let kind = s.get("kind").and_then(Json::as_str).unwrap_or("?");
        let dropped = s.get("dropped").and_then(Json::as_f64).unwrap_or(0.0);
        let mut last = 0.0f64;
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let points = s.get("points").and_then(Json::as_arr).unwrap_or(&[]);
        for p in points {
            if let Some(v) = p.as_arr().and_then(|a| a.get(1)).and_then(Json::as_f64) {
                last = v;
                min = min.min(v);
                max = max.max(v);
            }
        }
        if points.is_empty() {
            min = 0.0;
            max = 0.0;
        }
        println!(
            "  {:<22} {:>7} {:>7} {:>7} {:>12.3} {:>12.3} {:>12.3}",
            name,
            kind,
            points.len(),
            dropped,
            last,
            min,
            max
        );
    }
    let alerts = j.get("alerts").and_then(Json::as_arr).unwrap_or(&[]);
    if alerts.is_empty() {
        println!("  no alerts fired");
    } else {
        println!("  alerts:");
        for a in alerts {
            let num = |k: &str| a.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            println!(
                "    t={:.3} ms  {} on `{}`: value {:.3} vs threshold {:.3} (window {:.0} ms)",
                num("at_us") / 1e3,
                a.get("kind").and_then(Json::as_str).unwrap_or("?"),
                a.get("series").and_then(Json::as_str).unwrap_or("?"),
                num("value"),
                num("threshold"),
                num("window_us") / 1e3,
            );
        }
    }
    Ok(())
}

/// Counters / gauges / histograms of a `secda-metrics-v1` snapshot.
fn report_metrics(path: &str, doc: &str) -> Result<(), String> {
    use secda::obs::export::validate_metrics_json;
    use secda::obs::json::Json;
    let n = validate_metrics_json(doc)?;
    let j = Json::parse(doc)?;
    println!("{path}: metrics snapshot ({n} metrics)");
    for section in ["counters", "gauges"] {
        if let Some(obj) = j.get(section).and_then(Json::as_obj) {
            if !obj.is_empty() {
                println!("  {section}:");
                for (name, v) in obj {
                    println!("    {:<36} {}", name, v.as_f64().unwrap_or(0.0));
                }
            }
        }
    }
    if let Some(obj) = j.get("histograms").and_then(Json::as_obj) {
        if !obj.is_empty() {
            println!("  histograms:");
            for (name, h) in obj {
                let num = |k: &str| h.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                println!(
                    "    {:<36} count {} mean {:.1} p50 {} p99 {}",
                    name,
                    num("count"),
                    num("mean"),
                    num("p50"),
                    num("p99"),
                );
            }
        }
    }
    Ok(())
}

/// Fold a Chrome trace into the self-time attribution profile and
/// print the top-N frames (optionally writing collapsed stacks).
fn report_profile(trace_path: &str, top: usize, collapsed_out: Option<&str>) -> Result<(), String> {
    let trace = std::fs::read_to_string(trace_path)
        .map_err(|e| format!("cannot read {trace_path}: {e}"))?;
    let prof = secda::obs::AttributionProfile::from_chrome_trace(&trace)?;
    println!(
        "{trace_path}: profile — {} stacks, {:.3} ms total self time",
        prof.len(),
        prof.total_ns() as f64 / 1e6
    );
    let total = prof.total_ns().max(1) as f64;
    println!("  {:<44} {:>12} {:>7}", "frame", "self ms", "share");
    for (frame, ns) in prof.top(top) {
        println!(
            "  {:<44} {:>12.3} {:>6.1}%",
            frame,
            ns as f64 / 1e6,
            100.0 * ns as f64 / total
        );
    }
    if let Some(out) = collapsed_out {
        std::fs::write(out, prof.collapsed())
            .map_err(|e| format!("cannot write {out}: {e}"))?;
        println!("  collapsed stacks -> {out} (flamegraph.pl / speedscope ready)");
    }
    Ok(())
}

/// Row identity within a bench sweep: the non-metric keys that name
/// the configuration a row measured.
const BENCH_ID_KEYS: [&str; 5] = ["pool", "window_ms", "policy", "load", "boards"];
/// Metrics where bigger is better (regression = drop beyond tolerance).
const BENCH_HIGHER: [&str; 4] = ["req_s", "speedup", "slo_attainment", "util_mean"];
/// Metrics where smaller is better (regression = rise beyond tolerance).
const BENCH_LOWER: [&str; 2] = ["p50_us", "p99_us"];

fn bench_row_identity(row: &secda::obs::json::Json) -> String {
    use secda::obs::json::Json;
    let mut s = String::new();
    for k in BENCH_ID_KEYS {
        if let Some(v) = row.get(k) {
            if !s.is_empty() {
                s.push(' ');
            }
            match v.as_str() {
                Some(st) => s.push_str(&format!("{k}={st}")),
                None => s.push_str(&format!("{k}={}", v.as_f64().unwrap_or(f64::NAN))),
            }
        }
    }
    s
}

fn cmd_bench_diff(args: &[String]) -> ExitCode {
    use secda::obs::json::Json;
    let (Some(committed_path), Some(new_path)) = (args.first(), args.get(1)) else {
        eprintln!("usage: secda bench-diff <committed.json> <new.json> [--tol FRACTION]");
        return ExitCode::FAILURE;
    };
    let mut tol = 0.10f64;
    if let Some(flag) = args.get(2) {
        if flag != "--tol" {
            eprintln!("unknown bench-diff flag `{flag}` (see `secda help`)");
            return ExitCode::FAILURE;
        }
        match args.get(3).and_then(|s| s.parse().ok()) {
            Some(t) => tol = t,
            None => {
                eprintln!("flag --tol needs a fraction (e.g. 0.10)");
                return ExitCode::FAILURE;
            }
        }
    }
    let read = |p: &str| std::fs::read_to_string(p).map_err(|e| format!("cannot read {p}: {e}"));
    let (committed_doc, new_doc) = match (read(committed_path), read(new_path)) {
        (Ok(c), Ok(n)) => (c, n),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let parse = |p: &str, d: &str| -> Result<Json, String> {
        let j = Json::parse(d).map_err(|e| format!("{p}: {e}"))?;
        match j.get("schema").and_then(Json::as_str) {
            Some("secda-bench-serving-v1") => Ok(j),
            other => Err(format!("{p}: bad schema tag {other:?}")),
        }
    };
    let (cj, nj) = match (parse(committed_path, &committed_doc), parse(new_path, &new_doc)) {
        (Ok(c), Ok(n)) => (c, n),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    let committed_sweeps = cj.get("sweeps").and_then(Json::as_arr).unwrap_or(&[]);
    let new_sweeps = nj.get("sweeps").and_then(Json::as_arr).unwrap_or(&[]);
    if committed_sweeps.is_empty() {
        // bootstrap: nothing committed yet — surface the regenerated
        // snapshot so it can be committed, and pass
        println!(
            "{committed_path}: bootstrap placeholder (no sweeps committed); \
             commit the regenerated snapshot printed below as {committed_path}"
        );
        print!("{new_doc}");
        return ExitCode::SUCCESS;
    }
    let sweep_name = |s: &Json| s.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
    let mut compared = 0usize;
    let mut regressions = 0usize;
    for sweep in committed_sweeps {
        let name = sweep_name(sweep);
        let Some(new_sweep) = new_sweeps.iter().find(|s| sweep_name(s) == name) else {
            eprintln!(
                "sweep `{name}` missing from {new_path} — the bench matrix changed; \
                 refresh the committed snapshot"
            );
            return ExitCode::FAILURE;
        };
        let new_rows = new_sweep.get("rows").and_then(Json::as_arr).unwrap_or(&[]);
        for row in sweep.get("rows").and_then(Json::as_arr).unwrap_or(&[]) {
            let id = bench_row_identity(row);
            let Some(new_row) = new_rows.iter().find(|r| bench_row_identity(r) == id) else {
                eprintln!(
                    "{name}[{id}] missing from {new_path} — the bench matrix \
                     changed; refresh the committed snapshot"
                );
                return ExitCode::FAILURE;
            };
            let Some(fields) = row.as_obj() else { continue };
            for (key, v) in fields {
                if BENCH_ID_KEYS.contains(&key.as_str()) {
                    continue;
                }
                let Some(old) = v.as_f64() else { continue };
                let Some(new) = new_row.get(key).and_then(Json::as_f64) else {
                    eprintln!("{name}[{id}]: metric `{key}` missing from {new_path}");
                    return ExitCode::FAILURE;
                };
                let worse = if BENCH_HIGHER.contains(&key.as_str()) {
                    new < old * (1.0 - tol)
                } else if BENCH_LOWER.contains(&key.as_str()) {
                    new > old * (1.0 + tol)
                } else {
                    continue; // informational column (counts etc.)
                };
                compared += 1;
                if worse {
                    regressions += 1;
                    eprintln!(
                        "REGRESSION {name}[{id}]: {key} {old} -> {new} \
                         (beyond {:.0}% tolerance)",
                        tol * 100.0
                    );
                }
            }
        }
    }
    if regressions > 0 {
        eprintln!("bench-diff: {regressions} regression(s) across {compared} gated metrics");
        ExitCode::FAILURE
    } else {
        println!(
            "bench-diff: OK — {compared} gated metrics within ±{:.0}% of {committed_path}",
            tol * 100.0
        );
        ExitCode::SUCCESS
    }
}

#[cfg(not(feature = "pjrt"))]
fn cmd_runtime_check() -> ExitCode {
    eprintln!(
        "runtime-check needs the `pjrt` feature (PJRT execution of the AOT \
         artifacts); rebuild with `--features pjrt` after re-adding the \
         vendored xla crate (see Cargo.toml)"
    );
    ExitCode::FAILURE
}

#[cfg(feature = "pjrt")]
fn cmd_runtime_check() -> ExitCode {
    use secda::runtime::{default_dir, ArtifactRuntime};
    let dir = default_dir();
    if !ArtifactRuntime::available(&dir) {
        eprintln!("artifacts not found at {dir:?}; run `make artifacts`");
        return ExitCode::FAILURE;
    }
    let mut rt = match ArtifactRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime init failed: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    println!("loaded {} buckets from {dir:?}", rt.buckets.len());
    let mut st = 11u64;
    let mut rnd = || {
        st ^= st << 13;
        st ^= st >> 7;
        st ^= st << 17;
        st
    };
    for (m, k, n) in [(32, 27, 12544), (64, 32, 12544), (512, 4608, 49), (100, 100, 100)] {
        let w: Vec<i8> = (0..m * k).map(|_| (rnd() & 0xff) as u8 as i8).collect();
        let x: Vec<i8> = (0..k * n).map(|_| (rnd() & 0xff) as u8 as i8).collect();
        let (mult, shift) = quantize_multiplier(0.017);
        let p = QGemmParams::uniform(m, 42, mult, shift);
        match rt.qgemm(m, k, n, &w, &x, &p) {
            Ok(out) => {
                let cpu = secda::gemm::qgemm(&w, &x, m, k, n, &p, 1);
                let ok = out == cpu;
                println!("  GEMM ({m},{k},{n}): PJRT == CPU: {ok}");
                if !ok {
                    return ExitCode::FAILURE;
                }
            }
            Err(e) => {
                eprintln!("  GEMM ({m},{k},{n}) failed: {e:#}");
                return ExitCode::FAILURE;
            }
        }
    }
    println!(
        "runtime-check OK ({} executables compiled)",
        rt.compiled_count()
    );
    ExitCode::SUCCESS
}
