//! The heterogeneous accelerator pool.
//!
//! A [`WorkerPool`] owns N workers; each worker is one *instance* —
//! an SA or VM accelerator behind its own [`DriverHandle`] (its own
//! simulated fabric and driver state), or a CPU-only worker — plus a
//! bounded request queue (service order set by the configured
//! [`SchedulePolicy`]: FIFO by default, deadline-ordered under EDF)
//! and a `free_at` horizon in modeled time.
//!
//! Every worker executes requests through a [`PartitionedBackend`]:
//! the [`GemmBackend`] that realizes per-layer HW/SW partitioning
//! (route each GEMM to the instance's accelerator or to gemmlowp by
//! [`OffloadPlanner`] policy), charges AOT-executable compile costs
//! against the shared [`BucketBatcher`], upgrades weight residency for
//! warm same-model batches, and feeds every functional output through
//! the optional cross-check hook (the PJRT-vs-simulator bit-identity
//! assertion in `examples/edge_serving.rs`).
//!
//! Shared pool state (the executable-cache model, the cross-check
//! hook) lives behind `Arc<Mutex<_>>` so the same pool serves both
//! execution modes: the deterministic discrete-event scheduler
//! ([`super::scheduler`]) and the OS-thread worker loop
//! ([`super::threaded`]), where every worker — and everything it
//! closes over — must be [`Send`].

use std::collections::{HashSet, VecDeque};
use std::sync::{Arc, Mutex};

use crate::driver::DriverHandle;
use crate::framework::backend::{CpuBackend, GemmBackend, GemmTask, GemmTiming};
use crate::framework::graph::Graph;
use crate::obs::SpanRecorder;
use crate::perf::CpuModel;
use crate::sysc::SimTime;

use super::batch::BucketBatcher;
use super::policy::{Admission, CostModel, SchedulePolicy};
use super::scheduler::{OffloadPlanner, Route};
use super::{CoordinatorConfig, InferenceRequest};

/// Functional-output hook: called with every GEMM task and the bits
/// the pool produced for it. `edge_serving` installs the PJRT
/// cross-check here. Must not re-enter the coordinator, and must be
/// [`Send`]: under [`super::ExecMode::Threaded`] it is invoked from
/// worker threads (serialized by the hook's mutex).
pub type CrossCheckFn = dyn FnMut(&GemmTask<'_>, &[i8]) + Send;

/// The hook shared across all workers of a pool.
pub type SharedCrossCheck = Arc<Mutex<Option<Box<CrossCheckFn>>>>;

/// The shared executable-cache model, one per pool.
pub type SharedBatcher = Arc<Mutex<BucketBatcher>>;

/// One GEMM a worker executed while serving its current request —
/// kept only when tracing is enabled, and drained per request by the
/// scheduler ([`super::scheduler::execute_batch_on`]) to nest a
/// [`crate::obs::Stage::Gemm`] span (with its bridged simulator
/// events) inside the request's span.
#[derive(Debug, Clone)]
pub struct GemmLogEntry {
    /// The layer that issued the GEMM.
    pub layer: String,
    /// Where it ran (accelerator offload or CPU).
    pub route: Route,
    /// GEMM dimensions.
    pub m: usize,
    /// Reduction depth.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// Whether weights were resident on the fabric for this run.
    pub resident: bool,
    /// The GEMM's contribution to the layer wall time (including any
    /// AOT compile charge).
    pub total: SimTime,
    /// Fabric-active portion (zero on the CPU route).
    pub accel_active: SimTime,
    /// Kernel events bridged out of the accelerator simulator
    /// ([`crate::driver::DriverConfig::sim_trace`]), times relative to
    /// the simulator run start.
    pub sim_trace: Vec<crate::sysc::trace::TraceEntry>,
}

/// What kind of instance a worker wraps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerKind {
    /// Systolic-array accelerator instance (paper §IV-C).
    Sa,
    /// Vector-MAC accelerator instance (paper §IV-D).
    Vm,
    /// CPU-only worker (gemmlowp, no fabric).
    Cpu,
}

/// Per-layer partitioned execution backend of one worker.
pub struct PartitionedBackend {
    label: String,
    /// The accelerator instance; `None` for CPU-only workers.
    handle: Option<DriverHandle>,
    cpu: CpuBackend,
    /// The HW/SW partitioning policy driving this worker's routing.
    pub planner: OffloadPlanner,
    batcher: SharedBatcher,
    check: SharedCrossCheck,
    /// Set while serving the 2nd+ request of a same-model batch: the
    /// previous request already streamed this model's weights, so
    /// untiled layers are offloaded weights-resident.
    warm: bool,
    /// Layers actually offloaded while serving the current request.
    offloaded: HashSet<String>,
    /// Layers the *previous* request of this batch offloaded — only
    /// those have weights resident on the fabric, so only those earn
    /// the warm residency upgrade.
    prev_offloaded: HashSet<String>,
    /// The pool's shared span recorder (disabled by default).
    spans: Arc<SpanRecorder>,
    /// GEMMs executed for the current request (tracing only).
    gemm_log: Vec<GemmLogEntry>,
}

impl PartitionedBackend {
    /// A worker backend wrapping an accelerator instance.
    pub fn with_accel(
        handle: DriverHandle,
        threads: usize,
        sync_overhead: SimTime,
        batcher: SharedBatcher,
        check: SharedCrossCheck,
        spans: Arc<SpanRecorder>,
    ) -> Self {
        let cost = CostModel::new(threads, sync_overhead);
        Self::with_accel_cost(handle, cost, threads, batcher, check, spans)
    }

    /// A worker backend wrapping an accelerator instance, priced by an
    /// explicit cost model — the entry point for design-aware models
    /// when the pool runs a DSE-discovered configuration instead of
    /// the paper design.
    pub fn with_accel_cost(
        handle: DriverHandle,
        cost: CostModel,
        threads: usize,
        batcher: SharedBatcher,
        check: SharedCrossCheck,
        spans: Arc<SpanRecorder>,
    ) -> Self {
        PartitionedBackend {
            label: handle.label.clone(),
            handle: Some(handle),
            // serving tier: pool CPU paths run the SIMD-dispatched
            // kernels, and are timed accordingly (the cost model
            // prices them with the same model)
            cpu: CpuBackend::with_model(CpuModel::serving(), threads),
            planner: OffloadPlanner::with_cost(cost),
            batcher,
            check,
            warm: false,
            offloaded: HashSet::new(),
            prev_offloaded: HashSet::new(),
            spans,
            gemm_log: Vec::new(),
        }
    }

    /// A CPU-only worker backend (no accelerator to offload to).
    pub fn cpu_only(
        id: usize,
        threads: usize,
        batcher: SharedBatcher,
        check: SharedCrossCheck,
        spans: Arc<SpanRecorder>,
    ) -> Self {
        PartitionedBackend {
            label: format!("cpu{id}"),
            handle: None,
            cpu: CpuBackend::with_model(CpuModel::serving(), threads),
            // sync_overhead ZERO: there is nothing to offload to, the
            // planner only keeps its routing counters consistent
            planner: OffloadPlanner::new(threads, SimTime::ZERO),
            batcher,
            check,
            warm: false,
            offloaded: HashSet::new(),
            prev_offloaded: HashSet::new(),
            spans,
            gemm_log: Vec::new(),
        }
    }

    /// Mark the start of a request within a dispatch round. `warm`
    /// means the previous request in the batch was the same model, so
    /// the layers it offloaded still have weights on the fabric.
    pub fn set_warm(&mut self, warm: bool) {
        self.warm = warm;
        self.prev_offloaded = std::mem::take(&mut self.offloaded);
        if !warm {
            self.prev_offloaded.clear();
        }
    }

    /// The accelerator instance, when this worker has one.
    pub fn handle(&self) -> Option<&DriverHandle> {
        self.handle.as_ref()
    }

    /// The pool's shared span recorder.
    pub fn spans(&self) -> &Arc<SpanRecorder> {
        &self.spans
    }

    /// Drain the GEMMs logged for the current request (tracing only;
    /// empty when the recorder is disabled).
    pub fn take_gemm_log(&mut self) -> Vec<GemmLogEntry> {
        std::mem::take(&mut self.gemm_log)
    }
}

impl GemmBackend for PartitionedBackend {
    fn name(&self) -> &str {
        &self.label
    }

    fn run_gemm(&mut self, task: &GemmTask<'_>) -> (Vec<i8>, GemmTiming) {
        // residency upgrade only for layers the previous same-model
        // request actually offloaded — a layer it ran on the CPU never
        // put weights on the fabric
        let resident = task.weights_resident
            || (self.warm && self.prev_offloaded.contains(task.layer));
        let route = match self.handle {
            None => {
                // no accelerator on this worker: still count the
                // routing decision so worker_report stays truthful
                self.planner.cpu_routed += 1;
                Route::Cpu
            }
            Some(_) => self.planner.decide(task.m, task.k, task.n, resident),
        };
        let (out, timing) = match route {
            Route::Accel => {
                let warmed = GemmTask {
                    m: task.m,
                    k: task.k,
                    n: task.n,
                    weights: task.weights,
                    inputs: task.inputs,
                    params: task.params,
                    layer: task.layer,
                    weights_resident: resident,
                };
                let handle = self.handle.as_mut().expect("accel route without handle");
                let (out, mut timing) = handle.backend_mut().run_gemm(&warmed);
                self.planner
                    .observe(task.m, task.k, task.n, resident, timing.total);
                // executable-cache accounting: only a GEMM the driver
                // really offloaded runs through an AOT artifact (the
                // driver falls back internally when K exceeds the
                // design's buffers — no fabric time, no executable)
                if timing.accel_active > SimTime::ZERO {
                    self.offloaded.insert(task.layer.to_string());
                    let (_bucket, compile) = self
                        .batcher
                        .lock()
                        .expect("executable-cache lock")
                        .charge(task.m, task.k, task.n);
                    if compile > SimTime::ZERO {
                        timing.total += compile;
                        timing.cpu_time += compile;
                        timing.breakdown.push(("aot_compile", compile));
                    }
                }
                (out, timing)
            }
            Route::Cpu => self.cpu.run_gemm(task),
        };

        if self.spans.is_enabled() {
            let sim_trace = match route {
                Route::Accel => self
                    .handle
                    .as_mut()
                    .map(|h| h.backend_mut().take_sim_trace())
                    .unwrap_or_default(),
                Route::Cpu => Vec::new(),
            };
            self.gemm_log.push(GemmLogEntry {
                layer: task.layer.to_string(),
                route,
                m: task.m,
                k: task.k,
                n: task.n,
                resident,
                total: timing.total,
                accel_active: timing.accel_active,
                sim_trace,
            });
        }

        if let Some(cb) = self.check.lock().expect("cross-check lock").as_mut() {
            cb(task, &out);
        }
        (out, timing)
    }
}

/// One pool member: an instance, its queue, and its time horizon.
pub struct Worker {
    /// Stable pool index (also the `Completion::worker` stamp).
    pub id: usize,
    /// Which kind of instance this worker wraps.
    pub kind: WorkerKind,
    /// The worker's partitioned execution backend.
    pub backend: PartitionedBackend,
    /// Bounded admission queue, held in the configured policy's
    /// service order (FIFO by default, deadline-ordered under EDF) and
    /// drained by the scheduler.
    pub queue: VecDeque<InferenceRequest>,
    /// Modeled time at which this worker finishes its current work.
    pub free_at: SimTime,
    /// Cumulative modeled busy time (utilization numerator).
    pub busy: SimTime,
    /// Requests this worker completed.
    pub served: u64,
}

impl Worker {
    /// A fresh worker with an empty queue at modeled time zero.
    pub fn new(id: usize, kind: WorkerKind, backend: PartitionedBackend) -> Self {
        Worker {
            id,
            kind,
            backend,
            queue: VecDeque::new(),
            free_at: SimTime::ZERO,
            busy: SimTime::ZERO,
            served: 0,
        }
    }

    /// Human-readable instance label (e.g. `sa0`, `vm1`, `cpu2`).
    pub fn label(&self) -> &str {
        self.backend.name()
    }

    /// Busy share of a serving makespan.
    pub fn utilization(&self, makespan: SimTime) -> f64 {
        if makespan == SimTime::ZERO {
            return 0.0;
        }
        self.busy.as_secs_f64() / makespan.as_secs_f64()
    }
}

/// The worker set plus admission (queue-depth) policy.
pub struct WorkerPool {
    /// The pool members, in `[SA.., VM.., CPU..]` construction order.
    pub workers: Vec<Worker>,
    queue_depth: usize,
    /// Instances ever spawned on this pool (label counter: a worker
    /// added by a reconfiguration gets a fresh label, never a retired
    /// sibling's).
    spawned: usize,
}

impl WorkerPool {
    /// Build the pool a [`CoordinatorConfig`] describes.
    pub fn build(
        cfg: &CoordinatorConfig,
        batcher: SharedBatcher,
        check: SharedCrossCheck,
    ) -> Self {
        let threads = cfg.driver.threads;
        let sync = cfg.driver.sync_overhead;
        let mut workers: Vec<Worker> = Vec::new();
        let kinds = [
            (WorkerKind::Sa, cfg.sa_workers),
            (WorkerKind::Vm, cfg.vm_workers),
            (WorkerKind::Cpu, cfg.cpu_workers),
        ];
        for (kind, count) in kinds {
            for _ in 0..count {
                let id = workers.len();
                let backend = match kind {
                    WorkerKind::Sa => PartitionedBackend::with_accel_cost(
                        DriverHandle::sa_with(id, cfg.driver.clone(), cfg.sa_design.clone()),
                        CostModel::for_sa_design(&cfg.sa_design, threads, sync),
                        threads,
                        batcher.clone(),
                        check.clone(),
                        cfg.spans.clone(),
                    ),
                    WorkerKind::Vm => PartitionedBackend::with_accel_cost(
                        DriverHandle::vm_with(id, cfg.driver.clone(), cfg.vm_design.clone()),
                        CostModel::for_vm_design(&cfg.vm_design, threads, sync),
                        threads,
                        batcher.clone(),
                        check.clone(),
                        cfg.spans.clone(),
                    ),
                    WorkerKind::Cpu => PartitionedBackend::cpu_only(
                        id,
                        threads,
                        batcher.clone(),
                        check.clone(),
                        cfg.spans.clone(),
                    ),
                };
                workers.push(Worker::new(id, kind, backend));
            }
        }
        assert!(!workers.is_empty(), "coordinator pool must have at least one worker");
        let spawned = workers.len();
        WorkerPool {
            workers,
            queue_depth: cfg.queue_depth.max(1),
            spawned,
        }
    }

    /// Rebuild the pool to a target composition (the elastic layer's
    /// [`crate::coordinator::Coordinator::reconfigure`] core).
    ///
    /// Per kind, the first `target` workers are retained *with their
    /// state* — driver instances, cost-model observations, queues and
    /// horizons survive; surplus workers are retired and their queued
    /// requests returned for migration; missing instances are spawned
    /// fresh. A swapped-in accelerator becomes usable only at `now`
    /// plus its design's modeled bitstream-load time
    /// ([`crate::synth::reconfig_time`]); CPU workers need no fabric
    /// and start immediately. Pool order stays `[SA.., VM.., CPU..]`
    /// and worker ids are re-stamped to pool indices.
    pub fn apply_composition(
        &mut self,
        target: &crate::elastic::Composition,
        cfg: &CoordinatorConfig,
        batcher: SharedBatcher,
        check: SharedCrossCheck,
        now: SimTime,
    ) -> Vec<InferenceRequest> {
        assert!(target.total() >= 1, "coordinator pool must have at least one worker");
        let threads = cfg.driver.threads;
        let sync = cfg.driver.sync_overhead;
        let mut displaced = Vec::new();
        let mut sa: Vec<Worker> = Vec::new();
        let mut vm: Vec<Worker> = Vec::new();
        let mut cpu: Vec<Worker> = Vec::new();
        for mut w in std::mem::take(&mut self.workers) {
            let (kept, cap) = match w.kind {
                WorkerKind::Sa => (&mut sa, target.sa),
                WorkerKind::Vm => (&mut vm, target.vm),
                WorkerKind::Cpu => (&mut cpu, target.cpu),
            };
            if kept.len() < cap {
                kept.push(w);
            } else {
                displaced.extend(w.queue.drain(..));
            }
        }
        while sa.len() < target.sa {
            let label = self.spawned;
            self.spawned += 1;
            let backend = PartitionedBackend::with_accel_cost(
                DriverHandle::sa_with(label, cfg.driver.clone(), cfg.sa_design.clone()),
                CostModel::for_sa_design(&cfg.sa_design, threads, sync),
                threads,
                batcher.clone(),
                check.clone(),
                cfg.spans.clone(),
            );
            let mut w = Worker::new(0, WorkerKind::Sa, backend);
            w.free_at = now
                + crate::synth::reconfig_time(&crate::synth::sa_resources(&cfg.sa_design));
            sa.push(w);
        }
        while vm.len() < target.vm {
            let label = self.spawned;
            self.spawned += 1;
            let backend = PartitionedBackend::with_accel_cost(
                DriverHandle::vm_with(label, cfg.driver.clone(), cfg.vm_design.clone()),
                CostModel::for_vm_design(&cfg.vm_design, threads, sync),
                threads,
                batcher.clone(),
                check.clone(),
                cfg.spans.clone(),
            );
            let mut w = Worker::new(0, WorkerKind::Vm, backend);
            w.free_at = now
                + crate::synth::reconfig_time(&crate::synth::vm_resources(&cfg.vm_design));
            vm.push(w);
        }
        while cpu.len() < target.cpu {
            let label = self.spawned;
            self.spawned += 1;
            let backend = PartitionedBackend::cpu_only(
                label,
                threads,
                batcher.clone(),
                check.clone(),
                cfg.spans.clone(),
            );
            let mut w = Worker::new(0, WorkerKind::Cpu, backend);
            w.free_at = now;
            cpu.push(w);
        }
        self.workers = sa.into_iter().chain(vm).chain(cpu).collect();
        for (i, w) in self.workers.iter_mut().enumerate() {
            w.id = i;
        }
        displaced
    }

    /// Re-place a request displaced by a reconfiguration. Placement
    /// and queue order follow the policy, but admission does not run
    /// again — the request was already admitted once — and a full pool
    /// overflows onto the shortest queue rather than dropping it.
    pub fn migrate(&mut self, req: InferenceRequest, policy: &dyn SchedulePolicy) {
        let target = policy
            .place(&self.workers, self.queue_depth, &req)
            .unwrap_or_else(|| {
                self.workers
                    .iter()
                    .enumerate()
                    .min_by_key(|(i, w)| (w.queue.len(), *i))
                    .map(|(i, _)| i)
                    .expect("non-empty pool")
            });
        policy.enqueue(&mut self.workers[target].queue, req);
    }

    /// Requests currently queued across all workers.
    pub fn total_queued(&self) -> usize {
        self.workers.iter().map(|w| w.queue.len()).sum()
    }

    /// THE donor rule, in one place: the worker (other than `exclude`,
    /// the thief) whose non-empty queue head has the lowest
    /// (policy key, worker index) — oldest-first under FIFO,
    /// earliest-deadline-first under EDF. Shared by the actual steal
    /// ([`Self::take_batch`]) and the modeled drain's start-time
    /// estimate ([`Self::steal_candidate_arrival`]) so they can never
    /// disagree; the threaded path mirrors the same rule over its
    /// locked deques ([`super::threaded`]).
    fn steal_donor(&self, exclude: Option<usize>, policy: &dyn SchedulePolicy) -> Option<usize> {
        self.workers
            .iter()
            .enumerate()
            .filter(|(i, w)| Some(*i) != exclude && !w.queue.is_empty())
            .min_by_key(|(i, w)| {
                (policy.key(w.queue.front().expect("non-empty")), *i)
            })
            .map(|(i, _)| i)
    }

    /// Arrival stamp of the request an idle worker would steal right
    /// now (the [`Self::steal_donor`] queue head) — bounds the modeled
    /// drain's start-time estimate for idle workers. Under FIFO this
    /// is the oldest queued arrival in the pool.
    pub fn steal_candidate_arrival(&self, policy: &dyn SchedulePolicy) -> Option<SimTime> {
        self.steal_donor(None, policy)
            .and_then(|d| self.workers[d].queue.front().map(|r| r.arrival))
    }

    /// Worker with the earliest `free_at` (per-layer dispatch target).
    pub fn idlest(&self) -> usize {
        self.workers
            .iter()
            .enumerate()
            .min_by_key(|(i, w)| (w.free_at, *i))
            .map(|(i, _)| i)
            .expect("non-empty pool")
    }

    /// Admit a request, or hand it back when the policy rejects it.
    ///
    /// Placement, queue ordering and the admission verdict all belong
    /// to the [`SchedulePolicy`]: the default [`super::FifoPolicy`]
    /// places batch-affine (among workers with room, one whose queue
    /// tail already holds the same model wins if its queue is no more
    /// than one deeper than the shortest, so same-model requests land
    /// back to back and form batches; otherwise the shortest queue),
    /// appends FIFO and admits everything that fits. Admission-control
    /// policies additionally shed a request whose
    /// [`Self::predicted_completion`] exceeds its deadline.
    pub fn submit(
        &mut self,
        req: InferenceRequest,
        policy: &dyn SchedulePolicy,
        now: SimTime,
    ) -> Result<usize, SubmitRejection> {
        let Some(target) = policy.place(&self.workers, self.queue_depth, &req) else {
            return Err(SubmitRejection::Full(Box::new(req)));
        };
        if policy.admission_control() {
            let predicted = self.predicted_completion(target, &req, policy, now);
            if let Admission::Shed { predicted, deadline } = policy.admit(&req, predicted) {
                return Err(SubmitRejection::Shed {
                    request: Box::new(req),
                    predicted,
                    deadline,
                });
            }
        }
        policy.enqueue(&mut self.workers[target].queue, req);
        Ok(target)
    }

    /// Predicted completion time of `req` if placed on worker `widx`
    /// now: the worker's residual busy time, plus the modeled cost of
    /// every queued request the policy would serve before `req`
    /// (policy key less than or equal to its own), plus the request's
    /// own modeled cost — all from the worker's own [`CostModel`]
    /// (so observed simulator timings sharpen later predictions).
    ///
    /// [`CostModel`]: super::CostModel
    pub fn predicted_completion(
        &self,
        widx: usize,
        req: &InferenceRequest,
        policy: &dyn SchedulePolicy,
        now: SimTime,
    ) -> SimTime {
        let w = &self.workers[widx];
        let cost = &w.backend.planner.cost;
        // memoize per distinct model: request_cost walks the whole
        // graph, and a backlog usually holds few distinct Arc<Graph>s
        let mut memo: Vec<(*const Graph, SimTime)> = Vec::new();
        let mut cost_of = |model: &Arc<Graph>| -> SimTime {
            let p = Arc::as_ptr(model);
            match memo.iter().find(|(q, _)| *q == p) {
                Some(&(_, c)) => c,
                None => {
                    let c = cost.request_cost(model, w.kind);
                    memo.push((p, c));
                    c
                }
            }
        };
        let mut t = w.free_at.max(now);
        let key = (policy.key(req), req.id);
        for r in &w.queue {
            if (policy.key(r), r.id) <= key {
                t += cost_of(&r.model);
            }
        }
        t + cost_of(&req.model)
    }

    /// Move the most urgent queued request (the [`Self::steal_donor`]
    /// queue head) from some other worker to `widx`'s queue. Returns
    /// false when nothing is stealable.
    fn steal_into(&mut self, widx: usize, policy: &dyn SchedulePolicy) -> bool {
        match self.steal_donor(Some(widx), policy) {
            Some(d) => {
                let req = self.workers[d].queue.pop_front().expect("donor non-empty");
                self.workers[widx].queue.push_back(req);
                true
            }
            None => false,
        }
    }

    /// Pop the next batch for worker `widx`: the head of its queue
    /// plus every following request the policy lets join (same model
    /// within the batch window under every shipped policy), up to
    /// `max_batch`. Steals first when idle with an empty queue.
    /// Returns the batch and the number of steals.
    pub fn take_batch(
        &mut self,
        widx: usize,
        cfg: &CoordinatorConfig,
    ) -> (Vec<InferenceRequest>, u64) {
        let mut steals = 0;
        if self.workers[widx].queue.is_empty()
            && cfg.steal
            && self.steal_into(widx, cfg.policy.as_ref())
        {
            steals = 1;
        }
        let w = &mut self.workers[widx];
        let free_at = w.free_at;
        (pop_batch(&mut w.queue, cfg, free_at), steals)
    }
}

/// Why [`WorkerPool::submit`] refused a request. The request rides
/// along (boxed, keeping the error small) so the coordinator can hand
/// it back to the caller intact.
#[derive(Debug)]
pub enum SubmitRejection {
    /// Every queue the policy would place into is at `queue_depth`.
    Full(Box<InferenceRequest>),
    /// The admission policy predicts a deadline miss.
    Shed {
        /// The rejected request.
        request: Box<InferenceRequest>,
        /// Predicted completion that triggered the shed.
        predicted: SimTime,
        /// The deadline it would have missed.
        deadline: SimTime,
    },
}

/// Pop one batch from the front of a request queue: the head request
/// plus every following request the policy's
/// [`SchedulePolicy::may_join`] admits — under every shipped policy,
/// consecutive same-model requests, up to `max_batch`, whose arrivals
/// fall inside the batch window anchored at the earliest possible
/// round start (`free_at.max(head.arrival)`) of the worker that will
/// execute the batch.
///
/// This is THE batch-grouping rule, shared verbatim by the modeled
/// path ([`WorkerPool::take_batch`]) and the OS-thread path
/// ([`super::threaded`]) so batch composition policy cannot drift
/// between exec modes. Model comparison is by graph *instance*
/// ([`Arc::ptr_eq`]) — name equality is not model identity (weight
/// residency depends on it).
pub fn pop_batch(
    q: &mut VecDeque<InferenceRequest>,
    cfg: &CoordinatorConfig,
    free_at: SimTime,
) -> Vec<InferenceRequest> {
    let Some(first) = q.pop_front() else {
        return Vec::new();
    };
    let window_close = free_at.max(first.arrival) + cfg.batch_window;
    let model = first.model.clone();
    let mut batch = vec![first];
    while batch.len() < cfg.max_batch {
        let take = q
            .front()
            .is_some_and(|r| cfg.policy.may_join(r, &model, window_close));
        if !take {
            break;
        }
        batch.push(q.pop_front().expect("checked front"));
    }
    batch
}
