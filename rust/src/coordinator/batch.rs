//! Shape-bucket-aware batching.
//!
//! Every GEMM the pool offloads maps to one AOT shape bucket (the
//! PJRT executable identity, see [`crate::runtime`]). Compiling an
//! executable is expensive and happens once per bucket; grouping
//! same-model requests back to back therefore (a) hits the executable
//! cache instead of compiling, and (b) keeps layer weights resident on
//! the accelerator across the batch. [`BucketBatcher`] owns the
//! shared executable-cache model: the first offloaded GEMM that
//! touches a bucket is charged `compile_cost`, every later one is a
//! cache hit (CPU-routed GEMMs run gemmlowp and never touch an
//! executable, so they are not charged).
//!
//! Bucket identity comes from the artifact manifest when one is on
//! disk ([`crate::runtime::smallest_covering`] — the exact lookup the
//! PJRT runtime uses), and from the [`crate::runtime::bucket_shape`]
//! rounding grid otherwise, so batching decisions are identical with
//! and without artifacts.

use std::collections::HashMap;

use crate::runtime::{bucket_shape, smallest_covering, Bucket};
use crate::sysc::SimTime;

/// A bucket identity: the padded (m, k, n) the executable was
/// compiled for.
pub type BucketKey = (usize, usize, usize);

/// The pool-wide executable-reuse model.
pub struct BucketBatcher {
    /// Manifest bucket table; empty means "use the rounding grid".
    buckets: Vec<Bucket>,
    /// Modeled one-time compile latency per bucket.
    compile_cost: SimTime,
    /// Hit count per compiled bucket.
    compiled: HashMap<BucketKey, u64>,
    /// Number of compilations charged.
    pub compiles: u64,
    /// Number of warm executable hits.
    pub hits: u64,
    /// Total modeled compile time charged.
    pub compile_time: SimTime,
}

impl BucketBatcher {
    /// An executable-cache model over `buckets` (empty = rounding
    /// grid), charging `compile_cost` on each bucket's first use.
    pub fn new(buckets: Vec<Bucket>, compile_cost: SimTime) -> Self {
        BucketBatcher {
            buckets,
            compile_cost,
            compiled: HashMap::new(),
            compiles: 0,
            hits: 0,
            compile_time: SimTime::ZERO,
        }
    }

    /// The bucket a logical GEMM shape executes in.
    pub fn key(&self, m: usize, k: usize, n: usize) -> BucketKey {
        match smallest_covering(&self.buckets, m, k, n) {
            Some(b) => b.key(),
            None => bucket_shape(m, k, n),
        }
    }

    /// Account one GEMM against the executable cache: returns its
    /// bucket key and the compile latency to charge (zero on a warm
    /// hit).
    pub fn charge(&mut self, m: usize, k: usize, n: usize) -> (BucketKey, SimTime) {
        let key = self.key(m, k, n);
        match self.compiled.get_mut(&key) {
            Some(hits) => {
                *hits += 1;
                self.hits += 1;
                (key, SimTime::ZERO)
            }
            None => {
                self.compiled.insert(key, 0);
                self.compiles += 1;
                self.compile_time += self.compile_cost;
                (key, self.compile_cost)
            }
        }
    }

    /// Number of distinct buckets touched so far.
    pub fn distinct_buckets(&self) -> usize {
        self.compiled.len()
    }

    /// Diagnostic: group a list of GEMM shapes by bucket identity,
    /// preserving order inside each group. This is bucket-affinity
    /// introspection (and the spec the grouping tests pin) — the
    /// scheduler itself batches whole *requests* by graph identity,
    /// relying on same-model ⇒ same bucket sequence to realize this
    /// grouping implicitly.
    pub fn group(&self, shapes: &[(usize, usize, usize)]) -> HashMap<BucketKey, Vec<usize>> {
        let mut groups: HashMap<BucketKey, Vec<usize>> = HashMap::new();
        for (i, &(m, k, n)) in shapes.iter().enumerate() {
            groups.entry(self.key(m, k, n)).or_default().push(i);
        }
        groups
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Vec<Bucket> {
        vec![
            Bucket { m: 32, k: 32, n: 12544, file: "a".into() },
            Bucket { m: 64, k: 320, n: 12544, file: "b".into() },
            Bucket { m: 128, k: 1152, n: 3136, file: "c".into() },
        ]
    }

    #[test]
    fn first_touch_compiles_then_hits() {
        let mut b = BucketBatcher::new(Vec::new(), SimTime::ms(40));
        let (k1, c1) = b.charge(30, 27, 12500);
        assert_eq!(c1, SimTime::ms(40));
        // same bucket (after rounding) -> warm
        let (k2, c2) = b.charge(32, 20, 12544);
        assert_eq!(k1, k2);
        assert_eq!(c2, SimTime::ZERO);
        // different bucket -> compile again
        let (_k3, c3) = b.charge(64, 64, 64);
        assert_eq!(c3, SimTime::ms(40));
        assert_eq!(b.compiles, 2);
        assert_eq!(b.hits, 1);
        assert_eq!(b.compile_time, SimTime::ms(80));
        assert_eq!(b.distinct_buckets(), 2);
    }

    #[test]
    fn manifest_buckets_beat_grid_when_present() {
        let b = BucketBatcher::new(manifest(), SimTime::ZERO);
        // smallest covering manifest bucket, not the rounding grid
        assert_eq!(b.key(30, 27, 12500), (32, 32, 12544));
        assert_eq!(b.key(60, 300, 12000), (64, 320, 12544));
        // nothing covers it -> falls back to the grid
        assert_eq!(b.key(4096, 27, 12544), bucket_shape(4096, 27, 12544));
    }

    #[test]
    fn grouping_preserves_fifo_order_within_buckets() {
        let b = BucketBatcher::new(Vec::new(), SimTime::ZERO);
        let shapes = [
            (30, 27, 12500),  // bucket A
            (64, 64, 64),     // bucket B
            (32, 20, 12544),  // bucket A again
            (60, 60, 60),     // bucket B again
            (32, 32, 12544),  // bucket A again
        ];
        let groups = b.group(&shapes);
        assert_eq!(groups.len(), 2);
        let a = &groups[&b.key(30, 27, 12500)];
        let bb = &groups[&b.key(64, 64, 64)];
        assert_eq!(a, &vec![0, 2, 4]);
        assert_eq!(bb, &vec![1, 3]);
    }
}
