//! The pluggable scheduling-policy layer and its unified cost model.
//!
//! SECDA's methodology is iterating on hardware/software partitioning
//! decisions against a calibrated cost model (paper §IV-B); related
//! co-design work (Hao et al., 2019; Guo et al.'s FPGA survey) treats
//! scheduling and partitioning as *swappable strategies over a shared
//! cost model* rather than baked-in control flow. This module is that
//! seam for the serving layer: every scheduling decision the
//! coordinator makes — queue ordering, batch-window close,
//! worker-assignment preference, admit-or-shed — flows through one
//! [`SchedulePolicy`] object, and every latency prediction those
//! decisions need flows through one [`CostModel`].
//!
//! Three policies ship:
//!
//! * [`FifoPolicy`] (the default) — reproduces the coordinator's
//!   historical behavior **bit-for-bit** in both exec modes: FIFO
//!   queues, batch-affine placement, oldest-first stealing, admission
//!   bounded only by `queue_depth`.
//! * [`DeadlinePolicy`] — earliest-deadline-first: requests carry an
//!   optional SLO deadline ([`super::Coordinator::submit_with_slo`]);
//!   queues and the threaded injector order by deadline, and
//!   [`super::ServingMetrics`] reports `slo_attained` / `slo_missed`.
//! * [`AdmissionPolicy`] — EDF ordering plus predictive load shedding:
//!   a request is rejected at enqueue when its predicted completion
//!   (worker backlog cost plus its own modeled cost, both from the
//!   [`CostModel`]) already exceeds its deadline. Shed requests are
//!   counted separately from queue-full rejections
//!   (`shed_predicted` vs `rejected`).

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::Arc;

use crate::accel::components::{SaArrayModel, VmUnitModel};
use crate::accel::{SaConfig, VmConfig};
use crate::framework::graph::Graph;
use crate::framework::models::gemm_shapes;
use crate::gemm::mac_count;
use crate::perf::CpuModel;
use crate::sysc::{Clock, SimTime};

use super::pool::{Worker, WorkerKind};
use super::InferenceRequest;

/// The logical dimensions of one GEMM layer — the unit every cost
/// estimate is made for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmShape {
    /// Output channels (weight rows).
    pub m: usize,
    /// Reduction depth (kh·kw·cin for a convolution).
    pub k: usize,
    /// Output spatial positions (weight-stationary columns).
    pub n: usize,
}

impl GemmShape {
    /// Multiply-accumulate count of this GEMM.
    pub fn macs(&self) -> u64 {
        mac_count(self.m, self.k, self.n)
    }

    /// Bytes moved over DMA for one offload of this shape: inputs and
    /// outputs always stream; weights only when not already resident.
    pub fn dma_bytes(&self, weights_resident: bool) -> u64 {
        let io = (self.k * self.n + self.m * self.n) as u64;
        if weights_resident {
            io
        } else {
            io + (self.m * self.k) as u64
        }
    }
}

/// One modeled execution-cost estimate, split the way the driver
/// reports time: device-busy work vs fixed per-offload overhead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModeledCost {
    /// Device-busy time (CPU gemm time, or accelerator transfer +
    /// compute). For a measured estimate this is the observed total.
    pub busy: SimTime,
    /// Fixed per-offload synchronization overhead (zero on the CPU
    /// path and on measured estimates, whose totals already include
    /// it).
    pub overhead: SimTime,
    /// True when the estimate comes from an observed simulator run
    /// rather than the analytic prior.
    pub measured: bool,
}

impl ModeledCost {
    /// The full predicted latency: busy time plus overhead.
    pub fn total(&self) -> SimTime {
        self.busy + self.overhead
    }
}

/// Analytic DMA prior: one AXI HP port at ~400 MB/s effective.
const ACCEL_DMA_BYTES_PER_SEC: f64 = 400.0e6;

/// The unified per-layer HW/SW cost model.
///
/// Exactly one code path produces latency estimates for scheduling
/// decisions: the CPU side queries the calibrated [`CpuModel`]
/// (`perf::calib`), the accelerator side returns the best observed
/// simulator total for the shape when one exists ("measure once, then
/// pick the winner" — the simulation-in-the-loop partitioning SECDA
/// enables) and an analytic prior otherwise. The prior is *design
/// aware*: it runs the paper designs' own component cycle models
/// ([`SaArrayModel`], [`VmUnitModel`]) over the shape, so the SA's
/// column parallelism, the VM's serialized input fetch and the VM's
/// `max_k` local-buffer cliff (beyond which the driver falls back to
/// the CPU, §IV-E4) are all visible to scheduling *before* anything
/// has run — this is what lets the elastic planner
/// ([`crate::elastic`]) rank pool compositions against a traffic
/// profile. The [`super::OffloadPlanner`], the admission policies and
/// the backlog predictions all consult this struct — never `perf`
/// directly.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Serving-tier CPU model: what a pool CPU worker actually runs
    /// (the SIMD-dispatched kernels, [`CpuModel::serving`]).
    cpu: CpuModel,
    /// Paper-calibrated pynq model, used only for the VM `max_k`
    /// driver fallback — that path runs inside the driver at gemmlowp
    /// speed on the board CPU, not on the serving tier.
    fallback_cpu: CpuModel,
    threads: usize,
    sync_overhead: SimTime,
    /// Cycle model of the paper SA array (prior for [`WorkerKind::Sa`]).
    sa_array: SaArrayModel,
    /// Cycle model of one paper VM GEMM unit.
    vm_unit: VmUnitModel,
    /// GEMM units in the paper VM design (N is split across them).
    vm_units: usize,
    /// Largest K a paper-VM job holds natively; beyond it the driver
    /// falls back to CPU gemmlowp (§IV-E4).
    vm_max_k: usize,
    /// Fabric clock both paper designs run at.
    accel_clock: Clock,
    /// Best observed accelerator total per (shape, weights_resident).
    observed: HashMap<(GemmShape, bool), SimTime>,
}

impl CostModel {
    /// A cost model for a worker with `threads` CPU threads and the
    /// given per-offload synchronization overhead floor.
    pub fn new(threads: usize, sync_overhead: SimTime) -> Self {
        let sa = SaConfig::paper();
        let vm = VmConfig::paper();
        CostModel {
            cpu: CpuModel::serving(),
            fallback_cpu: CpuModel::pynq_a9(),
            threads,
            sync_overhead,
            sa_array: sa.array,
            vm_unit: vm.unit,
            vm_units: vm.units,
            vm_max_k: vm.max_k(),
            accel_clock: Clock::from_mhz(sa.clock_mhz),
            observed: HashMap::new(),
        }
    }

    /// A cost model whose SA prior runs an explicit (e.g. DSE-
    /// discovered) array design instead of the paper's 16x16.
    ///
    /// On [`SaConfig::paper`] this is identical to [`CostModel::new`],
    /// so paper-design pools price work bit-identically either way.
    pub fn for_sa_design(design: &SaConfig, threads: usize, sync_overhead: SimTime) -> Self {
        CostModel {
            sa_array: design.array,
            accel_clock: Clock::from_mhz(design.clock_mhz),
            ..Self::new(threads, sync_overhead)
        }
    }

    /// A cost model whose VM prior runs an explicit (e.g. DSE-
    /// discovered) vector-MAC design — unit count, unit cycle model
    /// and the `max_k` fallback cliff all follow the design.
    ///
    /// On [`VmConfig::paper`] this is identical to [`CostModel::new`].
    pub fn for_vm_design(design: &VmConfig, threads: usize, sync_overhead: SimTime) -> Self {
        CostModel {
            vm_unit: design.unit,
            vm_units: design.units,
            vm_max_k: design.max_k(),
            accel_clock: Clock::from_mhz(design.clock_mhz),
            ..Self::new(threads, sync_overhead)
        }
    }

    /// The per-offload synchronization overhead this model charges.
    pub fn sync_overhead(&self) -> SimTime {
        self.sync_overhead
    }

    /// Estimate one GEMM on a worker kind, weights not resident.
    pub fn estimate(&self, shape: GemmShape, kind: WorkerKind) -> ModeledCost {
        self.estimate_resident(shape, kind, false)
    }

    /// Estimate one GEMM on a worker kind with explicit weight
    /// residency.
    pub fn estimate_resident(
        &self,
        shape: GemmShape,
        kind: WorkerKind,
        weights_resident: bool,
    ) -> ModeledCost {
        match kind {
            WorkerKind::Cpu => ModeledCost {
                busy: self.cpu.gemm_time(shape.macs(), self.threads),
                overhead: SimTime::ZERO,
                measured: false,
            },
            WorkerKind::Vm if shape.k > self.vm_max_k => {
                // the design cannot hold the reduction natively: the
                // driver runs this GEMM on the CPU (§IV-E4), so a VM
                // worker serves it at gemmlowp speed (the pynq model,
                // not the serving tier) with no offload overhead
                ModeledCost {
                    busy: self.fallback_cpu.gemm_time(shape.macs(), self.threads),
                    overhead: SimTime::ZERO,
                    measured: false,
                }
            }
            WorkerKind::Sa | WorkerKind::Vm => {
                match self.observed.get(&(shape, weights_resident)) {
                    Some(&t) => ModeledCost {
                        busy: t,
                        overhead: SimTime::ZERO,
                        measured: true,
                    },
                    None => {
                        let cycles = self.accel_compute_cycles(shape, kind);
                        let compute = self.accel_clock.cycles(cycles);
                        let dma_secs = shape.dma_bytes(weights_resident) as f64
                            / ACCEL_DMA_BYTES_PER_SEC;
                        ModeledCost {
                            busy: compute + SimTime::ps((dma_secs * 1e12).round() as u64),
                            overhead: self.sync_overhead,
                            measured: false,
                        }
                    }
                }
            }
        }
    }

    /// Analytic compute-cycle prior for one GEMM on a paper design:
    /// the design's own component cycle model applied to the shape
    /// (edge-tile padding, the SA's fill/drain skew and the VM's
    /// serialized input fetch included). Replaced by the first
    /// observed simulator total.
    fn accel_compute_cycles(&self, shape: GemmShape, kind: WorkerKind) -> u64 {
        match kind {
            WorkerKind::Sa => {
                let stripes = shape.m.div_ceil(self.sa_array.dim) as u64;
                stripes * self.sa_array.stripe_compute_cycles(shape.k, shape.n)
            }
            WorkerKind::Vm => {
                // N splits across the units; the wall clock is the
                // per-unit share (all units run in parallel)
                let n_unit = shape.n.div_ceil(self.vm_units).max(1);
                let stripes = shape.m.div_ceil(self.vm_unit.tile_m) as u64;
                stripes * self.vm_unit.stripe_compute_cycles(shape.k, n_unit, 1.0)
            }
            WorkerKind::Cpu => 0,
        }
    }

    /// Record a measured accelerator total for a shape (keeps the
    /// best, so one outlier never poisons the policy).
    pub fn observe(&mut self, shape: GemmShape, weights_resident: bool, total: SimTime) {
        self.observed
            .entry((shape, weights_resident))
            .and_modify(|t| *t = (*t).min(total))
            .or_insert(total);
    }

    /// The best observed accelerator total for a shape, if any.
    pub fn observed(&self, shape: GemmShape, weights_resident: bool) -> Option<SimTime> {
        self.observed.get(&(shape, weights_resident)).copied()
    }

    /// Merge another model's observations into this one, keeping the
    /// best total per (shape, residency). The elastic controller uses
    /// this to pool what every worker of one design kind has measured
    /// into a per-design cost view that outlives the workers
    /// themselves (observations must survive a reconfiguration that
    /// retires the instance that made them).
    pub fn absorb(&mut self, other: &CostModel) {
        for (&key, &t) in &other.observed {
            self.observed
                .entry(key)
                .and_modify(|best| *best = (*best).min(t))
                .or_insert(t);
        }
    }

    /// Modeled per-request framework overhead (interpreter dispatch,
    /// (de)quantization), scaled by effective thread parallelism the
    /// way the interpreter scales it — the request-level constant
    /// every [`CostModel::request_cost`] estimate starts from.
    pub fn request_overhead(&self) -> SimTime {
        let ps = (self.cpu.framework_overhead.as_ps() as f64
            / self.cpu.eff_threads(self.threads))
        .round() as u64;
        SimTime::ps(ps)
    }

    /// Predicted service time of one whole inference request of model
    /// `g` on a worker of the given kind: the per-inference framework
    /// overhead (scaled by effective thread parallelism, mirroring the
    /// interpreter) plus, per conv GEMM layer, the cheaper of the CPU
    /// estimate and the accelerator estimate — the same better-of-two
    /// rule the offload planner applies per layer. Deliberately coarse
    /// (non-GEMM op time beyond the framework constant is ignored) but
    /// deterministic: admission verdicts must be reproducible.
    pub fn request_cost(&self, g: &Graph, kind: WorkerKind) -> SimTime {
        let mut t = self.request_overhead();
        for (m, k, n) in gemm_shapes(g) {
            let shape = GemmShape { m, k, n };
            let cpu = self.estimate(shape, WorkerKind::Cpu).total();
            let best = match kind {
                WorkerKind::Cpu => cpu,
                WorkerKind::Sa | WorkerKind::Vm => {
                    cpu.min(self.estimate(shape, kind).total())
                }
            };
            t += best;
        }
        t
    }
}

/// Verdict of a policy's admission check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Enqueue the request.
    Accept,
    /// Shed the request: it is predicted to miss its deadline.
    Shed {
        /// Predicted completion time that triggered the shed.
        predicted: SimTime,
        /// The deadline it would miss.
        deadline: SimTime,
    },
}

/// A scheduling policy: every decision point of the coordinator,
/// behind one object.
///
/// The default method bodies implement the historical FIFO behavior,
/// so [`FifoPolicy`] is the empty impl and stays bit-for-bit identical
/// to the pre-policy coordinator; other policies override exactly the
/// decisions they change. Policies are shared by reference across
/// worker threads under [`super::ExecMode::Threaded`], hence
/// `Send + Sync`, and must be cheap and deterministic — they run on
/// the submit path and inside drain loops.
pub trait SchedulePolicy: fmt::Debug + Send + Sync {
    /// Short policy name (reports, bench labels).
    fn name(&self) -> &'static str;

    /// Service-priority key of a request: lower keys are served first.
    /// Call sites append their own historical tie-breakers (request id
    /// or worker index) after this key, so a policy whose key degrades
    /// to `(arrival, arrival)` reproduces the FIFO orderings exactly.
    fn key(&self, req: &InferenceRequest) -> (SimTime, SimTime) {
        (req.arrival, req.arrival)
    }

    /// Insert an admitted request into a worker queue, maintaining
    /// this policy's service order. FIFO appends; EDF insertion-sorts
    /// by [`SchedulePolicy::key`].
    fn enqueue(&self, q: &mut VecDeque<InferenceRequest>, req: InferenceRequest) {
        q.push_back(req);
    }

    /// Pick the worker queue a request is placed on, or `None` when
    /// every eligible queue is at `queue_depth` (backpressure). The
    /// default is the historical batch-affine rule.
    fn place(
        &self,
        workers: &[Worker],
        queue_depth: usize,
        req: &InferenceRequest,
    ) -> Option<usize> {
        batch_affine_place(workers, queue_depth, req)
    }

    /// May `next` join a forming batch whose head runs `model`, given
    /// the close of the batch window? `max_batch` is enforced by the
    /// caller; this is the group-and-close verdict.
    fn may_join(
        &self,
        next: &InferenceRequest,
        model: &Arc<Graph>,
        window_close: SimTime,
    ) -> bool {
        Arc::ptr_eq(&next.model, model) && next.arrival <= window_close
    }

    /// Does this policy run an admission check? When false (the
    /// default) the pool skips computing the predicted completion
    /// entirely, so FIFO/EDF pay nothing on the submit path.
    fn admission_control(&self) -> bool {
        false
    }

    /// Admit-or-shed verdict given the predicted completion time of
    /// this request on its placement target.
    fn admit(&self, _req: &InferenceRequest, _predicted_done: SimTime) -> Admission {
        Admission::Accept
    }
}

/// The historical batch-affine placement rule (the
/// [`SchedulePolicy::place`] default): among workers with queue room,
/// one whose queue tail already holds the same model wins if its queue
/// is no more than one deeper than the shortest — so same-model
/// requests land back to back and form batches; otherwise the shortest
/// queue wins. Model identity is the graph `Arc` pointer, never the
/// name.
pub fn batch_affine_place(
    workers: &[Worker],
    queue_depth: usize,
    req: &InferenceRequest,
) -> Option<usize> {
    let min_len = workers
        .iter()
        .map(|w| w.queue.len())
        .filter(|&l| l < queue_depth)
        .min()?;
    let affine = workers.iter().position(|w| {
        w.queue.len() < queue_depth
            && w.queue.len() <= min_len + 1
            && w.queue
                .back()
                .is_some_and(|r| Arc::ptr_eq(&r.model, &req.model))
    });
    Some(affine.unwrap_or_else(|| {
        workers
            .iter()
            .position(|w| w.queue.len() == min_len)
            .expect("min_len worker exists")
    }))
}

/// Stable insertion-sort enqueue by `(policy key, request id)` — the
/// shared ordering core of the deadline-aware policies.
fn ordered_insert(
    policy: &dyn SchedulePolicy,
    q: &mut VecDeque<InferenceRequest>,
    req: InferenceRequest,
) {
    let key = (policy.key(&req), req.id);
    let pos = q
        .iter()
        .position(|r| (policy.key(r), r.id) > key)
        .unwrap_or(q.len());
    q.insert(pos, req);
}

/// EDF priority key: deadline first (requests without one sort last,
/// via [`SimTime::MAX`]), arrival second.
fn edf_key(req: &InferenceRequest) -> (SimTime, SimTime) {
    (req.deadline.unwrap_or(SimTime::MAX), req.arrival)
}

/// The default policy: strict FIFO queues, batch-affine placement,
/// oldest-first stealing, admission bounded only by `queue_depth` —
/// the coordinator's historical behavior, bit-for-bit, in both exec
/// modes.
#[derive(Debug, Clone, Copy, Default)]
pub struct FifoPolicy;

impl SchedulePolicy for FifoPolicy {
    fn name(&self) -> &'static str {
        "fifo"
    }
}

/// Earliest-deadline-first: queues (and the threaded injector) order
/// by the request's SLO deadline; requests without a deadline sort
/// last and keep FIFO order among themselves. Placement, batching and
/// admission stay at the FIFO defaults.
#[derive(Debug, Clone, Copy, Default)]
pub struct DeadlinePolicy;

impl SchedulePolicy for DeadlinePolicy {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn key(&self, req: &InferenceRequest) -> (SimTime, SimTime) {
        edf_key(req)
    }

    fn enqueue(&self, q: &mut VecDeque<InferenceRequest>, req: InferenceRequest) {
        ordered_insert(self, q, req);
    }
}

/// EDF ordering plus predictive admission control: a request whose
/// predicted completion — worker backlog cost plus its own modeled
/// cost, both from the [`CostModel`] — already exceeds its deadline is
/// shed at enqueue ([`super::SubmitError::ShedPredicted`], counted as
/// `shed_predicted`) instead of wasting queue space on a guaranteed
/// SLO miss. Requests without a deadline are always admitted.
#[derive(Debug, Clone, Copy, Default)]
pub struct AdmissionPolicy;

impl SchedulePolicy for AdmissionPolicy {
    fn name(&self) -> &'static str {
        "admission"
    }

    fn key(&self, req: &InferenceRequest) -> (SimTime, SimTime) {
        edf_key(req)
    }

    fn enqueue(&self, q: &mut VecDeque<InferenceRequest>, req: InferenceRequest) {
        ordered_insert(self, q, req);
    }

    fn admission_control(&self) -> bool {
        true
    }

    fn admit(&self, req: &InferenceRequest, predicted_done: SimTime) -> Admission {
        match req.deadline {
            Some(d) if predicted_done > d => Admission::Shed {
                predicted: predicted_done,
                deadline: d,
            },
            _ => Admission::Accept,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{convnet, image};
    use super::super::{Coordinator, CoordinatorConfig, SubmitError};
    use super::*;
    use crate::driver::DriverConfig;
    use crate::gemm;

    fn req(
        id: u64,
        model: &Arc<Graph>,
        arrival: SimTime,
        deadline: Option<SimTime>,
    ) -> InferenceRequest {
        InferenceRequest {
            id,
            model: model.clone(),
            input: image(model, 1 + id),
            arrival,
            deadline,
        }
    }

    #[test]
    fn cost_model_cpu_estimate_is_the_perf_model() {
        let cm = CostModel::new(2, SimTime::us(150));
        // pool CPU workers run the SIMD-dispatched kernels, so the
        // cost model prices them with the serving-tier CPU model
        let reference = CpuModel::serving();
        for (m, k, n) in [(8, 8, 8), (32, 27, 256), (128, 1152, 3136), (64, 320, 12544)] {
            let est = cm.estimate(GemmShape { m, k, n }, WorkerKind::Cpu);
            assert_eq!(est.busy, reference.gemm_time(gemm::mac_count(m, k, n), 2));
            assert_eq!(est.overhead, SimTime::ZERO);
            assert!(!est.measured);
        }
    }

    #[test]
    fn observed_measurement_overrides_the_prior() {
        let mut cm = CostModel::new(1, SimTime::us(150));
        let shape = GemmShape { m: 64, k: 64, n: 64 };
        let prior = cm.estimate(shape, WorkerKind::Sa);
        assert!(!prior.measured);
        assert_eq!(prior.overhead, SimTime::us(150));
        cm.observe(shape, false, SimTime::us(900));
        cm.observe(shape, false, SimTime::us(700)); // better run wins
        cm.observe(shape, false, SimTime::us(800)); // worse run ignored
        let m = cm.estimate(shape, WorkerKind::Sa);
        assert!(m.measured);
        assert_eq!(m.total(), SimTime::us(700));
        assert_eq!(cm.observed(shape, false), Some(SimTime::us(700)));
        // residency tracked separately: still the prior
        assert!(!cm.estimate_resident(shape, WorkerKind::Sa, true).measured);
    }

    #[test]
    fn prior_is_design_aware() {
        let cm = CostModel::new(1, SimTime::us(150));
        // A deep-K conv GEMM both designs can hold: the VM's
        // serialized input fetch (no prefetch overlap, §V-B) makes its
        // cycle prior slower than the SA's.
        let conv = GemmShape { m: 96, k: 2304, n: 196 };
        let sa = cm.estimate(conv, WorkerKind::Sa);
        let vm = cm.estimate(conv, WorkerKind::Vm);
        assert!(!sa.measured && !vm.measured);
        assert!(
            vm.total() > sa.total(),
            "vm prior {} not slower than sa prior {}",
            vm.total(),
            sa.total()
        );
        // K beyond the VM local buffers (§IV-E4): the prior must price
        // the driver's CPU fallback — gemmlowp speed, no offload
        // overhead — while the SA still prices it as (much cheaper)
        // fabric work.
        let deep = GemmShape { m: 96, k: 4608, n: 196 };
        let vm_deep = cm.estimate(deep, WorkerKind::Vm);
        assert_eq!(vm_deep.overhead, SimTime::ZERO);
        // priced at pynq gemmlowp speed (the fallback runs inside the
        // driver on the board CPU), not at the serving tier
        let pynq = CpuModel::pynq_a9();
        assert_eq!(vm_deep.busy, pynq.gemm_time(deep.macs(), 1));
        assert!(vm_deep.busy > cm.estimate(deep, WorkerKind::Cpu).busy);
        let sa_deep = cm.estimate(deep, WorkerKind::Sa);
        assert!(
            sa_deep.total().as_ps() * 4 < vm_deep.total().as_ps(),
            "sa {} not well under vm-fallback {}",
            sa_deep.total(),
            vm_deep.total()
        );
    }

    #[test]
    fn absorb_merges_best_observations() {
        let mut a = CostModel::new(1, SimTime::us(150));
        let mut b = CostModel::new(1, SimTime::us(150));
        let s = GemmShape { m: 32, k: 64, n: 32 };
        a.observe(s, false, SimTime::us(900));
        b.observe(s, false, SimTime::us(700));
        b.observe(s, true, SimTime::us(500));
        a.absorb(&b);
        assert_eq!(a.observed(s, false), Some(SimTime::us(700)));
        assert_eq!(a.observed(s, true), Some(SimTime::us(500)));
        // absorbing never makes an estimate worse
        a.observe(s, true, SimTime::us(400));
        a.absorb(&b);
        assert_eq!(a.observed(s, true), Some(SimTime::us(400)));
    }

    #[test]
    fn request_cost_is_deterministic_and_bounded_below_by_overhead() {
        let g = convnet("net", 24, 3);
        let cm = CostModel::new(1, DriverConfig::default().sync_overhead);
        let a = cm.request_cost(&g, WorkerKind::Sa);
        let b = cm.request_cost(&g, WorkerKind::Sa);
        assert_eq!(a, b, "request cost must be reproducible");
        // at least the framework overhead, at most the all-CPU route
        assert!(a >= SimTime::ms(50));
        assert!(a <= cm.request_cost(&g, WorkerKind::Cpu) + SimTime::ms(1));
    }

    #[test]
    fn fifo_key_and_enqueue_preserve_arrival_order() {
        let g = Arc::new(convnet("net", 16, 5));
        let p = FifoPolicy;
        let mut q = VecDeque::new();
        p.enqueue(&mut q, req(0, &g, SimTime::ms(5), None));
        p.enqueue(&mut q, req(1, &g, SimTime::ms(9), Some(SimTime::ms(1))));
        p.enqueue(&mut q, req(2, &g, SimTime::ms(12), None));
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2], "FIFO ignores deadlines entirely");
        assert_eq!(p.key(&q[1]), (SimTime::ms(9), SimTime::ms(9)));
    }

    #[test]
    fn edf_enqueue_orders_by_deadline_then_arrival() {
        let g = Arc::new(convnet("net", 16, 7));
        let p = DeadlinePolicy;
        let mut q = VecDeque::new();
        p.enqueue(&mut q, req(0, &g, SimTime::ms(0), Some(SimTime::ms(500))));
        p.enqueue(&mut q, req(1, &g, SimTime::ms(1), None)); // no SLO: last
        p.enqueue(&mut q, req(2, &g, SimTime::ms(2), Some(SimTime::ms(100))));
        p.enqueue(&mut q, req(3, &g, SimTime::ms(3), Some(SimTime::ms(100))));
        p.enqueue(&mut q, req(4, &g, SimTime::ms(4), Some(SimTime::ms(900))));
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        // 100ms deadlines first (arrival order among equals), then
        // 500ms, 900ms, and the deadline-less request at the end
        assert_eq!(ids, vec![2, 3, 0, 4, 1]);
    }

    #[test]
    fn edf_reorders_service_and_counts_slo_outcomes() {
        // Saturated 1-worker pool, distinct models (so batching cannot
        // merge them): the tight-deadline latecomer must run before the
        // relaxed early request.
        let g1 = Arc::new(convnet("net_a", 16, 11));
        let g2 = Arc::new(convnet("net_b", 24, 13));
        let run = || {
            let cfg = CoordinatorConfig::sa_pool(1)
                .with_policy(Arc::new(DeadlinePolicy));
            let mut coord = Coordinator::new(cfg);
            // relaxed SLO first, tight SLO second — both queued before
            // any drain, so EDF decides the order
            let relaxed = coord
                .submit_with_slo(g1.clone(), image(&g1, 21), SimTime::ms(100_000))
                .unwrap();
            let tight = coord
                .submit_with_slo(g2.clone(), image(&g2, 22), SimTime::ms(200))
                .unwrap();
            let done = coord.run_until_idle();
            (
                done.iter().map(|c| c.id).collect::<Vec<_>>(),
                relaxed,
                tight,
                coord.metrics().slo_attained + coord.metrics().slo_missed,
            )
        };
        let (order_a, relaxed, tight, judged) = run();
        assert_eq!(order_a.first(), Some(&tight), "EDF must serve the tight SLO first");
        assert_eq!(order_a.len(), 2);
        assert!(order_a.contains(&relaxed));
        assert_eq!(judged, 2, "every deadline request gets an SLO verdict");
        // modeled-mode EDF is deterministic: identical order on a rerun
        let (order_b, ..) = run();
        assert_eq!(order_a, order_b);
    }

    #[test]
    fn admission_sheds_exactly_the_predicted_misses() {
        // Saturated 1-worker pool (no drains between submits): the
        // predicted completion of the i-th accepted request is
        // (i+1) * request_cost, so deadlines pick exactly which
        // submissions shed — mirrored here with the same CostModel the
        // pool consults.
        let g = Arc::new(convnet("net", 16, 17));
        let cfg = CoordinatorConfig::sa_pool(1)
            .with_policy(Arc::new(AdmissionPolicy));
        let drv = cfg.driver.clone();
        let mut coord = Coordinator::new(cfg);
        let cost = CostModel::new(drv.threads, drv.sync_overhead)
            .request_cost(&g, WorkerKind::Sa);
        // deadlines in units of the per-request cost: 1.5c admits one
        // request (predicted c), 0.5c always sheds, 3.5c admits while
        // fewer than 3 cheaper-or-equal requests sit ahead, ...
        let slots = [3.5, 0.5, 1.5, 10.0, 0.9, 2.2];
        let mut expected_shed = Vec::new();
        let mut accepted_keys: Vec<SimTime> = Vec::new();
        let mut actual_shed = Vec::new();
        let mut accepted = Vec::new();
        for (i, mult) in slots.iter().enumerate() {
            let deadline = SimTime::ps((cost.as_ps() as f64 * mult) as u64);
            // mirror the pool's prediction: requests with an earlier
            // or equal deadline already queued run first
            let ahead = accepted_keys.iter().filter(|&&d| d <= deadline).count();
            let predicted = SimTime::ps(cost.as_ps() * (ahead as u64 + 1));
            if predicted > deadline {
                expected_shed.push(i);
            }
            match coord.submit_with_deadline(g.clone(), image(&g, 30 + i as u64), Some(deadline)) {
                Ok(id) => {
                    accepted_keys.push(deadline);
                    accepted.push(id);
                }
                Err(SubmitError::ShedPredicted { predicted: p, deadline: d, .. }) => {
                    assert_eq!(d, deadline);
                    assert!(p > d, "shed with predicted {p} <= deadline {d}");
                    actual_shed.push(i);
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert_eq!(actual_shed, expected_shed, "shed set diverged from the cost model");
        assert!(!actual_shed.is_empty(), "test must exercise shedding");
        assert!(!accepted.is_empty(), "test must admit something");
        assert_eq!(coord.metrics().shed_predicted, actual_shed.len() as u64);
        assert_eq!(coord.metrics().rejected, 0, "sheds are not backpressure");
        // everything admitted still completes
        let done = coord.run_until_idle();
        let mut got: Vec<u64> = done.iter().map(|c| c.id).collect();
        got.sort();
        assert_eq!(got, accepted);
    }

    #[test]
    fn admission_without_deadline_accepts() {
        let g = Arc::new(convnet("net", 16, 19));
        let cfg = CoordinatorConfig::sa_pool(1)
            .with_policy(Arc::new(AdmissionPolicy));
        let mut coord = Coordinator::new(cfg);
        for i in 0..4u64 {
            coord.submit(g.clone(), image(&g, 40 + i)).expect("no deadline, no shed");
        }
        assert_eq!(coord.run_until_idle().len(), 4);
        assert_eq!(coord.metrics().shed_predicted, 0);
    }
}
