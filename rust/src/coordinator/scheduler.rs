//! Dispatch: per-layer HW/SW partitioning and the batch-forming,
//! work-stealing request loop.
//!
//! Two decisions live here, mirroring the co-design split of the
//! paper (§IV-B) lifted to serving scale:
//!
//! * [`OffloadPlanner`] — *per layer*: offload a GEMM only when the
//!   accelerator is predicted to beat the CPU. Both sides of that
//!   comparison come from the worker's [`CostModel`] — the calibrated
//!   CPU estimate on one side, observed simulator totals on the other
//!   ("measure once, then pick the winner"): a layer whose CPU time
//!   cannot even cover the per-offload sync overhead stays on the CPU
//!   outright; otherwise the planner offloads once, records the
//!   simulator-measured total into the cost model, and from then on
//!   picks the measured winner per (shape, residency) — the
//!   simulation-in-the-loop partitioning SECDA's methodology enables.
//! * [`drain`] — *per request*: an event loop over modeled time. The
//!   worker that can start earliest takes the next dispatch round,
//!   forming a batch from the head of its queue (grouping and window
//!   rules from the [`super::SchedulePolicy`], up to `max_batch`); an
//!   idle worker with an empty queue steals from the sibling whose
//!   queue head has the lowest policy key (oldest-first under FIFO,
//!   earliest-deadline-first under EDF). Queue order itself is the
//!   policy's ([`super::SchedulePolicy::enqueue`]) and batches never
//!   reorder across a queue head, so under FIFO no request can starve.
//!
//! [`drain`] is the [`super::ExecMode::Modeled`] path: fully
//! deterministic, single-threaded, reproducible percentiles. Its
//! per-batch execution core ([`execute_batch_on`]) is shared with the
//! OS-thread path in [`super::threaded`], so both modes produce
//! bit-identical functional outputs per request.

use crate::framework::interpreter::{InferenceReport, Session};
use crate::obs::{Span, SpanRecorder, Stage};
use crate::sysc::SimTime;

use super::metrics::ServingMetrics;
use super::policy::{CostModel, GemmShape};
use super::pool::{GemmLogEntry, Worker, WorkerPool};
use super::{Completion, CoordinatorConfig, InferenceRequest};

/// Where one GEMM layer runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Offload to the worker's accelerator instance.
    Accel,
    /// Keep on the CPU (gemmlowp).
    Cpu,
}

/// The per-layer HW/SW partitioning policy of one worker.
///
/// A thin decision rule over the worker's [`CostModel`] — the *only*
/// source of latency estimates (there is exactly one cost path; a
/// regression test below pins the planner's CPU prediction to the
/// model's): the first time a (shape, residency) is seen it is
/// offloaded optimistically and the driver's modeled total — DMA,
/// compute, sync, everything — is recorded into the model; later
/// occurrences compare that observation against the CPU prediction.
pub struct OffloadPlanner {
    /// The unified cost model backing every decision (and the
    /// admission-control backlog predictions for this worker).
    pub cost: CostModel,
    /// Layers routed to the accelerator.
    pub offloads: u64,
    /// Layers kept on the CPU by policy.
    pub cpu_routed: u64,
}

impl OffloadPlanner {
    /// A planner for a worker with `threads` CPU threads and the given
    /// per-offload synchronization overhead floor.
    pub fn new(threads: usize, sync_overhead: SimTime) -> Self {
        Self::with_cost(CostModel::new(threads, sync_overhead))
    }

    /// A planner over an explicit cost model — how design-aware models
    /// ([`CostModel::for_sa_design`]/[`CostModel::for_vm_design`]),
    /// optionally pre-seeded from a DSE memo cache
    /// ([`crate::dse::MemoCache::seed_cost_model`]), reach a worker.
    pub fn with_cost(cost: CostModel) -> Self {
        OffloadPlanner {
            cost,
            offloads: 0,
            cpu_routed: 0,
        }
    }

    /// Predicted CPU (gemmlowp) time for a GEMM shape — the cost
    /// model's CPU estimate, verbatim.
    pub fn predicted_cpu(&self, m: usize, k: usize, n: usize) -> SimTime {
        self.cost
            .estimate(GemmShape { m, k, n }, super::pool::WorkerKind::Cpu)
            .total()
    }

    /// Choose where a GEMM layer runs.
    pub fn decide(&mut self, m: usize, k: usize, n: usize, resident: bool) -> Route {
        let shape = GemmShape { m, k, n };
        let cpu_t = self.predicted_cpu(m, k, n);
        let route = if cpu_t <= self.cost.sync_overhead() {
            // the offload round-trip alone costs more than the CPU run
            Route::Cpu
        } else {
            match self.cost.observed(shape, resident) {
                Some(accel_t) if accel_t >= cpu_t => Route::Cpu,
                _ => Route::Accel,
            }
        };
        match route {
            Route::Accel => self.offloads += 1,
            Route::Cpu => self.cpu_routed += 1,
        }
        route
    }

    /// Record a measured accelerator total for a shape (keeps the
    /// best, so one outlier never poisons the policy).
    pub fn observe(&mut self, m: usize, k: usize, n: usize, resident: bool, total: SimTime) {
        self.cost.observe(GemmShape { m, k, n }, resident, total);
    }
}

/// Execute one already-formed batch on one worker, advancing the
/// worker's modeled horizon (`free_at`), busy time and served count.
///
/// This is the execution core shared by both drain paths: the
/// deterministic discrete-event loop ([`drain`]) calls it from the
/// coordinator's thread; the OS-thread loop
/// ([`super::threaded::drain`]) calls it from each worker's own
/// thread, which is why it takes `&mut Worker` rather than the pool.
/// Within a batch the functional math runs eagerly on the host while
/// request timing advances in modeled PYNQ time; the 2nd+ request of
/// the batch runs warm (weights the previous same-model request
/// offloaded stay resident on the fabric).
pub fn execute_batch_on(
    w: &mut Worker,
    widx: usize,
    batch: Vec<InferenceRequest>,
    threads: usize,
) -> Vec<Completion> {
    let size = batch.len();
    let mut done = Vec::with_capacity(size);
    let mut t = w.free_at.max(batch[0].arrival);
    let mut warm = false;
    for req in batch {
        let started = t.max(req.arrival);
        w.backend.set_warm(warm);
        let (output, report) =
            Session::new(req.model.as_ref(), &mut w.backend, threads).run(&req.input);
        let finished = started + report.overall();
        if w.backend.spans().is_enabled() {
            let spans = w.backend.spans().clone();
            let gemms = w.backend.take_gemm_log();
            record_request_spans(
                &spans,
                widx,
                req.id,
                &req.model.name,
                size,
                req.arrival,
                started,
                finished,
                &report,
                gemms,
            );
        }
        done.push(Completion {
            id: req.id,
            model: req.model,
            worker: widx,
            arrival: req.arrival,
            started,
            finished,
            deadline: req.deadline,
            batch_size: size,
            output,
            report,
        });
        w.busy += finished.saturating_sub(started);
        w.served += 1;
        t = finished;
        warm = true;
    }
    w.backend.set_warm(false);
    w.free_at = t;
    done
}

/// Emit the per-request spans for one completed request: its queue
/// wait, its end-to-end execution, and one slice per layer — a
/// [`Stage::Gemm`] span (with bridged simulator instants) where the
/// worker logged a GEMM, a [`Stage::Op`] span otherwise. Layer slices
/// tile the request span: layer i starts where layer i-1 ended. The
/// GEMM sits at the tail of its layer's window (the CPU-side im2col
/// prep runs first), clamped inside it.
///
/// Only called when the recorder is enabled, from both drain paths.
#[allow(clippy::too_many_arguments)]
fn record_request_spans(
    spans: &SpanRecorder,
    widx: usize,
    id: u64,
    model: &str,
    batch_size: usize,
    arrival: SimTime,
    started: SimTime,
    finished: SimTime,
    report: &InferenceReport,
    gemms: Vec<GemmLogEntry>,
) {
    spans.record(|| {
        let mut s = Span::new(Stage::QueueWait, arrival, started);
        s.request_id = Some(id);
        s.worker = Some(widx);
        s
    });
    spans.record(|| {
        let mut s = Span::new(Stage::Request, started, finished);
        s.request_id = Some(id);
        s.worker = Some(widx);
        s.attrs.push(("model", model.to_string()));
        s.attrs.push(("batch_size", batch_size.to_string()));
        s
    });
    let mut lt = started;
    let mut gi = 0;
    for (lname, _, dt) in &report.layers {
        let end = lt + *dt;
        let mut layer_had_gemm = false;
        while gi < gemms.len() && gemms[gi].layer == *lname {
            let g = &gemms[gi];
            gi += 1;
            layer_had_gemm = true;
            let g_start = end.saturating_sub(g.total).max(lt);
            spans.record(|| {
                let mut s = Span::new(Stage::Gemm, g_start, end);
                s.request_id = Some(id);
                s.worker = Some(widx);
                s.attrs.push(("layer", g.layer.clone()));
                let route = match g.route {
                    Route::Accel => "accel",
                    Route::Cpu => "cpu",
                };
                s.attrs.push(("route", route.to_string()));
                s.attrs.push(("shape", format!("{}x{}x{}", g.m, g.k, g.n)));
                s.attrs.push(("resident", g.resident.to_string()));
                s.attrs.push(("accel_active", g.accel_active.to_string()));
                s
            });
            for e in &g.sim_trace {
                spans.record(|| {
                    let mut s = Span::instant(Stage::SimEvent, (g_start + e.time).min(end));
                    s.request_id = Some(id);
                    s.worker = Some(widx);
                    s.attrs.push(("label", format!("{}: {}", e.module, e.label)));
                    s
                });
            }
        }
        if !layer_had_gemm {
            spans.record(|| {
                let mut s = Span::new(Stage::Op, lt, end);
                s.request_id = Some(id);
                s.worker = Some(widx);
                s.attrs.push(("layer", lname.clone()));
                s
            });
        }
        lt = end;
    }
}

/// Run queued requests to completion, in modeled time — the
/// deterministic [`super::ExecMode::Modeled`] path.
///
/// Each iteration picks the worker with the earliest possible start
/// (its `free_at` vs the arrival of the next request it could run),
/// forms one batch and executes it. Within a batch the functional math
/// runs immediately on the host; completion times advance in modeled
/// PYNQ time, so a pool of N workers genuinely overlaps N requests.
pub fn drain(
    pool: &mut WorkerPool,
    cfg: &CoordinatorConfig,
    metrics: &mut ServingMetrics,
) -> Vec<Completion> {
    let mut done = Vec::new();
    while pool.total_queued() > 0 {
        // pick the worker that can start soonest; an idle worker's
        // start is bounded by the arrival of the request it would
        // actually steal (the lowest-policy-key queue head — equal to
        // the oldest arrival under FIFO)
        let steal_arrival = pool.steal_candidate_arrival(cfg.policy.as_ref());
        let mut best: Option<(SimTime, usize)> = None;
        for (i, w) in pool.workers.iter().enumerate() {
            let arrival = match w.queue.front() {
                Some(r) => Some(r.arrival),
                None if cfg.steal => steal_arrival,
                None => None,
            };
            if let Some(arr) = arrival {
                let start = w.free_at.max(arr);
                let better = match best {
                    None => true,
                    Some((s, _)) => start < s,
                };
                if better {
                    best = Some((start, i));
                }
            }
        }
        let Some((_, widx)) = best else { break };

        let (batch, stole) = pool.take_batch(widx, cfg);
        metrics.steals += stole;
        if batch.is_empty() {
            break; // defensive: no dispatchable work despite queue count
        }

        let w = &mut pool.workers[widx];
        let round_start = w.free_at.max(batch[0].arrival);
        metrics.record_batch(widx, &batch[0].model.name, batch.len(), round_start);
        let binfo = cfg
            .spans
            .is_enabled()
            .then(|| (batch[0].model.name.clone(), batch.len()));
        let completions = execute_batch_on(w, widx, batch, cfg.driver.threads);
        if let Some((model, batch_size)) = binfo {
            let w = &pool.workers[widx];
            let (end, label) = (w.free_at, w.label().to_string());
            cfg.spans.record(|| {
                let mut s = Span::new(Stage::Batch, round_start, end);
                s.worker = Some(widx);
                s.attrs.push(("worker", label));
                s.attrs.push(("model", model));
                s.attrs.push(("size", batch_size.to_string()));
                s
            });
        }
        for c in &completions {
            metrics.record_request(c.arrival, c.started, c.finished, c.deadline);
        }
        done.extend(completions);
    }
    done
}

#[cfg(test)]
mod tests {
    use super::super::pool::WorkerKind;
    use super::*;
    use crate::driver::DriverConfig;
    use crate::gemm;
    use crate::perf::CpuModel;

    #[test]
    fn tiny_layers_stay_on_cpu() {
        // 8x8x8 = 512 MACs: ~0.5us of CPU work vs a 150us offload
        // sync — the planner must not offload.
        let sync = DriverConfig::default().sync_overhead;
        let mut p = OffloadPlanner::new(1, sync);
        assert_eq!(p.decide(8, 8, 8, false), Route::Cpu);
        assert_eq!(p.cpu_routed, 1);
        assert_eq!(p.offloads, 0);
    }

    #[test]
    fn unknown_large_layers_explore_the_accelerator() {
        let mut p = OffloadPlanner::new(1, SimTime::us(150));
        // 256x256x256 = 16.7M MACs ≈ 16 ms on CPU
        assert_eq!(p.decide(256, 256, 256, false), Route::Accel);
        assert_eq!(p.offloads, 1);
    }

    #[test]
    fn observed_loss_flips_route_to_cpu() {
        let mut p = OffloadPlanner::new(1, SimTime::us(150));
        let (m, k, n) = (128, 128, 128);
        assert_eq!(p.decide(m, k, n, false), Route::Accel);
        // simulator reported the offload slower than the CPU estimate
        let cpu_t = p.predicted_cpu(m, k, n);
        p.observe(m, k, n, false, cpu_t + SimTime::ms(5));
        assert_eq!(p.decide(m, k, n, false), Route::Cpu);
        // ... and a later, better observation flips it back
        p.observe(m, k, n, false, SimTime::us(200));
        assert_eq!(p.decide(m, k, n, false), Route::Accel);
    }

    #[test]
    fn residency_tracked_separately() {
        let mut p = OffloadPlanner::new(1, SimTime::us(150));
        let (m, k, n) = (128, 512, 128);
        let cpu_t = p.predicted_cpu(m, k, n);
        // cold offloads lose (weight DMA dominates), warm ones win
        p.observe(m, k, n, false, cpu_t + SimTime::ms(1));
        p.observe(m, k, n, true, cpu_t.saturating_sub(SimTime::us(500)));
        assert_eq!(p.decide(m, k, n, false), Route::Cpu);
        assert_eq!(p.decide(m, k, n, true), Route::Accel);
    }

    #[test]
    fn planner_and_cost_model_share_one_cpu_path() {
        // Regression for the pre-policy duplication: the scheduler
        // must not re-derive CPU GEMM cost — its prediction, the cost
        // model's CPU estimate and perf::CpuModel must agree exactly
        // on every shape, at both thread counts.
        for threads in [1usize, 2] {
            let p = OffloadPlanner::new(threads, SimTime::us(150));
            // CPU workers run the SIMD kernels: the serving-tier model
            let reference = CpuModel::serving();
            for (m, k, n) in [
                (1, 1, 1),
                (8, 8, 8),
                (32, 27, 12544),
                (64, 320, 12544),
                (128, 1152, 3136),
                (512, 4608, 49),
            ] {
                let direct = reference.gemm_time(gemm::mac_count(m, k, n), threads);
                assert_eq!(p.predicted_cpu(m, k, n), direct, "({m},{k},{n}) x{threads}");
                assert_eq!(
                    p.cost
                        .estimate(GemmShape { m, k, n }, WorkerKind::Cpu)
                        .total(),
                    direct,
                    "cost model diverged on ({m},{k},{n}) x{threads}"
                );
            }
        }
    }
}
