//! OS-thread execution of the worker pool — the
//! [`super::ExecMode::Threaded`] drain.
//!
//! The modeled scheduler ([`super::scheduler::drain`]) interleaves all
//! workers on one host thread, so modeled throughput can never become
//! wall-clock throughput. This module runs each pool worker on its own
//! [`std::thread`] instead, with the classic work-stealing topology:
//!
//! * a **shared injector queue** — every queued request, in policy
//!   service order (arrival under FIFO, deadline under EDF), behind
//!   one [`Mutex`];
//! * **per-worker deques** — each worker refills its own deque with a
//!   FIFO chunk from the injector, executes the same-model run at its
//!   head, and leaves the tail stealable;
//! * **work stealing** — a worker that finds its deque and the
//!   injector empty steals the most urgent waiting run (lowest policy
//!   key: queued longest under FIFO, earliest deadline under EDF) from
//!   its siblings — the same fairness rule as the modeled path;
//! * **graceful shutdown** — a worker exits its loop only when the
//!   injector and every deque are empty; queues only ever shrink
//!   during a drain, so termination needs no signalling. The scope
//!   join then collects every thread before `drain` returns.
//!
//! Threads being per-drain is also what makes the elastic layer
//! ([`crate::elastic`]) exec-mode-agnostic: at the drain boundary all
//! worker threads have parked (joined), so a reconfiguration mutates
//! the pool with no thread alive to race it, and the swapped pool's
//! workers respawn as fresh threads at the next drain.
//!
//! Shared pool state is already thread-safe
//! ([`std::sync::Arc`]`<`[`Mutex`]`<_>>` for the executable-cache
//! model and the cross-check hook, atomics
//! for the steal counter), and each worker owns its accelerator
//! instance exclusively (`&mut Worker` moves into the thread), so the
//! per-instance driver state needs no locks at all.
//!
//! Functional outputs are bit-identical to [`super::ExecMode::Modeled`]
//! — both modes run the same [`super::scheduler::execute_batch_on`]
//! core and the math depends only on (model, input) — but batch
//! composition and worker assignment are scheduling-dependent, so
//! modeled percentiles are *not* pinned in this mode; wall-clock
//! throughput ([`super::ServingMetrics::wall_throughput_rps`]) is the
//! number this mode exists to produce.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::obs::{Span, Stage};
use crate::sysc::SimTime;

use super::metrics::ServingMetrics;
use super::pool::{pop_batch, WorkerPool};
use super::scheduler::execute_batch_on;
use super::{Completion, CoordinatorConfig, InferenceRequest};

/// The shared work-distribution state of one threaded drain.
struct Queues {
    /// All pending requests in arrival order (the injector).
    injector: Mutex<VecDeque<InferenceRequest>>,
    /// Per-worker deques; the tail of a refilled chunk is stealable.
    locals: Vec<Mutex<VecDeque<InferenceRequest>>>,
    /// Runs taken from a sibling's deque.
    steals: AtomicU64,
}

/// Get worker `widx`'s next batch: own deque first, then a chunk
/// refilled from the injector (which holds requests in policy service
/// order), then a steal from the sibling whose deque head has the
/// lowest policy key. `None` means the drain is complete for this
/// worker (no work anywhere it may take).
///
/// Batches form through [`pop_batch`] — the same grouping rule as the
/// modeled path, anchored at `free_at` (the calling worker's modeled
/// horizon: the caller executes whatever it pops, including steals).
fn next_batch(
    qs: &Queues,
    widx: usize,
    cfg: &CoordinatorConfig,
    free_at: SimTime,
) -> Option<Vec<InferenceRequest>> {
    // 1+2. own deque, refilling from the injector when it runs dry:
    //    move a FIFO chunk (two batches' worth) into the local deque;
    //    the head run executes now, the tail stays visible to
    //    stealing siblings. The move happens with BOTH locks held
    //    (own-local → injector nesting; the only nested acquisition
    //    in this module, so no ordering cycle) so in-flight work is
    //    never invisible to sibling scans — siblings block on one of
    //    the two locks and then see the requests.
    {
        let mut local = qs.locals[widx].lock().expect("own deque");
        if local.is_empty() {
            let mut inj = qs.injector.lock().expect("injector");
            let take = inj.len().min(cfg.max_batch.max(1).saturating_mul(2));
            local.extend(inj.drain(..take));
        }
        let batch = pop_batch(&mut local, cfg, free_at);
        if !batch.is_empty() {
            return Some(batch);
        }
    }
    // 3. steal: the sibling deque head with the lowest policy key
    //    first (oldest-waiting under FIFO, earliest deadline under
    //    EDF — the same fairness rule as the modeled path). Scan locks
    //    are taken one at a time; losing the race to a victim (its
    //    queue drained between the scan and the re-lock) re-scans
    //    instead of giving up — a worker exits only after a scan finds
    //    every deque empty. Each failed attempt implies some sibling
    //    made progress, so the retry loop terminates.
    if cfg.steal {
        loop {
            let mut best: Option<((SimTime, SimTime), u64, usize)> = None;
            for (i, l) in qs.locals.iter().enumerate() {
                if i == widx {
                    continue;
                }
                let q = l.lock().expect("sibling deque");
                if let Some(front) = q.front() {
                    let key = cfg.policy.key(front);
                    let better = match best {
                        None => true,
                        Some((bk, bid, _)) => (key, front.id) < (bk, bid),
                    };
                    if better {
                        best = Some((key, front.id, i));
                    }
                }
            }
            let Some((_, _, victim)) = best else { break };
            let mut q = qs.locals[victim].lock().expect("victim deque");
            let batch = pop_batch(&mut q, cfg, free_at);
            if !batch.is_empty() {
                qs.steals.fetch_add(1, Ordering::Relaxed);
                return Some(batch);
            }
        }
    }
    None
}

/// Run every queued request to completion on OS threads, one thread
/// per pool worker, and merge the per-thread results back into the
/// coordinator's metrics (including the host wall-clock span of the
/// drain). Completions are returned sorted by request id.
///
/// Requests queued on the per-worker admission queues are moved into
/// the shared injector in policy service order first — under
/// [`super::ExecMode::Threaded`] the submit-time placement is only an
/// admission bound; actual placement is decided by whichever thread
/// pulls the work.
pub fn drain(
    pool: &mut WorkerPool,
    cfg: &CoordinatorConfig,
    metrics: &mut ServingMetrics,
) -> Vec<Completion> {
    let mut pending: Vec<InferenceRequest> = Vec::new();
    for w in &mut pool.workers {
        pending.extend(w.queue.drain(..));
    }
    if pending.is_empty() {
        return Vec::new();
    }
    // policy service order (arrival under FIFO, deadline under EDF),
    // request id as the final tie-break
    pending.sort_by_key(|r| (cfg.policy.key(r), r.id));

    let n_workers = pool.workers.len();
    let qs = Queues {
        injector: Mutex::new(pending.into()),
        locals: (0..n_workers).map(|_| Mutex::new(VecDeque::new())).collect(),
        steals: AtomicU64::new(0),
    };
    let threads = cfg.driver.threads;

    // (completions, per-batch records) per worker thread
    type WorkerResult = (Vec<Completion>, Vec<(String, usize, SimTime)>);
    let t0 = Instant::now();
    let results: Vec<WorkerResult> = std::thread::scope(|s| {
        let handles: Vec<_> = pool
            .workers
            .iter_mut()
            .enumerate()
            .map(|(widx, w)| {
                let qs = &qs;
                std::thread::Builder::new()
                    .name(format!("secda-pool-{}", w.label()))
                    .spawn_scoped(s, move || {
                        let mut done: Vec<Completion> = Vec::new();
                        let mut batches = Vec::new();
                        let spans = w.backend.spans().clone();
                        while let Some(batch) = next_batch(qs, widx, cfg, w.free_at) {
                            let round_start = w.free_at.max(batch[0].arrival);
                            batches.push((batch[0].model.name.clone(), batch.len(), round_start));
                            // threaded batches get a second, host
                            // wall-clock timeline alongside modeled time
                            let wall0 = spans.is_enabled().then(|| spans.wall_now_ns());
                            done.extend(execute_batch_on(w, widx, batch, threads));
                            if let Some(w0) = wall0 {
                                let end = w.free_at;
                                let label = w.label().to_string();
                                let (model, size, _) =
                                    batches.last().expect("just pushed").clone();
                                spans.record(|| {
                                    let mut s = Span::new(Stage::Batch, round_start, end);
                                    s.worker = Some(widx);
                                    s.wall_ns = Some((w0, spans.wall_now_ns()));
                                    s.attrs.push(("worker", label));
                                    s.attrs.push(("model", model));
                                    s.attrs.push(("size", size.to_string()));
                                    s
                                });
                            }
                        }
                        (done, batches)
                    })
                    .expect("spawn coordinator worker thread")
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("coordinator worker thread panicked"))
            .collect()
    });
    let wall = t0.elapsed();
    metrics.steals += qs.steals.load(Ordering::Relaxed);

    let mut done = Vec::new();
    for (widx, (completions, batches)) in results.into_iter().enumerate() {
        for (model, size, start) in batches {
            metrics.record_batch(widx, &model, size, start);
        }
        for c in &completions {
            metrics.record_request(c.arrival, c.started, c.finished, c.deadline);
        }
        done.extend(completions);
    }
    metrics.record_wall(wall, done.len() as u64);
    done.sort_by_key(|c| c.id);
    done
}

/// Compile-time guarantee that everything a worker thread touches is
/// [`Send`] — the property the whole `ExecMode::Threaded` path rests
/// on (drivers, planners and queues move into worker threads).
#[allow(dead_code)]
fn assert_worker_state_is_send() {
    fn is_send<T: Send>() {}
    is_send::<super::pool::Worker>();
    is_send::<InferenceRequest>();
    is_send::<crate::driver::DriverHandle>();
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{convnet, cpu_reference, image};
    use super::super::{Coordinator, CoordinatorConfig, ExecMode, SubmitError};
    use crate::framework::graph::Graph;
    use crate::sysc::SimTime;
    use std::sync::Arc;

    /// Serve the same deterministic mixed-model stream in a given mode.
    fn serve_stream(
        mode: ExecMode,
        n: u64,
        g1: &Arc<Graph>,
        g2: &Arc<Graph>,
    ) -> Vec<super::Completion> {
        let cfg = CoordinatorConfig {
            exec_mode: mode,
            queue_depth: n as usize, // open loop: accept the full stream
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg);
        for i in 0..n {
            let g = if i % 3 == 0 { g2.clone() } else { g1.clone() };
            let input = image(&g, 500 + i);
            coord.submit(g, input).unwrap();
            coord.advance(SimTime::us(250));
        }
        let mut done = coord.run_until_idle();
        done.sort_by_key(|c| c.id);
        assert_eq!(coord.metrics().completed, n);
        done
    }

    #[test]
    fn threaded_matches_modeled_bit_exact() {
        let g1 = Arc::new(convnet("net_a", 16, 31));
        let g2 = Arc::new(convnet("net_b", 24, 37));
        let modeled = serve_stream(ExecMode::Modeled, 12, &g1, &g2);
        let threaded = serve_stream(ExecMode::Threaded, 12, &g1, &g2);
        assert_eq!(modeled.len(), threaded.len());
        for (m, t) in modeled.iter().zip(&threaded) {
            assert_eq!(m.id, t.id);
            assert_eq!(
                m.output.data, t.output.data,
                "request {} diverged between exec modes",
                m.id
            );
            assert_eq!(m.output.shape, t.output.shape);
        }
        // ... and both agree with the independent CPU reference
        for (i, t) in threaded.iter().enumerate() {
            let g = if (i as u64) % 3 == 0 { &g2 } else { &g1 };
            let input = image(g, 500 + i as u64);
            assert_eq!(t.output.data, cpu_reference(g, &input).data);
        }
    }

    #[test]
    fn threaded_completes_everything_under_concurrent_load() {
        let g = Arc::new(convnet("net", 32, 41));
        let mut cfg = CoordinatorConfig::sa_pool(4);
        cfg.exec_mode = ExecMode::Threaded;
        cfg.queue_depth = 64;
        cfg.max_batch = 4;
        let mut coord = Coordinator::new(cfg);
        let mut ids = Vec::new();
        for i in 0..32u64 {
            ids.push(coord.submit(g.clone(), image(&g, 900 + i)).unwrap());
        }
        let done = coord.run_until_idle();
        // no starvation, no duplication: every accepted request
        // completes exactly once, within the batch cap
        let mut got: Vec<u64> = done.iter().map(|c| c.id).collect();
        got.sort();
        assert_eq!(got, ids);
        for c in &done {
            assert!(c.batch_size >= 1 && c.batch_size <= 4);
            assert!(c.finished >= c.started);
            assert!(c.started >= c.arrival);
        }
        let m = coord.metrics();
        assert_eq!(m.completed, 32);
        assert!(m.wall_elapsed > std::time::Duration::ZERO);
        assert!(m.wall_throughput_rps() > 0.0);
        // every dispatch round respected the batch cap
        assert!(m.batches.iter().all(|b| b.size <= 4));
        let batched: usize = m.batches.iter().map(|b| b.size).sum();
        assert_eq!(batched, 32);
    }

    #[test]
    fn threaded_backpressure_still_enforced() {
        let g = Arc::new(convnet("net", 16, 43));
        let mut cfg = CoordinatorConfig::sa_pool(2);
        cfg.exec_mode = ExecMode::Threaded;
        cfg.queue_depth = 2;
        let mut coord = Coordinator::new(cfg);
        let mut accepted = Vec::new();
        let mut rejected = 0;
        for i in 0..10u64 {
            match coord.submit(g.clone(), image(&g, 70 + i)) {
                Ok(id) => accepted.push(id),
                Err(SubmitError::Backpressure { .. }) => rejected += 1,
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert_eq!(accepted.len(), 4);
        assert_eq!(rejected, 6);
        let done = coord.run_until_idle();
        let mut got: Vec<u64> = done.iter().map(|c| c.id).collect();
        got.sort();
        assert_eq!(got, accepted);
    }

    #[test]
    fn pop_batch_window_anchors_at_free_at_in_both_modes() {
        // regression: the threaded path must use the same batch-window
        // anchor as the modeled take_batch — free_at.max(head.arrival),
        // not head.arrival alone — or a backlogged worker loses warm
        // batching it would have had under ExecMode::Modeled.
        use super::super::pool::pop_batch;
        use std::collections::VecDeque;
        let g = Arc::new(convnet("net", 16, 53));
        let mut cfg = CoordinatorConfig::sa_pool(1);
        cfg.batch_window = SimTime::ms(5);
        cfg.max_batch = 8;
        let req = |id: u64, arrival| super::InferenceRequest {
            id,
            model: g.clone(),
            input: image(&g, 60 + id),
            arrival,
            deadline: None,
        };
        let q: VecDeque<_> = [req(0, SimTime::ZERO), req(1, SimTime::ms(7))]
            .into_iter()
            .collect();
        // worker busy until t=100ms: window closes at 105ms, both ride
        let batch = pop_batch(&mut q.clone(), &cfg, SimTime::ms(100));
        assert_eq!(batch.len(), 2);
        // idle worker: window closes at 5ms, the 7ms arrival waits
        let mut q2 = q;
        let batch = pop_batch(&mut q2, &cfg, SimTime::ZERO);
        assert_eq!(batch.len(), 1);
        assert_eq!(q2.len(), 1);
    }

    #[test]
    fn elastic_swap_works_on_os_threads() {
        use super::super::testutil::deep_convnet;
        use crate::elastic::{Composition, ElasticConfig};
        // Same scenario as the modeled-mode elastic test: a VM pool
        // under deep-K conv traffic must swap to the SA — here with
        // the pool on OS threads, where the swap lands between drains
        // (threads are per-drain, so nothing races the pool surgery).
        let g = Arc::new(deep_convnet("deep", 96, 59));
        let serve = |elastic: bool| {
            let cfg = CoordinatorConfig {
                sa_workers: 0,
                vm_workers: 1,
                cpu_workers: 0,
                queue_depth: 64,
                exec_mode: ExecMode::Threaded,
                elastic: elastic.then(|| ElasticConfig {
                    eval_interval: SimTime::ZERO,
                    window: SimTime::ms(60_000),
                    min_samples: 4,
                    hysteresis: SimTime::ms(1),
                    max_swaps: 1,
                    cpu_max: 0,
                    ..ElasticConfig::default()
                }),
                ..CoordinatorConfig::default()
            };
            let mut coord = Coordinator::new(cfg);
            let mut done = Vec::new();
            // 12-request first wave: enough observed win to clear the
            // reconfiguration cost now that the serving-tier CPU keeps
            // the VM pool's deep-K pain at a few ms per request
            for (wave, count) in [(0u64, 12u64), (1, 4)] {
                for i in 0..count {
                    coord
                        .submit(g.clone(), image(&g, 700 + wave * 20 + i))
                        .unwrap();
                }
                done.extend(coord.run_until_idle());
            }
            done.sort_by_key(|c| c.id);
            let swaps = coord.elastic_history().len();
            let comp = coord.composition();
            (done, swaps, comp)
        };
        let (elastic_done, swaps, comp) = serve(true);
        let (static_done, _, _) = serve(false);
        assert_eq!(swaps, 1, "threaded elastic pool never swapped");
        assert_eq!(comp, Composition::new(1, 0, 0));
        // reconfiguration is functionally invisible: bit-identical to
        // the static pool on every request
        assert_eq!(elastic_done.len(), static_done.len());
        for (e, s) in elastic_done.iter().zip(&static_done) {
            assert_eq!(e.id, s.id);
            assert_eq!(e.output.data, s.output.data, "request {} diverged", e.id);
        }
    }

    #[test]
    fn threaded_drain_is_repeatable_after_idle() {
        // a second wave through the same (already joined) coordinator
        // must work: threads are per-drain, not per-coordinator
        let g = Arc::new(convnet("net", 16, 47));
        let mut cfg = CoordinatorConfig::sa_pool(2);
        cfg.exec_mode = ExecMode::Threaded;
        let mut coord = Coordinator::new(cfg);
        for wave in 0..3u64 {
            for i in 0..4u64 {
                coord
                    .submit(g.clone(), image(&g, 1000 + wave * 10 + i))
                    .unwrap();
            }
            let done = coord.run_until_idle();
            assert_eq!(done.len(), 4);
        }
        assert_eq!(coord.metrics().completed, 12);
    }
}
