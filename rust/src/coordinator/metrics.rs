//! Serving telemetry: what the coordinator reports about itself.
//!
//! All times are modeled PYNQ-Z1 [`SimTime`] — the same time base as
//! the per-inference [`crate::framework::interpreter::InferenceReport`]
//! — so latency percentiles, worker utilization and throughput compose
//! with the Table II numbers rather than with host wall-clock.
//!
//! The one exception is [`ServingMetrics::wall_elapsed`]: under
//! [`crate::coordinator::ExecMode::Threaded`] each drain also records
//! its host wall-clock span, so
//! [`ServingMetrics::wall_throughput_rps`] reports *real* requests per
//! second next to the modeled figure.

use std::time::Duration;

use crate::obs::{Histogram, MetricsRegistry};
use crate::sysc::SimTime;

/// One dispatch round: a group of same-model requests executed back to
/// back on one worker.
#[derive(Debug, Clone)]
pub struct BatchRecord {
    /// Pool worker that ran the round.
    pub worker: usize,
    /// Model name the round grouped on (display only; grouping itself
    /// is by graph identity).
    pub model: String,
    /// Number of requests in the round.
    pub size: usize,
    /// Modeled start time of the round.
    pub start: SimTime,
}

/// Aggregate serving statistics over a coordinator's lifetime.
#[derive(Debug, Default)]
pub struct ServingMetrics {
    /// Requests accepted by `submit`.
    pub submitted: u64,
    /// Submissions rejected with backpressure (every queue full).
    pub rejected: u64,
    /// Submissions shed by admission control: predicted completion
    /// past the deadline. Distinct from `rejected` (queue-full) —
    /// sheds are a policy verdict, not a capacity wall.
    pub shed_predicted: u64,
    /// Requests that finished executing.
    pub completed: u64,
    /// Completed requests that carried an SLO deadline and finished on
    /// or before it.
    pub slo_attained: u64,
    /// Completed requests that carried an SLO deadline and finished
    /// after it.
    pub slo_missed: u64,
    /// Requests an idle worker stole from a sibling's queue (modeled
    /// mode counts stolen requests; threaded mode counts stolen runs).
    pub steals: u64,
    /// Pool reconfigurations applied ([`crate::elastic`] swaps plus
    /// any hand-driven [`crate::coordinator::Coordinator::reconfigure`]
    /// calls).
    pub reconfigs: u64,
    /// Total modeled bitstream-load time charged across those
    /// reconfigurations (swapped-in workers start late by their share
    /// of it).
    pub reconfig_time: SimTime,
    /// End-to-end modeled latency (finish - arrival) distribution.
    /// Streaming log-scale histogram: O(1) record, O(buckets)
    /// quantile, exact extremes — no samples retained.
    latencies: Histogram,
    /// Queue wait (start - arrival) distribution (same structure).
    waits: Histogram,
    /// Every dispatch round, in recording order.
    pub batches: Vec<BatchRecord>,
    /// Highest queue depth observed on any worker.
    pub queue_peak: usize,
    /// Host wall-clock spent inside threaded drains (zero in modeled
    /// mode, accumulated across drains in threaded mode).
    pub wall_elapsed: Duration,
    /// Requests completed inside threaded drains (the numerator of
    /// [`ServingMetrics::wall_throughput_rps`] — kept separate from
    /// `completed` so modeled-mode completions never inflate the
    /// wall-clock figure on a mixed-mode coordinator).
    pub wall_completed: u64,
    first_arrival: Option<SimTime>,
    last_finish: SimTime,
}

impl ServingMetrics {
    /// Count an accepted submission arriving at `arrival`.
    pub fn record_submit(&mut self, arrival: SimTime) {
        self.submitted += 1;
        self.first_arrival = Some(match self.first_arrival {
            Some(t) => t.min(arrival),
            None => arrival,
        });
    }

    /// Count a backpressure rejection.
    pub fn record_reject(&mut self) {
        self.rejected += 1;
    }

    /// Count an admission-control shed (predicted deadline miss).
    pub fn record_shed(&mut self) {
        self.shed_predicted += 1;
    }

    /// Count one applied pool reconfiguration and its modeled
    /// bitstream-load cost.
    pub fn record_reconfig(&mut self, cost: SimTime) {
        self.reconfigs += 1;
        self.reconfig_time += cost;
    }

    /// Record one dispatch round.
    pub fn record_batch(&mut self, worker: usize, model: &str, size: usize, start: SimTime) {
        self.batches.push(BatchRecord {
            worker,
            model: model.to_string(),
            size,
            start,
        });
    }

    /// Record one completed request's modeled timeline, judging its
    /// SLO when it carried a deadline.
    pub fn record_request(
        &mut self,
        arrival: SimTime,
        start: SimTime,
        finish: SimTime,
        deadline: Option<SimTime>,
    ) {
        self.completed += 1;
        self.latencies.record_time(finish.saturating_sub(arrival));
        self.waits.record_time(start.saturating_sub(arrival));
        self.last_finish = self.last_finish.max(finish);
        if let Some(d) = deadline {
            if finish <= d {
                self.slo_attained += 1;
            } else {
                self.slo_missed += 1;
            }
        }
    }

    /// Share of deadline-carrying completions that met their SLO.
    /// With zero judged completions: 1.0 when nothing was shed either
    /// (no SLO traffic at all — nothing was missed), but 0.0 when
    /// admission control shed deadline-carrying requests (a run that
    /// shed everything must not read as perfect attainment).
    pub fn slo_attainment(&self) -> f64 {
        let judged = self.slo_attained + self.slo_missed;
        if judged == 0 {
            return if self.shed_predicted > 0 { 0.0 } else { 1.0 };
        }
        self.slo_attained as f64 / judged as f64
    }

    /// Accumulate one threaded drain: its host wall-clock span and the
    /// number of requests it completed.
    pub fn record_wall(&mut self, elapsed: Duration, completed: u64) {
        self.wall_elapsed += elapsed;
        self.wall_completed += completed;
    }

    /// Serving makespan: first arrival to last completion.
    pub fn makespan(&self) -> SimTime {
        match self.first_arrival {
            Some(t0) => self.last_finish.saturating_sub(t0),
            None => SimTime::ZERO,
        }
    }

    /// Completed requests per modeled second.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.makespan().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / secs
    }

    /// Requests completed in threaded drains per *host wall-clock*
    /// second spent inside them — the real-concurrency figure
    /// [`crate::coordinator::ExecMode::Threaded`] exists to produce.
    /// Zero when no threaded drain has run; modeled-mode completions
    /// are excluded from the numerator.
    pub fn wall_throughput_rps(&self) -> f64 {
        let secs = self.wall_elapsed.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.wall_completed as f64 / secs
    }

    /// Latency percentile over completed requests (p in [0, 1]).
    /// Reads the streaming histogram: extremes are exact, interior
    /// percentiles are within ~1.6%. Nothing is cloned or sorted.
    pub fn latency_pct(&self, p: f64) -> SimTime {
        self.latencies.quantile_time(p)
    }

    /// Queue-wait percentile over completed requests (same histogram
    /// read as [`ServingMetrics::latency_pct`]).
    pub fn wait_pct(&self, p: f64) -> SimTime {
        self.waits.quantile_time(p)
    }

    /// Longest queue wait any completed request saw (exact).
    pub fn max_wait(&self) -> SimTime {
        SimTime::ps(self.waits.max())
    }

    /// The end-to-end latency histogram itself, for aggregation
    /// (the fleet tier merges per-board histograms via
    /// [`Histogram::merge`] to report fleet tail latency).
    pub fn latency_histogram(&self) -> &Histogram {
        &self.latencies
    }

    /// The queue-wait histogram itself (same aggregation seam as
    /// [`ServingMetrics::latency_histogram`]).
    pub fn wait_histogram(&self) -> &Histogram {
        &self.waits
    }

    /// Mean dispatch-round size over all recorded batches.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        let total: usize = self.batches.iter().map(|b| b.size).sum();
        total as f64 / self.batches.len() as f64
    }

    /// Track the peak per-worker queue depth seen at submit time.
    pub fn observe_queue_depth(&mut self, depth: usize) {
        self.queue_peak = self.queue_peak.max(depth);
    }

    /// One-paragraph serving summary. Reads the same streaming
    /// histograms as the `*_pct` accessors — one code path, no clones,
    /// however many percentiles the report wants.
    pub fn summary(&self) -> String {
        let wall = if self.wall_elapsed > Duration::ZERO {
            format!(
                "; wall {:.1} ms -> {:.1} req/s real",
                self.wall_elapsed.as_secs_f64() * 1e3,
                self.wall_throughput_rps()
            )
        } else {
            String::new()
        };
        let slo = if self.slo_attained + self.slo_missed + self.shed_predicted > 0 {
            format!(
                "; SLO {}/{} attained ({:.1}%), {} shed",
                self.slo_attained,
                self.slo_attained + self.slo_missed,
                100.0 * self.slo_attainment(),
                self.shed_predicted,
            )
        } else {
            String::new()
        };
        let reconfig = if self.reconfigs > 0 {
            format!(
                "; {} reconfigs ({} bitstream time)",
                self.reconfigs, self.reconfig_time
            )
        } else {
            String::new()
        };
        format!(
            "served {}/{} requests ({} rejected) in {} makespan -> {:.2} req/s; \
             latency p50 {} p99 {}; wait p50 {} max {}; \
             {} batches (mean size {:.2}), {} steals, queue peak {}{}{}{}",
            self.completed,
            self.submitted,
            self.rejected,
            self.makespan(),
            self.throughput_rps(),
            self.latency_pct(0.5),
            self.latency_pct(0.99),
            self.wait_pct(0.5),
            self.max_wait(),
            self.batches.len(),
            self.mean_batch_size(),
            self.steals,
            self.queue_peak,
            slo,
            reconfig,
            wall,
        )
    }

    /// A point-in-time [`MetricsRegistry`] snapshot of everything this
    /// struct tracks, for the flat-JSON exporter
    /// ([`crate::obs::export::metrics_json`]). Histogram values are in
    /// picoseconds (the [`SimTime`] base unit); derived rates and
    /// millisecond conversions are exported as gauges.
    pub fn registry(&self) -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        r.counter("submitted", self.submitted);
        r.counter("rejected", self.rejected);
        r.counter("shed_predicted", self.shed_predicted);
        r.counter("completed", self.completed);
        r.counter("slo_attained", self.slo_attained);
        r.counter("slo_missed", self.slo_missed);
        r.counter("steals", self.steals);
        r.counter("reconfigs", self.reconfigs);
        r.counter("batches", self.batches.len() as u64);
        r.counter("queue_peak", self.queue_peak as u64);
        r.counter("wall_completed", self.wall_completed);
        r.gauge("throughput_rps", self.throughput_rps());
        r.gauge("wall_throughput_rps", self.wall_throughput_rps());
        r.gauge("slo_attainment", self.slo_attainment());
        r.gauge("mean_batch_size", self.mean_batch_size());
        r.gauge("makespan_ms", self.makespan().as_ms_f64());
        r.gauge("reconfig_time_ms", self.reconfig_time.as_ms_f64());
        r.gauge("wall_elapsed_ms", self.wall_elapsed.as_secs_f64() * 1e3);
        r.histogram("latency_ps", &self.latencies);
        r.histogram("queue_wait_ps", &self.waits);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_throughput() {
        let mut m = ServingMetrics::default();
        for i in 0..10u64 {
            let arrival = SimTime::ms(i);
            m.record_submit(arrival);
            let start = arrival + SimTime::ms(1);
            let finish = start + SimTime::ms(10 + i);
            m.record_request(arrival, start, finish, None);
        }
        assert_eq!(m.completed, 10);
        // latencies are 11..=20 ms
        assert_eq!(m.latency_pct(0.0), SimTime::ms(11));
        assert_eq!(m.latency_pct(1.0), SimTime::ms(20));
        assert_eq!(m.wait_pct(0.5), SimTime::ms(1));
        // makespan = (arrival 9ms + 1ms wait + 19ms run) - 0
        assert_eq!(m.makespan(), SimTime::ms(29));
        let rps = m.throughput_rps();
        assert!((rps - 10.0 / 0.029).abs() < 1.0, "rps {rps}");
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = ServingMetrics::default();
        assert_eq!(m.makespan(), SimTime::ZERO);
        assert_eq!(m.throughput_rps(), 0.0);
        assert_eq!(m.latency_pct(0.99), SimTime::ZERO);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.wall_throughput_rps(), 0.0);
    }

    #[test]
    fn wall_throughput_accumulates_across_drains() {
        let mut m = ServingMetrics::default();
        m.record_submit(SimTime::ZERO);
        m.record_request(SimTime::ZERO, SimTime::ZERO, SimTime::ms(1), None);
        m.record_wall(Duration::from_millis(250), 1);
        m.record_wall(Duration::from_millis(250), 1);
        assert_eq!(m.wall_elapsed, Duration::from_millis(500));
        assert_eq!(m.wall_completed, 2);
        assert!((m.wall_throughput_rps() - 4.0).abs() < 1e-9);
        assert!(m.summary().contains("req/s real"), "{}", m.summary());
    }

    #[test]
    fn modeled_completions_never_inflate_wall_throughput() {
        // a coordinator that served 96 requests modeled, then 1
        // threaded, must report 1-request wall throughput — not 97
        let mut m = ServingMetrics::default();
        for i in 0..96u64 {
            m.record_request(SimTime::ms(i), SimTime::ms(i), SimTime::ms(i + 10), None);
        }
        m.record_request(SimTime::ms(100), SimTime::ms(100), SimTime::ms(110), None);
        m.record_wall(Duration::from_millis(5), 1);
        assert!((m.wall_throughput_rps() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn reconfig_accounting() {
        let mut m = ServingMetrics::default();
        assert!(!m.summary().contains("reconfigs"), "{}", m.summary());
        m.record_reconfig(SimTime::ms(30));
        m.record_reconfig(SimTime::ms(38));
        assert_eq!(m.reconfigs, 2);
        assert_eq!(m.reconfig_time, SimTime::ms(68));
        assert!(m.summary().contains("2 reconfigs"), "{}", m.summary());
    }

    #[test]
    fn slo_and_shed_accounting() {
        let mut m = ServingMetrics::default();
        // no deadlines anywhere -> vacuous full attainment, no line
        assert!((m.slo_attainment() - 1.0).abs() < 1e-12);
        assert!(!m.summary().contains("SLO"), "{}", m.summary());
        // attained: finished exactly at the deadline counts as met
        m.record_request(SimTime::ZERO, SimTime::ZERO, SimTime::ms(10), Some(SimTime::ms(10)));
        // missed by 1 ms
        m.record_request(SimTime::ZERO, SimTime::ms(1), SimTime::ms(21), Some(SimTime::ms(20)));
        // best-effort request: not judged
        m.record_request(SimTime::ZERO, SimTime::ms(2), SimTime::ms(99), None);
        m.record_shed();
        assert_eq!(m.slo_attained, 1);
        assert_eq!(m.slo_missed, 1);
        assert_eq!(m.shed_predicted, 1);
        assert_eq!(m.completed, 3);
        assert!((m.slo_attainment() - 0.5).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("SLO 1/2 attained"), "{s}");
        assert!(s.contains("1 shed"), "{s}");
    }

    #[test]
    fn registry_snapshot_covers_everything() {
        let mut m = ServingMetrics::default();
        m.record_submit(SimTime::ZERO);
        m.record_request(SimTime::ZERO, SimTime::ms(1), SimTime::ms(12), Some(SimTime::ms(20)));
        m.record_batch(0, "net", 1, SimTime::ms(1));
        m.record_reconfig(SimTime::ms(30));
        let r = m.registry();
        use crate::obs::MetricValue;
        assert_eq!(r.get("completed"), Some(&MetricValue::Counter(1)));
        assert_eq!(r.get("reconfigs"), Some(&MetricValue::Counter(1)));
        match r.get("latency_ps") {
            Some(MetricValue::Histogram(h)) => {
                assert_eq!(h.count, 1);
                assert_eq!(h.min, SimTime::ms(12).as_ps());
                assert_eq!(h.max, SimTime::ms(12).as_ps());
            }
            other => panic!("latency_ps missing: {other:?}"),
        }
        // and the export round-trips through the validator
        let json = crate::obs::export::metrics_json(&r);
        let n = crate::obs::export::validate_metrics_json(&json).expect("valid");
        assert_eq!(n, r.entries().len());
    }
}
