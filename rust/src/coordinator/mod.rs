//! The L3 serving coordinator — SECDA's co-design methodology lifted
//! from one accelerator to a serving system.
//!
//! The paper co-designs *one* accelerator with *one* driver for *one*
//! inference at a time. The ROADMAP north star is heavy multi-user
//! traffic, and related co-design work (Hao et al., FPGA/DNN
//! Co-Design) shows the same lesson at system scale: scheduling and
//! CPU/FPGA partitioning around the PE array — not the array alone —
//! determine end-to-end throughput. This module is that system layer:
//!
//! * [`pool`] — a heterogeneous pool of accelerator instances (N× SA,
//!   M× VM behind per-instance [`crate::driver::DriverHandle`]s, plus
//!   CPU-only workers), each with a bounded FIFO queue;
//! * [`batch`] — shape-bucket-aware batching: queued GEMM work is
//!   grouped by the AOT bucket it executes in (shared lookup with
//!   [`crate::runtime`]) so PJRT executable reuse and weight residency
//!   amortize across same-model requests;
//! * [`scheduler`] — per-layer HW/SW partitioning (offload a layer
//!   only when the accelerator is predicted to beat the calibrated
//!   [`crate::perf::CpuModel`]) and the work-stealing dispatch loop
//!   with queue-depth backpressure;
//! * [`policy`] — the pluggable scheduling-policy layer: every
//!   queue-ordering, batch-close, placement and admit-or-shed decision
//!   flows through a [`SchedulePolicy`] ([`FifoPolicy`] by default,
//!   [`DeadlinePolicy`] for EDF, [`AdmissionPolicy`] for predictive
//!   load shedding), backed by the unified [`CostModel`];
//! * [`metrics`] — latency percentiles, throughput, utilization,
//!   batching, stealing, SLO and reconfiguration telemetry, all in
//!   modeled PYNQ-Z1 time (plus host wall-clock for the threaded
//!   mode);
//! * [`crate::elastic`] — traffic-aware pool reconfiguration: when
//!   [`CoordinatorConfig::elastic`] is set, an elastic controller
//!   observes completed traffic and swaps the pool composition (which
//!   bitstream the fabric holds, how many CPU workers ride along)
//!   through [`Coordinator::reconfigure`] whenever the projected win
//!   amortizes the modeled bitstream-load cost;
//! * [`threaded`] — the OS-thread worker loop behind
//!   [`ExecMode::Threaded`]: a shared injector queue, per-worker
//!   deques, work stealing, and a clean scope-join shutdown.
//!
//! The coordinator executes in one of two [`ExecMode`]s:
//!
//! * [`ExecMode::Modeled`] (default) — a *discrete-event model*:
//!   functional math runs eagerly on the host while request timing
//!   advances in simulated [`SimTime`], so a pool of N instances
//!   genuinely overlaps N requests in modeled time and results stay
//!   bit-exact **and deterministic** — tests and modeled-time
//!   percentiles are pinned against this mode.
//! * [`ExecMode::Threaded`] — every pool worker runs on its own OS
//!   thread, so N instances overlap N requests in *host wall-clock*
//!   too. Functional outputs stay bit-identical to the modeled path
//!   (same execution core, math independent of scheduling); modeled
//!   percentiles become scheduling-dependent, and
//!   [`ServingMetrics::wall_throughput_rps`] reports real throughput.
//!
//! ```no_run
//! use std::sync::Arc;
//! use secda::coordinator::{Coordinator, CoordinatorConfig};
//! use secda::framework::{models, tensor::Tensor};
//!
//! let g = Arc::new(models::by_name("mobilenet_v1").unwrap());
//! let mut coord = Coordinator::new(CoordinatorConfig::default());
//! let input = Tensor::zeros(g.input_shape.clone(), g.input_qp);
//! let id = coord.submit(g.clone(), input).unwrap();
//! let done = coord.run_until_idle();
//! assert_eq!(done[0].id, id);
//! println!("{}", coord.metrics().summary());
//! ```

pub mod batch;
pub mod metrics;
pub mod policy;
pub mod pool;
pub mod scheduler;
pub mod threaded;

use std::fmt;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::driver::DriverConfig;
use crate::framework::backend::{GemmBackend, GemmTask, GemmTiming};
use crate::framework::graph::Graph;
use crate::framework::interpreter::InferenceReport;
use crate::framework::tensor::Tensor;
use crate::obs::{Span, SpanRecorder, Stage};
use crate::runtime::Bucket;
use crate::sysc::SimTime;

pub use batch::{BucketBatcher, BucketKey};
pub use metrics::{BatchRecord, ServingMetrics};
pub use policy::{
    Admission, AdmissionPolicy, CostModel, DeadlinePolicy, FifoPolicy, GemmShape, ModeledCost,
    SchedulePolicy,
};
pub use pool::{
    GemmLogEntry, PartitionedBackend, SharedCrossCheck, Worker, WorkerKind, WorkerPool,
};
pub use scheduler::{OffloadPlanner, Route};

/// How the coordinator executes its worker pool.
///
/// Not to be confused with [`crate::accel::ExecMode`], which selects
/// the *simulation fidelity* of one accelerator run (§III-C vs §III-D
/// of the paper); this enum selects how the *pool* advances: one
/// deterministic discrete-event loop, or one OS thread per worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Single-threaded discrete-event model (the default): fully
    /// deterministic, request timing advances only in modeled
    /// [`SimTime`]. Tests and pinned percentiles use this mode.
    #[default]
    Modeled,
    /// One OS thread per pool worker ([`threaded`]): batches execute
    /// concurrently on the host, wall-clock throughput becomes real,
    /// functional outputs stay bit-identical to [`ExecMode::Modeled`].
    Threaded,
}

impl fmt::Display for ExecMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ExecMode::Modeled => "modeled",
            ExecMode::Threaded => "threaded",
        })
    }
}

/// Pool- and queue-level serving policy (see also the per-instance
/// [`DriverConfig`] these workers are built from).
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Systolic-array instances in the pool.
    pub sa_workers: usize,
    /// Vector-MAC instances in the pool.
    pub vm_workers: usize,
    /// CPU-only (gemmlowp) workers.
    pub cpu_workers: usize,
    /// Per-instance driver configuration (threads, tiling, pipelining,
    /// sync overhead).
    pub driver: DriverConfig,
    /// The accelerator design SA workers instantiate (default: the
    /// paper's 16x16 array). DSE campaigns hand discovered frontier
    /// designs in here ([`crate::dse::ProfileReport::best_sa`]); the
    /// pool's driver handles, cost models and modeled reconfiguration
    /// times all follow it.
    pub sa_design: crate::accel::SaConfig,
    /// The accelerator design VM workers instantiate (default: the
    /// paper's 4-unit engine); see
    /// [`crate::dse::ProfileReport::best_vm`].
    pub vm_design: crate::accel::VmConfig,
    /// How long a dispatch round extends to group same-model requests
    /// into one batch.
    pub batch_window: SimTime,
    /// Batch size cap per dispatch round.
    pub max_batch: usize,
    /// Per-worker queue bound; submissions beyond it are rejected.
    pub queue_depth: usize,
    /// Idle workers steal the oldest queued request from siblings.
    pub steal: bool,
    /// Modeled one-time AOT executable compile cost per shape bucket.
    pub compile_cost: SimTime,
    /// How the pool executes: the deterministic discrete-event model
    /// ([`ExecMode::Modeled`], default) or one OS thread per worker
    /// ([`ExecMode::Threaded`]).
    pub exec_mode: ExecMode,
    /// The scheduling policy every queue-ordering, batching, placement
    /// and admission decision flows through. The default
    /// [`FifoPolicy`] reproduces the pre-policy coordinator
    /// bit-for-bit; see [`DeadlinePolicy`] and [`AdmissionPolicy`].
    pub policy: Arc<dyn SchedulePolicy>,
    /// Traffic-aware pool reconfiguration ([`crate::elastic`]): when
    /// set, the coordinator owns an elastic controller that observes
    /// completed traffic and, at drain boundaries, may swap the pool
    /// composition (which design the fabric holds, how many CPU
    /// workers ride along) through [`Coordinator::reconfigure`].
    /// `None` (the default) keeps the pool exactly as constructed.
    pub elastic: Option<crate::elastic::ElasticConfig>,
    /// The span recorder every lifecycle event flows through
    /// ([`crate::obs`]). Disabled by default — a disabled recorder
    /// costs one branch per call site and records nothing, and tracing
    /// is inert: enabling it never changes outputs or modeled timing
    /// (pinned by the `prop_tracing_is_inert` property test). Shared
    /// (`Arc`) because under [`ExecMode::Threaded`] every worker
    /// thread records into the same instance.
    pub spans: Arc<SpanRecorder>,
    /// Streaming telemetry ([`crate::obs::timeseries`]): when set, the
    /// coordinator samples ring-buffer time series at every drain
    /// boundary and evaluates SLO burn-rate / change-point alert rules
    /// over them. `None` (the default) records nothing. Telemetry is
    /// inert like tracing — sampling only reads already-computed state
    /// (pinned by `prop_telemetry_is_inert`); only the opt-in
    /// [`crate::obs::TelemetryConfig::feed_trend`] closes the loop
    /// into the elastic controller.
    pub telemetry: Option<crate::obs::TelemetryConfig>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            sa_workers: 2,
            vm_workers: 1,
            cpu_workers: 1,
            driver: DriverConfig::default(),
            sa_design: crate::accel::SaConfig::paper(),
            vm_design: crate::accel::VmConfig::paper(),
            batch_window: SimTime::ms(2),
            max_batch: 8,
            queue_depth: 16,
            steal: true,
            compile_cost: SimTime::ms(25),
            exec_mode: ExecMode::Modeled,
            policy: Arc::new(FifoPolicy),
            elastic: None,
            spans: Arc::new(SpanRecorder::disabled()),
            telemetry: None,
        }
    }
}

impl CoordinatorConfig {
    /// A homogeneous pool of `n` systolic-array instances (the
    /// pool-scaling baseline configuration).
    pub fn sa_pool(n: usize) -> Self {
        CoordinatorConfig {
            sa_workers: n,
            vm_workers: 0,
            cpu_workers: 0,
            ..Default::default()
        }
    }

    /// The same configuration with a different [`ExecMode`].
    pub fn with_exec_mode(mut self, mode: ExecMode) -> Self {
        self.exec_mode = mode;
        self
    }

    /// The same configuration with a different [`SchedulePolicy`].
    pub fn with_policy(mut self, policy: Arc<dyn SchedulePolicy>) -> Self {
        self.policy = policy;
        self
    }

    /// The same configuration with elastic pool reconfiguration
    /// enabled ([`crate::elastic::ElasticConfig`]).
    pub fn with_elastic(mut self, elastic: crate::elastic::ElasticConfig) -> Self {
        self.elastic = Some(elastic);
        self
    }

    /// The same configuration with span tracing enabled: an enabled
    /// recorder keeping up to `cap` spans, plus the driver-level
    /// simulator-trace bridge so each offloaded GEMM's kernel events
    /// nest inside its span. Tracing is inert — outputs and modeled
    /// timing are bit-identical to the untraced configuration.
    pub fn with_tracing(mut self, cap: usize) -> Self {
        self.spans = Arc::new(SpanRecorder::enabled(cap));
        self.driver.sim_trace = 32;
        self
    }

    /// The same configuration with streaming telemetry enabled
    /// ([`crate::obs::TelemetryConfig`]): drain-boundary time series,
    /// burn-rate and change-point alerting, and — when the config opts
    /// into `feed_trend` — the predictive trend signal into the
    /// elastic controller.
    pub fn with_telemetry(mut self, telemetry: crate::obs::TelemetryConfig) -> Self {
        self.telemetry = Some(telemetry);
        self
    }
}

/// One queued inference request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Coordinator-assigned request id (monotonic per coordinator).
    pub id: u64,
    /// The model to run; graph *identity* (the `Arc` pointer) is the
    /// batching key, not the model name.
    pub model: Arc<Graph>,
    /// The input tensor (must match the model's input shape).
    pub input: Tensor,
    /// Modeled arrival time (the coordinator's clock at submit).
    pub arrival: SimTime,
    /// Optional SLO deadline in absolute modeled time. `None` means
    /// best-effort: [`FifoPolicy`] ignores deadlines entirely;
    /// [`DeadlinePolicy`] serves earlier deadlines first (deadline-less
    /// requests last); [`AdmissionPolicy`] additionally sheds requests
    /// predicted to miss.
    pub deadline: Option<SimTime>,
}

/// One finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// The request id this completion answers.
    pub id: u64,
    /// The model the request ran (graph identity; the elastic
    /// estimator folds its GEMM shapes into the traffic profile).
    pub model: Arc<Graph>,
    /// Pool worker that served it.
    pub worker: usize,
    /// Modeled arrival time (copied from the request).
    pub arrival: SimTime,
    /// Modeled execution start (after queueing and batching).
    pub started: SimTime,
    /// Modeled completion time.
    pub finished: SimTime,
    /// The request's SLO deadline, if it carried one (compare against
    /// `finished` for attainment; [`ServingMetrics`] counts both).
    pub deadline: Option<SimTime>,
    /// Size of the dispatch round this request rode in.
    pub batch_size: usize,
    /// The inference output tensor.
    pub output: Tensor,
    /// Per-layer timing/energy report of this inference.
    pub report: InferenceReport,
}

impl Completion {
    /// End-to-end modeled latency: finish minus arrival.
    pub fn latency(&self) -> SimTime {
        self.finished.saturating_sub(self.arrival)
    }
}

/// Admission failure. The rejected request rides along so a caller
/// can drain/fix and retry without cloning inputs defensively.
#[derive(Debug)]
pub enum SubmitError {
    /// Every worker queue is at `queue_depth`.
    Backpressure {
        /// Total requests queued across the pool at rejection time.
        queued: usize,
        /// The rejected request, returned intact for retry.
        request: Box<InferenceRequest>,
    },
    /// The input tensor does not match the model's input shape.
    ShapeMismatch {
        /// The model's declared input shape.
        expected: Vec<usize>,
        /// The shape of the tensor actually submitted.
        got: Vec<usize>,
        /// The rejected request, returned intact.
        request: Box<InferenceRequest>,
    },
    /// The admission policy shed the request: its predicted completion
    /// (queue backlog plus its own modeled cost) already exceeds its
    /// deadline. Counted as [`ServingMetrics::shed_predicted`],
    /// distinct from queue-full [`ServingMetrics::rejected`].
    ShedPredicted {
        /// Predicted completion time from the [`CostModel`].
        predicted: SimTime,
        /// The deadline the request would have missed.
        deadline: SimTime,
        /// The shed request, returned intact.
        request: Box<InferenceRequest>,
    },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Backpressure { queued, .. } => {
                write!(f, "backpressure: all worker queues full ({queued} queued)")
            }
            SubmitError::ShapeMismatch { expected, got, request } => {
                write!(
                    f,
                    "input shape {got:?} does not match {}'s input shape {expected:?}",
                    request.model.name
                )
            }
            SubmitError::ShedPredicted { predicted, deadline, .. } => {
                write!(
                    f,
                    "admission control shed: predicted completion {predicted} past deadline {deadline}"
                )
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// The serving coordinator: owns the pool, the executable-cache model
/// and the clock; accepts requests and drains them through the
/// scheduler ([`ExecMode::Modeled`]) or the OS-thread worker loop
/// ([`ExecMode::Threaded`]).
pub struct Coordinator {
    /// The policy this coordinator was built with. The worker counts
    /// track the *live* composition: [`Coordinator::reconfigure`]
    /// updates them when the elastic layer swaps the pool.
    pub cfg: CoordinatorConfig,
    pool: WorkerPool,
    batcher: pool::SharedBatcher,
    check: SharedCrossCheck,
    metrics: ServingMetrics,
    /// Traffic-aware reprovisioning, when configured.
    elastic: Option<crate::elastic::ElasticController>,
    /// Streaming telemetry (series bank + alert engine), when
    /// configured.
    telemetry: Option<Telemetry>,
    /// The modeled "now": arrivals are stamped with it; `advance`
    /// moves it (load generation), `run_until_idle` never rewinds it.
    now: SimTime,
    next_id: u64,
}

/// Streaming telemetry state for one coordinator: the series bank the
/// drain boundary samples into, and the alert engine evaluated over
/// it. Sampling only *reads* serving state, so telemetry can never
/// perturb the modeled timeline.
struct Telemetry {
    cfg: crate::obs::TelemetryConfig,
    series: crate::obs::SeriesBank,
    engine: crate::obs::AlertEngine,
}

impl Telemetry {
    fn new(cfg: crate::obs::TelemetryConfig) -> Self {
        let series = crate::obs::SeriesBank::new(cfg.capacity);
        let engine = crate::obs::AlertEngine::new(&cfg);
        Telemetry { cfg, series, engine }
    }

    /// Take one drain-boundary sample of every canonical series.
    fn sample(
        &mut self,
        now: SimTime,
        m: &ServingMetrics,
        pool: &WorkerPool,
        done: &[Completion],
    ) {
        use crate::obs::timeseries::names;
        let s = &mut self.series;
        s.counter(names::SUBMITTED).push_counter(now, m.submitted);
        s.counter(names::COMPLETED).push_counter(now, m.completed);
        s.counter(names::SHED).push_counter(now, m.shed_predicted);
        s.counter(names::STEALS).push_counter(now, m.steals);
        s.counter(names::SLO_ATTAINED).push_counter(now, m.slo_attained);
        s.counter(names::SLO_MISSED).push_counter(now, m.slo_missed);
        s.gauge(names::QUEUE_PEAK).push_gauge(now, m.queue_peak as f64);
        s.gauge(names::REQ_S).push_gauge(now, m.throughput_rps());
        s.gauge(names::LATENCY_P99_MS).push_gauge(now, m.latency_pct(0.99).as_ms_f64());
        s.gauge(names::SLO_ATTAINMENT).push_gauge(now, m.slo_attainment());
        s.gauge(names::DRAIN_REQUESTS).push_gauge(now, done.len() as f64);
        // Per-drain mean latency via an order-independent integer sum:
        // the threaded drain returns completions in id order, the
        // modeled one in execution order, and the sample must be
        // bit-identical across exec modes.
        let mean_ms = if done.is_empty() {
            0.0
        } else {
            let sum_ps: u128 = done.iter().map(|c| c.latency().as_ps() as u128).sum();
            (sum_ps / done.len() as u128) as f64 / 1e9
        };
        s.gauge(names::DRAIN_LATENCY_MS).push_gauge(now, mean_ms);
        let makespan = m.makespan();
        for w in &pool.workers {
            s.gauge(&format!("util.{}", w.label())).push_gauge(now, w.utilization(makespan));
        }
    }
}

/// The instant span recorded for one fired telemetry alert.
fn alert_span(a: &crate::obs::Alert) -> Span {
    let mut s = Span::instant(Stage::Alert, a.at);
    s.attrs.push(("kind", a.kind.name().to_string()));
    s.attrs.push(("series", a.series.clone()));
    s.attrs.push(("value", format!("{:.3}", a.value)));
    s.attrs.push(("threshold", format!("{:.3}", a.threshold)));
    s.attrs.push(("window", a.window.to_string()));
    s
}

impl Coordinator {
    /// A coordinator whose batcher uses the [`crate::runtime::bucket_shape`]
    /// rounding grid for bucket identity.
    pub fn new(cfg: CoordinatorConfig) -> Self {
        Self::with_buckets(cfg, Vec::new())
    }

    /// A coordinator batching against an explicit AOT bucket table.
    pub fn with_buckets(cfg: CoordinatorConfig, buckets: Vec<Bucket>) -> Self {
        let batcher = Arc::new(Mutex::new(BucketBatcher::new(buckets, cfg.compile_cost)));
        let check: SharedCrossCheck = Arc::new(Mutex::new(None));
        let pool = WorkerPool::build(&cfg, batcher.clone(), check.clone());
        let elastic = cfg.elastic.clone().map(|e| {
            crate::elastic::ElasticController::with_designs(
                e,
                cfg.driver.threads,
                cfg.driver.sync_overhead,
                &cfg.sa_design,
                &cfg.vm_design,
            )
        });
        let telemetry = cfg.telemetry.clone().map(Telemetry::new);
        Coordinator {
            cfg,
            pool,
            batcher,
            check,
            metrics: ServingMetrics::default(),
            elastic,
            telemetry,
            now: SimTime::ZERO,
            next_id: 0,
        }
    }

    /// A coordinator batching against the artifact manifest in `dir`.
    /// A missing manifest falls back to the rounding grid (serving
    /// works without artifacts); a *corrupt* manifest is an error —
    /// silently diverging from the bucket table the PJRT runtime
    /// would use must not happen.
    pub fn with_artifact_manifest(
        cfg: CoordinatorConfig,
        dir: &Path,
    ) -> Result<Self, crate::runtime::RuntimeError> {
        let buckets = if crate::runtime::available(dir) {
            crate::runtime::load_manifest(dir)?
        } else {
            Vec::new()
        };
        Ok(Self::with_buckets(cfg, buckets))
    }

    /// Install a hook called with every GEMM task and its functional
    /// output — `edge_serving` uses it for the PJRT-vs-simulator
    /// bit-identity assertion. The hook must not re-enter the
    /// coordinator; under [`ExecMode::Threaded`] it is called from
    /// worker threads (serialized by the hook's mutex), hence the
    /// [`Send`] bound on [`pool::CrossCheckFn`].
    pub fn set_cross_check(&mut self, f: Box<pool::CrossCheckFn>) {
        *self.check.lock().expect("cross-check lock") = Some(f);
    }

    /// Remove the cross-check hook.
    pub fn clear_cross_check(&mut self) {
        *self.check.lock().expect("cross-check lock") = None;
    }

    /// The coordinator's modeled clock.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Advance the modeled clock (inter-arrival time of a load
    /// generator).
    pub fn advance(&mut self, dt: SimTime) {
        self.now += dt;
    }

    /// Submit a best-effort request (no SLO deadline) arriving at the
    /// current modeled time.
    pub fn submit(&mut self, model: Arc<Graph>, input: Tensor) -> Result<u64, SubmitError> {
        self.submit_with_deadline(model, input, None)
    }

    /// Submit a request with an SLO budget relative to now: its
    /// deadline is the current modeled time plus `slo`.
    pub fn submit_with_slo(
        &mut self,
        model: Arc<Graph>,
        input: Tensor,
        slo: SimTime,
    ) -> Result<u64, SubmitError> {
        let deadline = self.now + slo;
        self.submit_with_deadline(model, input, Some(deadline))
    }

    /// Submit a request with an explicit absolute deadline (or none),
    /// arriving at the current modeled time. How the deadline is
    /// honored belongs to the configured [`SchedulePolicy`].
    pub fn submit_with_deadline(
        &mut self,
        model: Arc<Graph>,
        input: Tensor,
        deadline: Option<SimTime>,
    ) -> Result<u64, SubmitError> {
        let req = InferenceRequest {
            id: self.next_id,
            model,
            input,
            arrival: self.now,
            deadline,
        };
        if req.input.shape != req.model.input_shape {
            // not counted in metrics.rejected: that counter means
            // backpressure (pool saturated), this is a caller bug
            let expected = req.model.input_shape.clone();
            let got = req.input.shape.clone();
            return Err(SubmitError::ShapeMismatch {
                expected,
                got,
                request: Box::new(req),
            });
        }
        self.cfg.spans.record(|| {
            let mut s = Span::instant(Stage::Submit, self.now);
            s.request_id = Some(req.id);
            s.attrs.push(("model", req.model.name.clone()));
            if let Some(d) = req.deadline {
                s.attrs.push(("deadline", d.to_string()));
            }
            s
        });
        // disjoint field borrows: &mut pool next to &cfg.policy
        match self.pool.submit(req, self.cfg.policy.as_ref(), self.now) {
            Ok(widx) => {
                let id = self.next_id;
                self.next_id += 1;
                self.metrics.record_submit(self.now);
                let depth = self.pool.workers[widx].queue.len();
                self.metrics.observe_queue_depth(depth);
                self.cfg.spans.record(|| {
                    let mut s = Span::instant(Stage::Admission, self.now);
                    s.request_id = Some(id);
                    s.attrs.push(("verdict", "admitted".into()));
                    s.attrs.push(("placed_on", widx.to_string()));
                    s.attrs.push(("queue_depth", depth.to_string()));
                    s
                });
                Ok(id)
            }
            Err(pool::SubmitRejection::Full(request)) => {
                self.metrics.record_reject();
                self.cfg.spans.record(|| {
                    let mut s = Span::instant(Stage::Admission, self.now);
                    s.attrs.push(("verdict", "backpressure".into()));
                    s.attrs.push(("model", request.model.name.clone()));
                    s
                });
                Err(SubmitError::Backpressure {
                    queued: self.pool.total_queued(),
                    request,
                })
            }
            Err(pool::SubmitRejection::Shed { request, predicted, deadline }) => {
                self.metrics.record_shed();
                self.cfg.spans.record(|| {
                    let mut s = Span::instant(Stage::Admission, self.now);
                    s.attrs.push(("verdict", "shed".into()));
                    s.attrs.push(("predicted", predicted.to_string()));
                    s.attrs.push(("deadline", deadline.to_string()));
                    s
                });
                Err(SubmitError::ShedPredicted {
                    predicted,
                    deadline,
                    request,
                })
            }
        }
    }

    /// Requests currently queued across the pool.
    pub fn queued(&self) -> usize {
        self.pool.total_queued()
    }

    /// Read-only admission probe: would this coordinator's policy shed
    /// a request for `model` submitted at modeled time `at` (clamped
    /// to no earlier than the board's own clock)?
    ///
    /// Runs the exact admission pipeline a real submit would —
    /// placement through [`SchedulePolicy::place`], predicted
    /// completion from the target worker's [`CostModel`], then the
    /// policy's [`SchedulePolicy::admit`] verdict — without mutating
    /// anything. Returns `Some((predicted, deadline))` when admission
    /// control would shed, `None` when the request would be admitted
    /// (including: the policy runs no admission control, or every
    /// queue is full — backpressure is a capacity verdict, not a
    /// shed). The fleet router uses this to keep the placement
    /// invariant "never place onto a board whose admission control
    /// would shed" exact rather than estimated.
    pub fn would_shed(
        &self,
        model: &Arc<Graph>,
        input: &Tensor,
        deadline: Option<SimTime>,
        at: SimTime,
    ) -> Option<(SimTime, SimTime)> {
        let policy = self.cfg.policy.as_ref();
        if !policy.admission_control() {
            return None;
        }
        let now = at.max(self.now);
        // probe id u64::MAX: every queued request's id is smaller than
        // the next real id, so the backlog counted ahead of the probe
        // is exactly the backlog counted ahead of the real submit
        let req = InferenceRequest {
            id: u64::MAX,
            model: model.clone(),
            input: input.clone(),
            arrival: now,
            deadline,
        };
        let target = policy.place(&self.pool.workers, self.cfg.queue_depth, &req)?;
        let predicted = self.pool.predicted_completion(target, &req, policy, now);
        match policy.admit(&req, predicted) {
            Admission::Shed { predicted, deadline } => Some((predicted, deadline)),
            Admission::Accept => None,
        }
    }

    /// Drain every queued request, returning the completions of this
    /// drain — in execution order under [`ExecMode::Modeled`], sorted
    /// by request id under [`ExecMode::Threaded`] (worker threads
    /// spawn, drain the shared queues, and are joined before this
    /// returns; no thread outlives the call).
    pub fn run_until_idle(&mut self) -> Vec<Completion> {
        let done = match self.cfg.exec_mode {
            ExecMode::Modeled => {
                scheduler::drain(&mut self.pool, &self.cfg, &mut self.metrics)
            }
            ExecMode::Threaded => {
                threaded::drain(&mut self.pool, &self.cfg, &mut self.metrics)
            }
        };
        if let Some(last) = done.iter().map(|c| c.finished).max() {
            self.now = self.now.max(last);
        }
        // telemetry sampling at the drain boundary: reads metrics the
        // drain already computed, so the modeled timeline is untouched
        // (pinned by prop_telemetry_is_inert)
        let mut trend = None;
        if let Some(tel) = self.telemetry.as_mut() {
            tel.sample(self.now, &self.metrics, &self.pool, &done);
            let fired = tel.engine.evaluate(self.now, &tel.series);
            for a in &fired {
                self.cfg.spans.record(|| alert_span(a));
            }
            if tel.cfg.feed_trend {
                trend = Some(tel.engine.trend());
            }
        }
        // elastic evaluation at the drain boundary: the pool is idle
        // (threaded workers have joined), so a reconfiguration never
        // races in-flight work in either exec mode
        if let Some(mut ctrl) = self.elastic.take() {
            for c in &done {
                ctrl.observe(c);
            }
            if let Some(t) = trend {
                ctrl.note_trend(t);
            }
            let plan = ctrl.evaluate(self.now, self.composition(), &self.pool);
            if let Some(profile) = ctrl.take_last_profile() {
                self.cfg.spans.record(|| {
                    let mut s = Span::new(
                        Stage::EstimatorWindow,
                        self.now.saturating_sub(profile.span),
                        self.now,
                    );
                    s.attrs.push(("requests", profile.requests.to_string()));
                    s.attrs
                        .push(("rate_rps", format!("{:.3}", profile.arrival_rate_rps)));
                    s.attrs.push(("shapes", profile.demand.len().to_string()));
                    s
                });
            }
            if let Some(plan) = plan {
                self.cfg.spans.record(|| {
                    let mut s = Span::instant(Stage::Plan, self.now);
                    s.attrs.push(("from", plan.from.to_string()));
                    s.attrs.push(("to", plan.to.to_string()));
                    s.attrs.push(("swaps", plan.swaps.to_string()));
                    s
                });
                self.reconfigure(&plan);
                ctrl.commit(&plan, self.now);
            }
            self.elastic = Some(ctrl);
        }
        done
    }

    /// The pool's live composition (workers per kind).
    pub fn composition(&self) -> crate::elastic::Composition {
        let mut c = crate::elastic::Composition::default();
        for w in &self.pool.workers {
            match w.kind {
                WorkerKind::Sa => c.sa += 1,
                WorkerKind::Vm => c.vm += 1,
                WorkerKind::Cpu => c.cpu += 1,
            }
        }
        c
    }

    /// Migrate the pool to `plan.to`: retire surplus workers (their
    /// queued requests are re-placed on the surviving pool through the
    /// configured policy — an admitted request is never dropped or
    /// re-subjected to admission control), spawn the missing
    /// instances, and delay every swapped-in accelerator by its
    /// modeled bitstream-load time ([`crate::synth::reconfig_time`]).
    /// Works identically in both exec modes — threaded workers are
    /// per-drain, so they park at the drain's scope join and respawn
    /// on the reconfigured pool at the next drain.
    ///
    /// Normally driven by the elastic controller, but public: a caller
    /// may apply a hand-built plan (e.g. scheduled maintenance to a
    /// CPU-only pool).
    pub fn reconfigure(&mut self, plan: &crate::elastic::ReconfigPlan) {
        self.cfg.spans.record(|| {
            let mut s =
                Span::new(Stage::Reconfigure, self.now, self.now + plan.reconfig_cost);
            s.attrs.push(("from", plan.from.to_string()));
            s.attrs.push(("to", plan.to.to_string()));
            s.attrs.push(("swaps", plan.swaps.to_string()));
            s
        });
        let displaced = self.pool.apply_composition(
            &plan.to,
            &self.cfg,
            self.batcher.clone(),
            self.check.clone(),
            self.now,
        );
        for req in displaced {
            self.pool.migrate(req, self.cfg.policy.as_ref());
        }
        self.cfg.sa_workers = plan.to.sa;
        self.cfg.vm_workers = plan.to.vm;
        self.cfg.cpu_workers = plan.to.cpu;
        self.metrics.record_reconfig(plan.reconfig_cost);
    }

    /// The composition timeline: every reconfiguration the elastic
    /// controller committed (empty without an elastic config).
    pub fn elastic_history(&self) -> &[crate::elastic::SwapRecord] {
        self.elastic.as_ref().map(|c| c.history()).unwrap_or(&[])
    }

    /// Accumulated serving telemetry.
    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// The span recorder this coordinator (and its pool) records into.
    /// Export a drained run with [`crate::obs::export::chrome_trace`].
    pub fn spans(&self) -> &SpanRecorder {
        &self.cfg.spans
    }

    /// The telemetry series bank sampled at every drain boundary
    /// (`None` without a telemetry config).
    pub fn telemetry_series(&self) -> Option<&crate::obs::SeriesBank> {
        self.telemetry.as_ref().map(|t| &t.series)
    }

    /// Every telemetry alert fired so far, in firing order (empty
    /// without a telemetry config).
    pub fn alerts(&self) -> &[crate::obs::Alert] {
        self.telemetry
            .as_ref()
            .map(|t| t.engine.alerts())
            .unwrap_or(&[])
    }

    /// The serving metrics registry, with every telemetry series
    /// registered alongside (`series.<name>.*` entries) when telemetry
    /// is configured.
    pub fn metrics_registry(&self) -> crate::obs::MetricsRegistry {
        let mut reg = self.metrics.registry();
        if let Some(tel) = &self.telemetry {
            tel.series.register_into(&mut reg);
        }
        reg
    }

    /// Chrome-trace export of this coordinator's spans, with telemetry
    /// counter tracks merged in when telemetry is configured.
    pub fn chrome_trace(&self) -> String {
        let spans = self.cfg.spans.snapshot();
        match &self.telemetry {
            Some(tel) => crate::obs::export::chrome_trace_with_series(&spans, &tel.series),
            None => crate::obs::export::chrome_trace(&spans),
        }
    }

    /// The shared executable-cache model (compiles / hits / buckets).
    pub fn batcher(&self) -> std::sync::MutexGuard<'_, BucketBatcher> {
        self.batcher.lock().expect("executable-cache lock")
    }

    /// The worker pool (read-only view for reports).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Per-layer serving seam: the coordinator as a [`GemmBackend`],
    /// for running an existing [`crate::framework::interpreter::Session`]
    /// with each GEMM dispatched to the pool instance that frees up
    /// first. Layers of one session form a dependency chain, so each
    /// layer starts no earlier than the previous layer's finish (the
    /// session horizon) — the pool buys device choice per layer, not
    /// impossible intra-request overlap.
    pub fn backend(&mut self) -> CoordinatorBackend<'_> {
        let horizon = self.now;
        CoordinatorBackend {
            coord: self,
            horizon,
        }
    }

    /// Multi-line per-worker serving report.
    pub fn worker_report(&self) -> String {
        let makespan = self.metrics.makespan();
        let mut out = String::new();
        for w in &self.pool.workers {
            let planner = &w.backend.planner;
            let drv = w
                .backend
                .handle()
                .and_then(|h| h.driver_stats())
                .map(|s| {
                    format!(
                        ", {} offloads, {} fallbacks, {:.1} MB moved",
                        s.offloads,
                        s.cpu_fallbacks,
                        (s.bytes_to_accel + s.bytes_from_accel) as f64 / 1e6
                    )
                })
                .unwrap_or_default();
            let kind = match w.kind {
                WorkerKind::Sa => "SA ",
                WorkerKind::Vm => "VM ",
                WorkerKind::Cpu => "CPU",
            };
            out.push_str(&format!(
                "  {:<6} [{kind}] served {:>4} ({:>5.1}% util), routed {} accel / {} cpu{}\n",
                w.label(),
                w.served,
                100.0 * w.utilization(makespan),
                planner.offloads,
                planner.cpu_routed,
                drv,
            ));
        }
        out
    }
}

/// [`Coordinator::backend`]: per-layer dispatch of a single session's
/// GEMMs across the pool. Each layer goes to the instance with the
/// earliest `free_at`, but never starts before the session horizon
/// (the previous layer's finish) — consecutive layers depend on each
/// other's data, so they must serialize even across instances.
pub struct CoordinatorBackend<'c> {
    coord: &'c mut Coordinator,
    /// Finish time of this session's latest layer.
    horizon: SimTime,
}

impl GemmBackend for CoordinatorBackend<'_> {
    fn name(&self) -> &str {
        "coordinator"
    }

    fn run_gemm(&mut self, task: &GemmTask<'_>) -> (Vec<i8>, GemmTiming) {
        let widx = self.coord.pool.idlest();
        let w = &mut self.coord.pool.workers[widx];
        let start = w.free_at.max(self.horizon);
        let (out, timing) = w.backend.run_gemm(task);
        let finish = start + timing.total;
        w.free_at = finish;
        w.busy += timing.total;
        self.horizon = finish;
        (out, timing)
    }
}

/// Shared fixtures for the coordinator test modules (here and in
/// [`threaded`]) — one definition so the threaded-vs-modeled agreement
/// tests provably exercise the same graphs as the modeled-path tests.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::framework::backend::CpuBackend;
    use crate::framework::graph::GraphBuilder;
    use crate::framework::interpreter::Session;
    use crate::framework::ops::{Activation, Conv2d, GlobalAvgPool, Op, SoftmaxOp};
    use crate::framework::quant::QParams;

    fn rnd(st: &mut u64) -> u64 {
        *st ^= *st << 13;
        *st ^= *st >> 7;
        *st ^= *st << 17;
        *st
    }

    /// A small convnet head. Its conv GEMM is (cout, 27, 256), which
    /// the serving-tier CPU model prices under the sync-overhead
    /// floor, so the planner keeps it on the worker's own (SIMD) CPU
    /// path; tests that need a deterministic offload use
    /// [`deep_convnet`] instead.
    pub(crate) fn convnet(name: &str, cout: usize, seed: u64) -> Graph {
        let mut st = seed.max(1);
        let cin = 3;
        // 16x16 input -> the conv GEMM is (cout, 27, 256)
        let mut b = GraphBuilder::new(name, vec![1, 16, 16, cin], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: format!("{name}.c1"),
            cout,
            kh: 3,
            kw: 3,
            cin,
            stride: 1,
            pad: 1,
            weights: (0..cout * 9 * cin)
                .map(|_| (rnd(&mut st) & 0xff) as u8 as i8)
                .collect(),
            bias: vec![7; cout],
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    /// A convnet whose single conv GEMM is (cout, 4608, 49): K = 4608
    /// exceeds the paper VM's local buffers (`max_k` 4096), so a VM
    /// worker's driver falls back to the CPU on it while the SA runs
    /// it on fabric — the shape class the elastic tests provision
    /// around.
    pub(crate) fn deep_convnet(name: &str, cout: usize, seed: u64) -> Graph {
        let mut st = seed.max(1);
        let cin = 512;
        let mut b = GraphBuilder::new(name, vec![1, 7, 7, cin], QParams::new(0.05, 0));
        let conv = Conv2d {
            name: format!("{name}.c1"),
            cout,
            kh: 3,
            kw: 3,
            cin,
            stride: 1,
            pad: 1,
            weights: (0..cout * 9 * cin)
                .map(|_| (rnd(&mut st) & 0xff) as u8 as i8)
                .collect(),
            bias: vec![3; cout],
            w_scales: vec![0.02; cout],
            out_qp: QParams::new(0.05, 0),
            act: Activation::Relu,
            weights_resident: false,
        };
        let c = b.push(Op::Conv(conv), vec![b.input()]);
        let g = b.push(Op::GlobalAvgPool(GlobalAvgPool { name: "gap".into() }), vec![c]);
        let s = b.push(Op::Softmax(SoftmaxOp { name: "sm".into() }), vec![g]);
        b.finish(s)
    }

    /// A deterministic pseudo-random input image for `g`.
    pub(crate) fn image(g: &Graph, seed: u64) -> Tensor {
        let mut st = seed.max(1);
        let n: usize = g.input_shape.iter().product();
        let data = (0..n).map(|_| (rnd(&mut st) & 0xff) as u8 as i8).collect();
        Tensor::new(g.input_shape.clone(), data, g.input_qp)
    }

    /// Independent single-threaded gemmlowp reference output.
    pub(crate) fn cpu_reference(g: &Graph, input: &Tensor) -> Tensor {
        let mut cb = CpuBackend::new(1);
        Session::new(g, &mut cb, 1).run(input).0
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::{convnet, cpu_reference, image};
    use super::*;
    use crate::framework::interpreter::Session;

    #[test]
    fn serves_mixed_models_bit_exact() {
        let g1 = Arc::new(convnet("net_a", 16, 3));
        let g2 = Arc::new(convnet("net_b", 24, 5));
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut expected = Vec::new();
        for i in 0..6u64 {
            let g = if i % 2 == 0 { g1.clone() } else { g2.clone() };
            let input = image(&g, 100 + i);
            expected.push((coord.submit(g.clone(), input.clone()).unwrap(), g, input));
            coord.advance(SimTime::us(300));
        }
        let done = coord.run_until_idle();
        assert_eq!(done.len(), 6);
        for (id, g, input) in expected {
            let c = done.iter().find(|c| c.id == id).expect("completed");
            let reference = cpu_reference(&g, &input);
            assert_eq!(c.output.data, reference.data, "request {id} diverged");
            assert!(c.finished >= c.started);
            assert!(c.started >= c.arrival);
        }
        assert_eq!(coord.metrics().completed, 6);
    }

    #[test]
    fn pool_of_two_beats_pool_of_one() {
        let g = Arc::new(convnet("net", 32, 9));
        let makespan = |workers: usize| {
            let mut coord = Coordinator::new(CoordinatorConfig::sa_pool(workers));
            for i in 0..8u64 {
                coord.submit(g.clone(), image(&g, 40 + i)).unwrap();
            }
            coord.run_until_idle();
            coord.metrics().makespan()
        };
        let one = makespan(1);
        let two = makespan(2);
        assert!(
            two < one,
            "pool=2 makespan {two} not better than pool=1 {one}"
        );
    }

    #[test]
    fn full_queues_backpressure_but_nothing_starves() {
        let g = Arc::new(convnet("net", 16, 11));
        let mut cfg = CoordinatorConfig::sa_pool(2);
        cfg.queue_depth = 2;
        let mut coord = Coordinator::new(cfg);
        let mut accepted = Vec::new();
        let mut rejected = 0;
        for i in 0..10u64 {
            match coord.submit(g.clone(), image(&g, 60 + i)) {
                Ok(id) => accepted.push(id),
                Err(SubmitError::Backpressure { queued, request }) => {
                    assert_eq!(queued, 4); // 2 workers x depth 2
                    // the rejected request comes back intact for retry
                    assert_eq!(request.model.name, "net");
                    assert_eq!(request.input.shape, g.input_shape);
                    rejected += 1;
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert_eq!(accepted.len(), 4);
        assert_eq!(rejected, 6);
        assert_eq!(coord.metrics().rejected, 6);
        let done = coord.run_until_idle();
        let mut ids: Vec<u64> = done.iter().map(|c| c.id).collect();
        ids.sort();
        assert_eq!(ids, accepted, "every accepted request completed exactly once");
    }

    #[test]
    fn mismatched_input_shape_is_rejected_not_fatal() {
        let g = Arc::new(convnet("net", 16, 12));
        let mut coord = Coordinator::new(CoordinatorConfig::sa_pool(1));
        let bad = Tensor::zeros(vec![1, 4, 4, 3], g.input_qp);
        match coord.submit(g.clone(), bad) {
            Err(SubmitError::ShapeMismatch { expected, got, request }) => {
                assert_eq!(expected, g.input_shape);
                assert_eq!(got, vec![1, 4, 4, 3]);
                assert_eq!(request.model.name, "net");
            }
            other => panic!("expected ShapeMismatch, got {other:?}"),
        }
        // the coordinator still serves good requests afterwards
        let ok = coord.submit(g.clone(), image(&g, 99)).unwrap();
        let done = coord.run_until_idle();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, ok);
    }

    #[test]
    fn fifo_order_preserved_per_worker() {
        let g1 = Arc::new(convnet("net_a", 16, 13));
        let g2 = Arc::new(convnet("net_b", 24, 15));
        let mut coord = Coordinator::new(CoordinatorConfig::sa_pool(2));
        for i in 0..12u64 {
            let g = if i % 3 == 0 { g2.clone() } else { g1.clone() };
            let input = image(&g, i + 1);
            coord.submit(g, input).unwrap();
            coord.advance(SimTime::us(100));
        }
        let done = coord.run_until_idle();
        assert_eq!(done.len(), 12);
        // per worker, execution must advance monotonically in modeled time
        for w in 0..2 {
            let starts: Vec<SimTime> = done
                .iter()
                .filter(|c| c.worker == w)
                .map(|c| c.started)
                .collect();
            let mut sorted = starts.clone();
            sorted.sort();
            assert_eq!(starts, sorted, "worker {w} ran out of order");
        }
    }

    #[test]
    fn idle_worker_steals_queued_work() {
        let g = Arc::new(convnet("net", 32, 17));
        let cfg = CoordinatorConfig::sa_pool(2);
        let batcher = Arc::new(Mutex::new(BucketBatcher::new(Vec::new(), SimTime::ZERO)));
        let check: SharedCrossCheck = Arc::new(Mutex::new(None));
        let mut pool = WorkerPool::build(&cfg, batcher, check);
        let mut cfg2 = cfg.clone();
        cfg2.max_batch = 1; // force one dispatch round per request
        // pile everything onto worker 0's queue
        for i in 0..4u64 {
            pool.workers[0].queue.push_back(InferenceRequest {
                id: i,
                model: g.clone(),
                input: image(&g, 80 + i),
                arrival: SimTime::ZERO,
                deadline: None,
            });
        }
        let mut metrics = ServingMetrics::default();
        let done = scheduler::drain(&mut pool, &cfg2, &mut metrics);
        assert_eq!(done.len(), 4);
        assert!(metrics.steals >= 1, "no steals recorded");
        assert!(
            pool.workers[1].served >= 1,
            "idle worker never took stolen work"
        );
    }

    #[test]
    fn cross_check_hook_sees_every_gemm() {
        let g = Arc::new(convnet("net", 16, 19));
        let mut coord = Coordinator::new(CoordinatorConfig::sa_pool(1));
        let count = Arc::new(Mutex::new(0u64));
        let c2 = count.clone();
        coord.set_cross_check(Box::new(move |task, out| {
            assert_eq!(out.len(), task.m * task.n);
            *c2.lock().unwrap() += 1;
        }));
        for i in 0..3u64 {
            coord.submit(g.clone(), image(&g, 70 + i)).unwrap();
        }
        coord.run_until_idle();
        // one conv per request
        assert_eq!(*count.lock().unwrap(), 3);
    }

    #[test]
    fn batching_groups_same_model_and_amortizes_compiles() {
        use super::testutil::deep_convnet;
        // deep-K conv: deterministically offloaded (the small convnet
        // now stays on the serving-tier CPU path, which never compiles
        // an AOT executable)
        let g = Arc::new(deep_convnet("net", 32, 23));
        let mut cfg = CoordinatorConfig::sa_pool(1);
        cfg.batch_window = SimTime::ms(50);
        let mut coord = Coordinator::new(cfg);
        for i in 0..6u64 {
            coord.submit(g.clone(), image(&g, 30 + i)).unwrap();
        }
        let done = coord.run_until_idle();
        assert_eq!(done.len(), 6);
        let m = coord.metrics();
        assert_eq!(m.batches.len(), 1, "expected one batch round: {:?}", m.batches);
        assert_eq!(m.batches[0].size, 6);
        // one conv bucket -> exactly one compile, five warm hits
        let b = coord.batcher();
        assert_eq!(b.compiles, 1);
        assert_eq!(b.hits, 5);
    }

    #[test]
    fn manual_reconfigure_migrates_queued_requests() {
        use crate::elastic::{Composition, ReconfigPlan};
        let g = Arc::new(convnet("net", 16, 31));
        let mut coord = Coordinator::new(CoordinatorConfig::sa_pool(2));
        let mut ids = Vec::new();
        for i in 0..6u64 {
            ids.push(coord.submit(g.clone(), image(&g, 200 + i)).unwrap());
        }
        let from = coord.composition();
        assert_eq!(from, Composition::new(2, 0, 0));
        let plan = ReconfigPlan {
            from,
            to: Composition::new(1, 0, 1),
            projected_current: SimTime::ZERO,
            projected_best: SimTime::ZERO,
            reconfig_cost: SimTime::ms(30),
            swaps: 1,
        };
        coord.reconfigure(&plan);
        assert_eq!(coord.composition(), Composition::new(1, 0, 1));
        assert_eq!(coord.cfg.sa_workers, 1);
        assert_eq!(coord.cfg.cpu_workers, 1);
        assert_eq!(coord.queued(), 6, "a queued request was lost in migration");
        assert_eq!(coord.metrics().reconfigs, 1);
        assert_eq!(coord.metrics().reconfig_time, SimTime::ms(30));
        let done = coord.run_until_idle();
        let mut got: Vec<u64> = done.iter().map(|c| c.id).collect();
        got.sort();
        assert_eq!(got, ids, "every admitted request completes exactly once");
        for c in &done {
            let reference = cpu_reference(&g, &image(&g, 200 + c.id));
            assert_eq!(c.output.data, reference.data, "request {} diverged", c.id);
        }
    }

    #[test]
    fn elastic_controller_swaps_vm_for_sa_under_conv_load() {
        use super::testutil::deep_convnet;
        use crate::elastic::{Composition, ElasticConfig};
        // Deliberately mis-provisioned: the fabric holds the VM while
        // the traffic is deep-K conv (K=4608 > the VM's max_k), which
        // the VM driver can only serve at CPU-fallback speed.
        let g = Arc::new(deep_convnet("deep", 96, 33));
        let cfg = CoordinatorConfig {
            sa_workers: 0,
            vm_workers: 1,
            cpu_workers: 0,
            queue_depth: 64,
            elastic: Some(ElasticConfig {
                eval_interval: SimTime::ZERO,
                window: SimTime::ms(60_000),
                min_samples: 4,
                hysteresis: SimTime::ms(1),
                max_swaps: 1,
                cpu_max: 0,
                ..ElasticConfig::default()
            }),
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg);
        assert_eq!(coord.composition(), Composition::new(0, 1, 0));
        // wave 1: served by the mis-provisioned VM, observed by the
        // controller. 12 requests: with the serving-tier CPU model the
        // planner sidesteps the VM's deep-K fallback by routing to the
        // worker CPU, so the per-request win of holding the SA instead
        // is a few ms — a short wave no longer justifies a ~30 ms
        // bitstream swap, a sustained one still does.
        for i in 0..12u64 {
            coord.submit(g.clone(), image(&g, 300 + i)).unwrap();
        }
        let wave1 = coord.run_until_idle();
        assert_eq!(wave1.len(), 12);
        // the drain boundary evaluated the planner: bitstream swapped
        assert_eq!(coord.composition(), Composition::new(1, 0, 0));
        let first = &coord.elastic_history()[0];
        assert_eq!(first.from, Composition::new(0, 1, 0));
        assert_eq!(first.to, Composition::new(1, 0, 0));
        assert!(first.projected_win > first.reconfig_cost);
        assert_eq!(coord.metrics().reconfigs, 1);
        assert_eq!(coord.cfg.sa_workers, 1);
        // wave 2 on the SA: correct bits, and no further churn
        for i in 0..4u64 {
            coord.submit(g.clone(), image(&g, 400 + i)).unwrap();
        }
        let wave2 = coord.run_until_idle();
        assert_eq!(wave2.len(), 4);
        assert_eq!(coord.elastic_history().len(), 1, "swap churn");
        for c in &wave2 {
            let reference = cpu_reference(&g, &image(&g, 400 + (c.id - 12)));
            assert_eq!(c.output.data, reference.data, "request {} diverged", c.id);
        }
    }

    #[test]
    fn coordinator_backend_runs_existing_sessions() {
        let g = convnet("net", 24, 29);
        let input = image(&g, 55);
        let reference = cpu_reference(&g, &input);
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut cb = coord.backend();
        let (out, report) = Session::new(&g, &mut cb, 1).run(&input);
        assert_eq!(out.data, reference.data);
        assert!(report.overall() > SimTime::ZERO);
    }
}
