//! Hardware-synthesis model (paper §III-D) — the substitute for the
//! Vivado HLS + logic-synthesis flow.
//!
//! Three roles:
//! 1. **Resource estimation**: LUT/FF/DSP/BRAM usage of a design
//!    configuration, checked against the PYNQ-Z1's Zynq-7020 budget.
//!    This is the feasibility gate SECDA's hardware-synthesis step
//!    enforces (e.g. "we are limited to four GEMM units by the
//!    resource constraints of the target device", §IV-C1) — and, at
//!    serving time, the gate the elastic pool planner
//!    ([`crate::elastic`]) applies to every candidate pool
//!    composition.
//! 2. **Synthesis-time model** (S_t of Eq. 1/2): scales with resource
//!    usage, anchored at the paper's observed S_t ≈ 25 x C_t.
//! 3. **Reconfiguration-time model** ([`reconfig_time`]): how long
//!    reprogramming the fabric with an already-synthesized bitstream
//!    takes — the cost the elastic controller charges per swapped-in
//!    instance before a reprovisioning pays off.

use crate::accel::components::BramArray;
use crate::accel::{SaConfig, VmConfig};
use crate::sysc::SimTime;

/// FPGA resource vector.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Resources {
    /// Look-up tables.
    pub luts: u32,
    /// Flip-flops.
    pub ffs: u32,
    /// DSP48 slices.
    pub dsps: u32,
    /// 36Kb block-RAM tiles.
    pub bram36: u32,
}

impl Resources {
    /// Component-wise sum of two resource vectors.
    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            luts: self.luts + o.luts,
            ffs: self.ffs + o.ffs,
            dsps: self.dsps + o.dsps,
            bram36: self.bram36 + o.bram36,
        }
    }

    /// This vector scaled by an instance count (the footprint of `n`
    /// identical design instances on one fabric).
    pub fn scaled(&self, n: u32) -> Resources {
        Resources {
            luts: self.luts * n,
            ffs: self.ffs * n,
            dsps: self.dsps * n,
            bram36: self.bram36 * n,
        }
    }

    /// Zynq-7020 (PYNQ-Z1) device budget.
    pub fn zynq7020() -> Resources {
        Resources {
            luts: 53_200,
            ffs: 106_400,
            dsps: 220,
            bram36: 140,
        }
    }

    /// Does this usage fit inside `budget` on every resource class?
    pub fn fits_in(&self, budget: &Resources) -> bool {
        self.luts <= budget.luts
            && self.ffs <= budget.ffs
            && self.dsps <= budget.dsps
            && self.bram36 <= budget.bram36
    }

    /// Highest utilization fraction across resource classes.
    pub fn max_utilization(&self, budget: &Resources) -> f64 {
        [
            self.luts as f64 / budget.luts as f64,
            self.ffs as f64 / budget.ffs as f64,
            self.dsps as f64 / budget.dsps as f64,
            self.bram36 as f64 / budget.bram36 as f64,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }
}

// Per-primitive costs (HLS-typical on 7-series):
// an int8 MAC maps to half a DSP48 (two 8-bit MACs pack per DSP) plus
// control LUTs; a PPU lane needs a 32x32 multiplier (1 DSP) + logic.
const LUTS_PER_MAC: u32 = 30;
const FFS_PER_MAC: u32 = 40;
const LUTS_PER_PPU_LANE: u32 = 350;
const FFS_PER_PPU_LANE: u32 = 400;
const DSPS_PER_PPU_LANE: u32 = 2;
const CONTROL_LUTS: u32 = 3_500; // scheduler + input handler + DMA glue
const CONTROL_FFS: u32 = 5_000;

fn bram_blocks(b: &BramArray) -> u32 {
    b.bram36_blocks()
}

/// Estimate resources of a VM configuration.
pub fn vm_resources(cfg: &VmConfig) -> Resources {
    let macs = (cfg.units * cfg.unit.tile_m * cfg.unit.tile_n * cfg.unit.macs_per_output) as u32;
    let ppu_lanes = match &cfg.ppu {
        Some(p) => (cfg.units * p.lanes) as u32,
        None => 0,
    };
    let local_bufs: u32 = cfg.units as u32
        * BramArray::new(2, 8, cfg.local_buf_bytes).bram36_blocks();
    Resources {
        luts: CONTROL_LUTS + macs * LUTS_PER_MAC + ppu_lanes * LUTS_PER_PPU_LANE,
        ffs: CONTROL_FFS + macs * FFS_PER_MAC + ppu_lanes * FFS_PER_PPU_LANE,
        dsps: macs / 2 + ppu_lanes * DSPS_PER_PPU_LANE,
        bram36: bram_blocks(&cfg.global_weight_buf)
            + bram_blocks(&cfg.global_input_buf)
            + local_bufs,
    }
}

/// Estimate resources of an SA configuration.
pub fn sa_resources(cfg: &SaConfig) -> Resources {
    let macs = (cfg.array.dim * cfg.array.dim) as u32;
    let ppu_lanes = cfg.ppu.as_ref().map(|p| p.lanes as u32).unwrap_or(0);
    // each data queue is a small FIFO: ~1/2 BRAM36 each
    let queue_brams = cfg.array.queue_count() as u32 / 2;
    Resources {
        luts: CONTROL_LUTS + macs * LUTS_PER_MAC + ppu_lanes * LUTS_PER_PPU_LANE,
        ffs: CONTROL_FFS + macs * FFS_PER_MAC + ppu_lanes * FFS_PER_PPU_LANE,
        dsps: macs / 2 + ppu_lanes * DSPS_PER_PPU_LANE,
        bram36: bram_blocks(&cfg.global_weight_buf)
            + bram_blocks(&cfg.global_input_buf)
            + queue_brams,
    }
}

/// Synthesis-time model: a base pass plus time proportional to device
/// utilization (place-and-route gets slower as the device fills).
/// Anchored so the paper VM design lands at ~25x the simulation
/// compile time (~40 min).
pub fn synthesis_time(r: &Resources) -> SimTime {
    let util = r.max_utilization(&Resources::zynq7020());
    let base_min = 12.0;
    let scale_min = 45.0;
    SimTime::ms(((base_min + scale_min * util) * 60_000.0) as u64)
}

/// Bitstream-reprogramming time for a design occupying `r` — the
/// *serving-time* cost of swapping what the fabric holds, as opposed
/// to [`synthesis_time`], the *design-time* cost of producing the
/// bitstream in the first place.
///
/// Model: the Zynq-7020 full bitstream (~4 MB) loads through the PCAP
/// port at ~128 MB/s in roughly 30 ms; partial reconfiguration scales
/// with the region being rewritten, so we charge a fixed setup plus a
/// term proportional to device utilization. The paper designs (~73%
/// utilized) land around 30 ms per swap — two orders of magnitude
/// above a single offload sync, three below a synthesis run, which is
/// exactly the regime where an elastic reprovisioner must amortize
/// swaps against a traffic window rather than per request.
pub fn reconfig_time(r: &Resources) -> SimTime {
    let util = r.max_utilization(&Resources::zynq7020());
    SimTime::ms((8.0 + 30.0 * util).round() as u64)
}

/// Outcome of a "synthesis run" on a design config.
#[derive(Debug, Clone)]
pub struct SynthReport {
    /// Estimated resource usage of the design.
    pub resources: Resources,
    /// Whether it fits the Zynq-7020 budget.
    pub fits: bool,
    /// Highest utilization fraction across resource classes.
    pub utilization: f64,
    /// Modeled synthesis (place-and-route) time.
    pub synth_time: SimTime,
}

/// "Synthesize" a VM configuration: estimate resources and check them
/// against the device budget.
pub fn synthesize_vm(cfg: &VmConfig) -> SynthReport {
    report(vm_resources(cfg))
}

/// "Synthesize" an SA configuration: estimate resources and check them
/// against the device budget.
pub fn synthesize_sa(cfg: &SaConfig) -> SynthReport {
    report(sa_resources(cfg))
}

fn report(r: Resources) -> SynthReport {
    let budget = Resources::zynq7020();
    SynthReport {
        resources: r,
        fits: r.fits_in(&budget),
        utilization: r.max_utilization(&budget),
        synth_time: synthesis_time(&r),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_designs_fit_the_device() {
        let vm = synthesize_vm(&VmConfig::paper());
        assert!(vm.fits, "VM must fit: {:?}", vm.resources);
        let sa = synthesize_sa(&SaConfig::paper());
        assert!(sa.fits, "SA must fit: {:?}", sa.resources);
        // and they should use a meaningful chunk of the device
        assert!(vm.utilization > 0.3, "VM util {}", vm.utilization);
        assert!(sa.utilization > 0.3, "SA util {}", sa.utilization);
    }

    #[test]
    fn five_units_would_not_fit() {
        // §IV-C1: "we are limited to four GEMM units by the resource
        // constraints of the target device"
        let mut cfg = VmConfig::paper();
        cfg.units = 8;
        let rep = synthesize_vm(&cfg);
        assert!(!rep.fits, "8 units must exceed the device: {:?}", rep.resources);
    }

    #[test]
    fn sa_sizes_scale_resources() {
        let r4 = sa_resources(&SaConfig::with_dim(4));
        let r8 = sa_resources(&SaConfig::with_dim(8));
        let r16 = sa_resources(&SaConfig::with_dim(16));
        assert!(r4.dsps < r8.dsps && r8.dsps < r16.dsps);
        assert!(r4.luts < r8.luts && r8.luts < r16.luts);
        // 8x8 "leaves much of the fabric unused" (§IV-E3): compute
        // fabric (DSP/LUT) utilization stays low; BRAM is shared
        let budget = Resources::zynq7020();
        let dsp_util = r8.dsps as f64 / budget.dsps as f64;
        let lut_util = r8.luts as f64 / budget.luts as f64;
        assert!(dsp_util < 0.5, "8x8 dsp util {dsp_util}");
        assert!(lut_util < 0.5, "8x8 lut util {lut_util}");
        assert!(synthesize_sa(&SaConfig::with_dim(16)).fits);
    }

    #[test]
    fn synthesis_time_scales_with_utilization() {
        let small = synthesis_time(&sa_resources(&SaConfig::with_dim(4)));
        let big = synthesis_time(&sa_resources(&SaConfig::with_dim(16)));
        assert!(big > small);
        // anchored in the tens-of-minutes range
        let minutes = big.as_secs_f64() / 60.0;
        assert!((15.0..=60.0).contains(&minutes), "{minutes} min");
    }

    #[test]
    fn resnet_variant_trades_brams_not_totals() {
        let base = vm_resources(&VmConfig::paper());
        let variant = vm_resources(&VmConfig::resnet_variant());
        // same compute resources, BRAM redistributed
        assert_eq!(base.dsps, variant.dsps);
        assert!(variant.fits_in(&Resources::zynq7020()));
    }

    #[test]
    fn one_paper_design_per_fabric() {
        // The serving-time reality the elastic planner enforces: one
        // paper design consumes most of the DSP budget, so the fabric
        // holds the SA *or* the VM, never both (and never two SAs).
        let sa = sa_resources(&SaConfig::paper());
        let vm = vm_resources(&VmConfig::paper());
        let budget = Resources::zynq7020();
        assert!(sa.fits_in(&budget) && vm.fits_in(&budget));
        assert!(!sa.add(&vm).fits_in(&budget), "SA+VM must not co-reside");
        assert!(!sa.scaled(2).fits_in(&budget), "2x SA must not fit");
        assert!(!vm.scaled(2).fits_in(&budget), "2x VM must not fit");
        // The same holds with non-paper designs from the registered
        // DSE candidate space: whatever frontier pair the campaign
        // hands the planner, every composition it enumerates must fit
        // the fabric — the feasibility gate, end to end.
        let space = crate::dse::design_space();
        for sa_point in space.iter().filter(|p| p.sa_config().is_some()) {
            for vm_point in space.iter().filter(|p| p.vm_config().is_some()) {
                let planner = crate::elastic::CompositionPlanner::with_designs(
                    budget,
                    &sa_point.sa_config().unwrap(),
                    &vm_point.vm_config().unwrap(),
                );
                let comps = planner.enumerate(2);
                assert!(!comps.is_empty());
                for c in &comps {
                    assert!(
                        planner.composition_resources(c).fits_in(&budget),
                        "{c} with {}/{} exceeds the fabric",
                        sa_point.key(),
                        vm_point.key()
                    );
                }
                // every registered design is individually servable
                assert!(comps.iter().any(|c| c.sa == 1));
                assert!(comps.iter().any(|c| c.vm == 1));
            }
        }
    }

    #[test]
    fn reconfig_time_sits_between_sync_and_synthesis() {
        let r = sa_resources(&SaConfig::paper());
        let t = reconfig_time(&r);
        // tens of milliseconds: far above an offload sync (~150 us),
        // far below a synthesis run (tens of minutes)
        assert!(t >= SimTime::ms(10), "{t}");
        assert!(t <= SimTime::ms(100), "{t}");
        assert!(t < synthesis_time(&r));
        // denser designs reprogram slower
        let small = reconfig_time(&sa_resources(&SaConfig::with_dim(4)));
        assert!(small < t, "{small} vs {t}");
    }
}
