//! Bounded FIFO channels (the `sc_fifo` analogue) with occupancy stats.

use std::collections::VecDeque;

use super::stats::FifoStats;
use super::time::SimTime;

/// A capacity-bounded FIFO. Push/pop are non-blocking; blocking
/// semantics are built by the kernel's wake notifications
/// ([`super::kernel::Wake`]), mirroring how SystemC processes sleep on
/// `data_written`/`data_read` events.
#[derive(Debug)]
pub struct Fifo<T> {
    items: VecDeque<T>,
    capacity: usize,
    stats: FifoStats,
}

impl<T> Fifo<T> {
    /// A FIFO holding at most `capacity` items (must be non-zero).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "zero-capacity fifo");
        Fifo {
            items: VecDeque::with_capacity(capacity),
            capacity,
            stats: FifoStats::default(),
        }
    }

    /// The bound this FIFO was created with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// True when at capacity (pushes will be rejected).
    pub fn is_full(&self) -> bool {
        self.items.len() == self.capacity
    }

    /// Push at simulated time `now`; returns false when full.
    pub fn push(&mut self, item: T, now: SimTime) -> bool {
        if self.is_full() {
            self.stats.push_rejects += 1;
            return false;
        }
        self.items.push_back(item);
        self.stats.pushes += 1;
        self.stats.high_water = self.stats.high_water.max(self.items.len());
        self.stats.last_activity = now;
        true
    }

    /// Pop at simulated time `now`; `None` when empty.
    pub fn pop(&mut self, now: SimTime) -> Option<T> {
        let item = self.items.pop_front();
        if item.is_some() {
            self.stats.pops += 1;
            self.stats.last_activity = now;
        } else {
            self.stats.pop_misses += 1;
        }
        item
    }

    /// The front item without popping it.
    pub fn peek(&self) -> Option<&T> {
        self.items.front()
    }

    /// Occupancy statistics accumulated over this FIFO's lifetime.
    pub fn stats(&self) -> &FifoStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_pop_order() {
        let mut f = Fifo::new(4);
        for v in 0..4 {
            assert!(f.push(v, SimTime::ZERO));
        }
        assert!(f.is_full());
        assert!(!f.push(9, SimTime::ZERO)); // rejected
        assert_eq!(f.pop(SimTime::ZERO), Some(0));
        assert_eq!(f.pop(SimTime::ZERO), Some(1));
        assert!(f.push(9, SimTime::ZERO));
        assert_eq!(f.pop(SimTime::ZERO), Some(2));
        assert_eq!(f.pop(SimTime::ZERO), Some(3));
        assert_eq!(f.pop(SimTime::ZERO), Some(9));
        assert!(f.pop(SimTime::ZERO).is_none());
    }

    #[test]
    fn stats_track_activity() {
        let mut f = Fifo::new(2);
        assert!(f.push(1, SimTime::ns(1)));
        assert!(f.push(2, SimTime::ns(2)));
        assert!(!f.push(3, SimTime::ns(3)));
        f.pop(SimTime::ns(4));
        assert_eq!(f.stats().pushes, 2);
        assert_eq!(f.stats().push_rejects, 1);
        assert_eq!(f.stats().pops, 1);
        assert_eq!(f.stats().high_water, 2);
        assert_eq!(f.stats().last_activity, SimTime::ns(4));
    }

    #[test]
    #[should_panic]
    fn zero_capacity_panics() {
        let _ = Fifo::<u8>::new(0);
    }
}
