//! Simulated time: picosecond-resolution counters and clock domains.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in simulated time, in integer picoseconds.
///
/// Picoseconds give headroom for multi-GHz clock domains while a u64
/// still spans ~213 days of simulated time — far beyond any inference.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    /// The start of simulated time.
    pub const ZERO: SimTime = SimTime(0);
    /// The far-future sentinel (used e.g. as the effective deadline of
    /// a request without an SLO under deadline-ordered scheduling).
    pub const MAX: SimTime = SimTime(u64::MAX);

    /// A duration of `v` picoseconds.
    pub fn ps(v: u64) -> Self {
        SimTime(v)
    }
    /// A duration of `v` nanoseconds.
    pub fn ns(v: u64) -> Self {
        SimTime(v * 1_000)
    }
    /// A duration of `v` microseconds.
    pub fn us(v: u64) -> Self {
        SimTime(v * 1_000_000)
    }
    /// A duration of `v` milliseconds.
    pub fn ms(v: u64) -> Self {
        SimTime(v * 1_000_000_000)
    }

    /// This time as integer picoseconds (the underlying count).
    pub fn as_ps(self) -> u64 {
        self.0
    }
    /// This time in nanoseconds, as a float.
    pub fn as_ns_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }
    /// This time in microseconds, as a float.
    pub fn as_us_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    /// This time in milliseconds, as a float.
    pub fn as_ms_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }
    /// This time in seconds, as a float.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e12
    }

    /// `self - rhs`, clamped at zero instead of underflowing.
    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 - rhs.0)
    }
}

impl fmt::Debug for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1_000_000_000 {
            write!(f, "{:.3}ms", self.as_ms_f64())
        } else if self.0 >= 1_000_000 {
            write!(f, "{:.3}us", self.as_us_f64())
        } else if self.0 >= 1_000 {
            write!(f, "{:.3}ns", self.as_ns_f64())
        } else {
            write!(f, "{}ps", self.0)
        }
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

/// A clock domain: converts between cycle counts and [`SimTime`].
///
/// Every accelerator component in [`crate::accel`] annotates its costs
/// in *cycles* of its domain clock; the kernel works in time so that
/// multi-clock designs (e.g. fabric @100MHz, AXI @133MHz) compose.
#[derive(Clone, Copy, Debug)]
pub struct Clock {
    /// Cycle period in picoseconds.
    pub period_ps: u64,
}

impl Clock {
    /// A clock domain running at `mhz` megahertz.
    pub fn from_mhz(mhz: f64) -> Self {
        assert!(mhz > 0.0);
        Clock {
            period_ps: (1e6 / mhz).round() as u64,
        }
    }

    /// The domain frequency in megahertz.
    pub fn freq_mhz(&self) -> f64 {
        1e6 / self.period_ps as f64
    }

    /// Duration of `n` cycles.
    pub fn cycles(&self, n: u64) -> SimTime {
        SimTime(self.period_ps * n)
    }

    /// Number of whole cycles elapsed at time `t` (floor).
    pub fn cycles_at(&self, t: SimTime) -> u64 {
        t.0 / self.period_ps
    }

    /// Cycles needed to cover duration `t` (ceil).
    pub fn cycles_for(&self, t: SimTime) -> u64 {
        t.0.div_ceil(self.period_ps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_units() {
        assert_eq!(SimTime::ns(1).as_ps(), 1_000);
        assert_eq!(SimTime::us(2).as_ps(), 2_000_000);
        assert_eq!(SimTime::ms(3).as_ps(), 3_000_000_000);
        assert_eq!(SimTime::ms(1).as_ms_f64(), 1.0);
    }

    #[test]
    fn simtime_arith() {
        let a = SimTime::ns(5) + SimTime::ns(7);
        assert_eq!(a, SimTime::ns(12));
        assert_eq!(a - SimTime::ns(2), SimTime::ns(10));
        assert_eq!(SimTime::ns(1).saturating_sub(SimTime::ns(9)), SimTime::ZERO);
    }

    #[test]
    fn clock_conversion() {
        let c = Clock::from_mhz(100.0); // 10ns period
        assert_eq!(c.period_ps, 10_000);
        assert_eq!(c.cycles(3), SimTime::ns(30));
        assert_eq!(c.cycles_at(SimTime::ns(35)), 3);
        assert_eq!(c.cycles_for(SimTime::ns(35)), 4);
        assert!((c.freq_mhz() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn simtime_display() {
        assert_eq!(format!("{}", SimTime::ns(30)), "30.000ns");
        assert_eq!(format!("{}", SimTime::ps(5)), "5ps");
        assert_eq!(format!("{}", SimTime::ms(2)), "2.000ms");
    }
}
