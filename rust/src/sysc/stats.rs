//! Per-component statistics — the §III-C simulation metrics: cycles
//! spent per component, utilization, queue occupancy, byte traffic.

use super::time::SimTime;

/// Busy/idle accounting for a module.
///
/// Components call [`ModuleStats::busy_for`] whenever they consume
/// simulated time doing work; utilization is busy-time over the window
/// between first and last activity.
#[derive(Debug, Clone, Default)]
pub struct ModuleStats {
    /// Total simulated time the component spent doing work.
    pub busy: SimTime,
    /// Number of transactions processed.
    pub transactions: u64,
    /// Bytes moved through the component (for bandwidth metrics).
    pub bytes: u64,
    /// Work cycles in the component's own clock domain.
    pub cycles: u64,
    /// First activity timestamp (start of the utilization window).
    pub first_activity: Option<SimTime>,
    /// Last activity timestamp (end of the utilization window).
    pub last_activity: SimTime,
    /// Cycles the component wanted to work but was starved/blocked.
    pub stall_cycles: u64,
}

impl ModuleStats {
    /// Charge `dur` of busy time (and `cycles` work cycles) starting
    /// at `start`, extending the activity window.
    pub fn busy_for(&mut self, start: SimTime, dur: SimTime, cycles: u64) {
        self.busy += dur;
        self.cycles += cycles;
        if self.first_activity.is_none() {
            self.first_activity = Some(start);
        }
        self.last_activity = self.last_activity.max(start + dur);
    }

    /// Count one transaction moving `bytes` through the component.
    pub fn add_transaction(&mut self, bytes: u64) {
        self.transactions += 1;
        self.bytes += bytes;
    }

    /// Count cycles lost to starvation/backpressure.
    pub fn add_stall(&mut self, cycles: u64) {
        self.stall_cycles += cycles;
    }

    /// Busy fraction of the activity window, in [0, 1].
    pub fn utilization(&self) -> f64 {
        match self.first_activity {
            Some(first) if self.last_activity > first => {
                self.busy.as_ps() as f64 / (self.last_activity - first).as_ps() as f64
            }
            _ => 0.0,
        }
    }

    /// Effective bandwidth over the activity window, bytes/second.
    pub fn bandwidth_bps(&self) -> f64 {
        match self.first_activity {
            Some(first) if self.last_activity > first => {
                self.bytes as f64 / (self.last_activity - first).as_secs_f64()
            }
            _ => 0.0,
        }
    }
}

/// Occupancy statistics of a [`super::fifo::Fifo`].
#[derive(Debug, Clone, Default)]
pub struct FifoStats {
    /// Successful pushes.
    pub pushes: u64,
    /// Successful pops.
    pub pops: u64,
    /// Pushes rejected because the FIFO was full.
    pub push_rejects: u64,
    /// Pops attempted on an empty FIFO.
    pub pop_misses: u64,
    /// Highest occupancy ever observed.
    pub high_water: usize,
    /// Timestamp of the most recent push or pop.
    pub last_activity: SimTime,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_window() {
        let mut s = ModuleStats::default();
        s.busy_for(SimTime::ns(0), SimTime::ns(10), 1);
        s.busy_for(SimTime::ns(30), SimTime::ns(10), 1);
        // busy 20ns over a 40ns window
        assert!((s.utilization() - 0.5).abs() < 1e-9);
        assert_eq!(s.cycles, 2);
    }

    #[test]
    fn bandwidth() {
        let mut s = ModuleStats::default();
        s.busy_for(SimTime::ZERO, SimTime::us(1), 100);
        s.add_transaction(1000);
        // 1000 bytes over 1us = 1 GB/s
        assert!((s.bandwidth_bps() - 1e9).abs() / 1e9 < 1e-6);
    }

    #[test]
    fn idle_module_reports_zero() {
        let s = ModuleStats::default();
        assert_eq!(s.utilization(), 0.0);
        assert_eq!(s.bandwidth_bps(), 0.0);
    }
}
