//! Lightweight event tracing (the waveform-dump analogue).
//!
//! Disabled by default — the trace is on the simulation hot path, so a
//! disabled trace must cost one branch. When enabled it records
//! `(time, module, label)` tuples, capped to avoid unbounded growth.

use super::time::SimTime;

/// One recorded simulation event.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// When it happened.
    pub time: SimTime,
    /// The module that recorded it.
    pub module: String,
    /// Free-form event label.
    pub label: String,
}

/// A bounded event recorder attached to a simulation run.
#[derive(Debug)]
pub struct Trace {
    enabled: bool,
    cap: usize,
    /// Recorded entries, in record order (up to the cap).
    pub entries: Vec<TraceEntry>,
    dropped: u64,
}

impl Trace {
    /// A disabled trace: records nothing, costs one branch per call.
    pub fn disabled() -> Self {
        Trace {
            enabled: false,
            cap: 0,
            entries: Vec::new(),
            dropped: 0,
        }
    }

    /// An enabled trace keeping at most `cap` entries (later events
    /// are counted as dropped).
    pub fn enabled(cap: usize) -> Self {
        Trace {
            enabled: true,
            cap,
            entries: Vec::with_capacity(cap.min(4096)),
            dropped: 0,
        }
    }

    /// Whether this trace records anything.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record one event. `label` is a closure so a disabled trace
    /// never pays for formatting.
    #[inline]
    pub fn record(&mut self, time: SimTime, module: &str, label: impl FnOnce() -> String) {
        if !self.enabled {
            return;
        }
        if self.entries.len() >= self.cap {
            self.dropped += 1;
            return;
        }
        self.entries.push(TraceEntry {
            time,
            module: module.to_string(),
            label: label(),
        });
    }

    /// Events dropped after the cap filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Render as a text "waveform" listing, one event per line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for e in &self.entries {
            out.push_str(&format!("{:>14}  {:<20} {}\n", format!("{}", e.time), e.module, e.label));
        }
        if self.dropped > 0 {
            out.push_str(&format!("... {} entries dropped (cap {})\n", self.dropped, self.cap));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::disabled();
        t.record(SimTime::ns(1), "m", || "x".into());
        assert!(t.entries.is_empty());
    }

    #[test]
    fn enabled_trace_caps() {
        let mut t = Trace::enabled(2);
        for i in 0..5 {
            t.record(SimTime::ns(i), "m", || format!("e{i}"));
        }
        assert_eq!(t.entries.len(), 2);
        assert_eq!(t.dropped(), 3);
        let s = t.render();
        assert!(s.contains("e0") && s.contains("dropped"));
    }
}
