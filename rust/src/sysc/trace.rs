//! Lightweight event tracing (the waveform-dump analogue).
//!
//! Disabled by default — the trace is on the simulation hot path, so a
//! disabled trace must cost one branch. When enabled it records
//! `(time, module, label)` tuples, capped to avoid unbounded growth.

use super::time::SimTime;

/// One recorded simulation event.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// When it happened.
    pub time: SimTime,
    /// The module that recorded it.
    pub module: String,
    /// Free-form event label.
    pub label: String,
}

/// A bounded event recorder attached to a simulation run.
#[derive(Debug)]
pub struct Trace {
    enabled: bool,
    cap: usize,
    /// Recorded entries, in record order (up to the cap).
    pub entries: Vec<TraceEntry>,
    dropped: u64,
}

impl Trace {
    /// A disabled trace: records nothing, costs one branch per call.
    pub fn disabled() -> Self {
        Trace {
            enabled: false,
            cap: 0,
            entries: Vec::new(),
            dropped: 0,
        }
    }

    /// An enabled trace keeping at most `cap` entries (later events
    /// are counted as dropped).
    pub fn enabled(cap: usize) -> Self {
        Trace {
            enabled: true,
            cap,
            entries: Vec::with_capacity(cap.min(4096)),
            dropped: 0,
        }
    }

    /// Whether this trace records anything.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record one event. `label` is a closure so a disabled trace
    /// never pays for formatting.
    #[inline]
    pub fn record(&mut self, time: SimTime, module: &str, label: impl FnOnce() -> String) {
        if !self.enabled {
            return;
        }
        if self.entries.len() >= self.cap {
            self.dropped += 1;
            return;
        }
        self.entries.push(TraceEntry {
            time,
            module: module.to_string(),
            label: label(),
        });
    }

    /// Events dropped after the cap filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Render as a text "waveform" listing, one event per line.
    ///
    /// The module column is sized to the longest module name (long
    /// names used to break alignment), and formatting goes through a
    /// single reused buffer instead of allocating per line.
    pub fn render(&self) -> String {
        use std::fmt::Write;

        let width = self
            .entries
            .iter()
            .map(|e| e.module.len())
            .max()
            .unwrap_or(0)
            .max(20);
        let mut out = String::new();
        let mut tbuf = String::new();
        for e in &self.entries {
            tbuf.clear();
            let _ = write!(tbuf, "{}", e.time);
            let _ = writeln!(out, "{tbuf:>14}  {:<width$} {}", e.module, e.label);
        }
        if self.dropped > 0 {
            let _ = writeln!(out, "... {} entries dropped (cap {})", self.dropped, self.cap);
        }
        out
    }

    /// Export as Chrome trace-event JSON (one track per module, one
    /// instant per entry), reusing the serving exporter in
    /// [`crate::obs::export`]. Load in Perfetto or `chrome://tracing`.
    pub fn to_chrome_json(&self) -> String {
        crate::obs::export::sim_trace_chrome_json(&self.entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::disabled();
        t.record(SimTime::ns(1), "m", || "x".into());
        assert!(t.entries.is_empty());
    }

    #[test]
    fn enabled_trace_caps() {
        let mut t = Trace::enabled(2);
        for i in 0..5 {
            t.record(SimTime::ns(i), "m", || format!("e{i}"));
        }
        assert_eq!(t.entries.len(), 2);
        assert_eq!(t.dropped(), 3);
        let s = t.render();
        assert!(s.contains("e0") && s.contains("dropped"));
    }

    #[test]
    fn render_aligns_long_module_names() {
        let mut t = Trace::enabled(4);
        t.record(SimTime::ns(1), "m", || "short".into());
        t.record(SimTime::ns(2), "a_very_long_module_name.sub", || "long".into());
        let lines: Vec<&str> = t.render().lines().collect();
        // the label column starts at the same offset on every line
        let col = |l: &str| l.rfind(' ').unwrap();
        assert_eq!(col(lines[0]), col(lines[1]), "misaligned:\n{:?}", lines);
    }

    #[test]
    fn chrome_json_export_validates() {
        let mut t = Trace::enabled(8);
        t.record(SimTime::ns(10), "dma", || "load tile".into());
        t.record(SimTime::ns(20), "pe_grid", || "mac burst".into());
        let json = t.to_chrome_json();
        let check = crate::obs::export::validate_chrome_trace(&json).expect("valid");
        assert_eq!(check.instants, 2);
        assert_eq!(check.tracks, 2);
    }
}
