//! The discrete-event simulation kernel: event wheel + module dispatch.
//!
//! Semantics follow the SystemC evaluate/update model at transaction
//! granularity: events scheduled for the same timestamp are delivered
//! in schedule order (deterministic delta-cycles); modules react to
//! delivered payloads and schedule further events through [`Ctx`].
//!
//! Messages are a design-defined enum `M` (one per accelerator design),
//! which keeps dispatch monomorphic and allocation-free on the hot path
//! — this kernel is itself a §Perf target (see `benches/hotpath.rs`).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::fifo::Fifo;
use super::time::SimTime;
use super::trace::Trace;

/// Handle of a module registered with a [`Simulator`].
pub type ModuleId = usize;
/// Handle of a FIFO created on a [`Simulator`].
pub type FifoId = usize;

/// A scheduled event: deliver `payload` to `target` at `time`.
#[derive(Debug, Clone)]
pub struct Event<M> {
    /// Delivery time.
    pub time: SimTime,
    /// Receiving module.
    pub target: ModuleId,
    /// The design-defined message delivered.
    pub payload: M,
}

#[derive(Debug)]
struct QEntry<M> {
    time: SimTime,
    seq: u64,
    target: ModuleId,
    payload: M,
}

impl<M> PartialEq for QEntry<M> {
    fn eq(&self, o: &Self) -> bool {
        self.time == o.time && self.seq == o.seq
    }
}
impl<M> Eq for QEntry<M> {}
impl<M> PartialOrd for QEntry<M> {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl<M> Ord for QEntry<M> {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(o.time, o.seq))
    }
}

/// A simulated hardware module (SystemC `sc_module` analogue).
pub trait Module<M> {
    /// Human-readable module name (reports, traces).
    fn name(&self) -> &str;
    /// React to a delivered event. All further activity is expressed by
    /// scheduling events / touching FIFOs through `ctx`.
    fn handle(&mut self, payload: M, ctx: &mut Ctx<'_, M>);
    /// Per-module statistics for end-of-run reporting, if tracked.
    fn stats(&self) -> Option<&super::stats::ModuleStats> {
        None
    }
}

/// Wake notification attached to a FIFO endpoint: when the FIFO gains
/// an item (consumer side) or frees a slot (producer side), `payload`
/// is scheduled for `module` in the next delta.
#[derive(Debug, Clone)]
pub struct Wake<M> {
    /// The module to wake.
    pub module: ModuleId,
    /// The message delivered by the wake.
    pub payload: M,
}

struct FifoSlot<M> {
    fifo: Fifo<M>,
    on_push: Option<Wake<M>>,
    on_pop: Option<Wake<M>>,
}

/// The mutable simulation context handed to module handlers.
pub struct Ctx<'a, M> {
    now: SimTime,
    seq: &'a mut u64,
    queue: &'a mut BinaryHeap<Reverse<QEntry<M>>>,
    fifos: &'a mut Vec<FifoSlot<M>>,
    /// The run's event trace (modules record through it directly).
    pub trace: &'a mut Trace,
    stop: &'a mut bool,
    current: ModuleId,
}

impl<M: Clone> Ctx<'_, M> {
    /// The current simulated time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Id of the module currently handling an event.
    pub fn current_module(&self) -> ModuleId {
        self.current
    }

    /// Schedule `payload` for `target` after `delay`.
    pub fn schedule(&mut self, delay: SimTime, target: ModuleId, payload: M) {
        let e = QEntry {
            time: self.now + delay,
            seq: *self.seq,
            target,
            payload,
        };
        *self.seq += 1;
        self.queue.push(Reverse(e));
    }

    /// Schedule for the current module (a self-wakeup).
    pub fn schedule_self(&mut self, delay: SimTime, payload: M) {
        let me = self.current;
        self.schedule(delay, me, payload);
    }

    /// Try to push into a FIFO. On success the consumer-side wake (if
    /// any) fires in the next delta. Returns `false` when full — the
    /// producer must retry on its `on_pop` wake.
    pub fn fifo_push(&mut self, fid: FifoId, item: M) -> bool {
        let now = self.now;
        let slot = &mut self.fifos[fid];
        if !slot.fifo.push(item, now) {
            return false;
        }
        if let Some(w) = slot.on_push.clone() {
            self.schedule(SimTime::ZERO, w.module, w.payload);
        }
        true
    }

    /// Pop from a FIFO; fires the producer-side wake when a slot frees.
    pub fn fifo_pop(&mut self, fid: FifoId) -> Option<M> {
        let now = self.now;
        let slot = &mut self.fifos[fid];
        let item = slot.fifo.pop(now)?;
        if let Some(w) = slot.on_pop.clone() {
            self.schedule(SimTime::ZERO, w.module, w.payload);
        }
        Some(item)
    }

    /// Items currently queued in a FIFO.
    pub fn fifo_len(&self, fid: FifoId) -> usize {
        self.fifos[fid].fifo.len()
    }

    /// True when the FIFO is at capacity.
    pub fn fifo_is_full(&self, fid: FifoId) -> bool {
        self.fifos[fid].fifo.is_full()
    }

    /// True when the FIFO holds nothing.
    pub fn fifo_is_empty(&self, fid: FifoId) -> bool {
        self.fifos[fid].fifo.is_empty()
    }

    /// Request simulation stop after the current delta.
    pub fn stop(&mut self) {
        *self.stop = true;
    }
}

/// The simulator: owns modules, FIFOs, the event queue and the clock.
pub struct Simulator<M> {
    now: SimTime,
    seq: u64,
    queue: BinaryHeap<Reverse<QEntry<M>>>,
    modules: Vec<Option<Box<dyn Module<M>>>>,
    names: Vec<String>,
    fifos: Vec<FifoSlot<M>>,
    /// Event trace of this run ([`Trace::disabled`] by default).
    pub trace: Trace,
    stop: bool,
    events_dispatched: u64,
}

impl<M: Clone> Default for Simulator<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M: Clone> Simulator<M> {
    /// An empty simulator (no modules, trace disabled).
    pub fn new() -> Self {
        Simulator {
            now: SimTime::ZERO,
            seq: 0,
            queue: BinaryHeap::new(),
            modules: Vec::new(),
            names: Vec::new(),
            fifos: Vec::new(),
            trace: Trace::disabled(),
            stop: false,
            events_dispatched: 0,
        }
    }

    /// The same simulator with an event trace installed.
    pub fn with_trace(mut self, trace: Trace) -> Self {
        self.trace = trace;
        self
    }

    /// Register a module, returning its dispatch handle.
    pub fn add_module(&mut self, m: Box<dyn Module<M>>) -> ModuleId {
        self.names.push(m.name().to_string());
        self.modules.push(Some(m));
        self.modules.len() - 1
    }

    /// Create a bounded FIFO with optional push/pop wakes.
    pub fn add_fifo(
        &mut self,
        capacity: usize,
        on_push: Option<Wake<M>>,
        on_pop: Option<Wake<M>>,
    ) -> FifoId {
        self.fifos.push(FifoSlot {
            fifo: Fifo::new(capacity),
            on_push,
            on_pop,
        });
        self.fifos.len() - 1
    }

    /// Late-bind a wake (modules often get their ids after FIFO setup).
    pub fn set_fifo_wakes(
        &mut self,
        fid: FifoId,
        on_push: Option<Wake<M>>,
        on_pop: Option<Wake<M>>,
    ) {
        self.fifos[fid].on_push = on_push;
        self.fifos[fid].on_pop = on_pop;
    }

    /// Schedule `payload` for `target` at absolute time `time`.
    pub fn schedule(&mut self, time: SimTime, target: ModuleId, payload: M) {
        let e = QEntry {
            time,
            seq: self.seq,
            target,
            payload,
        };
        self.seq += 1;
        self.queue.push(Reverse(e));
    }

    /// The current simulated time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Events dispatched over this simulator's lifetime.
    pub fn events_dispatched(&self) -> u64 {
        self.events_dispatched
    }

    /// Name a module registered itself under.
    pub fn module_name(&self, id: ModuleId) -> &str {
        &self.names[id]
    }

    /// Occupancy statistics of a FIFO.
    pub fn fifo_stats(&self, fid: FifoId) -> &super::stats::FifoStats {
        self.fifos[fid].fifo.stats()
    }

    /// Borrow a module back (e.g. to read results after `run`).
    pub fn module(&self, id: ModuleId) -> &dyn Module<M> {
        self.modules[id].as_deref().expect("module in flight")
    }

    /// Mutably borrow a module back.
    pub fn module_mut(&mut self, id: ModuleId) -> &mut (dyn Module<M> + '_) {
        self.modules[id].as_deref_mut().expect("module in flight")
    }

    /// Run until the queue drains, `stop()` is called, or `limit`
    /// events have been dispatched. Returns the final simulated time.
    pub fn run_with_limit(&mut self, limit: u64) -> SimTime {
        let mut dispatched = 0u64;
        while let Some(Reverse(e)) = self.queue.pop() {
            debug_assert!(e.time >= self.now, "time must be monotonic");
            self.now = e.time;
            let mut module = self.modules[e.target].take().expect("re-entrant dispatch");
            {
                let mut ctx = Ctx {
                    now: self.now,
                    seq: &mut self.seq,
                    queue: &mut self.queue,
                    fifos: &mut self.fifos,
                    trace: &mut self.trace,
                    stop: &mut self.stop,
                    current: e.target,
                };
                module.handle(e.payload, &mut ctx);
            }
            self.modules[e.target] = Some(module);
            dispatched += 1;
            self.events_dispatched += 1;
            if self.stop || dispatched >= limit {
                break;
            }
        }
        self.now
    }

    /// Run until the queue drains or `stop()` is called.
    pub fn run(&mut self) -> SimTime {
        self.run_with_limit(u64::MAX)
    }

    /// End-of-run utilization report over all stat-tracking modules.
    pub fn report(&self) -> Vec<(String, super::stats::ModuleStats)> {
        self.modules
            .iter()
            .enumerate()
            .filter_map(|(i, m)| {
                let m = m.as_deref()?;
                m.stats().map(|s| (self.names[i].clone(), s.clone()))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Debug, PartialEq)]
    enum Msg {
        Ping(u32),
        WakeConsumer,
        Produce,
    }

    struct Echo {
        got: Vec<(SimTime, u32)>,
    }
    impl Module<Msg> for Echo {
        fn name(&self) -> &str {
            "echo"
        }
        fn handle(&mut self, p: Msg, ctx: &mut Ctx<'_, Msg>) {
            if let Msg::Ping(v) = p {
                self.got.push((ctx.now(), v));
                if v < 3 {
                    ctx.schedule_self(SimTime::ns(10), Msg::Ping(v + 1));
                }
            }
        }
    }

    #[test]
    fn events_dispatch_in_time_order() {
        let mut sim = Simulator::new();
        let id = sim.add_module(Box::new(Echo { got: vec![] }));
        sim.schedule(SimTime::ns(5), id, Msg::Ping(1));
        sim.schedule(SimTime::ns(1), id, Msg::Ping(0));
        let end = sim.run();
        // Ping(0)@1ns chains 1@11, 2@21, 3@31; Ping(1)@5ns chains 2@15, 3@25.
        assert_eq!(end, SimTime::ns(31));
        let echo = sim.modules[id].as_ref().unwrap();
        let _ = echo;
    }

    #[test]
    fn same_time_events_fifo_order() {
        struct Rec {
            seen: Vec<u32>,
        }
        impl Module<Msg> for Rec {
            fn name(&self) -> &str {
                "rec"
            }
            fn handle(&mut self, p: Msg, _ctx: &mut Ctx<'_, Msg>) {
                if let Msg::Ping(v) = p {
                    self.seen.push(v);
                }
            }
        }
        let mut sim = Simulator::new();
        let id = sim.add_module(Box::new(Rec { seen: vec![] }));
        for v in 0..10 {
            sim.schedule(SimTime::ns(7), id, Msg::Ping(v));
        }
        sim.run();
        // deterministic delta ordering = schedule order
        let any = sim.module(id);
        let _ = any;
        assert_eq!(sim.events_dispatched(), 10);
    }

    struct Producer {
        fid: FifoId,
        remaining: u32,
        blocked: u32,
    }
    impl Module<Msg> for Producer {
        fn name(&self) -> &str {
            "producer"
        }
        fn handle(&mut self, _p: Msg, ctx: &mut Ctx<'_, Msg>) {
            while self.remaining > 0 {
                if ctx.fifo_push(self.fid, Msg::Ping(self.remaining)) {
                    self.remaining -= 1;
                } else {
                    self.blocked += 1;
                    return; // retry on on_pop wake
                }
            }
        }
    }

    struct Consumer {
        fid: FifoId,
        consumed: u32,
        delay: SimTime,
    }
    impl Module<Msg> for Consumer {
        fn name(&self) -> &str {
            "consumer"
        }
        fn handle(&mut self, _p: Msg, ctx: &mut Ctx<'_, Msg>) {
            // pop one item per wake, with a processing delay
            if ctx.fifo_pop(self.fid).is_some() {
                self.consumed += 1;
                let d = self.delay;
                ctx.schedule_self(d, Msg::WakeConsumer);
            }
        }
    }

    #[test]
    fn fifo_backpressure_blocks_and_wakes_producer() {
        let mut sim: Simulator<Msg> = Simulator::new();
        let fid = sim.add_fifo(2, None, None);
        let pid = sim.add_module(Box::new(Producer {
            fid,
            remaining: 10,
            blocked: 0,
        }));
        let cid = sim.add_module(Box::new(Consumer {
            fid,
            consumed: 0,
            delay: SimTime::ns(10),
        }));
        sim.set_fifo_wakes(
            fid,
            Some(Wake {
                module: cid,
                payload: Msg::WakeConsumer,
            }),
            Some(Wake {
                module: pid,
                payload: Msg::Produce,
            }),
        );
        sim.schedule(SimTime::ZERO, pid, Msg::Produce);
        sim.run();
        // all items flowed through the capacity-2 fifo
        let consumed = {
            let c = sim.modules[cid].as_ref().unwrap();
            // downcast via stats-free trick: re-box
            let _ = c;
            // use fifo stats instead
            sim.fifo_stats(fid).pushes
        };
        assert_eq!(consumed, 10);
        assert_eq!(sim.fifo_stats(fid).pops, 10);
        assert!(sim.fifo_stats(fid).high_water <= 2);
    }

    #[test]
    fn stop_halts_simulation() {
        struct Stopper;
        impl Module<Msg> for Stopper {
            fn name(&self) -> &str {
                "stopper"
            }
            fn handle(&mut self, _p: Msg, ctx: &mut Ctx<'_, Msg>) {
                ctx.stop();
            }
        }
        let mut sim = Simulator::new();
        let id = sim.add_module(Box::new(Stopper));
        sim.schedule(SimTime::ns(1), id, Msg::Produce);
        sim.schedule(SimTime::ns(2), id, Msg::Produce);
        sim.run();
        assert_eq!(sim.events_dispatched(), 1);
        assert_eq!(sim.now(), SimTime::ns(1));
    }
}
