//! `sysc` — a SystemC-flavoured discrete-event / transaction-level
//! simulation kernel, written from scratch in Rust.
//!
//! This is the substrate the paper takes from SystemC 2.3 (IEEE 1666):
//! SECDA models accelerator designs at *transaction level* — components
//! exchange tile-sized transactions through bounded FIFOs, with cycle
//! costs annotated per component — instead of register-transfer level.
//! The kernel provides:
//!
//! * [`time::SimTime`] — picosecond-resolution simulated time, plus
//!   [`time::Clock`] for cycle↔time conversion at a component frequency.
//! * [`kernel::Simulator`] — the event wheel: schedule, delta-cycles,
//!   run-to-quiescence, per-module dispatch.
//! * [`fifo::Fifo`] — bounded FIFOs with producer/consumer wake
//!   notifications and occupancy statistics (the `sc_fifo` analogue).
//! * [`stats::ModuleStats`] — busy/idle accounting, transaction and
//!   byte counters; the numbers §III-C says simulation must surface
//!   (clock cycles per component, utilization, BRAM bandwidth, ...).
//! * [`trace::Trace`] — lightweight event tracing for debugging and for
//!   the waveform-ish dumps used in tests.
//!
//! The accelerator models in [`crate::accel`] are built exclusively on
//! this module, mirroring how the paper's designs are built on SystemC.

pub mod fifo;
pub mod kernel;
pub mod stats;
pub mod time;
pub mod trace;

pub use fifo::Fifo;
pub use kernel::{Ctx, Event, FifoId, Module, ModuleId, Simulator, Wake};
pub use stats::{FifoStats, ModuleStats};
pub use time::{Clock, SimTime};
pub use trace::Trace;
