//! Design-space exploration: parallel simulation campaigns over the
//! SA/VM candidate space with a memoized cycle-model cache.
//!
//! SECDA's core claim is that cost-effective simulation makes design
//! iteration cheap; this layer exploits it at scale. Life of a
//! campaign:
//!
//! 1. **Space** ([`space`]) — enumerate candidate [`DesignPoint`]s
//!    (SA array dimensions, VM unit counts and buffer depths), gated
//!    by [`crate::synth::Resources::fits_in`] against the Zynq-7020
//!    budget so only synthesizable designs are ever evaluated.
//! 2. **Evaluate** ([`campaign`]) — simulate each `(design, shape)`
//!    pair a [`WorkloadProfile`] demands on the cycle-modeled
//!    simulators, across a work-stealing pool of OS threads.
//! 3. **Memoize** ([`cache`]) — every result lands in a sharded
//!    [`MemoCache`]; no pair is simulated twice, within a campaign or
//!    across campaigns via the on-disk JSON snapshot. Cached totals
//!    also seed the policy [`crate::coordinator::CostModel`] so
//!    serving-time placement prices discovered designs from campaign
//!    data.
//! 4. **Pareto** ([`pareto`]) — reduce to the non-dominated set over
//!    modeled latency, energy, and fabric utilization. The frontier is
//!    bit-identical for any campaign thread count.
//! 5. **Planner hand-off** — [`ProfileReport::best_sa`]/[`best_vm`]
//!    pick frontier designs that flow into
//!    [`crate::coordinator::CoordinatorConfig::sa_design`]/`vm_design`
//!    and the elastic [`crate::elastic::CompositionPlanner`], so
//!    reprovisioning composes discovered designs, not just the paper's.
//!
//! [`best_vm`]: ProfileReport::best_vm
//!
//! The `secda dse` CLI subcommand runs a campaign end to end; see the
//! README quickstart and ARCHITECTURE.md's "DSE layer" section.

pub mod cache;
pub mod campaign;
pub mod pareto;
pub mod space;
pub mod workload;

pub use cache::{CachedSim, MemoCache};
pub use campaign::{run_campaign, CampaignConfig, CampaignReport, ProfileReport};
pub use pareto::{pareto_frontier, validate_pareto_json, DesignEval};
pub use space::{design_space, DesignPoint};
pub use workload::WorkloadProfile;
