//! The candidate design space: every SA/VM configuration a campaign
//! may evaluate, gated by Zynq-7020 feasibility.
//!
//! A [`DesignPoint`] is a *compact, hashable* identity for one
//! accelerator configuration — the memo-cache key half (the other half
//! is the GEMM shape). It expands on demand into the full
//! [`SaConfig`]/[`VmConfig`] the simulators consume, into its modeled
//! [`Resources`] footprint, or into a ready [`DriverHandle`] instance.

use crate::accel::components::BramArray;
use crate::accel::{SaConfig, VmConfig};
use crate::driver::{DriverConfig, DriverHandle};
use crate::synth::{sa_resources, vm_resources, Resources};

/// One candidate accelerator design in the exploration space.
///
/// The enum is deliberately small and `Copy`/`Hash`/`Ord`: campaigns
/// key their memo cache on `(DesignPoint, GemmShape)` and sort
/// frontiers by it, so identity must be cheap and total-ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DesignPoint {
    /// A systolic array sized `dim x dim` (§IV-E3 sweep axis).
    Sa {
        /// Array dimension; the paper sweeps {4, 8, 16}.
        dim: usize,
    },
    /// A vector-MAC engine with `units` GEMM units over
    /// `local_buf_kib` KiB per-unit local weight buffers.
    Vm {
        /// GEMM unit count; the paper design uses 4.
        units: usize,
        /// Per-unit local buffer capacity in KiB; sets the `max_k`
        /// reduction-depth cliff (`local_buf_kib * 1024 / tile_m`).
        local_buf_kib: usize,
    },
}

impl DesignPoint {
    /// Stable string key (`sa16`, `vm4x16`) used by the on-disk cache
    /// and the Pareto JSON document.
    pub fn key(&self) -> String {
        match *self {
            DesignPoint::Sa { dim } => format!("sa{dim}"),
            DesignPoint::Vm {
                units,
                local_buf_kib,
            } => format!("vm{units}x{local_buf_kib}"),
        }
    }

    /// Inverse of [`DesignPoint::key`]; `None` for malformed keys.
    pub fn parse(key: &str) -> Option<DesignPoint> {
        if let Some(rest) = key.strip_prefix("sa") {
            return rest.parse().ok().map(|dim| DesignPoint::Sa { dim });
        }
        let rest = key.strip_prefix("vm")?;
        let (units, kib) = rest.split_once('x')?;
        Some(DesignPoint::Vm {
            units: units.parse().ok()?,
            local_buf_kib: kib.parse().ok()?,
        })
    }

    /// The full SA configuration, when this is an SA point.
    pub fn sa_config(&self) -> Option<SaConfig> {
        match *self {
            DesignPoint::Sa { dim } => Some(SaConfig::with_dim(dim)),
            DesignPoint::Vm { .. } => None,
        }
    }

    /// The full VM configuration, when this is a VM point.
    ///
    /// 16 KiB points keep the paper's global buffers; deeper local
    /// buffers trade global weight-buffer capacity for reduction
    /// depth, mirroring [`VmConfig::resnet_variant`].
    pub fn vm_config(&self) -> Option<VmConfig> {
        match *self {
            DesignPoint::Sa { .. } => None,
            DesignPoint::Vm {
                units,
                local_buf_kib,
            } => {
                let mut cfg = VmConfig::paper();
                cfg.units = units;
                cfg.local_buf_bytes = local_buf_kib * 1024;
                if local_buf_kib > 16 {
                    cfg.global_weight_buf = BramArray::new(8, 8, 128 * 1024);
                }
                Some(cfg)
            }
        }
    }

    /// Modeled post-synthesis footprint of one instance.
    pub fn resources(&self) -> Resources {
        match *self {
            DesignPoint::Sa { .. } => sa_resources(&self.sa_config().expect("sa point")),
            DesignPoint::Vm { .. } => vm_resources(&self.vm_config().expect("vm point")),
        }
    }

    /// Whether one instance fits the given fabric budget.
    pub fn fits(&self, budget: &Resources) -> bool {
        self.resources().fits_in(budget)
    }

    /// A driver-wrapped simulator instance of this design.
    pub fn handle(&self, id: usize, cfg: DriverConfig) -> DriverHandle {
        match *self {
            DesignPoint::Sa { .. } => {
                DriverHandle::sa_with(id, cfg, self.sa_config().expect("sa point"))
            }
            DesignPoint::Vm { .. } => {
                DriverHandle::vm_with(id, cfg, self.vm_config().expect("vm point"))
            }
        }
    }
}

/// Candidate SA array dimensions: the §IV-E3 sweep plus one oversized
/// probe the feasibility gate must reject (DSP overflow).
const SA_DIMS: [usize; 4] = [4, 8, 16, 32];
/// Candidate VM unit counts around the paper's 4.
const VM_UNITS: [usize; 4] = [1, 2, 4, 8];
/// Candidate VM per-unit local-buffer depths (KiB).
const VM_BUF_KIB: [usize; 2] = [16, 32];

/// Enumerate every candidate design that fits a Zynq-7020 fabric, in
/// canonical (deterministic) order: SA points by dimension, then VM
/// points by unit count then buffer depth.
///
/// Infeasible grid corners (e.g. a 32x32 array needing 576 DSPs on a
/// 220-DSP part) are filtered here, so downstream layers never see a
/// design that could not be synthesized.
pub fn design_space() -> Vec<DesignPoint> {
    let budget = Resources::zynq7020();
    let mut space = Vec::new();
    for dim in SA_DIMS {
        let p = DesignPoint::Sa { dim };
        if p.fits(&budget) {
            space.push(p);
        }
    }
    for units in VM_UNITS {
        for local_buf_kib in VM_BUF_KIB {
            let p = DesignPoint::Vm {
                units,
                local_buf_kib,
            };
            if p.fits(&budget) {
                space.push(p);
            }
        }
    }
    space
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_round_trip_across_the_space() {
        for p in design_space() {
            assert_eq!(DesignPoint::parse(&p.key()), Some(p), "key {}", p.key());
        }
        assert_eq!(DesignPoint::parse("sa16"), Some(DesignPoint::Sa { dim: 16 }));
        assert_eq!(
            DesignPoint::parse("vm4x16"),
            Some(DesignPoint::Vm {
                units: 4,
                local_buf_kib: 16
            })
        );
        assert_eq!(DesignPoint::parse("nope"), None);
        assert_eq!(DesignPoint::parse("vm4"), None);
    }

    #[test]
    fn space_is_feasible_and_contains_the_paper_designs() {
        let space = design_space();
        let budget = Resources::zynq7020();
        assert!(space.iter().all(|p| p.fits(&budget)));
        assert!(space.contains(&DesignPoint::Sa { dim: 16 }));
        assert!(space.contains(&DesignPoint::Vm {
            units: 4,
            local_buf_kib: 16
        }));
        // The oversized SA probe must be gated out: 32x32 needs more
        // DSPs than the whole part carries.
        assert!(!space.contains(&DesignPoint::Sa { dim: 32 }));
        assert!(!DesignPoint::Sa { dim: 32 }.fits(&budget));
    }

    #[test]
    fn paper_points_expand_to_the_paper_configs() {
        let sa = DesignPoint::Sa { dim: 16 }.sa_config().unwrap();
        assert_eq!(sa.array.dim, SaConfig::paper().array.dim);
        let vm = DesignPoint::Vm {
            units: 4,
            local_buf_kib: 16,
        }
        .vm_config()
        .unwrap();
        assert_eq!(vm.units, VmConfig::paper().units);
        assert_eq!(vm.local_buf_bytes, VmConfig::paper().local_buf_bytes);
        assert_eq!(vm.max_k(), VmConfig::paper().max_k());
    }
}
