//! Multi-objective dominance and the deterministic Pareto frontier.
//!
//! Objectives are minimized jointly: modeled latency over the
//! profile's demand, modeled energy, and fabric utilization (the
//! resource footprint collapsed to its binding-constraint share, so a
//! cheaper design leaves more fabric for co-resident logic).

use crate::synth::Resources;
use crate::sysc::SimTime;

use super::space::DesignPoint;

/// One design's modeled objectives against one workload profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignEval {
    /// The evaluated design.
    pub design: DesignPoint,
    /// Modeled latency of one workload pass (demand-weighted sum of
    /// per-shape simulated totals).
    pub latency: SimTime,
    /// Modeled PYNQ energy of one workload pass, joules.
    pub energy_j: f64,
    /// Zynq-7020 utilization of one instance, in [0, 1].
    pub utilization: f64,
    /// Full modeled resource footprint behind `utilization`.
    pub resources: Resources,
}

impl DesignEval {
    /// Strict Pareto dominance: no objective worse, at least one
    /// strictly better.
    pub fn dominates(&self, other: &DesignEval) -> bool {
        let no_worse = self.latency <= other.latency
            && self.energy_j <= other.energy_j
            && self.utilization <= other.utilization;
        let strictly_better = self.latency < other.latency
            || self.energy_j < other.energy_j
            || self.utilization < other.utilization;
        no_worse && strictly_better
    }
}

/// The non-dominated subset of `evals`, sorted by design identity.
///
/// The result depends only on the eval values — never on input order
/// or on how many threads produced them — which is what makes campaign
/// frontiers bit-comparable across thread counts.
pub fn pareto_frontier(evals: &[DesignEval]) -> Vec<DesignEval> {
    let mut frontier: Vec<DesignEval> = evals
        .iter()
        .filter(|e| !evals.iter().any(|o| o.dominates(e)))
        .copied()
        .collect();
    frontier.sort_by_key(|e| e.design);
    frontier.dedup_by(|a, b| a.design == b.design);
    frontier
}

/// Validate a Pareto JSON document (schema `secda-dse-pareto-v1`)
/// emitted by [`crate::dse::CampaignReport::pareto_json`], using the
/// crate's own [`crate::obs::json`] reader.
///
/// Checks structure, design-key parseability, and that every frontier
/// entry's footprint fits the Zynq-7020 budget — the invariant the
/// feasibility gate is supposed to guarantee end to end.
pub fn validate_pareto_json(doc: &str) -> Result<(), String> {
    use crate::obs::json::Json;
    let json = Json::parse(doc)?;
    let schema = json
        .get("schema")
        .and_then(Json::as_str)
        .ok_or("pareto document has no schema")?;
    if schema != "secda-dse-pareto-v1" {
        return Err(format!("unexpected pareto schema {schema}"));
    }
    let profiles = json
        .get("profiles")
        .and_then(Json::as_arr)
        .ok_or("pareto document has no profiles array")?;
    if profiles.is_empty() {
        return Err("pareto document has zero profiles".to_string());
    }
    let budget = Resources::zynq7020();
    for p in profiles {
        let workload = p
            .get("workload")
            .and_then(Json::as_str)
            .ok_or("profile missing workload name")?;
        let frontier = p
            .get("frontier")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("profile {workload} missing frontier"))?;
        if frontier.is_empty() {
            return Err(format!("profile {workload} has an empty frontier"));
        }
        for e in frontier {
            let key = e
                .get("design")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("profile {workload}: frontier entry missing design"))?;
            let design = DesignPoint::parse(key)
                .ok_or_else(|| format!("profile {workload}: unparseable design key {key}"))?;
            let num = |name: &str| -> Result<f64, String> {
                e.get(name).and_then(Json::as_f64).ok_or_else(|| {
                    format!("profile {workload}, design {key}: missing field {name}")
                })
            };
            if num("latency_ps")? < 0.0 {
                return Err(format!("design {key}: negative latency"));
            }
            if num("energy_j")? < 0.0 {
                return Err(format!("design {key}: negative energy"));
            }
            let util = num("utilization")?;
            if !(0.0..=1.0).contains(&util) {
                return Err(format!("design {key}: utilization {util} outside [0, 1]"));
            }
            for field in ["luts", "ffs", "dsps", "bram36"] {
                num(field)?;
            }
            if !design.resources().fits_in(&budget) {
                return Err(format!("design {key} does not fit the zynq7020 budget"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval(design: DesignPoint, lat_ps: u64, energy_j: f64, util: f64) -> DesignEval {
        DesignEval {
            design,
            latency: SimTime::ps(lat_ps),
            energy_j,
            utilization: util,
            resources: design.resources(),
        }
    }

    #[test]
    fn dominance_requires_strict_improvement_somewhere() {
        let a = eval(DesignPoint::Sa { dim: 16 }, 100, 1.0, 0.5);
        let b = eval(DesignPoint::Sa { dim: 8 }, 100, 1.0, 0.5);
        assert!(!a.dominates(&b), "equal objectives do not dominate");
        let c = eval(DesignPoint::Sa { dim: 4 }, 90, 1.0, 0.5);
        assert!(c.dominates(&a));
        assert!(!a.dominates(&c));
    }

    #[test]
    fn frontier_is_order_independent_and_nondominated() {
        let sa16 = eval(DesignPoint::Sa { dim: 16 }, 100, 2.0, 0.8);
        let sa8 = eval(DesignPoint::Sa { dim: 8 }, 200, 1.0, 0.4);
        let worse = eval(DesignPoint::Sa { dim: 4 }, 300, 3.0, 0.9);
        let forward = pareto_frontier(&[sa16, sa8, worse]);
        let reversed = pareto_frontier(&[worse, sa8, sa16]);
        assert_eq!(forward, reversed);
        assert_eq!(forward.len(), 2);
        for e in &forward {
            assert!(!forward.iter().any(|o| o.dominates(e)));
        }
    }

    #[test]
    fn validate_rejects_malformed_documents() {
        assert!(validate_pareto_json("{}").is_err());
        assert!(validate_pareto_json("{\"schema\":\"secda-dse-pareto-v1\",\"profiles\":[]}")
            .is_err());
        let empty_frontier = "{\"schema\":\"secda-dse-pareto-v1\",\"profiles\":\
                              [{\"workload\":\"w\",\"frontier\":[]}]}";
        assert!(validate_pareto_json(empty_frontier).is_err());
    }
}
