//! Workload profiles a campaign evaluates designs against.
//!
//! A profile is a named GEMM-shape demand histogram — the same
//! representation the elastic estimator derives from live traffic
//! ([`crate::elastic::TrafficProfile::demand`]), so campaign results
//! speak the serving stack's language directly.

use crate::coordinator::GemmShape;
use crate::framework::models;

/// A named demand histogram over GEMM shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadProfile {
    /// Profile name (a model name, or a scenario label).
    pub name: String,
    /// Per-shape demand in first-seen order: how many times each
    /// distinct GEMM shape one pass of the workload issues.
    pub demand: Vec<(GemmShape, u64)>,
}

impl WorkloadProfile {
    /// A profile from an explicit demand histogram.
    pub fn new(name: impl Into<String>, demand: Vec<(GemmShape, u64)>) -> Self {
        WorkloadProfile {
            name: name.into(),
            demand,
        }
    }

    /// The demand histogram of one forward pass of a bundled model
    /// (`mobilenet_v1`, `resnet18`, ...); `None` for unknown names.
    pub fn from_model(name: &str) -> Option<WorkloadProfile> {
        let g = models::by_name(name)?;
        let mut demand: Vec<(GemmShape, u64)> = Vec::new();
        for (m, k, n) in models::gemm_shapes(&g) {
            let shape = GemmShape { m, k, n };
            match demand.iter_mut().find(|(s, _)| *s == shape) {
                Some(entry) => entry.1 += 1,
                None => demand.push((shape, 1)),
            }
        }
        Some(WorkloadProfile::new(name, demand))
    }

    /// One profile per bundled model, in [`models::ALL`] order.
    pub fn all_models() -> Vec<WorkloadProfile> {
        models::ALL
            .iter()
            .filter_map(|name| WorkloadProfile::from_model(name))
            .collect()
    }

    /// Total GEMM invocations one pass of this workload issues.
    pub fn total_demand(&self) -> u64 {
        self.demand.iter().map(|(_, c)| c).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_bundled_model_yields_a_nonempty_profile() {
        let profiles = WorkloadProfile::all_models();
        assert_eq!(profiles.len(), models::ALL.len());
        for p in &profiles {
            assert!(!p.demand.is_empty(), "{} has no GEMM demand", p.name);
            assert!(p.total_demand() > 0);
        }
    }

    #[test]
    fn demand_is_a_histogram_of_distinct_shapes() {
        let p = WorkloadProfile::from_model("mobilenet_v1").unwrap();
        for (i, (s, _)) in p.demand.iter().enumerate() {
            assert!(
                !p.demand[i + 1..].iter().any(|(o, _)| o == s),
                "duplicate shape in demand"
            );
        }
    }
}
