//! The sharded memo cache: each `(design, shape)` pair is simulated at
//! most once — within a campaign, across campaigns in one process, and
//! across processes via the on-disk JSON snapshot.
//!
//! The cache is also the bridge back into serving:
//! [`MemoCache::seed_cost_model`] replays cached simulator totals into
//! a policy [`CostModel`]'s observed-measurements path, so coordinator
//! placement prices a discovered design from campaign results instead
//! of priors.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::{CostModel, GemmShape};
use crate::obs::json::Json;
use crate::sysc::SimTime;

use super::space::DesignPoint;

/// Modeled outcome of one `(design, shape)` simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CachedSim {
    /// End-to-end modeled GEMM latency (driver + accelerator).
    pub total: SimTime,
    /// Fabric-active portion (drives the energy model).
    pub accel_active: SimTime,
    /// CPU-busy portion (prep + unpack + any CPU fallback compute).
    pub cpu_side: SimTime,
}

/// One memo shard: a plain map behind its own lock.
type Shard = Mutex<HashMap<(DesignPoint, GemmShape), CachedSim>>;

/// Shard count; a small power of two keeps lock contention negligible
/// at campaign thread counts (≤ 16 workers) without bloating the map.
const SHARDS: usize = 16;

/// Sharded, counter-instrumented memoization of simulator results,
/// keyed by `(design, shape)`.
///
/// All methods take `&self`; the cache is shared across campaign
/// worker threads by reference (it is `Sync`). Counters are campaign
/// bookkeeping, not cached state: they are *not* serialized.
pub struct MemoCache {
    shards: Vec<Shard>,
    hits: AtomicU64,
    misses: AtomicU64,
    fresh: AtomicU64,
}

impl Default for MemoCache {
    fn default() -> Self {
        MemoCache::new()
    }
}

impl MemoCache {
    /// An empty cache with zeroed counters.
    pub fn new() -> Self {
        MemoCache {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            fresh: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &(DesignPoint, GemmShape)) -> &Shard {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Look up a pair, counting a hit or a miss.
    pub fn get(&self, design: DesignPoint, shape: GemmShape) -> Option<CachedSim> {
        let found = self.peek(design, shape);
        match found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Look up a pair without touching the counters (aggregation path).
    pub fn peek(&self, design: DesignPoint, shape: GemmShape) -> Option<CachedSim> {
        let key = (design, shape);
        self.shard(&key).lock().unwrap().get(&key).copied()
    }

    /// Record a freshly simulated pair (bumps the fresh-sim counter).
    pub fn record(&self, design: DesignPoint, shape: GemmShape, sim: CachedSim) {
        self.fresh.fetch_add(1, Ordering::Relaxed);
        self.preload(design, shape, sim);
    }

    /// Insert a pair without counting it as fresh (snapshot loading).
    pub fn preload(&self, design: DesignPoint, shape: GemmShape, sim: CachedSim) {
        let key = (design, shape);
        self.shard(&key).lock().unwrap().insert(key, sim);
    }

    /// Cached pair count across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// True when no pair is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups answered from the cache since construction.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that found nothing since construction.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Simulator invocations recorded since construction — the warm-
    /// rerun acceptance counter: a rerun over a populated cache must
    /// leave this unchanged.
    pub fn fresh_sims(&self) -> u64 {
        self.fresh.load(Ordering::Relaxed)
    }

    /// Every cached entry in canonical order (design key, then shape),
    /// independent of shard layout and insertion order.
    pub fn snapshot(&self) -> Vec<(DesignPoint, GemmShape, CachedSim)> {
        let mut entries: Vec<(DesignPoint, GemmShape, CachedSim)> = self
            .shards
            .iter()
            .flat_map(|s| {
                s.lock()
                    .unwrap()
                    .iter()
                    .map(|(&(d, sh), &sim)| (d, sh, sim))
                    .collect::<Vec<_>>()
            })
            .collect();
        entries.sort_by_key(|&(d, s, _)| (d, s.m, s.k, s.n));
        entries
    }

    /// Serialize the cache as a deterministic JSON document
    /// (schema `secda-dse-cache-v1`), entries in canonical order so
    /// equal caches produce byte-identical files.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\"schema\":\"secda-dse-cache-v1\",\"entries\":[");
        for (i, (design, shape, sim)) in self.snapshot().iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"design\":\"{}\",\"m\":{},\"k\":{},\"n\":{},\
                 \"total_ps\":{},\"accel_active_ps\":{},\"cpu_side_ps\":{}}}",
                design.key(),
                shape.m,
                shape.k,
                shape.n,
                sim.total.as_ps(),
                sim.accel_active.as_ps(),
                sim.cpu_side.as_ps()
            ));
        }
        s.push_str("]}\n");
        s
    }

    /// Deserialize a cache snapshot produced by [`MemoCache::to_json`].
    ///
    /// Entries whose design key no longer parses (a removed candidate
    /// axis) are rejected as corrupt rather than silently dropped.
    pub fn from_json(doc: &str) -> Result<MemoCache, String> {
        let json = Json::parse(doc)?;
        let schema = json
            .get("schema")
            .and_then(Json::as_str)
            .ok_or("cache document has no schema")?;
        if schema != "secda-dse-cache-v1" {
            return Err(format!("unexpected cache schema {schema}"));
        }
        let entries = json
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("cache document has no entries array")?;
        let cache = MemoCache::new();
        for e in entries {
            let design_key = e
                .get("design")
                .and_then(Json::as_str)
                .ok_or("entry missing design")?;
            let design = DesignPoint::parse(design_key)
                .ok_or_else(|| format!("unparseable design key {design_key}"))?;
            let field = |name: &str| -> Result<u64, String> {
                e.get(name)
                    .and_then(Json::as_f64)
                    .map(|v| v as u64)
                    .ok_or_else(|| format!("entry missing {name}"))
            };
            let shape = GemmShape {
                m: field("m")? as usize,
                k: field("k")? as usize,
                n: field("n")? as usize,
            };
            let sim = CachedSim {
                total: SimTime::ps(field("total_ps")?),
                accel_active: SimTime::ps(field("accel_active_ps")?),
                cpu_side: SimTime::ps(field("cpu_side_ps")?),
            };
            cache.preload(design, shape, sim);
        }
        Ok(cache)
    }

    /// Replay this design's cached totals into a policy [`CostModel`]
    /// as observed measurements, so the coordinator's placement math
    /// prices the design from campaign simulations instead of priors.
    pub fn seed_cost_model(&self, design: DesignPoint, model: &mut CostModel) {
        for (d, shape, sim) in self.snapshot() {
            if d == design {
                model.observe(shape, false, sim.total);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(ps: u64) -> CachedSim {
        CachedSim {
            total: SimTime::ps(ps),
            accel_active: SimTime::ps(ps / 2),
            cpu_side: SimTime::ps(ps / 4),
        }
    }

    #[test]
    fn counters_track_hits_misses_and_fresh_sims() {
        let cache = MemoCache::new();
        let d = DesignPoint::Sa { dim: 8 };
        let s = GemmShape { m: 4, k: 8, n: 4 };
        assert!(cache.get(d, s).is_none());
        assert_eq!((cache.hits(), cache.misses(), cache.fresh_sims()), (0, 1, 0));
        cache.record(d, s, sim(1000));
        assert_eq!(cache.get(d, s), Some(sim(1000)));
        assert_eq!((cache.hits(), cache.misses(), cache.fresh_sims()), (1, 1, 1));
        // peek and preload leave the counters alone
        assert!(cache.peek(d, s).is_some());
        cache.preload(d, s, sim(1000));
        assert_eq!((cache.hits(), cache.misses(), cache.fresh_sims()), (1, 1, 1));
    }

    #[test]
    fn json_round_trips_byte_identically() {
        let cache = MemoCache::new();
        let shapes = [
            GemmShape { m: 16, k: 32, n: 8 },
            GemmShape { m: 8, k: 256, n: 49 },
        ];
        for (i, &s) in shapes.iter().enumerate() {
            cache.record(DesignPoint::Sa { dim: 16 }, s, sim(1_000 * (i as u64 + 1)));
            cache.record(
                DesignPoint::Vm {
                    units: 4,
                    local_buf_kib: 16,
                },
                s,
                sim(2_000 * (i as u64 + 1)),
            );
        }
        let doc = cache.to_json();
        let reloaded = MemoCache::from_json(&doc).unwrap();
        assert_eq!(reloaded.snapshot(), cache.snapshot());
        assert_eq!(reloaded.to_json(), doc);
        assert_eq!(reloaded.fresh_sims(), 0, "loading is not simulating");
    }

    #[test]
    fn corrupt_documents_are_rejected() {
        assert!(MemoCache::from_json("{}").is_err());
        assert!(MemoCache::from_json("{\"schema\":\"other\",\"entries\":[]}").is_err());
        let bad_key = "{\"schema\":\"secda-dse-cache-v1\",\"entries\":[{\"design\":\"zz9\",\
                       \"m\":1,\"k\":1,\"n\":1,\"total_ps\":1,\"accel_active_ps\":0,\
                       \"cpu_side_ps\":0}]}";
        assert!(MemoCache::from_json(bad_key).is_err());
    }

    #[test]
    fn seeding_routes_cached_totals_into_the_cost_model() {
        let cache = MemoCache::new();
        let d = DesignPoint::Sa { dim: 16 };
        let s = GemmShape {
            m: 64,
            k: 256,
            n: 196,
        };
        cache.record(d, s, sim(123_456_789));
        let mut model = CostModel::for_sa_design(&d.sa_config().unwrap(), 1, SimTime::ZERO);
        cache.seed_cost_model(d, &mut model);
        assert_eq!(model.observed(s, false), Some(SimTime::ps(123_456_789)));
    }
}
