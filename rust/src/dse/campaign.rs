//! The campaign runner: fan `(design, shape)` simulations out across a
//! work-stealing pool of OS threads, memoize every result, and reduce
//! to one deterministic Pareto frontier per workload profile.
//!
//! Determinism across thread counts comes from three properties: the
//! task list (budget truncation included) is fixed *before* any thread
//! starts; each `(design, shape)` simulation is itself deterministic
//! and lands in the memo cache regardless of which worker ran it; and
//! aggregation is a single-threaded reduction over the cache in
//! canonical order. Threads only change *who* computes a cache entry,
//! never its value or the reduction that consumes it.

use std::collections::{HashSet, VecDeque};
use std::sync::Mutex;

use crate::accel::{SaConfig, VmConfig};
use crate::coordinator::GemmShape;
use crate::driver::DriverConfig;
use crate::framework::backend::GemmTask;
use crate::framework::quant::quantize_multiplier;
use crate::gemm::QGemmParams;
use crate::perf::EnergyModel;
use crate::synth::Resources;
use crate::sysc::SimTime;

use super::cache::{CachedSim, MemoCache};
use super::pareto::{pareto_frontier, DesignEval};
use super::space::DesignPoint;
use super::workload::WorkloadProfile;

/// Campaign knobs.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Worker threads simulating candidates (clamped to ≥ 1).
    pub threads: usize,
    /// Optional bound on distinct shapes taken per profile (prefix of
    /// the demand histogram). Applied before any thread spawns, so the
    /// truncation — like everything downstream — is thread-invariant.
    pub budget: Option<usize>,
    /// Driver configuration every simulated instance runs under.
    pub driver: DriverConfig,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            threads: 1,
            budget: None,
            driver: DriverConfig::default(),
        }
    }
}

/// Per-profile campaign output.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    /// Workload profile name.
    pub workload: String,
    /// Every candidate's objectives against this profile, in space
    /// order.
    pub evals: Vec<DesignEval>,
    /// The non-dominated subset, sorted by design identity.
    pub frontier: Vec<DesignEval>,
}

impl ProfileReport {
    /// The lowest-latency SA design on this profile's frontier — the
    /// configuration the elastic planner should provision SA slots
    /// with. `None` when no SA design made the frontier.
    pub fn best_sa(&self) -> Option<SaConfig> {
        self.frontier
            .iter()
            .filter(|e| matches!(e.design, DesignPoint::Sa { .. }))
            .min_by_key(|e| e.latency)
            .and_then(|e| e.design.sa_config())
    }

    /// The lowest-latency VM design on this profile's frontier.
    pub fn best_vm(&self) -> Option<VmConfig> {
        self.frontier
            .iter()
            .filter(|e| matches!(e.design, DesignPoint::Vm { .. }))
            .min_by_key(|e| e.latency)
            .and_then(|e| e.design.vm_config())
    }
}

/// Whole-campaign output: per-profile reports plus the cache-counter
/// deltas this run produced.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    /// One report per input profile, in input order.
    pub profiles: Vec<ProfileReport>,
    /// Distinct `(design, shape)` pairs the campaign needed.
    pub pairs: usize,
    /// Simulator invocations this run performed (0 on a warm rerun).
    pub fresh_sims: u64,
    /// Pairs answered from the memo cache this run.
    pub cache_hits: u64,
}

impl CampaignReport {
    /// The per-profile frontiers as a deterministic JSON document
    /// (schema `secda-dse-pareto-v1`): identical campaigns — cold or
    /// warm, any thread count — produce byte-identical files.
    pub fn pareto_json(&self) -> String {
        let mut s = String::from("{\"schema\":\"secda-dse-pareto-v1\",\"profiles\":[");
        for (i, p) in self.profiles.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("{{\"workload\":\"{}\",\"frontier\":[", p.workload));
            for (j, e) in p.frontier.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                s.push_str(&format!(
                    "{{\"design\":\"{}\",\"latency_ps\":{},\"energy_j\":{},\
                     \"utilization\":{},\"luts\":{},\"ffs\":{},\"dsps\":{},\"bram36\":{}}}",
                    e.design.key(),
                    e.latency.as_ps(),
                    e.energy_j,
                    e.utilization,
                    e.resources.luts,
                    e.resources.ffs,
                    e.resources.dsps,
                    e.resources.bram36
                ));
            }
            s.push_str("]}");
        }
        s.push_str("]}\n");
        s
    }
}

/// Deterministic per-shape input data: the simulated GEMM's operands
/// are a pure function of the shape, so a `(design, shape)` result is
/// reproducible across runs, machines, and cache generations.
fn shape_task_data(shape: GemmShape) -> (Vec<i8>, Vec<i8>, QGemmParams) {
    let mut st = (((shape.m as u64) << 42) ^ ((shape.k as u64) << 21) ^ (shape.n as u64)) | 1;
    let mut rnd = || {
        st ^= st << 13;
        st ^= st >> 7;
        st ^= st << 17;
        st
    };
    let weights: Vec<i8> = (0..shape.m * shape.k)
        .map(|_| (rnd() & 0xff) as u8 as i8)
        .collect();
    let inputs: Vec<i8> = (0..shape.k * shape.n)
        .map(|_| (rnd() & 0xff) as u8 as i8)
        .collect();
    let (mult, shift) = quantize_multiplier(0.042);
    (weights, inputs, QGemmParams::uniform(shape.m, 9, mult, shift))
}

/// Run one `(design, shape)` pair through the design's cycle-modeled
/// simulator under the co-designed driver.
fn simulate(design: DesignPoint, shape: GemmShape, cfg: DriverConfig) -> CachedSim {
    let mut handle = design.handle(0, cfg);
    let (weights, inputs, params) = shape_task_data(shape);
    let task = GemmTask {
        m: shape.m,
        k: shape.k,
        n: shape.n,
        weights: &weights,
        inputs: &inputs,
        params: &params,
        layer: "dse",
        weights_resident: false,
    };
    let (_, timing) = handle.backend_mut().run_gemm(&task);
    CachedSim {
        total: timing.total,
        accel_active: timing.accel_active,
        cpu_side: timing.cpu_time,
    }
}

/// Pop the next task index: own queue front first, else steal from the
/// back of the longest sibling backlog. Returns `None` only once every
/// queue is drained (no tasks are ever added after start).
fn next_task(queues: &[Mutex<VecDeque<usize>>], own: usize) -> Option<usize> {
    loop {
        if let Some(i) = queues[own].lock().unwrap().pop_front() {
            return Some(i);
        }
        let victim = (0..queues.len())
            .filter(|&i| i != own)
            .map(|i| (queues[i].lock().unwrap().len(), i))
            .max()?;
        if victim.0 == 0 {
            return None;
        }
        // The victim may have been drained since we measured it; loop
        // and re-scan rather than give up while work remains.
        if let Some(i) = queues[victim.1].lock().unwrap().pop_back() {
            return Some(i);
        }
    }
}

/// Run a campaign: simulate every uncached `(design, shape)` pair the
/// profiles demand across `cfg.threads` work-stealing workers, then
/// reduce the memo cache to per-profile evals and Pareto frontiers.
///
/// The returned report is bit-identical for any thread count; the
/// cache carries all memoized results forward to later campaigns.
pub fn run_campaign(
    cfg: &CampaignConfig,
    profiles: &[WorkloadProfile],
    space: &[DesignPoint],
    cache: &MemoCache,
) -> CampaignReport {
    let fresh_before = cache.fresh_sims();
    let hits_before = cache.hits();

    // Budget truncation happens here, once, before any thread exists.
    let truncated: Vec<Vec<(GemmShape, u64)>> = profiles
        .iter()
        .map(|p| {
            let mut d = p.demand.clone();
            if let Some(b) = cfg.budget {
                d.truncate(b);
            }
            d
        })
        .collect();

    // Distinct (design, shape) pairs in deterministic order — each is
    // simulated at most once per campaign by construction.
    let mut pairs: Vec<(DesignPoint, GemmShape)> = Vec::new();
    let mut seen: HashSet<(DesignPoint, GemmShape)> = HashSet::new();
    for &design in space {
        for demand in &truncated {
            for &(shape, _) in demand {
                if seen.insert((design, shape)) {
                    pairs.push((design, shape));
                }
            }
        }
    }

    let threads = cfg.threads.max(1);
    let queues: Vec<Mutex<VecDeque<usize>>> =
        (0..threads).map(|_| Mutex::new(VecDeque::new())).collect();
    for i in 0..pairs.len() {
        queues[i % threads].lock().unwrap().push_back(i);
    }
    std::thread::scope(|s| {
        for w in 0..threads {
            let queues = &queues;
            let pairs = &pairs;
            let driver = &cfg.driver;
            s.spawn(move || {
                while let Some(i) = next_task(queues, w) {
                    let (design, shape) = pairs[i];
                    if cache.get(design, shape).is_none() {
                        cache.record(design, shape, simulate(design, shape, driver.clone()));
                    }
                }
            });
        }
    });

    // Single-threaded reduction in canonical order.
    let budget = Resources::zynq7020();
    let energy_model = EnergyModel::pynq();
    let reports = profiles
        .iter()
        .zip(&truncated)
        .map(|(profile, demand)| {
            let evals: Vec<DesignEval> = space
                .iter()
                .map(|&design| {
                    let mut latency = SimTime::ZERO;
                    let mut active = SimTime::ZERO;
                    for &(shape, count) in demand {
                        let sim = cache
                            .peek(design, shape)
                            .expect("campaign simulated every demanded pair");
                        latency += SimTime::ps(sim.total.as_ps() * count);
                        active += SimTime::ps(sim.accel_active.as_ps() * count);
                    }
                    let resources = design.resources();
                    DesignEval {
                        design,
                        latency,
                        energy_j: energy_model.energy_j(latency, active, cfg.driver.threads),
                        utilization: resources.max_utilization(&budget),
                        resources,
                    }
                })
                .collect();
            let frontier = pareto_frontier(&evals);
            ProfileReport {
                workload: profile.name.clone(),
                evals,
                frontier,
            }
        })
        .collect();

    CampaignReport {
        profiles: reports,
        pairs: pairs.len(),
        fresh_sims: cache.fresh_sims() - fresh_before,
        cache_hits: cache.hits() - hits_before,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::design_space;
    use crate::dse::pareto::validate_pareto_json;

    fn tiny_profiles() -> Vec<WorkloadProfile> {
        vec![
            WorkloadProfile::new(
                "convish",
                vec![
                    (GemmShape { m: 8, k: 27, n: 16 }, 3),
                    (GemmShape { m: 16, k: 64, n: 8 }, 1),
                ],
            ),
            WorkloadProfile::new("deepish", vec![(GemmShape { m: 4, k: 96, n: 8 }, 2)]),
        ]
    }

    #[test]
    fn warm_rerun_performs_zero_fresh_simulations() {
        let cfg = CampaignConfig::default();
        let profiles = tiny_profiles();
        let space = design_space();
        let cache = MemoCache::new();
        let cold = run_campaign(&cfg, &profiles, &space, &cache);
        assert!(cold.fresh_sims > 0);
        assert_eq!(cold.fresh_sims as usize, cold.pairs);
        let warm = run_campaign(&cfg, &profiles, &space, &cache);
        assert_eq!(warm.fresh_sims, 0, "warm rerun must not simulate");
        assert_eq!(warm.cache_hits as usize, warm.pairs);
        assert_eq!(warm.pareto_json(), cold.pareto_json());
    }

    #[test]
    fn warm_rerun_from_a_reloaded_snapshot_is_also_free() {
        let cfg = CampaignConfig::default();
        let profiles = tiny_profiles();
        let space = design_space();
        let cache = MemoCache::new();
        let cold = run_campaign(&cfg, &profiles, &space, &cache);
        let reloaded = MemoCache::from_json(&cache.to_json()).unwrap();
        let warm = run_campaign(&cfg, &profiles, &space, &reloaded);
        assert_eq!(warm.fresh_sims, 0);
        assert_eq!(warm.pareto_json(), cold.pareto_json());
    }

    #[test]
    fn budget_bounds_distinct_shapes_per_profile() {
        let cfg = CampaignConfig {
            budget: Some(1),
            ..Default::default()
        };
        let profiles = tiny_profiles();
        let space = design_space();
        let cache = MemoCache::new();
        let report = run_campaign(&cfg, &profiles, &space, &cache);
        // 2 distinct shapes survive truncation (one per profile).
        assert_eq!(report.pairs, 2 * space.len());
    }

    #[test]
    fn pareto_json_validates_and_frontier_designs_fit() {
        let cfg = CampaignConfig::default();
        let profiles = tiny_profiles();
        let space = design_space();
        let cache = MemoCache::new();
        let report = run_campaign(&cfg, &profiles, &space, &cache);
        validate_pareto_json(&report.pareto_json()).unwrap();
        let budget = Resources::zynq7020();
        for p in &report.profiles {
            assert!(!p.frontier.is_empty());
            for e in &p.frontier {
                assert!(e.design.fits(&budget));
            }
            assert!(p.best_sa().is_some() || p.best_vm().is_some());
        }
    }
}
