//! The Systolic-Array (SA) accelerator design — paper §IV-C2, Figure 4.
//!
//! A single `dim x dim` output-stationary MAC array: weights move
//! vertically and inputs horizontally, one hop per step; each PE
//! accumulates one output value. The boundary rows/columns are fed by
//! `2*dim` data queues which the Scheduler fills — in the improved
//! design (§IV-E1), in parallel with array compute, eliminating MAC
//! idle time. A single wide PPU post-processes completed `dim x dim`
//! tiles and streams them to the output DMA.
//!
//! TLM granularity: one job = (`dim` output rows) x (all N columns),
//! i.e. a stripe of output tiles processed back to back by the array.

use std::cell::RefCell;
use std::rc::Rc;

use crate::accel::components::{AxiBus, BramArray, PpuModel, SaArrayModel};
use crate::accel::types::{AccelReport, ExecMode, GemmAccel, GemmRequest, GemmResult};
use crate::gemm;
use crate::sysc::{Clock, Ctx, Module, ModuleStats, SimTime, Simulator, Trace, Wake};

/// Configuration of an SA design instance.
#[derive(Debug, Clone)]
pub struct SaConfig {
    /// Systolic-array cycle model (dimension, fill overlap).
    pub array: SaArrayModel,
    /// Fabric clock in MHz.
    pub clock_mhz: f64,
    /// Global weight buffer (SA keeps both weights and inputs global,
    /// §IV-D1).
    pub global_weight_buf: BramArray,
    /// Global input buffer.
    pub global_input_buf: BramArray,
    /// Off-chip AXI DMA path.
    pub axi: AxiBus,
    /// None = CPU-side post-processing (int32 outputs).
    pub ppu: Option<PpuModel>,
    /// Stripe-job FIFO depth between scheduler and array.
    pub job_fifo_depth: usize,
}

impl SaConfig {
    /// The paper's final 16x16 design.
    pub fn paper() -> Self {
        Self::with_dim(16)
    }

    /// §IV-E3 size sweep: 4x4, 8x8 or 16x16.
    pub fn with_dim(dim: usize) -> Self {
        SaConfig {
            array: SaArrayModel::paper(dim),
            clock_mhz: 100.0,
            global_weight_buf: BramArray::new(8, 8, 256 * 1024),
            global_input_buf: BramArray::new(8, 8, 128 * 1024),
            axi: AxiBus::pynq_all_links(),
            ppu: Some(PpuModel {
                lanes: dim,
                pipeline_latency: 5,
            }),
            job_fifo_depth: 2,
        }
    }

    /// §IV-E1 ablation: queues refilled serially with compute.
    pub fn serial_fill(dim: usize) -> Self {
        let mut c = Self::with_dim(dim);
        c.array.parallel_fill = false;
        c
    }

    /// §IV-E2-style ablation for SA: no on-fabric PPU.
    pub fn no_ppu() -> Self {
        SaConfig {
            ppu: None,
            ..Self::paper()
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Job {
    id: usize,
    m0: usize,
    m1: usize,
}

#[derive(Debug, Clone)]
enum Msg {
    Start,
    DmaChunk { bytes: u64 },
    TryDispatch,
    ArrayWake,
    ArrayDone { job: usize },
    PpuWake,
    PpuDone { job: usize },
    DmaOut { job: usize },
    DrainCheck,
    Token(usize),
}

struct Run {
    req: GemmRequest,
    mode: ExecMode,
    cfg: SaConfig,
    clock: Clock,
    jobs: Vec<Job>,
    next_job: usize,
    pending_acc: Vec<Option<Vec<i32>>>,
    output: Vec<i8>,
    raw_acc: Option<Vec<i32>>,
    bytes_needed: u64,
    bytes_arrived: u64,
    weight_bytes: u64,
    completed: usize,
    report: AccelReport,
}

impl Run {
    fn gate_ok(&self, job_idx: usize) -> bool {
        if self.mode == ExecMode::Simulation {
            return true;
        }
        let frac = (job_idx + 1) as f64 / self.jobs.len() as f64;
        let need =
            self.weight_bytes as f64 + frac * (self.bytes_needed - self.weight_bytes) as f64;
        (self.bytes_arrived as f64) >= need - 1e-9
    }
}

type Shared = Rc<RefCell<Run>>;

/// Input handler: DMA in + distribution to the global buffers.
struct InputHandler {
    run: Shared,
    sched: usize,
    stats: ModuleStats,
}

impl Module<Msg> for InputHandler {
    fn name(&self) -> &str {
        "input_handler"
    }
    fn stats(&self) -> Option<&ModuleStats> {
        Some(&self.stats)
    }
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Start => {
                let (mode, bytes, chunk, clock) = {
                    let r = self.run.borrow();
                    (r.mode, r.bytes_needed, r.cfg.axi.chunk_bytes(), r.clock)
                };
                match mode {
                    ExecMode::Simulation => {
                        self.run.borrow_mut().bytes_arrived = bytes;
                        ctx.schedule(SimTime::ZERO, self.sched, Msg::TryDispatch);
                    }
                    ExecMode::HardwareEval => {
                        let mut sent = 0u64;
                        let mut t = SimTime::ZERO;
                        let me = ctx.current_module();
                        while sent < bytes {
                            let sz = chunk.min(bytes - sent);
                            let cycles = self.run.borrow().cfg.axi.transfer_cycles(sz);
                            t += clock.cycles(cycles);
                            sent += sz;
                            ctx.schedule(t, me, Msg::DmaChunk { bytes: sz });
                        }
                        let mut r = self.run.borrow_mut();
                        r.report.dma_in_cycles = clock.cycles_for(t);
                        r.report.bytes_in = bytes;
                    }
                }
            }
            Msg::DmaChunk { bytes } => {
                self.run.borrow_mut().bytes_arrived += bytes;
                self.stats.add_transaction(bytes);
                ctx.schedule(SimTime::ZERO, self.sched, Msg::TryDispatch);
            }
            _ => {}
        }
    }
}

/// Scheduler (§IV-D2): feeds stripe jobs (and, inside the array model,
/// the 2*dim data queues) to the systolic array.
struct Scheduler {
    run: Shared,
    array_fifo: usize,
    array_mod: usize,
    stats: ModuleStats,
}

impl Module<Msg> for Scheduler {
    fn name(&self) -> &str {
        "scheduler"
    }
    fn stats(&self) -> Option<&ModuleStats> {
        Some(&self.stats)
    }
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if !matches!(msg, Msg::TryDispatch) {
            return;
        }
        loop {
            let job = {
                let r = self.run.borrow();
                if r.next_job >= r.jobs.len() || !r.gate_ok(r.next_job) {
                    return;
                }
                r.jobs[r.next_job]
            };
            if ctx.fifo_is_full(self.array_fifo) {
                return;
            }
            {
                let mut r = self.run.borrow_mut();
                // queue-fill reads: the scheduler streams the stripe's
                // weights and the whole input matrix through the queues
                let stripe_w = ((job.m1 - job.m0) * r.req.k) as u64;
                r.report.global_buffer_reads += stripe_w;
                r.next_job += 1;
            }
            self.stats.add_transaction(0);
            let ok = ctx.fifo_push(self.array_fifo, Msg::Token(job.id));
            debug_assert!(ok);
            ctx.schedule(SimTime::ZERO, self.array_mod, Msg::ArrayWake);
        }
    }
}

/// The systolic array: processes one stripe job at a time.
struct SystolicArray {
    run: Shared,
    in_fifo: usize,
    out_fifo: usize,
    ppu_mod: usize,
    sched_mod: usize,
    busy: bool,
    parked: Option<usize>,
    stats: ModuleStats,
}

impl SystolicArray {
    fn try_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.busy || self.parked.is_some() {
            return;
        }
        let Some(Msg::Token(job_id)) = ctx.fifo_pop(self.in_fifo) else {
            return;
        };
        ctx.schedule(SimTime::ZERO, self.sched_mod, Msg::TryDispatch);
        let (cycles, dur) = {
            let r = self.run.borrow();
            let c = r.cfg.array.stripe_compute_cycles(r.req.k, r.req.n);
            (c, r.clock.cycles(c))
        };
        self.busy = true;
        self.stats.busy_for(ctx.now(), dur, cycles);
        ctx.trace.record(ctx.now(), "systolic_array", || {
            format!("stripe {job_id} ({cycles} cyc)")
        });
        ctx.schedule_self(dur, Msg::ArrayDone { job: job_id });
    }
}

impl Module<Msg> for SystolicArray {
    fn name(&self) -> &str {
        "systolic_array"
    }
    fn stats(&self) -> Option<&ModuleStats> {
        Some(&self.stats)
    }
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::ArrayWake => {
                if let Some(job) = self.parked.take() {
                    if ctx.fifo_push(self.out_fifo, Msg::Token(job)) {
                        ctx.schedule(SimTime::ZERO, self.ppu_mod, Msg::PpuWake);
                    } else {
                        self.parked = Some(job);
                        return;
                    }
                }
                self.try_start(ctx);
            }
            Msg::ArrayDone { job } => {
                {
                    let mut r = self.run.borrow_mut();
                    let j = r.jobs[job];
                    let (k, n) = (r.req.k, r.req.n);
                    let mut acc = vec![0i32; (j.m1 - j.m0) * n];
                    gemm::accumulate_rows(
                        &r.req.weights, &r.req.inputs, j.m0, j.m1, k, n, &mut acc,
                    );
                    let cycles = r.cfg.array.stripe_compute_cycles(k, n);
                    r.report.compute_cycles += cycles;
                    r.pending_acc[job] = Some(acc);
                }
                self.busy = false;
                if ctx.fifo_push(self.out_fifo, Msg::Token(job)) {
                    ctx.schedule(SimTime::ZERO, self.ppu_mod, Msg::PpuWake);
                    self.try_start(ctx);
                } else {
                    self.parked = Some(job);
                    self.run.borrow_mut().report.stall_cycles += 1;
                }
            }
            _ => {}
        }
    }
}

/// The single wide PPU (§IV-D3).
struct Ppu {
    run: Shared,
    model: Option<PpuModel>,
    in_fifo: usize,
    array_mod: usize,
    dma_mod: usize,
    busy: bool,
    stats: ModuleStats,
}

impl Ppu {
    fn try_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.busy {
            return;
        }
        let Some(Msg::Token(job_id)) = ctx.fifo_pop(self.in_fifo) else {
            return;
        };
        ctx.schedule(SimTime::ZERO, self.array_mod, Msg::ArrayWake);
        let (cycles, dur) = {
            let r = self.run.borrow();
            let j = r.jobs[job_id];
            let outputs = ((j.m1 - j.m0) * r.req.n) as u64;
            let c = match &self.model {
                Some(p) => p.cycles(outputs),
                None => 1,
            };
            (c, r.clock.cycles(c))
        };
        self.busy = true;
        self.stats.busy_for(ctx.now(), dur, cycles);
        ctx.schedule_self(dur, Msg::PpuDone { job: job_id });
    }
}

impl Module<Msg> for Ppu {
    fn name(&self) -> &str {
        "ppu"
    }
    fn stats(&self) -> Option<&ModuleStats> {
        Some(&self.stats)
    }
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::PpuWake => self.try_start(ctx),
            Msg::PpuDone { job } => {
                {
                    let mut r = self.run.borrow_mut();
                    let j = r.jobs[job];
                    let n = r.req.n;
                    let acc = r.pending_acc[job].take().expect("acc parked");
                    if self.model.is_some() {
                        let mut block = vec![0i8; acc.len()];
                        let params = r.req.params.clone();
                        gemm::ppu_rows(&acc, &params, j.m0, j.m1, n, &mut block);
                        r.output[j.m0 * n..j.m1 * n].copy_from_slice(&block);
                    } else {
                        let raw = r.raw_acc.as_mut().expect("raw buffer");
                        raw[j.m0 * n..j.m1 * n].copy_from_slice(&acc);
                    }
                }
                self.busy = false;
                ctx.schedule(SimTime::ZERO, self.dma_mod, Msg::DmaOut { job });
                self.try_start(ctx);
            }
            _ => {}
        }
    }
}

/// Output DMA + completion detection.
struct OutputDma {
    run: Shared,
    busy_until: SimTime,
    stats: ModuleStats,
}

impl Module<Msg> for OutputDma {
    fn name(&self) -> &str {
        "output_dma"
    }
    fn stats(&self) -> Option<&ModuleStats> {
        Some(&self.stats)
    }
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::DmaOut { job } => {
                let done_at;
                let all_done;
                {
                    let mut r = self.run.borrow_mut();
                    let j = r.jobs[job];
                    let bytes =
                        ((j.m1 - j.m0) * r.req.n) as u64 * if r.cfg.ppu.is_some() { 1 } else { 4 };
                    r.report.bytes_out += bytes;
                    match r.mode {
                        ExecMode::Simulation => done_at = ctx.now(),
                        ExecMode::HardwareEval => {
                            let cycles = r.cfg.axi.transfer_cycles(bytes);
                            let clock = r.clock;
                            let start = self.busy_until.max(ctx.now());
                            let dur = clock.cycles(cycles);
                            self.busy_until = start + dur;
                            r.report.dma_out_cycles += cycles;
                            self.stats.busy_for(start, dur, cycles);
                            done_at = self.busy_until;
                        }
                    }
                    r.completed += 1;
                    all_done = r.completed == r.jobs.len();
                    if all_done {
                        r.report.total_time = done_at;
                    }
                }
                if all_done {
                    let delay = done_at.saturating_sub(ctx.now());
                    ctx.schedule_self(delay, Msg::DrainCheck);
                }
            }
            Msg::DrainCheck => ctx.stop(),
            _ => {}
        }
    }
}

/// The SA accelerator design (implements [`GemmAccel`]).
#[derive(Debug, Clone)]
pub struct SaDesign {
    /// Design parameters of this instance.
    pub cfg: SaConfig,
}

impl SaDesign {
    /// Build a design from an explicit configuration.
    pub fn new(cfg: SaConfig) -> Self {
        SaDesign { cfg }
    }

    /// The paper's final 16x16 design.
    pub fn paper() -> Self {
        Self::new(SaConfig::paper())
    }

    /// A design at one of the §IV-E3 sweep dimensions.
    pub fn with_dim(dim: usize) -> Self {
        Self::new(SaConfig::with_dim(dim))
    }

    /// The full simulation, with `trace` attached to the kernel.
    /// Trace recording only appends to a side buffer, so results and
    /// timings are identical whether the trace is enabled or not.
    fn run_inner(&self, req: &GemmRequest, mode: ExecMode, trace: Trace) -> (GemmResult, Trace) {
        let clock = self.clock();
        let dim = self.cfg.array.dim;
        let jobs: Vec<Job> = (0..req.m.div_ceil(dim))
            .map(|s| Job {
                id: s,
                m0: s * dim,
                m1: ((s + 1) * dim).min(req.m),
            })
            .collect();
        let n_jobs = jobs.len();
        let weight_bytes = if req.weights_resident {
            0
        } else {
            req.weight_bytes()
        };
        let run = Rc::new(RefCell::new(Run {
            req: req.clone(),
            mode,
            cfg: self.cfg.clone(),
            clock,
            jobs,
            next_job: 0,
            pending_acc: (0..n_jobs).map(|_| None).collect(),
            output: vec![0i8; req.m * req.n],
            raw_acc: if self.cfg.ppu.is_none() {
                Some(vec![0i32; req.m * req.n])
            } else {
                None
            },
            bytes_needed: weight_bytes + req.input_bytes(),
            bytes_arrived: 0,
            weight_bytes,
            completed: 0,
            report: AccelReport::default(),
        }));

        // ids: 0 dma, 1 ppu, 2 array, 3 sched, 4 ih
        let mut sim: Simulator<Msg> = Simulator::new().with_trace(trace);
        let array_fifo = sim.add_fifo(self.cfg.job_fifo_depth, None, None);
        let ppu_fifo = sim.add_fifo(2, None, None);
        let dma = sim.add_module(Box::new(OutputDma {
            run: run.clone(),
            busy_until: SimTime::ZERO,
            stats: ModuleStats::default(),
        }));
        let ppu = sim.add_module(Box::new(Ppu {
            run: run.clone(),
            model: self.cfg.ppu,
            in_fifo: ppu_fifo,
            array_mod: 2,
            dma_mod: dma,
            busy: false,
            stats: ModuleStats::default(),
        }));
        let array = sim.add_module(Box::new(SystolicArray {
            run: run.clone(),
            in_fifo: array_fifo,
            out_fifo: ppu_fifo,
            ppu_mod: ppu,
            sched_mod: 3,
            busy: false,
            parked: None,
            stats: ModuleStats::default(),
        }));
        assert_eq!(array, 2);
        let sched = sim.add_module(Box::new(Scheduler {
            run: run.clone(),
            array_fifo,
            array_mod: array,
            stats: ModuleStats::default(),
        }));
        assert_eq!(sched, 3);
        let ih = sim.add_module(Box::new(InputHandler {
            run: run.clone(),
            sched,
            stats: ModuleStats::default(),
        }));
        sim.set_fifo_wakes(
            array_fifo,
            Some(Wake {
                module: array,
                payload: Msg::ArrayWake,
            }),
            Some(Wake {
                module: sched,
                payload: Msg::TryDispatch,
            }),
        );
        sim.set_fifo_wakes(
            ppu_fifo,
            Some(Wake {
                module: ppu,
                payload: Msg::PpuWake,
            }),
            Some(Wake {
                module: array,
                payload: Msg::ArrayWake,
            }),
        );

        sim.schedule(SimTime::ZERO, ih, Msg::Start);
        let end = sim.run();

        let modules = sim.report();
        let trace = std::mem::replace(&mut sim.trace, Trace::disabled());
        drop(sim); // release the modules' Rc clones of the run state
        let mut run = Rc::try_unwrap(run)
            .unwrap_or_else(|_| panic!("run state still shared"))
            .into_inner();
        if run.report.total_time == SimTime::ZERO {
            run.report.total_time = end;
        }
        run.report.total_cycles = clock.cycles_at(run.report.total_time);
        run.report.modules = modules;
        assert_eq!(run.completed, run.jobs.len(), "all jobs must drain");
        (
            GemmResult {
                output: run.output,
                raw_acc: run.raw_acc,
                report: run.report,
            },
            trace,
        )
    }
}

impl GemmAccel for SaDesign {
    fn name(&self) -> &str {
        "sa"
    }

    fn clock(&self) -> Clock {
        Clock::from_mhz(self.cfg.clock_mhz)
    }

    fn weight_buffer_bytes(&self) -> usize {
        self.cfg.global_weight_buf.capacity_bytes
    }

    fn has_ppu(&self) -> bool {
        self.cfg.ppu.is_some()
    }

    fn run(&self, req: &GemmRequest, mode: ExecMode) -> GemmResult {
        self.run_inner(req, mode, Trace::disabled()).0
    }

    fn run_traced(
        &self,
        req: &GemmRequest,
        mode: ExecMode,
        trace_cap: usize,
    ) -> (GemmResult, Trace) {
        self.run_inner(req, mode, Trace::enabled(trace_cap))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::quant::quantize_multiplier;
    use crate::gemm::QGemmParams;

    fn request(m: usize, k: usize, n: usize, seed: u64) -> GemmRequest {
        let mut st = seed.max(1);
        let mut rnd = || {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            st
        };
        let w: Vec<i8> = (0..m * k).map(|_| (rnd() & 0xff) as u8 as i8).collect();
        let x: Vec<i8> = (0..k * n).map(|_| (rnd() & 0xff) as u8 as i8).collect();
        let (mult, shift) = quantize_multiplier(0.019);
        GemmRequest::new(m, k, n, w, x, QGemmParams::uniform(m, -25, mult, shift))
    }

    #[test]
    fn sa_output_matches_cpu_gemm() {
        let req = request(32, 48, 40, 5);
        let res = SaDesign::paper().run(&req, ExecMode::Simulation);
        let cpu = gemm::qgemm(&req.weights, &req.inputs, 32, 48, 40, &req.params, 1);
        assert_eq!(res.output, cpu);
    }

    #[test]
    fn sa_sizes_all_correct() {
        for dim in [4, 8, 16] {
            let req = request(24, 16, 20, dim as u64);
            let res = SaDesign::with_dim(dim).run(&req, ExecMode::Simulation);
            let cpu = gemm::qgemm(&req.weights, &req.inputs, 24, 16, 20, &req.params, 1);
            assert_eq!(res.output, cpu, "dim {dim}");
        }
    }

    #[test]
    fn bigger_array_is_faster() {
        let req = request(128, 256, 256, 3);
        let c4 = SaDesign::with_dim(4).run(&req, ExecMode::Simulation).report.total_cycles;
        let c8 = SaDesign::with_dim(8).run(&req, ExecMode::Simulation).report.total_cycles;
        let c16 = SaDesign::with_dim(16).run(&req, ExecMode::Simulation).report.total_cycles;
        assert!(c4 > c8 && c8 > c16, "{c4} {c8} {c16}");
        // compute-bound scaling is ~4x per size doubling
        let r = c8 as f64 / c16 as f64;
        assert!((2.0..=4.6).contains(&r), "8->16 ratio {r}");
    }

    #[test]
    fn serial_fill_slower_than_parallel() {
        let req = request(64, 128, 128, 7);
        let par = SaDesign::paper().run(&req, ExecMode::Simulation);
        let ser = SaDesign::new(SaConfig::serial_fill(16)).run(&req, ExecMode::Simulation);
        assert!(ser.report.total_cycles > par.report.total_cycles);
        assert_eq!(ser.output, par.output);
    }

    #[test]
    fn sa_hardware_mode_pays_transfers() {
        let req = request(32, 64, 64, 9);
        let sim = SaDesign::paper().run(&req, ExecMode::Simulation);
        let hw = SaDesign::paper().run(&req, ExecMode::HardwareEval);
        assert_eq!(sim.output, hw.output);
        assert!(hw.report.total_cycles > sim.report.total_cycles);
        assert!(hw.report.dma_in_cycles > 0);
    }

    #[test]
    fn sa_no_ppu_raw_output() {
        let req = request(16, 16, 16, 11);
        let res = SaDesign::new(SaConfig::no_ppu()).run(&req, ExecMode::Simulation);
        let raw = res.raw_acc.expect("raw acc");
        let mut acc = vec![0i32; 16 * 16];
        gemm::accumulate_rows(&req.weights, &req.inputs, 0, 16, 16, 16, &mut acc);
        assert_eq!(raw, acc);
    }

    #[test]
    fn sa_odd_shapes() {
        for (m, k, n) in [(1, 1, 1), (17, 3, 5), (33, 7, 2), (15, 9, 31)] {
            let req = request(m, k, n, (m + n) as u64);
            let res = SaDesign::paper().run(&req, ExecMode::Simulation);
            let cpu = gemm::qgemm(&req.weights, &req.inputs, m, k, n, &req.params, 1);
            assert_eq!(res.output, cpu, "({m},{k},{n})");
        }
    }

    #[test]
    fn sim_vs_hw_internal_cycles_close() {
        // The A1 experiment at unit level: accelerator-internal compute
        // cycles agree between the two modes (paper: >99%).
        let req = request(64, 96, 128, 13);
        let sim = SaDesign::paper().run(&req, ExecMode::Simulation);
        let hw = SaDesign::paper().run(&req, ExecMode::HardwareEval);
        let a = sim.report.compute_cycles as f64;
        let b = hw.report.compute_cycles as f64;
        assert!((a - b).abs() / a < 0.01, "sim {a} hw {b}");
    }
}
