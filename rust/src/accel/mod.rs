//! The case-study accelerator designs (paper §IV), built on the
//! [`crate::sysc`] TLM kernel from the shared component library.
//!
//! * [`vm`] — the Vector-MAC design: 4 GEMM units of 4x4 MAC tiles
//!   with adder trees, per-unit PPUs and an output crossbar (Fig. 3).
//! * [`sa`] — the Systolic-Array design: one output-stationary
//!   `dim x dim` MAC array fed by 2*dim data queues, single wide PPU
//!   (Fig. 4); `dim` in {4, 8, 16} (§IV-E3).
//! * [`components`] — the §IV-D component models both compose.
//!
//! Both designs implement [`types::GemmAccel`]: the driver hands them
//! [`types::GemmRequest`]s and gets bit-exact outputs plus an
//! [`types::AccelReport`] of cycles/bytes/utilization per component.

pub mod components;
pub mod sa;
pub mod types;
pub mod vm;

pub use sa::{SaConfig, SaDesign};
pub use types::{AccelReport, ExecMode, GemmAccel, GemmRequest, GemmResult};
pub use vm::{VmConfig, VmDesign};
