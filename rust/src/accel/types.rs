//! Shared accelerator-facing types: the GEMM transaction the driver
//! offloads, execution modes, and the per-run report.

use std::sync::Arc;

use crate::gemm::QGemmParams;
use crate::sysc::{ModuleStats, SimTime};

/// Execution mode of an accelerator run — the two SECDA design loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// SystemC-simulation loop: off-chip transfers are NOT modeled
    /// (paper §III-C/§III-E keeps simulation cheap by skipping them).
    Simulation,
    /// Hardware-evaluation loop: AXI DMA in/out transfers are modeled,
    /// exposing the off-chip bottlenecks simulation is blind to
    /// (paper §III-D; in the real flow this runs on the FPGA).
    HardwareEval,
}

/// One GEMM offload request (the paper's Fig. 2 transaction):
/// `out[i8; m*n] = PPU(W[m,k] @ X[k,n])`.
#[derive(Debug, Clone)]
pub struct GemmRequest {
    /// Output rows (weight rows / conv output channels).
    pub m: usize,
    /// Reduction depth (weight columns = activation rows).
    pub k: usize,
    /// Output columns (im2col patches).
    pub n: usize,
    /// Row-major `m x k` weights (driver-reshaped accelerator layout).
    pub weights: Arc<Vec<i8>>,
    /// Row-major `k x n` im2col activations.
    pub inputs: Arc<Vec<i8>>,
    /// Requantization parameters the PPU applies to the accumulators.
    pub params: Arc<QGemmParams>,
    /// Weights already resident in accelerator global buffers (layer
    /// weights are reused across an inference; the driver preloads
    /// them once). When false, the weight DMA is part of the run.
    pub weights_resident: bool,
}

impl GemmRequest {
    /// Build a request from owned buffers (validates shapes).
    pub fn new(
        m: usize,
        k: usize,
        n: usize,
        weights: Vec<i8>,
        inputs: Vec<i8>,
        params: QGemmParams,
    ) -> Self {
        Self::from_shared(m, k, n, Arc::new(weights), Arc::new(inputs), params)
    }

    /// Zero-copy variant: the driver shares one DMA input buffer across
    /// all tiling chunks of a layer.
    pub fn from_shared(
        m: usize,
        k: usize,
        n: usize,
        weights: Arc<Vec<i8>>,
        inputs: Arc<Vec<i8>>,
        params: QGemmParams,
    ) -> Self {
        assert_eq!(weights.len(), m * k);
        assert_eq!(inputs.len(), k * n);
        GemmRequest {
            m,
            k,
            n,
            weights,
            inputs,
            params: Arc::new(params),
            weights_resident: false,
        }
    }

    /// Weight bytes a non-resident run must move on-chip.
    pub fn weight_bytes(&self) -> u64 {
        (self.m * self.k) as u64
    }
    /// Activation bytes the input DMA moves per run.
    pub fn input_bytes(&self) -> u64 {
        (self.k * self.n) as u64
    }
    /// Output bytes as transferred: int8 with PPU on-accelerator,
    /// int32 when post-processing stays on the CPU (4x, §IV-E2).
    pub fn output_bytes(&self, ppu_on_accel: bool) -> u64 {
        let base = (self.m * self.n) as u64;
        if ppu_on_accel {
            base
        } else {
            base * 4
        }
    }
    /// Multiply-accumulates this GEMM performs (`m * k * n`).
    pub fn macs(&self) -> u64 {
        crate::gemm::mac_count(self.m, self.k, self.n)
    }
}

/// Result of simulating one GEMM on an accelerator design.
#[derive(Debug, Clone)]
pub struct GemmResult {
    /// Functional output, bit-exact vs [`crate::gemm::qgemm`]:
    /// int8 `m x n` when the PPU runs on the accelerator.
    pub output: Vec<i8>,
    /// Raw int32 accumulators (only when the PPU is disabled and
    /// unpacking falls back to the CPU, §IV-E2 ablation).
    pub raw_acc: Option<Vec<i32>>,
    /// Cycle/byte/utilization accounting for the run.
    pub report: AccelReport,
}

/// Per-run performance report — the §III-C simulation metrics.
#[derive(Debug, Clone, Default)]
pub struct AccelReport {
    /// End-to-end accelerator wall time for this GEMM.
    pub total_time: SimTime,
    /// Total fabric cycles (at the design clock).
    pub total_cycles: u64,
    /// Cycles the compute units spent doing MACs.
    pub compute_cycles: u64,
    /// Cycles loading weight tiles from global buffers.
    pub weight_load_cycles: u64,
    /// Compute-unit cycles lost to starvation/backpressure.
    pub stall_cycles: u64,
    /// Input-DMA cycles (0 in Simulation mode).
    pub dma_in_cycles: u64,
    /// Output-DMA cycles (0 in Simulation mode).
    pub dma_out_cycles: u64,
    /// Bytes moved on-chip over the AXI links.
    pub bytes_in: u64,
    /// Bytes moved off-chip over the AXI links.
    pub bytes_out: u64,
    /// Reads issued against the global weight buffer (the §IV-E2
    /// scheduler ablation observable: 4x fewer with the Scheduler).
    pub global_buffer_reads: u64,
    /// Per-module busy/utilization stats (name, stats).
    pub modules: Vec<(String, ModuleStats)>,
}

impl AccelReport {
    /// Utilization of the compute units over the run.
    pub fn compute_utilization(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.compute_cycles as f64 / self.total_cycles as f64
    }

    /// Merge a sub-report (e.g. one tiling chunk) into an aggregate.
    pub fn accumulate(&mut self, other: &AccelReport) {
        self.total_time += other.total_time;
        self.total_cycles += other.total_cycles;
        self.compute_cycles += other.compute_cycles;
        self.weight_load_cycles += other.weight_load_cycles;
        self.stall_cycles += other.stall_cycles;
        self.dma_in_cycles += other.dma_in_cycles;
        self.dma_out_cycles += other.dma_out_cycles;
        self.bytes_in += other.bytes_in;
        self.bytes_out += other.bytes_out;
        self.global_buffer_reads += other.global_buffer_reads;
    }
}

/// A GEMM accelerator design that the driver can target. Both case
/// study designs (VM, SA) and the VTA comparison model implement this.
pub trait GemmAccel {
    /// Short design name (used in reports and traces).
    fn name(&self) -> &str;
    /// Simulate one GEMM request end to end.
    fn run(&self, req: &GemmRequest, mode: ExecMode) -> GemmResult;
    /// Fabric clock of the design.
    fn clock(&self) -> crate::sysc::Clock;
    /// Capacity of the on-chip global weight buffer, bytes (drives the
    /// driver's weight-tiling decisions, §IV-E4).
    fn weight_buffer_bytes(&self) -> usize;
    /// Whether post-processing runs on the accelerator (PPU present).
    fn has_ppu(&self) -> bool;
    /// Largest reduction depth K a single offload can hold natively
    /// (None = unlimited, e.g. the SA design streams K).
    fn max_k(&self) -> Option<usize> {
        None
    }
    /// Simulate one GEMM with an enabled [`crate::sysc::Trace`]
    /// attached to the simulator, returning up to `trace_cap`
    /// recorded kernel events alongside the result. Tracing must be
    /// inert: the result is bit-identical to [`GemmAccel::run`].
    /// The default runs untraced and returns an empty trace (designs
    /// without internal simulators, e.g. analytic models, keep it).
    fn run_traced(
        &self,
        req: &GemmRequest,
        mode: ExecMode,
        trace_cap: usize,
    ) -> (GemmResult, crate::sysc::Trace) {
        let _ = trace_cap;
        (self.run(req, mode), crate::sysc::Trace::disabled())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> GemmRequest {
        GemmRequest::new(
            4,
            3,
            2,
            vec![1; 12],
            vec![2; 6],
            QGemmParams::uniform(4, 0, 1 << 30, 0),
        )
    }

    #[test]
    fn byte_accounting() {
        let r = req();
        assert_eq!(r.weight_bytes(), 12);
        assert_eq!(r.input_bytes(), 6);
        assert_eq!(r.output_bytes(true), 8);
        assert_eq!(r.output_bytes(false), 32); // int32 fallback is 4x
        assert_eq!(r.macs(), 24);
    }

    #[test]
    fn report_accumulate() {
        let mut a = AccelReport {
            total_cycles: 10,
            compute_cycles: 5,
            ..Default::default()
        };
        let b = AccelReport {
            total_cycles: 30,
            compute_cycles: 15,
            bytes_in: 7,
            ..Default::default()
        };
        a.accumulate(&b);
        assert_eq!(a.total_cycles, 40);
        assert_eq!(a.compute_cycles, 20);
        assert_eq!(a.bytes_in, 7);
        assert!((a.compute_utilization() - 0.5).abs() < 1e-12);
    }
}
