//! The Vector-MAC (VM) accelerator design — paper §IV-C1, Figure 3.
//!
//! Four SIMD-style GEMM units, each producing 4x4 output tiles through
//! 4-MAC adder trees. A Scheduler broadcasts weight stripes from the
//! global weight buffer to the units (once per stripe — the §IV-E2
//! improvement that cut global buffer reads 4x) and splits the N
//! dimension of the GEMM across the units. Each unit feeds a small
//! per-unit PPU; an Output Crossbar reorders the PPU tiles before the
//! output DMA.
//!
//! The TLM model runs at output-stripe transaction granularity: one
//! job = (4 weight rows) x (one unit's share of N columns). Cycle
//! costs come from the component models in
//! [`crate::accel::components`]; functional values are computed with
//! [`crate::gemm`] so results are bit-exact against the CPU path.

use std::cell::RefCell;
use std::rc::Rc;

use crate::accel::components::{AxiBus, BramArray, PpuModel, VmUnitModel};
use crate::accel::types::{AccelReport, ExecMode, GemmAccel, GemmRequest, GemmResult};
use crate::gemm;
use crate::sysc::{Clock, Ctx, Module, ModuleStats, SimTime, Simulator, Trace, Wake};

/// Configuration of a VM design instance (the §IV-E ablation knobs).
#[derive(Debug, Clone)]
pub struct VmConfig {
    /// Number of GEMM units (4 — the Zynq-7020 resource limit, §IV-C1).
    pub units: usize,
    /// Cycle model of one GEMM unit.
    pub unit: VmUnitModel,
    /// Fabric clock in MHz.
    pub clock_mhz: f64,
    /// Global weight buffer (capacity drives §IV-E4 weight tiling).
    pub global_weight_buf: BramArray,
    /// Global input buffer; its banking is the §IV-E1 ablation.
    pub global_input_buf: BramArray,
    /// Per-unit local weight tile buffer, bytes. Bounds the K a job
    /// can hold natively: `max_k = local_buf_bytes / tile_m`.
    pub local_buf_bytes: usize,
    /// Off-chip AXI DMA path.
    pub axi: AxiBus,
    /// None = post-processing stays on the CPU (§IV-E2 ablation).
    pub ppu: Option<PpuModel>,
    /// Scheduler broadcast of weight stripes; false = each unit
    /// fetches its own copy (4x global reads, §IV-E2).
    pub scheduler_broadcast: bool,
    /// Per-unit job FIFO depth (2 = double buffering).
    pub job_fifo_depth: usize,
}

impl VmConfig {
    /// The final paper design: 4 units, banked BRAMs, all AXI links,
    /// PPU on fabric, broadcasting scheduler.
    pub fn paper() -> Self {
        VmConfig {
            units: 4,
            unit: VmUnitModel::paper(),
            clock_mhz: 100.0,
            // 256 KiB global weight buffer over 8 banks
            global_weight_buf: BramArray::new(8, 8, 256 * 1024),
            // 96 KiB input buffer over 8 banks: 64 B/cycle feeds all
            // four units (4 x 16 B/cycle) without stalls
            global_input_buf: BramArray::new(8, 8, 96 * 1024),
            local_buf_bytes: 16 * 1024,
            axi: AxiBus::pynq_all_links(),
            ppu: Some(PpuModel::vm_small()),
            scheduler_broadcast: true,
            job_fifo_depth: 2,
        }
    }

    /// §IV-E2 ablation: post-processing on the CPU, int32 outputs.
    pub fn no_ppu() -> Self {
        VmConfig {
            ppu: None,
            ..Self::paper()
        }
    }

    /// §IV-E2 ablation: no weight-broadcast scheduler.
    pub fn no_scheduler() -> Self {
        VmConfig {
            scheduler_broadcast: false,
            ..Self::paper()
        }
    }

    /// §IV-E1 ablation: input data not distributed across BRAM banks.
    pub fn unbanked() -> Self {
        VmConfig {
            global_input_buf: BramArray::new(2, 8, 96 * 1024),
            ..Self::paper()
        }
    }

    /// §IV-E1 ablation: single AXI HP port (the first synthesis).
    pub fn single_link() -> Self {
        VmConfig {
            axi: AxiBus::pynq_single_link(),
            ..Self::paper()
        }
    }

    /// §IV-E4: the ResNet18 variant trading global buffer space for
    /// larger local buffers so K=4608 layers fit natively.
    pub fn resnet_variant() -> Self {
        VmConfig {
            global_weight_buf: BramArray::new(8, 8, 128 * 1024),
            local_buf_bytes: 32 * 1024,
            ..Self::paper()
        }
    }

    /// Largest K a single job can hold in the local tile buffer.
    pub fn max_k(&self) -> usize {
        self.local_buf_bytes / self.unit.tile_m
    }

    /// Input feed stall factor with all units active (§IV-E1).
    pub fn feed_stall(&self) -> f64 {
        let needed = self.units as u64 * self.unit.input_bytes_per_cycle();
        self.global_input_buf.stall_factor(needed)
    }
}

/// One TLM job: output rows `[m0, m1)` x columns `[n0, n1)` on `unit`.
#[derive(Debug, Clone, Copy)]
struct Job {
    id: usize,
    unit: usize,
    m0: usize,
    m1: usize,
    n0: usize,
    n1: usize,
    /// Weight-load cycles charged to this job at dispatch.
    load_cycles: u64,
}

impl Job {
    fn outputs(&self) -> u64 {
        ((self.m1 - self.m0) * (self.n1 - self.n0)) as u64
    }
}

/// Messages of the VM design's module graph.
#[derive(Debug, Clone)]
enum Msg {
    Start,
    /// A DMA burst-chunk worth of input data arrived (hardware mode).
    DmaChunk { bytes: u64 },
    TryDispatch,
    UnitWake,
    UnitDone { job: usize },
    PpuWake,
    PpuDone { job: usize },
    XbarJob { job: usize },
    DmaOut { job: usize },
    DrainCheck,
    /// FIFO token carrying a job id.
    Token(usize),
}

/// Shared run state (the TLM "memory": request data, results, counters).
struct Run {
    req: GemmRequest,
    mode: ExecMode,
    cfg: VmConfig,
    clock: Clock,
    jobs: Vec<Job>,
    next_job: usize,
    /// int32 accumulators parked between unit and PPU, per job.
    pending_acc: Vec<Option<Vec<i32>>>,
    output: Vec<i8>,
    raw_acc: Option<Vec<i32>>,
    bytes_needed: u64,
    bytes_arrived: u64,
    weight_bytes: u64,
    completed: usize,
    report: AccelReport,
}

impl Run {
    /// Streaming gate: job `j` may dispatch once the weights plus a
    /// proportional share of the input stream have arrived (hardware
    /// mode models DMA/compute overlap at stripe granularity).
    fn gate_ok(&self, job_idx: usize) -> bool {
        if self.mode == ExecMode::Simulation {
            return true;
        }
        let frac = (job_idx + 1) as f64 / self.jobs.len() as f64;
        let need =
            self.weight_bytes as f64 + frac * (self.bytes_needed - self.weight_bytes) as f64;
        (self.bytes_arrived as f64) >= need - 1e-9
    }
}

type Shared = Rc<RefCell<Run>>;

// ---------------------------------------------------------------------
// Modules
// ---------------------------------------------------------------------

/// Input Handler (§IV-D1): receives driver DMA data and distributes it
/// across the global BRAM banks.
struct InputHandler {
    run: Shared,
    sched: usize,
    stats: ModuleStats,
}

impl Module<Msg> for InputHandler {
    fn name(&self) -> &str {
        "input_handler"
    }
    fn stats(&self) -> Option<&ModuleStats> {
        Some(&self.stats)
    }
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Start => {
                let (mode, bytes, chunk, clock) = {
                    let r = self.run.borrow();
                    (r.mode, r.bytes_needed, r.cfg.axi.chunk_bytes(), r.clock)
                };
                match mode {
                    ExecMode::Simulation => {
                        // transfers unmodeled: everything is resident
                        self.run.borrow_mut().bytes_arrived = bytes;
                        ctx.schedule(SimTime::ZERO, self.sched, Msg::TryDispatch);
                    }
                    ExecMode::HardwareEval => {
                        // deliver the stream chunk by chunk
                        let mut sent = 0u64;
                        let mut t = SimTime::ZERO;
                        let me = ctx.current_module();
                        while sent < bytes {
                            let sz = chunk.min(bytes - sent);
                            let cycles = {
                                let r = self.run.borrow();
                                r.cfg.axi.transfer_cycles(sz)
                            };
                            t += clock.cycles(cycles);
                            sent += sz;
                            ctx.schedule(t, me, Msg::DmaChunk { bytes: sz });
                        }
                        let mut r = self.run.borrow_mut();
                        r.report.dma_in_cycles = clock.cycles_for(t);
                        r.report.bytes_in = bytes;
                    }
                }
            }
            Msg::DmaChunk { bytes } => {
                self.run.borrow_mut().bytes_arrived += bytes;
                self.stats.add_transaction(bytes);
                self.stats.busy_for(ctx.now(), SimTime::ZERO, 0);
                ctx.schedule(SimTime::ZERO, self.sched, Msg::TryDispatch);
            }
            _ => {}
        }
    }
}

/// Scheduler (§IV-D2): assigns stripes, broadcasts weight tiles,
/// maximizes weight reuse.
struct Scheduler {
    run: Shared,
    unit_fifos: Vec<usize>,
    unit_mods: Vec<usize>,
    stats: ModuleStats,
}

impl Module<Msg> for Scheduler {
    fn name(&self) -> &str {
        "scheduler"
    }
    fn stats(&self) -> Option<&ModuleStats> {
        Some(&self.stats)
    }
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if !matches!(msg, Msg::TryDispatch) {
            return;
        }
        loop {
            let (job, fifo, unit_mod) = {
                let r = self.run.borrow();
                if r.next_job >= r.jobs.len() {
                    return;
                }
                if !r.gate_ok(r.next_job) {
                    return; // re-woken on the next DMA chunk
                }
                let j = r.jobs[r.next_job];
                (j, self.unit_fifos[j.unit], self.unit_mods[j.unit])
            };
            if ctx.fifo_is_full(fifo) {
                return; // re-woken when the unit pops
            }
            // account the weight stripe read(s) from the global buffer
            {
                let mut r = self.run.borrow_mut();
                let stripe_bytes = r.cfg.unit.weight_stripe_bytes(r.req.k);
                let reads = if r.cfg.scheduler_broadcast {
                    // broadcast: one global read per stripe, shared by
                    // the unit quartet — charge it to unit-0 jobs only
                    if job.unit == 0 {
                        stripe_bytes
                    } else {
                        0
                    }
                } else {
                    stripe_bytes // every unit fetches its own copy: 4x
                };
                r.report.global_buffer_reads += reads;
                r.next_job += 1;
            }
            self.stats.add_transaction(0);
            let pushed = ctx.fifo_push(fifo, Msg::Token(job.id));
            debug_assert!(pushed);
            ctx.schedule(SimTime::ZERO, unit_mod, Msg::UnitWake);
        }
    }
}

/// One GEMM unit (Fig. 3): pops jobs, computes output-stationary 4x4
/// tiles, hands int32 stripes to its PPU.
struct GemmUnit {
    run: Shared,
    in_fifo: usize,
    out_fifo: usize, // to this unit's PPU
    ppu_mod: usize,
    sched_mod: usize,
    busy: bool,
    /// Job finished but waiting for space in the out FIFO.
    parked: Option<usize>,
    name: String,
    stats: ModuleStats,
}

impl GemmUnit {
    fn try_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.busy || self.parked.is_some() {
            return;
        }
        let Some(Msg::Token(job_id)) = ctx.fifo_pop(self.in_fifo) else {
            return;
        };
        // the scheduler may be blocked on this fifo: re-wake it
        ctx.schedule(SimTime::ZERO, self.sched_mod, Msg::TryDispatch);
        let (cycles, dur) = {
            let r = self.run.borrow();
            let j = r.jobs[job_id];
            let compute =
                r.cfg
                    .unit
                    .stripe_compute_cycles(r.req.k, j.n1 - j.n0, r.cfg.feed_stall());
            let total = j.load_cycles + compute;
            (total, r.clock.cycles(total))
        };
        self.busy = true;
        self.stats.busy_for(ctx.now(), dur, cycles);
        ctx.trace.record(ctx.now(), &self.name, || {
            format!("job {job_id} start ({cycles} cyc)")
        });
        ctx.schedule_self(dur, Msg::UnitDone { job: job_id });
    }

    fn finish(&mut self, job_id: usize, ctx: &mut Ctx<'_, Msg>) {
        // functional compute (bit-exact TLM): int32 stripe block
        {
            let mut r = self.run.borrow_mut();
            let j = r.jobs[job_id];
            let (k, n) = (r.req.k, r.req.n);
            let mut acc = vec![0i32; (j.m1 - j.m0) * (j.n1 - j.n0)];
            gemm::accumulate_block(
                &r.req.weights,
                &r.req.inputs,
                j.m0,
                j.m1,
                k,
                n,
                j.n0,
                j.n1,
                &mut acc,
            );
            let compute = r
                .cfg
                .unit
                .stripe_compute_cycles(k, j.n1 - j.n0, r.cfg.feed_stall());
            r.report.compute_cycles += compute;
            r.report.weight_load_cycles += j.load_cycles;
            r.pending_acc[job_id] = Some(acc);
        }
        self.busy = false;
        if ctx.fifo_push(self.out_fifo, Msg::Token(job_id)) {
            ctx.schedule(SimTime::ZERO, self.ppu_mod, Msg::PpuWake);
            self.try_start(ctx);
        } else {
            self.parked = Some(job_id);
            self.run.borrow_mut().report.stall_cycles += 1;
            // retried on out-fifo pop wake
        }
    }
}

impl Module<Msg> for GemmUnit {
    fn name(&self) -> &str {
        &self.name
    }
    fn stats(&self) -> Option<&ModuleStats> {
        Some(&self.stats)
    }
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::UnitWake => {
                // a parked job may now fit in the out fifo
                if let Some(job) = self.parked.take() {
                    if ctx.fifo_push(self.out_fifo, Msg::Token(job)) {
                        ctx.schedule(SimTime::ZERO, self.ppu_mod, Msg::PpuWake);
                    } else {
                        self.parked = Some(job);
                        return;
                    }
                }
                self.try_start(ctx);
            }
            Msg::UnitDone { job } => self.finish(job, ctx),
            _ => {}
        }
    }
}

/// Post-Processing Unit (§IV-D3); when `model` is None this module
/// forwards raw int32 stripes (CPU-side unpacking ablation).
struct Ppu {
    run: Shared,
    model: Option<PpuModel>,
    in_fifo: usize,
    unit_mod: usize,
    xbar_mod: usize,
    busy: bool,
    name: String,
    stats: ModuleStats,
}

impl Ppu {
    fn try_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.busy {
            return;
        }
        let Some(Msg::Token(job_id)) = ctx.fifo_pop(self.in_fifo) else {
            return;
        };
        // unit may be parked on this fifo
        ctx.schedule(SimTime::ZERO, self.unit_mod, Msg::UnitWake);
        let (cycles, dur) = {
            let r = self.run.borrow();
            let j = r.jobs[job_id];
            let c = match &self.model {
                Some(p) => p.cycles(j.outputs()),
                None => 1, // pass-through register stage
            };
            (c, r.clock.cycles(c))
        };
        self.busy = true;
        self.stats.busy_for(ctx.now(), dur, cycles);
        ctx.schedule_self(dur, Msg::PpuDone { job: job_id });
    }
}

impl Module<Msg> for Ppu {
    fn name(&self) -> &str {
        &self.name
    }
    fn stats(&self) -> Option<&ModuleStats> {
        Some(&self.stats)
    }
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::PpuWake => self.try_start(ctx),
            Msg::PpuDone { job } => {
                {
                    let mut r = self.run.borrow_mut();
                    let j = r.jobs[job];
                    let acc = r.pending_acc[job].take().expect("acc parked by unit");
                    let bn = j.n1 - j.n0;
                    let n = r.req.n;
                    if self.model.is_some() {
                        // requantize on-fabric and scatter into output
                        let mut block = vec![0i8; acc.len()];
                        let params = r.req.params.clone();
                        gemm::ppu_rows(&acc, &params, j.m0, j.m1, bn, &mut block);
                        for (bi, i) in (j.m0..j.m1).enumerate() {
                            r.output[i * n + j.n0..i * n + j.n1]
                                .copy_from_slice(&block[bi * bn..(bi + 1) * bn]);
                        }
                    } else {
                        // raw int32 goes back to the CPU
                        let raw = r.raw_acc.as_mut().expect("raw buffer");
                        for (bi, i) in (j.m0..j.m1).enumerate() {
                            raw[i * n + j.n0..i * n + j.n1]
                                .copy_from_slice(&acc[bi * bn..(bi + 1) * bn]);
                        }
                    }
                }
                self.busy = false;
                ctx.schedule(SimTime::ZERO, self.xbar_mod, Msg::XbarJob { job });
                self.try_start(ctx);
            }
            _ => {}
        }
    }
}

/// Output crossbar (§IV-D4): reorders PPU tiles into main-memory
/// order before the output DMA. Modeled as a serializing stage with a
/// busy-until horizon.
struct Crossbar {
    run: Shared,
    dma_mod: usize,
    busy_until: SimTime,
    stats: ModuleStats,
}

impl Module<Msg> for Crossbar {
    fn name(&self) -> &str {
        "output_crossbar"
    }
    fn stats(&self) -> Option<&ModuleStats> {
        Some(&self.stats)
    }
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if let Msg::XbarJob { job } = msg {
            let (cycles, clock) = {
                let r = self.run.borrow();
                let j = r.jobs[job];
                let bytes = j.outputs() * if r.cfg.ppu.is_some() { 1 } else { 4 };
                (bytes.div_ceil(16), r.clock) // 16 B/cycle reorder
            };
            let start = self.busy_until.max(ctx.now());
            let dur = clock.cycles(cycles);
            self.busy_until = start + dur;
            self.stats.busy_for(start, dur, cycles);
            let delay = self.busy_until.saturating_sub(ctx.now());
            ctx.schedule(delay, self.dma_mod, Msg::DmaOut { job });
        }
    }
}

/// Output DMA: models the transfer back to main memory (hardware mode)
/// and detects completion of the whole GEMM.
struct OutputDma {
    run: Shared,
    busy_until: SimTime,
    stats: ModuleStats,
}

impl Module<Msg> for OutputDma {
    fn name(&self) -> &str {
        "output_dma"
    }
    fn stats(&self) -> Option<&ModuleStats> {
        Some(&self.stats)
    }
    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::DmaOut { job } => {
                let done_at;
                let all_done;
                {
                    let mut r = self.run.borrow_mut();
                    let j = r.jobs[job];
                    let bytes = j.outputs() * if r.cfg.ppu.is_some() { 1 } else { 4 };
                    r.report.bytes_out += bytes;
                    match r.mode {
                        ExecMode::Simulation => {
                            done_at = ctx.now();
                        }
                        ExecMode::HardwareEval => {
                            let cycles = r.cfg.axi.transfer_cycles(bytes);
                            let clock = r.clock;
                            let start = self.busy_until.max(ctx.now());
                            let dur = clock.cycles(cycles);
                            self.busy_until = start + dur;
                            r.report.dma_out_cycles += cycles;
                            self.stats.busy_for(start, dur, cycles);
                            done_at = self.busy_until;
                        }
                    }
                    r.completed += 1;
                    all_done = r.completed == r.jobs.len();
                    if all_done {
                        r.report.total_time = done_at;
                    }
                }
                if all_done {
                    let delay = done_at.saturating_sub(ctx.now());
                    ctx.schedule_self(delay, Msg::DrainCheck);
                }
            }
            Msg::DrainCheck => {
                ctx.trace
                    .record(ctx.now(), "output_dma", || "gemm complete".into());
                ctx.stop();
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------
// The design
// ---------------------------------------------------------------------

/// The VM accelerator design (implements [`GemmAccel`]).
#[derive(Debug, Clone)]
pub struct VmDesign {
    /// Design parameters of this instance.
    pub cfg: VmConfig,
}

impl VmDesign {
    /// Build a design from an explicit configuration.
    pub fn new(cfg: VmConfig) -> Self {
        VmDesign { cfg }
    }

    /// The final paper design ([`VmConfig::paper`]).
    pub fn paper() -> Self {
        Self::new(VmConfig::paper())
    }

    fn build_jobs(&self, req: &GemmRequest) -> Vec<Job> {
        let cfg = &self.cfg;
        let tile_m = cfg.unit.tile_m;
        let stripes = req.m.div_ceil(tile_m);
        // N split across units in contiguous chunks
        let chunk_n = req.n.div_ceil(cfg.units);
        let mut jobs = Vec::new();
        let stripe_bytes = cfg.unit.weight_stripe_bytes(req.k);
        // local tile buffer fill rate: global weight buffer bandwidth
        let load_cycles = cfg.global_weight_buf.read_cycles(stripe_bytes);
        for s in 0..stripes {
            for u in 0..cfg.units {
                let n0 = u * chunk_n;
                if n0 >= req.n {
                    continue;
                }
                let n1 = ((u + 1) * chunk_n).min(req.n);
                jobs.push(Job {
                    id: jobs.len(),
                    unit: u,
                    m0: s * tile_m,
                    m1: ((s + 1) * tile_m).min(req.m),
                    n0,
                    n1,
                    load_cycles: if cfg.scheduler_broadcast {
                        load_cycles
                    } else {
                        // units contend for the global buffer port:
                        // each fetch serializes with its peers
                        load_cycles * cfg.units as u64
                    },
                });
            }
        }
        jobs
    }

    /// The full simulation, with `trace` attached to the kernel.
    /// Trace recording only appends to a side buffer, so results and
    /// timings are identical whether the trace is enabled or not.
    fn run_inner(&self, req: &GemmRequest, mode: ExecMode, trace: Trace) -> (GemmResult, Trace) {
        assert!(
            req.k <= self.cfg.max_k(),
            "K={} exceeds local buffer capacity (max_k={}); the driver \
             must split the GEMM (see driver::tiling)",
            req.k,
            self.cfg.max_k()
        );
        let clock = self.clock();
        let jobs = self.build_jobs(req);
        let n_jobs = jobs.len();
        let weight_bytes = if req.weights_resident {
            0
        } else {
            req.weight_bytes()
        };
        let run = Rc::new(RefCell::new(Run {
            req: req.clone(),
            mode,
            cfg: self.cfg.clone(),
            clock,
            jobs,
            next_job: 0,
            pending_acc: (0..n_jobs).map(|_| None).collect(),
            output: vec![0i8; req.m * req.n],
            raw_acc: if self.cfg.ppu.is_none() {
                Some(vec![0i32; req.m * req.n])
            } else {
                None
            },
            bytes_needed: weight_bytes + req.input_bytes(),
            bytes_arrived: 0,
            weight_bytes,
            completed: 0,
            report: AccelReport::default(),
        }));

        let mut sim: Simulator<Msg> = Simulator::new().with_trace(trace);
        // Module ids are sequential in creation order; precompute the
        // graph so every module can be constructed fully wired:
        //   0: output_dma, 1: crossbar,
        //   2+2u: ppu[u], 3+2u: gemm_unit[u],
        //   2+2*units: scheduler, 3+2*units: input_handler
        let units = self.cfg.units;
        let id_ppu = |u: usize| 2 + 2 * u;
        let id_unit = |u: usize| 3 + 2 * u;
        let id_sched = 2 + 2 * units;
        let id_ih = id_sched + 1;

        let dma_out = sim.add_module(Box::new(OutputDma {
            run: run.clone(),
            busy_until: SimTime::ZERO,
            stats: ModuleStats::default(),
        }));
        assert_eq!(dma_out, 0);
        let xbar = sim.add_module(Box::new(Crossbar {
            run: run.clone(),
            dma_mod: dma_out,
            busy_until: SimTime::ZERO,
            stats: ModuleStats::default(),
        }));
        assert_eq!(xbar, 1);
        let mut unit_fifos = Vec::new();
        let mut unit_mods = Vec::new();
        for u in 0..units {
            let in_fifo = sim.add_fifo(self.cfg.job_fifo_depth, None, None);
            let ppu_fifo = sim.add_fifo(2, None, None);
            let ppu = sim.add_module(Box::new(Ppu {
                run: run.clone(),
                model: self.cfg.ppu,
                in_fifo: ppu_fifo,
                unit_mod: id_unit(u),
                xbar_mod: xbar,
                busy: false,
                name: format!("ppu[{u}]"),
                stats: ModuleStats::default(),
            }));
            assert_eq!(ppu, id_ppu(u));
            let unit = sim.add_module(Box::new(GemmUnit {
                run: run.clone(),
                in_fifo,
                out_fifo: ppu_fifo,
                ppu_mod: ppu,
                sched_mod: id_sched,
                busy: false,
                parked: None,
                name: format!("gemm_unit[{u}]"),
                stats: ModuleStats::default(),
            }));
            assert_eq!(unit, id_unit(u));
            sim.set_fifo_wakes(
                in_fifo,
                Some(Wake {
                    module: unit,
                    payload: Msg::UnitWake,
                }),
                Some(Wake {
                    module: id_sched,
                    payload: Msg::TryDispatch,
                }),
            );
            sim.set_fifo_wakes(
                ppu_fifo,
                Some(Wake {
                    module: ppu,
                    payload: Msg::PpuWake,
                }),
                Some(Wake {
                    module: unit,
                    payload: Msg::UnitWake,
                }),
            );
            unit_fifos.push(in_fifo);
            unit_mods.push(unit);
        }
        let sched = sim.add_module(Box::new(Scheduler {
            run: run.clone(),
            unit_fifos: unit_fifos.clone(),
            unit_mods: unit_mods.clone(),
            stats: ModuleStats::default(),
        }));
        assert_eq!(sched, id_sched);
        let ih = sim.add_module(Box::new(InputHandler {
            run: run.clone(),
            sched,
            stats: ModuleStats::default(),
        }));
        assert_eq!(ih, id_ih);

        sim.schedule(SimTime::ZERO, ih, Msg::Start);
        let end = sim.run();

        let modules = sim.report();
        let trace = std::mem::replace(&mut sim.trace, Trace::disabled());
        drop(sim); // release the modules' Rc clones of the run state
        let mut run = Rc::try_unwrap(run)
            .unwrap_or_else(|_| panic!("run state still shared"))
            .into_inner();
        if run.report.total_time == SimTime::ZERO {
            run.report.total_time = end;
        }
        run.report.total_cycles = clock.cycles_at(run.report.total_time);
        run.report.modules = modules;
        assert_eq!(run.completed, run.jobs.len(), "all jobs must drain");
        (
            GemmResult {
                output: run.output,
                raw_acc: run.raw_acc,
                report: run.report,
            },
            trace,
        )
    }
}

impl GemmAccel for VmDesign {
    fn name(&self) -> &str {
        "vm"
    }

    fn clock(&self) -> Clock {
        Clock::from_mhz(self.cfg.clock_mhz)
    }

    fn weight_buffer_bytes(&self) -> usize {
        self.cfg.global_weight_buf.capacity_bytes
    }

    fn has_ppu(&self) -> bool {
        self.cfg.ppu.is_some()
    }

    fn max_k(&self) -> Option<usize> {
        Some(self.cfg.max_k())
    }

    fn run(&self, req: &GemmRequest, mode: ExecMode) -> GemmResult {
        self.run_inner(req, mode, Trace::disabled()).0
    }

    fn run_traced(
        &self,
        req: &GemmRequest,
        mode: ExecMode,
        trace_cap: usize,
    ) -> (GemmResult, Trace) {
        self.run_inner(req, mode, Trace::enabled(trace_cap))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::quant::quantize_multiplier;
    use crate::gemm::QGemmParams;

    fn request(m: usize, k: usize, n: usize, seed: u64) -> GemmRequest {
        let mut st = seed.max(1);
        let mut rnd = || {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            st
        };
        let w: Vec<i8> = (0..m * k).map(|_| (rnd() & 0xff) as u8 as i8).collect();
        let x: Vec<i8> = (0..k * n).map(|_| (rnd() & 0xff) as u8 as i8).collect();
        let (mult, shift) = quantize_multiplier(0.031);
        GemmRequest::new(m, k, n, w, x, QGemmParams::uniform(m, 50, mult, shift))
    }

    #[test]
    fn vm_output_matches_cpu_gemm() {
        let req = request(16, 32, 24, 7);
        let vm = VmDesign::paper();
        let res = vm.run(&req, ExecMode::Simulation);
        let cpu = gemm::qgemm(&req.weights, &req.inputs, 16, 32, 24, &req.params, 1);
        assert_eq!(res.output, cpu);
    }

    #[test]
    fn vm_hardware_mode_matches_functionally() {
        let req = request(12, 16, 20, 9);
        let vm = VmDesign::paper();
        let sim = vm.run(&req, ExecMode::Simulation);
        let hw = vm.run(&req, ExecMode::HardwareEval);
        assert_eq!(sim.output, hw.output);
        // hardware mode pays for DMA
        assert!(hw.report.dma_in_cycles > 0);
        assert!(hw.report.dma_out_cycles > 0);
        assert!(hw.report.total_cycles >= sim.report.total_cycles);
        assert_eq!(sim.report.dma_in_cycles, 0);
    }

    #[test]
    fn vm_no_ppu_returns_raw_acc() {
        let req = request(8, 8, 8, 3);
        let vm = VmDesign::new(VmConfig::no_ppu());
        let res = vm.run(&req, ExecMode::Simulation);
        let raw = res.raw_acc.expect("raw int32 output");
        // raw acc must match a plain accumulation (+ nothing else)
        let mut acc = vec![0i32; 8 * 8];
        gemm::accumulate_rows(&req.weights, &req.inputs, 0, 8, 8, 8, &mut acc);
        assert_eq!(raw, acc);
        // and 4x the output bytes of the PPU design
        let with_ppu = VmDesign::paper().run(&req, ExecMode::Simulation);
        assert_eq!(res.report.bytes_out, with_ppu.report.bytes_out * 4);
    }

    #[test]
    fn scheduler_reduces_global_reads_4x() {
        let req = request(32, 64, 32, 11);
        let with_sched = VmDesign::paper().run(&req, ExecMode::Simulation);
        let without = VmDesign::new(VmConfig::no_scheduler()).run(&req, ExecMode::Simulation);
        let ratio = without.report.global_buffer_reads as f64
            / with_sched.report.global_buffer_reads as f64;
        assert!((3.9..=4.1).contains(&ratio), "ratio {ratio}");
        // functional result identical
        assert_eq!(with_sched.output, without.output);
    }

    #[test]
    fn unbanked_input_buffer_stalls_compute() {
        let req = request(16, 64, 64, 13);
        let fast = VmDesign::paper().run(&req, ExecMode::Simulation);
        let slow = VmDesign::new(VmConfig::unbanked()).run(&req, ExecMode::Simulation);
        assert!(
            slow.report.total_cycles as f64 > fast.report.total_cycles as f64 * 2.0,
            "unbanked {} vs banked {}",
            slow.report.total_cycles,
            fast.report.total_cycles
        );
        assert_eq!(fast.output, slow.output);
    }

    #[test]
    fn single_axi_link_slows_hardware_mode() {
        let req = request(32, 128, 64, 17);
        let four = VmDesign::paper().run(&req, ExecMode::HardwareEval);
        let one = VmDesign::new(VmConfig::single_link()).run(&req, ExecMode::HardwareEval);
        assert!(one.report.total_cycles > four.report.total_cycles);
        assert_eq!(one.output, four.output);
    }

    #[test]
    fn resident_weights_skip_weight_dma() {
        let mut req = request(16, 32, 16, 19);
        let vm = VmDesign::paper();
        let cold = vm.run(&req, ExecMode::HardwareEval);
        req.weights_resident = true;
        let warm = vm.run(&req, ExecMode::HardwareEval);
        assert!(warm.report.bytes_in < cold.report.bytes_in);
        assert_eq!(warm.output, cold.output);
    }

    #[test]
    fn odd_shapes_handled() {
        // m not a multiple of tile_m, n not a multiple of units*tile_n
        for (m, k, n) in [(5, 7, 3), (1, 1, 1), (9, 11, 13), (6, 33, 50)] {
            let req = request(m, k, n, (m * 100 + n) as u64);
            let res = VmDesign::paper().run(&req, ExecMode::Simulation);
            let cpu = gemm::qgemm(&req.weights, &req.inputs, m, k, n, &req.params, 1);
            assert_eq!(res.output, cpu, "shape ({m},{k},{n})");
        }
    }

    #[test]
    #[should_panic(expected = "exceeds local buffer")]
    fn oversized_k_panics() {
        let cfg = VmConfig::paper();
        let k = cfg.max_k() + 1;
        let req = request(4, k, 4, 1);
        VmDesign::new(cfg).run(&req, ExecMode::Simulation);
    }

    #[test]
    fn report_utilization_sane() {
        let req = request(64, 128, 128, 23);
        let res = VmDesign::paper().run(&req, ExecMode::Simulation);
        assert!(res.report.total_cycles > 0);
        assert!(res.report.compute_cycles > 0);
        assert!(!res.report.modules.is_empty());
        assert!(res.report.global_buffer_reads > 0);
    }
}
