//! Reusable accelerator component models (paper §IV-D).
//!
//! Each component couples a *cycle cost model* (what the SystemC HLS
//! testbench feeds into the end-to-end simulation, §III-C) with the
//! functional behaviour needed for bit-exact TLM. The VM and SA designs
//! are compositions of these components with different parameters and
//! wiring — "adapting, reusing, and recomposing these components for
//! new designs" is the reuse property §IV-D calls out.

pub mod axi;
pub mod bram;
pub mod compute;
pub mod ppu;

pub use axi::AxiBus;
pub use bram::BramArray;
pub use compute::{SaArrayModel, VmUnitModel};
pub use ppu::PpuModel;
