//! On-chip BRAM buffer model.
//!
//! Zynq-7020 block RAM: 140 x 36Kb blocks, dual-ported, 4 bytes per
//! port per cycle. §IV-E1: the VM design initially starved its GEMM
//! units because input/weight data lived in too few BRAMs; the Input
//! Handler was extended to *distribute* incoming data across banks,
//! multiplying the accesses available per cycle.

/// A banked BRAM buffer (global weight/input buffer, local buffers).
#[derive(Debug, Clone, Copy)]
pub struct BramArray {
    /// Number of banks data is distributed across.
    pub banks: usize,
    /// Bytes readable per bank per cycle (dual-port 36Kb ≈ 8B/cycle
    /// using both ports).
    pub bytes_per_bank_cycle: usize,
    /// Total capacity in bytes.
    pub capacity_bytes: usize,
}

impl BramArray {
    /// Build a banked array (`banks` must be non-zero).
    pub fn new(banks: usize, bytes_per_bank_cycle: usize, capacity_bytes: usize) -> Self {
        assert!(banks > 0);
        BramArray {
            banks,
            bytes_per_bank_cycle,
            capacity_bytes,
        }
    }

    /// Aggregate read bandwidth, bytes per cycle.
    pub fn read_bytes_per_cycle(&self) -> u64 {
        (self.banks * self.bytes_per_bank_cycle) as u64
    }

    /// Cycles to stream `bytes` out of the array.
    pub fn read_cycles(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.read_bytes_per_cycle())
    }

    /// Stall factor for a consumer needing `needed` bytes/cycle:
    /// 1.0 when the banks keep up, >1.0 when reads serialize.
    pub fn stall_factor(&self, needed_bytes_per_cycle: u64) -> f64 {
        let have = self.read_bytes_per_cycle();
        if needed_bytes_per_cycle <= have {
            1.0
        } else {
            needed_bytes_per_cycle as f64 / have as f64
        }
    }

    /// Number of Zynq 36Kb BRAM blocks this array occupies (for the
    /// synthesis resource model).
    pub fn bram36_blocks(&self) -> u32 {
        let per_block = 36 * 1024 / 8; // 4.5 KiB usable
        (self.capacity_bytes as u32).div_ceil(per_block as u32).max(self.banks as u32)
    }

    /// Whether a buffer of `bytes` fits in the array's capacity.
    pub fn fits(&self, bytes: usize) -> bool {
        bytes <= self.capacity_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banking_multiplies_bandwidth() {
        let one = BramArray::new(1, 8, 64 * 1024);
        let eight = BramArray::new(8, 8, 64 * 1024);
        assert_eq!(one.read_bytes_per_cycle(), 8);
        assert_eq!(eight.read_bytes_per_cycle(), 64);
        assert_eq!(one.read_cycles(640), 80);
        assert_eq!(eight.read_cycles(640), 10);
    }

    #[test]
    fn stall_factor() {
        let b = BramArray::new(2, 8, 1024);
        assert_eq!(b.stall_factor(8), 1.0);
        assert_eq!(b.stall_factor(16), 1.0);
        assert_eq!(b.stall_factor(64), 4.0);
    }

    #[test]
    fn bram_block_estimate() {
        let b = BramArray::new(4, 8, 64 * 1024);
        // 64KiB / 4.5KiB ≈ 15 blocks
        assert!(b.bram36_blocks() >= 14 && b.bram36_blocks() <= 16);
        // at least one block per bank
        let tiny = BramArray::new(8, 8, 1024);
        assert_eq!(tiny.bram36_blocks(), 8);
    }

    #[test]
    fn capacity_check() {
        let b = BramArray::new(1, 8, 1000);
        assert!(b.fits(1000));
        assert!(!b.fits(1001));
    }
}
