//! AXI high-performance port / DMA model.
//!
//! The PYNQ-Z1 (Zynq-7020) exposes four 64-bit AXI HP ports between the
//! programmable logic and DDR. §IV-E1: the first VM synthesis revealed
//! an off-chip transfer bottleneck invisible in simulation; the fix was
//! to spread the memory-mapped buffers over *all* HP ports so data is
//! sent concurrently. This model captures exactly that knob.

/// Bandwidth model of the off-chip AXI DMA path.
#[derive(Debug, Clone, Copy)]
pub struct AxiBus {
    /// Active high-performance ports (1..=4 on the Zynq-7020).
    pub links: usize,
    /// Bytes per beat per link (64-bit ports = 8 bytes).
    pub bytes_per_beat: usize,
    /// Burst length in beats (AXI4 max 256); each burst pays setup.
    pub burst_beats: usize,
    /// Per-burst setup overhead, cycles (address phase + DMA engine).
    pub burst_setup_cycles: u64,
}

impl AxiBus {
    /// The PYNQ-Z1 configuration after the §IV-E1 fix (all 4 HP ports).
    pub fn pynq_all_links() -> Self {
        AxiBus {
            links: 4,
            bytes_per_beat: 8,
            burst_beats: 64,
            burst_setup_cycles: 12,
        }
    }

    /// The initial single-port design that exposed the bottleneck.
    pub fn pynq_single_link() -> Self {
        AxiBus {
            links: 1,
            ..Self::pynq_all_links()
        }
    }

    /// Cycles to move `bytes` across the bus (all links in parallel).
    pub fn transfer_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let per_link = bytes.div_ceil(self.links as u64);
        let beats = per_link.div_ceil(self.bytes_per_beat as u64);
        let bursts = beats.div_ceil(self.burst_beats as u64);
        beats + bursts * self.burst_setup_cycles
    }

    /// Peak payload bandwidth in bytes/cycle.
    pub fn peak_bytes_per_cycle(&self) -> f64 {
        (self.links * self.bytes_per_beat) as f64
    }

    /// Split a transfer into per-burst chunks: the hardware-eval loop
    /// delivers data incrementally so compute can start early (and the
    /// sim-accuracy experiment A1 can observe interleaving effects).
    pub fn chunk_bytes(&self) -> u64 {
        (self.links * self.bytes_per_beat * self.burst_beats) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_links_are_4x_faster_asymptotically() {
        let one = AxiBus::pynq_single_link();
        let four = AxiBus::pynq_all_links();
        let big = 1 << 20;
        let r = one.transfer_cycles(big) as f64 / four.transfer_cycles(big) as f64;
        assert!((3.5..=4.5).contains(&r), "ratio {r}");
    }

    #[test]
    fn zero_bytes_free() {
        assert_eq!(AxiBus::pynq_all_links().transfer_cycles(0), 0);
    }

    #[test]
    fn small_transfer_dominated_by_setup() {
        let bus = AxiBus::pynq_all_links();
        let c = bus.transfer_cycles(8);
        assert_eq!(c, 1 + bus.burst_setup_cycles);
    }

    #[test]
    fn transfer_monotonic_in_bytes() {
        let bus = AxiBus::pynq_all_links();
        let mut last = 0;
        for sz in [1u64, 64, 512, 4096, 65536, 1 << 20] {
            let c = bus.transfer_cycles(sz);
            assert!(c >= last);
            last = c;
        }
    }
}
