//! Post-Processing Unit model (paper §IV-D3).
//!
//! The PPU moved gemmlowp's "unpacking" (bias add, fixed-point
//! scaling, activation, narrowing to 8 bits) from the CPU into the
//! fabric, cutting output transfer bytes by 4x and giving the §IV-E2
//! end-to-end speedups. The VM design instantiates one small PPU per
//! GEMM unit plus an output crossbar; SA uses a single wide PPU.

/// Throughput model of one PPU instance.
#[derive(Debug, Clone, Copy)]
pub struct PpuModel {
    /// Output values requantized per cycle.
    pub lanes: usize,
    /// Pipeline latency in cycles (bias+SRDHM+shift+clamp stages).
    pub pipeline_latency: u64,
}

impl PpuModel {
    /// The per-GEMM-unit PPU of the VM design.
    pub fn vm_small() -> Self {
        PpuModel {
            lanes: 4,
            pipeline_latency: 5,
        }
    }

    /// The single wide PPU of the SA design.
    pub fn sa_wide() -> Self {
        PpuModel {
            lanes: 16,
            pipeline_latency: 5,
        }
    }

    /// Cycles to post-process `outputs` values.
    pub fn cycles(&self, outputs: u64) -> u64 {
        outputs.div_ceil(self.lanes as u64) + self.pipeline_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput() {
        let p = PpuModel::vm_small();
        assert_eq!(p.cycles(16), 4 + 5);
        let w = PpuModel::sa_wide();
        assert_eq!(w.cycles(256), 16 + 5);
    }

    #[test]
    fn wide_ppu_faster() {
        assert!(PpuModel::sa_wide().cycles(1024) < PpuModel::vm_small().cycles(1024));
    }
}
