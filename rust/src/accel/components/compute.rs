//! Compute-core cycle models: the VM GEMM unit and the SA systolic
//! array (paper Figs. 3 and 4).
//!
//! Both are *output-stationary* (§IV-C): an output tile's accumulators
//! stay in the unit until complete, so no intermediate int32 results
//! ever spill to buffers. The models return cycle counts per output
//! stripe; functional values are computed separately (bit-exactly) via
//! [`crate::gemm::accumulate_rows`] by the design state machines.

/// One VM "GEMM unit" (Fig. 3): a 4x4 grid of output accumulators,
/// each fed by `macs_per_output` MAC units reduced through an adder
/// tree; weights broadcast from a local tile buffer.
#[derive(Debug, Clone, Copy)]
pub struct VmUnitModel {
    /// Output tile height (4 in the paper).
    pub tile_m: usize,
    /// Output tile width (4 in the paper).
    pub tile_n: usize,
    /// Parallel MACs per output value (4 in the paper).
    pub macs_per_output: usize,
    /// Adder-tree latency in cycles (log2(macs) rounded up).
    pub tree_latency: u64,
    /// Whether the next input tile is prefetched while the current one
    /// computes. The paper's VM design loads the 4-column x-tile into
    /// unit registers and then streams it through the MACs, so fetch
    /// and compute serialize — one reason SA outperforms VM end to end
    /// (§V-B: "SA achieves slightly better performance, 16% on
    /// average").
    pub input_prefetch_overlap: bool,
}

impl VmUnitModel {
    /// The paper's VM GEMM-unit parameters (Fig. 3).
    pub fn paper() -> Self {
        VmUnitModel {
            tile_m: 4,
            tile_n: 4,
            macs_per_output: 4,
            tree_latency: 2,
            input_prefetch_overlap: false,
        }
    }

    /// MACs retired per cycle when fully fed.
    pub fn macs_per_cycle(&self) -> u64 {
        (self.tile_m * self.tile_n * self.macs_per_output) as u64
    }

    /// Cycles to compute one 4x4 output tile over a K-deep reduction:
    /// each output consumes `macs_per_output` K-elements per cycle.
    /// Without prefetch overlap the x-tile load serializes with the
    /// MAC streaming, doubling the K term.
    pub fn tile_cycles(&self, k: usize) -> u64 {
        let stream = (k as u64).div_ceil(self.macs_per_output as u64);
        let fetch = if self.input_prefetch_overlap { 0 } else { stream };
        stream + fetch + self.tree_latency + 1
    }

    /// Cycles for an output stripe of `tile_m` rows x `n` columns.
    /// `feed_stall` >= 1.0 models BRAM input starvation (§IV-E1).
    pub fn stripe_compute_cycles(&self, k: usize, n: usize, feed_stall: f64) -> u64 {
        let tiles = (n as u64).div_ceil(self.tile_n as u64);
        let base = tiles * self.tile_cycles(k);
        (base as f64 * feed_stall).ceil() as u64
    }

    /// Input bytes the unit consumes per compute cycle when unstalled:
    /// `tile_n` columns x `macs_per_output` K-lanes (int8).
    pub fn input_bytes_per_cycle(&self) -> u64 {
        (self.tile_n * self.macs_per_output) as u64
    }

    /// Bytes of one weight tile block (`tile_m` rows x k).
    pub fn weight_stripe_bytes(&self, k: usize) -> u64 {
        (self.tile_m * k) as u64
    }
}

/// The SA design's `dim x dim` output-stationary systolic array
/// (Fig. 4): weights move vertically, inputs horizontally, one hop per
/// cycle; boundary PEs are fed from `2*dim` data queues.
#[derive(Debug, Clone, Copy)]
pub struct SaArrayModel {
    /// Array dimension (4, 8 or 16 in §IV-E3).
    pub dim: usize,
    /// Whether the Scheduler refills the data queues in parallel with
    /// array compute (§IV-E1's SA improvement). When false the fill
    /// serializes with compute.
    pub parallel_fill: bool,
}

impl SaArrayModel {
    /// The paper's SA array at a given dimension (Fig. 4, §IV-E3).
    pub fn paper(dim: usize) -> Self {
        SaArrayModel {
            dim,
            parallel_fill: true,
        }
    }

    /// MACs retired per cycle when fully fed (`dim^2`).
    pub fn macs_per_cycle(&self) -> u64 {
        (self.dim * self.dim) as u64
    }

    /// Cycles for one `dim x dim` output tile with K-deep reduction:
    /// K streaming steps plus 2*dim skew (fill + drain wavefronts).
    pub fn tile_cycles(&self, k: usize) -> u64 {
        let stream = k as u64 + 2 * self.dim as u64;
        if self.parallel_fill {
            stream
        } else {
            // queues must be refilled between tiles: dim queues x k
            // values each, 4 bytes/cycle queue write port
            stream + (k as u64 * self.dim as u64) / 4
        }
    }

    /// Cycles for an output stripe of `dim` rows x `n` columns.
    pub fn stripe_compute_cycles(&self, k: usize, n: usize) -> u64 {
        let tiles = (n as u64).div_ceil(self.dim as u64);
        tiles * self.tile_cycles(k)
    }

    /// Queue count feeding the array boundary (32 in the 16x16 paper
    /// design: 16 weight columns + 16 input rows).
    pub fn queue_count(&self) -> usize {
        2 * self.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vm_paper_parameters() {
        let u = VmUnitModel::paper();
        assert_eq!(u.macs_per_cycle(), 64);
        assert_eq!(u.input_bytes_per_cycle(), 16);
        // K=64: 16 k-steps + 16 fetch (no prefetch overlap) + tree 2 + wb 1
        assert_eq!(u.tile_cycles(64), 35);
        // a double-buffered variant overlaps the fetch
        let db = VmUnitModel {
            input_prefetch_overlap: true,
            ..u
        };
        assert_eq!(db.tile_cycles(64), 19);
    }

    #[test]
    fn vm_stall_scales_cycles() {
        let u = VmUnitModel::paper();
        let fast = u.stripe_compute_cycles(64, 256, 1.0);
        let slow = u.stripe_compute_cycles(64, 256, 2.0);
        assert_eq!(slow, fast * 2);
    }

    #[test]
    fn sa_tile_cycles() {
        let a = SaArrayModel::paper(16);
        assert_eq!(a.tile_cycles(128), 128 + 32);
        assert_eq!(a.macs_per_cycle(), 256);
        assert_eq!(a.queue_count(), 32);
    }

    #[test]
    fn sa_serial_fill_is_slower() {
        let par = SaArrayModel::paper(16);
        let ser = SaArrayModel {
            parallel_fill: false,
            ..par
        };
        assert!(ser.tile_cycles(256) > par.tile_cycles(256));
    }

    #[test]
    fn sa_dim_throughput_scaling() {
        // compute-bound stripe cycle totals scale ~1/d^2 per full GEMM:
        // (m/d stripes) x (n/d tiles) x (k + 2d)
        let k = 512;
        let n = 1024;
        let m = 256;
        let cyc = |d: usize| {
            let a = SaArrayModel::paper(d);
            (m as u64).div_ceil(d as u64) * a.stripe_compute_cycles(k, n)
        };
        let c8 = cyc(8);
        let c16 = cyc(16);
        let ratio = c8 as f64 / c16 as f64;
        assert!((3.0..=4.5).contains(&ratio), "ratio {ratio}");
    }
}
