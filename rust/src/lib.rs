//! # SECDA — SystemC-Enabled Co-design of DNN Accelerators (reproduction)
//!
//! A full-system reproduction of *SECDA: Efficient Hardware/Software
//! Co-Design of FPGA-based DNN Accelerators for Edge Inference*
//! (Haris et al., 2021) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the SECDA system itself: a SystemC-like
//!   TLM simulation kernel ([`sysc`]), the paper's two accelerator
//!   designs ([`accel::vm`], [`accel::sa`]) built from a shared
//!   component library, the co-designed accelerator driver ([`driver`]),
//!   a TFLite-like quantized inference framework with the GEMM delegate
//!   hook ([`framework`]), the gemmlowp-style CPU baseline ([`gemm`]),
//!   PYNQ-Z1 timing/energy models ([`perf`]), the synthesis model
//!   ([`synth`]), a VTA-like comparison accelerator ([`vta`]), the
//!   PJRT runtime that executes the AOT-compiled artifacts ([`runtime`]),
//!   the serving coordinator ([`coordinator`]) that schedules
//!   request streams across a pool of accelerator instances with
//!   bucket-aware batching and HW/SW partitioning, the elastic
//!   reprovisioning layer ([`elastic`]) that swaps what the fabric
//!   holds to match the observed traffic, the fleet tier ([`fleet`])
//!   that shards the coordinator across N modeled boards behind a
//!   gossip-fed cost-model router with fleet-wide bitstream-portfolio
//!   planning, the design-space exploration engine ([`dse`]) that runs
//!   parallel memoized simulation campaigns over the SA/VM candidate
//!   space and hands Pareto-optimal designs to the planner, and the observability
//!   layer ([`obs`]) — structured spans, streaming histograms, and
//!   Perfetto-loadable trace export across the whole serving stack.
//! * **Layer 2 (python/compile/model.py)** — the accelerated subgraph
//!   (int8 GEMM-convolution) in JAX, AOT-lowered per shape bucket.
//! * **Layer 1 (python/compile/kernels/qgemm.py)** — the Pallas
//!   output-stationary int8 GEMM kernel with fused PPU epilogue.
//!
//! Python never runs on the inference path: `make artifacts` lowers the
//! kernels once to HLO text; the Rust binary loads and executes them via
//! the PJRT C API.
//!
//! See `ARCHITECTURE.md` for the layer map and a request's life
//! through the serving stack, and `README.md` for the quickstart
//! (build/test/bench commands and feature flags).

// Every layer is held to full rustdoc coverage; `cargo doc` runs with
// `-D warnings` in CI.
#![warn(missing_docs)]

pub mod accel;
pub mod cli;
pub mod coordinator;
pub mod driver;
pub mod dse;
pub mod elastic;
pub mod fleet;
pub mod framework;
pub mod gemm;
pub mod obs;
pub mod perf;
pub mod runtime;
pub mod synth;
pub mod sysc;
pub mod vta;
