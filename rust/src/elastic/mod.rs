//! Elastic pool reconfiguration — traffic-aware FPGA reprovisioning
//! that closes the co-design loop at serving time.
//!
//! SECDA's point is that the SA and VM designs have different sweet
//! spots, and that the Zynq-7020 budget caps what fits on the fabric
//! ([`crate::synth::Resources::zynq7020`]): one paper design consumes
//! most of the DSP budget, so the fabric holds the SA *or* the VM at
//! any moment. The serving coordinator nevertheless froze its pool
//! composition at construction — every traffic mix got whatever
//! `sa_workers`/`vm_workers`/`cpu_workers` said at startup. Related
//! co-design work (Hao et al., arXiv:1904.04421) and the FPGA
//! accelerator survey (Guo et al., arXiv:1712.08934) both treat
//! reconfigurability as the FPGA's defining advantage; this subsystem
//! exploits it with three parts:
//!
//! * [`estimate`] — a **workload estimator**: folds completed-request
//!   GEMM shapes, arrival gaps and SLO outcomes into a windowed
//!   [`TrafficProfile`] (per-shape demand, arrival rate, SLO
//!   pressure).
//! * [`plan`] — a **composition planner**: enumerates `(n_sa, n_vm,
//!   n_cpu)` pool compositions gated by
//!   [`crate::synth::Resources::fits_in`] against the device budget,
//!   scores each with the PR-4 cost model
//!   ([`crate::coordinator::CostModel`]) against the observed profile,
//!   and charges a modeled bitstream-reprogramming cost
//!   ([`crate::synth::reconfig_time`]) per swapped-in instance — a
//!   migration is proposed only when the projected steady-state win
//!   over the profile window exceeds that cost plus a hysteresis
//!   margin.
//! * [`controller`] — the **elastic controller** wired into
//!   [`crate::coordinator::Coordinator`]: it observes completions,
//!   pools per-design cost observations across workers (so
//!   measurements survive the instance that made them), evaluates the
//!   planner on a configurable interval, and records the composition
//!   timeline. The coordinator applies an emitted plan through
//!   [`crate::coordinator::Coordinator::reconfigure`], which retires /
//!   spawns workers, migrates queued requests, and delays swapped-in
//!   instances by the bitstream load time — in both execution modes
//!   (threaded workers are per-drain, so they park at the scope join
//!   and respawn on the reconfigured pool at the next drain).
//!
//! Configuration lives on
//! [`crate::coordinator::CoordinatorConfig::elastic`]
//! ([`ElasticConfig`]): evaluation interval, estimator window,
//! hysteresis margin, maximum swaps per step, CPU-worker bound and the
//! resource budget. `elastic: None` (the default) reproduces the
//! static coordinator exactly; so does `max_swaps: 0` (pinned by a
//! property test).
//!
//! ```no_run
//! use std::sync::Arc;
//! use secda::coordinator::{Coordinator, CoordinatorConfig};
//! use secda::elastic::ElasticConfig;
//! use secda::framework::{models, tensor::Tensor};
//!
//! let g = Arc::new(models::by_name("mobilenet_v1").unwrap());
//! let cfg = CoordinatorConfig {
//!     sa_workers: 0,
//!     vm_workers: 1, // mis-provisioned on purpose
//!     cpu_workers: 0,
//!     elastic: Some(ElasticConfig::default()),
//!     ..CoordinatorConfig::default()
//! };
//! let mut coord = Coordinator::new(cfg);
//! let input = Tensor::zeros(g.input_shape.clone(), g.input_qp);
//! coord.submit(g.clone(), input).unwrap();
//! coord.run_until_idle();
//! // after enough traffic the controller swaps the bitstream:
//! for swap in coord.elastic_history() {
//!     println!("{} -> {} at {}", swap.from, swap.to, swap.at);
//! }
//! ```

pub mod controller;
pub mod estimate;
pub mod plan;

pub use controller::{ElasticController, SwapRecord};
pub use estimate::{TrafficProfile, WorkloadEstimator};
pub use plan::{Composition, CompositionPlanner, DesignCosts, ReconfigPlan};

use crate::sysc::SimTime;

/// Policy knobs of the elastic layer, carried on
/// [`crate::coordinator::CoordinatorConfig::elastic`].
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Minimum modeled time between planner evaluations (evaluations
    /// happen at drain boundaries, rate-limited by this interval).
    pub eval_interval: SimTime,
    /// Estimator window: completions older than this no longer shape
    /// the traffic profile.
    pub window: SimTime,
    /// Minimum completions inside the window before the planner is
    /// consulted at all (no reprovisioning off a handful of samples).
    pub min_samples: usize,
    /// Hysteresis margin: a reconfiguration is taken only when the
    /// projected win over the profile window exceeds the modeled
    /// reconfiguration cost *plus* this margin. Guards against
    /// swap churn on noise-level wins.
    pub hysteresis: SimTime,
    /// Maximum instances swapped (added or removed) per planner step.
    /// `0` pins the pool: the controller observes but never migrates
    /// (bit-identical to a static pool, pinned by a property test).
    pub max_swaps: usize,
    /// Upper bound on CPU-only workers the planner may provision. CPU
    /// workers consume no fabric, but on the two-core PYNQ A9 they
    /// contend with the drivers' own prep threads — this knob bounds
    /// that (`0` makes planning a pure which-bitstream decision).
    pub cpu_max: usize,
    /// Device resource budget every emitted composition must fit.
    pub budget: crate::synth::Resources,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            eval_interval: SimTime::ms(250),
            window: SimTime::ms(2_000),
            min_samples: 8,
            hysteresis: SimTime::ms(25),
            max_swaps: 1,
            cpu_max: 1,
            budget: crate::synth::Resources::zynq7020(),
        }
    }
}
