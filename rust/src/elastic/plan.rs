//! The composition planner: which pool composition should the fabric
//! hold for the observed traffic?
//!
//! A [`CompositionPlanner`] enumerates every `(n_sa, n_vm, n_cpu)`
//! pool composition whose fabric footprint fits the device budget
//! (the SECDA feasibility gate, [`crate::synth::Resources::fits_in`] —
//! on the Zynq-7020 each paper design consumes most of the DSP budget,
//! so the accelerator part degenerates to *which* bitstream, SA or VM,
//! plus CPU workers), scores each against a [`TrafficProfile`] with
//! the per-design [`CostModel`]s, and proposes a [`ReconfigPlan`] only
//! when the projected win over the profile window exceeds the modeled
//! bitstream-reprogramming cost ([`crate::synth::reconfig_time`]) plus
//! the configured hysteresis margin.
//!
//! Scoring model: for each worker kind the planner computes the mean
//! modeled request service time over the profile — the per-request
//! framework overhead plus, per GEMM in the demand histogram, the
//! cheaper of the CPU estimate and the *weights-resident* accelerator
//! estimate (steady-state serving batches same-model requests warm;
//! the cold first touch is part of what the hysteresis margin
//! absorbs). A composition's capacity is the sum of its workers'
//! service rates; its score is the time that capacity needs to serve
//! the window's demand. Lower is better. The estimates come from the
//! same [`CostModel`] the offload planner and admission control use,
//! sharpened by pooled per-design observations ([`DesignCosts`]).

use std::fmt;

use crate::accel::{SaConfig, VmConfig};
use crate::coordinator::{CostModel, WorkerKind};
use crate::synth::{self, Resources};
use crate::sysc::SimTime;

use super::estimate::TrafficProfile;
use super::ElasticConfig;

/// A pool composition: how many instances of each worker kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Composition {
    /// Systolic-array instances.
    pub sa: usize,
    /// Vector-MAC instances.
    pub vm: usize,
    /// CPU-only workers.
    pub cpu: usize,
}

impl Composition {
    /// A composition from explicit counts.
    pub fn new(sa: usize, vm: usize, cpu: usize) -> Self {
        Composition { sa, vm, cpu }
    }

    /// Total workers of any kind.
    pub fn total(&self) -> usize {
        self.sa + self.vm + self.cpu
    }

    /// Fabric footprint of this composition: the paper designs'
    /// per-instance estimates scaled by instance count (CPU workers
    /// consume no fabric).
    pub fn resources(&self) -> Resources {
        let sa = synth::sa_resources(&SaConfig::paper()).scaled(self.sa as u32);
        let vm = synth::vm_resources(&VmConfig::paper()).scaled(self.vm as u32);
        sa.add(&vm)
    }

    /// Does this composition's fabric footprint fit `budget`?
    pub fn fits(&self, budget: &Resources) -> bool {
        self.resources().fits_in(budget)
    }

    /// Instances swapped getting here from `from`: the larger of the
    /// adds and the removals (an SA→VM exchange is one swap — one
    /// instance retired, one programmed in its place).
    pub fn swaps_from(&self, from: &Composition) -> usize {
        let added = self.sa.saturating_sub(from.sa)
            + self.vm.saturating_sub(from.vm)
            + self.cpu.saturating_sub(from.cpu);
        let removed = from.sa.saturating_sub(self.sa)
            + from.vm.saturating_sub(self.vm)
            + from.cpu.saturating_sub(self.cpu);
        added.max(removed)
    }
}

impl fmt::Display for Composition {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}xSA {}xVM {}xCPU", self.sa, self.vm, self.cpu)
    }
}

/// Per-design cost views: one [`CostModel`] per worker kind, pooled
/// from every worker of that kind that has ever run. Observations a
/// retired instance made keep informing the planner after a
/// reconfiguration — without this, swapping a design out would also
/// forget why it was (or wasn't) worth having.
#[derive(Debug, Clone)]
pub struct DesignCosts {
    sa: CostModel,
    vm: CostModel,
    cpu: CostModel,
}

impl DesignCosts {
    /// Fresh per-design models (analytic priors only) for workers with
    /// `threads` CPU threads and the given offload sync overhead.
    pub fn new(threads: usize, sync_overhead: SimTime) -> Self {
        Self::for_designs(
            threads,
            sync_overhead,
            &SaConfig::paper(),
            &VmConfig::paper(),
        )
    }

    /// Per-design models whose SA/VM priors run explicit (e.g.
    /// DSE-discovered) designs. Identical to [`DesignCosts::new`] on
    /// the paper configurations.
    pub fn for_designs(
        threads: usize,
        sync_overhead: SimTime,
        sa: &SaConfig,
        vm: &VmConfig,
    ) -> Self {
        DesignCosts {
            sa: CostModel::for_sa_design(sa, threads, sync_overhead),
            vm: CostModel::for_vm_design(vm, threads, sync_overhead),
            cpu: CostModel::new(threads, sync_overhead),
        }
    }

    /// Pool a worker's observations into its kind's model.
    pub fn absorb(&mut self, kind: WorkerKind, observed: &CostModel) {
        self.model_mut(kind).absorb(observed);
    }

    /// The cost model for one worker kind.
    pub fn model(&self, kind: WorkerKind) -> &CostModel {
        match kind {
            WorkerKind::Sa => &self.sa,
            WorkerKind::Vm => &self.vm,
            WorkerKind::Cpu => &self.cpu,
        }
    }

    /// Mutable access (tests inject synthetic observations through
    /// [`CostModel::observe`]).
    pub fn model_mut(&mut self, kind: WorkerKind) -> &mut CostModel {
        match kind {
            WorkerKind::Sa => &mut self.sa,
            WorkerKind::Vm => &mut self.vm,
            WorkerKind::Cpu => &mut self.cpu,
        }
    }
}

/// A proposed reconfiguration, with the projection that justified it.
#[derive(Debug, Clone)]
pub struct ReconfigPlan {
    /// Composition the pool held when the plan was made.
    pub from: Composition,
    /// Composition to migrate to.
    pub to: Composition,
    /// Projected time for `from` to serve the profile window's demand.
    pub projected_current: SimTime,
    /// Projected time for `to` to serve the same demand.
    pub projected_best: SimTime,
    /// Modeled bitstream-load cost of the migration (per swapped-in
    /// accelerator instance; retiring an instance is free).
    pub reconfig_cost: SimTime,
    /// Instances swapped ([`Composition::swaps_from`]).
    pub swaps: usize,
}

impl ReconfigPlan {
    /// The projected steady-state win: current minus best.
    pub fn projected_win(&self) -> SimTime {
        self.projected_current.saturating_sub(self.projected_best)
    }
}

/// Enumerates and scores resource-feasible pool compositions.
#[derive(Debug, Clone)]
pub struct CompositionPlanner {
    budget: Resources,
    sa_unit: Resources,
    vm_unit: Resources,
}

impl CompositionPlanner {
    /// A planner gated by the given device budget (normally
    /// [`Resources::zynq7020`]).
    pub fn new(budget: Resources) -> Self {
        Self::with_designs(budget, &SaConfig::paper(), &VmConfig::paper())
    }

    /// A planner whose per-instance footprints come from explicit SA
    /// and VM designs — the hand-off point for DSE-discovered
    /// frontiers ([`crate::dse::ProfileReport::best_sa`]/`best_vm`):
    /// registering a frontier design here makes every enumerated
    /// composition, score and reconfiguration cost price that design's
    /// fabric, not the paper's. Identical to [`CompositionPlanner::new`]
    /// on the paper configurations.
    pub fn with_designs(budget: Resources, sa: &SaConfig, vm: &VmConfig) -> Self {
        CompositionPlanner {
            budget,
            sa_unit: synth::sa_resources(sa),
            vm_unit: synth::vm_resources(vm),
        }
    }

    /// `comp`'s fabric footprint under this planner's registered
    /// per-instance designs (unlike [`Composition::resources`], which
    /// always prices the paper designs).
    pub fn composition_resources(&self, comp: &Composition) -> Resources {
        self.sa_unit
            .scaled(comp.sa as u32)
            .add(&self.vm_unit.scaled(comp.vm as u32))
    }

    /// Every composition whose fabric footprint fits the budget, with
    /// at most `cpu_max` CPU workers and at least one worker total, in
    /// a fixed deterministic order (SA count, then VM count, then CPU
    /// count, each ascending).
    pub fn enumerate(&self, cpu_max: usize) -> Vec<Composition> {
        let mut out = Vec::new();
        for sa in 0..=16usize {
            if !self.sa_unit.scaled(sa as u32).fits_in(&self.budget) {
                break;
            }
            for vm in 0..=16usize {
                let fabric = self
                    .sa_unit
                    .scaled(sa as u32)
                    .add(&self.vm_unit.scaled(vm as u32));
                if !fabric.fits_in(&self.budget) {
                    break;
                }
                for cpu in 0..=cpu_max {
                    let comp = Composition::new(sa, vm, cpu);
                    if comp.total() >= 1 {
                        out.push(comp);
                    }
                }
            }
        }
        out
    }

    /// Projected time for `comp` to serve the profile window's demand
    /// (see the module doc for the capacity model). [`SimTime::MAX`]
    /// for a composition with no workers.
    pub fn score(
        &self,
        comp: &Composition,
        profile: &TrafficProfile,
        costs: &DesignCosts,
    ) -> SimTime {
        let kinds = [
            (WorkerKind::Sa, comp.sa),
            (WorkerKind::Vm, comp.vm),
            (WorkerKind::Cpu, comp.cpu),
        ];
        let mut capacity_rps = 0.0f64;
        for (kind, count) in kinds {
            if count == 0 {
                continue;
            }
            let t = Self::mean_request_secs(costs.model(kind), kind, profile);
            if t > 0.0 {
                capacity_rps += count as f64 / t;
            }
        }
        if capacity_rps <= 0.0 || profile.requests == 0 {
            return SimTime::MAX;
        }
        let secs = profile.requests as f64 / capacity_rps;
        SimTime::ps((secs * 1e12).round() as u64)
    }

    /// Mean modeled service time of one profile request on a worker of
    /// `kind`: framework overhead plus, per demanded GEMM, the cheaper
    /// of the CPU route and the weights-resident accelerator route —
    /// the same better-of-two rule the offload planner applies live.
    fn mean_request_secs(cm: &CostModel, kind: WorkerKind, profile: &TrafficProfile) -> f64 {
        let n = profile.requests.max(1) as f64;
        let mut total = cm.request_overhead().as_secs_f64() * n;
        for &(shape, count) in &profile.demand {
            let cpu_t = cm.estimate(shape, WorkerKind::Cpu).total();
            let best = match kind {
                WorkerKind::Cpu => cpu_t,
                WorkerKind::Sa | WorkerKind::Vm => {
                    cpu_t.min(cm.estimate_resident(shape, kind, true).total())
                }
            };
            total += best.as_secs_f64() * count as f64;
        }
        total / n
    }

    /// Modeled migration cost `from` → `to`: one bitstream load
    /// ([`synth::reconfig_time`]) per *added* accelerator instance.
    /// Retiring an instance (or changing CPU workers) is free.
    pub fn reconfig_cost(&self, from: &Composition, to: &Composition) -> SimTime {
        let added_sa = to.sa.saturating_sub(from.sa) as u64;
        let added_vm = to.vm.saturating_sub(from.vm) as u64;
        SimTime::ps(
            synth::reconfig_time(&self.sa_unit).as_ps() * added_sa
                + synth::reconfig_time(&self.vm_unit).as_ps() * added_vm,
        )
    }

    /// The planning step: among feasible compositions within
    /// `cfg.max_swaps` of `current`, pick the best-scoring one and
    /// propose it iff the projected win strictly exceeds the modeled
    /// reconfiguration cost plus the hysteresis margin. `None` means
    /// "stay put" — including always when `max_swaps` is zero.
    pub fn plan(
        &self,
        current: Composition,
        profile: &TrafficProfile,
        costs: &DesignCosts,
        cfg: &ElasticConfig,
    ) -> Option<ReconfigPlan> {
        let projected_current = self.score(&current, profile, costs);
        let mut best: Option<(SimTime, Composition)> = None;
        for comp in self.enumerate(cfg.cpu_max) {
            if comp.swaps_from(&current) > cfg.max_swaps {
                continue;
            }
            let s = self.score(&comp, profile, costs);
            let better = match &best {
                None => true,
                Some((bs, _)) => s < *bs,
            };
            if better {
                best = Some((s, comp));
            }
        }
        let (projected_best, to) = best?;
        if to == current {
            return None;
        }
        let reconfig_cost = self.reconfig_cost(&current, &to);
        let win = projected_current.saturating_sub(projected_best);
        if win > reconfig_cost + cfg.hysteresis {
            Some(ReconfigPlan {
                from: current,
                to,
                projected_current,
                projected_best,
                reconfig_cost,
                swaps: to.swaps_from(&current),
            })
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GemmShape;
    use crate::driver::DriverConfig;

    fn planner() -> CompositionPlanner {
        CompositionPlanner::new(Resources::zynq7020())
    }

    fn costs() -> DesignCosts {
        DesignCosts::new(1, DriverConfig::default().sync_overhead)
    }

    fn ecfg() -> ElasticConfig {
        // cpu_max 0: a pure which-bitstream decision, so the planner
        // cannot paper over a wrong design by adding CPU workers
        ElasticConfig {
            hysteresis: SimTime::ms(1),
            cpu_max: 0,
            max_swaps: 1,
            ..ElasticConfig::default()
        }
    }

    /// A conv-heavy profile whose K exceeds the VM local buffers: the
    /// design-aware cost model prices a VM worker at CPU-fallback
    /// speed while the SA runs it on fabric.
    fn deep_conv_profile(requests: usize) -> TrafficProfile {
        TrafficProfile {
            requests,
            span: SimTime::ms(500),
            arrival_rate_rps: requests as f64 / 0.5,
            demand: vec![(GemmShape { m: 96, k: 4608, n: 196 }, requests as u64)],
            slo_carrying: 0,
            slo_missed: 0,
            trend: 0.0,
        }
    }

    #[test]
    fn enumeration_respects_the_zynq_budget() {
        let p = planner();
        let comps = p.enumerate(2);
        assert!(!comps.is_empty());
        let budget = Resources::zynq7020();
        for c in &comps {
            assert!(c.fits(&budget), "{c} exceeds the device budget");
            assert!(c.total() >= 1);
            assert!(c.cpu <= 2);
        }
        // the paper designs' serving-time reality: the fabric holds
        // one of them at a time, so no feasible composition mixes or
        // doubles accelerators
        assert!(comps.iter().all(|c| c.sa + c.vm <= 1));
        assert!(comps.iter().any(|c| c.sa == 1));
        assert!(comps.iter().any(|c| c.vm == 1));
        assert!(comps.iter().any(|c| c.sa == 0 && c.vm == 0 && c.cpu > 0));
    }

    #[test]
    fn deep_k_traffic_swaps_vm_for_sa() {
        let p = planner();
        let profile = deep_conv_profile(8);
        let plan = p
            .plan(Composition::new(0, 1, 0), &profile, &costs(), &ecfg())
            .expect("deep-K conv traffic must justify the SA bitstream");
        assert_eq!(plan.to, Composition::new(1, 0, 0));
        assert_eq!(plan.swaps, 1);
        assert!(plan.projected_win() > plan.reconfig_cost);
        assert!(plan.to.fits(&Resources::zynq7020()));
        // and the SA pool is already the right place to be: no churn
        assert!(p
            .plan(Composition::new(1, 0, 0), &profile, &costs(), &ecfg())
            .is_none());
    }

    #[test]
    fn reconfiguration_needs_win_beyond_cost_plus_hysteresis() {
        // Pin the decision rule exactly: win > cost + hysteresis.
        let p = planner();
        let profile = deep_conv_profile(8);
        let current = Composition::new(0, 1, 0);
        let target = Composition::new(1, 0, 0);
        let cur = p.score(&current, &profile, &costs());
        let best = p.score(&target, &profile, &costs());
        let win = cur.saturating_sub(best);
        let cost = p.reconfig_cost(&current, &target);
        assert!(win > cost, "profile must make the swap worthwhile");
        let slack = win.saturating_sub(cost);
        // hysteresis one tick below the slack: the swap still fires
        let mut cfg = ecfg();
        cfg.hysteresis = slack.saturating_sub(SimTime::ps(1));
        assert!(p.plan(current, &profile, &costs(), &cfg).is_some());
        // hysteresis exactly at the slack: win == cost + hysteresis is
        // NOT strictly greater — the planner must stay put
        cfg.hysteresis = slack;
        assert!(p.plan(current, &profile, &costs(), &cfg).is_none());
    }

    #[test]
    fn max_swaps_zero_never_plans() {
        let p = planner();
        let profile = deep_conv_profile(32);
        let mut cfg = ecfg();
        cfg.max_swaps = 0;
        cfg.hysteresis = SimTime::ZERO;
        for current in [Composition::new(0, 1, 0), Composition::new(0, 0, 1)] {
            assert!(
                p.plan(current, &profile, &costs(), &cfg).is_none(),
                "max_swaps=0 must pin {current}"
            );
        }
    }

    #[test]
    fn observations_override_priors_in_scoring() {
        let p = planner();
        let shape = GemmShape { m: 96, k: 2304, n: 196 };
        let profile = TrafficProfile {
            requests: 8,
            span: SimTime::ms(500),
            arrival_rate_rps: 16.0,
            demand: vec![(shape, 8)],
            slo_carrying: 0,
            slo_missed: 0,
            trend: 0.0,
        };
        let mut c = costs();
        let sa_prior = p.score(&Composition::new(1, 0, 0), &profile, &c);
        // the simulator measured the SA much slower than its prior on
        // this shape (warm): scoring must track the measurement
        c.model_mut(WorkerKind::Sa)
            .observe(shape, true, SimTime::ms(400));
        let sa_measured = p.score(&Composition::new(1, 0, 0), &profile, &c);
        assert!(
            sa_measured > sa_prior,
            "measured {sa_measured} not above prior {sa_prior}"
        );
    }

    #[test]
    fn swaps_from_counts_exchanges_once() {
        let a = Composition::new(0, 1, 0);
        let b = Composition::new(1, 0, 0);
        assert_eq!(b.swaps_from(&a), 1, "SA<->VM exchange is one swap");
        assert_eq!(a.swaps_from(&a), 0);
        assert_eq!(Composition::new(1, 0, 2).swaps_from(&a), 2);
        assert_eq!(Composition::new(0, 0, 0).swaps_from(&b), 1);
    }
}
