//! The elastic controller: estimator + planner wired to a live pool.
//!
//! Owned by [`crate::coordinator::Coordinator`] when
//! [`crate::coordinator::CoordinatorConfig::elastic`] is set. At every
//! drain boundary the coordinator feeds completions into the
//! controller's [`WorkloadEstimator`] and asks it to evaluate; the
//! controller rate-limits evaluations to the configured interval,
//! pools every worker's cost observations into per-design views
//! ([`DesignCosts`] — measurements must survive the instance that made
//! them), and consults the [`CompositionPlanner`]. An emitted
//! [`ReconfigPlan`] is applied by the coordinator
//! ([`crate::coordinator::Coordinator::reconfigure`]) and committed
//! here, building the composition timeline
//! ([`ElasticController::history`]).

use crate::coordinator::pool::WorkerPool;
use crate::coordinator::Completion;
use crate::sysc::SimTime;

use super::estimate::{TrafficProfile, WorkloadEstimator};
use super::plan::{Composition, CompositionPlanner, DesignCosts, ReconfigPlan};
use super::ElasticConfig;

/// One committed reconfiguration — an entry of the composition
/// timeline.
#[derive(Debug, Clone)]
pub struct SwapRecord {
    /// Modeled time the swap was committed.
    pub at: SimTime,
    /// Composition before the swap.
    pub from: Composition,
    /// Composition after the swap.
    pub to: Composition,
    /// Modeled bitstream-load cost charged for it.
    pub reconfig_cost: SimTime,
    /// The projected window win that justified it.
    pub projected_win: SimTime,
}

/// Traffic-aware reprovisioning state for one coordinator.
#[derive(Debug)]
pub struct ElasticController {
    cfg: ElasticConfig,
    estimator: WorkloadEstimator,
    planner: CompositionPlanner,
    costs: DesignCosts,
    last_eval: Option<SimTime>,
    /// Armed by a non-zero telemetry trend signal: the next evaluate
    /// bypasses the eval-interval rate limit once, so a detected
    /// regime shift is planned against one interval earlier than the
    /// reactive cadence would allow.
    pending_eval: bool,
    /// The window summary the most recent full evaluation ran against
    /// (set once the `min_samples` gate passes, whether or not a plan
    /// came out) — drained by the coordinator's observability layer.
    last_profile: Option<TrafficProfile>,
    history: Vec<SwapRecord>,
}

impl ElasticController {
    /// A controller for workers with `threads` CPU threads and the
    /// given per-offload sync overhead (the same parameters the pool's
    /// own cost models use, so estimates line up).
    pub fn new(cfg: ElasticConfig, threads: usize, sync_overhead: SimTime) -> Self {
        Self::with_designs(
            cfg,
            threads,
            sync_overhead,
            &crate::accel::SaConfig::paper(),
            &crate::accel::VmConfig::paper(),
        )
    }

    /// A controller planning over explicit SA/VM designs: the planner
    /// prices compositions and reconfigurations with these designs'
    /// fabric footprints, and the per-design cost priors run their
    /// cycle models. This is how a DSE-discovered frontier design
    /// ([`crate::dse::ProfileReport::best_sa`]/`best_vm`, threaded
    /// through [`crate::coordinator::CoordinatorConfig::sa_design`])
    /// reaches serving-time reprovisioning. Identical to
    /// [`ElasticController::new`] on the paper configurations.
    pub fn with_designs(
        cfg: ElasticConfig,
        threads: usize,
        sync_overhead: SimTime,
        sa: &crate::accel::SaConfig,
        vm: &crate::accel::VmConfig,
    ) -> Self {
        let estimator = WorkloadEstimator::new(cfg.window);
        let planner = CompositionPlanner::with_designs(cfg.budget, sa, vm);
        ElasticController {
            cfg,
            estimator,
            planner,
            costs: DesignCosts::for_designs(threads, sync_overhead, sa, vm),
            last_eval: None,
            pending_eval: false,
            last_profile: None,
            history: Vec::new(),
        }
    }

    /// Fold one completion into the traffic window.
    pub fn observe(&mut self, c: &Completion) {
        self.estimator.observe(c);
    }

    /// Feed the telemetry change-point trend signal
    /// ([`crate::obs::AlertEngine::trend`]). A non-zero trend stamps
    /// the next profile ([`TrafficProfile::trend`]) and arms a one-shot
    /// bypass of the evaluation rate limit — the predictive half of
    /// reprovisioning: react to the shift's onset, not to the next
    /// scheduled window.
    pub fn note_trend(&mut self, trend: f64) {
        self.estimator.set_trend(trend);
        if trend != 0.0 {
            self.pending_eval = true;
        }
    }

    /// Evaluate the planner against the current traffic window.
    /// Rate-limited to the configured interval; requires the window to
    /// hold at least `min_samples` completions. Never mutates the pool
    /// — it only reads cost observations out of it.
    pub fn evaluate(
        &mut self,
        now: SimTime,
        current: Composition,
        pool: &WorkerPool,
    ) -> Option<ReconfigPlan> {
        let pending = std::mem::take(&mut self.pending_eval);
        if !pending {
            if let Some(last) = self.last_eval {
                if now.saturating_sub(last) < self.cfg.eval_interval {
                    return None;
                }
            }
        }
        self.last_eval = Some(now);
        for w in &pool.workers {
            self.costs.absorb(w.kind, &w.backend.planner.cost);
        }
        let profile = self.estimator.profile(now)?;
        if profile.requests < self.cfg.min_samples {
            return None;
        }
        let plan = self.planner.plan(current, &profile, &self.costs, &self.cfg);
        self.last_profile = Some(profile);
        plan
    }

    /// Take the traffic profile the most recent evaluation ran against
    /// (if one passed the sample gate since the last take). The
    /// coordinator turns it into an estimator-window span.
    pub fn take_last_profile(&mut self) -> Option<TrafficProfile> {
        self.last_profile.take()
    }

    /// Record an applied plan into the composition timeline.
    pub fn commit(&mut self, plan: &ReconfigPlan, at: SimTime) {
        self.history.push(SwapRecord {
            at,
            from: plan.from,
            to: plan.to,
            reconfig_cost: plan.reconfig_cost,
            projected_win: plan.projected_win(),
        });
    }

    /// The composition timeline: every committed swap, in order.
    pub fn history(&self) -> &[SwapRecord] {
        &self.history
    }

    /// The configuration this controller runs under.
    pub fn config(&self) -> &ElasticConfig {
        &self.cfg
    }

    /// The pooled per-design cost views (diagnostics).
    pub fn costs(&self) -> &DesignCosts {
        &self.costs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::convnet;
    use crate::coordinator::{Coordinator, CoordinatorConfig};
    use crate::driver::DriverConfig;
    use std::sync::Arc;

    #[test]
    fn evaluation_is_rate_limited_and_sample_gated() {
        let drv = DriverConfig::default();
        let cfg = ElasticConfig {
            eval_interval: SimTime::ms(100),
            min_samples: 3,
            cpu_max: 0,
            ..ElasticConfig::default()
        };
        let mut ctrl = ElasticController::new(cfg, drv.threads, drv.sync_overhead);
        // a pool to absorb observations from (contents irrelevant here)
        let coord = Coordinator::new(CoordinatorConfig::sa_pool(1));
        let pool = coord.pool();
        let current = Composition::new(1, 0, 0);
        let g = Arc::new(convnet("net", 16, 3));

        // first call: no samples in the window -> no plan, but the
        // rate limiter arms
        assert!(ctrl.evaluate(SimTime::ms(0), current, pool).is_none());
        for i in 1..=3u64 {
            ctrl.estimator
                .observe_request(&g, SimTime::ms(i), SimTime::ms(i + 1), None);
        }
        // inside the interval: rate-limited even with enough samples
        assert!(ctrl.evaluate(SimTime::ms(50), current, pool).is_none());
        // past the interval, enough samples: the planner runs (and
        // finds nothing worth a swap on this tiny-conv traffic, but
        // the eval stamp advances, proving the gate opened)
        assert!(ctrl.evaluate(SimTime::ms(150), current, pool).is_none());
        assert_eq!(ctrl.last_eval, Some(SimTime::ms(150)));
        assert!(ctrl.history().is_empty());
    }

    #[test]
    fn trend_signal_bypasses_the_rate_limit_once() {
        let drv = DriverConfig::default();
        let cfg = ElasticConfig {
            eval_interval: SimTime::ms(100),
            min_samples: 3,
            cpu_max: 0,
            ..ElasticConfig::default()
        };
        let mut ctrl = ElasticController::new(cfg, drv.threads, drv.sync_overhead);
        let coord = Coordinator::new(CoordinatorConfig::sa_pool(1));
        let pool = coord.pool();
        let current = Composition::new(1, 0, 0);
        let g = Arc::new(convnet("net", 16, 3));

        assert!(ctrl.evaluate(SimTime::ms(0), current, pool).is_none());
        for i in 1..=3u64 {
            ctrl.estimator
                .observe_request(&g, SimTime::ms(i), SimTime::ms(i + 1), None);
        }
        // in-regime trend does not arm the bypass
        ctrl.note_trend(0.0);
        assert!(ctrl.evaluate(SimTime::ms(40), current, pool).is_none());
        assert_eq!(ctrl.last_eval, Some(SimTime::ms(0)));
        // a regime shift does: the evaluation runs inside the interval
        // and the profile carries the trend
        ctrl.note_trend(2.5);
        assert!(ctrl.evaluate(SimTime::ms(50), current, pool).is_none());
        assert_eq!(ctrl.last_eval, Some(SimTime::ms(50)));
        let profile = ctrl.take_last_profile().expect("gate passed");
        assert_eq!(profile.trend, 2.5);
        // the bypass is one-shot: the next call rate-limits again
        assert!(ctrl.evaluate(SimTime::ms(60), current, pool).is_none());
        assert_eq!(ctrl.last_eval, Some(SimTime::ms(50)));
    }
}
