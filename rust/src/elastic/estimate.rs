//! The workload estimator: completed requests in, traffic profile out.
//!
//! The estimator keeps a sliding window of completed-request samples —
//! each sample is the request's arrival/finish stamps, its SLO outcome
//! and the GEMM shapes of the model it ran (resolved once per distinct
//! graph and shared via `Arc`) — and folds the window into a
//! [`TrafficProfile`]: per-shape demand, arrival rate and SLO
//! pressure. The profile is everything the composition planner
//! ([`super::plan`]) needs to rank pool compositions; no raw requests
//! or tensors are retained.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::coordinator::{Completion, GemmShape};
use crate::framework::graph::Graph;
use crate::framework::models::gemm_shapes;
use crate::sysc::SimTime;

/// What the serving pool observed over the estimator window — the
/// planner's entire view of the live workload.
#[derive(Debug, Clone)]
pub struct TrafficProfile {
    /// Completed requests inside the window.
    pub requests: usize,
    /// Modeled span the window's samples cover (first arrival to last
    /// finish).
    pub span: SimTime,
    /// Arrival rate over the window, requests per modeled second
    /// (zero when the window holds fewer than two samples).
    pub arrival_rate_rps: f64,
    /// Per-GEMM-shape demand: how many times each distinct shape was
    /// served inside the window, in first-seen order (deterministic —
    /// the planner iterates this).
    pub demand: Vec<(GemmShape, u64)>,
    /// Samples that carried an SLO deadline.
    pub slo_carrying: usize,
    /// Deadline-carrying samples that finished past their deadline.
    pub slo_missed: usize,
    /// Change-point trend signal from the telemetry layer
    /// ([`crate::obs::AlertEngine::trend`]): 0.0 in-regime, else the
    /// signed sigma-normalized deviation of the shifted latency or
    /// arrival gauge. Early warning only — the planner still prices
    /// compositions from the windowed demand; the controller uses a
    /// non-zero trend to evaluate ahead of its rate limit.
    pub trend: f64,
}

impl TrafficProfile {
    /// SLO pressure in [0, 1]: share of deadline-carrying completions
    /// that missed. Zero when nothing carried a deadline.
    pub fn slo_pressure(&self) -> f64 {
        if self.slo_carrying == 0 {
            return 0.0;
        }
        self.slo_missed as f64 / self.slo_carrying as f64
    }
}

/// One windowed sample (internal).
#[derive(Debug, Clone)]
struct Sample {
    arrival: SimTime,
    finished: SimTime,
    deadline: Option<SimTime>,
    shapes: Arc<Vec<GemmShape>>,
}

/// GEMM shapes per distinct graph, resolved once. Holding the
/// `Arc<Graph>` pins the graph alive so pointer identity can never
/// alias a dropped model.
type ShapeMemo = Vec<(Arc<Graph>, Arc<Vec<GemmShape>>)>;

/// Folds completed requests into a windowed [`TrafficProfile`].
#[derive(Debug)]
pub struct WorkloadEstimator {
    window: SimTime,
    samples: VecDeque<Sample>,
    shape_memo: ShapeMemo,
    trend: f64,
}

impl WorkloadEstimator {
    /// An estimator whose profile covers the trailing `window` of
    /// modeled time.
    pub fn new(window: SimTime) -> Self {
        WorkloadEstimator {
            window,
            samples: VecDeque::new(),
            shape_memo: Vec::new(),
            trend: 0.0,
        }
    }

    /// Set the change-point trend signal the next profile will carry
    /// (see [`TrafficProfile::trend`]). The telemetry layer feeds this
    /// every drain; it decays to whatever the caller last set, never
    /// on its own.
    pub fn set_trend(&mut self, trend: f64) {
        self.trend = trend;
    }

    /// The trend signal currently staged for the next profile.
    pub fn trend(&self) -> f64 {
        self.trend
    }

    /// Fold one completion into the window.
    pub fn observe(&mut self, c: &Completion) {
        self.observe_request(&c.model, c.arrival, c.finished, c.deadline);
    }

    /// Fold one completed request by its parts (what [`Self::observe`]
    /// extracts from a [`Completion`]).
    pub fn observe_request(
        &mut self,
        model: &Arc<Graph>,
        arrival: SimTime,
        finished: SimTime,
        deadline: Option<SimTime>,
    ) {
        let shapes = self.shapes_of(model);
        self.samples.push_back(Sample {
            arrival,
            finished,
            deadline,
            shapes,
        });
    }

    /// Samples currently inside the estimator (before eviction).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples are held.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn shapes_of(&mut self, model: &Arc<Graph>) -> Arc<Vec<GemmShape>> {
        if let Some((_, shapes)) = self
            .shape_memo
            .iter()
            .find(|(g, _)| Arc::ptr_eq(g, model))
        {
            return shapes.clone();
        }
        let shapes: Vec<GemmShape> = gemm_shapes(model)
            .into_iter()
            .map(|(m, k, n)| GemmShape { m, k, n })
            .collect();
        let shapes = Arc::new(shapes);
        self.shape_memo.push((model.clone(), shapes.clone()));
        shapes
    }

    /// Evict samples older than the window (by finish time) and fold
    /// the survivors into a profile. `None` when the window is empty —
    /// the planner has nothing to plan against. Eviction is a full
    /// retain, not a front-pop: completions are observed in drain
    /// order (execution order under the modeled drain, id order under
    /// the threaded one), which is *not* finish-time order, so an
    /// expired sample can sit behind a fresher front.
    pub fn profile(&mut self, now: SimTime) -> Option<TrafficProfile> {
        let horizon = now.saturating_sub(self.window);
        self.samples.retain(|s| s.finished >= horizon);
        if self.samples.is_empty() {
            return None;
        }
        let mut demand: Vec<(GemmShape, u64)> = Vec::new();
        let mut first_arrival = SimTime::MAX;
        let mut last_arrival = SimTime::ZERO;
        let mut last_finish = SimTime::ZERO;
        let mut slo_carrying = 0usize;
        let mut slo_missed = 0usize;
        for s in &self.samples {
            first_arrival = first_arrival.min(s.arrival);
            last_arrival = last_arrival.max(s.arrival);
            last_finish = last_finish.max(s.finished);
            if let Some(d) = s.deadline {
                slo_carrying += 1;
                if s.finished > d {
                    slo_missed += 1;
                }
            }
            for &shape in s.shapes.iter() {
                match demand.iter_mut().find(|(sh, _)| *sh == shape) {
                    Some((_, count)) => *count += 1,
                    None => demand.push((shape, 1)),
                }
            }
        }
        let requests = self.samples.len();
        let arrival_span = last_arrival.saturating_sub(first_arrival);
        let arrival_rate_rps = if requests >= 2 && arrival_span > SimTime::ZERO {
            (requests - 1) as f64 / arrival_span.as_secs_f64()
        } else {
            0.0
        };
        Some(TrafficProfile {
            requests,
            span: last_finish.saturating_sub(first_arrival),
            arrival_rate_rps,
            demand,
            slo_carrying,
            slo_missed,
            trend: self.trend,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::convnet;

    #[test]
    fn window_evicts_and_aggregates() {
        let g1 = Arc::new(convnet("net_a", 16, 3));
        let g2 = Arc::new(convnet("net_b", 24, 5));
        let mut est = WorkloadEstimator::new(SimTime::ms(100));
        // two old samples that must fall out of the window
        est.observe_request(&g1, SimTime::ZERO, SimTime::ms(1), None);
        est.observe_request(&g1, SimTime::ms(1), SimTime::ms(2), None);
        // three fresh ones: 2x net_a, 1x net_b
        for (i, g) in [&g1, &g1, &g2].into_iter().enumerate() {
            let at = SimTime::ms(460 + 10 * i as u64);
            est.observe_request(g, at, at + SimTime::ms(5), Some(at + SimTime::ms(1)));
        }
        assert_eq!(est.len(), 5);
        let p = est.profile(SimTime::ms(500)).expect("profile");
        assert_eq!(p.requests, 3, "old samples evicted");
        assert_eq!(est.len(), 3);
        // one conv per net: net_a's shape counted twice, net_b's once
        assert_eq!(p.demand.len(), 2);
        assert_eq!(p.demand[0].1, 2);
        assert_eq!(p.demand[1].1, 1);
        // every sample carried (and missed) its deadline
        assert_eq!(p.slo_carrying, 3);
        assert_eq!(p.slo_missed, 3);
        assert!((p.slo_pressure() - 1.0).abs() < 1e-12);
        // 2 inter-arrival gaps of 10 ms -> 100 req/s
        assert!((p.arrival_rate_rps - 100.0).abs() < 1.0, "{}", p.arrival_rate_rps);
    }

    #[test]
    fn eviction_handles_out_of_finish_order_observation() {
        let g = Arc::new(convnet("net", 16, 13));
        let mut est = WorkloadEstimator::new(SimTime::ms(100));
        // observed in drain order, NOT finish order: the late finisher
        // lands at the front of the deque
        est.observe_request(&g, SimTime::ZERO, SimTime::ms(450), None);
        est.observe_request(&g, SimTime::ZERO, SimTime::ms(50), None);
        let p = est.profile(SimTime::ms(500)).expect("profile");
        assert_eq!(
            p.requests, 1,
            "expired sample behind a fresher front must still evict"
        );
        assert_eq!(est.len(), 1);
    }

    #[test]
    fn empty_window_yields_no_profile() {
        let g = Arc::new(convnet("net", 16, 7));
        let mut est = WorkloadEstimator::new(SimTime::ms(10));
        assert!(est.profile(SimTime::ms(1)).is_none());
        est.observe_request(&g, SimTime::ZERO, SimTime::ms(1), None);
        // sample aged out entirely
        assert!(est.profile(SimTime::ms(500)).is_none());
        assert!(est.is_empty());
    }

    #[test]
    fn shape_memo_dedupes_by_graph_identity() {
        let g = Arc::new(convnet("net", 16, 9));
        let same_name = Arc::new(convnet("net", 16, 11));
        let mut est = WorkloadEstimator::new(SimTime::ms(1000));
        est.observe_request(&g, SimTime::ZERO, SimTime::ms(1), None);
        est.observe_request(&g, SimTime::ms(1), SimTime::ms(2), None);
        est.observe_request(&same_name, SimTime::ms(2), SimTime::ms(3), None);
        assert_eq!(est.shape_memo.len(), 2, "identity is the Arc, not the name");
        let p = est.profile(SimTime::ms(3)).expect("profile");
        // identical shapes from distinct graphs still merge in demand
        assert_eq!(p.demand.len(), 1);
        assert_eq!(p.demand[0].1, 3);
    }
}
