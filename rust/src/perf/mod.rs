//! PYNQ-Z1 timing and energy models.
//!
//! The paper measures wall-clock on a dual Cortex-A9 @650MHz and energy
//! with a COOWOO USB power meter. Neither exists here, so Table II's
//! CPU-side numbers come from an analytic model *calibrated against the
//! paper's own CPU-only baselines* (see [`calib`] for constants and
//! provenance), while accelerator times come from the [`crate::sysc`]
//! TLM simulations. This is the substitution DESIGN.md documents:
//! predictions for the accelerated configurations then follow from the
//! models, and the comparison against the paper's measured rows is the
//! reproduction result.

pub mod calib;
pub mod devtime;

use crate::sysc::SimTime;

/// Cortex-A9 (2-core, 650 MHz) execution-time model for the TFLite
/// CPU paths.
#[derive(Debug, Clone)]
pub struct CpuModel {
    /// Effective int8 GEMM throughput per thread, MAC/s (gemmlowp with
    /// NEON on the A9).
    pub gemm_macs_per_sec: f64,
    /// Depthwise conv throughput per thread, MAC/s (lower arithmetic
    /// intensity than GEMM).
    pub dwconv_macs_per_sec: f64,
    /// Streaming element-wise throughput per thread, bytes/s.
    pub elementwise_bytes_per_sec: f64,
    /// im2col / data-reshape throughput (driver prep), bytes/s.
    pub reshape_bytes_per_sec: f64,
    /// gemmlowp output unpacking (requant on CPU), outputs/s.
    pub unpack_outputs_per_sec: f64,
    /// Fixed per-op dispatch overhead.
    pub op_overhead: SimTime,
    /// Per-inference framework overhead (TFLite interpreter dispatch,
    /// tensor (de)quantization, allocation churn) — the bulk of the
    /// Non-CONV column that is not attributable to any single op.
    pub framework_overhead: SimTime,
    /// Marginal efficiency of the second thread (Table II shows ~1.93x
    /// scaling on CONV): `eff_threads = 1 + scaling * (threads - 1)`.
    pub second_thread_scaling: f64,
}

impl CpuModel {
    /// The calibrated PYNQ-Z1 Cortex-A9 model ([`calib`] constants,
    /// fit against the paper's CPU-only Table II rows).
    pub fn pynq_a9() -> Self {
        calib::cpu_model()
    }

    /// The serving-tier CPU model: [`Self::pynq_a9`] with GEMM and
    /// unpack rates scaled by the SIMD kernel uplift (see
    /// [`calib::SIMD_GEMM_UPLIFT`]). Used by the coordinator's CPU
    /// workers and cost model; the pynq model stays the Table II
    /// reproduction baseline.
    pub fn serving() -> Self {
        calib::cpu_model_serving()
    }

    /// Effective parallelism for `threads` CPU threads.
    pub fn eff_threads(&self, threads: usize) -> f64 {
        1.0 + self.second_thread_scaling * (threads.max(1) - 1) as f64
    }

    fn time(&self, amount: f64, rate_per_sec: f64, threads: usize) -> SimTime {
        let secs = amount / (rate_per_sec * self.eff_threads(threads));
        SimTime::ps((secs * 1e12).round() as u64) + self.op_overhead
    }

    /// CPU-side quantized GEMM (gemmlowp) time.
    pub fn gemm_time(&self, macs: u64, threads: usize) -> SimTime {
        self.time(macs as f64, self.gemm_macs_per_sec, threads)
    }

    /// Depthwise convolution time.
    pub fn dwconv_time(&self, macs: u64, threads: usize) -> SimTime {
        self.time(macs as f64, self.dwconv_macs_per_sec, threads)
    }

    /// Pool / add / concat / activation style streaming ops.
    pub fn elementwise_time(&self, bytes: u64, threads: usize) -> SimTime {
        self.time(bytes as f64, self.elementwise_bytes_per_sec, threads)
    }

    /// Driver data preparation (im2col, accelerator-layout reshape).
    pub fn reshape_time(&self, bytes: u64, threads: usize) -> SimTime {
        self.time(bytes as f64, self.reshape_bytes_per_sec, threads)
    }

    /// CPU-side gemmlowp "unpack" (bias+requant+narrow) when the PPU
    /// is not on the accelerator.
    pub fn unpack_time(&self, outputs: u64, threads: usize) -> SimTime {
        self.time(outputs as f64, self.unpack_outputs_per_sec, threads)
    }
}

/// Board-level energy model (COOWOO power-meter analogue):
/// `E = T_total * (P_idle + P_cpu * threads) + T_accel_active * P_fpga`.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// Board idle power (SoC static + DRAM + peripherals), watts.
    pub p_idle_w: f64,
    /// Marginal power per active A9 thread, watts.
    pub p_per_thread_w: f64,
    /// Marginal FPGA fabric power while the accelerator is active.
    pub p_fpga_active_w: f64,
}

impl EnergyModel {
    /// The calibrated PYNQ-Z1 board power model ([`calib`] constants).
    pub fn pynq() -> Self {
        calib::energy_model()
    }

    /// Energy in joules for an inference.
    pub fn energy_j(&self, total: SimTime, accel_active: SimTime, threads: usize) -> f64 {
        let t = total.as_secs_f64();
        let ta = accel_active.as_secs_f64().min(t);
        t * (self.p_idle_w + self.p_per_thread_w * threads as f64) + ta * self.p_fpga_active_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_time_scales_with_threads() {
        let m = CpuModel::pynq_a9();
        let one = m.gemm_time(1_000_000_000, 1);
        let two = m.gemm_time(1_000_000_000, 2);
        let ratio = one.as_secs_f64() / two.as_secs_f64();
        assert!((1.8..=2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn serving_tier_is_the_pynq_model_scaled_by_the_simd_uplift() {
        let pynq = CpuModel::pynq_a9();
        let serving = CpuModel::serving();
        let macs = 256u64 * 256 * 256;
        // op overhead is additive, so compare the rate-driven part
        let p = (pynq.gemm_time(macs, 1) - pynq.op_overhead).as_secs_f64();
        let s = (serving.gemm_time(macs, 1) - serving.op_overhead).as_secs_f64();
        let ratio = p / s;
        assert!((ratio - calib::SIMD_GEMM_UPLIFT).abs() < 1e-6, "ratio {ratio}");
        // non-GEMM rates are untouched
        assert_eq!(
            pynq.elementwise_time(1 << 20, 1),
            serving.elementwise_time(1 << 20, 1)
        );
    }

    #[test]
    fn calibration_mobilenet_v1_cpu_baseline() {
        // MobileNetV1 CPU(1thr) CONV = 635 ms in Table II. Our model on
        // the same workload (GEMM convs + depthwise + im2col) must land
        // within 20% of the paper's measurement.
        let m = CpuModel::pynq_a9();
        let gemm_macs: u64 = 567_716_864; // from the python shape table
        let dw_macs: u64 = 42_264_768;
        let im2col_bytes: u64 = 12_153_344;
        let t = m.gemm_time(gemm_macs, 1)
            + m.dwconv_time(dw_macs, 1)
            + m.reshape_time(im2col_bytes, 1);
        let ms = t.as_ms_f64();
        assert!((508.0..=762.0).contains(&ms), "modeled CONV {ms} ms vs paper 635 ms");
    }

    #[test]
    fn energy_model_matches_cpu_rows() {
        // Table II MobileNetV1: CPU 1thr 776 ms -> 1.84 J (2.37 W);
        // CPU 2thr 402 ms -> 1.04 J (2.59 W).
        let e = EnergyModel::pynq();
        let j1 = e.energy_j(SimTime::ms(776), SimTime::ZERO, 1);
        let j2 = e.energy_j(SimTime::ms(402), SimTime::ZERO, 2);
        assert!((j1 - 1.84).abs() < 0.15, "1thr {j1} J");
        assert!((j2 - 1.04).abs() < 0.15, "2thr {j2} J");
    }

    #[test]
    fn fpga_power_adds_energy() {
        let e = EnergyModel::pynq();
        let base = e.energy_j(SimTime::ms(100), SimTime::ZERO, 1);
        let with = e.energy_j(SimTime::ms(100), SimTime::ms(80), 1);
        assert!(with > base);
    }
}
