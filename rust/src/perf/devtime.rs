//! Development-time ("idle evaluation time") model — paper §II-B,
//! Equations 1–3, and the §V-B 25x / 16x claims.
//!
//! * Eq. 1 (SECDA):      E_t = #Sim*(C_t + IS_t) + #Synth*(S_t + I_t)
//! * Eq. 2 (synth-only): E_t = (#Sim + #Synth)*(S_t + I_t)
//! * Eq. 3 (full-system sim, SMAUG-like):
//!                       E_t = (#Sim + #Synth)*(C_t + IS_t')
//!   with a much larger IS_t' (hours per inference).

use crate::sysc::SimTime;

/// Per-iteration cost parameters of a design flow.
#[derive(Debug, Clone, Copy)]
pub struct DevTimeParams {
    /// Compile time of the simulation build (C_t).
    pub compile: SimTime,
    /// End-to-end inference time in simulation (IS_t).
    pub sim_inference: SimTime,
    /// Logic synthesis time (S_t).
    pub synthesis: SimTime,
    /// Inference time on the FPGA (I_t).
    pub hw_inference: SimTime,
}

impl DevTimeParams {
    /// The paper's observed ratio: S_t ≈ 25 x C_t for the VM design,
    /// with minutes-scale simulation builds.
    pub fn paper_like() -> Self {
        DevTimeParams {
            compile: SimTime::ms(96_000),        // ~1.6 min sim build
            sim_inference: SimTime::ms(45_000),  // minutes-order e2e sim
            synthesis: SimTime::ms(2_400_000),   // 40 min logic synthesis
            hw_inference: SimTime::ms(2_000),    // seconds on the FPGA
        }
    }

    /// Parameters measured on THIS reproduction (filled by the devtime
    /// bench: our sim build + e2e sim times, synthesis from the synth
    /// model).
    pub fn measured(compile: SimTime, sim_inference: SimTime, synthesis: SimTime) -> Self {
        DevTimeParams {
            compile,
            sim_inference,
            synthesis,
            hw_inference: SimTime::ms(2_000),
        }
    }
}

/// Eq. 1: the SECDA two-loop flow.
pub fn eq1_secda(p: &DevTimeParams, n_sim: u64, n_synth: u64) -> SimTime {
    SimTime::ps(
        n_sim * (p.compile + p.sim_inference).as_ps()
            + n_synth * (p.synthesis + p.hw_inference).as_ps(),
    )
}

/// Eq. 2: every iteration goes through logic synthesis.
pub fn eq2_synth_only(p: &DevTimeParams, n_sim: u64, n_synth: u64) -> SimTime {
    SimTime::ps((n_sim + n_synth) * (p.synthesis + p.hw_inference).as_ps())
}

/// Eq. 3: every iteration through full-system simulation; `slow_factor`
/// scales IS_t to a gem5-Aladdin-like cost (hours, §II-B cites several
/// hours for ResNet50).
pub fn eq3_full_sim(p: &DevTimeParams, n_sim: u64, n_synth: u64, slow_factor: f64) -> SimTime {
    let is_slow = SimTime::ps((p.sim_inference.as_ps() as f64 * slow_factor) as u64);
    SimTime::ps((n_sim + n_synth) * (p.compile + is_slow).as_ps())
}

/// The §V-B headline: average evaluation-time reduction of SECDA vs the
/// synthesis-only flow for the same iteration plan.
pub fn secda_speedup(p: &DevTimeParams, n_sim: u64, n_synth: u64) -> f64 {
    eq2_synth_only(p, n_sim, n_synth).as_secs_f64() / eq1_secda(p, n_sim, n_synth).as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesis_dominates() {
        let p = DevTimeParams::paper_like();
        // S_t / C_t ≈ 25x (the paper's measured ratio)
        let ratio = p.synthesis.as_secs_f64() / p.compile.as_secs_f64();
        assert!((20.0..=30.0).contains(&ratio), "{ratio}");
    }

    #[test]
    fn secda_beats_synth_only_by_order_of_magnitude() {
        // The paper's flow: dozens of sim iterations, a handful of
        // synthesis passes -> ~16x less time evaluating designs.
        let p = DevTimeParams::paper_like();
        let s = secda_speedup(&p, 50, 3);
        assert!((8.0..=25.0).contains(&s), "speedup {s}");
    }

    #[test]
    fn full_system_sim_is_worst() {
        let p = DevTimeParams::paper_like();
        // SMAUG-like: each end-to-end sim takes ~100x longer
        let smaug = eq3_full_sim(&p, 50, 3, 100.0);
        let secda = eq1_secda(&p, 50, 3);
        assert!(smaug.as_secs_f64() > secda.as_secs_f64() * 5.0);
    }

    #[test]
    fn eq1_reduces_to_eq2_without_sim() {
        let p = DevTimeParams::paper_like();
        assert_eq!(
            eq1_secda(&p, 0, 5).as_ps(),
            eq2_synth_only(&p, 0, 5).as_ps()
        );
    }
}
