//! Calibration constants for the PYNQ-Z1 models, with provenance.
//!
//! Every constant here is fit against the *CPU-only* rows of the
//! paper's Table II (the measured baselines), so that the accelerated
//! configurations are genuine predictions of the simulators:
//!
//! * `GEMM_MACS_PER_SEC`: MobileNetV1 CPU(1thr) CONV = 635 ms over
//!   ~568M GEMM MACs + 42M depthwise MACs + im2col ⇒ ≈ 1.0 GMAC/s
//!   effective for gemmlowp int8 on one A9 @650MHz (NEON, ~1.6
//!   MAC/cycle). Cross-checked against InceptionV1 (1416 ms / 1.58G
//!   MACs ⇒ 1.12 GMAC/s) and ResNet18 (1762 ms / 1.82G ⇒ 1.03 GMAC/s).
//! * `SECOND_THREAD_SCALING`: CONV 2-thread speedups in Table II are
//!   635/329=1.93 (MbV1), 526/277=1.90 (MbV2), 1416/736=1.92 (IncV1),
//!   1762/919=1.92 (Res18) ⇒ 0.92 marginal second-core efficiency.
//! * Power: CPU 1thr rows average 2.36 W, 2thr rows 2.60 W across the
//!   four models ⇒ P_idle ≈ 2.13 W, P_thread ≈ 0.23 W. The accelerated
//!   rows run at visibly higher board power (SA ResNet18 2thr: 1.76 J /
//!   537 ms = 3.28 W) ⇒ ~0.9 W marginal fabric power while the
//!   accelerator is active.
//! * `NONCONV_*`: MobileNetV1 Non-CONV 141 ms (1thr) over ~5.5 MB of
//!   streamed activation traffic ⇒ ~40 MB/s effective element-wise
//!   throughput (quantized add/pool/softmax are requant-heavy).

use super::{CpuModel, EnergyModel};
use crate::sysc::SimTime;

/// Effective gemmlowp int8 GEMM throughput per A9 core (see module
/// doc for the Table II fit).
pub const GEMM_MACS_PER_SEC: f64 = 1.05e9;
/// Depthwise-conv throughput per core (lower arithmetic intensity).
pub const DWCONV_MACS_PER_SEC: f64 = 0.40e9;
/// Streaming element-wise (add/pool/requant) throughput per core.
pub const ELEMENTWISE_BYTES_PER_SEC: f64 = 100.0e6;
/// im2col / accelerator-layout reshape throughput per core.
pub const RESHAPE_BYTES_PER_SEC: f64 = 180.0e6;
/// gemmlowp int32→int8 output-unpack throughput per core.
pub const UNPACK_OUTPUTS_PER_SEC: f64 = 120.0e6;
/// Fixed per-op dispatch overhead, microseconds.
pub const OP_OVERHEAD_US: u64 = 20;
/// Table II Non-CONV columns sit at 117-176 ms (1 thread) even for
/// models with almost no non-conv compute (MobileNetV1's GAP+FC+softmax
/// is < 10 ms of real work): the bulk is TFLite interpreter dispatch,
/// quantize/dequantize of the input/output, and allocator churn. We
/// model it as a fixed per-inference cost.
pub const FRAMEWORK_OVERHEAD_MS: u64 = 105;
/// Marginal efficiency of the second A9 core (Table II CONV scaling).
pub const SECOND_THREAD_SCALING: f64 = 0.92;

/// Board idle power (SoC static + DRAM + peripherals), watts.
pub const P_IDLE_W: f64 = 2.13;
/// Marginal power per active A9 thread, watts.
pub const P_PER_THREAD_W: f64 = 0.23;
/// Marginal fabric power while the accelerator is active, watts.
pub const P_FPGA_ACTIVE_W: f64 = 0.90;

/// GEMM throughput uplift of the arch-dispatched SIMD kernels
/// ([`crate::gemm::simd`]) over the scalar reference on the serving
/// host's CPU tier. Provenance: pinned to the floor of the PR's
/// acceptance criterion (≥ 4× on the 256³ int8 qgemm under AVX2, see
/// `benches/hotpath.rs`), deliberately *not* to a local measurement —
/// the model must stay machine-independent so cost-model decisions
/// (and the committed serving snapshot) are reproducible everywhere.
/// The pynq constants above are untouched: they model gemmlowp with
/// NEON on the A9 and remain the Table II baseline.
pub const SIMD_GEMM_UPLIFT: f64 = 4.0;
/// Unpack/requant uplift from the vectorized PPU row kernel, same
/// provenance and caveats as [`SIMD_GEMM_UPLIFT`].
pub const SIMD_UNPACK_UPLIFT: f64 = 4.0;

/// The calibrated [`CpuModel`] assembled from the constants above.
pub fn cpu_model() -> CpuModel {
    CpuModel {
        gemm_macs_per_sec: GEMM_MACS_PER_SEC,
        dwconv_macs_per_sec: DWCONV_MACS_PER_SEC,
        elementwise_bytes_per_sec: ELEMENTWISE_BYTES_PER_SEC,
        reshape_bytes_per_sec: RESHAPE_BYTES_PER_SEC,
        unpack_outputs_per_sec: UNPACK_OUTPUTS_PER_SEC,
        op_overhead: SimTime::us(OP_OVERHEAD_US),
        framework_overhead: SimTime::ms(FRAMEWORK_OVERHEAD_MS),
        second_thread_scaling: SECOND_THREAD_SCALING,
    }
}

/// The serving-tier [`CpuModel`]: the pynq calibration with the GEMM
/// and unpack rates scaled by the SIMD uplift constants. This is what
/// CPU workers in the serving pool actually run
/// ([`crate::gemm::simd`] dispatch), so the coordinator's cost model
/// estimates CPU capacity with it; the unscaled [`cpu_model`] remains
/// the paper-fidelity Table II baseline used by the driver and the
/// single-inference interpreter paths.
pub fn cpu_model_serving() -> CpuModel {
    CpuModel {
        gemm_macs_per_sec: GEMM_MACS_PER_SEC * SIMD_GEMM_UPLIFT,
        unpack_outputs_per_sec: UNPACK_OUTPUTS_PER_SEC * SIMD_UNPACK_UPLIFT,
        ..cpu_model()
    }
}

/// The calibrated [`EnergyModel`] assembled from the constants above.
pub fn energy_model() -> EnergyModel {
    EnergyModel {
        p_idle_w: P_IDLE_W,
        p_per_thread_w: P_PER_THREAD_W,
        p_fpga_active_w: P_FPGA_ACTIVE_W,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cross_check_inception_resnet_baselines() {
        // InceptionV1: 1.58G GEMM MACs / 1.05 GMAC/s ≈ 1.5 s ≈ paper's
        // 1416 ms CONV; ResNet18: 1.82G / 1.05 ≈ 1.73 s vs 1762 ms.
        let m = cpu_model();
        let inc = m.gemm_time(1_580_000_000, 1).as_ms_f64();
        assert!((1200.0..=1700.0).contains(&inc), "{inc}");
        let res = m.gemm_time(1_820_000_000, 1).as_ms_f64();
        assert!((1500.0..=2000.0).contains(&res), "{res}");
    }

    #[test]
    fn power_fits_table2_average() {
        // 1thr rows ≈ 2.36 W, 2thr ≈ 2.60 W
        let e = energy_model();
        let p1 = e.p_idle_w + e.p_per_thread_w;
        let p2 = e.p_idle_w + 2.0 * e.p_per_thread_w;
        assert!((p1 - 2.36).abs() < 0.1, "{p1}");
        assert!((p2 - 2.60).abs() < 0.1, "{p2}");
    }
}
