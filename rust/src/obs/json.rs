//! A minimal JSON parser (std-only; the crate vendors no
//! dependencies). Used by the exporter validators in
//! [`crate::obs::export`], the `secda trace-validate` subcommand and
//! the golden trace tests — it only needs to *read back* what the
//! exporters wrote, so it favours simplicity over speed.

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (parsed as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, in source order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document. Trailing non-whitespace is an
    /// error.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object member lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The string contents, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The members, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            members.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(members));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            // surrogate pairs are not needed by our exporters
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {:?}", other.map(|b| b as char)))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-')
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("invalid number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse(r#""a\"b\nA""#).unwrap(),
            Json::Str("a\"b\nA".into())
        );
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(arr[2], Json::Null);
        assert!(v.get("c").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }
}
