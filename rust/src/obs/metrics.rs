//! Streaming metrics: fixed-bucket log-scale histograms and a typed
//! registry for flat JSON export.
//!
//! The histogram replaces the clone-and-sort `Vec<SimTime>` percentile
//! reads [`crate::coordinator::ServingMetrics`] used to do: recording
//! is O(1), a quantile query walks at most [`Histogram::BUCKETS`]
//! buckets, and nothing is ever cloned or sorted. Buckets are
//! HDR-style log-linear — each octave above 2^6 is split into 64
//! sub-buckets, so any reported quantile is within ~1.6% of the true
//! sample. Exact `min`/`max` are tracked on the side so the 0th and
//! 100th percentiles are exact, which keeps the pre-existing
//! `ServingMetrics` accessor contracts intact.

use std::sync::Mutex;

use crate::sysc::SimTime;

/// Sub-bucket resolution: each octave splits into `2^SUB_BITS` buckets.
const SUB_BITS: u32 = 6;
const SUB: usize = 1 << SUB_BITS;

/// A streaming log-linear histogram over `u64` values (picoseconds,
/// when used for [`SimTime`] samples).
pub struct Histogram {
    /// Lazily allocated on first record so an empty histogram is free.
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
    /// Bumped by every mutation ([`Histogram::record`],
    /// [`Histogram::merge`]); pairs with `cached` below.
    generation: u64,
    /// The snapshot computed at `generation`, so repeated registry
    /// reads between mutations (the fleet summary path samples every
    /// board's registry at every drain) are O(1) instead of four
    /// O(buckets) quantile scans each.
    cached: Mutex<Option<(u64, HistogramSnapshot)>>,
}

impl Clone for Histogram {
    fn clone(&self) -> Self {
        Histogram {
            buckets: self.buckets.clone(),
            count: self.count,
            sum: self.sum,
            min: self.min,
            max: self.max,
            generation: self.generation,
            cached: Mutex::new(self.cached.lock().expect("snapshot cache").clone()),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count)
            .field("min", &self.min)
            .field("max", &self.max)
            .field("mean", &self.mean())
            .finish()
    }
}

impl Histogram {
    /// Total number of buckets (fixed; covers the whole `u64` range).
    pub const BUCKETS: usize = (64 - SUB_BITS as usize + 1) * SUB;

    /// An empty histogram. Allocates nothing until the first record.
    pub fn new() -> Self {
        Histogram {
            buckets: Vec::new(),
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
            generation: 0,
            cached: Mutex::new(None),
        }
    }

    fn bucket_index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros();
        let octave = (msb - SUB_BITS + 1) as usize;
        let mantissa = ((v >> (msb - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        octave * SUB + mantissa
    }

    /// The largest value that lands in bucket `i` (the reported
    /// representative, so quantiles never under-estimate).
    fn bucket_upper(i: usize) -> u64 {
        if i < SUB {
            return i as u64;
        }
        let octave = i / SUB;
        let mantissa = (i % SUB) as u64;
        let shift = (octave - 1) as u32;
        let lower = (SUB as u64 + mantissa) << shift;
        lower.saturating_add((1u64 << shift) - 1)
    }

    /// Record one sample. O(1).
    pub fn record(&mut self, v: u64) {
        if self.buckets.is_empty() {
            self.buckets = vec![0; Self::BUCKETS];
        }
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.generation += 1;
    }

    /// Record one [`SimTime`] sample (its picosecond count).
    pub fn record_time(&mut self, t: SimTime) {
        self.record(t.as_ps());
    }

    /// Fold another histogram into this one. Bucket-wise addition:
    /// the merged histogram reports exactly what one histogram fed
    /// every sample from both sides would report (both use the same
    /// fixed bucket layout). This is how fleet-level tail latency is
    /// built from per-board [`crate::coordinator::ServingMetrics`]
    /// without retaining any samples. O(buckets); merging an empty
    /// histogram is free and allocates nothing.
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        if self.buckets.is_empty() {
            self.buckets = vec![0; Self::BUCKETS];
        }
        for (dst, src) in self.buckets.iter_mut().zip(&other.buckets) {
            *dst += src;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.generation += 1;
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min }
    }

    /// Exact largest recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Mean of all recorded values (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The `p`-quantile (`p` in `[0, 1]`), using the same
    /// nearest-rank convention the old sorted-vector accessor used:
    /// rank `round(p * (count - 1))`. O(buckets). The extremes are
    /// exact; interior quantiles are bucket upper bounds, within
    /// ~1.6% of the true sample. Returns 0 when empty.
    pub fn quantile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if self.min == self.max {
            return self.min;
        }
        let rank = (p.clamp(0.0, 1.0) * (self.count - 1) as f64).round() as u64;
        if rank == 0 {
            return self.min;
        }
        if rank >= self.count - 1 {
            return self.max;
        }
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum > rank {
                return Self::bucket_upper(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// [`Histogram::quantile`] as a [`SimTime`].
    pub fn quantile_time(&self, p: f64) -> SimTime {
        SimTime::ps(self.quantile(p))
    }

    /// A fixed summary (count/min/max/mean and standard quantiles)
    /// for the registry and the JSON exporter. Cached per mutation
    /// generation: the first call after a `record`/`merge` pays the
    /// four quantile scans, every repeated call is an O(1) clone.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut cache = self.cached.lock().expect("snapshot cache");
        if let Some((g, snap)) = cache.as_ref() {
            if *g == self.generation {
                return snap.clone();
            }
        }
        let snap = HistogramSnapshot {
            count: self.count(),
            min: self.min(),
            max: self.max(),
            mean: self.mean(),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            p999: self.quantile(0.999),
        };
        *cache = Some((self.generation, snap.clone()));
        snap
    }
}

/// A point-in-time summary of a [`Histogram`].
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    /// Number of samples.
    pub count: u64,
    /// Exact minimum.
    pub min: u64,
    /// Exact maximum.
    pub max: u64,
    /// Mean.
    pub mean: f64,
    /// Median (50th percentile).
    pub p50: u64,
    /// 90th percentile.
    pub p90: u64,
    /// 99th percentile.
    pub p99: u64,
    /// 99.9th percentile.
    pub p999: u64,
}

/// One exported metric value.
#[derive(Debug, Clone, PartialEq)]
pub enum MetricValue {
    /// A monotonic integer count.
    Counter(u64),
    /// A point-in-time float reading.
    Gauge(f64),
    /// A distribution summary.
    Histogram(HistogramSnapshot),
}

/// A named, ordered collection of metric readings — the unit the
/// flat-JSON exporter consumes. Built fresh per snapshot (e.g. by
/// [`crate::coordinator::ServingMetrics::registry`]), so it carries
/// values, not live instruments.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    entries: Vec<(String, MetricValue)>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        MetricsRegistry::default()
    }

    /// Add a counter reading.
    pub fn counter(&mut self, name: &str, v: u64) {
        self.entries.push((name.to_string(), MetricValue::Counter(v)));
    }

    /// Add a gauge reading.
    pub fn gauge(&mut self, name: &str, v: f64) {
        self.entries.push((name.to_string(), MetricValue::Gauge(v)));
    }

    /// Add a histogram summary.
    pub fn histogram(&mut self, name: &str, h: &Histogram) {
        self.entries
            .push((name.to_string(), MetricValue::Histogram(h.snapshot())));
    }

    /// All readings, in insertion order.
    pub fn entries(&self) -> &[(String, MetricValue)] {
        &self.entries
    }

    /// Look up a reading by name.
    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0);
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = Histogram::new();
        for v in 0..64u64 {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(1.0), 63);
        // every value below 2^6 has its own bucket
        assert_eq!(h.quantile(0.5), 32);
    }

    #[test]
    fn extremes_are_exact_and_interior_is_close() {
        let mut h = Histogram::new();
        let samples: Vec<u64> = (1..=1000u64).map(|i| i * 7_919).collect();
        for &s in &samples {
            h.record(s);
        }
        assert_eq!(h.quantile(0.0), samples[0]);
        assert_eq!(h.quantile(1.0), *samples.last().unwrap());
        for p in [0.1, 0.5, 0.9, 0.99] {
            let rank = (p * (samples.len() - 1) as f64).round() as usize;
            let exact = samples[rank] as f64;
            let got = h.quantile(p) as f64;
            let rel = (got - exact).abs() / exact;
            assert!(rel <= 0.016, "p{p}: got {got}, exact {exact}, rel {rel}");
            assert!(got >= exact, "bucket upper bound must not under-estimate");
        }
    }

    #[test]
    fn matches_old_sorted_percentile_on_distinct_ms_values() {
        // The exact scenario the pre-existing ServingMetrics tests pin.
        let mut h = Histogram::new();
        for ms in 11..=20u64 {
            h.record_time(SimTime::ms(ms));
        }
        assert_eq!(h.quantile_time(0.0), SimTime::ms(11));
        assert_eq!(h.quantile_time(1.0), SimTime::ms(20));
        let mut w = Histogram::new();
        w.record_time(SimTime::ms(1));
        w.record_time(SimTime::ms(1));
        assert_eq!(w.quantile_time(0.5), SimTime::ms(1));
    }

    #[test]
    fn huge_values_do_not_overflow() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX - 1);
        h.record(1);
        assert_eq!(h.max(), u64::MAX);
        assert_eq!(h.quantile(1.0), u64::MAX);
        assert_eq!(h.quantile(0.0), 1);
        assert!(h.quantile(0.5) >= u64::MAX - 1);
    }

    #[test]
    fn bucket_roundtrip_bounds() {
        for v in [0, 1, 63, 64, 65, 127, 128, 1 << 20, u64::MAX / 2, u64::MAX] {
            let i = Histogram::bucket_index(v);
            assert!(i < Histogram::BUCKETS, "index {i} out of range for {v}");
            let upper = Histogram::bucket_upper(i);
            assert!(upper >= v, "upper {upper} < value {v}");
            // representative error bounded by the sub-bucket width
            assert!(upper - v <= (v >> SUB_BITS), "loose bucket for {v}");
        }
    }

    #[test]
    fn merge_matches_recording_into_one() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        for i in 0..500u64 {
            let v = i * 104_729 + 13;
            if i % 3 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.snapshot(), all.snapshot());
    }

    #[test]
    fn merge_with_empty_sides() {
        let mut h = Histogram::new();
        h.record(42);
        let before = h.snapshot();
        // empty rhs: no-op
        h.merge(&Histogram::new());
        assert_eq!(h.snapshot(), before);
        // empty lhs: becomes a copy of rhs
        let mut empty = Histogram::new();
        empty.merge(&h);
        assert_eq!(empty.snapshot(), h.snapshot());
        // two empties stay empty (and allocation-free)
        let mut e = Histogram::new();
        e.merge(&Histogram::new());
        assert_eq!(e.count(), 0);
        assert_eq!(e.quantile(0.5), 0);
    }

    #[test]
    fn snapshot_cache_invalidates_on_mutation() {
        let mut h = Histogram::new();
        h.record(100);
        let first = h.snapshot();
        // repeated reads at the same generation come from the cache
        // and must be identical
        assert_eq!(h.snapshot(), first);
        // a record invalidates: the next snapshot sees the new sample
        h.record(1_000_000);
        let second = h.snapshot();
        assert_eq!(second.count, 2);
        assert!(second.max >= 1_000_000);
        // a merge invalidates too
        let mut other = Histogram::new();
        other.record(5);
        h.merge(&other);
        assert_eq!(h.snapshot().count, 3);
        assert_eq!(h.snapshot().min, 5);
        // clones carry the cache but stay independently consistent
        let c = h.clone();
        assert_eq!(c.snapshot(), h.snapshot());
    }

    #[test]
    fn registry_round_trip() {
        let mut h = Histogram::new();
        h.record(10);
        let mut r = MetricsRegistry::new();
        r.counter("completed", 7);
        r.gauge("throughput_rps", 1.5);
        r.histogram("latency", &h);
        assert_eq!(r.entries().len(), 3);
        assert_eq!(r.get("completed"), Some(&MetricValue::Counter(7)));
        match r.get("latency") {
            Some(MetricValue::Histogram(s)) => assert_eq!(s.count, 1),
            other => panic!("wrong entry: {other:?}"),
        }
    }
}
