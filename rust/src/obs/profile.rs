//! Continuous-profiling attribution: fold recorded `Batch`/`Request`/
//! `Gemm`/`Op` span slices into a self-time profile keyed by collapsed
//! stacks, so "where did the modeled cycles go" is one command
//! (`secda report --profile trace.json`) instead of a Perfetto
//! session.
//!
//! Stacks follow the slice nesting the scheduler already guarantees —
//! a worker's batches nest the requests they executed, which nest the
//! per-layer GEMM/op slices — and frames carry the attribution axes:
//! worker kind + design (from the batch's worker label), model, layer
//! and route. Self time is a slice's duration minus its children, so
//! the profile partitions modeled time with no double counting. The
//! text export is flamegraph-collapsed format (`frame;frame;... N`,
//! one stack per line, N in nanoseconds of modeled self time), which
//! `inferno`/`flamegraph.pl`/speedscope all ingest directly.
//!
//! Two entry points: [`AttributionProfile::from_spans`] for in-process
//! span snapshots, and [`AttributionProfile::from_chrome_trace`] for
//! an exported trace JSON — both feed the same geometric-containment
//! fold, so a post-hoc trace file attributes identically to a live
//! run.

use std::collections::BTreeMap;

use super::json::Json;
use super::span::{Span, Stage};

/// Nesting rank of an attributable slice: batches contain requests
/// contain compute slices.
fn stage_rank(stage: Stage) -> Option<u8> {
    match stage {
        Stage::Batch => Some(0),
        Stage::Request => Some(1),
        Stage::Gemm | Stage::Op => Some(2),
        _ => None,
    }
}

/// One attributable slice, normalized from either source.
struct Slice {
    /// Track key: `(pid, tid)` for traces, `(0, worker)` for spans.
    key: (u64, u64),
    start_ps: u64,
    end_ps: u64,
    rank: u8,
    /// Root worker frame, used when this slice is stack-bottom.
    root: String,
    /// This slice's own frame label.
    frame: String,
}

fn attr<'a>(attrs: &'a [(&'static str, String)], key: &str) -> Option<&'a str> {
    attrs
        .iter()
        .find(|(k, _)| *k == key)
        .map(|(_, v)| v.as_str())
}

/// A self-time profile over collapsed stacks.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AttributionProfile {
    /// `frame;frame;...` → modeled self time in nanoseconds.
    stacks: BTreeMap<String, u64>,
}

impl AttributionProfile {
    /// Fold an in-process span snapshot.
    pub fn from_spans(spans: &[Span]) -> Self {
        let mut slices = Vec::new();
        for s in spans {
            let Some(rank) = stage_rank(s.stage) else {
                continue;
            };
            let Some(w) = s.worker else { continue };
            if s.t_end <= s.t_start {
                continue;
            }
            let root = match attr(&s.attrs, "worker") {
                Some(l) => format!("worker:{l}"),
                None => format!("worker:w{w}"),
            };
            let frame = match s.stage {
                Stage::Batch => format!("batch:{}", attr(&s.attrs, "model").unwrap_or("?")),
                Stage::Request => {
                    format!("request:{}", attr(&s.attrs, "model").unwrap_or("?"))
                }
                Stage::Gemm => format!(
                    "gemm:{}:{}",
                    attr(&s.attrs, "layer").unwrap_or("?"),
                    attr(&s.attrs, "route").unwrap_or("?")
                ),
                Stage::Op => format!("op:{}", attr(&s.attrs, "layer").unwrap_or("?")),
                _ => unreachable!("stage_rank filtered"),
            };
            slices.push(Slice {
                key: (0, w as u64),
                start_ps: s.t_start.as_ps(),
                end_ps: s.t_end.as_ps(),
                rank,
                root,
                frame,
            });
        }
        Self::fold(slices)
    }

    /// Fold an exported Chrome trace (the `X` slices of
    /// [`super::export::chrome_trace`] or the fleet variant).
    pub fn from_chrome_trace(json: &str) -> Result<Self, String> {
        let doc = Json::parse(json)?;
        let events = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .ok_or("missing traceEvents array")?;
        let mut slices = Vec::new();
        for e in events {
            if e.get("ph").and_then(Json::as_str) != Some("X") {
                continue;
            }
            let name = e.get("name").and_then(Json::as_str).unwrap_or("");
            let sarg = |k: &str| -> Option<String> {
                e.get("args")
                    .and_then(|a| a.get(k))
                    .and_then(Json::as_str)
                    .map(str::to_string)
            };
            let (rank, frame, worker_label) = if name == "batch" {
                (
                    0u8,
                    format!("batch:{}", sarg("model").unwrap_or_else(|| "?".into())),
                    sarg("worker"),
                )
            } else if name == "request" || name.starts_with("request ") {
                (
                    1,
                    format!("request:{}", sarg("model").unwrap_or_else(|| "?".into())),
                    None,
                )
            } else if name == "gemm" {
                (
                    2,
                    format!(
                        "gemm:{}:{}",
                        sarg("layer").unwrap_or_else(|| "?".into()),
                        sarg("route").unwrap_or_else(|| "?".into())
                    ),
                    None,
                )
            } else if name == "op" {
                (
                    2,
                    format!("op:{}", sarg("layer").unwrap_or_else(|| "?".into())),
                    None,
                )
            } else {
                continue;
            };
            let num = |k: &str| -> Result<f64, String> {
                e.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("slice {name}: missing numeric {k}"))
            };
            let ts = num("ts")?;
            let dur = num("dur")?;
            if dur <= 0.0 {
                continue;
            }
            let pid = num("pid")? as u64;
            let tid = num("tid")? as u64;
            let root = match worker_label {
                Some(l) => format!("worker:{l}"),
                None => format!("worker:p{pid}t{tid}"),
            };
            slices.push(Slice {
                key: (pid, tid),
                // trace timestamps are microseconds
                start_ps: (ts * 1e6).round() as u64,
                end_ps: ((ts + dur) * 1e6).round() as u64,
                rank,
                root,
                frame,
            });
        }
        Ok(Self::fold(slices))
    }

    /// The geometric-containment fold shared by both sources: per
    /// track, sweep slices in start order keeping the stack of open
    /// ancestors; a slice's self time is its duration minus the
    /// durations of its direct children.
    fn fold(mut slices: Vec<Slice>) -> Self {
        slices.sort_by(|a, b| {
            a.key
                .cmp(&b.key)
                .then(a.start_ps.cmp(&b.start_ps))
                .then(b.end_ps.cmp(&a.end_ps))
                .then(a.rank.cmp(&b.rank))
                .then(a.frame.cmp(&b.frame))
        });
        struct Open {
            end_ps: u64,
            path: String,
            dur_ps: u64,
            child_ps: u64,
        }
        let mut stacks: BTreeMap<String, u64> = BTreeMap::new();
        let mut flush = |o: Open| {
            let self_ns = o.dur_ps.saturating_sub(o.child_ps) / 1_000;
            if self_ns > 0 {
                *stacks.entry(o.path).or_insert(0) += self_ns;
            }
        };
        let mut open: Vec<Open> = Vec::new();
        let mut cur_key = None;
        for s in slices {
            if cur_key != Some(s.key) {
                while let Some(o) = open.pop() {
                    flush(o);
                }
                cur_key = Some(s.key);
            }
            while open.last().is_some_and(|o| o.end_ps <= s.start_ps) {
                let o = open.pop().expect("checked");
                flush(o);
            }
            let dur_ps = s.end_ps - s.start_ps;
            let path = match open.last_mut() {
                Some(parent) => {
                    parent.child_ps += dur_ps;
                    format!("{};{}", parent.path, s.frame)
                }
                None => format!("{};{}", s.root, s.frame),
            };
            open.push(Open {
                end_ps: s.end_ps,
                path,
                dur_ps,
                child_ps: 0,
            });
        }
        while let Some(o) = open.pop() {
            flush(o);
        }
        AttributionProfile { stacks }
    }

    /// Collapsed-stack text: one `frame;frame;... self_ns` line per
    /// stack, lexicographically ordered (deterministic).
    pub fn collapsed(&self) -> String {
        let mut out = String::new();
        for (path, ns) in &self.stacks {
            out.push_str(path);
            out.push(' ');
            out.push_str(&ns.to_string());
            out.push('\n');
        }
        out
    }

    /// Top `n` leaf frames by aggregate self time (descending, name
    /// tie-break ascending).
    pub fn top(&self, n: usize) -> Vec<(String, u64)> {
        let mut by_leaf: BTreeMap<&str, u64> = BTreeMap::new();
        for (path, ns) in &self.stacks {
            let leaf = path.rsplit(';').next().unwrap_or(path);
            *by_leaf.entry(leaf).or_insert(0) += ns;
        }
        let mut v: Vec<(String, u64)> = by_leaf
            .into_iter()
            .map(|(k, ns)| (k.to_string(), ns))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }

    /// Total attributed self time, nanoseconds.
    pub fn total_ns(&self) -> u64 {
        self.stacks.values().sum()
    }

    /// Number of distinct stacks.
    pub fn len(&self) -> usize {
        self.stacks.len()
    }

    /// True when nothing was attributable.
    pub fn is_empty(&self) -> bool {
        self.stacks.is_empty()
    }

    /// Iterate `(stack, self_ns)` in stack order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.stacks.iter().map(|(k, v)| (k.as_str(), *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::chrome_trace;
    use crate::sysc::SimTime;

    fn slice(
        stage: Stage,
        w: usize,
        t0: u64,
        t1: u64,
        attrs: &[(&'static str, &str)],
    ) -> Span {
        let mut s = Span::new(stage, SimTime::us(t0), SimTime::us(t1));
        s.worker = Some(w);
        s.request_id = Some(0);
        s.attrs = attrs.iter().map(|(k, v)| (*k, v.to_string())).collect();
        s
    }

    fn golden_spans() -> Vec<Span> {
        vec![
            slice(
                Stage::Batch,
                0,
                0,
                100,
                &[("worker", "sa0:SA"), ("model", "m"), ("size", "1")],
            ),
            slice(Stage::Request, 0, 10, 90, &[("model", "m")]),
            slice(
                Stage::Gemm,
                0,
                10,
                50,
                &[("layer", "m.c1"), ("route", "accel"), ("shape", "8x9x4")],
            ),
            slice(Stage::Op, 0, 50, 90, &[("layer", "m.gap")]),
        ]
    }

    #[test]
    fn self_time_partitions_the_batch() {
        let p = AttributionProfile::from_spans(&golden_spans());
        // batch 100us − request 80us = 20us; request 80 − 40 − 40 = 0
        // (dropped); gemm and op keep their full 40us.
        assert_eq!(
            p.collapsed(),
            "worker:sa0:SA;batch:m 20000\n\
             worker:sa0:SA;batch:m;request:m;gemm:m.c1:accel 40000\n\
             worker:sa0:SA;batch:m;request:m;op:m.gap 40000\n"
        );
        assert_eq!(p.total_ns(), 100_000);
        let top = p.top(2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].1, 40_000);
    }

    #[test]
    fn trace_round_trip_attributes_identically() {
        let spans = golden_spans();
        let from_spans = AttributionProfile::from_spans(&spans);
        let from_trace = AttributionProfile::from_chrome_trace(&chrome_trace(&spans))
            .expect("trace parses");
        assert_eq!(from_spans, from_trace);
    }

    #[test]
    fn sibling_batches_do_not_nest() {
        let spans = vec![
            slice(
                Stage::Batch,
                0,
                0,
                10,
                &[("worker", "sa0:SA"), ("model", "a")],
            ),
            // second batch starts exactly where the first ends
            slice(
                Stage::Batch,
                0,
                10,
                30,
                &[("worker", "sa0:SA"), ("model", "b")],
            ),
            // other worker overlaps in time but is its own track
            slice(
                Stage::Batch,
                1,
                0,
                30,
                &[("worker", "vm1:VM"), ("model", "c")],
            ),
        ];
        let p = AttributionProfile::from_spans(&spans);
        assert_eq!(
            p.collapsed(),
            "worker:sa0:SA;batch:a 10000\n\
             worker:sa0:SA;batch:b 20000\n\
             worker:vm1:VM;batch:c 30000\n"
        );
    }
}
