//! Exporters: Chrome trace-event JSON (Perfetto-loadable) and a flat
//! metrics snapshot, plus the validators the `secda trace-validate`
//! subcommand and CI run against them.
//!
//! The trace layout: one process (pid 0) with one track per pool
//! worker, a coordinator track for submit/admission instants, and an
//! elastic-controller track for estimator windows, plans and
//! reconfigurations. Queue waits are async spans (they overlap
//! arbitrarily across requests), and each admitted request gets a
//! flow arrow from its submit instant to its execution span.
//! [`fleet_chrome_trace`] replicates that whole layout once per board
//! (pid = board index), so a fleet run loads as one process group per
//! board with the boards' timelines aligned on the shared modeled
//! clock.

use std::fmt::Write as _;

use crate::sysc::trace::TraceEntry;

use super::alert::{Alert, AlertKind};
use super::metrics::{MetricValue, MetricsRegistry};
use super::span::{Span, Stage};
use super::timeseries::SeriesBank;

/// Track ids within pid 0.
const TID_COORD: u64 = 0;
const TID_ELASTIC: u64 = 900;

fn worker_tid(w: usize) -> u64 {
    1 + w as u64
}

/// Append `s` to `out` with JSON string escaping.
fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

/// `s` as a JSON string literal body (no quotes).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    escape_into(&mut out, s);
    out
}

fn fmt_f64(v: f64) -> String {
    if !v.is_finite() {
        return "0".into();
    }
    let mut s = format!("{v:.6}");
    while s.ends_with('0') {
        s.pop();
    }
    if s.ends_with('.') {
        s.pop();
    }
    s
}

/// Assembles Chrome trace-event JSON one event at a time, then sorts
/// by timestamp (metadata first) and renders the final document.
/// Timestamps and durations are in microseconds, per the format spec.
#[derive(Debug, Default)]
pub struct ChromeTraceBuilder {
    // (ts_us, rank, rendered event) — rank 0 sorts metadata first
    events: Vec<(f64, u8, String)>,
}

impl ChromeTraceBuilder {
    /// An empty builder.
    pub fn new() -> Self {
        ChromeTraceBuilder::default()
    }

    fn args_into(out: &mut String, args: &[(&str, String)]) {
        if args.is_empty() {
            return;
        }
        out.push_str(",\"args\":{");
        for (i, (k, v)) in args.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            escape_into(out, k);
            out.push_str("\":\"");
            escape_into(out, v);
            out.push('"');
        }
        out.push('}');
    }

    fn head(name: &str, cat: &str, ph: char, ts_us: f64, pid: u64, tid: u64) -> String {
        let mut e = String::with_capacity(96);
        e.push_str("{\"name\":\"");
        escape_into(&mut e, name);
        e.push_str("\",\"cat\":\"");
        escape_into(&mut e, cat);
        let _ = write!(
            e,
            "\",\"ph\":\"{ph}\",\"ts\":{},\"pid\":{pid},\"tid\":{tid}",
            fmt_f64(ts_us)
        );
        e
    }

    /// Name a process (`M`/`process_name` metadata event) — the fleet
    /// exporter uses one process per board.
    pub fn process_name(&mut self, pid: u64, name: &str) {
        let mut e = String::with_capacity(96);
        let _ = write!(
            e,
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"ts\":0,\"pid\":{pid},\"tid\":0,\"args\":{{\"name\":\""
        );
        escape_into(&mut e, name);
        e.push_str("\"}}");
        self.events.push((f64::NEG_INFINITY, 0, e));
    }

    /// Name a track (`M`/`thread_name` metadata event).
    pub fn thread_name(&mut self, pid: u64, tid: u64, name: &str) {
        let mut e = String::with_capacity(96);
        let _ = write!(
            e,
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"ts\":0,\"pid\":{pid},\"tid\":{tid},\"args\":{{\"name\":\""
        );
        escape_into(&mut e, name);
        e.push_str("\"}}");
        self.events.push((f64::NEG_INFINITY, 0, e));
    }

    /// A complete (`X`) event: a slice with a duration.
    #[allow(clippy::too_many_arguments)]
    pub fn complete(
        &mut self,
        name: &str,
        cat: &str,
        ts_us: f64,
        dur_us: f64,
        pid: u64,
        tid: u64,
        args: &[(&str, String)],
    ) {
        let mut e = Self::head(name, cat, 'X', ts_us, pid, tid);
        let _ = write!(e, ",\"dur\":{}", fmt_f64(dur_us.max(0.0)));
        Self::args_into(&mut e, args);
        e.push('}');
        self.events.push((ts_us, 1, e));
    }

    /// An instant (`i`) event, thread-scoped.
    pub fn instant(
        &mut self,
        name: &str,
        cat: &str,
        ts_us: f64,
        pid: u64,
        tid: u64,
        args: &[(&str, String)],
    ) {
        let mut e = Self::head(name, cat, 'i', ts_us, pid, tid);
        e.push_str(",\"s\":\"t\"");
        Self::args_into(&mut e, args);
        e.push('}');
        self.events.push((ts_us, 1, e));
    }

    /// A counter (`C`) event: one sample of a numeric counter track.
    pub fn counter(&mut self, name: &str, cat: &str, ts_us: f64, pid: u64, tid: u64, value: f64) {
        let mut e = Self::head(name, cat, 'C', ts_us, pid, tid);
        let _ = write!(e, ",\"args\":{{\"value\":{}}}}}", fmt_f64(value));
        self.events.push((ts_us, 1, e));
    }

    /// A flow-start (`s`) event; the arrow source.
    pub fn flow_start(&mut self, name: &str, cat: &str, id: u64, ts_us: f64, pid: u64, tid: u64) {
        let mut e = Self::head(name, cat, 's', ts_us, pid, tid);
        let _ = write!(e, ",\"id\":{id}}}");
        self.events.push((ts_us, 1, e));
    }

    /// A flow-finish (`f`, binding to the enclosing slice) event; the
    /// arrow target.
    pub fn flow_finish(&mut self, name: &str, cat: &str, id: u64, ts_us: f64, pid: u64, tid: u64) {
        let mut e = Self::head(name, cat, 'f', ts_us, pid, tid);
        let _ = write!(e, ",\"bp\":\"e\",\"id\":{id}}}");
        self.events.push((ts_us, 2, e));
    }

    /// An async-begin (`b`) event. Async spans may overlap freely.
    pub fn async_begin(&mut self, name: &str, cat: &str, id: u64, ts_us: f64, pid: u64, tid: u64) {
        let mut e = Self::head(name, cat, 'b', ts_us, pid, tid);
        let _ = write!(e, ",\"id\":{id}}}");
        self.events.push((ts_us, 1, e));
    }

    /// The matching async-end (`e`) event.
    pub fn async_end(&mut self, name: &str, cat: &str, id: u64, ts_us: f64, pid: u64, tid: u64) {
        let mut e = Self::head(name, cat, 'e', ts_us, pid, tid);
        let _ = write!(e, ",\"id\":{id}}}");
        self.events.push((ts_us, 1, e));
    }

    /// Sort events by timestamp (metadata first) and render the
    /// document.
    pub fn finish(mut self) -> String {
        self.events
            .sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut out = String::with_capacity(64 + self.events.iter().map(|e| e.2.len() + 2).sum::<usize>());
        out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
        for (i, (_, _, e)) in self.events.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(e);
        }
        out.push_str("\n]}\n");
        out
    }
}

/// Export serving spans as Chrome trace-event JSON.
///
/// Load the result in [Perfetto](https://ui.perfetto.dev) or
/// `chrome://tracing`: one track per worker (batches nesting requests
/// nesting per-GEMM/per-op slices), async queue-wait spans, flow
/// arrows from each submit to its execution, and the elastic
/// controller's windows/plans/reconfigurations on their own track.
pub fn chrome_trace(spans: &[Span]) -> String {
    let mut b = ChromeTraceBuilder::new();
    emit_serving_spans(&mut b, 0, 0, spans);
    b.finish()
}

/// Export a fleet run as one Chrome trace: each board's serving spans
/// on its own process (pid = board index, named `boardN`), with the
/// full per-board track layout of [`chrome_trace`] replicated under
/// each pid. Flow and async ids are namespaced per board (request ids
/// restart at 0 on every board), so arrows and queue spans never pair
/// across boards. All boards share the fleet's modeled clock, so the
/// merged document stays globally timestamp-sorted and passes
/// [`validate_chrome_trace`].
pub fn fleet_chrome_trace(boards: &[Vec<Span>]) -> String {
    let mut b = ChromeTraceBuilder::new();
    for (i, spans) in boards.iter().enumerate() {
        let pid = i as u64;
        b.process_name(pid, &format!("board{i}"));
        emit_serving_spans(&mut b, pid, (pid + 1) << 32, spans);
    }
    b.finish()
}

/// The shared span→event mapping behind [`chrome_trace`] and
/// [`fleet_chrome_trace`]: emit one board's serving spans under `pid`,
/// offsetting every flow/async id by `id_base` so per-board request
/// ids stay distinct in a merged fleet document.
fn emit_serving_spans(b: &mut ChromeTraceBuilder, pid: u64, id_base: u64, spans: &[Span]) {
    // name the tracks: coordinator, each worker seen, elastic
    b.thread_name(pid, TID_COORD, "coordinator");
    let mut workers: Vec<(usize, Option<String>)> = Vec::new();
    let mut saw_elastic = false;
    for s in spans {
        if let Some(w) = s.worker {
            let label = s
                .attrs
                .iter()
                .find(|(k, _)| *k == "worker")
                .map(|(_, v)| v.clone());
            match workers.iter_mut().find(|(idx, _)| *idx == w) {
                Some((_, slot)) => {
                    if slot.is_none() {
                        *slot = label;
                    }
                }
                None => workers.push((w, label)),
            }
        }
        if matches!(
            s.stage,
            Stage::EstimatorWindow | Stage::Plan | Stage::Reconfigure
        ) {
            saw_elastic = true;
        }
    }
    workers.sort_by_key(|(idx, _)| *idx);
    for (idx, label) in &workers {
        let name = match label {
            Some(l) => format!("worker{idx} ({l})"),
            None => format!("worker{idx}"),
        };
        b.thread_name(pid, worker_tid(*idx), &name);
    }
    if saw_elastic {
        b.thread_name(pid, TID_ELASTIC, "elastic controller");
    }

    for s in spans {
        let ts = s.t_start.as_us_f64();
        let dur = s.duration().as_us_f64();
        let tid = s.worker.map(worker_tid).unwrap_or(TID_COORD);
        let args: Vec<(&str, String)> = s.attrs.clone();
        match s.stage {
            Stage::Submit => {
                b.instant("submit", "serving", ts, pid, TID_COORD, &args);
                if let Some(id) = s.request_id {
                    b.flow_start("req", "serving", id_base + id, ts, pid, TID_COORD);
                }
            }
            Stage::Admission => b.instant("admission", "serving", ts, pid, TID_COORD, &args),
            Stage::QueueWait => {
                if let Some(id) = s.request_id {
                    let name = format!("queue r{id}");
                    b.async_begin(&name, "queue", id_base + id, ts, pid, tid);
                    b.async_end(&name, "queue", id_base + id, s.t_end.as_us_f64(), pid, tid);
                }
            }
            Stage::Batch => b.complete("batch", "serving", ts, dur, pid, tid, &args),
            Stage::Request => {
                let name = match s.request_id {
                    Some(id) => format!("request r{id}"),
                    None => "request".to_string(),
                };
                b.complete(&name, "serving", ts, dur, pid, tid, &args);
                if let Some(id) = s.request_id {
                    b.flow_finish("req", "serving", id_base + id, ts, pid, tid);
                }
            }
            Stage::Gemm => b.complete("gemm", "compute", ts, dur, pid, tid, &args),
            Stage::Op => b.complete("op", "compute", ts, dur, pid, tid, &args),
            Stage::SimEvent => {
                let name = s
                    .attrs
                    .iter()
                    .find(|(k, _)| *k == "label")
                    .map(|(_, v)| v.as_str())
                    .unwrap_or("sim");
                b.instant(name, "sim", ts, pid, tid, &args);
            }
            Stage::EstimatorWindow => {
                b.complete("estimator window", "elastic", ts, dur, pid, TID_ELASTIC, &args)
            }
            Stage::Plan => b.instant("plan", "elastic", ts, pid, TID_ELASTIC, &args),
            Stage::Reconfigure => {
                // the instant marker the issue asks for, plus the
                // bitstream-load interval itself
                b.instant("reconfigure!", "elastic", ts, pid, TID_ELASTIC, &args);
                b.complete("reconfigure", "elastic", ts, dur, pid, TID_ELASTIC, &args);
            }
            Stage::Alert => b.instant("alert", "alert", ts, pid, TID_COORD, &args),
        }
    }
}

/// Emit one telemetry bank as Perfetto counter tracks under `pid`:
/// one `C` track per series (named `ts.<series>`), one sample per
/// retained point.
fn emit_counter_tracks(b: &mut ChromeTraceBuilder, pid: u64, bank: &SeriesBank) {
    for s in bank.iter() {
        let name = format!("ts.{}", s.name());
        for (t, v) in s.points() {
            b.counter(&name, "telemetry", t.as_us_f64(), pid, TID_COORD, v);
        }
    }
}

/// [`chrome_trace`] plus the telemetry bank's series merged in as
/// Perfetto counter tracks, so the load curves render above the same
/// worker timeline.
pub fn chrome_trace_with_series(spans: &[Span], bank: &SeriesBank) -> String {
    let mut b = ChromeTraceBuilder::new();
    emit_serving_spans(&mut b, 0, 0, spans);
    emit_counter_tracks(&mut b, 0, bank);
    b.finish()
}

/// [`fleet_chrome_trace`] plus telemetry: per-board counter tracks
/// under each board's pid (`series[i]`, when present), and the merged
/// fleet-level bank as its own `fleet` process after the boards.
pub fn fleet_chrome_trace_with_series(
    boards: &[Vec<Span>],
    series: &[Option<&SeriesBank>],
    fleet: Option<&SeriesBank>,
) -> String {
    let mut b = ChromeTraceBuilder::new();
    for (i, spans) in boards.iter().enumerate() {
        let pid = i as u64;
        b.process_name(pid, &format!("board{i}"));
        emit_serving_spans(&mut b, pid, (pid + 1) << 32, spans);
        if let Some(Some(bank)) = series.get(i) {
            emit_counter_tracks(&mut b, pid, bank);
        }
    }
    if let Some(bank) = fleet {
        let pid = boards.len() as u64;
        b.process_name(pid, "fleet");
        b.thread_name(pid, TID_COORD, "fleet telemetry");
        emit_counter_tracks(&mut b, pid, bank);
    }
    b.finish()
}

/// Export a simulator [`crate::sysc::Trace`]'s entries as Chrome
/// trace-event JSON: one track per module, one instant per entry.
/// (Backs [`crate::sysc::Trace::to_chrome_json`].)
pub fn sim_trace_chrome_json(entries: &[TraceEntry]) -> String {
    const PID: u64 = 0;
    let mut b = ChromeTraceBuilder::new();
    let mut modules: Vec<&str> = Vec::new();
    for e in entries {
        if !modules.iter().any(|m| *m == e.module) {
            modules.push(&e.module);
        }
    }
    for (i, m) in modules.iter().enumerate() {
        b.thread_name(PID, i as u64, m);
    }
    for e in entries {
        let tid = modules.iter().position(|m| *m == e.module).unwrap() as u64;
        b.instant(
            &e.label,
            "sim",
            e.time.as_us_f64(),
            PID,
            tid,
            &[("module", e.module.clone())],
        );
    }
    b.finish()
}

/// Schema tag for metrics snapshots, checked by the validator.
pub const METRICS_SCHEMA: &str = "secda-metrics-v1";

/// Export a [`MetricsRegistry`] snapshot as flat JSON, grouped by
/// metric kind under a stable `"schema"` tag.
pub fn metrics_json(reg: &MetricsRegistry) -> String {
    let mut counters = String::new();
    let mut gauges = String::new();
    let mut hists = String::new();
    for (name, v) in reg.entries() {
        match v {
            MetricValue::Counter(c) => {
                if !counters.is_empty() {
                    counters.push(',');
                }
                let _ = write!(counters, "\n    \"{}\": {c}", json_escape(name));
            }
            MetricValue::Gauge(g) => {
                if !gauges.is_empty() {
                    gauges.push(',');
                }
                let _ = write!(gauges, "\n    \"{}\": {}", json_escape(name), fmt_f64(*g));
            }
            MetricValue::Histogram(h) => {
                if !hists.is_empty() {
                    hists.push(',');
                }
                let _ = write!(
                    hists,
                    "\n    \"{}\": {{\"count\": {}, \"min\": {}, \"max\": {}, \"mean\": {}, \"p50\": {}, \"p90\": {}, \"p99\": {}, \"p999\": {}}}",
                    json_escape(name),
                    h.count,
                    h.min,
                    h.max,
                    fmt_f64(h.mean),
                    h.p50,
                    h.p90,
                    h.p99,
                    h.p999
                );
            }
        }
    }
    format!(
        "{{\n  \"schema\": \"{METRICS_SCHEMA}\",\n  \"counters\": {{{counters}\n  }},\n  \"gauges\": {{{gauges}\n  }},\n  \"histograms\": {{{hists}\n  }}\n}}\n"
    )
}

/// Schema tag for time-series documents, checked by the validator.
pub const TIMESERIES_SCHEMA: &str = "secda-timeseries-v1";

/// Export a telemetry bank (and the alerts its engine fired) as a
/// `secda-timeseries-v1` JSON document: per series the kind, drop
/// count and `[t_us, value]` points; per alert the firing time, rule
/// kind, evaluated series and window evidence.
pub fn timeseries_json(bank: &SeriesBank, alerts: &[Alert]) -> String {
    let mut series = String::new();
    for s in bank.iter() {
        if !series.is_empty() {
            series.push(',');
        }
        let _ = write!(
            series,
            "\n    {{\"name\": \"{}\", \"kind\": \"{}\", \"dropped\": {}, \"points\": [",
            json_escape(s.name()),
            s.kind().name(),
            s.dropped()
        );
        for (i, (t, v)) in s.points().enumerate() {
            if i > 0 {
                series.push(',');
            }
            let _ = write!(series, "[{}, {}]", fmt_f64(t.as_us_f64()), fmt_f64(v));
        }
        series.push_str("]}");
    }
    let mut al = String::new();
    for a in alerts {
        if !al.is_empty() {
            al.push(',');
        }
        let _ = write!(
            al,
            "\n    {{\"at_us\": {}, \"kind\": \"{}\", \"series\": \"{}\", \"value\": {}, \"threshold\": {}, \"window_us\": {}}}",
            fmt_f64(a.at.as_us_f64()),
            a.kind.name(),
            json_escape(&a.series),
            fmt_f64(a.value),
            fmt_f64(a.threshold),
            fmt_f64(a.window.as_us_f64())
        );
    }
    format!(
        "{{\n  \"schema\": \"{TIMESERIES_SCHEMA}\",\n  \"series\": [{series}\n  ],\n  \"alerts\": [{al}\n  ]\n}}\n"
    )
}

/// Validate a `secda-timeseries-v1` document: schema tag, every series
/// has a known kind and timestamp-sorted numeric points, every alert a
/// known rule kind and complete evidence fields. Returns
/// `(series, alerts)` counts.
pub fn validate_timeseries_json(json: &str) -> Result<(usize, usize), String> {
    use super::json::Json;
    let doc = Json::parse(json)?;
    match doc.get("schema").and_then(Json::as_str) {
        Some(s) if s == TIMESERIES_SCHEMA => {}
        other => return Err(format!("bad schema tag {other:?} (want {TIMESERIES_SCHEMA})")),
    }
    let series = doc
        .get("series")
        .and_then(Json::as_arr)
        .ok_or("missing series array")?;
    for s in series {
        let name = s
            .get("name")
            .and_then(Json::as_str)
            .ok_or("series without name")?;
        match s.get("kind").and_then(Json::as_str) {
            Some("counter") | Some("gauge") => {}
            other => return Err(format!("series {name}: unknown kind {other:?}")),
        }
        s.get("dropped")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("series {name}: missing dropped"))?;
        let points = s
            .get("points")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("series {name}: missing points"))?;
        let mut last = f64::NEG_INFINITY;
        for p in points {
            let pair = p
                .as_arr()
                .filter(|a| a.len() == 2)
                .ok_or_else(|| format!("series {name}: point is not a [ts, value] pair"))?;
            let ts = pair[0]
                .as_f64()
                .ok_or_else(|| format!("series {name}: non-numeric ts"))?;
            pair[1]
                .as_f64()
                .ok_or_else(|| format!("series {name}: non-numeric value"))?;
            if ts < last {
                return Err(format!("series {name}: timestamps not sorted"));
            }
            last = ts;
        }
    }
    let alerts = doc
        .get("alerts")
        .and_then(Json::as_arr)
        .ok_or("missing alerts array")?;
    for (i, a) in alerts.iter().enumerate() {
        match a.get("kind").and_then(Json::as_str) {
            Some(k) if AlertKind::from_name(k).is_some() => {}
            other => return Err(format!("alert {i}: unknown kind {other:?}")),
        }
        a.get("series")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("alert {i}: missing series"))?;
        for field in ["at_us", "value", "threshold", "window_us"] {
            a.get(field)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("alert {i}: missing numeric {field}"))?;
        }
    }
    Ok((series.len(), alerts.len()))
}

/// What [`validate_chrome_trace`] found in a well-formed trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceCheck {
    /// Total events (including metadata).
    pub events: usize,
    /// Complete (`X`) slices.
    pub slices: usize,
    /// Instant (`i`) events.
    pub instants: usize,
    /// Named tracks (`thread_name` metadata events).
    pub tracks: usize,
    /// Matched submit→execution flow arrows.
    pub flows: usize,
    /// Counter (`C`) samples.
    pub counters: usize,
}

/// Validate Chrome trace-event JSON produced by [`chrome_trace`] (or
/// anything claiming the same shape): parses, every event carries the
/// mandatory fields for its phase, non-metadata events are sorted by
/// timestamp, async begin/end and flow start/finish pair up.
pub fn validate_chrome_trace(json: &str) -> Result<TraceCheck, String> {
    use super::json::Json;
    let doc = Json::parse(json)?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("missing traceEvents array")?;
    let mut check = TraceCheck {
        events: events.len(),
        slices: 0,
        instants: 0,
        tracks: 0,
        flows: 0,
        counters: 0,
    };
    let mut last_ts = f64::NEG_INFINITY;
    let mut flow_starts: Vec<u64> = Vec::new();
    let mut flow_finishes: Vec<u64> = Vec::new();
    let mut async_open: Vec<(String, u64)> = Vec::new();
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing name"))?;
        for field in ["ts", "pid", "tid"] {
            e.get(field)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("event {i} ({name}): missing numeric {field}"))?;
        }
        let ts = e.get("ts").and_then(Json::as_f64).unwrap();
        match ph {
            "M" => {
                if name == "thread_name" {
                    e.get("args")
                        .and_then(|a| a.get("name"))
                        .and_then(Json::as_str)
                        .ok_or_else(|| format!("event {i}: thread_name without args.name"))?;
                    check.tracks += 1;
                }
                continue; // metadata is exempt from ts ordering
            }
            "X" => {
                let dur = e
                    .get("dur")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("event {i} ({name}): X without dur"))?;
                if dur < 0.0 {
                    return Err(format!("event {i} ({name}): negative dur"));
                }
                check.slices += 1;
            }
            "i" => check.instants += 1,
            "C" => {
                e.get("args")
                    .and_then(|a| a.get("value"))
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("event {i} ({name}): counter without numeric args.value"))?;
                check.counters += 1;
            }
            "s" => flow_starts.push(
                e.get("id")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("event {i}: flow start without id"))?
                    as u64,
            ),
            "f" => flow_finishes.push(
                e.get("id")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("event {i}: flow finish without id"))?
                    as u64,
            ),
            "b" | "e" => {
                let id = e
                    .get("id")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("event {i}: async event without id"))?
                    as u64;
                let key = (name.to_string(), id);
                if ph == "b" {
                    async_open.push(key);
                } else {
                    let pos = async_open
                        .iter()
                        .position(|k| *k == key)
                        .ok_or_else(|| format!("event {i}: async end without begin ({name})"))?;
                    async_open.remove(pos);
                }
            }
            other => return Err(format!("event {i} ({name}): unknown phase {other:?}")),
        }
        if ts < last_ts {
            return Err(format!(
                "event {i} ({name}): timestamps not sorted ({ts} after {last_ts})"
            ));
        }
        last_ts = ts;
    }
    if !async_open.is_empty() {
        return Err(format!("{} async spans never ended", async_open.len()));
    }
    for id in &flow_finishes {
        if !flow_starts.contains(id) {
            return Err(format!("flow finish id {id} has no start"));
        }
        check.flows += 1;
    }
    Ok(check)
}

/// Validate a metrics snapshot produced by [`metrics_json`]: schema
/// tag, the three kind groups, and complete histogram summaries.
/// Returns the total number of metrics found.
pub fn validate_metrics_json(json: &str) -> Result<usize, String> {
    use super::json::Json;
    let doc = Json::parse(json)?;
    match doc.get("schema").and_then(Json::as_str) {
        Some(s) if s == METRICS_SCHEMA => {}
        other => return Err(format!("bad schema tag {other:?} (want {METRICS_SCHEMA})")),
    }
    let mut total = 0;
    for group in ["counters", "gauges", "histograms"] {
        let members = doc
            .get(group)
            .and_then(Json::as_obj)
            .ok_or_else(|| format!("missing {group} object"))?;
        for (name, v) in members {
            match group {
                "histograms" => {
                    for field in ["count", "min", "max", "mean", "p50", "p90", "p99", "p999"] {
                        v.get(field)
                            .and_then(Json::as_f64)
                            .ok_or_else(|| format!("histogram {name}: missing {field}"))?;
                    }
                }
                _ => {
                    v.as_f64()
                        .ok_or_else(|| format!("{group} entry {name} is not a number"))?;
                }
            }
            total += 1;
        }
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::{Histogram, MetricsRegistry};
    use crate::obs::span::{Span, SpanRecorder, Stage};
    use crate::sysc::SimTime;

    #[test]
    fn escaping_round_trips_through_parser() {
        let nasty = "a\"b\\c\nd\te\u{1}f";
        let doc = format!("{{\"k\": \"{}\"}}", json_escape(nasty));
        let parsed = crate::obs::json::Json::parse(&doc).unwrap();
        assert_eq!(parsed.get("k").and_then(|v| v.as_str()), Some(nasty));
    }

    #[test]
    fn exported_trace_validates() {
        let r = SpanRecorder::enabled(100);
        r.record(|| {
            let mut s = Span::instant(Stage::Submit, SimTime::us(1));
            s.request_id = Some(0);
            s.attrs.push(("model", "net".into()));
            s
        });
        r.record(|| {
            let mut s = Span::new(Stage::QueueWait, SimTime::us(1), SimTime::us(3));
            s.request_id = Some(0);
            s.worker = Some(0);
            s
        });
        r.record(|| {
            let mut s = Span::new(Stage::Batch, SimTime::us(3), SimTime::us(9));
            s.worker = Some(0);
            s.attrs.push(("worker", "sa0:SA".into()));
            s
        });
        r.record(|| {
            let mut s = Span::new(Stage::Request, SimTime::us(3), SimTime::us(9));
            s.request_id = Some(0);
            s.worker = Some(0);
            s
        });
        r.record(|| {
            let mut s = Span::new(Stage::Reconfigure, SimTime::us(9), SimTime::us(12));
            s.attrs.push(("from", "2SA+1VM".into()));
            s
        });
        let json = chrome_trace(&r.snapshot());
        let check = validate_chrome_trace(&json).expect("trace validates");
        assert!(check.slices >= 3, "{check:?}");
        assert_eq!(check.flows, 1, "{check:?}");
        // coordinator + worker0 + elastic
        assert_eq!(check.tracks, 3, "{check:?}");
    }

    #[test]
    fn fleet_trace_namespaces_boards_and_validates() {
        // two boards, both serving a request id 0: flows and async
        // queue spans must pair within a board, never across
        let board = |t0: u64| {
            let r = SpanRecorder::enabled(100);
            r.record(|| {
                let mut s = Span::instant(Stage::Submit, SimTime::us(t0));
                s.request_id = Some(0);
                s
            });
            r.record(|| {
                let mut s =
                    Span::new(Stage::QueueWait, SimTime::us(t0), SimTime::us(t0 + 2));
                s.request_id = Some(0);
                s.worker = Some(0);
                s
            });
            r.record(|| {
                let mut s = Span::new(Stage::Request, SimTime::us(t0 + 2), SimTime::us(t0 + 8));
                s.request_id = Some(0);
                s.worker = Some(0);
                s
            });
            r.snapshot()
        };
        let json = fleet_chrome_trace(&[board(1), board(2)]);
        let check = validate_chrome_trace(&json).expect("fleet trace validates");
        assert_eq!(check.flows, 2, "{check:?}");
        // (coordinator + worker0) per board
        assert_eq!(check.tracks, 4, "{check:?}");
        assert!(json.contains("board0") && json.contains("board1"), "{json}");
    }

    #[test]
    fn metrics_snapshot_validates() {
        let mut h = Histogram::new();
        for i in 1..=100u64 {
            h.record(i * 1000);
        }
        let mut reg = MetricsRegistry::new();
        reg.counter("completed", 100);
        reg.gauge("throughput_rps", 42.5);
        reg.histogram("latency_ps", &h);
        let json = metrics_json(&reg);
        assert_eq!(validate_metrics_json(&json), Ok(3));
    }

    #[test]
    fn validator_rejects_malformed_traces() {
        assert!(validate_chrome_trace("{}").is_err());
        assert!(validate_chrome_trace("{\"traceEvents\": [{\"ph\": \"X\"}]}").is_err());
        // unsorted timestamps
        let bad = r#"{"traceEvents": [
            {"name":"a","ph":"i","ts":5,"pid":0,"tid":0,"s":"t"},
            {"name":"b","ph":"i","ts":1,"pid":0,"tid":0,"s":"t"}
        ]}"#;
        assert!(validate_chrome_trace(bad).unwrap_err().contains("sorted"));
        assert!(validate_metrics_json("{\"schema\": \"nope\"}").is_err());
    }

    #[test]
    fn counter_tracks_and_timeseries_schema_validate() {
        use crate::obs::alert::{Alert, AlertKind};
        use crate::obs::timeseries::SeriesBank;

        let mut bank = SeriesBank::new(16);
        bank.counter("completed").push_counter(SimTime::us(10), 3);
        bank.counter("completed").push_counter(SimTime::us(20), 7);
        bank.gauge("queue_peak").push_gauge(SimTime::us(20), 4.0);
        let alerts = vec![Alert {
            at: SimTime::us(20),
            kind: AlertKind::BurnRate,
            series: "slo_missed".into(),
            value: 3.5,
            threshold: 2.0,
            window: SimTime::ms(2),
        }];

        // counter tracks merged into the chrome trace
        let r = SpanRecorder::enabled(16);
        r.record(|| {
            let mut s = Span::new(Stage::Batch, SimTime::us(3), SimTime::us(9));
            s.worker = Some(0);
            s
        });
        let json = chrome_trace_with_series(&r.snapshot(), &bank);
        let check = validate_chrome_trace(&json).expect("trace with counters validates");
        assert_eq!(check.counters, 3, "{check:?}");
        assert!(json.contains("ts.completed"), "{json}");

        // fleet variant: per-board + fleet-level counter process
        let fleet_json =
            fleet_chrome_trace_with_series(&[r.snapshot()], &[Some(&bank)], Some(&bank));
        let check = validate_chrome_trace(&fleet_json).expect("fleet trace validates");
        assert_eq!(check.counters, 6, "{check:?}");
        assert!(fleet_json.contains("\"fleet\""), "{fleet_json}");

        // timeseries document round-trips through its validator
        let doc = timeseries_json(&bank, &alerts);
        assert_eq!(validate_timeseries_json(&doc), Ok((2, 1)));
        assert!(validate_timeseries_json("{\"schema\": \"nope\"}").is_err());
        let bad = doc.replace("burn_rate", "nonsense");
        assert!(validate_timeseries_json(&bad).is_err());
    }

    #[test]
    fn fmt_f64_is_json_safe() {
        assert_eq!(fmt_f64(1.5), "1.5");
        assert_eq!(fmt_f64(2.0), "2");
        assert_eq!(fmt_f64(f64::NAN), "0");
        assert_eq!(fmt_f64(0.000001), "0.000001");
    }
}
