//! Streaming time-series: fixed-capacity ring-buffer series sampled at
//! modeled-time drain boundaries.
//!
//! Where [`super::metrics`] answers "what are the totals now", a
//! [`TimeSeries`] answers "how did this signal move" — each sample is
//! a `(SimTime, f64)` point taken by the coordinator (or the fleet
//! router) at the end of a drain, in BOTH exec modes, so the series is
//! as deterministic as the modeled timeline itself. Two kinds:
//!
//! * **counter** series store per-sample *deltas* of a monotonic
//!   total (`push_counter` takes the cumulative value and diffs it
//!   against the previous push), so windowed sums — the input of the
//!   SLO burn-rate rules in [`super::alert`] — are a plain range sum;
//! * **gauge** series store point-in-time readings (queue depth, p99,
//!   per-worker utilization, per-drain arrival counts).
//!
//! A [`SeriesBank`] owns the series of one telemetry scope (one
//! coordinator, one fleet) with deterministic get-or-create order, and
//! knows how to fold last-values into a [`MetricsRegistry`] snapshot.
//! JSON export (`secda-timeseries-v1`) and Perfetto counter tracks
//! live in [`super::export`].
//!
//! Telemetry is inert by construction, like span tracing: sampling
//! only reads values the serving layer already computed, so outputs
//! and modeled timelines are bit-identical with telemetry on or off
//! (pinned by `prop_telemetry_is_inert`).

use std::collections::VecDeque;

use crate::sysc::SimTime;

use super::metrics::MetricsRegistry;

/// Canonical series names sampled by the serving layers (coordinator
/// and fleet use the same taxonomy so one alert engine reads both).
pub mod names {
    /// Counter: requests accepted into the queue.
    pub const SUBMITTED: &str = "submitted";
    /// Counter: requests completed.
    pub const COMPLETED: &str = "completed";
    /// Counter: requests shed by predictive admission control.
    pub const SHED: &str = "shed";
    /// Counter: work-stealing events.
    pub const STEALS: &str = "steals";
    /// Counter: SLO-carrying requests that met their deadline.
    pub const SLO_ATTAINED: &str = "slo_attained";
    /// Counter: SLO-carrying requests that missed their deadline.
    pub const SLO_MISSED: &str = "slo_missed";
    /// Gauge: peak queue depth seen so far.
    pub const QUEUE_PEAK: &str = "queue_peak";
    /// Gauge: modeled throughput (requests per modeled second).
    pub const REQ_S: &str = "req_s";
    /// Gauge: p99 end-to-end latency, milliseconds.
    pub const LATENCY_P99_MS: &str = "latency_p99_ms";
    /// Gauge: fraction of SLO-carrying requests that met the deadline.
    pub const SLO_ATTAINMENT: &str = "slo_attainment";
    /// Gauge: requests completed by the drain that took this sample —
    /// the arrival-rate signal the change-point detector watches.
    pub const DRAIN_REQUESTS: &str = "drain_requests";
    /// Gauge: mean end-to-end latency of that drain's completions, in
    /// milliseconds — the latency-shift signal.
    pub const DRAIN_LATENCY_MS: &str = "drain_latency_ms";
}

/// Configuration of the streaming telemetry engine
/// ([`crate::coordinator::CoordinatorConfig::telemetry`],
/// [`crate::fleet::FleetConfig::with_telemetry`]).
#[derive(Debug, Clone)]
pub struct TelemetryConfig {
    /// Ring capacity per series; the oldest points drop beyond it
    /// (the drop count is kept, nothing else is lost silently).
    pub capacity: usize,
    /// SLO attainment objective the burn-rate rules guard: the target
    /// fraction of SLO-carrying requests that meet their deadline.
    pub slo_objective: f64,
    /// Fast burn-rate evidence window (catches sharp burns).
    pub burn_fast: SimTime,
    /// Slow burn-rate evidence window (filters blips: both windows
    /// must burn before the alert fires).
    pub burn_slow: SimTime,
    /// Error-budget burn factor both windows must exceed to fire
    /// (1.0 = burning exactly the budget).
    pub burn_factor: f64,
    /// Feed the change-point trend signal into the elastic
    /// controller's estimator ([`crate::elastic::TrafficProfile::
    /// trend`]), letting a planned swap begin one eval-interval early.
    /// Off by default so telemetry stays a pure observer.
    pub feed_trend: bool,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            capacity: 1024,
            slo_objective: 0.99,
            burn_fast: SimTime::ms(250),
            burn_slow: SimTime::ms(2_000),
            burn_factor: 2.0,
            feed_trend: false,
        }
    }
}

/// What a series' points mean.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeriesKind {
    /// Per-sample deltas of a monotonic total.
    Counter,
    /// Point-in-time readings.
    Gauge,
}

impl SeriesKind {
    /// Stable exported name.
    pub fn name(&self) -> &'static str {
        match self {
            SeriesKind::Counter => "counter",
            SeriesKind::Gauge => "gauge",
        }
    }
}

/// One fixed-capacity ring-buffer series.
#[derive(Debug, Clone)]
pub struct TimeSeries {
    name: String,
    kind: SeriesKind,
    cap: usize,
    points: VecDeque<(SimTime, f64)>,
    dropped: u64,
    /// Counters only: the cumulative total of the previous push, so
    /// the stored point is the delta.
    last_total: u64,
}

impl TimeSeries {
    fn new(name: &str, kind: SeriesKind, cap: usize) -> Self {
        TimeSeries {
            name: name.to_string(),
            kind,
            cap: cap.max(1),
            points: VecDeque::new(),
            dropped: 0,
            last_total: 0,
        }
    }

    /// Series name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Counter or gauge.
    pub fn kind(&self) -> SeriesKind {
        self.kind
    }

    /// Number of retained points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when no point has been retained.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Points evicted by the ring capacity.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Retained points, oldest first.
    pub fn points(&self) -> impl Iterator<Item = (SimTime, f64)> + '_ {
        self.points.iter().copied()
    }

    /// The most recent point.
    pub fn last(&self) -> Option<(SimTime, f64)> {
        self.points.back().copied()
    }

    /// Counters only: the cumulative total as of the last push.
    pub fn total(&self) -> u64 {
        self.last_total
    }

    fn push(&mut self, at: SimTime, v: f64) {
        if self.points.len() == self.cap {
            self.points.pop_front();
            self.dropped += 1;
        }
        self.points.push_back((at, v));
    }

    /// Record a gauge reading.
    pub fn push_gauge(&mut self, at: SimTime, v: f64) {
        debug_assert_eq!(self.kind, SeriesKind::Gauge);
        self.push(at, v);
    }

    /// Record a counter sample from its *cumulative* total; the stored
    /// point is the delta since the previous push (the first push
    /// stores the whole total). Totals are monotonic, so a saturating
    /// diff never goes negative.
    pub fn push_counter(&mut self, at: SimTime, total: u64) {
        debug_assert_eq!(self.kind, SeriesKind::Counter);
        let delta = total.saturating_sub(self.last_total);
        self.last_total = total;
        self.push(at, delta as f64);
    }

    /// Sum of retained point values stamped after `since` (exclusive)
    /// — for a counter, the total increment over the window
    /// `(since, latest]`.
    pub fn sum_since(&self, since: SimTime) -> f64 {
        self.points
            .iter()
            .filter(|(t, _)| *t > since)
            .map(|(_, v)| v)
            .sum()
    }
}

/// The series of one telemetry scope, with deterministic get-or-create
/// order (insertion order is preserved, so exports and registry folds
/// are stable).
#[derive(Debug, Clone)]
pub struct SeriesBank {
    cap: usize,
    series: Vec<TimeSeries>,
}

impl SeriesBank {
    /// An empty bank whose series retain `capacity` points each.
    pub fn new(capacity: usize) -> Self {
        SeriesBank {
            cap: capacity.max(1),
            series: Vec::new(),
        }
    }

    fn get_or_create(&mut self, name: &str, kind: SeriesKind) -> &mut TimeSeries {
        if let Some(i) = self.series.iter().position(|s| s.name == name) {
            debug_assert_eq!(self.series[i].kind, kind, "series {name} kind changed");
            return &mut self.series[i];
        }
        self.series.push(TimeSeries::new(name, kind, self.cap));
        self.series.last_mut().expect("just pushed")
    }

    /// The counter series `name`, created on first use.
    pub fn counter(&mut self, name: &str) -> &mut TimeSeries {
        self.get_or_create(name, SeriesKind::Counter)
    }

    /// The gauge series `name`, created on first use.
    pub fn gauge(&mut self, name: &str) -> &mut TimeSeries {
        self.get_or_create(name, SeriesKind::Gauge)
    }

    /// Look up a series by name.
    pub fn get(&self, name: &str) -> Option<&TimeSeries> {
        self.series.iter().find(|s| s.name == name)
    }

    /// All series, in creation order.
    pub fn iter(&self) -> impl Iterator<Item = &TimeSeries> {
        self.series.iter()
    }

    /// Number of series.
    pub fn len(&self) -> usize {
        self.series.len()
    }

    /// True when no series has been created.
    pub fn is_empty(&self) -> bool {
        self.series.is_empty()
    }

    /// Fold the bank into a metrics snapshot: per series, the running
    /// total (counters) or last reading (gauges) plus the retained
    /// sample count, under `series.<name>.*`.
    pub fn register_into(&self, reg: &mut MetricsRegistry) {
        for s in &self.series {
            match s.kind {
                SeriesKind::Counter => {
                    reg.counter(&format!("series.{}.total", s.name), s.total());
                }
                SeriesKind::Gauge => {
                    reg.gauge(
                        &format!("series.{}.last", s.name),
                        s.last().map(|(_, v)| v).unwrap_or(0.0),
                    );
                }
            }
            reg.counter(&format!("series.{}.samples", s.name), s.len() as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_stores_deltas_of_the_cumulative_total() {
        let mut s = TimeSeries::new("completed", SeriesKind::Counter, 8);
        s.push_counter(SimTime::ms(10), 4);
        s.push_counter(SimTime::ms(20), 9);
        s.push_counter(SimTime::ms(30), 9);
        let pts: Vec<_> = s.points().collect();
        assert_eq!(
            pts,
            vec![
                (SimTime::ms(10), 4.0),
                (SimTime::ms(20), 5.0),
                (SimTime::ms(30), 0.0)
            ]
        );
        assert_eq!(s.total(), 9);
        // window sums over the deltas
        assert_eq!(s.sum_since(SimTime::ms(10)), 5.0);
        assert_eq!(s.sum_since(SimTime::ZERO), 9.0);
    }

    #[test]
    fn ring_capacity_drops_oldest_and_counts_it() {
        let mut s = TimeSeries::new("q", SeriesKind::Gauge, 3);
        for i in 0..5u64 {
            s.push_gauge(SimTime::ms(i), i as f64);
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.dropped(), 2);
        let pts: Vec<_> = s.points().collect();
        assert_eq!(pts[0], (SimTime::ms(2), 2.0));
        assert_eq!(s.last(), Some((SimTime::ms(4), 4.0)));
    }

    #[test]
    fn bank_is_get_or_create_in_stable_order() {
        let mut b = SeriesBank::new(16);
        b.counter(names::COMPLETED).push_counter(SimTime::ms(1), 2);
        b.gauge(names::QUEUE_PEAK).push_gauge(SimTime::ms(1), 3.0);
        b.counter(names::COMPLETED).push_counter(SimTime::ms(2), 5);
        assert_eq!(b.len(), 2);
        let order: Vec<&str> = b.iter().map(|s| s.name()).collect();
        assert_eq!(order, vec![names::COMPLETED, names::QUEUE_PEAK]);
        assert_eq!(b.get(names::COMPLETED).unwrap().len(), 2);

        let mut reg = MetricsRegistry::new();
        b.register_into(&mut reg);
        assert_eq!(
            reg.get("series.completed.total"),
            Some(&crate::obs::MetricValue::Counter(5))
        );
        assert_eq!(
            reg.get("series.queue_peak.last"),
            Some(&crate::obs::MetricValue::Gauge(3.0))
        );
    }
}
