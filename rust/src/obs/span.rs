//! Structured spans and the shared, thread-safe span recorder.
//!
//! The recorder follows the [`crate::sysc::Trace`] discipline: a
//! disabled recorder costs exactly one branch per call site, and all
//! span construction (allocation, formatting, attribute assembly)
//! happens inside a closure that a disabled recorder never invokes.
//! Unlike `sysc::Trace` it is `Sync` — under
//! [`crate::coordinator::ExecMode::Threaded`] every pool worker
//! records into the same instance.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::sysc::SimTime;

/// Which lifecycle stage a [`Span`] covers.
///
/// The serving stages mirror a request's path through the
/// coordinator; the elastic stages cover the reconfiguration loop.
/// See ARCHITECTURE.md ("Observability layer") for the full taxonomy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// A request entered `submit_*` (instant; carries the model name).
    Submit,
    /// The admission verdict: `admitted`, `backpressure` or `shed`.
    Admission,
    /// From arrival to the start of execution on the chosen worker.
    QueueWait,
    /// One batch round on one worker (window + execution).
    Batch,
    /// One request's end-to-end execution (all layers).
    Request,
    /// One GEMM inside a request: accelerator offload or CPU fallback.
    Gemm,
    /// One non-GEMM operator inside a request (pool, softmax, ...).
    Op,
    /// One bridged simulator [`crate::sysc::Trace`] entry (instant).
    SimEvent,
    /// The traffic window the elastic estimator summarized.
    EstimatorWindow,
    /// The elastic planner emitted a reconfiguration plan (instant).
    Plan,
    /// A fabric reconfiguration (bitstream load) in progress.
    Reconfigure,
    /// A telemetry alert fired (instant; carries kind, series and the
    /// window evidence as attributes).
    Alert,
}

impl Stage {
    /// The stable exported name of this stage.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Submit => "submit",
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::Batch => "batch",
            Stage::Request => "request",
            Stage::Gemm => "gemm",
            Stage::Op => "op",
            Stage::SimEvent => "sim_event",
            Stage::EstimatorWindow => "estimator_window",
            Stage::Plan => "plan",
            Stage::Reconfigure => "reconfigure",
            Stage::Alert => "alert",
        }
    }
}

/// One recorded interval (or instant, when `t_start == t_end`) of
/// modeled time, optionally doubled with host wall-clock timestamps.
#[derive(Debug, Clone)]
pub struct Span {
    /// The request this span belongs to, if any (elastic-layer spans
    /// and rejected submissions have none).
    pub request_id: Option<u64>,
    /// Lifecycle stage.
    pub stage: Stage,
    /// The pool worker involved, if any.
    pub worker: Option<usize>,
    /// Start, in modeled time.
    pub t_start: SimTime,
    /// End, in modeled time (equal to `t_start` for instants).
    pub t_end: SimTime,
    /// Free-form key/value attributes (model, route, shape, verdict...).
    pub attrs: Vec<(&'static str, String)>,
    /// Host wall-clock `(start_ns, end_ns)` relative to the recorder
    /// epoch — only set for batch spans under threaded execution.
    pub wall_ns: Option<(u64, u64)>,
}

impl Span {
    /// A span with no request, worker, attributes or wall clock —
    /// a convenient base to build from inside `record` closures.
    pub fn new(stage: Stage, t_start: SimTime, t_end: SimTime) -> Self {
        Span {
            request_id: None,
            stage,
            worker: None,
            t_start,
            t_end,
            attrs: Vec::new(),
            wall_ns: None,
        }
    }

    /// An instant span (zero duration) at `t`.
    pub fn instant(stage: Stage, t: SimTime) -> Self {
        Span::new(stage, t, t)
    }

    /// The modeled duration.
    pub fn duration(&self) -> SimTime {
        self.t_end.saturating_sub(self.t_start)
    }
}

/// A bounded, thread-safe recorder of [`Span`]s.
///
/// Disabled (the default) it records nothing and costs one branch.
/// Enabled it keeps up to `cap` spans and counts the rest as dropped,
/// so tracing can never grow without bound on a long serving run.
#[derive(Debug)]
pub struct SpanRecorder {
    enabled: bool,
    cap: usize,
    epoch: Instant,
    spans: Mutex<Vec<Span>>,
    dropped: AtomicU64,
}

impl Default for SpanRecorder {
    fn default() -> Self {
        SpanRecorder::disabled()
    }
}

impl SpanRecorder {
    /// A disabled recorder: records nothing, costs one branch per call.
    pub fn disabled() -> Self {
        SpanRecorder {
            enabled: false,
            cap: 0,
            epoch: Instant::now(),
            spans: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
        }
    }

    /// An enabled recorder keeping at most `cap` spans.
    pub fn enabled(cap: usize) -> Self {
        SpanRecorder {
            enabled: true,
            cap,
            epoch: Instant::now(),
            spans: Mutex::new(Vec::with_capacity(cap.min(4096))),
            dropped: AtomicU64::new(0),
        }
    }

    /// Whether this recorder stores anything. Call sites gate all
    /// span assembly behind this so a disabled recorder stays free.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record one span. `build` is a closure so a disabled recorder
    /// never pays for span construction.
    #[inline]
    pub fn record(&self, build: impl FnOnce() -> Span) {
        if !self.enabled {
            return;
        }
        let span = build();
        let mut spans = self.spans.lock().expect("span recorder poisoned");
        if spans.len() >= self.cap {
            drop(spans);
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        spans.push(span);
    }

    /// Nanoseconds of host wall clock since this recorder was created.
    /// Used to double-stamp batch spans under threaded execution.
    pub fn wall_now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Number of spans recorded so far.
    pub fn len(&self) -> usize {
        self.spans.lock().expect("span recorder poisoned").len()
    }

    /// Whether no spans have been recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans dropped after the cap filled.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// A copy of every recorded span, in record order.
    pub fn snapshot(&self) -> Vec<Span> {
        self.spans.lock().expect("span recorder poisoned").clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let r = SpanRecorder::disabled();
        r.record(|| panic!("disabled recorder must never build a span"));
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn enabled_recorder_caps_and_counts_drops() {
        let r = SpanRecorder::enabled(2);
        for i in 0..5u64 {
            r.record(|| {
                let mut s = Span::instant(Stage::Submit, SimTime::ns(i));
                s.request_id = Some(i);
                s
            });
        }
        assert_eq!(r.len(), 2);
        assert_eq!(r.dropped(), 3);
        let spans = r.snapshot();
        assert_eq!(spans[0].request_id, Some(0));
        assert_eq!(spans[1].request_id, Some(1));
    }

    #[test]
    fn recorder_is_shareable_across_threads() {
        let r = std::sync::Arc::new(SpanRecorder::enabled(100));
        std::thread::scope(|scope| {
            for w in 0..4usize {
                let r = r.clone();
                scope.spawn(move || {
                    for i in 0..10u64 {
                        r.record(|| {
                            let mut s =
                                Span::new(Stage::Batch, SimTime::ns(i), SimTime::ns(i + 1));
                            s.worker = Some(w);
                            s.wall_ns = Some((r.wall_now_ns(), r.wall_now_ns()));
                            s
                        });
                    }
                });
            }
        });
        assert_eq!(r.len(), 40);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn span_duration_saturates() {
        let s = Span::new(Stage::Request, SimTime::ns(10), SimTime::ns(4));
        assert_eq!(s.duration(), SimTime::ZERO);
        assert_eq!(Span::instant(Stage::Plan, SimTime::ns(9)).duration(), SimTime::ZERO);
    }
}
