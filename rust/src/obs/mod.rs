//! `obs` — the observability layer: structured spans, streaming
//! metrics, and trace exporters for the serving stack.
//!
//! SECDA's methodology is to "quickly and iteratively explore the
//! hardware/software stack while identifying and mitigating
//! performance bottlenecks" (§III). Aggregate tail numbers cannot
//! answer *where* a p99 request spent its time; this module can. It
//! provides three pieces:
//!
//! * [`span::SpanRecorder`] — a one-branch-when-disabled recorder of
//!   [`span::Span`]s covering the full request lifecycle (submit,
//!   admission verdict, queue wait, batch, per-request execution,
//!   per-GEMM accelerator/CPU work, simulator events) plus the
//!   elastic layer (estimator window, plan, reconfiguration). Spans
//!   are stamped in modeled [`crate::sysc::SimTime`] in both exec
//!   modes, and additionally in host wall-clock under
//!   [`crate::coordinator::ExecMode::Threaded`].
//! * [`metrics::Histogram`] / [`metrics::MetricsRegistry`] — streaming
//!   counters and fixed-bucket log-scale histograms: O(1) recording,
//!   O(buckets) quantile queries, no clone-and-sort.
//! * [`export`] — Chrome trace-event JSON (loadable in Perfetto or
//!   `chrome://tracing`: one track per worker, async arrows from
//!   submit to completion, reconfigurations as instant events) and a
//!   flat metrics JSON snapshot, plus schema validators used by the
//!   `secda trace-validate` subcommand and CI.
//! * [`timeseries`] / [`alert`] — the streaming telemetry engine:
//!   fixed-capacity ring-buffer series sampled at drain boundaries,
//!   multi-window SLO burn-rate rules and EWMA/CUSUM change-point
//!   detection over them, and a continuous trend signal the elastic
//!   controller can consume for predictive reprovisioning.
//! * [`profile`] — continuous-profiling attribution: fold batch/
//!   request/GEMM/op slices into a per-(layer, route, worker kind)
//!   self-time profile with collapsed-stack (flamegraph) export.
//!
//! Tracing and telemetry are *provably inert*: recording and sampling
//! only read values the coordinator already computed, so outputs are
//! bit-identical with them on or off (pinned by
//! `prop_tracing_is_inert` / `prop_telemetry_is_inert`).

pub mod alert;
pub mod export;
pub mod json;
pub mod metrics;
pub mod profile;
pub mod span;
pub mod timeseries;

pub use alert::{Alert, AlertEngine, AlertKind, ChangePoint};
pub use metrics::{Histogram, MetricValue, MetricsRegistry};
pub use profile::AttributionProfile;
pub use span::{Span, SpanRecorder, Stage};
pub use timeseries::{SeriesBank, SeriesKind, TelemetryConfig, TimeSeries};
