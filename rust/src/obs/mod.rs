//! `obs` — the observability layer: structured spans, streaming
//! metrics, and trace exporters for the serving stack.
//!
//! SECDA's methodology is to "quickly and iteratively explore the
//! hardware/software stack while identifying and mitigating
//! performance bottlenecks" (§III). Aggregate tail numbers cannot
//! answer *where* a p99 request spent its time; this module can. It
//! provides three pieces:
//!
//! * [`span::SpanRecorder`] — a one-branch-when-disabled recorder of
//!   [`span::Span`]s covering the full request lifecycle (submit,
//!   admission verdict, queue wait, batch, per-request execution,
//!   per-GEMM accelerator/CPU work, simulator events) plus the
//!   elastic layer (estimator window, plan, reconfiguration). Spans
//!   are stamped in modeled [`crate::sysc::SimTime`] in both exec
//!   modes, and additionally in host wall-clock under
//!   [`crate::coordinator::ExecMode::Threaded`].
//! * [`metrics::Histogram`] / [`metrics::MetricsRegistry`] — streaming
//!   counters and fixed-bucket log-scale histograms: O(1) recording,
//!   O(buckets) quantile queries, no clone-and-sort.
//! * [`export`] — Chrome trace-event JSON (loadable in Perfetto or
//!   `chrome://tracing`: one track per worker, async arrows from
//!   submit to completion, reconfigurations as instant events) and a
//!   flat metrics JSON snapshot, plus schema validators used by the
//!   `secda trace-validate` subcommand and CI.
//!
//! Tracing is *provably inert*: span recording only reads values the
//! coordinator already computed, so outputs are bit-identical with
//! tracing on or off (pinned by `prop_tracing_is_inert`).

pub mod export;
pub mod json;
pub mod metrics;
pub mod span;

pub use metrics::{Histogram, MetricValue, MetricsRegistry};
pub use span::{Span, SpanRecorder, Stage};
