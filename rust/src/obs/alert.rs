//! SLO burn-rate alerting and change-point detection over the
//! telemetry series.
//!
//! The [`AlertEngine`] is evaluated once per drain boundary, right
//! after the [`super::timeseries::SeriesBank`] was sampled, and emits
//! typed [`Alert`] records. Two rule families:
//!
//! * **Multi-window error-budget burn rate** (the SRE formulation):
//!   with objective `o`, the error budget is `1 - o`; the burn rate of
//!   a window is `windowed_error_rate / (1 - o)` computed from the
//!   `slo_attained` / `slo_missed` counter deltas. The alert fires
//!   when BOTH a fast and a slow window burn above the configured
//!   factor — the fast window catches the burn early, the slow window
//!   filters one-drain blips. The rule is latched: it re-arms only
//!   after the fast window drops back below the factor, so a sustained
//!   burn yields one alert, not one per drain.
//! * **EWMA/CUSUM change-point detection** on the per-drain latency
//!   and arrival-rate gauges: an exponentially-weighted mean/variance
//!   tracks the regime; a sample deviating by more than `k` sigma, or
//!   a CUSUM excursion beyond `h` sigma, fires a shift alert (also
//!   latched). The *unlatched* deviation magnitude is exposed as
//!   [`AlertEngine::trend`] — a continuous early-warning signal the
//!   elastic controller's estimator can consume
//!   ([`crate::elastic::TrafficProfile::trend`]) to begin a planned
//!   swap one eval-interval before the reactive window catches up.
//!
//! Everything here is pure arithmetic over already-sampled series:
//! evaluating alerts never touches the modeled timeline.

use crate::sysc::SimTime;

use super::timeseries::{names, SeriesBank, TelemetryConfig};

/// What kind of rule fired.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertKind {
    /// Multi-window SLO error-budget burn.
    BurnRate,
    /// Change-point on the per-drain latency gauge.
    LatencyShift,
    /// Change-point on the per-drain arrival-rate gauge.
    ArrivalShift,
}

impl AlertKind {
    /// Stable exported name.
    pub fn name(&self) -> &'static str {
        match self {
            AlertKind::BurnRate => "burn_rate",
            AlertKind::LatencyShift => "latency_shift",
            AlertKind::ArrivalShift => "arrival_shift",
        }
    }

    /// Inverse of [`AlertKind::name`], for schema validation.
    pub fn from_name(s: &str) -> Option<AlertKind> {
        match s {
            "burn_rate" => Some(AlertKind::BurnRate),
            "latency_shift" => Some(AlertKind::LatencyShift),
            "arrival_shift" => Some(AlertKind::ArrivalShift),
            _ => None,
        }
    }
}

/// One fired alert: when, which rule, over which series, and the
/// window evidence that crossed the threshold.
#[derive(Debug, Clone, PartialEq)]
pub struct Alert {
    /// Modeled firing time (the drain boundary that evaluated it).
    pub at: SimTime,
    /// Rule family.
    pub kind: AlertKind,
    /// Series the rule evaluated.
    pub series: String,
    /// Observed value: burn rate (BurnRate) or sigma-normalized
    /// deviation (shift alerts).
    pub value: f64,
    /// Threshold the value crossed (burn factor, or 1.0 for the
    /// normalized shift deviation).
    pub threshold: f64,
    /// Evidence window (the slow burn window, or the EWMA horizon for
    /// shifts).
    pub window: SimTime,
}

/// EWMA mean/variance tracker with a CUSUM excursion detector.
///
/// `observe` feeds one sample; [`ChangePoint::deviation`] then reports
/// the sigma-normalized shift magnitude of that sample, normalized so
/// 1.0 is exactly at threshold: `max(|z|/k, s+/h, s-/h)`.
#[derive(Debug, Clone)]
pub struct ChangePoint {
    alpha: f64,
    k: f64,
    h: f64,
    drift: f64,
    warmup: usize,
    seen: usize,
    mean: f64,
    var: f64,
    s_pos: f64,
    s_neg: f64,
    deviation: f64,
    direction: f64,
}

impl ChangePoint {
    /// A detector with EWMA weight `alpha`, a `k`-sigma point
    /// threshold, a CUSUM decision interval of `h` sigma (with half a
    /// sigma of slack), and `warmup` samples of pure learning before
    /// anything can fire.
    pub fn new(alpha: f64, k: f64, h: f64, warmup: usize) -> Self {
        ChangePoint {
            alpha,
            k,
            h,
            drift: 0.5,
            warmup: warmup.max(1),
            seen: 0,
            mean: 0.0,
            var: 0.0,
            s_pos: 0.0,
            s_neg: 0.0,
            deviation: 0.0,
            direction: 0.0,
        }
    }

    /// Feed one sample; true when it crosses the EWMA or CUSUM
    /// threshold (after warmup).
    pub fn observe(&mut self, x: f64) -> bool {
        if self.seen < self.warmup {
            // Pure learning: seed the mean with a plain running
            // average so the first samples don't anchor at zero.
            self.seen += 1;
            let n = self.seen as f64;
            let prev = self.mean;
            self.mean += (x - self.mean) / n;
            self.var += (x - prev) * (x - self.mean);
            if self.seen == self.warmup {
                self.var /= n;
            }
            self.deviation = 0.0;
            self.direction = 0.0;
            return false;
        }
        // Sigma floor: a perfectly flat warmup must not make every
        // later sample an infinite-sigma shift.
        let sigma = self.var.sqrt().max(self.mean.abs() * 0.05).max(1e-9);
        let z = (x - self.mean) / sigma;
        self.s_pos = (self.s_pos + z - self.drift).max(0.0);
        self.s_neg = (self.s_neg - z - self.drift).max(0.0);
        self.deviation = (z.abs() / self.k)
            .max(self.s_pos / self.h)
            .max(self.s_neg / self.h);
        self.direction = if z >= 0.0 { 1.0 } else { -1.0 };
        let fired = self.deviation >= 1.0;
        // Keep adapting so the tracker converges onto the new regime
        // and the deviation decays once the shift is absorbed.
        let d = x - self.mean;
        self.mean += self.alpha * d;
        self.var = (1.0 - self.alpha) * (self.var + self.alpha * d * d);
        fired
    }

    /// Sigma-normalized deviation of the last sample (1.0 = exactly at
    /// threshold); 0.0 during warmup.
    pub fn deviation(&self) -> f64 {
        self.deviation
    }

    /// Sign of the last deviation: +1.0 upward, -1.0 downward.
    pub fn direction(&self) -> f64 {
        self.direction
    }
}

/// Latching state for one rule.
#[derive(Debug, Clone, Default)]
struct Latch {
    armed_off: bool,
}

impl Latch {
    /// Returns true exactly once per excursion: on the first `hot`
    /// after a cool period.
    fn fire(&mut self, hot: bool) -> bool {
        let fresh = hot && !self.armed_off;
        self.armed_off = hot;
        fresh
    }
}

/// The per-scope alert evaluator: burn-rate over the SLO counters,
/// change-points over the drain gauges.
#[derive(Debug, Clone)]
pub struct AlertEngine {
    cfg: TelemetryConfig,
    latency_cp: ChangePoint,
    arrival_cp: ChangePoint,
    burn_latch: Latch,
    latency_latch: Latch,
    arrival_latch: Latch,
    alerts: Vec<Alert>,
    trend: f64,
}

impl AlertEngine {
    /// An engine with the scope's telemetry configuration.
    pub fn new(cfg: &TelemetryConfig) -> Self {
        AlertEngine {
            cfg: cfg.clone(),
            latency_cp: ChangePoint::new(0.2, 4.0, 5.0, 3),
            arrival_cp: ChangePoint::new(0.2, 4.0, 5.0, 3),
            burn_latch: Latch::default(),
            latency_latch: Latch::default(),
            arrival_latch: Latch::default(),
            alerts: Vec::new(),
            trend: 0.0,
        }
    }

    /// Burn rate of the error budget over `(now - window, now]`:
    /// `error_rate / (1 - objective)`. 0.0 when the window carried no
    /// SLO traffic.
    fn burn_rate(&self, bank: &SeriesBank, now: SimTime, window: SimTime) -> f64 {
        let since = now.saturating_sub(window);
        let att = bank
            .get(names::SLO_ATTAINED)
            .map(|s| s.sum_since(since))
            .unwrap_or(0.0);
        let miss = bank
            .get(names::SLO_MISSED)
            .map(|s| s.sum_since(since))
            .unwrap_or(0.0);
        let total = att + miss;
        if total <= 0.0 {
            return 0.0;
        }
        let budget = (1.0 - self.cfg.slo_objective).max(1e-9);
        (miss / total) / budget
    }

    /// Evaluate every rule against the freshly-sampled bank. Returns
    /// the alerts that fired at this boundary (also appended to
    /// [`AlertEngine::alerts`]).
    pub fn evaluate(&mut self, now: SimTime, bank: &SeriesBank) -> Vec<Alert> {
        let mut fired = Vec::new();

        // Multi-window burn rate: both windows must burn.
        let fast = self.burn_rate(bank, now, self.cfg.burn_fast);
        let slow = self.burn_rate(bank, now, self.cfg.burn_slow);
        let hot = fast > self.cfg.burn_factor && slow > self.cfg.burn_factor;
        if self.burn_latch.fire(hot) {
            fired.push(Alert {
                at: now,
                kind: AlertKind::BurnRate,
                series: names::SLO_MISSED.to_string(),
                value: fast.min(slow),
                threshold: self.cfg.burn_factor,
                window: self.cfg.burn_slow,
            });
        }

        // Change-points on the per-drain gauges. Each drain pushes
        // exactly one sample, so the latest point is the new one.
        let mut shift = |cp: &mut ChangePoint,
                         latch: &mut Latch,
                         series: &str,
                         kind: AlertKind,
                         window: SimTime|
         -> (f64, Option<Alert>) {
            let Some((_, x)) = bank.get(series).and_then(|s| s.last()) else {
                return (0.0, None);
            };
            let hot = cp.observe(x);
            let alert = latch.fire(hot).then(|| Alert {
                at: now,
                kind,
                series: series.to_string(),
                value: cp.deviation(),
                threshold: 1.0,
                window,
            });
            (cp.deviation() * cp.direction(), alert)
        };
        let horizon = self.cfg.burn_slow;
        let (lat_dev, lat_alert) = shift(
            &mut self.latency_cp,
            &mut self.latency_latch,
            names::DRAIN_LATENCY_MS,
            AlertKind::LatencyShift,
            horizon,
        );
        let (arr_dev, arr_alert) = shift(
            &mut self.arrival_cp,
            &mut self.arrival_latch,
            names::DRAIN_REQUESTS,
            AlertKind::ArrivalShift,
            horizon,
        );
        fired.extend(lat_alert);
        fired.extend(arr_alert);

        // The trend signal stays continuous (unlatched): it reports
        // the regime deviation every drain while the shift persists,
        // so a rate-limited elastic evaluation can still catch it on
        // the next boundary. Only above-threshold deviations count —
        // in-regime noise must not trigger early evaluations.
        let dom = if lat_dev.abs() >= arr_dev.abs() {
            lat_dev
        } else {
            arr_dev
        };
        self.trend = if dom.abs() >= 1.0 { dom } else { 0.0 };

        self.alerts.extend(fired.iter().cloned());
        fired
    }

    /// Every alert fired so far, in firing order.
    pub fn alerts(&self) -> &[Alert] {
        &self.alerts
    }

    /// Continuous change-point trend signal: 0.0 in-regime, else the
    /// signed sigma-normalized deviation (>= 1.0 in magnitude) of the
    /// dominant shifted gauge.
    pub fn trend(&self) -> f64 {
        self.trend
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slo_bank(phases: &[(u64, u64, u64)]) -> (SeriesBank, SimTime) {
        // phases: (at_ms, cumulative attained, cumulative missed)
        let mut b = SeriesBank::new(64);
        let mut last = SimTime::ZERO;
        for &(at, att, miss) in phases {
            last = SimTime::ms(at);
            b.counter(names::SLO_ATTAINED).push_counter(last, att);
            b.counter(names::SLO_MISSED).push_counter(last, miss);
        }
        (b, last)
    }

    #[test]
    fn burn_rate_fires_once_and_rearms_after_cooling() {
        let cfg = TelemetryConfig {
            slo_objective: 0.9,
            burn_fast: SimTime::ms(50),
            burn_slow: SimTime::ms(200),
            burn_factor: 2.0,
            ..TelemetryConfig::default()
        };
        let mut eng = AlertEngine::new(&cfg);

        // Healthy traffic: no burn.
        let (bank, now) = slo_bank(&[(10, 10, 0), (20, 20, 0)]);
        assert!(eng.evaluate(now, &bank).is_empty());

        // Full-miss drain: both windows burn at 10x the budget.
        let (bank, now) = slo_bank(&[(10, 10, 0), (20, 20, 0), (30, 20, 8)]);
        let fired = eng.evaluate(now, &bank);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].kind, AlertKind::BurnRate);
        assert_eq!(fired[0].at, SimTime::ms(30));
        assert!(fired[0].value > cfg.burn_factor);
        assert_eq!(fired[0].window, cfg.burn_slow);

        // Still burning: latched, no second alert.
        let (bank, now) = slo_bank(&[(10, 10, 0), (20, 20, 0), (30, 20, 8), (40, 20, 16)]);
        assert!(eng.evaluate(now, &bank).is_empty());

        // Cool (healthy window) then burn again: re-fires.
        let (bank, now) = slo_bank(&[(240, 200, 16), (260, 400, 16)]);
        assert!(eng.evaluate(now, &bank).is_empty());
        let (bank, now) = slo_bank(&[(240, 200, 16), (260, 400, 16), (280, 400, 440)]);
        let fired = eng.evaluate(now, &bank);
        assert_eq!(fired.len(), 1);
        assert_eq!(eng.alerts().len(), 2);
    }

    #[test]
    fn change_point_fires_on_regime_shift_and_trend_is_continuous() {
        let mut cp = ChangePoint::new(0.2, 4.0, 5.0, 3);
        for _ in 0..6 {
            assert!(!cp.observe(10.0));
            assert!(cp.deviation() < 1.0);
        }
        // 10x jump: immediate k-sigma violation.
        assert!(cp.observe(100.0));
        assert!(cp.deviation() >= 1.0);
        assert_eq!(cp.direction(), 1.0);
        // The tracker adapts: after enough samples at the new level
        // the deviation decays back under threshold.
        let mut calmed = false;
        for _ in 0..64 {
            cp.observe(100.0);
            if cp.deviation() < 1.0 {
                calmed = true;
                break;
            }
        }
        assert!(calmed, "EWMA never absorbed the new regime");
    }

    #[test]
    fn engine_latency_shift_sets_trend_then_alert_latches() {
        let cfg = TelemetryConfig::default();
        let mut eng = AlertEngine::new(&cfg);
        let mut bank = SeriesBank::new(64);
        for i in 0..6u64 {
            bank.gauge(names::DRAIN_LATENCY_MS)
                .push_gauge(SimTime::ms(10 * (i + 1)), 5.0);
            bank.gauge(names::DRAIN_REQUESTS)
                .push_gauge(SimTime::ms(10 * (i + 1)), 4.0);
            let fired = eng.evaluate(SimTime::ms(10 * (i + 1)), &bank);
            assert!(fired.is_empty());
            assert_eq!(eng.trend(), 0.0);
        }
        bank.gauge(names::DRAIN_LATENCY_MS)
            .push_gauge(SimTime::ms(70), 80.0);
        bank.gauge(names::DRAIN_REQUESTS)
            .push_gauge(SimTime::ms(70), 4.0);
        let fired = eng.evaluate(SimTime::ms(70), &bank);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].kind, AlertKind::LatencyShift);
        assert!(eng.trend() >= 1.0, "trend = {}", eng.trend());
        // Latched alert, but the trend stays continuous while hot.
        bank.gauge(names::DRAIN_LATENCY_MS)
            .push_gauge(SimTime::ms(80), 80.0);
        bank.gauge(names::DRAIN_REQUESTS)
            .push_gauge(SimTime::ms(80), 4.0);
        let fired = eng.evaluate(SimTime::ms(80), &bank);
        assert!(fired.is_empty());
        assert!(eng.trend() >= 1.0);
    }

    #[test]
    fn alert_kind_names_round_trip() {
        for k in [
            AlertKind::BurnRate,
            AlertKind::LatencyShift,
            AlertKind::ArrivalShift,
        ] {
            assert_eq!(AlertKind::from_name(k.name()), Some(k));
        }
        assert_eq!(AlertKind::from_name("nope"), None);
    }
}
